//! `oseba` — the leader binary.
//!
//! Subcommands:
//! * `run`    — the paper's five-period interactive workload (Fig 4 + 6)
//!              with either method, printing the per-phase table.
//! * `batch`  — plan + execute N (possibly overlapping) selective queries
//!              as one concurrent batch, printing the merged plan, the
//!              per-query stats, and the partitions-touched savings.
//! * `serve`  — load a dataset and serve interactive range-stat queries
//!              over TCP (line-delimited JSON; see docs/PROTOCOL.md).
//!              With `--live`, start *empty* and accept `append` ops while
//!              serving snapshot-consistent queries.
//! * `ingest` — stream a CSV (file or stdin) into a running `serve --live`
//!              server as `append` requests.
//! * `index`  — build both indexes over a dataset and report their
//!              footprint and lookup behaviour.
//! * `save`   — generate a dataset and persist it as an `.oseg` segment
//!              store with a super-index manifest snapshot.
//! * `open`   — open a saved store (index restored without reading data)
//!              and optionally run one selective query against it.
//! * `info`   — print resolved config and artifact manifest summary.
//!
//! `batch` and `serve` accept `--memory-budget`: the dataset then lives in
//! a tiered store (`--spill-dir`, or a per-process temp directory) and
//! partitions beyond the budget spill to segments, faulting back in only
//! when the index targets them.

use std::sync::Arc;

use oseba::analysis::five_periods;
use oseba::cli::{bool_flag, flag, Cli};
use oseba::config::{parse_bytes, AppConfig, BackendKind};
use oseba::coordinator::{plan_batch, run_session, Coordinator, IndexKind, Method};
use oseba::datagen::ClimateGen;
use oseba::engine::{LiveConfig, MemoryTracker};
use oseba::error::{OsebaError, Result};
use oseba::index::{ContentIndex, RangeQuery};
use oseba::runtime::make_backend;
use oseba::server::QueryServer;
use oseba::storage::partition_batch_uniform;
use oseba::store::TieredStore;
use oseba::util::humansize;
use oseba::util::json::Json;
use oseba::util::rng::Xoshiro256;

fn cli() -> Cli {
    let common = || {
        vec![
            flag("size", "raw dataset bytes (k/m/g suffixes)", Some("64m")),
            flag("partitions", "number of partitions", Some("15")),
            flag("backend", "analysis backend: hlo | native", Some("hlo")),
            flag("artifacts", "artifacts directory", Some("artifacts")),
            flag("workers", "simulated cluster workers", Some("4")),
            flag("seed", "generator seed", Some("23274")),
            flag("net-latency-us", "simulated per-message latency (µs)", Some("0")),
        ]
    };
    Cli::new("oseba", "selective bulk analysis with content-aware indexing")
        .command("run", "run the five-period workload (Fig 4 + Fig 6)", {
            let mut f = common();
            f.push(flag("method", "default | oseba | both", Some("both")));
            f.push(flag("index", "table | cias", Some("cias")));
            f.push(flag("column", "column to analyze", Some("temperature")));
            f.push(flag("repeat", "session repetitions (profiling)", Some("1")));
            f.push(bool_flag("json", "emit metrics as JSON"));
            f
        })
        .command("batch", "plan + run N selective queries as one batch", {
            let mut f = common();
            f.push(flag("index", "table | cias", Some("cias")));
            f.push(flag("column", "column to analyze", Some("temperature")));
            f.push(flag("queries", "number of generated queries", Some("16")));
            f.push(flag(
                "width-pct",
                "generated query width, % of the key span",
                Some("8"),
            ));
            f.push(flag(
                "ranges",
                "explicit queries 'lo:hi,lo:hi,...' (overrides --queries)",
                None,
            ));
            f.push(flag(
                "where",
                "value predicates, e.g. 'temperature>30,humidity<=50'",
                None,
            ));
            f.push(flag(
                "memory-budget",
                "storage budget (k/m/g); excess partitions spill to disk",
                None,
            ));
            f.push(flag(
                "spill-dir",
                "tiered-store segment directory (default: per-process tmp)",
                None,
            ));
            f.push(bool_flag("json", "emit the batch report as JSON"));
            f
        })
        .command("serve", "serve interactive queries over TCP", {
            let mut f = common();
            f.push(flag("addr", "bind address", Some("127.0.0.1:7341")));
            f.push(flag("index", "table | cias", Some("cias")));
            f.push(bool_flag(
                "live",
                "start empty and accept `append` ops while serving (ignores --size)",
            ));
            f.push(flag(
                "schema",
                "live dataset schema: climate | stock | cdr",
                Some("climate"),
            ));
            f.push(flag(
                "rows-per-partition",
                "live mode: rows per sealed partition",
                Some("4096"),
            ));
            f.push(flag(
                "max-asl",
                "live mode: ASL length that triggers an index rebuild",
                Some("8"),
            ));
            f.push(flag(
                "memory-budget",
                "storage budget (k/m/g); excess partitions spill to disk",
                None,
            ));
            f.push(flag(
                "spill-dir",
                "tiered-store segment directory (default: per-process tmp)",
                None,
            ));
            f
        })
        .command("ingest", "stream a CSV into a running `serve --live` server", {
            vec![
                flag("addr", "server address", Some("127.0.0.1:7341")),
                flag("file", "CSV path ('-' for stdin)", Some("-")),
                flag("chunk-rows", "rows per append request", Some("2048")),
            ]
        })
        .command("index", "build and inspect both indexes", common())
        .command("save", "generate a dataset and persist it as a segment store", {
            let mut f = common();
            f.push(flag("dir", "store directory to write", Some("oseba-store")));
            f
        })
        .command("open", "open a saved store and optionally query it", {
            vec![
                flag("dir", "store directory to open", Some("oseba-store")),
                flag("backend", "analysis backend: hlo | native", Some("native")),
                flag("artifacts", "artifacts directory", Some("artifacts")),
                flag("workers", "simulated cluster workers", Some("4")),
                flag("memory-budget", "storage budget (k/m/g)", None),
                flag("column", "column to analyze (default: first column)", None),
                flag("lo", "query lower key (inclusive)", None),
                flag("hi", "query upper key (inclusive)", None),
            ]
        })
        .command("info", "print config and manifest summary", common())
}

fn app_config(p: &oseba::cli::Parsed) -> Result<AppConfig> {
    let cfg = AppConfig {
        dataset_bytes: parse_bytes(p.require("size")?)?,
        num_partitions: p.require_parse("partitions")?,
        backend: p.require("backend")?.parse()?,
        artifacts_dir: p.require("artifacts")?.to_string(),
        cluster_workers: p.require_parse("workers")?,
        seed: p.require_parse::<u64>("seed")?,
        net_latency_us: p.require_parse::<u64>("net-latency-us")?,
        ..AppConfig::default()
    };
    cfg.validate()?;
    Ok(cfg)
}

/// Generate the configured dataset, reporting its shape.
fn generate(cfg: &AppConfig, tiered_to: Option<&std::path::Path>) -> oseba::storage::RecordBatch {
    let gen = ClimateGen { seed: cfg.seed, ..Default::default() };
    let batch = gen.generate_bytes(cfg.dataset_bytes);
    let where_ = match tiered_to {
        Some(dir) => format!("tiered partitions (spill: {})", dir.display()),
        None => "partitions".to_string(),
    };
    eprintln!(
        "loaded {} rows ({}) into {} {where_}",
        batch.rows(),
        humansize::bytes(batch.raw_bytes()),
        cfg.num_partitions
    );
    batch
}

fn load(coord: &Coordinator, cfg: &AppConfig) -> Result<oseba::engine::Dataset> {
    coord.load(generate(cfg, None), cfg.num_partitions)
}

/// Removes an auto-created temp spill directory when dropped — covers
/// every exit path, error or success, of the command using it.
struct SpillCleanup(Option<std::path::PathBuf>);

impl Drop for SpillCleanup {
    fn drop(&mut self) {
        if let Some(dir) = self.0.take() {
            let _ = std::fs::remove_dir_all(dir);
        }
    }
}

/// Apply `--memory-budget` (if present) to the context config.
fn apply_budget(cfg: &mut AppConfig, p: &oseba::cli::Parsed) -> Result<()> {
    if let Some(b) = p.get("memory-budget") {
        cfg.ctx.memory_budget = Some(parse_bytes(b)?);
    }
    Ok(())
}

/// Load resident, or tiered when `--spill-dir`/`--memory-budget` asks for
/// it — under a budget the dataset must be able to exceed RAM, so it goes
/// through a [`TieredStore`] (spill segments in `--spill-dir` or a
/// per-process temp directory). The second return value is a directory to
/// delete when the command finishes: `Some` only for the auto temp
/// default, never for a user-chosen `--spill-dir`.
fn load_maybe_tiered(
    coord: &Coordinator,
    cfg: &AppConfig,
    p: &oseba::cli::Parsed,
) -> Result<(oseba::engine::Dataset, Option<std::path::PathBuf>)> {
    let (dir, cleanup) = match p.get("spill-dir") {
        Some(d) if !d.is_empty() => (Some(std::path::PathBuf::from(d)), None),
        _ => match cfg.ctx.memory_budget {
            Some(_) => {
                let d = std::env::temp_dir()
                    .join(format!("oseba-spill-{}", std::process::id()));
                (Some(d.clone()), Some(d))
            }
            None => (None, None),
        },
    };
    match dir {
        None => Ok((load(coord, cfg)?, None)),
        Some(dir) => {
            let batch = generate(cfg, Some(&dir));
            let ds = coord.load_tiered(batch, cfg.num_partitions, &dir)?;
            if let Some(store) = ds.store() {
                eprintln!(
                    "tiered load: {} resident of {} total, {} spilled to disk",
                    humansize::bytes(store.resident_bytes()),
                    humansize::bytes(store.total_bytes()),
                    store.counters().evictions
                );
            }
            Ok((ds, cleanup))
        }
    }
}

fn cmd_run(p: &oseba::cli::Parsed) -> Result<()> {
    let cfg = app_config(p)?;
    let index_kind: IndexKind = p.require("index")?.parse()?;
    let methods: Vec<Method> = match p.require("method")? {
        "both" => vec![Method::Default, Method::Oseba],
        m => vec![m.parse()?],
    };
    let column_name = p.require("column")?;

    let repeat: usize = p.require_parse("repeat")?;
    for method in methods {
        // Fresh coordinator per method: the paper measures each run from a
        // clean cluster state.
        let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
        let coord = Coordinator::new(&cfg, backend)?;
        let ds = load(&coord, &cfg)?;
        let column = ds.schema().column_index(column_name)?;
        let mut report =
            run_session(&coord, &ds, method, index_kind, &five_periods(), column, false)?;
        for _ in 1..repeat {
            report =
                run_session(&coord, &ds, method, index_kind, &five_periods(), column, false)?;
        }
        if let Some(s) = coord.analyzer().backend_stats() {
            println!(
                "kernel service: {} requests, {} executions, busy {:.3}s",
                s.requests, s.executions, s.busy_secs
            );
        }
        println!("\n== method: {} (backend: {}) ==", method.label(), coord.analyzer().backend_name());
        println!("{}", report.metrics.table());
        if method == Method::Oseba {
            println!("index: {} bytes ({index_kind:?})", report.index_bytes);
        }
        for (i, st) in report.stats.iter().enumerate() {
            println!(
                "phase {}: n={} max={:.3} min={:.3} mean={:.3} std={:.3}",
                i + 1,
                st.count,
                st.max,
                st.min,
                st.mean,
                st.std
            );
        }
        if p.get_bool("json") {
            println!("{}", report.metrics.to_json());
        }
    }
    Ok(())
}

/// Parse `lo:hi,lo:hi,...` into validated range queries.
fn parse_ranges(spec: &str) -> Result<Vec<RangeQuery>> {
    spec.split(',')
        .map(|s| {
            let (lo, hi) = s
                .split_once(':')
                .ok_or_else(|| OsebaError::Config(format!("bad range '{s}' (want lo:hi)")))?;
            let lo: i64 = lo
                .trim()
                .parse()
                .map_err(|_| OsebaError::Config(format!("bad lo in '{s}'")))?;
            let hi: i64 = hi
                .trim()
                .parse()
                .map_err(|_| OsebaError::Config(format!("bad hi in '{s}'")))?;
            RangeQuery::new(lo, hi)
        })
        .collect()
}

/// Generate `n` random queries of `width_frac` of the key span each;
/// placements are uniform, so wide batches overlap heavily — the workload
/// the planner exists for.
fn random_queries(
    n: usize,
    width_frac: f64,
    seed: u64,
    key_min: i64,
    key_max: i64,
) -> Vec<RangeQuery> {
    let span = (key_max - key_min) as f64;
    let width = (span * width_frac).max(1.0);
    let mut rng = Xoshiro256::seeded(seed);
    (0..n)
        .map(|_| {
            let lo = key_min + (rng.next_f64() * (span - width)) as i64;
            let hi = lo + width as i64;
            RangeQuery { lo, hi: hi.min(key_max) }
        })
        .collect()
}

fn cmd_batch(p: &oseba::cli::Parsed) -> Result<()> {
    let mut cfg = app_config(p)?;
    apply_budget(&mut cfg, p)?;
    let index_kind: IndexKind = p.require("index")?.parse()?;
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let coord = Coordinator::new(&cfg, backend)?;
    let (ds, cleanup) = load_maybe_tiered(&coord, &cfg, p)?;
    let _cleanup = SpillCleanup(cleanup);
    let column = ds.schema().column_index(p.require("column")?)?;

    let queries = match p.get("ranges") {
        Some(spec) if !spec.is_empty() => parse_ranges(spec)?,
        _ => {
            let n: usize = p.require_parse("queries")?;
            let width: f64 = p.require_parse::<f64>("width-pct")? / 100.0;
            let (Some(key_min), Some(key_max)) = (ds.key_min(), ds.key_max()) else {
                return Err(OsebaError::Config("generated dataset is empty".into()));
            };
            random_queries(n, width, cfg.seed, key_min, key_max)
        }
    };

    let predicates = match p.get("where") {
        Some(w) if !w.is_empty() => oseba::coordinator::parse_predicates(w, ds.schema())?,
        _ => Vec::new(),
    };

    // One index build serves the naive-cost comparison and the batch run.
    let index = coord.build_index(&ds, index_kind)?;
    let naive_touched: usize = queries.iter().map(|q| index.lookup(*q).len()).sum();

    let plan = plan_batch(&queries);
    println!("plan: {} queries -> {} merged ranges", queries.len(), plan.len());
    for pq in &plan {
        println!(
            "  [{}, {}] <- queries {:?}",
            pq.range.lo, pq.range.hi, pq.sources
        );
    }
    if !predicates.is_empty() {
        println!("where: {} predicate(s) pushed down to zone maps", predicates.len());
    }

    let before = coord.context().counters();
    let (stats, report) =
        coord.execute_batch(&ds, index.as_ref(), &queries, &predicates, column)?;
    let after = coord.context().counters();
    println!();
    for (i, (q, st)) in queries.iter().zip(&stats).enumerate() {
        println!(
            "query {i:>3} [{}, {}]: n={} max={:.3} min={:.3} mean={:.3} std={:.3}",
            q.lo, q.hi, st.count, st.max, st.min, st.mean, st.std
        );
    }
    println!("\n{}", report.line());
    let delta = after.partitions_targeted - before.partitions_targeted;
    println!(
        "partitions targeted: {delta} (naive per-query execution: {naive_touched})"
    );
    if let Some(store) = ds.store() {
        println!(
            "tiered: read {} of {} total ({} faults, {} evictions)",
            humansize::bytes(report.segment_bytes_read),
            humansize::bytes(store.total_bytes()),
            report.faults,
            report.evictions,
        );
    }
    println!("index: {} bytes ({index_kind:?})", index.memory_bytes());
    if p.get_bool("json") {
        println!("{}", report.to_json());
    }
    Ok(())
}

fn cmd_serve(p: &oseba::cli::Parsed) -> Result<()> {
    let mut cfg = app_config(p)?;
    apply_budget(&mut cfg, p)?;
    let index_kind: IndexKind = p.require("index")?.parse()?;
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let coord = Arc::new(Coordinator::new(&cfg, backend)?);
    let addr = p.require("addr")?;
    if p.get_bool("live") {
        return cmd_serve_live(p, &cfg, coord, addr);
    }
    let (ds, cleanup) = load_maybe_tiered(&coord, &cfg, p)?;
    let _cleanup = SpillCleanup(cleanup);
    let server = QueryServer::new(coord, ds, index_kind)?;
    eprintln!("serving on {addr} (op: info | stats | explain | shutdown)");
    server.serve(addr, |a| eprintln!("bound {a}"))
}

/// `serve --live`: start an empty live dataset (resident, or spilling when
/// a budget / spill dir is configured) and accept `append` ops alongside
/// snapshot-consistent queries.
fn cmd_serve_live(
    p: &oseba::cli::Parsed,
    cfg: &AppConfig,
    coord: Arc<Coordinator>,
    addr: &str,
) -> Result<()> {
    let schema = match p.require("schema")? {
        "climate" => oseba::storage::Schema::climate(),
        "stock" => oseba::storage::Schema::stock(),
        "cdr" => oseba::storage::Schema::cdr(),
        other => {
            return Err(OsebaError::Config(format!("unknown schema '{other}'")));
        }
    };
    let live_cfg = LiveConfig {
        rows_per_partition: p.require_parse("rows-per-partition")?,
        max_asl: p.require_parse("max-asl")?,
    };
    let spill_dir = match p.get("spill-dir") {
        Some(d) if !d.is_empty() => Some(std::path::PathBuf::from(d)),
        _ => cfg.ctx.memory_budget.map(|_| {
            std::env::temp_dir().join(format!("oseba-live-{}", std::process::id()))
        }),
    };
    let cleanup = match (p.get("spill-dir"), &spill_dir) {
        (Some(d), _) if !d.is_empty() => None, // user-chosen: keep
        (_, Some(d)) => Some(d.clone()),       // auto temp: remove on exit
        _ => None,
    };
    let _cleanup = SpillCleanup(cleanup);
    let live = match &spill_dir {
        Some(dir) => coord.create_live_spilling(schema, live_cfg, dir)?,
        None => coord.create_live(schema, live_cfg)?,
    };
    eprintln!(
        "serving LIVE on {addr} (op: info | stats | explain | append | snapshot | shutdown); \
         rows/partition {}, max ASL {}{}",
        live_cfg.rows_per_partition,
        live_cfg.max_asl,
        spill_dir
            .as_ref()
            .map(|d| format!(", spill: {}", d.display()))
            .unwrap_or_default()
    );
    let server = QueryServer::live(coord, live);
    server.serve(addr, |a| eprintln!("bound {a}"))
}

/// The `append` request for one buffered chunk of rows.
fn append_request(keys: &[i64], cols: &[Vec<f32>]) -> Json {
    Json::obj(vec![
        ("op", Json::str("append")),
        (
            "keys",
            Json::arr(keys.iter().map(|&k| Json::num(k as f64)).collect()),
        ),
        (
            "columns",
            Json::arr(
                cols.iter()
                    .map(|c| Json::arr(c.iter().map(|&v| Json::num(v as f64)).collect()))
                    .collect(),
            ),
        ),
    ])
}

/// `ingest`: stream a CSV into a running live server as `append` requests.
/// Rows are parsed and shipped incrementally — a chunk every `chunk_rows`
/// lines — so an unbounded pipe on stdin (a live feed) works and memory
/// stays O(chunk), not O(file).
fn cmd_ingest(p: &oseba::cli::Parsed) -> Result<()> {
    use std::io::{BufRead, BufReader, Write};

    let addr = p.require("addr")?;
    let file = p.require("file")?;
    let chunk_rows: usize = p.require_parse("chunk-rows")?;
    if chunk_rows == 0 {
        return Err(OsebaError::Config("chunk-rows must be > 0".into()));
    }
    let reader: Box<dyn BufRead> = if file == "-" {
        Box::new(BufReader::new(std::io::stdin()))
    } else {
        let f = std::fs::File::open(file).map_err(|e| OsebaError::io(file, e))?;
        Box::new(BufReader::new(f))
    };
    let mut lines = reader.lines();
    let header = lines
        .next()
        .ok_or_else(|| OsebaError::Schema("empty csv".into()))??;
    let width = header
        .split(',')
        .count()
        .checked_sub(1)
        .filter(|w| *w >= 1)
        .ok_or_else(|| {
            OsebaError::Schema("csv header needs a key column and value columns".into())
        })?;
    eprintln!("streaming '{file}' to {addr} in chunks of {chunk_rows} ({width} value columns)");

    let stream = std::net::TcpStream::connect(addr)?;
    let mut writer = stream.try_clone()?;
    let mut resp_reader = BufReader::new(stream);
    let mut ask = |req: &Json| -> Result<Json> {
        writer.write_all(req.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        let mut line = String::new();
        resp_reader.read_line(&mut line)?;
        let resp = Json::parse(line.trim())?;
        if resp.get("ok") != Some(&Json::Bool(true)) {
            let msg = resp
                .get("error")
                .and_then(|e| e.as_str())
                .unwrap_or("unknown server error");
            return Err(OsebaError::Ingest(format!("server rejected request: {msg}")));
        }
        Ok(resp)
    };

    let bad_row = |lineno: usize, msg: &str| {
        // +2: one for the header, one for 1-based numbering.
        OsebaError::Schema(format!("csv row {}: {msg}", lineno + 2))
    };
    let mut keys: Vec<i64> = Vec::with_capacity(chunk_rows);
    let mut cols: Vec<Vec<f32>> = vec![Vec::with_capacity(chunk_rows); width];
    let mut sent = 0usize;
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let mut fields = line.split(',').map(str::trim);
        let key: i64 = fields
            .next()
            .unwrap_or("")
            .parse()
            .map_err(|_| bad_row(lineno, "key not an integer"))?;
        keys.push(key);
        for (c, col) in cols.iter_mut().enumerate() {
            let f = fields
                .next()
                .ok_or_else(|| bad_row(lineno, &format!("missing column {}", c + 1)))?;
            col.push(f.parse().map_err(|_| bad_row(lineno, "value not a number"))?);
        }
        if fields.next().is_some() {
            return Err(bad_row(lineno, "too many columns"));
        }
        if keys.len() >= chunk_rows {
            let resp = ask(&append_request(&keys, &cols))?;
            sent += keys.len();
            keys.clear();
            for c in &mut cols {
                c.clear();
            }
            eprint!(
                "\r{sent} rows | epoch {} | sealed {} | unsealed {}   ",
                resp.get("epoch").and_then(|e| e.as_usize()).unwrap_or(0),
                resp.get("sealed_rows").and_then(|e| e.as_usize()).unwrap_or(0),
                resp.get("unsealed_rows").and_then(|e| e.as_usize()).unwrap_or(0),
            );
        }
    }
    if !keys.is_empty() {
        ask(&append_request(&keys, &cols))?;
        sent += keys.len();
    }
    eprintln!();
    let snap = ask(&Json::obj(vec![("op", Json::str("snapshot"))]))?;
    println!(
        "done: {sent} rows sent; server at epoch {} with {} partitions / {} rows \
         sealed ({} unsealed, asl {}, rebuilds {})",
        snap.get("epoch").and_then(|e| e.as_usize()).unwrap_or(0),
        snap.get("partitions").and_then(|e| e.as_usize()).unwrap_or(0),
        snap.get("rows").and_then(|e| e.as_usize()).unwrap_or(0),
        snap.get("unsealed_rows").and_then(|e| e.as_usize()).unwrap_or(0),
        snap.get("asl_len").and_then(|e| e.as_usize()).unwrap_or(0),
        snap.get("rebuilds").and_then(|e| e.as_usize()).unwrap_or(0),
    );
    Ok(())
}

fn cmd_index(p: &oseba::cli::Parsed) -> Result<()> {
    let cfg = app_config(p)?;
    let backend = make_backend(BackendKind::Native, &cfg.artifacts_dir)?;
    let coord = Coordinator::new(&cfg, backend)?;
    let ds = load(&coord, &cfg)?;
    let table = oseba::index::TableIndex::build(ds.partitions())?;
    let cias = oseba::index::Cias::build(ds.partitions())?;
    println!("partitions:        {}", ds.num_partitions());
    println!("table index:       {} ({} entries)", humansize::bytes(table.memory_bytes()), table.entries().len());
    println!(
        "cias index:        {} (compressed: \"{}\", asl: {})",
        humansize::bytes(cias.memory_bytes()),
        cias.compressed_repr(),
        cias.asl_len()
    );
    let ratio = table.memory_bytes() as f64 / cias.memory_bytes().max(1) as f64;
    println!("space ratio:       {ratio:.1}x");
    Ok(())
}

fn cmd_save(p: &oseba::cli::Parsed) -> Result<()> {
    let cfg = app_config(p)?;
    let dir = p.require("dir")?;
    let gen = ClimateGen { seed: cfg.seed, ..Default::default() };
    let batch = gen.generate_bytes(cfg.dataset_bytes);
    let rows = batch.rows();
    let raw = batch.raw_bytes();
    let store = TieredStore::create(dir, batch.schema.clone(), MemoryTracker::unbounded())?;
    let rows_per = rows.div_ceil(cfg.num_partitions);
    for part in partition_batch_uniform(&batch, rows_per)? {
        store.insert(part)?;
    }
    store.save()?;
    let index = store.build_cias()?;
    println!(
        "saved {} rows ({} raw) as {} segments to '{dir}'",
        rows,
        humansize::bytes(raw),
        store.num_partitions()
    );
    println!(
        "index snapshot: \"{}\" (+{} asl entries) — restored on open without a data scan",
        index.compressed_repr(),
        index.asl_len()
    );
    Ok(())
}

fn cmd_open(p: &oseba::cli::Parsed) -> Result<()> {
    let mut cfg = AppConfig {
        backend: p.require("backend")?.parse()?,
        artifacts_dir: p.require("artifacts")?.to_string(),
        cluster_workers: p.require_parse("workers")?,
        ..AppConfig::default()
    };
    apply_budget(&mut cfg, p)?;
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let coord = Coordinator::new(&cfg, backend)?;

    let dir = p.require("dir")?;
    let timer = std::time::Instant::now();
    let (ds, index) = coord.open_store(dir)?;
    let open_secs = timer.elapsed().as_secs_f64();
    let store = ds.store().ok_or_else(|| {
        OsebaError::Store("open_store returned a dataset without a segment store".into())
    })?;
    println!(
        "opened '{dir}' in {}: {} rows in {} partitions ({} on disk), index {} bytes",
        humansize::secs(open_secs),
        ds.total_rows(),
        ds.num_partitions(),
        humansize::bytes(store.total_bytes()),
        index.memory_bytes()
    );
    println!(
        "segment bytes read so far: {} (index restored from the manifest snapshot)",
        store.counters().segment_bytes_read
    );

    let (lo, hi) = (p.get_parse::<i64>("lo")?, p.get_parse::<i64>("hi")?);
    if let (Some(lo), Some(hi)) = (lo, hi) {
        let column = match p.get("column") {
            Some(c) => ds.schema().column_index(c)?,
            None => 0,
        };
        let q = RangeQuery::new(lo, hi)?;
        let timer = std::time::Instant::now();
        let st = coord.analyze_period_oseba(&ds, index.as_ref(), q, column)?;
        let secs = timer.elapsed().as_secs_f64();
        println!(
            "stats[{lo}, {hi}]: n={} max={:.3} min={:.3} mean={:.3} std={:.3} in {}",
            st.count,
            st.max,
            st.min,
            st.mean,
            st.std,
            humansize::secs(secs)
        );
        let c = store.counters();
        println!(
            "selective fault-in: {} of {} read ({} faults)",
            humansize::bytes(c.segment_bytes_read),
            humansize::bytes(store.total_bytes()),
            c.faults
        );
    }
    Ok(())
}

fn cmd_info(p: &oseba::cli::Parsed) -> Result<()> {
    let cfg = app_config(p)?;
    println!("dataset_bytes:   {}", humansize::bytes(cfg.dataset_bytes));
    println!("num_partitions:  {}", cfg.num_partitions);
    println!("backend:         {:?}", cfg.backend);
    println!("cluster_workers: {}", cfg.cluster_workers);
    println!("artifacts_dir:   {}", cfg.artifacts_dir);
    match oseba::runtime::Manifest::load(&cfg.artifacts_dir) {
        Ok(m) => {
            println!("manifest:        {} entries, block_rows={}, windows={:?}",
                m.entries.len(), m.block_rows, m.ma_windows);
            for name in m.entries.keys() {
                println!("  - {name}");
            }
        }
        Err(e) => println!("manifest:        unavailable ({e})"),
    }
    Ok(())
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cli = cli();
    let parsed = match cli.parse(&args) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };
    let result = match parsed.command.as_str() {
        "run" => cmd_run(&parsed),
        "batch" => cmd_batch(&parsed),
        "serve" => cmd_serve(&parsed),
        "ingest" => cmd_ingest(&parsed),
        "index" => cmd_index(&parsed),
        "save" => cmd_save(&parsed),
        "open" => cmd_open(&parsed),
        "info" => cmd_info(&parsed),
        // `Cli::parse` only returns declared commands, but an exhaustive
        // error here beats a panic if the two lists ever drift.
        other => Err(OsebaError::Config(format!("unknown command '{other}'"))),
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}
