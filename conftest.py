"""Pytest bootstrap: make `compile.*` importable when pytest runs from the
repository root (the build-time Python package lives under python/)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "python"))
