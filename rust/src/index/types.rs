//! Core index vocabulary: range queries, partition slices, per-column
//! value-domain zone maps with the predicates that consult them, the
//! per-column aggregate sketches the planner answers covered partitions
//! from, and the [`ContentIndex`] trait both index implementations
//! satisfy.

use crate::error::{OsebaError, Result};
use crate::util::stats::{fold_stats_f32, Moments, TrendPartial};

/// An inclusive key-range selection `[lo, hi]` — the paper's "data ranging
/// from index i to j" (§III-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RangeQuery {
    /// Lower key bound, inclusive.
    pub lo: i64,
    /// Upper key bound, inclusive.
    pub hi: i64,
}

impl RangeQuery {
    /// Validate `lo <= hi`.
    pub fn new(lo: i64, hi: i64) -> Result<RangeQuery> {
        if lo > hi {
            return Err(OsebaError::InvalidRange(format!("lo {lo} > hi {hi}")));
        }
        Ok(RangeQuery { lo, hi })
    }
}

/// A targeted region of one partition: valid-row indices `[row_start,
/// row_end)` of partition `partition`. The unit of work the coordinator
/// dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionSlice {
    /// Target partition id.
    pub partition: usize,
    /// First valid row (inclusive).
    pub row_start: usize,
    /// One past the last valid row.
    pub row_end: usize,
}

impl PartitionSlice {
    /// Number of rows the slice covers.
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Content-aware metadata over a partitioned dataset: maps key ranges to
/// the partitions (and row ranges) that hold them, without touching data.
pub trait ContentIndex: Send + Sync {
    /// Human-readable implementation name (bench labels).
    fn name(&self) -> &'static str;

    /// All slices intersecting `q`, ordered by partition id; empty when the
    /// query misses the dataset entirely.
    fn lookup(&self, q: RangeQuery) -> Vec<PartitionSlice>;

    /// Resident metadata footprint in bytes — the §III space-complexity
    /// comparison (table: O(m); CIAS: O(1) + ASL).
    fn memory_bytes(&self) -> usize;

    /// Number of partitions the index covers.
    fn num_partitions(&self) -> usize;
}

/// Per-column value-domain statistics of one partition: min/max over the
/// non-NaN values plus a NaN count. This is the zone map predicate
/// pruning consults — pure metadata, so a cold (spilled) partition can be
/// ruled out *before* it is faulted in.
///
/// Zone maps ride next to [`PartitionMeta`] (in partitions, store slots
/// and the manifest) rather than inside it: the CIAS compressed region
/// keeps no per-partition metadata at all, so storing zones in the index
/// would reintroduce the O(m) footprint §III-B eliminates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ZoneMap {
    /// Smallest non-NaN value (`f32::INFINITY` when none).
    pub min: f32,
    /// Largest non-NaN value (`f32::NEG_INFINITY` when none).
    pub max: f32,
    /// Number of NaN values in the column.
    pub nans: usize,
}

impl ZoneMap {
    /// The empty zone map (identity for [`ZoneMap::absorb`]).
    pub const EMPTY: ZoneMap =
        ZoneMap { min: f32::INFINITY, max: f32::NEG_INFINITY, nans: 0 };

    /// Zone map of a value slice (one pass; NaNs counted, not folded).
    pub fn of(values: &[f32]) -> ZoneMap {
        let mut z = ZoneMap::EMPTY;
        for &x in values {
            z.absorb(x);
        }
        z
    }

    /// Fold one value in.
    pub fn absorb(&mut self, x: f32) {
        if x.is_nan() {
            self.nans += 1;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
    }

    /// Whether the column holds no non-NaN value.
    pub fn is_empty(&self) -> bool {
        self.min > self.max
    }
}

/// Associative **aggregate sketch** of one value column of one partition:
/// the full [`Moments`] partial (max/min/sum/sumsq/count/nans — a strict
/// superset of the min/max-only [`ZoneMap`]) plus the linear-trend
/// regression partial over (key, value) pairs.
///
/// Sketches are computed once at seal time and carried wherever partition
/// metadata lives (resident partitions, the tiered store's slot table,
/// manifest v3), so a query whose key range *fully covers* a partition —
/// and carries no value predicates — is answered by merging the sketch
/// instead of scanning (or, when the partition is cold, faulting in) the
/// data. The stats moments are folded block-by-block through
/// [`crate::util::stats::fold_stats_f32`] — the same function the native
/// backend's `segment_stats` kernel uses — so on the native backend a
/// sketch partial is **bit-identical** to the partial a full scan of the
/// partition would produce, and merged results cannot drift (the property
/// tests assert exact equality). The AOT HLO kernels (non-default `xla`
/// feature) may regroup their f32 reductions, so there — as for every
/// other HLO-vs-native comparison in the crate — sketch-vs-scan agreement
/// is tolerance-level, not bitwise. On NaN-bearing columns the gap is
/// wider still: the HLO kernels fold NaN into their sums (the known
/// kernel-path limitation, DESIGN.md §10) while sketches enforce the
/// crate-wide counted-out policy — a sketch-answered partition therefore
/// reports the *correct* statistics where the kernel scan would poison
/// them, and a query straddling the covered/edge boundary can observe
/// that difference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColumnSketch {
    /// Raw-moment partial over the column's valid rows.
    pub moments: Moments,
    /// Linear-regression partial over (key, value) pairs.
    pub trend: TrendPartial,
}

impl ColumnSketch {
    /// The identity sketch (empty partition).
    pub const EMPTY: ColumnSketch =
        ColumnSketch { moments: Moments::EMPTY, trend: TrendPartial::EMPTY };

    /// Sketch one column: `keys` are the partition's valid keys and
    /// `values` the parallel column slice (`values.len() >= keys.len()`;
    /// padding beyond the keys is ignored). `block_rows` is the kernel
    /// block size the moments are folded in — pass
    /// [`crate::storage::BLOCK_ROWS`] so the partial matches the scan
    /// path's block decomposition exactly.
    pub fn of(keys: &[i64], values: &[f32], block_rows: usize) -> ColumnSketch {
        let rows = keys.len().min(values.len());
        let values = &values[..rows];
        let mut moments = Moments::EMPTY;
        for block in values.chunks(block_rows.max(1)) {
            let (mx, mn, sum, sumsq, nans) = fold_stats_f32(block);
            let mut m =
                Moments::from_kernel(mx, mn, sum, sumsq, (block.len() - nans) as f32);
            m.nans = nans as f64;
            moments = moments.merge(m);
        }
        ColumnSketch { moments, trend: TrendPartial::scan(keys, values) }
    }

    /// The zone map this sketch subsumes (min/max/nans), for predicate
    /// pruning. Empty sketches map to the unbounded-empty sentinel.
    pub fn zone(&self) -> ZoneMap {
        if self.moments.is_empty() {
            return ZoneMap { nans: self.moments.nans as usize, ..ZoneMap::EMPTY };
        }
        ZoneMap {
            min: self.moments.min,
            max: self.moments.max,
            nans: self.moments.nans as usize,
        }
    }
}

/// Aggregate sketches for every value column of a partition's valid rows.
pub fn sketches_of(
    keys: &[i64],
    columns: &[Vec<f32>],
    block_rows: usize,
) -> Vec<ColumnSketch> {
    columns.iter().map(|c| ColumnSketch::of(keys, c, block_rows)).collect()
}

/// Comparison operator of a value predicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredOp {
    /// `column > value`
    Gt,
    /// `column >= value`
    Ge,
    /// `column < value`
    Lt,
    /// `column <= value`
    Le,
    /// `column == value` — the point-lookup operator. The only operator
    /// membership-filter pruning fires for (DESIGN.md §14).
    Eq,
}

impl PredOp {
    /// The operator's source spelling (`">"`, `">="`, ...).
    pub fn symbol(&self) -> &'static str {
        match self {
            PredOp::Gt => ">",
            PredOp::Ge => ">=",
            PredOp::Lt => "<",
            PredOp::Le => "<=",
            PredOp::Eq => "==",
        }
    }
}

/// One `column OP value` predicate over a value column. A conjunction of
/// these is the `where` clause of a selective analysis; rows whose value
/// is NaN never match (IEEE comparison semantics).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColumnPredicate {
    /// Index of the value column the predicate reads.
    pub column: usize,
    /// Comparison operator.
    pub op: PredOp,
    /// Comparison constant (finite).
    pub value: f32,
}

impl ColumnPredicate {
    /// Whether one row value satisfies the predicate (NaN never does).
    pub fn matches(&self, x: f32) -> bool {
        match self.op {
            PredOp::Gt => x > self.value,
            PredOp::Ge => x >= self.value,
            PredOp::Lt => x < self.value,
            PredOp::Le => x <= self.value,
            PredOp::Eq => x == self.value,
        }
    }

    /// Whether *any* row of a partition could satisfy the predicate,
    /// judged from its zone map alone. `false` means the partition can be
    /// pruned without reading it: the zone bounds cover every non-NaN
    /// value, and NaN rows never match a comparison.
    pub fn satisfiable(&self, z: &ZoneMap) -> bool {
        match self.op {
            PredOp::Gt => z.max > self.value,
            PredOp::Ge => z.max >= self.value,
            PredOp::Lt => z.min < self.value,
            PredOp::Le => z.min <= self.value,
            PredOp::Eq => z.min <= self.value && self.value <= z.max,
        }
    }
}

/// Whether a row (given by its per-column values accessor) satisfies every
/// predicate of a conjunction.
pub fn row_matches(preds: &[ColumnPredicate], value_of: impl Fn(usize) -> f32) -> bool {
    preds.iter().all(|p| p.matches(value_of(p.column)))
}

/// Whether a partition survives zone-map pruning for a conjunction:
/// every predicate must be satisfiable under the partition's zones.
pub fn zones_satisfiable(preds: &[ColumnPredicate], zones: &[ZoneMap]) -> bool {
    preds.iter().all(|p| match zones.get(p.column) {
        Some(z) => p.satisfiable(z),
        // Unknown zone (column out of range): never prune on it.
        None => true,
    })
}

/// Shared per-partition metadata record extracted at load time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionMeta {
    /// Partition id within its dataset.
    pub id: usize,
    /// Smallest key the partition holds.
    pub key_min: i64,
    /// Largest key the partition holds.
    pub key_max: i64,
    /// Valid row count.
    pub rows: usize,
    /// Key step within the partition; `None` if irregular or single-row.
    pub step: Option<i64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_query_validates() {
        assert!(RangeQuery::new(5, 5).is_ok());
        assert!(RangeQuery::new(5, 4).is_err());
        assert_eq!(RangeQuery::new(1, 9).unwrap(), RangeQuery { lo: 1, hi: 9 });
    }

    #[test]
    fn slice_rows() {
        let s = PartitionSlice { partition: 0, row_start: 10, row_end: 25 };
        assert_eq!(s.rows(), 15);
    }

    #[test]
    fn zone_map_excludes_nans_from_bounds() {
        let z = ZoneMap::of(&[3.0, f32::NAN, -1.0, 7.5, f32::NAN]);
        assert_eq!(z.min, -1.0);
        assert_eq!(z.max, 7.5);
        assert_eq!(z.nans, 2);
        assert!(!z.is_empty());

        let all_nan = ZoneMap::of(&[f32::NAN, f32::NAN]);
        assert!(all_nan.is_empty());
        assert_eq!(all_nan.nans, 2);

        assert!(ZoneMap::of(&[]).is_empty());
    }

    #[test]
    fn derived_zone_maps_cover_valid_rows_only() {
        // Zones are a view of the sketches: padding rows (beyond the two
        // keys) must stay invisible to the derived bounds.
        let keys = vec![1, 2];
        let cols = vec![vec![1.0, 2.0, 99.0, 99.0], vec![5.0, f32::NAN, 99.0, 99.0]];
        let zs: Vec<ZoneMap> =
            sketches_of(&keys, &cols, 4096).iter().map(ColumnSketch::zone).collect();
        assert_eq!(zs.len(), 2);
        assert_eq!((zs[0].min, zs[0].max), (1.0, 2.0));
        assert_eq!((zs[1].min, zs[1].max), (5.0, 5.0));
        assert_eq!(zs[1].nans, 1);
    }

    #[test]
    fn predicate_matches_and_nan_never_does() {
        let p = ColumnPredicate { column: 0, op: PredOp::Gt, value: 30.0 };
        assert!(p.matches(30.5));
        assert!(!p.matches(30.0));
        assert!(!p.matches(f32::NAN));
        let p = ColumnPredicate { column: 0, op: PredOp::Le, value: 2.0 };
        assert!(p.matches(2.0));
        assert!(!p.matches(2.1));
        assert!(!p.matches(f32::NAN));
        let p = ColumnPredicate { column: 0, op: PredOp::Eq, value: 2.0 };
        assert!(p.matches(2.0));
        assert!(p.matches(-0.0 + 2.0));
        assert!(!p.matches(2.0000002));
        assert!(!p.matches(f32::NAN));
        assert_eq!(PredOp::Ge.symbol(), ">=");
        assert_eq!(PredOp::Eq.symbol(), "==");
    }

    #[test]
    fn predicate_satisfiable_against_zone_bounds() {
        let z = ZoneMap { min: 10.0, max: 20.0, nans: 3 };
        let pred = |op, value| ColumnPredicate { column: 0, op, value };
        assert!(pred(PredOp::Gt, 19.9).satisfiable(&z));
        assert!(!pred(PredOp::Gt, 20.0).satisfiable(&z));
        assert!(pred(PredOp::Ge, 20.0).satisfiable(&z));
        assert!(pred(PredOp::Lt, 10.1).satisfiable(&z));
        assert!(!pred(PredOp::Lt, 10.0).satisfiable(&z));
        assert!(pred(PredOp::Le, 10.0).satisfiable(&z));
        // Eq is satisfiable exactly inside the closed zone interval.
        assert!(pred(PredOp::Eq, 10.0).satisfiable(&z));
        assert!(pred(PredOp::Eq, 15.0).satisfiable(&z));
        assert!(pred(PredOp::Eq, 20.0).satisfiable(&z));
        assert!(!pred(PredOp::Eq, 9.9).satisfiable(&z));
        assert!(!pred(PredOp::Eq, 20.1).satisfiable(&z));
        // An all-NaN partition satisfies no comparison: always prunable.
        let empty = ZoneMap::EMPTY;
        for op in [PredOp::Gt, PredOp::Ge, PredOp::Lt, PredOp::Le, PredOp::Eq] {
            assert!(!pred(op, 0.0).satisfiable(&empty), "{op:?}");
        }
    }

    #[test]
    fn column_sketch_matches_blockwise_fold_and_zone() {
        use crate::util::stats::fold_stats_f32;
        let keys: Vec<i64> = (0..10_000).map(|i| i * 3).collect();
        let values: Vec<f32> =
            (0..10_000).map(|i| if i == 77 { f32::NAN } else { (i % 311) as f32 }).collect();
        let block = 4096usize;
        let sk = ColumnSketch::of(&keys, &values, block);

        // Oracle: the same blockwise kernel fold, merged in block order.
        let mut want = Moments::EMPTY;
        for b in values.chunks(block) {
            let (mx, mn, sum, sumsq, nans) = fold_stats_f32(b);
            let mut m = Moments::from_kernel(mx, mn, sum, sumsq, (b.len() - nans) as f32);
            m.nans = nans as f64;
            want = want.merge(m);
        }
        assert_eq!(sk.moments, want);
        assert_eq!(sk.moments.count, 9_999.0);
        assert_eq!(sk.moments.nans, 1.0);

        // Trend matches a direct scan; padding past the keys is ignored.
        assert_eq!(sk.trend, crate::util::stats::TrendPartial::scan(&keys, &values));
        let mut padded = values.clone();
        padded.extend([9e9, 9e9]);
        assert_eq!(ColumnSketch::of(&keys, &padded, block), sk);

        // The derived zone subsumes ZoneMap::of.
        let z = sk.zone();
        let direct = ZoneMap::of(&values);
        assert_eq!((z.min, z.max, z.nans), (direct.min, direct.max, direct.nans));

        // Empty and all-NaN sketches degrade to the empty zone.
        assert!(ColumnSketch::EMPTY.zone().is_empty());
        let nan_sk = ColumnSketch::of(&[1, 2], &[f32::NAN, f32::NAN], block);
        assert!(nan_sk.zone().is_empty());
        assert_eq!(nan_sk.zone().nans, 2);
        assert!(nan_sk.moments.is_empty());
        assert!(nan_sk.trend.is_empty());
    }

    #[test]
    fn sketches_of_covers_every_column() {
        let keys = vec![10, 20, 30];
        let cols = vec![vec![1.0, 2.0, 3.0, 99.0], vec![5.0, 5.0, 5.0, 99.0]];
        let sks = sketches_of(&keys, &cols, 4096);
        assert_eq!(sks.len(), 2);
        assert_eq!(sks[0].moments.count, 3.0);
        assert_eq!(sks[0].moments.max, 3.0, "padding row 3 excluded");
        assert_eq!(sks[1].moments.min, 5.0);
        assert!((sks[0].trend.slope().unwrap() - 0.1).abs() < 1e-9);
        assert_eq!(sks[1].trend.slope(), Some(0.0), "flat column fits a flat line");
    }

    #[test]
    fn conjunction_helpers() {
        let preds = vec![
            ColumnPredicate { column: 0, op: PredOp::Gt, value: 1.0 },
            ColumnPredicate { column: 1, op: PredOp::Lt, value: 5.0 },
        ];
        let row = [2.0f32, 4.0];
        assert!(row_matches(&preds, |c| row[c]));
        let row = [2.0f32, 6.0];
        assert!(!row_matches(&preds, |c| row[c]));

        let zones = vec![
            ZoneMap { min: 0.0, max: 3.0, nans: 0 },
            ZoneMap { min: 4.0, max: 9.0, nans: 0 },
        ];
        assert!(zones_satisfiable(&preds, &zones));
        let blocked = vec![
            ZoneMap { min: 0.0, max: 1.0, nans: 0 }, // col0 > 1 impossible
            ZoneMap { min: 4.0, max: 9.0, nans: 0 },
        ];
        assert!(!zones_satisfiable(&preds, &blocked));
        // Empty conjunction never prunes, always matches.
        assert!(zones_satisfiable(&[], &zones));
        assert!(row_matches(&[], |_| 0.0));
    }
}
