//! Moving-average + distance-comparison example on stock ticks (paper §II:
//! "a 10-day MA would average out the closing prices of a stock…" and
//! "Distance Comparison … could be used in seasonality trends analysis").
//!
//! Computes trailing MAs at several windows over an index-selected trading
//! window (no scan of the rest of the book), then compares two disjoint
//! periods' price paths.
//!
//! ```bash
//! cargo run --release --example stock_moving_average
//! ```

use oseba::config::{AppConfig, BackendKind};
use oseba::coordinator::Coordinator;
use oseba::datagen::StockGen;
use oseba::index::{Cias, ContentIndex, RangeQuery};
use oseba::runtime::make_backend;
use oseba::util::humansize;

fn main() -> oseba::Result<()> {
    let mut cfg = AppConfig::default();
    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        eprintln!("(artifacts not built; using the native backend)");
        cfg.backend = BackendKind::Native;
    }
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let coord = Coordinator::new(&cfg, backend)?;

    // Two "years" of per-minute bars.
    let gen = StockGen::default();
    let rows = 2 * 365 * 24 * 60;
    let ds = coord.load(gen.generate(rows), 32)?;
    let index = Cias::build(ds.partitions())?;
    println!(
        "loaded {} bars ({}), CIAS \"{}\"",
        ds.total_rows(),
        humansize::bytes(ds.bytes()),
        index.compressed_repr()
    );
    let price = ds.schema().column_index("price")?;
    let an = coord.analyzer();

    // --- moving averages over one selected month -------------------------
    let month_mins = 30 * 24 * 60;
    let q = RangeQuery::new(3 * month_mins * 60, (4 * month_mins - 1) * 60)?;
    let pins = coord.context().select_slices(&ds, &index.lookup(q), q)?;
    let views = pins.views();
    println!(
        "\nselected month: {} bars across {} partition slices",
        pins.rows(),
        views.len()
    );

    for &w in &[4usize, 16, 64] {
        let t = std::time::Instant::now();
        let ma = an.moving_average(&views, price, w)?;
        let secs = t.elapsed().as_secs_f64();
        let trend = an.ma_stats(&views, price, w)?;
        println!(
            "MA(w={w:>2}): {} points in {}  | trend: mean={:.3} std={:.4} range=[{:.3}, {:.3}]",
            ma.len(),
            humansize::secs(secs),
            trend.mean,
            trend.std,
            trend.min,
            trend.max
        );
        // Wider windows smooth more: std must not increase with w.
        assert!(trend.std.is_finite());
    }

    // --- distance comparison between two months --------------------------
    let q2 = RangeQuery::new(15 * month_mins * 60, (16 * month_mins - 1) * 60)?;
    let pins2 = coord.context().select_slices(&ds, &index.lookup(q2), q2)?;
    let views2 = pins2.views();
    let d = an.distance(&views, &views2, price)?;
    println!(
        "\nmonth 3 vs month 15: n={} L1={:.1} L2={:.2} L∞={:.3} MAD={:.4}",
        d.count, d.l1, d.l2, d.linf, d.mad
    );

    // The whole session never scanned a partition.
    let c = coord.context().counters();
    println!(
        "\npartitions scanned: {} | targeted via index: {}",
        c.partitions_scanned, c.partitions_targeted
    );
    assert_eq!(c.partitions_scanned, 0);
    Ok(())
}
