//! The kernel service: a dedicated thread owning the (thread-bound) PJRT
//! runtime, fed by an mpsc request queue.
//!
//! Architecture note (DESIGN.md §3): the PJRT CPU client is a single
//! "device" whose handles are `!Send`; pinning it to one service thread
//! with a submission queue mirrors how serving systems front a device
//! engine with router threads. [`KernelHandle`] is cheap to clone,
//! `Send + Sync`, and implements [`AnalysisBackend`], so coordinator
//! workers dispatch kernels without knowing where they run. Batched
//! requests ride the queue as one message (one wake-up, N executions) —
//! the batching lever the ablation bench measures.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};
#[cfg(feature = "xla")]
use std::time::Instant;

use crate::error::{OsebaError, Result};
use crate::runtime::backend::{check_block_len, AnalysisBackend};
#[cfg(feature = "xla")]
use crate::runtime::pjrt::{lit, PjRtRuntime};
use crate::util::stats::{DistancePartial, Moments};
use crate::util::sync::MutexExt;

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
enum Request {
    Stats { block: Vec<f32>, start: i32, end: i32, reply: mpsc::Sender<Result<Moments>> },
    StatsBatch {
        blocks: Vec<(Vec<f32>, i32, i32)>,
        reply: mpsc::Sender<Result<Vec<Moments>>>,
    },
    Ma {
        block: Vec<f32>,
        start: i32,
        end: i32,
        window: usize,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    MaStats {
        block: Vec<f32>,
        start: i32,
        end: i32,
        window: usize,
        reply: mpsc::Sender<Result<Moments>>,
    },
    Distance {
        a: Vec<f32>,
        b: Vec<f32>,
        start: i32,
        end: i32,
        reply: mpsc::Sender<Result<DistancePartial>>,
    },
    Hist {
        block: Vec<f32>,
        start: i32,
        end: i32,
        lo: f32,
        hi: f32,
        reply: mpsc::Sender<Result<Vec<f32>>>,
    },
    ServiceStats { reply: mpsc::Sender<ServiceStats> },
}

/// Cumulative service-side counters (perf accounting, EXPERIMENTS.md §Perf).
#[derive(Clone, Copy, Debug, Default)]
pub struct ServiceStats {
    /// Kernel executions performed.
    pub executions: u64,
    /// Requests (batch = 1 request).
    pub requests: u64,
    /// Total seconds spent inside PJRT execution.
    pub busy_secs: f64,
}

/// Cloneable, thread-safe handle to the kernel service.
#[derive(Clone)]
pub struct KernelHandle {
    tx: Arc<Mutex<mpsc::Sender<Request>>>,
    block_rows: usize,
    ma_windows: Vec<usize>,
}

/// Spawn the service thread over the artifacts in `dir`. Fails fast if the
/// manifest is missing or the PJRT client cannot start. When `precompile`
/// is set, all entries are compiled before this returns.
///
/// Without the `xla` cargo feature (the default — the vendored build has no
/// PJRT bindings) this returns a clear [`OsebaError::Runtime`]; use the
/// native backend instead.
#[cfg(not(feature = "xla"))]
pub fn spawn(dir: impl Into<std::path::PathBuf>, _precompile: bool) -> Result<KernelHandle> {
    let dir = dir.into();
    Err(OsebaError::Runtime(format!(
        "the 'hlo' backend needs the vendored `xla` crate (artifacts dir {}); \
         build with `--features xla` or use `--backend native`",
        dir.display()
    )))
}

/// Spawn the service thread over the artifacts in `dir`. Fails fast if the
/// manifest is missing or the PJRT client cannot start. When `precompile`
/// is set, all entries are compiled before this returns.
#[cfg(feature = "xla")]
pub fn spawn(dir: impl Into<std::path::PathBuf>, precompile: bool) -> Result<KernelHandle> {
    let dir = dir.into();
    let (tx, rx) = mpsc::channel::<Request>();
    let (init_tx, init_rx) = mpsc::channel::<Result<(usize, Vec<usize>)>>();
    std::thread::Builder::new()
        .name("oseba-kernel-service".into())
        .spawn(move || {
            let mut rt = match PjRtRuntime::new(&dir) {
                Ok(mut rt) => {
                    if precompile {
                        if let Err(e) = rt.precompile_all() {
                            let _ = init_tx.send(Err(e));
                            return;
                        }
                    }
                    let m = rt.manifest();
                    let _ = init_tx.send(Ok((m.block_rows, m.ma_windows.clone())));
                    rt
                }
                Err(e) => {
                    let _ = init_tx.send(Err(e));
                    return;
                }
            };
            serve(&mut rt, rx);
        })
        .map_err(|e| OsebaError::Runtime(format!("spawn kernel service: {e}")))?;
    let (block_rows, ma_windows) = init_rx
        .recv()
        .map_err(|_| OsebaError::Runtime("kernel service died during init".into()))??;
    Ok(KernelHandle { tx: Arc::new(Mutex::new(tx)), block_rows, ma_windows })
}

#[cfg(feature = "xla")]
fn serve(rt: &mut PjRtRuntime, rx: mpsc::Receiver<Request>) {
    let mut stats = ServiceStats::default();
    while let Ok(req) = rx.recv() {
        stats.requests += 1;
        let t0 = Instant::now();
        match req {
            Request::Stats { block, start, end, reply } => {
                let _ = reply.send(run_stats(rt, "segment_stats", &block, start, end));
                stats.executions += 1;
            }
            Request::StatsBatch { blocks, reply } => {
                let (out, execs) = run_stats_batch(rt, &blocks);
                let _ = reply.send(out);
                stats.executions += execs;
            }
            Request::Ma { block, start, end, window, reply } => {
                let _ = reply.send(run_ma(rt, &block, start, end, window));
                stats.executions += 1;
            }
            Request::MaStats { block, start, end, window, reply } => {
                let _ = reply
                    .send(run_stats(rt, &format!("ma_stats_w{window}"), &block, start, end));
                stats.executions += 1;
            }
            Request::Distance { a, b, start, end, reply } => {
                let _ = reply.send(run_distance(rt, &a, &b, start, end));
                stats.executions += 1;
            }
            Request::Hist { block, start, end, lo, hi, reply } => {
                let _ = reply.send(run_hist(rt, &block, start, end, lo, hi));
                stats.executions += 1;
            }
            Request::ServiceStats { reply } => {
                let _ = reply.send(stats);
            }
        }
        stats.busy_secs += t0.elapsed().as_secs_f64();
    }
}

/// Batched moments: pack tasks into the grid artifacts (`segment_stats_bN`)
/// when they exist, cutting PJRT dispatch overhead ~N× (EXPERIMENTS.md
/// §Perf); falls back to per-block executions otherwise. Multiple batch
/// sizes are packed greedily — the largest size whose padding waste stays
/// under 50% — so a 23-block task list runs as one b128? no: one b16 + …
/// concretely `128` only engages from 64 pending blocks upward. Returns
/// the results plus the number of executions performed.
#[cfg(feature = "xla")]
fn run_stats_batch(
    rt: &mut PjRtRuntime,
    blocks: &[(Vec<f32>, i32, i32)],
) -> (Result<Vec<Moments>>, u64) {
    // Available grid sizes, largest first.
    let mut sizes: Vec<(String, usize)> = rt
        .manifest()
        .entries
        .keys()
        .filter_map(|k| {
            k.strip_prefix("segment_stats_b")
                .and_then(|n| n.parse::<usize>().ok())
                .map(|b| (k.clone(), b))
        })
        .collect();
    sizes.sort_by(|a, b| b.1.cmp(&a.1));

    let mut out = Vec::with_capacity(blocks.len());
    let mut execs = 0u64;
    let mut rest = blocks;
    while !rest.is_empty() {
        // Largest size with <50% padding waste; singles below half the
        // smallest grid.
        let pick = sizes.iter().find(|(_, b)| rest.len() * 2 >= *b).cloned();
        let Some((entry, bsz)) = pick else {
            for (b, s, e) in rest {
                match run_stats(rt, "segment_stats", b, *s, *e) {
                    Ok(m) => out.push(m),
                    Err(e) => return (Err(e), execs),
                }
                execs += 1;
            }
            break;
        };
        let chunk = &rest[..rest.len().min(bsz)];
        rest = &rest[chunk.len()..];
        match run_stats_grid(rt, &entry, bsz, chunk) {
            Ok(ms) => out.extend(ms),
            Err(e) => return (Err(e), execs + 1),
        }
        execs += 1;
    }
    (Ok(out), execs)
}

/// One grid execution over up to `bsz` tasks (zero-padded; padded rows use
/// `start == end == 0`, the identity partial).
#[cfg(feature = "xla")]
fn run_stats_grid(
    rt: &mut PjRtRuntime,
    entry: &str,
    bsz: usize,
    chunk: &[(Vec<f32>, i32, i32)],
) -> Result<Vec<Moments>> {
    let rows = rt.manifest().block_rows;
    let mut xs = vec![0f32; bsz * rows];
    let mut starts = vec![0i32; bsz];
    let mut ends = vec![0i32; bsz];
    for (i, (b, s, e)) in chunk.iter().enumerate() {
        xs[i * rows..i * rows + b.len()].copy_from_slice(b);
        starts[i] = *s;
        ends[i] = *e;
    }
    let x_lit = lit::f32_vec(&xs).reshape(&[bsz as i64, rows as i64])?;
    let res = rt.execute(
        entry,
        &[x_lit, xla::Literal::vec1(&starts), xla::Literal::vec1(&ends)],
    )?;
    let cols: Vec<Vec<f32>> = res.iter().map(lit::to_f32_vec).collect::<Result<_>>()?;
    Ok((0..chunk.len())
        .map(|i| Moments::from_kernel(cols[0][i], cols[1][i], cols[2][i], cols[3][i], cols[4][i]))
        .collect())
}

#[cfg(feature = "xla")]
fn run_stats(rt: &mut PjRtRuntime, entry: &str, block: &[f32], s: i32, e: i32) -> Result<Moments> {
    let out = rt.execute(
        entry,
        &[lit::f32_vec(block), lit::i32_scalar(s), lit::i32_scalar(e)],
    )?;
    let v = PjRtRuntime::to_f32_scalars(&out)?;
    if v.len() != 5 {
        return Err(OsebaError::Runtime(format!("{entry}: expected 5 outputs, got {}", v.len())));
    }
    Ok(Moments::from_kernel(v[0], v[1], v[2], v[3], v[4]))
}

#[cfg(feature = "xla")]
fn run_ma(rt: &mut PjRtRuntime, block: &[f32], s: i32, e: i32, window: usize) -> Result<Vec<f32>> {
    let entry = rt.manifest().ma_entry(window)?;
    let out = rt.execute(
        &entry,
        &[lit::f32_vec(block), lit::i32_scalar(s), lit::i32_scalar(e)],
    )?;
    lit::to_f32_vec(&out[0])
}

#[cfg(feature = "xla")]
fn run_distance(
    rt: &mut PjRtRuntime,
    a: &[f32],
    b: &[f32],
    s: i32,
    e: i32,
) -> Result<DistancePartial> {
    let out = rt.execute(
        "distance",
        &[lit::f32_vec(a), lit::f32_vec(b), lit::i32_scalar(s), lit::i32_scalar(e)],
    )?;
    let v = PjRtRuntime::to_f32_scalars(&out)?;
    Ok(DistancePartial::from_kernel(v[0], v[1], v[2], v[3]))
}

#[cfg(feature = "xla")]
fn run_hist(
    rt: &mut PjRtRuntime,
    block: &[f32],
    s: i32,
    e: i32,
    lo: f32,
    hi: f32,
) -> Result<Vec<f32>> {
    let out = rt.execute(
        "histogram64",
        &[
            lit::f32_vec(block),
            lit::i32_scalar(s),
            lit::i32_scalar(e),
            lit::f32_scalar(lo),
            lit::f32_scalar(hi),
        ],
    )?;
    lit::to_f32_vec(&out[0])
}

impl KernelHandle {
    fn send(&self, req: Request) -> Result<()> {
        self.tx
            .lock_recover()
            .send(req)
            .map_err(|_| OsebaError::Runtime("kernel service is gone".into()))
    }

    fn recv<T>(&self, rx: mpsc::Receiver<Result<T>>) -> Result<T> {
        rx.recv()
            .map_err(|_| OsebaError::Runtime("kernel service dropped reply".into()))?
    }

    /// Service-side counters.
    pub fn service_stats(&self) -> Result<ServiceStats> {
        let (tx, rx) = mpsc::channel();
        self.send(Request::ServiceStats { reply: tx })?;
        rx.recv().map_err(|_| OsebaError::Runtime("kernel service dropped reply".into()))
    }

    /// Moving-average windows available in the artifacts.
    pub fn ma_windows(&self) -> &[usize] {
        &self.ma_windows
    }
}

impl AnalysisBackend for KernelHandle {
    fn name(&self) -> &'static str {
        "hlo"
    }

    fn service_stats(&self) -> Option<ServiceStats> {
        KernelHandle::service_stats(self).ok()
    }

    fn block_rows(&self) -> Option<usize> {
        Some(self.block_rows)
    }

    fn segment_stats(&self, block: &[f32], start: usize, end: usize) -> Result<Moments> {
        check_block_len(self.block_rows, block.len(), "segment_stats")?;
        let (tx, rx) = mpsc::channel();
        self.send(Request::Stats {
            block: block.to_vec(),
            start: start as i32,
            end: end as i32,
            reply: tx,
        })?;
        self.recv(rx)
    }

    fn segment_stats_batch(&self, blocks: &[(&[f32], usize, usize)]) -> Result<Vec<Moments>> {
        for (b, _, _) in blocks {
            check_block_len(self.block_rows, b.len(), "segment_stats_batch")?;
        }
        let (tx, rx) = mpsc::channel();
        self.send(Request::StatsBatch {
            blocks: blocks
                .iter()
                .map(|(b, s, e)| (b.to_vec(), *s as i32, *e as i32))
                .collect(),
            reply: tx,
        })?;
        self.recv(rx)
    }

    fn moving_average(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        window: usize,
    ) -> Result<Vec<f32>> {
        check_block_len(self.block_rows, block.len(), "moving_average")?;
        let (tx, rx) = mpsc::channel();
        self.send(Request::Ma {
            block: block.to_vec(),
            start: start as i32,
            end: end as i32,
            window,
            reply: tx,
        })?;
        self.recv(rx)
    }

    fn ma_stats(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        window: usize,
    ) -> Result<Moments> {
        check_block_len(self.block_rows, block.len(), "ma_stats")?;
        if !self.ma_windows.contains(&window) {
            return Err(OsebaError::Artifact(format!(
                "window {window} not AOT-compiled (available: {:?})",
                self.ma_windows
            )));
        }
        let (tx, rx) = mpsc::channel();
        self.send(Request::MaStats {
            block: block.to_vec(),
            start: start as i32,
            end: end as i32,
            window,
            reply: tx,
        })?;
        self.recv(rx)
    }

    fn distance(
        &self,
        a: &[f32],
        b: &[f32],
        start: usize,
        end: usize,
    ) -> Result<DistancePartial> {
        check_block_len(self.block_rows, a.len(), "distance.a")?;
        check_block_len(self.block_rows, b.len(), "distance.b")?;
        let (tx, rx) = mpsc::channel();
        self.send(Request::Distance {
            a: a.to_vec(),
            b: b.to_vec(),
            start: start as i32,
            end: end as i32,
            reply: tx,
        })?;
        self.recv(rx)
    }

    fn histogram64(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        lo: f32,
        hi: f32,
    ) -> Result<Vec<f32>> {
        check_block_len(self.block_rows, block.len(), "histogram64")?;
        let (tx, rx) = mpsc::channel();
        self.send(Request::Hist {
            block: block.to_vec(),
            start: start as i32,
            end: end as i32,
            lo,
            hi,
            reply: tx,
        })?;
        self.recv(rx)
    }
}
