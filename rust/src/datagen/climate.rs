//! Climate time-series generator (the paper's evaluation dataset shape).
//!
//! Hourly records with seasonal + diurnal structure and AR(1) noise:
//!
//! * `temperature` — annual sinusoid + daily sinusoid + AR(1) residual;
//! * `humidity`    — anti-correlated with temperature, clamped to [5, 100];
//! * `wind_speed`  — log-normal-ish, always positive;
//! * `wind_dir`    — slowly drifting direction in [0, 360).
//!
//! Keys are UNIX-style seconds starting at `start_key` with a fixed
//! `step_secs` — a regular grid. The paper's 480 MB dataset at this schema's
//! 24 B/row is ~20 M rows (≈2282 years hourly; the volume, not the calendar,
//! is what matters for the experiment).

use crate::storage::{BatchBuilder, RecordBatch, Schema};
use crate::util::rng::Xoshiro256;

/// Configurable climate generator.
#[derive(Clone, Debug)]
pub struct ClimateGen {
    /// RNG seed (deterministic output per seed).
    pub seed: u64,
    /// First key (seconds).
    pub start_key: i64,
    /// Key step between consecutive rows (seconds). 3600 = hourly.
    pub step_secs: i64,
    /// Mean temperature (°C) around which the sinusoids ride.
    pub base_temp: f64,
    /// Annual swing amplitude (°C).
    pub seasonal_amp: f64,
    /// Diurnal swing amplitude (°C).
    pub diurnal_amp: f64,
    /// AR(1) coefficient of the residual.
    pub ar: f64,
    /// Residual innovation stddev (°C).
    pub noise_std: f64,
}

impl Default for ClimateGen {
    fn default() -> Self {
        ClimateGen {
            seed: 0x05EBA,
            start_key: 0,
            step_secs: 3600,
            base_temp: 21.0, // Florida-ish
            seasonal_amp: 7.0,
            diurnal_amp: 4.0,
            ar: 0.9,
            noise_std: 1.2,
        }
    }
}

const YEAR_SECS: f64 = 365.25 * 24.0 * 3600.0;
const DAY_SECS: f64 = 24.0 * 3600.0;

impl ClimateGen {
    /// Generate `rows` hourly records.
    pub fn generate(&self, rows: usize) -> RecordBatch {
        let mut rng = Xoshiro256::seeded(self.seed);
        let mut b = BatchBuilder::with_capacity(Schema::climate(), rows);
        let mut resid = 0.0f64;
        let mut dir = rng.uniform(0.0, 360.0);
        for i in 0..rows {
            let key = self.start_key + i as i64 * self.step_secs;
            let t = key as f64;
            let seasonal = self.seasonal_amp * (2.0 * std::f64::consts::PI * t / YEAR_SECS).sin();
            let diurnal = self.diurnal_amp * (2.0 * std::f64::consts::PI * t / DAY_SECS).sin();
            resid = self.ar * resid + rng.normal_with(0.0, self.noise_std);
            let temp = self.base_temp + seasonal + diurnal + resid;
            let humidity = (80.0 - 1.5 * (temp - self.base_temp) + rng.normal_with(0.0, 5.0))
                .clamp(5.0, 100.0);
            let wind = (rng.normal_with(0.0, 0.6).exp() * 3.0).min(60.0);
            dir = (dir + rng.normal_with(0.0, 15.0)).rem_euclid(360.0);
            b.push(key, &[temp as f32, humidity as f32, wind as f32, dir as f32]);
        }
        b.finish().expect("generator emits sorted keys")
    }

    /// Generate a dataset sized to approximately `target_bytes` of raw data
    /// (the paper's "~480 MB" framing). Returns the batch and its row count.
    pub fn generate_bytes(&self, target_bytes: usize) -> RecordBatch {
        let rows = (target_bytes / Schema::climate().row_bytes()).max(1);
        self.generate(rows)
    }

    /// Rows equivalent to `years` of hourly data — handy for the examples
    /// ("compare the temperatures in Florida throughout 1940 and 2014").
    pub fn rows_for_years(&self, years: f64) -> usize {
        (years * YEAR_SECS / self.step_secs as f64).round() as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let g = ClimateGen::default();
        let a = g.generate(500);
        let b = g.generate(500);
        assert_eq!(a.keys, b.keys);
        assert_eq!(a.columns[0], b.columns[0]);
    }

    #[test]
    fn keys_form_regular_grid() {
        let g = ClimateGen { step_secs: 3600, start_key: 100, ..Default::default() };
        let rb = g.generate(1000);
        assert_eq!(rb.keys[0], 100);
        assert!(rb.keys.windows(2).all(|w| w[1] - w[0] == 3600));
    }

    #[test]
    fn temperature_within_physical_bounds() {
        let g = ClimateGen::default();
        let rb = g.generate(20_000);
        let temps = rb.column("temperature").unwrap();
        for &t in temps {
            assert!((-30.0..70.0).contains(&t), "t={t}");
        }
        let mean = temps.iter().map(|&t| t as f64).sum::<f64>() / temps.len() as f64;
        assert!((mean - g.base_temp).abs() < 3.0, "mean={mean}");
    }

    #[test]
    fn humidity_clamped_and_wind_positive() {
        let rb = ClimateGen::default().generate(10_000);
        assert!(rb.column("humidity").unwrap().iter().all(|&h| (5.0..=100.0).contains(&h)));
        assert!(rb.column("wind_speed").unwrap().iter().all(|&w| w >= 0.0));
        assert!(rb.column("wind_dir").unwrap().iter().all(|&d| (0.0..360.0).contains(&d)));
    }

    #[test]
    fn seasonality_visible_in_annual_window() {
        // Summer (quarter-year in) should be warmer than winter (three
        // quarters in) on average — the signal periods analysis relies on.
        let g = ClimateGen { noise_std: 0.5, ..Default::default() };
        let rows = g.rows_for_years(1.0);
        let rb = g.generate(rows);
        let temps = rb.column("temperature").unwrap();
        let q = rows / 4;
        let mean = |s: &[f32]| s.iter().map(|&t| t as f64).sum::<f64>() / s.len() as f64;
        let summer = mean(&temps[q - 200..q + 200]);
        let winter = mean(&temps[3 * q - 200..3 * q + 200]);
        assert!(summer > winter + 5.0, "summer={summer} winter={winter}");
    }

    #[test]
    fn generate_bytes_hits_target_size() {
        let g = ClimateGen::default();
        let rb = g.generate_bytes(1 << 20);
        let got = rb.raw_bytes();
        assert!((got as i64 - (1 << 20) as i64).abs() < Schema::climate().row_bytes() as i64);
    }
}
