"""64-bin masked histogram Pallas kernel.

Paper §II "Events Analysis": "fraud can be detected by comparing the
distributions of typical phone calls and of calls made from a stolen phone".
The distribution estimate is a fixed-bin histogram over the selected range;
histograms from different partitions merge by elementwise addition.

Implementation is gather-free (TPU-friendly): a one-hot compare of each
element's bin id against ``iota(HIST_BINS)``, reduced over rows — an
O(rows × bins) VPU pass instead of a scatter.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 4096
HIST_BINS = 64


def _hist_kernel(x_ref, start_ref, end_ref, lo_ref, hi_ref, o_ref):
    x = x_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    mask = (idx >= start_ref[0]) & (idx < end_ref[0])
    lo = lo_ref[0]
    hi = hi_ref[0]
    width = (hi - lo) / jnp.float32(HIST_BINS)
    # Clamp to [0, HIST_BINS-1]: values == hi land in the last bin,
    # out-of-range values clamp to the edge bins (documented contract).
    bin_id = jnp.clip(((x - lo) / width).astype(jnp.int32), 0, HIST_BINS - 1)
    onehot = (bin_id[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, HIST_BINS), 1))
    counts = jnp.sum(onehot.astype(jnp.float32) *
                     mask.astype(jnp.float32)[:, None], axis=0)
    o_ref[...] = counts


@functools.partial(jax.jit, static_argnames=("block_rows",))
def histogram64(x, start, end, lo, hi, *, block_rows=None):
    """Histogram of ``x[start:end]`` over 64 equal bins spanning [lo, hi).

    Returns f32[64] bin counts (float so they share the merge path with the
    other kernels; exact for counts < 2^24).
    """
    assert block_rows is None or x.shape[0] == block_rows
    start = jnp.asarray(start, jnp.int32).reshape((1,))
    end = jnp.asarray(end, jnp.int32).reshape((1,))
    lo = jnp.asarray(lo, jnp.float32).reshape((1,))
    hi = jnp.asarray(hi, jnp.float32).reshape((1,))
    return pl.pallas_call(
        _hist_kernel,
        out_shape=jax.ShapeDtypeStruct((HIST_BINS,), jnp.float32),
        interpret=True,
    )(x, start, end, lo, hi)
