//! End-to-end integration: the paper's full §IV experiment at reduced
//! scale, on both backends, asserting the Fig 4 / Fig 6 *shapes* — plus
//! the cross-analysis flows (distance, split, histogram) through the
//! coordinator and engine together.

use oseba::analysis::{five_periods, train_test_split, Analyzer, SplitSpec};
use oseba::config::{AppConfig, BackendKind, ContextConfig};
use oseba::coordinator::{run_session, Coordinator, IndexKind, Method};
use oseba::datagen::{CdrGen, ClimateGen};
use oseba::index::{Cias, ContentIndex, RangeQuery};
use oseba::runtime::make_backend;

fn artifacts_available() -> bool {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("artifacts/manifest.json")
        .exists()
}

fn app_cfg() -> AppConfig {
    AppConfig {
        ctx: ContextConfig { num_workers: 4, memory_budget: None },
        cluster_workers: 4,
        artifacts_dir: format!("{}/artifacts", env!("CARGO_MANIFEST_DIR")),
        ..Default::default()
    }
}

fn run_both_methods(backend_kind: BackendKind) {
    let cfg = app_cfg();
    let rows = 80_000;

    let mut reports = Vec::new();
    for method in [Method::Default, Method::Oseba] {
        let backend = make_backend(backend_kind, &cfg.artifacts_dir).unwrap();
        let coord = Coordinator::new(&cfg, backend).unwrap();
        let ds = coord.load(ClimateGen::default().generate(rows), 15).unwrap();
        let report =
            run_session(&coord, &ds, method, IndexKind::Cias, &five_periods(), 0, false)
                .unwrap();
        reports.push((report, coord.context().memory_used()));
    }
    let (default, default_mem) = &reports[0];
    let (oseba, oseba_mem) = &reports[1];

    // Identical analysis answers.
    for (a, b) in default.stats.iter().zip(&oseba.stats) {
        assert_eq!(a.count, b.count);
        assert_eq!(a.max, b.max);
        assert_eq!(a.min, b.min);
        assert!((a.mean - b.mean).abs() < 1e-4);
        assert!((a.std - b.std).abs() < 1e-3);
    }

    // Fig 4 shape: default memory grows monotonically; oseba stays flat at
    // the raw-data footprint; final ratio ≥ ~1.4x (paper: ~3x at phase 5
    // with their period widths).
    let dm = default.metrics.memory_series();
    let om = oseba.metrics.memory_series();
    assert!(dm.windows(2).all(|w| w[1] > w[0]), "default grows {dm:?}");
    assert!(om.windows(2).all(|w| w[0] == w[1]), "oseba flat {om:?}");
    let ratio = dm[4] as f64 / om[4] as f64;
    assert!(ratio > 1.3, "phase-5 memory ratio {ratio}");
    assert!(default_mem > oseba_mem);

    // Fig 6 signal: default pays a full scan every phase.
    let total: usize = default.metrics.records.iter().map(|r| r.partitions_scanned).sum();
    assert_eq!(total, 5 * 15);
    let targeted: usize = oseba.metrics.records.iter().map(|r| r.partitions_targeted).sum();
    assert!(targeted < 5 * 15, "oseba targets a subset: {targeted}");
}

#[test]
fn five_phase_experiment_native_backend() {
    run_both_methods(BackendKind::Native);
}

#[test]
fn five_phase_experiment_hlo_backend() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    run_both_methods(BackendKind::Hlo);
}

#[test]
fn hlo_and_native_backends_agree_end_to_end() {
    if !artifacts_available() {
        eprintln!("skipping: no artifacts");
        return;
    }
    let cfg = app_cfg();
    let mut all = Vec::new();
    for kind in [BackendKind::Native, BackendKind::Hlo] {
        let backend = make_backend(kind, &cfg.artifacts_dir).unwrap();
        let coord = Coordinator::new(&cfg, backend).unwrap();
        let ds = coord.load(ClimateGen::default().generate(40_000), 11).unwrap();
        let report =
            run_session(&coord, &ds, Method::Oseba, IndexKind::Cias, &five_periods(), 0, false)
                .unwrap();
        all.push(report.stats);
    }
    for (n, h) in all[0].iter().zip(&all[1]) {
        assert_eq!(n.count, h.count);
        assert_eq!(n.max, h.max);
        assert_eq!(n.min, h.min);
        assert!((n.mean - h.mean).abs() < 1e-3, "{} vs {}", n.mean, h.mean);
        assert!((n.std - h.std).abs() < 1e-2);
    }
}

#[test]
fn distance_comparison_two_periods_via_index() {
    // Paper §II: compare the same season across two different "years".
    let cfg = app_cfg();
    let backend = make_backend(BackendKind::Native, &cfg.artifacts_dir).unwrap();
    let coord = Coordinator::new(&cfg, backend).unwrap();
    let gen = ClimateGen::default();
    let year = gen.rows_for_years(1.0);
    let ds = coord.load(gen.generate(2 * year + 100), 8).unwrap();
    let index = Cias::build(ds.partitions()).unwrap();

    let window = 30 * 24; // 30 days
    let q1 = RangeQuery { lo: 0, hi: (window as i64 - 1) * 3600 };
    let q2 = RangeQuery {
        lo: year as i64 * 3600,
        hi: (year as i64 + window as i64 - 1) * 3600,
    };
    let p1 = coord.context().select_slices(&ds, &index.lookup(q1), q1).unwrap();
    let p2 = coord.context().select_slices(&ds, &index.lookup(q2), q2).unwrap();
    let (v1, v2) = (p1.views(), p2.views());
    let an = coord.analyzer();
    let d = an.distance(&v1, &v2, 0).unwrap();
    assert_eq!(d.count as usize, window);
    // Same phase of the seasonal cycle → differences are noise-scale, well
    // below the seasonal amplitude.
    assert!(d.mad < 8.0, "mad={}", d.mad);
    assert!(d.l2 > 0.0);

    // Against the opposite season the distance must be clearly larger.
    let q3 = RangeQuery {
        lo: (year / 2) as i64 * 3600,
        hi: ((year / 2) as i64 + window as i64 - 1) * 3600,
    };
    let p3 = coord.context().select_slices(&ds, &index.lookup(q3), q3).unwrap();
    let v3 = p3.views();
    let d_opp = an.distance(&v1, &v3, 0).unwrap();
    assert!(
        d_opp.mad > d.mad,
        "opposite-season mad {} should exceed same-season {}",
        d_opp.mad,
        d.mad
    );
}

#[test]
fn train_test_split_served_by_index_without_scans() {
    let cfg = app_cfg();
    let backend = make_backend(BackendKind::Native, &cfg.artifacts_dir).unwrap();
    let coord = Coordinator::new(&cfg, backend).unwrap();
    let ds = coord.load(ClimateGen::default().generate(50_000), 10).unwrap();
    let index = Cias::build(ds.partitions()).unwrap();

    let split = train_test_split(
        ds.key_min().unwrap(),
        ds.key_max().unwrap(),
        SplitSpec { unit_keys: 5_000 * 3600, train_frac: 0.6, test_frac: 0.2, seed: 9 },
    )
    .unwrap();
    assert!(!split.train.is_empty() && !split.test.is_empty());

    let before = coord.context().counters();
    let mut total_rows = 0u64;
    for q in split.train.iter().chain(&split.test).chain(&split.validation) {
        let views = coord.context().select_slices(&ds, &index.lookup(*q), *q).unwrap();
        total_rows += views.rows() as u64;
    }
    let after = coord.context().counters();
    assert_eq!(total_rows, 50_000, "split covers every row exactly once");
    assert_eq!(after.partitions_scanned, before.partitions_scanned, "no scans");
}

#[test]
fn events_analysis_histogram_separates_fraud() {
    let cfg = app_cfg();
    let backend = make_backend(BackendKind::Native, &cfg.artifacts_dir).unwrap();
    let coord = Coordinator::new(&cfg, backend).unwrap();
    let gen = CdrGen { fraud_rows: Some((20_000, 24_000)), ..Default::default() };
    let ds = coord.load(gen.generate(40_000), 8).unwrap();
    let index = Cias::build(ds.partitions()).unwrap();
    let an = coord.analyzer();
    let dur_col = ds.schema().column_index("duration").unwrap();

    let step = 30i64;
    let normal_q = RangeQuery { lo: 0, hi: 19_999 * step };
    let fraud_q = RangeQuery { lo: 20_000 * step, hi: 23_999 * step };
    let np = coord.context().select_slices(&ds, &index.lookup(normal_q), normal_q).unwrap();
    let fp = coord.context().select_slices(&ds, &index.lookup(fraud_q), fraud_q).unwrap();
    let (nv, fv) = (np.views(), fp.views());
    let hn = an.histogram(&nv, dur_col, 0.0, 3600.0).unwrap();
    let hf = an.histogram(&fv, dur_col, 0.0, 3600.0).unwrap();

    // Normalize and compare mass in the long-call tail (> ~900 s).
    let tail = |h: &[f32]| {
        let total: f32 = h.iter().sum();
        h[16..].iter().sum::<f32>() / total
    };
    assert!(tail(&hf) > 4.0 * tail(&hn), "fraud tail {} vs normal {}", tail(&hf), tail(&hn));
}

#[test]
fn memory_budget_evicts_or_errors_cleanly() {
    // With a tight budget the default method must hit OutOfMemory while
    // Oseba completes — the paper's memory argument as a failure mode.
    let gen = ClimateGen::default();
    let batch = gen.generate(40_000);
    let raw = batch.raw_bytes();

    let cfg = AppConfig {
        ctx: ContextConfig { num_workers: 2, memory_budget: Some(raw * 2) },
        cluster_workers: 2,
        ..app_cfg()
    };
    let backend = make_backend(BackendKind::Native, &cfg.artifacts_dir).unwrap();
    let coord = Coordinator::new(&cfg, backend).unwrap();
    let ds = coord.load(batch, 10).unwrap();
    let index = Cias::build(ds.partitions()).unwrap();

    let periods = five_periods();
    let key_min = ds.key_min().unwrap();
    let key_max = ds.key_max().unwrap();

    // Oseba: all five phases succeed within budget.
    for spec in &periods {
        let q = spec.resolve(key_min, key_max).unwrap();
        coord.analyze_period_oseba(&ds, &index, q, 0).unwrap();
    }

    // Default: accumulating filtered datasets eventually exceeds budget.
    let mut failed = false;
    for _ in 0..3 {
        for spec in &periods {
            let q = spec.resolve(key_min, key_max).unwrap();
            match coord.analyze_period_default(&ds, q, 0) {
                Ok(_) => {}
                Err(oseba::OsebaError::OutOfMemory { .. }) => {
                    failed = true;
                    break;
                }
                Err(e) => panic!("unexpected error {e}"),
            }
        }
        if failed {
            break;
        }
    }
    assert!(failed, "default method should exhaust a 2x-raw budget");
}

#[test]
fn analyzer_full_views_equals_indexed_full_span() {
    let cfg = app_cfg();
    let backend = make_backend(BackendKind::Native, &cfg.artifacts_dir).unwrap();
    let coord = Coordinator::new(&cfg, backend).unwrap();
    let ds = coord.load(ClimateGen::default().generate(12_345), 7).unwrap();
    let index = Cias::build(ds.partitions()).unwrap();
    let q = RangeQuery { lo: ds.key_min().unwrap(), hi: ds.key_max().unwrap() };
    let via_index = coord.analyze_period_oseba(&ds, &index, q, 3).unwrap();
    let full = coord.analyzer().period_stats(&Analyzer::full_views(&ds), 3).unwrap();
    assert_eq!(via_index, full);
}
