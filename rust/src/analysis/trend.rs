//! Trend analyses from the paper's introduction (§I): *Centered Moving
//! Average* and *Stationarity Computation* ("statistical methods like
//! Centered Moving Average or Stationarity Computation could be applied to
//! investigate how the data changes within a period of time").
//!
//! Both compose the existing L1 kernels: the centered MA is a shifted
//! trailing MA; stationarity segments the selection and compares
//! per-segment moments (all `segment_stats` dispatches, merged in rust).

use crate::analysis::ops::Analyzer;
use crate::analysis::PeriodStats;
use crate::engine::SliceView;
use crate::error::{OsebaError, Result};

/// Per-segment statistics plus drift scores for a stationarity check.
#[derive(Clone, Debug)]
pub struct StationarityReport {
    /// Segment statistics, in order.
    pub segments: Vec<PeriodStats>,
    /// Whole-selection statistics.
    pub overall: PeriodStats,
    /// Max |segment mean − overall mean| / overall std (0 for flat series).
    pub mean_drift: f64,
    /// Max segment std / min segment std (1 for homoscedastic series).
    pub variance_ratio: f64,
}

impl StationarityReport {
    /// A simple stationarity verdict with conventional thresholds: means
    /// within one overall σ and variance ratio under 4.
    pub fn is_stationary(&self) -> bool {
        self.mean_drift < 1.0 && self.variance_ratio < 4.0
    }
}

impl Analyzer {
    /// Centered moving average over the concatenated selection: the value
    /// at position `i` averages `window` points centred on `i` (`window`
    /// must be odd so the centre is well-defined). Returns `n - window + 1`
    /// values, aligned so index 0 corresponds to selected row
    /// `(window-1)/2`.
    pub fn centered_moving_average(
        &self,
        views: &[SliceView<'_>],
        column: usize,
        window: usize,
    ) -> Result<Vec<f32>> {
        if window % 2 == 0 {
            return Err(OsebaError::InvalidRange(format!(
                "centered MA needs an odd window, got {window}"
            )));
        }
        // centered(i) == trailing(i + (w-1)/2): identical value set, so the
        // trailing-MA kernel serves both (only the alignment differs).
        self.moving_average(views, column, window)
    }

    /// Stationarity computation: split the selection into `segments`
    /// near-equal spans, compute per-segment moments (kernel dispatches),
    /// and report mean drift and variance ratio across segments.
    pub fn stationarity(
        &self,
        views: &[SliceView<'_>],
        column: usize,
        segments: usize,
    ) -> Result<StationarityReport> {
        if segments < 2 {
            return Err(OsebaError::InvalidRange("need at least 2 segments".into()));
        }
        let total: usize = views.iter().map(|v| v.rows()).sum();
        if total < segments {
            return Err(OsebaError::InvalidRange(format!(
                "selection of {total} rows cannot form {segments} segments"
            )));
        }
        let overall = self.period_stats(views, column)?;

        // Walk the views, cutting them into `segments` global row spans.
        let per = total.div_ceil(segments);
        let mut seg_stats = Vec::with_capacity(segments);
        let mut current: Vec<SliceView<'_>> = Vec::new();
        let mut filled = 0usize;
        for v in views {
            let mut offset = 0usize;
            while offset < v.rows() {
                let take = (per - filled).min(v.rows() - offset);
                current.push(SliceView {
                    part: v.part,
                    row_start: v.row_start + offset,
                    row_end: v.row_start + offset + take,
                });
                offset += take;
                filled += take;
                if filled == per {
                    seg_stats.push(self.period_stats(&current, column)?);
                    current.clear();
                    filled = 0;
                }
            }
        }
        if filled > 0 {
            seg_stats.push(self.period_stats(&current, column)?);
        }

        let mean_drift = seg_stats
            .iter()
            .map(|s| (s.mean - overall.mean).abs())
            .fold(0.0f64, f64::max)
            / overall.std.max(f64::EPSILON);
        let stds: Vec<f64> = seg_stats.iter().map(|s| s.std.max(f64::EPSILON)).collect();
        let variance_ratio = stds.iter().cloned().fold(0.0f64, f64::max)
            / stds.iter().cloned().fold(f64::INFINITY, f64::min);

        Ok(StationarityReport { segments: seg_stats, overall, mean_drift, variance_ratio })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ContextConfig;
    use crate::datagen::ClimateGen;
    use crate::engine::OsebaContext;
    use crate::runtime::NativeBackend;
    use crate::storage::{BatchBuilder, Schema};
    use std::sync::Arc;

    fn analyzer() -> Analyzer {
        Analyzer::new(Arc::new(NativeBackend))
    }

    fn ds_from(xs: &[f32]) -> (OsebaContext, crate::engine::Dataset) {
        let ctx = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
        let mut b = BatchBuilder::new(Schema::stock());
        for (i, &x) in xs.iter().enumerate() {
            b.push(i as i64, &[x, 0.0]);
        }
        let ds = ctx.load(b.finish().unwrap(), 3).unwrap();
        (ctx, ds)
    }

    #[test]
    fn centered_ma_requires_odd_window() {
        let (_ctx, ds) = ds_from(&[1.0; 100]);
        let an = analyzer();
        let views = Analyzer::full_views(&ds);
        assert!(an.centered_moving_average(&views, 0, 4).is_err());
        let got = an.centered_moving_average(&views, 0, 5).unwrap();
        assert_eq!(got.len(), 96);
        assert!(got.iter().all(|&v| (v - 1.0).abs() < 1e-6));
    }

    #[test]
    fn centered_ma_of_ramp_is_center_value() {
        let xs: Vec<f32> = (0..200).map(|i| i as f32).collect();
        let (_ctx, ds) = ds_from(&xs);
        let an = analyzer();
        let views = Analyzer::full_views(&ds);
        let w = 7;
        let got = an.centered_moving_average(&views, 0, w).unwrap();
        // Centered MA of a linear ramp equals the centre sample: index 0
        // corresponds to selected row (w-1)/2 = 3 → value 3.0.
        for (k, &v) in got.iter().enumerate().take(20) {
            let want = (k + (w - 1) / 2) as f32;
            assert!((v - want).abs() < 1e-3, "k={k} got={v} want={want}");
        }
    }

    #[test]
    fn stationary_series_passes() {
        let gen = ClimateGen { seasonal_amp: 0.0, diurnal_amp: 0.0, ..Default::default() };
        let ctx = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
        let ds = ctx.load(gen.generate(20_000), 5).unwrap();
        let an = analyzer();
        let views = Analyzer::full_views(&ds);
        let rep = an.stationarity(&views, 0, 8).unwrap();
        assert_eq!(rep.segments.len(), 8);
        assert!(rep.is_stationary(), "drift={} ratio={}", rep.mean_drift, rep.variance_ratio);
    }

    #[test]
    fn trending_series_fails_stationarity() {
        // Strong linear trend: mean drifts far beyond one σ per segment.
        let xs: Vec<f32> = (0..10_000).map(|i| i as f32 * 0.1).collect();
        let (_ctx, ds) = ds_from(&xs);
        let an = analyzer();
        let views = Analyzer::full_views(&ds);
        let rep = an.stationarity(&views, 0, 5).unwrap();
        assert!(rep.mean_drift > 1.0);
        assert!(!rep.is_stationary());
    }

    #[test]
    fn heteroscedastic_series_fails_variance_check() {
        // First half ~N(0, 0.01), second half ~N(0, 10).
        let mut rng = crate::util::rng::Xoshiro256::seeded(3);
        let xs: Vec<f32> = (0..10_000)
            .map(|i| {
                let s = if i < 5_000 { 0.01 } else { 10.0 };
                rng.normal_with(0.0, s) as f32
            })
            .collect();
        let (_ctx, ds) = ds_from(&xs);
        let rep = analyzer().stationarity(&Analyzer::full_views(&ds), 0, 4).unwrap();
        assert!(rep.variance_ratio > 4.0);
        assert!(!rep.is_stationary());
    }

    #[test]
    fn segment_counts_cover_selection() {
        let (_ctx, ds) = ds_from(&vec![1.0; 1003]);
        let rep = analyzer().stationarity(&Analyzer::full_views(&ds), 0, 4).unwrap();
        let total: u64 = rep.segments.iter().map(|s| s.count).sum();
        assert_eq!(total, 1003);
        assert_eq!(rep.overall.count, 1003);
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let (_ctx, ds) = ds_from(&[1.0; 10]);
        let an = analyzer();
        let views = Analyzer::full_views(&ds);
        assert!(an.stationarity(&views, 0, 1).is_err());
        assert!(an.stationarity(&views, 0, 11).is_err());
    }
}
