//! Tiered persistent storage (DESIGN.md §8): the layer that takes the
//! super index past RAM.
//!
//! * [`segment`] — the dependency-free `.oseg` binary columnar segment
//!   format (one partition per file, CRC-32 per section);
//! * [`manifest`] — the JSON manifest snapshotting schema, segment
//!   metadata and the super index, so `open` restores lookup in O(index)
//!   without reading data;
//! * [`fault`] — [`StoreIo`], the only doorway from this module to the
//!   filesystem, plus the seeded failpoint injector behind the
//!   crash/corruption batteries and [`RetryPolicy`] (DESIGN.md §16);
//! * [`tiered`] — [`TieredStore`]: Hot/Cold partition residency over a
//!   segment directory, spilling under memory pressure and faulting in
//!   only the partitions the index targets, with crash-safe commits,
//!   bounded retry and corruption quarantine.

pub mod crc32;
pub mod fault;
pub mod manifest;
pub mod segment;
pub mod tiered;

pub use fault::{FaultInjector, FaultKind, FaultRule, RetryPolicy, StoreIo};
pub use manifest::{SegmentEntry, StoreManifest, MANIFEST_FILE};
pub use segment::{read_segment, write_segment};
pub use tiered::{RecoveryReport, Residency, StoreCounters, TieredStore};
