//! Cross-cutting utilities built from scratch (the vendored dependency set
//! has no `serde`, `rand` or `criterion` — see DESIGN.md §4).

pub mod humansize;
pub mod json;
pub mod rng;
pub mod stats;
pub mod sync;
pub mod threadpool;
