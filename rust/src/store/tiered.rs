//! `TieredStore` — Hot/Cold residency for a dataset's partitions.
//!
//! The store owns every partition of one dataset. A **Hot** partition is
//! memory-resident (its bytes charged to the shared [`MemoryTracker`]); a
//! **Cold** partition lives only as an `.oseg` segment in the store
//! directory. Under memory pressure the least-recently-used hot partition
//! is *spilled* (written once, then dropped) instead of the allocation
//! erroring; a lookup that targets a cold partition *faults it in*
//! (CRC-verified read, possibly evicting other partitions to make room).
//!
//! Because the super index ([`Cias`]) is pure metadata, index lookups never
//! touch residency: only the partitions a query actually targets are
//! faulted, which is the paper's selectivity argument extended past RAM —
//! bytes read from disk scale with the selection, not the dataset.
//!
//! One coarse mutex guards the slot table; segment I/O happens under it.
//! Fault/evict traffic is metadata-rate (per partition, not per row), so
//! the simple lock is the right trade for this engine.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::engine::memory::MemoryTracker;
use crate::error::{OsebaError, Result};
use crate::index::builder::detect_step;
use crate::index::{
    BlockSketches, Cias, ColumnSketch, MembershipFilter, PartitionMeta, ZoneMap,
};
use crate::storage::{Partition, Schema, BLOCK_ROWS};
use crate::store::fault::{site, RetryPolicy, StoreIo};
use crate::store::manifest::{
    SegmentEntry, StoreManifest, MANIFEST_FILE, PREV_MANIFEST_FILE,
};
use crate::store::segment::{read_segment_with, segment_len, write_segment_with};
use crate::util::sync::MutexExt;

/// Where a partition currently lives.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Residency {
    /// Memory-resident (bytes charged to the tracker).
    Hot,
    /// On disk only (an `.oseg` segment).
    Cold,
}

/// Monotonic fault/evict/I/O counters (see [`TieredStore::counters`]).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct StoreCounters {
    /// Cold partitions faulted into memory.
    pub faults: usize,
    /// Hot partitions evicted (spilled or dropped) to reclaim memory.
    pub evictions: usize,
    /// Segment bytes read from disk by faults.
    pub segment_bytes_read: usize,
    /// Segment bytes written by spills and saves.
    pub segment_bytes_written: usize,
    /// Fault-in read attempts retried after a transient failure.
    pub io_retries: usize,
    /// Fault-ins that succeeded only after at least one retry.
    pub io_retry_successes: usize,
    /// Partitions quarantined after exhausting retries on corruption.
    pub quarantined: usize,
    /// Nanoseconds spent inside fault-recovery (retry backoff + re-reads).
    pub recovery_nanos: u64,
}

impl StoreCounters {
    /// Counter deltas since an earlier snapshot.
    pub fn since(&self, earlier: &StoreCounters) -> StoreCounters {
        StoreCounters {
            faults: self.faults - earlier.faults,
            evictions: self.evictions - earlier.evictions,
            segment_bytes_read: self.segment_bytes_read - earlier.segment_bytes_read,
            segment_bytes_written: self.segment_bytes_written - earlier.segment_bytes_written,
            io_retries: self.io_retries - earlier.io_retries,
            io_retry_successes: self.io_retry_successes - earlier.io_retry_successes,
            quarantined: self.quarantined - earlier.quarantined,
            recovery_nanos: self.recovery_nanos - earlier.recovery_nanos,
        }
    }
}

/// What the open-time recovery scan found and fixed
/// (see [`TieredStore::recovery_report`]).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct RecoveryReport {
    /// Orphaned `*.tmp` files deleted (commits interrupted before their
    /// rename).
    pub removed_tmp: Vec<String>,
    /// `*.oseg` files present on disk but absent from the manifest —
    /// reported, never loaded (they are not part of the committed
    /// snapshot; a later save will overwrite them).
    pub unreferenced: Vec<String>,
    /// Whether `manifest.json` was corrupt and the previous snapshot
    /// (`manifest.json.prev`) was restored over it.
    pub restored_previous: bool,
}

#[derive(Debug)]
struct Slot {
    meta: PartitionMeta,
    /// Per-column zone maps — resident metadata, so a Cold partition can
    /// be zone-pruned without faulting it in.
    zones: Vec<ZoneMap>,
    /// Per-column aggregate sketches — resident metadata surviving
    /// eviction, so a fully-covered Cold partition is answered with
    /// **zero fault-in**. `None` for stores opened from a pre-v3 manifest
    /// (those partitions always scan).
    sketches: Option<Vec<ColumnSketch>>,
    /// Per-column membership filters — resident metadata surviving
    /// eviction, so a Cold partition is filter-pruned for equality
    /// predicates **before fault-in**. `None` for stores opened from a
    /// pre-v4 manifest (no filter → always consider, DESIGN.md §14).
    filters: Option<Arc<Vec<MembershipFilter>>>,
    /// Per-block sketch hierarchy — resident metadata surviving eviction,
    /// so a Cold partition's blocks are classified (covered / pruned /
    /// scanned) **before fault-in**. `None` for stores opened from a
    /// pre-v5 manifest (no block sketches → the partition's edge and
    /// predicate scans read every targeted block, DESIGN.md §15).
    block_sketches: Option<Arc<BlockSketches>>,
    /// In-memory footprint (keys + padded columns) when hot.
    bytes: usize,
    /// Segment file name relative to the store directory.
    file: String,
    /// Whether a current segment for this partition exists on disk.
    on_disk: bool,
    /// Whether the segment failed CRC verification after exhausting
    /// retries — a quarantined partition fails fast on fetch and is
    /// served degraded (from retained sketches, or dropped with
    /// `degraded` accounting) by the planner (DESIGN.md §16).
    quarantined: bool,
    resident: Option<Arc<Partition>>,
    last_touch: u64,
}

#[derive(Debug, Default)]
struct Inner {
    slots: Vec<Slot>,
    clock: u64,
}

/// The tiered partition store. See the module docs.
#[derive(Debug)]
pub struct TieredStore {
    dir: PathBuf,
    schema: Schema,
    tracker: Arc<MemoryTracker>,
    inner: Mutex<Inner>,
    io: StoreIo,
    retry: Mutex<RetryPolicy>,
    /// Strict mode: `true` keeps the historic hard-error behavior on
    /// corruption; `false` (the default) lets the planner serve around
    /// quarantined partitions with `degraded` accounting.
    strict: AtomicBool,
    recovery: RecoveryReport,
    faults: AtomicUsize,
    evictions: AtomicUsize,
    bytes_read: AtomicUsize,
    bytes_written: AtomicUsize,
    io_retries: AtomicUsize,
    io_retry_successes: AtomicUsize,
    quarantined: AtomicUsize,
    recovery_nanos: AtomicU64,
}

fn segment_file(id: usize) -> String {
    format!("part-{id:05}.oseg")
}

/// In-memory footprint of a partition with `rows` valid rows. Saturating:
/// manifest-supplied values must never panic, only fail allocation.
/// Crate-visible so a live snapshot can size its visible prefix from
/// metadata alone.
pub(crate) fn partition_bytes(rows: usize, width: usize) -> usize {
    let padded = rows.div_ceil(BLOCK_ROWS).max(1).saturating_mul(BLOCK_ROWS);
    rows.saturating_mul(8)
        .saturating_add(width.saturating_mul(padded).saturating_mul(4))
}

impl TieredStore {
    /// Create an empty store over `dir` (created if missing). Partition
    /// bytes are charged to `tracker` — share the engine's tracker so the
    /// store competes with (and relieves) the block manager's budget.
    ///
    /// Any manifest left by a previous store in the same directory is
    /// removed: this store's spills will overwrite the segments, and a
    /// stale manifest must not let a later `open` serve the new data
    /// under the old dataset's identity.
    pub fn create(
        dir: impl AsRef<Path>,
        schema: Schema,
        tracker: Arc<MemoryTracker>,
    ) -> Result<TieredStore> {
        Self::create_with(dir, schema, tracker, StoreIo::from_env()?)
    }

    /// [`TieredStore::create`] with an explicit [`StoreIo`] (tests and
    /// benches inject faults; `create` itself wires `OSEBA_FAULTS`).
    pub fn create_with(
        dir: impl AsRef<Path>,
        schema: Schema,
        tracker: Arc<MemoryTracker>,
        io: StoreIo,
    ) -> Result<TieredStore> {
        let dir = dir.as_ref().to_path_buf();
        io.create_dir_all(site::DIR_MAINTENANCE, &dir)?;
        // Remove the rollback copy too: a later open must not "recover"
        // the old dataset's manifest over this store's segments.
        for stale in [MANIFEST_FILE, PREV_MANIFEST_FILE] {
            let path = dir.join(stale);
            if io.exists(&path) {
                io.remove_file(site::DIR_MAINTENANCE, &path)?;
            }
        }
        Ok(Self::assemble(dir, schema, tracker, io, Inner::default(), RecoveryReport::default()))
    }

    /// Open a saved store: parse + validate the manifest and restore the
    /// super index from its snapshot. **O(index size)** — no segment is
    /// read; every partition starts Cold and is faulted in on demand.
    ///
    /// Opening runs the recovery scan (DESIGN.md §16): a corrupt or torn
    /// `manifest.json` is rolled back to the durable `manifest.json.prev`
    /// snapshot when one validates, orphaned `*.tmp` files (commits that
    /// crashed before their rename) are deleted, and `*.oseg` files the
    /// manifest does not reference are reported — not loaded — in the
    /// [`RecoveryReport`].
    pub fn open(
        dir: impl AsRef<Path>,
        tracker: Arc<MemoryTracker>,
    ) -> Result<(TieredStore, Cias)> {
        Self::open_with(dir, tracker, StoreIo::from_env()?)
    }

    /// [`TieredStore::open`] with an explicit [`StoreIo`].
    pub fn open_with(
        dir: impl AsRef<Path>,
        tracker: Arc<MemoryTracker>,
        io: StoreIo,
    ) -> Result<(TieredStore, Cias)> {
        let dir = dir.as_ref().to_path_buf();
        let (manifest, mut recovery) = Self::load_or_rollback(&dir, &io)?;
        Self::recovery_scan(&dir, &io, &manifest, &mut recovery)?;
        let width = manifest.schema.width();
        let slots = manifest
            .segments
            .iter()
            .map(|e| Slot {
                meta: e.meta,
                zones: e.zones.clone(),
                sketches: e.sketches.clone(),
                filters: e.filters.clone(),
                block_sketches: e.blocks.clone(),
                bytes: partition_bytes(e.meta.rows, width),
                file: e.file.clone(),
                on_disk: true,
                quarantined: false,
                resident: None,
                last_touch: 0,
            })
            .collect();
        let store = Self::assemble(
            dir,
            manifest.schema,
            tracker,
            io,
            Inner { slots, clock: 0 },
            recovery,
        );
        Ok((store, manifest.index))
    }

    fn assemble(
        dir: PathBuf,
        schema: Schema,
        tracker: Arc<MemoryTracker>,
        io: StoreIo,
        inner: Inner,
        recovery: RecoveryReport,
    ) -> TieredStore {
        TieredStore {
            dir,
            schema,
            tracker,
            inner: Mutex::new(inner),
            io,
            retry: Mutex::new(RetryPolicy::default()),
            strict: AtomicBool::new(false),
            recovery,
            faults: AtomicUsize::new(0),
            evictions: AtomicUsize::new(0),
            bytes_read: AtomicUsize::new(0),
            bytes_written: AtomicUsize::new(0),
            io_retries: AtomicUsize::new(0),
            io_retry_successes: AtomicUsize::new(0),
            quarantined: AtomicUsize::new(0),
            recovery_nanos: AtomicU64::new(0),
        }
    }

    /// Load the manifest, rolling back to `manifest.json.prev` when the
    /// committed one is corrupt (`OsebaError::Store`) and the previous
    /// snapshot validates. I/O failures (`OsebaError::Io`, e.g. a missing
    /// manifest) propagate unchanged — rollback is for torn documents,
    /// not for directories that were never a store.
    fn load_or_rollback(dir: &Path, io: &StoreIo) -> Result<(StoreManifest, RecoveryReport)> {
        let mut recovery = RecoveryReport::default();
        let manifest = match StoreManifest::load_with(dir, io) {
            Ok(m) => m,
            Err(corrupt @ OsebaError::Store(_)) => {
                let prev = dir.join(PREV_MANIFEST_FILE);
                if !io.exists(&prev) {
                    return Err(corrupt);
                }
                let bytes = io.read(site::MANIFEST_READ, &prev)?;
                let Ok(text) = String::from_utf8(bytes.clone()) else {
                    return Err(corrupt);
                };
                let Ok(m) = StoreManifest::parse_named(&text, &prev) else {
                    return Err(corrupt);
                };
                // Durably promote the snapshot so the next open (and any
                // reader of the directory) sees a valid manifest again.
                io.commit(site::MANIFEST_WRITE, dir.join(MANIFEST_FILE), &bytes)?;
                recovery.restored_previous = true;
                m
            }
            Err(e) => return Err(e),
        };
        Ok((manifest, recovery))
    }

    /// Delete orphaned `*.tmp` files and report unreferenced `*.oseg`
    /// files (see [`RecoveryReport`]).
    fn recovery_scan(
        dir: &Path,
        io: &StoreIo,
        manifest: &StoreManifest,
        recovery: &mut RecoveryReport,
    ) -> Result<()> {
        let referenced: std::collections::HashSet<&str> =
            manifest.segments.iter().map(|e| e.file.as_str()).collect();
        for name in io.read_dir(site::DIR_MAINTENANCE, dir)? {
            if name.ends_with(".tmp") {
                io.remove_file(site::DIR_MAINTENANCE, dir.join(&name))?;
                recovery.removed_tmp.push(name);
            } else if name.ends_with(".oseg") && !referenced.contains(name.as_str()) {
                recovery.unreferenced.push(name);
            }
        }
        recovery.removed_tmp.sort();
        recovery.unreferenced.sort();
        if !recovery.removed_tmp.is_empty() {
            io.sync_dir(site::DIR_MAINTENANCE, dir)?;
        }
        Ok(())
    }

    /// Append the next partition. Ids must be contiguous and key ranges
    /// ordered/non-overlapping (the index invariant). The partition stays
    /// Hot when the tracker has room — evicting colder partitions if
    /// needed — and is spilled straight to its segment when even a full
    /// eviction cannot fit it (partition larger than the whole budget).
    /// Returns the metadata extracted for the partition, so callers
    /// maintaining their own index (the spilling ingestor) need not
    /// rescan the keys.
    pub fn insert(&self, part: Arc<Partition>) -> Result<PartitionMeta> {
        if part.columns.len() != self.schema.width() {
            return Err(OsebaError::Schema(format!(
                "partition has {} columns, store schema {}",
                part.columns.len(),
                self.schema.width()
            )));
        }
        let (Some(key_min), Some(key_max)) = (part.key_min(), part.key_max()) else {
            return Err(OsebaError::Schema("cannot store an empty partition".into()));
        };

        let mut inner = self.inner.lock_recover();
        let id = inner.slots.len();
        if part.id != id {
            return Err(OsebaError::Store(format!(
                "insert out of order: partition id {} (expected {id})",
                part.id
            )));
        }
        if let Some(last) = inner.slots.last() {
            if key_min <= last.meta.key_max {
                return Err(OsebaError::Index(format!(
                    "partition {id} overlaps: key_min {key_min} <= previous key_max {}",
                    last.meta.key_max
                )));
            }
        }
        let meta = PartitionMeta {
            id,
            key_min,
            key_max,
            rows: part.rows,
            step: detect_step(&part.keys),
        };
        let bytes = part.bytes();
        let file = segment_file(id);

        let mut slot = Slot {
            meta,
            zones: part.zone_maps(),
            sketches: Some(part.sketches.clone()),
            filters: Some(Arc::clone(&part.filters)),
            block_sketches: Some(Arc::clone(&part.block_sketches)),
            bytes,
            file,
            on_disk: false,
            quarantined: false,
            resident: None,
            last_touch: 0,
        };
        match self.allocate_evicting(&mut inner, bytes, usize::MAX) {
            Ok(()) => {
                inner.clock += 1;
                slot.last_touch = inner.clock;
                slot.resident = Some(part);
            }
            Err(OsebaError::OutOfMemory { .. }) => {
                // Nothing left to evict: the partition itself exceeds the
                // remaining budget. Spill it directly — ingestion proceeds
                // instead of erroring.
                let path = self.dir.join(&slot.file);
                let written = write_segment_with(&path, &part, &self.io)?;
                self.bytes_written.fetch_add(written, Ordering::Relaxed);
                self.evictions.fetch_add(1, Ordering::Relaxed);
                slot.on_disk = true;
            }
            Err(e) => return Err(e),
        }
        inner.slots.push(slot);
        Ok(meta)
    }

    /// Fetch partition `id`, faulting it in from its segment if Cold.
    /// The returned handle pins the data for the caller regardless of
    /// later evictions (evicting only drops the store's reference).
    ///
    /// Transient read failures are retried per the store's
    /// [`RetryPolicy`]; a segment still failing CRC verification after
    /// the retries **quarantines** the partition — this fetch and every
    /// later one fail fast with a typed [`OsebaError::Store`], and the
    /// planner serves around it (DESIGN.md §16).
    pub fn fetch(&self, id: usize) -> Result<Arc<Partition>> {
        let mut inner = self.inner.lock_recover();
        inner.clock += 1;
        let now = inner.clock;
        let nslots = inner.slots.len();
        {
            let slot = inner.slots.get_mut(id).ok_or_else(|| {
                OsebaError::Store(format!("unknown partition {id} (store has {nslots})"))
            })?;
            if slot.quarantined {
                return Err(OsebaError::Store(format!(
                    "partition {id} is quarantined (segment '{}' failed verification)",
                    slot.file
                )));
            }
            if let Some(p) = &slot.resident {
                slot.last_touch = now;
                return Ok(Arc::clone(p));
            }
        }

        // Cold: read + verify the segment, then make room and pin it. The
        // slot's resident seal-time sketches are attached to the decoded
        // partition (skipping the recompute pass); a pre-v3-manifest slot
        // without sketches falls back to recomputing them from the data.
        let path = self.dir.join(&inner.slots[id].file);
        let part = self.read_with_retry(&mut inner, id, &path)?;
        let expect = inner.slots[id].meta;
        if part.id != id
            || part.rows != expect.rows
            || part.columns.len() != self.schema.width()
        {
            return Err(OsebaError::Store(format!(
                "segment '{}' disagrees with manifest (id {} rows {} width {}, \
                 expected id {id} rows {} width {})",
                path.display(),
                part.id,
                part.rows,
                part.columns.len(),
                expect.rows,
                self.schema.width()
            )));
        }
        let bytes = part.bytes();
        self.allocate_evicting(&mut inner, bytes, id)?;
        let arc = Arc::new(part);
        let slot = &mut inner.slots[id];
        slot.resident = Some(Arc::clone(&arc));
        slot.bytes = bytes;
        slot.last_touch = now;
        self.faults.fetch_add(1, Ordering::Relaxed);
        self.bytes_read.fetch_add(
            segment_len(arc.rows, arc.padded_rows, arc.columns.len()),
            Ordering::Relaxed,
        );
        Ok(arc)
    }

    /// Read slot `id`'s segment with bounded-backoff retries. After the
    /// policy is exhausted a corruption failure ([`OsebaError::Store`] —
    /// CRC mismatch, truncation, bad magic) quarantines the partition;
    /// plain I/O failures propagate unquarantined (the segment bytes may
    /// be fine — the path to them isn't).
    fn read_with_retry(
        &self,
        inner: &mut Inner,
        id: usize,
        path: &Path,
    ) -> Result<Partition> {
        let policy = *self.retry.lock_recover();
        let started = Instant::now();
        let mut attempt = 0usize;
        loop {
            match read_segment_with(
                path,
                &self.io,
                inner.slots[id].sketches.clone(),
                inner.slots[id].filters.clone(),
                inner.slots[id].block_sketches.clone(),
            ) {
                Ok(part) => {
                    if attempt > 0 {
                        self.io_retry_successes.fetch_add(1, Ordering::Relaxed);
                        self.note_recovery(started);
                    }
                    return Ok(part);
                }
                Err(e) => {
                    attempt += 1;
                    if attempt >= policy.max_attempts.max(1) {
                        if attempt > 1 {
                            self.note_recovery(started);
                        }
                        return match e {
                            OsebaError::Store(msg) => {
                                inner.slots[id].quarantined = true;
                                self.quarantined.fetch_add(1, Ordering::Relaxed);
                                Err(OsebaError::Store(format!(
                                    "partition {id} quarantined after {attempt} attempt(s): {msg}"
                                )))
                            }
                            other => Err(other),
                        };
                    }
                    self.io_retries.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(policy.backoff(attempt - 1));
                }
            }
        }
    }

    fn note_recovery(&self, started: Instant) {
        let nanos = u64::try_from(started.elapsed().as_nanos()).unwrap_or(u64::MAX);
        self.recovery_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Charge `bytes` to the tracker, spilling LRU hot partitions (never
    /// slot `exclude`) until it fits. Fails with the tracker's
    /// `OutOfMemory` once nothing evictable remains.
    fn allocate_evicting(&self, inner: &mut Inner, bytes: usize, exclude: usize) -> Result<()> {
        // A request larger than the whole budget can never fit: fail now
        // instead of pointlessly spilling the entire hot set first.
        if let Some(budget) = self.tracker.budget() {
            if bytes > budget {
                return Err(OsebaError::OutOfMemory { requested: bytes, budget });
            }
        }
        loop {
            match self.tracker.allocate(bytes) {
                Ok(()) => return Ok(()),
                Err(oom @ OsebaError::OutOfMemory { .. }) => {
                    if self.spill_lru(inner, exclude)?.is_none() {
                        return Err(oom);
                    }
                }
                Err(e) => return Err(e),
            }
        }
    }

    /// Spill the least-recently-used hot partition (skipping `exclude`).
    /// Returns the bytes freed, or `None` when nothing hot is left.
    fn spill_lru(&self, inner: &mut Inner, exclude: usize) -> Result<Option<usize>> {
        let victim = inner
            .slots
            .iter()
            .enumerate()
            .filter(|(i, s)| *i != exclude && s.resident.is_some())
            .min_by_key(|(_, s)| s.last_touch)
            .map(|(i, _)| i);
        match victim {
            Some(vi) => {
                let bytes = inner.slots[vi].bytes;
                self.spill_slot(inner, vi)?;
                Ok(Some(bytes))
            }
            None => Ok(None),
        }
    }

    /// Write slot `vi`'s segment if none exists yet (immutable data: a
    /// segment, once written, stays current forever).
    fn ensure_on_disk(&self, inner: &mut Inner, vi: usize) -> Result<()> {
        if inner.slots[vi].on_disk {
            return Ok(());
        }
        let path = self.dir.join(&inner.slots[vi].file);
        // Every slot is resident, on disk, or both (insert establishes one
        // of the two); a slot with neither is corrupt state, not a bug to
        // die on — surface it as a store error.
        let part = match inner.slots[vi].resident.as_ref() {
            Some(p) => Arc::clone(p),
            None => {
                return Err(OsebaError::Store(format!(
                    "partition {vi} has neither a resident copy nor a segment"
                )))
            }
        };
        let written = write_segment_with(&path, &part, &self.io)?;
        self.bytes_written.fetch_add(written, Ordering::Relaxed);
        inner.slots[vi].on_disk = true;
        Ok(())
    }

    /// Write slot `vi`'s segment if it has none, then drop the resident
    /// copy and credit the tracker.
    fn spill_slot(&self, inner: &mut Inner, vi: usize) -> Result<()> {
        self.ensure_on_disk(inner, vi)?;
        let slot = &mut inner.slots[vi];
        slot.resident = None;
        self.tracker.release(slot.bytes);
        self.evictions.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Spill LRU hot partitions until at least `needed` bytes are freed
    /// (or nothing hot remains). Returns the bytes actually freed — the
    /// block manager's memory-pressure hook.
    pub fn shrink(&self, needed: usize) -> Result<usize> {
        let mut inner = self.inner.lock_recover();
        let mut freed = 0usize;
        while freed < needed {
            match self.spill_lru(&mut inner, usize::MAX)? {
                Some(bytes) => freed += bytes,
                None => break,
            }
        }
        Ok(freed)
    }

    /// Persist the store: write segments for any hot-only partitions and
    /// write the manifest (schema + segment metadata + super-index
    /// snapshot). Hot partitions stay hot — `save` is a checkpoint, not an
    /// eviction.
    ///
    /// The commit order is segments-then-manifest, each durably committed
    /// (fsync'd tmp + rename + directory sync): a crash at any point
    /// leaves either the last committed snapshot or the new one — never a
    /// manifest referencing a segment that isn't fully on disk
    /// (DESIGN.md §16).
    pub fn save(&self) -> Result<()> {
        let mut inner = self.inner.lock_recover();
        if inner.slots.is_empty() {
            return Err(OsebaError::Store(format!(
                "store '{}' has no partitions to save",
                self.dir.display()
            )));
        }
        for vi in 0..inner.slots.len() {
            self.ensure_on_disk(&mut inner, vi)?;
        }
        let segments = inner
            .slots
            .iter()
            .map(|s| SegmentEntry {
                file: s.file.clone(),
                meta: s.meta,
                zones: s.zones.clone(),
                sketches: s.sketches.clone(),
                filters: s.filters.clone(),
                blocks: s.block_sketches.clone(),
            })
            .collect();
        StoreManifest::for_segments(self.schema.clone(), segments)?
            .save_with(&self.dir, &self.io)
    }

    /// Drop every resident partition and credit the tracker — the
    /// unpersist path. Segments already on disk are untouched; hot-only
    /// data is discarded (unpersist means discard).
    pub fn release_resident(&self) {
        let mut inner = self.inner.lock_recover();
        for slot in &mut inner.slots {
            if slot.resident.take().is_some() {
                self.tracker.release(slot.bytes);
            }
        }
    }

    /// Build the super index over the current partition set — pure
    /// metadata, no residency change.
    pub fn build_cias(&self) -> Result<Cias> {
        Cias::from_meta(self.metas())
    }

    /// Per-partition metadata (also the §III-A table-index rows).
    pub fn metas(&self) -> Vec<PartitionMeta> {
        self.inner.lock_recover().slots.iter().map(|s| s.meta).collect()
    }

    /// Per-column zone maps of partition `id` — pure metadata: no
    /// residency change, no fault-in. `None` for an unknown id.
    pub fn zone_maps(&self, id: usize) -> Option<Vec<ZoneMap>> {
        self.inner.lock_recover().slots.get(id).map(|s| s.zones.clone())
    }

    /// The aggregate sketch of one column of partition `id` — pure
    /// metadata: no residency change, no fault-in. `None` for an unknown
    /// id, an out-of-range column, or a store opened from a pre-v3
    /// manifest (no sketch → the partition always scans).
    pub fn sketch(&self, id: usize, column: usize) -> Option<ColumnSketch> {
        self.inner
            .lock_recover()
            .slots
            .get(id)
            .and_then(|s| s.sketches.as_ref())
            .and_then(|sk| sk.get(column).copied())
    }

    /// The per-column membership filters of partition `id` — pure
    /// metadata: no residency change, no fault-in, so a Cold partition is
    /// filter-pruned before any segment read. `None` for an unknown id or
    /// a store opened from a pre-v4 manifest (no filter → the planner
    /// always considers the partition).
    pub fn filters(&self, id: usize) -> Option<Arc<Vec<MembershipFilter>>> {
        self.inner.lock_recover().slots.get(id).and_then(|s| s.filters.clone())
    }

    /// The per-block sketch hierarchy of partition `id` — pure metadata:
    /// no residency change, no fault-in, so a Cold partition's blocks are
    /// classified before any segment read. `None` for an unknown id or a
    /// store opened from a pre-v5 manifest (no block sketches → every
    /// targeted block scans).
    pub fn block_sketches(&self, id: usize) -> Option<Arc<BlockSketches>> {
        self.inner.lock_recover().slots.get(id).and_then(|s| s.block_sketches.clone())
    }

    /// Total resident footprint of the membership filters across all
    /// partitions, in bytes — the metadata cost the server's `info` op
    /// surfaces as `filter_bytes`.
    pub fn filter_bytes(&self) -> usize {
        self.inner
            .lock_recover()
            .slots
            .iter()
            .filter_map(|s| s.filters.as_ref())
            .map(|fs| fs.iter().map(MembershipFilter::memory_bytes).sum::<usize>())
            .sum()
    }

    /// Metadata of partition `id` (`None` for an unknown id) — O(1), no
    /// residency change.
    pub fn meta(&self, id: usize) -> Option<PartitionMeta> {
        self.inner.lock_recover().slots.get(id).map(|s| s.meta)
    }

    /// Number of partitions the store holds (Hot + Cold).
    pub fn num_partitions(&self) -> usize {
        self.inner.lock_recover().slots.len()
    }

    /// Total valid rows across all partitions.
    pub fn total_rows(&self) -> usize {
        self.inner.lock_recover().slots.iter().map(|s| s.meta.rows).sum()
    }

    /// In-memory footprint of the full dataset if everything were Hot.
    pub fn total_bytes(&self) -> usize {
        self.inner.lock_recover().slots.iter().map(|s| s.bytes).sum()
    }

    /// Bytes currently Hot (charged to the tracker by this store).
    pub fn resident_bytes(&self) -> usize {
        self.inner
            .lock_recover()
            .slots
            .iter()
            .filter(|s| s.resident.is_some())
            .map(|s| s.bytes)
            .sum()
    }

    /// Smallest key across all partitions (`None` when empty).
    pub fn key_min(&self) -> Option<i64> {
        self.inner.lock_recover().slots.first().map(|s| s.meta.key_min)
    }

    /// Largest key across all partitions (`None` when empty).
    pub fn key_max(&self) -> Option<i64> {
        self.inner.lock_recover().slots.last().map(|s| s.meta.key_max)
    }

    /// Current residency of partition `id` (`None` for an unknown id).
    pub fn residency(&self, id: usize) -> Option<Residency> {
        self.inner.lock_recover().slots.get(id).map(|s| {
            if s.resident.is_some() {
                Residency::Hot
            } else {
                Residency::Cold
            }
        })
    }

    /// The schema every stored partition matches.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// The segment directory this store reads/writes.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The shared memory tracker Hot partitions are charged to.
    pub fn tracker(&self) -> &Arc<MemoryTracker> {
        &self.tracker
    }

    /// Point-in-time copy of the fault/evict/I-O counters.
    pub fn counters(&self) -> StoreCounters {
        StoreCounters {
            faults: self.faults.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            segment_bytes_read: self.bytes_read.load(Ordering::Relaxed),
            segment_bytes_written: self.bytes_written.load(Ordering::Relaxed),
            io_retries: self.io_retries.load(Ordering::Relaxed),
            io_retry_successes: self.io_retry_successes.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            recovery_nanos: self.recovery_nanos.load(Ordering::Relaxed),
        }
    }

    /// What the open-time recovery scan found (empty for created stores).
    pub fn recovery_report(&self) -> &RecoveryReport {
        &self.recovery
    }

    /// The retry policy applied to fault-in reads.
    pub fn retry_policy(&self) -> RetryPolicy {
        *self.retry.lock_recover()
    }

    /// Replace the fault-in retry policy.
    pub fn set_retry_policy(&self, policy: RetryPolicy) {
        *self.retry.lock_recover() = policy;
    }

    /// Whether strict mode is on (hard errors instead of degraded
    /// serving; off by default).
    pub fn strict(&self) -> bool {
        self.strict.load(Ordering::Relaxed)
    }

    /// Toggle strict mode: `true` restores the historic behavior where a
    /// quarantined partition fails the query instead of being served
    /// around with `degraded` accounting.
    pub fn set_strict(&self, strict: bool) {
        self.strict.store(strict, Ordering::Relaxed);
    }

    /// Whether partition `id` is quarantined (`false` for unknown ids).
    pub fn is_quarantined(&self, id: usize) -> bool {
        self.inner
            .lock_recover()
            .slots
            .get(id)
            .map(|s| s.quarantined)
            .unwrap_or(false)
    }

    /// Ids of every quarantined partition, ascending.
    pub fn quarantined_ids(&self) -> Vec<usize> {
        self.inner
            .lock_recover()
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.quarantined)
            .map(|(i, _)| i)
            .collect()
    }

    /// The `StoreIo` this store routes every filesystem touch through.
    pub fn store_io(&self) -> &StoreIo {
        &self.io
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{partition_batch_uniform, BatchBuilder};
    use crate::store::fault::{FaultInjector, FaultKind, FaultRule};
    use crate::testing::temp_dir;
    use std::time::Duration;

    fn parts(rows: usize, per: usize) -> Vec<Arc<Partition>> {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..rows {
            b.push(i as i64 * 10, &[i as f32, (i * 2) as f32]);
        }
        partition_batch_uniform(&b.finish().unwrap(), per).unwrap()
    }

    fn fill(store: &TieredStore, ps: &[Arc<Partition>]) {
        for p in ps {
            store.insert(Arc::clone(p)).unwrap();
        }
    }

    #[test]
    fn unbounded_store_stays_hot() {
        let dir = temp_dir("ts-hot");
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        let ps = parts(10_000, 4096);
        fill(&store, &ps);
        assert_eq!(store.num_partitions(), 3);
        assert_eq!(store.total_rows(), 10_000);
        for i in 0..3 {
            assert_eq!(store.residency(i), Some(Residency::Hot));
        }
        assert_eq!(store.counters(), StoreCounters::default());
        assert_eq!(store.resident_bytes(), store.total_bytes());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn pressure_spills_lru_instead_of_erroring() {
        let dir = temp_dir("ts-spill");
        let ps = parts(20_000, 4096); // 5 partitions
        let one = ps[0].bytes();
        // Room for ~2 partitions.
        let tracker = MemoryTracker::with_budget(2 * one + one / 2);
        let store = TieredStore::create(&dir, Schema::stock(), tracker).unwrap();
        fill(&store, &ps);
        assert_eq!(store.num_partitions(), 5);
        assert!(store.resident_bytes() <= 2 * one + one / 2);
        let c = store.counters();
        assert!(c.evictions >= 3, "evictions: {}", c.evictions);
        assert!(c.segment_bytes_written > 0);
        // Oldest partitions went cold first.
        assert_eq!(store.residency(0), Some(Residency::Cold));
        assert_eq!(store.residency(4), Some(Residency::Hot));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn fault_in_restores_identical_data_and_counts() {
        let dir = temp_dir("ts-fault");
        let ps = parts(20_000, 4096);
        let one = ps[0].bytes();
        let tracker = MemoryTracker::with_budget(2 * one + one / 2);
        let store = TieredStore::create(&dir, Schema::stock(), tracker).unwrap();
        fill(&store, &ps);
        assert_eq!(store.residency(0), Some(Residency::Cold));

        let before = store.counters();
        let p0 = store.fetch(0).unwrap();
        assert_eq!(p0.keys, ps[0].keys);
        assert_eq!(p0.columns, ps[0].columns);
        let d = store.counters().since(&before);
        assert_eq!(d.faults, 1);
        assert!(d.segment_bytes_read > 0);
        // Faulting 0 in must have evicted someone to make room.
        assert!(d.evictions >= 1);
        assert_eq!(store.residency(0), Some(Residency::Hot));

        // A hot fetch is free.
        let before = store.counters();
        let again = store.fetch(0).unwrap();
        assert!(Arc::ptr_eq(&p0, &again));
        assert_eq!(store.counters().since(&before), StoreCounters::default());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn partition_larger_than_budget_spills_directly() {
        let dir = temp_dir("ts-big");
        let ps = parts(5_000, 4096);
        let tracker = MemoryTracker::with_budget(16);
        let store = TieredStore::create(&dir, Schema::stock(), tracker).unwrap();
        fill(&store, &ps); // must not error
        assert_eq!(store.residency(0), Some(Residency::Cold));
        assert_eq!(store.resident_bytes(), 0);
        // ... and fetch of an over-budget partition fails with OutOfMemory.
        assert!(matches!(
            store.fetch(0),
            Err(OsebaError::OutOfMemory { .. })
        ));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn save_open_restores_index_without_reading_segments() {
        let dir = temp_dir("ts-saveopen");
        let ps = parts(10_000, 4096);
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        fill(&store, &ps);
        store.save().unwrap();
        let original = store.build_cias().unwrap();
        drop(store);

        let (back, index) =
            TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap();
        assert_eq!(back.num_partitions(), 3);
        assert_eq!(back.total_rows(), 10_000);
        assert_eq!(back.counters(), StoreCounters::default(), "open reads no data");
        for i in 0..3 {
            assert_eq!(back.residency(i), Some(Residency::Cold));
        }
        use crate::index::{ContentIndex, RangeQuery};
        let q = RangeQuery { lo: 500, hi: 60_000 };
        assert_eq!(index.lookup(q), original.lookup(q));

        // Fetch after open round-trips the data.
        let p1 = back.fetch(1).unwrap();
        assert_eq!(p1.keys, ps[1].keys);
        assert_eq!(back.counters().faults, 1);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn zone_maps_survive_save_open_without_fault_in() {
        let dir = temp_dir("ts-zones");
        let ps = parts(10_000, 4096);
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        fill(&store, &ps);
        let want: Vec<_> = (0..3).map(|i| store.zone_maps(i).unwrap()).collect();
        assert_eq!(want[0], ps[0].zone_maps());
        let want_sk: Vec<_> =
            (0..3).map(|i| store.sketch(i, 0).unwrap()).collect();
        assert_eq!(want_sk[1], ps[1].sketches[0]);
        store.save().unwrap();
        drop(store);

        let (back, _index) =
            TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap();
        for (i, w) in want.iter().enumerate() {
            assert_eq!(back.zone_maps(i).as_ref(), Some(w), "partition {i}");
        }
        // Sketches round-trip the manifest bit-for-bit and stay available
        // while every partition is Cold — zero fault-in.
        for (i, w) in want_sk.iter().enumerate() {
            assert_eq!(back.sketch(i, 0), Some(*w), "partition {i}");
            assert_eq!(back.residency(i), Some(Residency::Cold));
        }
        assert_eq!(back.sketch(0, 1), Some(ps[0].sketches[1]));
        assert_eq!(back.counters(), StoreCounters::default(), "metadata only");
        assert!(back.zone_maps(99).is_none());
        assert!(back.sketch(99, 0).is_none());
        assert!(back.sketch(0, 9).is_none());
        assert_eq!(back.meta(1).map(|m| m.rows), Some(4096));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn membership_filters_survive_save_open_without_fault_in() {
        let dir = temp_dir("ts-filters");
        let ps = parts(10_000, 4096);
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        fill(&store, &ps);
        assert!(store.filter_bytes() > 0);
        let want: Vec<_> = (0..3).map(|i| store.filters(i).unwrap()).collect();
        assert_eq!(*want[1], *ps[1].filters);
        store.save().unwrap();
        drop(store);

        let (back, _index) =
            TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap();
        // Filters round-trip the manifest bit-for-bit and stay available
        // while every partition is Cold — probes prune with zero fault-in.
        for (i, w) in want.iter().enumerate() {
            let fs = back.filters(i).unwrap();
            assert_eq!(*fs, **w, "partition {i}");
            assert_eq!(back.residency(i), Some(Residency::Cold));
            // Partition i of `parts` holds column-0 values i*4096.. — a
            // value from another partition must not be claimed present
            // unless it is a (rare, deterministic-here) false positive;
            // the value it does hold must always be found.
            let present = (i * 4096) as f32;
            assert!(fs[0].contains(present), "partition {i} lost {present}");
        }
        assert_eq!(back.filter_bytes(), want.iter().map(|fs| {
            fs.iter().map(MembershipFilter::memory_bytes).sum::<usize>()
        }).sum::<usize>());
        assert_eq!(back.counters(), StoreCounters::default(), "metadata only");
        assert!(back.filters(99).is_none());

        // Fault-in attaches the resident filters to the decoded partition.
        let p0 = back.fetch(0).unwrap();
        assert!(Arc::ptr_eq(&p0.filters, &back.filters(0).unwrap()));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn block_sketches_survive_save_open_without_fault_in() {
        let dir = temp_dir("ts-blocks");
        let ps = parts(10_000, 4096);
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        fill(&store, &ps);
        let want: Vec<_> = (0..3).map(|i| store.block_sketches(i).unwrap()).collect();
        assert_eq!(*want[1], *ps[1].block_sketches);
        store.save().unwrap();
        drop(store);

        let (back, _index) =
            TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap();
        // Block sketches round-trip the manifest bit-for-bit and stay
        // available while every partition is Cold — block classification
        // with zero fault-in.
        for (i, w) in want.iter().enumerate() {
            let bs = back.block_sketches(i).unwrap();
            assert_eq!(*bs, **w, "partition {i}");
            assert_eq!(bs.block_rows(), BLOCK_ROWS);
            assert_eq!(back.residency(i), Some(Residency::Cold));
        }
        assert_eq!(back.counters(), StoreCounters::default(), "metadata only");
        assert!(back.block_sketches(99).is_none());

        // Fault-in attaches the resident block sketches to the decoded
        // partition instead of recomputing them.
        let p0 = back.fetch(0).unwrap();
        assert!(Arc::ptr_eq(&p0.block_sketches, &back.block_sketches(0).unwrap()));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn create_removes_stale_manifest() {
        let dir = temp_dir("ts-stale");
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        fill(&store, &parts(8_192, 4096));
        store.save().unwrap();
        drop(store);
        // Re-creating a store over the directory invalidates the old
        // manifest: an open before the new store saves is a clean error,
        // not stale metadata over overwritten segments.
        let _fresh =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        assert!(TieredStore::open(&dir, MemoryTracker::unbounded()).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn insert_rejects_disorder_and_overlap() {
        let dir = temp_dir("ts-order");
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        let ps = parts(8_192, 4096);
        store.insert(Arc::clone(&ps[0])).unwrap();
        // Wrong id.
        assert!(store.insert(Arc::clone(&ps[0])).is_err());
        // Overlapping keys (re-id'd copy of partition 0).
        let dup = Arc::new(Partition {
            id: 1,
            ..(*ps[0]).clone()
        });
        assert!(store.insert(dup).is_err());
        // Wrong width.
        let skinny =
            Arc::new(Partition::from_rows(1, vec![i64::MAX - 1], vec![vec![0.0]]));
        assert!(store.insert(skinny).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shrink_frees_requested_bytes() {
        let dir = temp_dir("ts-shrink");
        let ps = parts(20_000, 4096);
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        fill(&store, &ps);
        let one = ps[0].bytes();
        let freed = store.shrink(one + 1).unwrap();
        assert!(freed >= one + 1, "freed {freed}");
        assert_eq!(store.residency(0), Some(Residency::Cold));
        assert_eq!(store.residency(1), Some(Residency::Cold));
        assert_eq!(store.residency(4), Some(Residency::Hot));
        // Shrinking more than exists frees what's left, then stops.
        let rest = store.resident_bytes();
        assert_eq!(store.shrink(usize::MAX).unwrap(), rest);
        assert_eq!(store.resident_bytes(), 0);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A retry policy with no sleeps, so fault batteries run fast.
    fn instant_retries(attempts: usize) -> RetryPolicy {
        RetryPolicy {
            max_attempts: attempts,
            base_delay: Duration::ZERO,
            max_delay: Duration::ZERO,
        }
    }

    #[test]
    fn kill_at_every_write_point_battery() {
        // Commit snapshot A (2 partitions), then extend to B (3) with a
        // simulated crash at the k-th mutating filesystem op — for every
        // k until the commit completes crash-free. Whatever op the crash
        // hits, a clean reopen must serve A or B in full, with every
        // referenced segment readable — never a torn hybrid.
        let ps = parts(12_288, 4096);
        let mut k = 0usize;
        loop {
            let dir = temp_dir(&format!("ts-kill-{k}"));
            let inj = Arc::new(FaultInjector::new(9));
            let store = TieredStore::create_with(
                &dir,
                Schema::stock(),
                MemoryTracker::unbounded(),
                StoreIo::with(Arc::clone(&inj)),
            )
            .unwrap();
            store.insert(Arc::clone(&ps[0])).unwrap();
            store.insert(Arc::clone(&ps[1])).unwrap();
            store.save().unwrap(); // snapshot A durably committed
            inj.arm_crash_after(k);
            let extended =
                store.insert(Arc::clone(&ps[2])).and_then(|_| store.save()).is_ok();
            if extended {
                assert!(!inj.crashed(), "crash at op {k} cannot also commit B");
            }
            drop(store);

            // Reopen with clean I/O — a restart after the power loss.
            let (back, index) = TieredStore::open(&dir, MemoryTracker::unbounded())
                .unwrap_or_else(|e| panic!("crash at op {k}: reopen failed: {e}"));
            let n = back.num_partitions();
            assert!(n == 2 || n == 3, "crash at op {k}: {n} partitions");
            if extended {
                assert_eq!(n, 3, "crash-free save must commit B");
            }
            assert_eq!(index.num_partitions(), n, "index matches the snapshot");
            for id in 0..n {
                let p = back.fetch(id).unwrap_or_else(|e| {
                    panic!("crash at op {k}: referenced partition {id} unreadable: {e}")
                });
                assert_eq!(p.keys, ps[id].keys, "crash at op {k}: partition {id}");
                assert_eq!(p.columns, ps[id].columns, "crash at op {k}: partition {id}");
            }
            // The scan scrubbed any orphaned tmp and only *reported*
            // segments outside the committed snapshot.
            let r = back.recovery_report();
            assert!(r.removed_tmp.iter().all(|f| f.ends_with(".tmp")), "{r:?}");
            assert!(r.unreferenced.iter().all(|f| f.ends_with(".oseg")), "{r:?}");
            if n == 2 {
                assert!(
                    r.unreferenced.iter().all(|f| f == "part-00002.oseg"),
                    "crash at op {k}: {r:?}"
                );
            }
            std::fs::remove_dir_all(&dir).unwrap();
            if extended {
                break;
            }
            k += 1;
            assert!(k < 64, "battery did not converge");
        }
        assert!(k >= 4, "the commit path must expose several crash points, saw {k}");
    }

    /// First index of `needle` in `hay`.
    fn find(hay: &[u8], needle: &str) -> Option<usize> {
        hay.windows(needle.len()).position(|w| w == needle.as_bytes())
    }

    #[test]
    fn torn_manifest_battery_rolls_back_to_previous_snapshot() {
        // Commit snapshot A (2 partitions) then B (3) so the durable
        // rollback copy holds A. Tear `manifest.json` at every section
        // boundary and a sweep of byte offsets: open must restore the A
        // snapshot from `.prev` — typed errors only, never a panic.
        let ps = parts(12_288, 4096);
        let dir = temp_dir("ts-torn");
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        store.insert(Arc::clone(&ps[0])).unwrap();
        store.insert(Arc::clone(&ps[1])).unwrap();
        store.save().unwrap();
        store.insert(Arc::clone(&ps[2])).unwrap();
        store.save().unwrap(); // `.prev` now holds the first snapshot
        drop(store);
        let manifest_path = dir.join(MANIFEST_FILE);
        let good = std::fs::read(&manifest_path).unwrap();

        let mut cuts: Vec<usize> = (0..good.len()).step_by(97).collect();
        for marker in
            ["\"format\"", "\"schema\"", "\"segments\"", "\"sketch\"", "\"filter\"", "\"blocks\"", "\"index\"", "\"asl\""]
        {
            if let Some(pos) = find(&good, marker) {
                cuts.push(pos);
                cuts.push(pos + marker.len());
            }
        }
        cuts.push(good.len() - 1);
        for cut in cuts {
            std::fs::write(&manifest_path, &good[..cut]).unwrap();
            let (back, _index) = TieredStore::open(&dir, MemoryTracker::unbounded())
                .unwrap_or_else(|e| panic!("cut at {cut}: rollback failed: {e}"));
            assert!(
                back.recovery_report().restored_previous,
                "cut at {cut}: must report the rollback"
            );
            // `.prev` holds the 2-partition snapshot; the stray third
            // segment is reported, not loaded.
            assert_eq!(back.num_partitions(), 2, "cut at {cut}");
            assert_eq!(
                back.recovery_report().unreferenced,
                ["part-00002.oseg"],
                "cut at {cut}"
            );
            let p = back.fetch(1).unwrap_or_else(|e| panic!("cut at {cut}: {e}"));
            assert_eq!(p.keys, ps[1].keys, "cut at {cut}");
            drop(back);
            // Rollback durably promoted `.prev` over the torn manifest:
            // a second open sees a clean store without recovering.
            let (again, _index) =
                TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap();
            assert!(!again.recovery_report().restored_previous, "cut at {cut}");
        }

        // Without the rollback copy a torn manifest is a typed store
        // error — not a panic, and not an accidental empty store.
        std::fs::write(&manifest_path, &good[..good.len() / 2]).unwrap();
        std::fs::remove_file(dir.join(PREV_MANIFEST_FILE)).unwrap();
        let err = TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap_err();
        assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
        // A missing manifest stays an I/O error (never a store that was
        // a directory full of segments gets "recovered" into something).
        std::fs::remove_file(&manifest_path).unwrap();
        let err = TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap_err();
        assert!(matches!(err, OsebaError::Io { .. }), "got: {err:?}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn transient_read_errors_retry_then_succeed() {
        let dir = temp_dir("ts-retry");
        let ps = parts(12_288, 4096);
        let inj = Arc::new(FaultInjector::new(3));
        let store = TieredStore::create_with(
            &dir,
            Schema::stock(),
            MemoryTracker::unbounded(),
            StoreIo::with(Arc::clone(&inj)),
        )
        .unwrap();
        fill(&store, &ps);
        store.save().unwrap();
        store.release_resident();
        assert_eq!(store.retry_policy(), RetryPolicy::default());
        store.set_retry_policy(instant_retries(3));
        // Two transient errors, then clean: attempt 3 succeeds.
        inj.add_rule(FaultRule::new(site::SEGMENT_READ, FaultKind::Error).budget(2));
        let before = store.counters();
        let p = store.fetch(0).unwrap();
        assert_eq!(p.keys, ps[0].keys);
        let d = store.counters().since(&before);
        assert_eq!(d.io_retries, 2);
        assert_eq!(d.io_retry_successes, 1);
        assert_eq!(d.quarantined, 0);
        assert_eq!(d.faults, 1);
        assert!(d.recovery_nanos > 0, "retries must account recovery time");
        assert!(!store.is_quarantined(0));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corruption_after_retries_quarantines_the_partition() {
        let dir = temp_dir("ts-quarantine");
        let ps = parts(12_288, 4096);
        let inj = Arc::new(FaultInjector::new(5));
        let store = TieredStore::create_with(
            &dir,
            Schema::stock(),
            MemoryTracker::unbounded(),
            StoreIo::with(Arc::clone(&inj)),
        )
        .unwrap();
        fill(&store, &ps);
        store.save().unwrap();
        store.release_resident();
        store.set_retry_policy(instant_retries(2));
        // Every read of the segment comes back with one bit flipped: CRC
        // verification fails on both attempts → quarantine.
        inj.add_rule(FaultRule::new(site::SEGMENT_READ, FaultKind::BitFlip));
        let err = store.fetch(1).unwrap_err();
        assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
        assert!(
            err.to_string().contains("quarantined after 2 attempt(s)"),
            "got: {err}"
        );
        assert_eq!(store.counters().quarantined, 1);
        assert!(store.is_quarantined(1));
        assert_eq!(store.quarantined_ids(), [1]);
        // Later fetches fail fast — no further reads, no second count.
        inj.clear_rules();
        let before = store.counters();
        let err = store.fetch(1).unwrap_err();
        assert!(err.to_string().contains("is quarantined"), "got: {err}");
        assert_eq!(store.counters().since(&before), StoreCounters::default());
        // Resident metadata keeps serving; other partitions are fine.
        assert!(store.sketch(1, 0).is_some());
        assert!(store.zone_maps(1).is_some());
        assert_eq!(store.fetch(0).unwrap().keys, ps[0].keys);
        assert!(!store.is_quarantined(0));
        // Strict mode is a store-level toggle the planner consults; the
        // store itself errors either way.
        assert!(!store.strict());
        store.set_strict(true);
        assert!(store.strict());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn plain_io_errors_propagate_without_quarantine() {
        let dir = temp_dir("ts-ioerr");
        let ps = parts(12_288, 4096);
        let inj = Arc::new(FaultInjector::new(7));
        let store = TieredStore::create_with(
            &dir,
            Schema::stock(),
            MemoryTracker::unbounded(),
            StoreIo::with(Arc::clone(&inj)),
        )
        .unwrap();
        fill(&store, &ps);
        store.save().unwrap();
        store.release_resident();
        store.set_retry_policy(instant_retries(2));
        // Errors on every attempt: the segment bytes may be fine — the
        // path to them isn't — so the partition is NOT quarantined.
        inj.add_rule(FaultRule::new(site::SEGMENT_READ, FaultKind::Error));
        let err = store.fetch(0).unwrap_err();
        assert!(matches!(err, OsebaError::Io { .. }), "got: {err:?}");
        assert!(!store.is_quarantined(0));
        assert_eq!(store.counters().quarantined, 0);
        // The path heals → the same fetch succeeds.
        inj.clear_rules();
        assert_eq!(store.fetch(0).unwrap().keys, ps[0].keys);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn open_scrubs_orphaned_tmp_and_reports_unreferenced_segments() {
        let dir = temp_dir("ts-scrub");
        let ps = parts(8_192, 4096);
        let store =
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap();
        fill(&store, &ps);
        store.save().unwrap();
        drop(store);
        // A crashed commit's staging file, a segment no manifest
        // references, and an unrelated file.
        std::fs::write(dir.join("part-00000.oseg.tmp"), b"half-written").unwrap();
        std::fs::write(dir.join("part-00099.oseg"), b"stray segment").unwrap();
        std::fs::write(dir.join("notes.txt"), b"out of scope").unwrap();

        let (back, _index) = TieredStore::open(&dir, MemoryTracker::unbounded()).unwrap();
        let r = back.recovery_report();
        assert_eq!(r.removed_tmp, ["part-00000.oseg.tmp"]);
        assert_eq!(r.unreferenced, ["part-00099.oseg"]);
        assert!(!r.restored_previous);
        assert!(!dir.join("part-00000.oseg.tmp").exists(), "orphan deleted");
        assert!(dir.join("part-00099.oseg").exists(), "reported, never deleted");
        assert!(dir.join("notes.txt").exists(), "unrelated files untouched");
        // The committed snapshot is untouched by the scrub.
        assert_eq!(back.num_partitions(), 2);
        assert_eq!(back.fetch(0).unwrap().keys, ps[0].keys);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn seeded_fault_storm_recovers_with_typed_errors_only() {
        // CI sweeps OSEBA_FAULT_SEED over fixed values; locally any run
        // uses the default. Under a 20% everything-errors storm every
        // failure must be typed, progress must be monotone, and the data
        // that finally lands must be bit-identical to the input.
        let seed = std::env::var("OSEBA_FAULT_SEED")
            .ok()
            .and_then(|s| s.trim().parse::<u64>().ok())
            .unwrap_or(0xA11CE);
        let assert_typed = |e: &OsebaError| {
            assert!(
                matches!(e, OsebaError::Io { .. } | OsebaError::Store(_)),
                "storm produced a non-store error: {e:?}"
            );
        };
        let ps = parts(12_288, 4096);
        let dir = temp_dir(&format!("ts-storm-{seed}"));
        let inj = Arc::new(FaultInjector::new(seed));
        inj.add_rule(FaultRule::new("*", FaultKind::Error).prob(0.2));
        let mut creates = 0usize;
        let store = loop {
            match TieredStore::create_with(
                &dir,
                Schema::stock(),
                MemoryTracker::unbounded(),
                StoreIo::with(Arc::clone(&inj)),
            ) {
                Ok(s) => break s,
                Err(e) => {
                    assert_typed(&e);
                    creates += 1;
                    assert!(creates < 1_000, "seed {seed}: create never converged");
                }
            }
        };
        fill(&store, &ps);
        let mut attempts = 0usize;
        while let Err(e) = store.save() {
            assert_typed(&e);
            attempts += 1;
            assert!(attempts < 1_000, "seed {seed}: save never converged");
        }
        store.release_resident();
        store.set_retry_policy(instant_retries(4));
        for (id, want) in ps.iter().enumerate() {
            let mut tries = 0usize;
            let got = loop {
                match store.fetch(id) {
                    Ok(p) => break p,
                    Err(e) => {
                        assert_typed(&e);
                        tries += 1;
                        assert!(tries < 1_000, "seed {seed}: fetch {id} never converged");
                    }
                }
            };
            assert_eq!(got.keys, want.keys, "seed {seed}: partition {id}");
            assert_eq!(got.columns, want.columns, "seed {seed}: partition {id}");
        }
        assert!(
            store.quarantined_ids().is_empty(),
            "seed {seed}: transient error storms must not quarantine"
        );
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
