//! The Spark-like in-memory processing substrate: datasets (RDDs) with
//! lineage, a block manager with storage-memory accounting, and the two
//! competing selective-access paths (scan-filter vs indexed slices).

pub mod block_manager;
pub mod context;
pub mod dataset;
pub mod memory;

pub use block_manager::{BlockManager, DatasetId};
pub use context::{CounterSnapshot, OsebaContext};
pub use dataset::{Dataset, Lineage, PinnedSlice, PinnedSlices, SliceView};
pub use memory::MemoryTracker;
