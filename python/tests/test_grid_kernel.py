"""Grid-batched segment_stats vs per-block oracle (the §Perf kernel)."""

import numpy as np
import pytest

pytest.importorskip("jax")
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.segment_stats import STATS_BATCH, segment_stats_grid

N = 128
B = 4

floats = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False, width=32)


@st.composite
def batch_case(draw):
    xs = np.asarray(
        draw(st.lists(st.lists(floats, min_size=N, max_size=N), min_size=B, max_size=B)),
        np.float32,
    )
    starts = np.asarray(draw(st.lists(st.integers(0, N), min_size=B, max_size=B)), np.int32)
    ends = np.asarray(draw(st.lists(st.integers(0, N), min_size=B, max_size=B)), np.int32)
    return xs, starts, ends


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.large_base_example])
@given(batch_case())
def test_grid_matches_per_block_oracle(case):
    xs, starts, ends = case
    out = segment_stats_grid(xs, starts, ends)
    for b in range(B):
        want = ref.segment_stats_ref(xs[b], int(starts[b]), int(ends[b]))
        for g, w, name in zip([o[b] for o in out], want,
                              ["max", "min", "sum", "sumsq", "count"]):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-3, err_msg=f"b={b} {name}")


def test_padded_rows_are_identity():
    xs = np.ones((B, N), np.float32)
    starts = np.array([0, 5, 0, 0], np.int32)
    ends = np.array([N, 5, 0, 1], np.int32)  # rows 1 and 2 empty
    mx, mn, s, ss, n = segment_stats_grid(xs, starts, ends)
    assert n[1] == 0 and n[2] == 0 and n[3] == 1
    assert mx[1] < -1e38 and mn[1] > 1e38
    assert s[0] == N


def test_full_batch_shape():
    xs = np.zeros((STATS_BATCH, N), np.float32)
    starts = np.zeros(STATS_BATCH, np.int32)
    ends = np.full(STATS_BATCH, N, np.int32)
    out = segment_stats_grid(xs, starts, ends)
    assert all(o.shape == (STATS_BATCH,) for o in out)
    np.testing.assert_array_equal(np.asarray(out[4]), np.full(STATS_BATCH, N, np.float32))
