//! The leader: the unified query-plan layer (logical [`Query`] →
//! optimizer → [`PhysicalPlan`] → execution), task routing/batching over
//! the simulated cluster, partial merging, and the interactive-session
//! driver that produces the paper's Fig 4 / Fig 6 measurements.

pub mod plan;
pub mod planner;
pub mod session;

pub use plan::{
    parse_predicates, plan_query, plan_query_opts, Explain, PhysicalPlan, PlanOptions,
    PlanTimings, PrunedRange, Query, QueryOp, QueryOutput,
};
pub use planner::{plan_batch, verify_batch, IndexKind, Method, PlannedQuery};
pub use session::{run_batch_session, run_session, BatchSessionReport, SessionReport};

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::analysis::ops::{gather_filtered, selection_mask, slice_moments_filtered};
use crate::analysis::{Analyzer, PeriodStats};
use crate::cluster::{Cluster, NetworkModel};
use crate::config::AppConfig;
use crate::engine::{Dataset, EpochSnapshot, LiveConfig, LiveDataset, OsebaContext};
use crate::error::{OsebaError, Result};
use crate::index::{
    for_each_block_class, BlockClass, Cias, ColumnPredicate, ContentIndex, RangeQuery, TableIndex,
};
use crate::metrics::{phase_mark, BatchReport, PlanPhase, Span, Timer};
use crate::runtime::backend::AnalysisBackend;
use crate::storage::{Partition, RecordBatch, Schema, BLOCK_ROWS};
use crate::util::stats::{Moments, TrendPartial};

/// How one targeted slice contributes to plan execution: scanned from the
/// pinned partition data, or answered by its seal-time aggregate sketch
/// (on the native backend, bit-identical to the scan — same kernel-block
/// fold; no data touch, no fault-in either way).
enum PlanSource {
    /// Read the slice rows from this pinned partition.
    Scan(Arc<Partition>),
    /// Merge the precomputed sketch partials instead of reading.
    Sketch(crate::index::ColumnSketch),
    /// Merge this pre-merged partial of the slice's covered blocks —
    /// block classification left nothing to scan, so the partition was
    /// never resolved (a cold slot's segment stays unread).
    Blocks(Moments),
}

/// Fold `[row_start, row_end)` of `column` with block-sketch assistance:
/// walk the slice's kernel blocks in order — merge the retained partial
/// of a fully-selected block (predicate-free selections only), skip a
/// block whose block-level zones cannot satisfy the conjunction, and
/// masked-fold the rest. Bit-identical on the native backend to the
/// plain slice fold, which decomposes at the same block boundaries with
/// the same kernels: a covered partial IS that block's fold, a pruned
/// block's fold selects nothing (merging it is the identity), and the
/// left-to-right merge order is unchanged.
fn assisted_slice_moments(
    backend: &dyn AnalysisBackend,
    part: &Arc<Partition>,
    row_start: usize,
    row_end: usize,
    column: usize,
    preds: &[ColumnPredicate],
    batch: bool,
) -> Result<Moments> {
    let blocks = Arc::clone(&part.block_sketches);
    if blocks.block_rows() != BLOCK_ROWS || blocks.num_blocks() == 0 {
        return slice_moments_filtered(backend, part, row_start, row_end, column, preds, batch);
    }
    let cover_ok = preds.is_empty() && column < blocks.num_columns();
    let mut m = Moments::EMPTY;
    let mut err = None;
    for_each_block_class(
        &blocks,
        part.rows,
        row_start,
        row_end,
        preds,
        cover_ok,
        |b, bs, be, class| {
            if err.is_some() {
                return;
            }
            match class {
                BlockClass::Covered => {
                    // `cover_ok` guarantees the partial exists.
                    m = m.merge(blocks.moments(column, b).unwrap_or(Moments::EMPTY));
                }
                BlockClass::Pruned => {}
                BlockClass::Scanned => {
                    match slice_moments_filtered(backend, part, bs, be, column, preds, batch) {
                        Ok(p) => m = m.merge(p),
                        Err(e) => err = Some(e),
                    }
                }
            }
        },
    );
    match err {
        Some(e) => Err(e),
        None => Ok(m),
    }
}

/// Wall-clock split of one physical execution: slice resolve / cold
/// fault-in versus scanning + partial merging. Accumulated with
/// [`phase_mark`], so readings are monotonic-safe. Also carries the
/// execution-time degraded count — slices the plan targeted but whose
/// partition failed verification *during* this execution (and was
/// quarantined by the store), answered by skipping.
#[derive(Clone, Copy, Debug, Default)]
struct ExecTimings {
    fault_in: Duration,
    scan_merge: Duration,
    degraded: usize,
}

/// Assemble the span tree of one executed plan. Phase wall times come
/// from the lowering/execution timings; per-phase counts come straight
/// from the plan's [`Explain`], so a trace always agrees with the
/// `explain` output for the same query.
fn trace_span(plan: &PhysicalPlan, et: &ExecTimings, faults: usize, total: Duration) -> Span {
    let ex = &plan.explain;
    Span::new("query")
        .with_secs(total.as_secs_f64())
        .count("partitions", ex.partitions as u64)
        .count("merged_ranges", ex.merged_ranges as u64)
        .child(
            Span::new("targeting")
                .with_secs(plan.timings.targeting.as_secs_f64())
                .count("considered", ex.considered as u64)
                .count("key_pruned", ex.key_pruned as u64),
        )
        .child(
            Span::new("zone_pruning")
                .with_secs(plan.timings.zone_pruning.as_secs_f64())
                .count("zone_pruned", ex.zone_pruned as u64),
        )
        .child(
            Span::new("filter_pruning")
                .with_secs(plan.timings.filter_pruning.as_secs_f64())
                .count("filter_pruned", ex.filter_pruned as u64)
                .count("filter_bytes", ex.filter_bytes as u64),
        )
        .child(
            Span::new("sketch_classify")
                .with_secs(plan.timings.sketch_classify.as_secs_f64())
                .count("agg_answered", ex.agg_answered as u64)
                .count("rows_avoided", ex.rows_avoided as u64)
                .count("bytes_avoided", ex.bytes_avoided as u64),
        )
        .child(
            Span::new("block_classify")
                .with_secs(plan.timings.block_classify.as_secs_f64())
                .count("blocks_covered", ex.blocks_covered as u64)
                .count("blocks_pruned", ex.blocks_pruned as u64),
        )
        .child(
            Span::new("fault_in")
                .with_secs(et.fault_in.as_secs_f64())
                .count("targeted", ex.targeted as u64)
                .count("faults", faults as u64),
        )
        .child(
            Span::new("scan_merge")
                .with_secs(et.scan_merge.as_secs_f64())
                .count("estimated_rows", ex.estimated_rows as u64)
                .count("estimated_bytes", ex.estimated_bytes as u64),
        )
}

/// A finalized linear-trend fit over a key-range selection (least squares
/// of value over key), the consumer of the sketches' regression partials:
/// covered partitions contribute their seal-time [`TrendPartial`]s, edge
/// partitions are scanned — the merged fit is identical either way
/// because the partial algebra is associative.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrendLine {
    /// Least-squares slope (value units per key unit).
    pub slope: f64,
    /// Least-squares intercept (value at key 0).
    pub intercept: f64,
    /// (key, value) pairs fitted (NaN values excluded).
    pub count: u64,
    /// Pairs excluded because their value was NaN.
    pub nans: u64,
}

/// The driver/leader of the system.
pub struct Coordinator {
    ctx: OsebaContext,
    analyzer: Analyzer,
    backend: Arc<dyn AnalysisBackend>,
    cluster: Cluster,
    /// Batch all of a worker's kernel blocks into one backend submission.
    pub batch_kernel_calls: bool,
}

impl Coordinator {
    /// Build from config + an already-constructed backend.
    pub fn new(cfg: &AppConfig, backend: Arc<dyn AnalysisBackend>) -> Result<Coordinator> {
        let ctx = OsebaContext::new(cfg.ctx.clone());
        let cluster = Cluster::new(
            cfg.cluster_workers,
            0,
            NetworkModel { latency_us: cfg.net_latency_us },
        )?;
        Ok(Coordinator {
            ctx,
            analyzer: Analyzer::new(Arc::clone(&backend)),
            backend,
            cluster,
            batch_kernel_calls: true,
        })
    }

    /// The engine context this coordinator drives.
    pub fn context(&self) -> &OsebaContext {
        &self.ctx
    }

    /// The analysis engine (backend + block decomposition).
    pub fn analyzer(&self) -> &Analyzer {
        &self.analyzer
    }

    /// The simulated cluster (placement, liveness, network model).
    pub fn cluster(&self) -> &Cluster {
        &self.cluster
    }

    /// Load a batch as a cached dataset and register its partitions with
    /// the cluster placement.
    pub fn load(&self, batch: RecordBatch, num_partitions: usize) -> Result<Dataset> {
        let ds = self.ctx.load(batch, num_partitions)?;
        self.cluster.ensure_partitions(ds.num_partitions());
        Ok(ds)
    }

    /// Load a batch as a **tiered** dataset rooted at `dir`: partitions
    /// spill to `.oseg` segments under memory pressure instead of failing
    /// the load, so datasets larger than the budget are admissible.
    pub fn load_tiered(
        &self,
        batch: RecordBatch,
        num_partitions: usize,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<Dataset> {
        let ds = self.ctx.load_tiered(batch, num_partitions, dir)?;
        self.cluster.ensure_partitions(ds.num_partitions());
        Ok(ds)
    }

    /// Open a saved store directory as a tiered dataset, restoring the
    /// super index from its manifest snapshot (no segment data is read).
    pub fn open_store(
        &self,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<(Dataset, Box<dyn ContentIndex>)> {
        let (ds, index) = self.ctx.open_tiered(dir)?;
        self.cluster.ensure_partitions(ds.num_partitions());
        Ok((ds, Box::new(index)))
    }

    /// Create a **live** (append-while-serving) dataset on this
    /// coordinator's engine. Writers stream chunks in (directly or via
    /// [`crate::ingest::LiveIngestor`]); queries go through the
    /// snapshot-pinned [`Self::analyze_live`] / [`Self::analyze_live_batch`].
    pub fn create_live(&self, schema: Schema, cfg: LiveConfig) -> Result<Arc<LiveDataset>> {
        self.ctx.create_live(schema, cfg)
    }

    /// [`Self::create_live`] with sealed-partition spill to a
    /// [`crate::store::TieredStore`] rooted at `dir`.
    pub fn create_live_spilling(
        &self,
        schema: Schema,
        cfg: LiveConfig,
        dir: impl AsRef<std::path::Path>,
    ) -> Result<Arc<LiveDataset>> {
        self.ctx.create_live_spilling(schema, cfg, dir)
    }

    /// Pin the live dataset's current epoch and register its partitions
    /// with the cluster placement — every live analysis goes through here
    /// so a plan can never see a half-published partition.
    pub fn snapshot_live(&self, live: &LiveDataset) -> EpochSnapshot {
        let snap = live.snapshot();
        self.cluster.ensure_partitions(snap.num_partitions());
        snap
    }

    /// **Live Oseba phase**: snapshot-pinned single-query analysis.
    /// Returns the stats plus the epoch they were computed at.
    pub fn analyze_live(
        &self,
        live: &LiveDataset,
        q: RangeQuery,
        column: usize,
    ) -> Result<(PeriodStats, u64)> {
        let snap = self.snapshot_live(live);
        let index = snap.index().ok_or_else(|| {
            OsebaError::InvalidRange("live dataset has no sealed partitions yet".into())
        })?;
        let stats = self.analyze_period_oseba(snap.dataset(), index, q, column)?;
        Ok((stats, snap.epoch()))
    }

    /// **Live batch phase**: one epoch snapshot serves the whole planned
    /// batch, so every merged range, segment and demuxed result refers to
    /// the same immutable partition set even while appends continue.
    /// Returns per-query stats, the batch report, and the pinned epoch.
    pub fn analyze_live_batch(
        &self,
        live: &LiveDataset,
        queries: &[RangeQuery],
        column: usize,
    ) -> Result<(Vec<PeriodStats>, BatchReport, u64)> {
        let snap = self.snapshot_live(live);
        let index = snap.index().ok_or_else(|| {
            OsebaError::InvalidRange("live dataset has no sealed partitions yet".into())
        })?;
        let (stats, report) =
            self.analyze_batch_with_report(snap.dataset(), index, queries, column)?;
        Ok((stats, report, snap.epoch()))
    }

    /// Build the configured index over a dataset. For a tiered dataset the
    /// index is built from the store's metadata — no partition is faulted
    /// in.
    pub fn build_index(&self, ds: &Dataset, kind: IndexKind) -> Result<Box<dyn ContentIndex>> {
        if let Some(store) = ds.store() {
            let metas = store.metas();
            return Ok(match kind {
                IndexKind::Table => Box::new(TableIndex::from_meta(metas)?),
                IndexKind::Cias => Box::new(Cias::from_meta(metas)?),
            });
        }
        Ok(match kind {
            IndexKind::Table => Box::new(TableIndex::build(ds.partitions())?),
            IndexKind::Cias => Box::new(Cias::build(ds.partitions())?),
        })
    }

    /// **Baseline phase** (paper §IV-A "first method"): filter-scan all
    /// partitions, materialize + cache the selection, then analyze the
    /// filtered dataset. Returns the stats *and* the filtered dataset
    /// handle — which stays resident, exactly like Spark's default.
    pub fn analyze_period_default(
        &self,
        ds: &Dataset,
        q: RangeQuery,
        column: usize,
    ) -> Result<(PeriodStats, Dataset)> {
        let filtered = self.ctx.filter_range(ds, q)?;
        self.cluster.ensure_partitions(filtered.num_partitions());
        if filtered.total_rows() == 0 {
            return Err(OsebaError::InvalidRange(format!(
                "no rows in [{}, {}]",
                q.lo, q.hi
            )));
        }
        // Analyze every row of the filtered dataset, routed per worker.
        let slices: Vec<_> = filtered
            .partitions()
            .iter()
            .filter(|p| p.rows > 0)
            .map(|p| crate::index::PartitionSlice { partition: p.id, row_start: 0, row_end: p.rows })
            .collect();
        let items: Vec<_> = slices
            .iter()
            .map(|s| {
                (*s, PlanSource::Scan(Arc::clone(&filtered.partitions()[s.partition])))
            })
            .collect();
        let stats = self.run_stats_tasks(items, column, &[], false)?;
        Ok((stats, filtered))
    }

    /// **Oseba phase** (paper §IV-A "second method"): a thin wrapper over
    /// [`Self::execute_plan`] for a single key-range stats query — index
    /// lookup targets the partitions + row ranges; per-worker tasks
    /// compute moments over zero-copy views of the *original* partitions;
    /// the leader merges.
    pub fn analyze_period_oseba(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        q: RangeQuery,
        column: usize,
    ) -> Result<PeriodStats> {
        match self.execute_plan(ds, index, &Query::stats(q, column))?.0 {
            QueryOutput::Stats(s) => Ok(s),
            _ => Err(OsebaError::Runtime(
                "stats query produced a non-stats output".into(),
            )),
        }
    }

    /// Fit a least-squares **trend line** (value over key) to a key-range
    /// selection, through the same covered/edge lowering as stats: fully
    /// covered partitions contribute the centered regression co-moments
    /// their aggregate sketches carry (zero data touch, zero fault-in
    /// when cold); only the ≤2 edge partitions are resolved and scanned.
    /// The partial algebra merges pairwise, so the fit equals a full
    /// scan's wherever the merge tree groups the same way — the sketch
    /// partial *is* the per-partition scan partial.
    pub fn analyze_trend_line(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        q: RangeQuery,
        column: usize,
    ) -> Result<(TrendLine, Explain)> {
        let query = Query::stats(q, column);
        let plan = plan_query(ds, index, &query, true)?;
        let mut merged = TrendPartial::EMPTY;
        let degraded = self.for_each_plan_slice(ds, &plan.ranges, column, None, |s, src| {
            merged = merged.merge(match src {
                PlanSource::Sketch(sk) => sk.trend,
                PlanSource::Scan(part) => TrendPartial::scan(
                    &part.keys[s.row_start..s.row_end],
                    &part.columns[column][s.row_start..s.row_end],
                ),
                // Block sketches hold no regression partials; the trend
                // walk passes `block_preds: None`, so this variant is
                // never emitted for it.
                PlanSource::Blocks(_) => TrendPartial::EMPTY,
            });
        })?;
        let (Some(slope), Some(intercept)) = (merged.slope(), merged.intercept()) else {
            return Err(OsebaError::InvalidRange(format!(
                "selection [{}, {}] has no defined trend (fewer than two distinct keys)",
                q.lo, q.hi
            )));
        };
        let mut explain = plan.explain;
        explain.degraded += degraded;
        Ok((
            TrendLine {
                slope,
                intercept,
                count: merged.n as u64,
                nans: merged.nans as u64,
            },
            explain,
        ))
    }

    /// Lower + execute one logical [`Query`]: CIAS/ASL key targeting,
    /// zone-map pruning, batch merge of the ranges, then predicate-masked
    /// execution over only the surviving slices. Every specialized
    /// `analyze_*` entry point is a thin wrapper over this — fixed,
    /// tiered and live(-snapshot) datasets all take the identical path.
    pub fn execute_plan(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        query: &Query,
    ) -> Result<(QueryOutput, Explain)> {
        let (out, explain, _) = self.execute_plan_observed(ds, index, query, false)?;
        Ok((out, explain))
    }

    /// [`Self::execute_plan`] plus the per-query trace: the returned
    /// [`Span`] tree carries wall time per plan/execution phase and the
    /// phase counts from the same plan's [`Explain`] (so a trace always
    /// agrees with `explain` for the identical query). The server's
    /// `"trace":true` flag and the slow-query log are fed from here.
    pub fn execute_plan_traced(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        query: &Query,
    ) -> Result<(QueryOutput, Explain, Span)> {
        let (out, explain, span) = self.execute_plan_observed(ds, index, query, true)?;
        Ok((out, explain, span.unwrap_or_default()))
    }

    /// Shared body of [`Self::execute_plan`] / [`Self::execute_plan_traced`]:
    /// lower, record per-phase latencies into the metrics registry, execute,
    /// and (when asked) assemble the span tree.
    fn execute_plan_observed(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        query: &Query,
        want_trace: bool,
    ) -> Result<(QueryOutput, Explain, Option<Span>)> {
        let total = Instant::now();
        let plan = plan_query(ds, index, query, true)?;
        let m = self.ctx.metrics();
        m.record_phase(PlanPhase::Targeting, plan.timings.targeting);
        m.record_phase(PlanPhase::ZonePruning, plan.timings.zone_pruning);
        m.record_phase(PlanPhase::FilterPruning, plan.timings.filter_pruning);
        m.record_phase(PlanPhase::SketchClassify, plan.timings.sketch_classify);
        m.record_phase(PlanPhase::BlockClassify, plan.timings.block_classify);
        let store_before = ds.store().map(|s| s.counters()).unwrap_or_default();
        let mut et = ExecTimings::default();
        let out = self.execute_physical_timed(ds, &plan, query, &mut et)?;
        m.record_phase(PlanPhase::FaultIn, et.fault_in);
        m.record_phase(PlanPhase::ScanMerge, et.scan_merge);
        let store_delta = ds
            .store()
            .map(|s| s.counters().since(&store_before))
            .unwrap_or_default();
        // Time the store spent retrying/quarantining while this query
        // resolved its slices. Recorded only when fault handling actually
        // ran, so the histogram's count is the number of affected queries.
        if store_delta.recovery_nanos > 0 {
            m.record_phase(
                PlanPhase::FaultRecovery,
                Duration::from_nanos(store_delta.recovery_nanos),
            );
        }
        // Plan-time degraded (already in `plan.explain`) counts slices the
        // lowering dropped for known-quarantined partitions; execution-time
        // degraded adds partitions that failed verification during *this*
        // execution.
        let mut explain = plan.explain;
        explain.degraded += et.degraded;
        let span =
            want_trace.then(|| trace_span(&plan, &et, store_delta.faults, total.elapsed()));
        Ok((out, explain, span))
    }

    /// Execute an already-lowered [`PhysicalPlan`]. Public so the pruning
    /// bench and the property tests can run the `zone_pruning: false`
    /// oracle arm through the *identical* execution path.
    pub fn execute_physical(
        &self,
        ds: &Dataset,
        plan: &PhysicalPlan,
        query: &Query,
    ) -> Result<QueryOutput> {
        self.execute_physical_timed(ds, plan, query, &mut ExecTimings::default())
    }

    /// [`Self::execute_physical`] with the execution wall clock split into
    /// fault-in (slice resolve, including cold faults) and scan/merge.
    /// Trend and distance gather+analyze in one pass, so their whole body
    /// is attributed to scan/merge.
    fn execute_physical_timed(
        &self,
        ds: &Dataset,
        plan: &PhysicalPlan,
        query: &Query,
        et: &mut ExecTimings,
    ) -> Result<QueryOutput> {
        match query.op {
            QueryOp::Stats { column } => {
                let mark = Instant::now();
                let block_preds =
                    plan.block_assist.then_some(query.predicates.as_slice());
                let (items, degraded) =
                    self.stats_items(ds, &plan.ranges, column, block_preds)?;
                et.degraded += degraded;
                let mark = phase_mark(&mut et.fault_in, mark);
                if items.is_empty() {
                    return Err(empty_selection_error(query));
                }
                let stats =
                    self.run_stats_tasks(items, column, &query.predicates, plan.block_assist)?;
                phase_mark(&mut et.scan_merge, mark);
                Ok(QueryOutput::Stats(stats))
            }
            QueryOp::Trend { column, window } => {
                let mark = Instant::now();
                let (series, dropped) =
                    self.gather_plan_series(ds, &plan.ranges, column, &query.predicates)?;
                let mut stats = self.analyzer.ma_stats_of(&series, window)?;
                // NaN policy: the rows the gather dropped (NaN target
                // values of predicate-passing rows) stay surfaced.
                stats.nans += dropped as u64;
                phase_mark(&mut et.scan_merge, mark);
                Ok(QueryOutput::Trend(stats))
            }
            QueryOp::Distance { column, .. } => {
                let mark = Instant::now();
                let (av, am) =
                    self.gather_plan_masked(ds, &plan.ranges, column, &query.predicates)?;
                let (bv, bm) =
                    self.gather_plan_masked(ds, &plan.baseline, column, &query.predicates)?;
                if av.len() != bv.len() {
                    return Err(OsebaError::InvalidRange(format!(
                        "distance requires equal selections ({} vs {} rows)",
                        av.len(),
                        bv.len()
                    )));
                }
                // Pairs are positional in the raw key selections; a pair
                // is compared only when BOTH rows pass the predicates
                // (dropped pairs never shift the alignment). NaN pairs
                // are counted out by the distance kernel itself.
                let (sa, sb): (Vec<f32>, Vec<f32>) = av
                    .into_iter()
                    .zip(bv)
                    .zip(am.into_iter().zip(bm))
                    .filter(|&(_, (ma, mb))| ma && mb)
                    .map(|(pair, _)| pair)
                    .unzip();
                let distance = self.analyzer.distance_of(&sa, &sb)?;
                phase_mark(&mut et.scan_merge, mark);
                Ok(QueryOutput::Distance(distance))
            }
        }
    }

    /// The one covered/edge walk plan execution shares (stats and trend):
    /// visit every surviving slice of a plan in range/partition order —
    /// covered partitions as their sketches (no resolve, no fault-in —
    /// their cold segments are never read), edge partitions as resolved
    /// (pinned, refined, faulted in if cold) slices to scan. The visit
    /// order is identical whether or not any partition is covered, so
    /// sketch-answered and all-scanned runs merge partials in the same
    /// structure — a precondition for bit-identical results. Covered
    /// visits receive the plan's slice; scan visits the refined slice.
    ///
    /// `block_preds` is `Some(conjunction)` when the plan carries block
    /// assist (stats only — the trend walk passes `None` because block
    /// sketches hold no regression partials). An assisted slice is
    /// classified here, pre-resolve, from pure metadata: blocks are
    /// booked into the engine counters, and when classification leaves
    /// nothing to scan the slice is answered as [`PlanSource::Blocks`]
    /// without ever resolving — a cold partition faults nothing in.
    ///
    /// Returns the number of slices skipped as **degraded**: a resolve
    /// that fails with a store-level verification error (the segment was
    /// corrupt and the store quarantined the partition) drops the slice
    /// instead of failing the query — unless the store is in strict mode,
    /// in which case the error propagates. I/O errors other than
    /// verification failures always propagate.
    fn for_each_plan_slice(
        &self,
        ds: &Dataset,
        ranges: &[PrunedRange],
        column: usize,
        block_preds: Option<&[ColumnPredicate]>,
        mut visit: impl FnMut(crate::index::PartitionSlice, PlanSource),
    ) -> Result<usize> {
        let mut degraded = 0usize;
        let mut answered = 0usize;
        let mut block_answered = 0usize;
        let mut covered_blocks = 0usize;
        let mut pruned_blocks = 0usize;
        for pr in ranges {
            for s in &pr.slices {
                if pr.is_covered(s.partition) {
                    let sk = ds.sketch(s.partition, column).ok_or_else(|| {
                        OsebaError::Index(format!(
                            "plan marked partition {} covered but it has no sketch",
                            s.partition
                        ))
                    })?;
                    answered += 1;
                    visit(*s, PlanSource::Sketch(sk));
                    continue;
                }
                if let Some(preds) = block_preds {
                    if let Some((blocks, rows, cover_ok)) =
                        plan::block_assist_for(ds, s, pr.range, preds, column)
                    {
                        let mut merged = Moments::EMPTY;
                        let mut scanned = 0usize;
                        for_each_block_class(
                            &blocks,
                            rows,
                            s.row_start,
                            s.row_end,
                            preds,
                            cover_ok,
                            |b, _bs, _be, class| match class {
                                BlockClass::Covered => {
                                    covered_blocks += 1;
                                    // `cover_ok` guarantees the partial exists.
                                    merged = merged.merge(
                                        blocks.moments(column, b).unwrap_or(Moments::EMPTY),
                                    );
                                }
                                BlockClass::Pruned => pruned_blocks += 1,
                                BlockClass::Scanned => scanned += 1,
                            },
                        );
                        if scanned == 0 {
                            block_answered += 1;
                            visit(*s, PlanSource::Blocks(merged));
                            continue;
                        }
                    }
                }
                let resolved =
                    match self.ctx.resolve_slices(ds, std::slice::from_ref(s), pr.range) {
                        Ok(r) => r,
                        Err(OsebaError::Store(_)) if !ds.strict_faults() => {
                            // The store quarantined the partition: serve
                            // the rest of the selection and account for
                            // the gap instead of failing the query.
                            degraded += 1;
                            continue;
                        }
                        Err(e) => return Err(e),
                    };
                for (part, refined) in resolved {
                    visit(refined, PlanSource::Scan(part));
                }
            }
        }
        self.ctx.note_agg_answered(answered);
        self.ctx.note_targeted(block_answered);
        self.ctx.note_blocks(covered_blocks, pruned_blocks);
        self.ctx.note_degraded(degraded);
        Ok(degraded)
    }

    /// Collect [`Self::for_each_plan_slice`] into the stats work list,
    /// plus the count of slices skipped as degraded.
    fn stats_items(
        &self,
        ds: &Dataset,
        ranges: &[PrunedRange],
        column: usize,
        block_preds: Option<&[ColumnPredicate]>,
    ) -> Result<(Vec<(crate::index::PartitionSlice, PlanSource)>, usize)> {
        let mut items = Vec::new();
        let degraded = self.for_each_plan_slice(ds, ranges, column, block_preds, |s, src| {
            items.push((s, src))
        })?;
        Ok((items, degraded))
    }

    /// Pin + gather the (predicate-filtered) series of `column` across a
    /// plan's pruned ranges, in range/partition order. The second return
    /// value counts predicate-passing rows dropped for being NaN.
    fn gather_plan_series(
        &self,
        ds: &Dataset,
        ranges: &[PrunedRange],
        column: usize,
        predicates: &[ColumnPredicate],
    ) -> Result<(Vec<f32>, usize)> {
        let mut out = Vec::new();
        let mut nans = 0usize;
        for pr in ranges {
            let pins = self.ctx.select_slices(ds, &pr.slices, pr.range)?;
            let (vals, dropped) = gather_filtered(&pins.views(), column, predicates);
            out.extend(vals);
            nans += dropped;
        }
        Ok((out, nans))
    }

    /// Pin + gather one side of a distance comparison: the **raw** values
    /// of `column` (NaNs and predicate failures included, so positions
    /// stay aligned) plus the per-row predicate mask.
    fn gather_plan_masked(
        &self,
        ds: &Dataset,
        ranges: &[PrunedRange],
        column: usize,
        predicates: &[ColumnPredicate],
    ) -> Result<(Vec<f32>, Vec<bool>)> {
        let mut vals = Vec::new();
        let mut mask = Vec::new();
        for pr in ranges {
            let pins = self.ctx.select_slices(ds, &pr.slices, pr.range)?;
            let views = pins.views();
            vals.extend(crate::analysis::ops::gather(&views, column));
            mask.extend(selection_mask(&views, predicates));
        }
        Ok((vals, mask))
    }

    /// **Batch phase** (many concurrent sessions, one engine): plan N
    /// possibly-overlapping queries into disjoint merged ranges
    /// ([`plan_batch`]), route each merged range through the cluster
    /// *once*, execute every per-worker task concurrently on the engine
    /// thread pool, and demultiplex exact per-query [`PeriodStats`] from
    /// the shared elementary-segment partials.
    ///
    /// Overlap between input queries costs nothing extra: each partition
    /// intersecting a merged range is resolved (and counted in
    /// [`crate::engine::CounterSnapshot::partitions_targeted`]) exactly
    /// once per merged range, however many queries cover it — so a batch
    /// of N mutually-overlapping queries targets each partition once,
    /// instead of N times.
    ///
    /// Takes `&self` and is safe to call from many threads at once — the
    /// coordinator is `Send + Sync`.
    pub fn analyze_batch(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        queries: &[RangeQuery],
        column: usize,
    ) -> Result<Vec<PeriodStats>> {
        self.analyze_batch_with_report(ds, index, queries, column).map(|(stats, _)| stats)
    }

    /// [`Self::analyze_batch`] plus the planner/execution counters.
    pub fn analyze_batch_with_report(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        queries: &[RangeQuery],
        column: usize,
    ) -> Result<(Vec<PeriodStats>, BatchReport)> {
        self.execute_batch(ds, index, queries, &[], column)
    }

    /// The batch path with cross-layer predicate pushdown: plan N queries
    /// into disjoint merged ranges, **zone-prune** each merged range's
    /// partition list against `predicates` before anything is resolved
    /// (cold partitions are never faulted in), route once per merged
    /// range, run predicate-masked per-worker tasks, and demux exact
    /// per-query stats. With an empty conjunction this is byte-for-byte
    /// the classic batch path.
    pub fn execute_batch(
        &self,
        ds: &Dataset,
        index: &dyn ContentIndex,
        queries: &[RangeQuery],
        predicates: &[ColumnPredicate],
        column: usize,
    ) -> Result<(Vec<PeriodStats>, BatchReport)> {
        let timer = Timer::start();
        let store_before =
            ds.store().map(|s| s.counters()).unwrap_or_default();
        for (i, q) in queries.iter().enumerate() {
            if q.lo > q.hi {
                return Err(OsebaError::InvalidRange(format!(
                    "query {i}: lo {} > hi {}",
                    q.lo, q.hi
                )));
            }
        }
        let plan = plan_batch(queries);
        // Batch plans self-check in debug builds (DESIGN.md §12): sorted
        // disjoint merged ranges, every valid query owned exactly once,
        // demux segments tiling each merged range.
        #[cfg(debug_assertions)]
        planner::verify_batch(queries, &plan)?;

        // Global elementary-segment table across all merged ranges: the
        // shared partials per-query stats are demultiplexed from.
        let mut segments: Vec<RangeQuery> = Vec::new();
        let mut seg_sources: Vec<Vec<usize>> = Vec::new();
        // One work item per (partition, segment) contribution: a scanned
        // sub-slice, or a covered partition's sketch partial. Sketch items
        // ride the same routing and fold positions a scan of that
        // partition would occupy, so pushdown never regroups the merge.
        enum BatchItem {
            /// Scan `[rs, re)` of this pinned partition for one segment.
            Scan(Arc<Partition>, usize, usize, usize),
            /// The covered partition's whole contribution to one segment.
            Sketch(usize, Moments),
        }
        // One work list per (merged range, owning worker), executed as one
        // pool task each — independent merged queries run concurrently.
        let mut worker_lists: Vec<Vec<BatchItem>> = Vec::new();
        let mut partitions_touched = 0usize;
        let mut zone_pruned = 0usize;
        let mut filter_pruned = 0usize;
        let mut agg_answered = 0usize;
        let mut rows_avoided = 0usize;
        let mut blocks_covered = 0usize;
        let mut blocks_pruned = 0usize;
        let mut degraded = 0usize;

        for pq in &plan {
            let mut slices = index.lookup(pq.range);
            // Zone-map pruning (the same `zone_keep` decision the plan
            // layer makes): a partition whose value domain cannot satisfy
            // the conjunction is dropped here, before resolve — so a cold
            // (tiered) partition is never faulted in for it.
            if !predicates.is_empty() {
                slices.retain(|s| {
                    let keep = plan::zone_keep(ds, predicates, s.partition);
                    if !keep {
                        zone_pruned += 1;
                    }
                    keep
                });
                // Membership-filter pruning (the same `filter_keep`
                // decision): equality predicates probe each survivor's
                // per-column filter; a miss drops it before resolve.
                slices.retain(|s| {
                    let (keep, _) = plan::filter_keep(ds, predicates, s.partition);
                    if !keep {
                        filter_pruned += 1;
                    }
                    keep
                });
                // Block-level pre-check (the same classification the
                // plan layer books): a survivor whose every block the
                // conjunction rules out contributes nothing to any
                // segment — drop it before resolve, so a cold partition
                // with a hostile block grid is never faulted in.
                slices.retain(|s| {
                    match plan::block_counts_for(ds, s, pq.range, predicates, column) {
                        Some(c) if c.scanned == 0 => {
                            blocks_pruned += c.pruned;
                            rows_avoided += c.rows_avoided;
                            false
                        }
                        _ => true,
                    }
                });
            }
            partitions_touched += slices.len();
            let seg_base = segments.len();
            for (seg, srcs) in pq.segments(queries) {
                segments.push(seg);
                seg_sources.push(srcs);
            }
            // Aggregate pushdown: a partition whose key range lies fully
            // inside ONE elementary segment contributes exactly its
            // whole-partition partial to that segment — the sketch. Such
            // partitions are never resolved, so cold ones fault nothing
            // in. (Contained-in-a-segment implies contained in the merged
            // range: segments tile it.) A partition straddling a segment
            // boundary needs per-segment sub-slices and is scanned. Each
            // partition intersecting the merged range contributes once,
            // however many queries overlap it.
            let segs_here = &segments[seg_base..];
            let mut items: Vec<(usize, BatchItem)> = Vec::new();
            for s in &slices {
                let covered = if predicates.is_empty() {
                    plan::covered_in(ds, s.partition, column, segs_here)
                } else {
                    None
                };
                match covered {
                    Some((si, rows, sk)) => {
                        agg_answered += 1;
                        rows_avoided += rows;
                        items.push((
                            s.partition,
                            BatchItem::Sketch(seg_base + si, sk.moments),
                        ));
                    }
                    None => {
                        // A verification failure quarantines the partition
                        // inside the store; unless strict mode demands a
                        // hard error, skip its slice and keep serving the
                        // remainder of the batch. (Touched implies
                        // resolved, so back the count out.)
                        let resolved = match self.ctx.resolve_slices(
                            ds,
                            std::slice::from_ref(s),
                            pq.range,
                        ) {
                            Ok(r) => r,
                            Err(OsebaError::Store(_)) if !ds.strict_faults() => {
                                degraded += 1;
                                partitions_touched -= 1;
                                continue;
                            }
                            Err(e) => return Err(e),
                        };
                        for (part, slice) in resolved {
                            for (si, seg) in segs_here.iter().enumerate() {
                                let rs = part.lower_bound(seg.lo).max(slice.row_start);
                                let re = part.upper_bound(seg.hi).min(slice.row_end);
                                if rs < re {
                                    // Book the block classification the
                                    // worker's assisted fold will apply
                                    // to this sub-slice. (rs, re) come
                                    // from the partition's actual keys,
                                    // so the bounds are exact; a block
                                    // wholly inside them belongs to this
                                    // segment alone, which is what makes
                                    // merging its partial demux-safe.
                                    let sub = crate::index::PartitionSlice {
                                        partition: slice.partition,
                                        row_start: rs,
                                        row_end: re,
                                    };
                                    // Not booked into `rows_avoided`:
                                    // the partition is resolved either
                                    // way, so its bytes were read.
                                    if let Some(c) = plan::block_counts_for(
                                        ds, &sub, *seg, predicates, column,
                                    ) {
                                        blocks_covered += c.covered;
                                        blocks_pruned += c.pruned;
                                    }
                                    items.push((
                                        slice.partition,
                                        BatchItem::Scan(
                                            Arc::clone(&part),
                                            seg_base + si,
                                            rs,
                                            re,
                                        ),
                                    ));
                                }
                            }
                        }
                    }
                }
            }
            for (_worker, list) in self.cluster.route_tagged(items)? {
                worker_lists.push(list);
            }
        }
        self.ctx.note_agg_answered(agg_answered);
        self.ctx.note_blocks(blocks_covered, blocks_pruned);
        self.ctx.note_degraded(degraded);

        let batch = self.batch_kernel_calls;
        let net = self.cluster.net;
        let tasks: Vec<_> = worker_lists
            .into_iter()
            .map(|list| {
                let backend = Arc::clone(&self.backend);
                let preds = predicates.to_vec();
                move || -> Result<Vec<(usize, Moments)>> {
                    net.message(); // task dispatch to this worker
                    let mut out = Vec::with_capacity(list.len());
                    for item in &list {
                        out.push(match item {
                            BatchItem::Sketch(seg, m) => (*seg, *m),
                            BatchItem::Scan(part, seg, rs, re) => {
                                let m = assisted_slice_moments(
                                    backend.as_ref(),
                                    part,
                                    *rs,
                                    *re,
                                    column,
                                    &preds,
                                    batch,
                                )?;
                                (*seg, m)
                            }
                        });
                    }
                    net.message(); // result return
                    Ok(out)
                }
            })
            .collect();
        let n_tasks = tasks.len();
        let mark = Instant::now();
        let partials = self.ctx.pool().scope_execute(tasks);

        let mut seg_moments = vec![Moments::EMPTY; segments.len()];
        for partial in partials {
            for (seg, m) in partial? {
                seg_moments[seg] = seg_moments[seg].merge(m);
            }
        }
        let mut scan_merge = Duration::ZERO;
        let mark = phase_mark(&mut scan_merge, mark);
        // Demux: a query's moments are the merge of the elementary
        // segments it covers (each segment knows its covering sources).
        let mut per_query = vec![Moments::EMPTY; queries.len()];
        for (seg, srcs) in seg_sources.iter().enumerate() {
            for &qi in srcs {
                per_query[qi] = per_query[qi].merge(seg_moments[seg]);
            }
        }
        let stats = per_query
            .into_iter()
            .enumerate()
            .map(|(i, m)| {
                PeriodStats::from_moments(m).ok_or_else(|| {
                    OsebaError::InvalidRange(format!(
                        "query {i} selects no rows in [{}, {}]",
                        queries[i].lo, queries[i].hi
                    ))
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let mut demux = Duration::ZERO;
        phase_mark(&mut demux, mark);
        self.ctx.metrics().record_phase(PlanPhase::ScanMerge, scan_merge);
        self.ctx.metrics().record_phase(PlanPhase::Demux, demux);

        let store_delta = ds
            .store()
            .map(|s| s.counters().since(&store_before))
            .unwrap_or_default();
        if store_delta.recovery_nanos > 0 {
            self.ctx.metrics().record_phase(
                PlanPhase::FaultRecovery,
                Duration::from_nanos(store_delta.recovery_nanos),
            );
        }
        let report = BatchReport {
            queries: queries.len(),
            merged_ranges: plan.len(),
            segments: segments.len(),
            partitions_touched,
            zone_pruned,
            filter_pruned,
            agg_answered,
            rows_avoided,
            bytes_avoided: rows_avoided * ds.schema().row_bytes(),
            blocks_covered,
            blocks_pruned,
            tasks: n_tasks,
            faults: store_delta.faults,
            evictions: store_delta.evictions,
            segment_bytes_read: store_delta.segment_bytes_read,
            degraded,
            secs: timer.secs(),
        };
        Ok((stats, report))
    }

    /// Snapshot-pinned execution of one logical [`Query`] against a live
    /// dataset — the live arm of the unified plan layer. Returns the
    /// output, the pruning report, and the epoch it was computed at.
    pub fn analyze_live_query(
        &self,
        live: &LiveDataset,
        query: &Query,
    ) -> Result<(QueryOutput, Explain, u64)> {
        let snap = self.snapshot_live(live);
        let index = snap.index().ok_or_else(|| {
            OsebaError::InvalidRange("live dataset has no sealed partitions yet".into())
        })?;
        let (out, explain) = self.execute_plan(snap.dataset(), index, query)?;
        Ok((out, explain, snap.epoch()))
    }

    /// Route slice tasks (scanned or sketch-answered) to their owning
    /// workers, execute (predicate-masked when `predicates` is non-empty),
    /// merge, finalize. Sketch items ride the same routing and fold
    /// positions as the scans they replace, so turning pushdown on or off
    /// never changes the merge structure — only whether data is read.
    fn run_stats_tasks(
        &self,
        items: Vec<(crate::index::PartitionSlice, PlanSource)>,
        column: usize,
        predicates: &[ColumnPredicate],
        block_assist: bool,
    ) -> Result<PeriodStats> {
        let groups = self
            .cluster
            .route_tagged(items.into_iter().map(|(s, src)| (s.partition, (s, src))).collect())?;

        let batch = self.batch_kernel_calls;
        let net = self.cluster.net;
        let tasks: Vec<_> = groups
            .into_iter()
            .map(|(_w, group)| {
                let backend = Arc::clone(&self.backend);
                let preds = predicates.to_vec();
                move || -> Result<Moments> {
                    net.message(); // task dispatch to this worker
                    let mut m = Moments::EMPTY;
                    for (s, src) in &group {
                        m = m.merge(match src {
                            PlanSource::Sketch(sk) => sk.moments,
                            PlanSource::Blocks(partial) => *partial,
                            PlanSource::Scan(part) if block_assist => assisted_slice_moments(
                                backend.as_ref(),
                                part,
                                s.row_start,
                                s.row_end,
                                column,
                                &preds,
                                batch,
                            )?,
                            PlanSource::Scan(part) => slice_moments_filtered(
                                backend.as_ref(),
                                part,
                                s.row_start,
                                s.row_end,
                                column,
                                &preds,
                                batch,
                            )?,
                        });
                    }
                    net.message(); // result return
                    Ok(m)
                }
            })
            .collect();

        let partials = self.ctx.pool().scope_execute(tasks);
        let mut merged = Moments::EMPTY;
        for p in partials {
            merged = merged.merge(p?);
        }
        PeriodStats::from_moments(merged)
            .ok_or_else(|| OsebaError::InvalidRange("empty selection".into()))
    }
}

/// The error for a plan whose selection resolves to nothing — either the
/// key ranges miss every partition, or zone maps proved the predicates
/// unsatisfiable everywhere.
fn empty_selection_error(query: &Query) -> OsebaError {
    let ranges = match query.ranges.as_slice() {
        [q] => format!("[{}, {}]", q.lo, q.hi),
        qs => format!("{} ranges", qs.len()),
    };
    if query.predicates.is_empty() {
        OsebaError::InvalidRange(format!("no partitions intersect {ranges}"))
    } else {
        OsebaError::InvalidRange(format!(
            "no partition in {ranges} can satisfy the predicates"
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{AppConfig, ContextConfig};
    use crate::datagen::ClimateGen;
    use crate::runtime::NativeBackend;

    fn coord(workers: usize) -> Coordinator {
        let cfg = AppConfig {
            ctx: ContextConfig { num_workers: 4, memory_budget: None },
            cluster_workers: workers,
            ..Default::default()
        };
        Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
    }

    fn q_hours(lo: i64, hi: i64) -> RangeQuery {
        RangeQuery { lo: lo * 3600, hi: hi * 3600 }
    }

    #[test]
    fn default_and_oseba_agree_exactly() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        for (lo, hi) in [(0, 100), (5_000, 12_000), (29_000, 29_999), (100, 25_000)] {
            let q = q_hours(lo, hi);
            let (d, filtered) = c.analyze_period_default(&ds, q, 0).unwrap();
            let o = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
            assert_eq!(d.count, o.count, "q={q:?}");
            assert_eq!(d.max, o.max);
            assert_eq!(d.min, o.min);
            assert!((d.mean - o.mean).abs() < 1e-6);
            assert!((d.std - o.std).abs() < 1e-6);
            c.context().unpersist(&filtered);
        }
    }

    #[test]
    fn oseba_touches_fewer_partitions() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let before = c.context().counters();
        let q = q_hours(0, 1_000); // first partition only
        c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        let after = c.context().counters();
        assert_eq!(after.partitions_scanned, before.partitions_scanned);
        assert_eq!(after.partitions_targeted - before.partitions_targeted, 1);
    }

    #[test]
    fn default_grows_memory_oseba_does_not() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let base = c.context().memory_used();
        let q = q_hours(2_000, 9_000);
        c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(c.context().memory_used(), base);
        let (_, _filtered) = c.analyze_period_default(&ds, q, 0).unwrap();
        assert!(c.context().memory_used() > base);
    }

    #[test]
    fn survives_worker_failure() {
        let c = coord(4);
        let ds = c.load(ClimateGen::default().generate(20_000), 12).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(1_000, 15_000);
        let before = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        c.cluster().kill_worker(2).unwrap();
        let after = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(before.count, after.count);
        assert_eq!(before.max, after.max);
        assert!((before.mean - after.mean).abs() < 1e-9);
    }

    #[test]
    fn table_and_cias_agree_via_coordinator() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(25_000), 9).unwrap();
        let t = c.build_index(&ds, IndexKind::Table).unwrap();
        let s = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(3_000, 17_000);
        let a = c.analyze_period_oseba(&ds, t.as_ref(), q, 2).unwrap();
        let b = c.analyze_period_oseba(&ds, s.as_ref(), q, 2).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn miss_query_errors() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(1_000), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = RangeQuery { lo: i64::MAX - 5, hi: i64::MAX };
        assert!(c.analyze_period_oseba(&ds, index.as_ref(), q, 0).is_err());
        assert!(c.analyze_period_default(&ds, q, 0).is_err());
    }

    #[test]
    fn unbatched_matches_batched() {
        let mut c = coord(2);
        let ds = c.load(ClimateGen::default().generate(15_000), 6).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(500, 11_000);
        let a = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        c.batch_kernel_calls = false;
        let b = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        assert_eq!(a, b);
    }

    fn assert_stats_close(a: &PeriodStats, b: &PeriodStats, ctx: &str) {
        // Exact on count/extremes; mean/std tolerate the f32 kernel
        // partials regrouping when blocks are split at segment boundaries
        // (same tolerance the default-vs-oseba equivalence tests use).
        assert_eq!(a.count, b.count, "{ctx}");
        assert_eq!(a.max, b.max, "{ctx}");
        assert_eq!(a.min, b.min, "{ctx}");
        assert!((a.mean - b.mean).abs() < 1e-6, "{ctx}: {} vs {}", a.mean, b.mean);
        assert!((a.std - b.std).abs() < 1e-6, "{ctx}: {} vs {}", a.std, b.std);
    }

    #[test]
    fn coordinator_is_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>() {}
        assert_send_sync::<Coordinator>();
    }

    #[test]
    fn analyze_batch_matches_individual_queries() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        // Overlapping, adjacent, contained and disjoint queries together.
        let qs = vec![
            q_hours(0, 4_000),
            q_hours(2_000, 9_000),
            q_hours(3_000, 3_500),
            q_hours(9_001, 12_000),
            q_hours(20_000, 22_000),
        ];
        let batch = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        assert_eq!(batch.len(), qs.len());
        for (i, q) in qs.iter().enumerate() {
            let single = c.analyze_period_oseba(&ds, index.as_ref(), *q, 0).unwrap();
            assert_stats_close(&batch[i], &single, &format!("query {i}"));
        }
    }

    #[test]
    fn overlapping_batch_targets_each_partition_once() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        // Six mutually-overlapping queries whose union is hours [0, 7500].
        let qs: Vec<RangeQuery> =
            (0..6).map(|i| q_hours(i * 500, 5_000 + i * 500)).collect();
        let union = q_hours(0, 7_500);
        let expect = index.lookup(union).len();
        assert!(expect > 1, "several partitions intersect");

        let before = c.context().counters();
        let (stats, report) =
            c.analyze_batch_with_report(&ds, index.as_ref(), &qs, 0).unwrap();
        let after = c.context().counters();

        // Each intersecting partition is targeted exactly once for the
        // whole batch — not once per query.
        assert_eq!(after.partitions_targeted - before.partitions_targeted, expect);
        assert_eq!(after.partitions_scanned, before.partitions_scanned, "no scans");
        assert_eq!(report.merged_ranges, 1);
        assert_eq!(report.queries, 6);
        assert_eq!(report.partitions_touched, expect);
        assert_eq!(stats.len(), 6);
    }

    #[test]
    fn analyze_batch_empty_and_miss_cases() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(5_000), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        // Empty batch: trivially fine.
        let (stats, report) =
            c.analyze_batch_with_report(&ds, index.as_ref(), &[], 0).unwrap();
        assert!(stats.is_empty());
        assert_eq!(report.merged_ranges, 0);
        // Inverted range: rejected up front.
        let bad = RangeQuery { lo: 10, hi: 5 };
        assert!(c.analyze_batch(&ds, index.as_ref(), &[bad], 0).is_err());
        // A query that misses the dataset errors, naming the query.
        let miss = RangeQuery { lo: i64::MAX - 5, hi: i64::MAX };
        let err = c
            .analyze_batch(&ds, index.as_ref(), &[q_hours(0, 100), miss], 0)
            .unwrap_err();
        assert!(err.to_string().contains("query 1"), "got: {err}");
    }

    #[test]
    fn analyze_batch_concurrent_callers_agree() {
        let c = coord(4);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let qs = vec![q_hours(0, 5_000), q_hours(3_000, 9_000), q_hours(15_000, 18_000)];
        let expected = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (c, ds, index, qs, expected) = (&c, &ds, &*index, &qs, &expected);
                s.spawn(move || {
                    for _ in 0..3 {
                        let got = c.analyze_batch(ds, index, qs, 0).unwrap();
                        for (g, e) in got.iter().zip(expected) {
                            assert_stats_close(g, e, "concurrent");
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn tiered_analysis_matches_resident_and_counts_faults() {
        let dir = crate::testing::temp_dir("coord-tiered");
        // Resident reference run.
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(30_000), 15).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let qs = vec![q_hours(0, 3_000), q_hours(2_000, 5_000)];
        let want = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();

        // Same workload, tiered, with a budget of ~3 of 15 partitions.
        let batch = ClimateGen::default().generate(30_000);
        let one = crate::storage::partition_batch_uniform(&batch, 2_000).unwrap()[0].bytes();
        let cfg = AppConfig {
            ctx: ContextConfig { num_workers: 4, memory_budget: Some(3 * one + one / 2) },
            cluster_workers: 3,
            ..Default::default()
        };
        let ct = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let tds = ct.load_tiered(batch, 15, &dir).unwrap();
        assert!(tds.is_tiered());
        let tindex = ct.build_index(&tds, IndexKind::Cias).unwrap();
        let (got, report) =
            ct.analyze_batch_with_report(&tds, tindex.as_ref(), &qs, 0).unwrap();
        for (g, e) in got.iter().zip(&want) {
            assert_stats_close(g, e, "tiered batch");
        }
        assert!(report.faults > 0, "cold partitions must fault in");
        assert!(report.segment_bytes_read > 0);

        // Single-query Oseba path works tiered too.
        let single = ct
            .analyze_period_oseba(&tds, tindex.as_ref(), q_hours(0, 3_000), 0)
            .unwrap();
        assert_stats_close(&single, &want[0], "tiered single");
        ct.context().unpersist(&tds);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn live_analysis_matches_batch_loaded() {
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();

        // Same data streamed into a live dataset with the same layout.
        let live = c
            .create_live(
                Schema::climate(),
                LiveConfig { rows_per_partition: 2_000, max_asl: 8 },
            )
            .unwrap();
        for chunk in crate::ingest::chunk_batch(&ClimateGen::default().generate(20_000), 777)
        {
            live.append(chunk).unwrap();
        }
        live.flush().unwrap();

        let q = q_hours(1_000, 15_000);
        let want = c.analyze_period_oseba(&ds, index.as_ref(), q, 0).unwrap();
        let (got, epoch) = c.analyze_live(&live, q, 0).unwrap();
        assert!(epoch > 0);
        assert_stats_close(&got, &want, "live vs loaded");

        let qs = vec![q_hours(0, 4_000), q_hours(3_000, 9_000)];
        let want: Vec<PeriodStats> = qs
            .iter()
            .map(|q| c.analyze_period_oseba(&ds, index.as_ref(), *q, 0).unwrap())
            .collect();
        let (got, report, batch_epoch) = c.analyze_live_batch(&live, &qs, 0).unwrap();
        assert_eq!(report.queries, 2);
        assert_eq!(batch_epoch, epoch, "no appends between the two calls");
        for (g, w) in got.iter().zip(&want) {
            assert_stats_close(g, w, "live batch");
        }
        live.close();
    }

    #[test]
    fn live_analysis_on_empty_dataset_errors() {
        let c = coord(2);
        let live = c.create_live(Schema::climate(), LiveConfig::default()).unwrap();
        assert!(c.analyze_live(&live, q_hours(0, 10), 0).is_err());
        assert!(c.analyze_live_batch(&live, &[q_hours(0, 10)], 0).is_err());
        live.close();
    }

    #[test]
    fn predicate_stats_match_scan_filter_oracle() {
        use crate::analysis::Analyzer;
        use crate::index::{ColumnPredicate, PredOp};
        let c = coord(3);
        let ds = c.load(ClimateGen::default().generate(20_000), 10).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let q = q_hours(2_000, 12_000);
        let query = Query::stats(q, 0)
            .filtered(vec![ColumnPredicate { column: 0, op: PredOp::Gt, value: 15.0 }]);
        let (out, explain) = c.execute_plan(&ds, index.as_ref(), &query).unwrap();
        let got = out.stats().unwrap();
        assert!(explain.targeted > 0);

        // Scan-filter oracle through the fully general engine filter.
        let filtered = c
            .context()
            .filter(&ds, "oracle", move |k, row| {
                (q.lo..=q.hi).contains(&k) && row[0] > 15.0
            })
            .unwrap();
        assert_eq!(got.count as usize, filtered.total_rows());
        let want = c
            .analyzer()
            .period_stats(&Analyzer::full_views(&filtered), 0)
            .unwrap();
        assert_eq!(got.count, want.count);
        assert_eq!(got.max, want.max);
        assert_eq!(got.min, want.min);
        assert!((got.mean - want.mean).abs() < 1e-3);
        assert!((got.std - want.std).abs() < 1e-2);
        c.context().unpersist(&filtered);
    }

    #[test]
    fn trend_and_distance_ops_execute_through_plan() {
        let c = coord(2);
        let ds = c.load(ClimateGen::default().generate(10_000), 5).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();

        let q = q_hours(0, 3_999);
        let query = Query {
            ranges: vec![q],
            predicates: Vec::new(),
            op: QueryOp::Trend { column: 0, window: 16 },
        };
        let (out, _) = c.execute_plan(&ds, index.as_ref(), &query).unwrap();
        let QueryOutput::Trend(got) = out else { panic!("trend output") };
        let pins = c.context().select_slices(&ds, &index.lookup(q), q).unwrap();
        let want = c.analyzer().ma_stats(&pins.views(), 0, 16).unwrap();
        assert_eq!(got, want);

        // Distance of a selection against itself is zero.
        let query = Query {
            ranges: vec![q_hours(0, 999)],
            predicates: Vec::new(),
            op: QueryOp::Distance { column: 0, baseline: q_hours(0, 999) },
        };
        let (out, explain) = c.execute_plan(&ds, index.as_ref(), &query).unwrap();
        let QueryOutput::Distance(d) = out else { panic!("distance output") };
        assert_eq!(d.count, 1000);
        assert_eq!(d.l1, 0.0);
        assert_eq!(d.nans, 0);
        assert!(explain.merged_ranges >= 2, "primary + baseline");
    }

    #[test]
    fn distance_predicates_drop_pairs_positionally() {
        use crate::index::{ColumnPredicate, PredOp};
        use crate::storage::BatchBuilder;
        // Regression: predicates on a distance query used to filter each
        // side independently, silently shifting the pairing when the two
        // sides dropped different rows. Pairs must be dropped positionally.
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..100i64 {
            let price = if i == 20 { f32::NAN } else { i as f32 };
            let volume = if i == 10 || i == 75 { 0.0 } else { 1.0 };
            b.push(i, &[price, volume]);
        }
        let c = coord(2);
        let ds = c.load(b.finish().unwrap(), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let query = Query {
            ranges: vec![RangeQuery { lo: 0, hi: 49 }],
            predicates: vec![ColumnPredicate { column: 1, op: PredOp::Ge, value: 1.0 }],
            op: QueryOp::Distance { column: 0, baseline: RangeQuery { lo: 50, hi: 99 } },
        };
        let (out, _) = c.execute_plan(&ds, index.as_ref(), &query).unwrap();
        let QueryOutput::Distance(d) = out else { panic!("distance output") };
        // 50 positional pairs, each |a - b| = 50. Pair 10 fails the
        // predicate on the a side, pair 25 on the b side (row 75); pair
        // 20 is a NaN pair counted out by the kernel.
        assert_eq!(d.count, 47);
        assert_eq!(d.nans, 1);
        assert_eq!(d.linf, 50.0);
        assert_eq!(d.l1, 47.0 * 50.0);
        assert_eq!(d.mad, 50.0);
    }

    #[test]
    fn batch_with_predicates_zone_prunes_cold_partitions() {
        use crate::index::{ColumnPredicate, PredOp};
        use crate::storage::BatchBuilder;
        // Trending price column: each of the 4 partitions has a disjoint
        // value domain, so a selective predicate admits exactly one.
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..8_000 {
            b.push(i as i64 * 10, &[i as f32, 7.0]);
        }
        let c = coord(3);
        let ds = c.load(b.finish().unwrap(), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let preds = vec![ColumnPredicate { column: 0, op: PredOp::Ge, value: 6_000.0 }];
        let qs = vec![RangeQuery { lo: 0, hi: i64::MAX }];

        let before = c.context().counters();
        let (stats, report) =
            c.execute_batch(&ds, index.as_ref(), &qs, &preds, 0).unwrap();
        let after = c.context().counters();
        assert_eq!(report.zone_pruned, 3, "three partitions cannot match");
        assert_eq!(report.partitions_touched, 1);
        assert_eq!(after.partitions_targeted - before.partitions_targeted, 1);
        assert_eq!(stats[0].count, 2_000);
        assert_eq!(stats[0].min, 6_000.0);
        assert_eq!(stats[0].max, 7_999.0);

        // Identical to the same query executed without zone pruning.
        let query = Query::stats(qs[0], 0).filtered(preds.clone());
        let unpruned = plan_query(&ds, index.as_ref(), &query, false).unwrap();
        assert_eq!(unpruned.explain.zone_pruned, 0);
        let QueryOutput::Stats(oracle) =
            c.execute_physical(&ds, &unpruned, &query).unwrap()
        else {
            panic!("stats output")
        };
        assert_eq!(stats[0], oracle, "pruning must not change results");
    }

    #[test]
    fn batch_with_equality_predicate_filter_prunes_what_zones_cannot() {
        use crate::index::{ColumnPredicate, PredOp};
        use crate::storage::BatchBuilder;
        // price walks the multiples of 37 modulo 10000 (a cycle longer
        // than any partition): every partition's zone map spans nearly
        // the whole domain, so only the membership filters can rule a
        // probe value out. 5000.0 occurs exactly once, in partition 2.
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..8_000u64 {
            b.push(i as i64 * 10, &[(i * 37 % 10_000) as f32, 7.0]);
        }
        let c = coord(3);
        let ds = c.load(b.finish().unwrap(), 4).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let preds = vec![ColumnPredicate { column: 0, op: PredOp::Eq, value: 5_000.0 }];
        let qs = vec![RangeQuery { lo: 0, hi: i64::MAX }];

        let (stats, report) =
            c.execute_batch(&ds, index.as_ref(), &qs, &preds, 0).unwrap();
        assert_eq!(report.zone_pruned, 0, "zones span the probe everywhere");
        // A false positive may keep an extra partition but can never drop
        // the one that truly holds the probe.
        assert!(report.filter_pruned >= 2, "filters must prune");
        assert_eq!(report.partitions_touched, 4 - report.filter_pruned);
        assert_eq!(stats[0].count, 1);
        assert_eq!(stats[0].min, 5_000.0);
        assert_eq!(stats[0].max, 5_000.0);

        // Identical to the same query executed without any pruning.
        let query = Query::stats(qs[0], 0).filtered(preds.clone());
        let unpruned = plan_query(&ds, index.as_ref(), &query, false).unwrap();
        assert_eq!(unpruned.explain.filter_pruned, 0);
        let QueryOutput::Stats(oracle) =
            c.execute_physical(&ds, &unpruned, &query).unwrap()
        else {
            panic!("stats output")
        };
        assert_eq!(stats[0], oracle, "pruning must not change results");
    }

    #[test]
    fn covered_query_answers_from_sketches_without_touching_cold_data() {
        let dir = crate::testing::temp_dir("coord-agg");
        let batch = ClimateGen::default().generate(30_000);
        let one = crate::storage::partition_batch_uniform(&batch, 2_000).unwrap()[0].bytes();
        let cfg = AppConfig {
            ctx: ContextConfig { num_workers: 4, memory_budget: Some(3 * one + one / 2) },
            cluster_workers: 3,
            ..Default::default()
        };
        let c = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let ds = c.load_tiered(batch, 15, &dir).unwrap();
        let store = Arc::clone(ds.store().unwrap());
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        store.shrink(usize::MAX).unwrap(); // everything Cold

        // Full-span query: every partition is covered — answered entirely
        // from sketches, with zero faults and zero segment bytes read.
        let q = RangeQuery { lo: 0, hi: i64::MAX };
        let query = Query::stats(q, 0);
        let plan = plan_query(&ds, index.as_ref(), &query, true).unwrap();
        assert_eq!(plan.explain.agg_answered, 15);
        assert_eq!(plan.explain.rows_avoided, 30_000);
        assert_eq!(plan.explain.estimated_rows, 0);
        let counters_before = c.context().counters();
        let before = store.counters();
        let QueryOutput::Stats(got) = c.execute_physical(&ds, &plan, &query).unwrap()
        else {
            panic!("stats output")
        };
        let delta = store.counters().since(&before);
        assert_eq!(delta.faults, 0, "covered partitions must not fault in");
        assert_eq!(delta.segment_bytes_read, 0);
        let cd = c.context().counters();
        assert_eq!(
            cd.partitions_agg_answered - counters_before.partitions_agg_answered,
            15
        );
        assert_eq!(cd.partitions_targeted - counters_before.partitions_targeted, 15);

        // The oracle arm (pushdown off) scans everything — and produces a
        // bit-identical result, because a sketch partial IS the partial
        // the scan computes, merged in the same structure.
        store.shrink(usize::MAX).unwrap();
        let opts = PlanOptions {
            zone_pruning: true,
            filter_pruning: true,
            agg_pushdown: false,
            block_pruning: false,
        };
        let oracle_plan = plan_query_opts(&ds, index.as_ref(), &query, opts).unwrap();
        assert_eq!(oracle_plan.explain.agg_answered, 0);
        let before = store.counters();
        let QueryOutput::Stats(want) =
            c.execute_physical(&ds, &oracle_plan, &query).unwrap()
        else {
            panic!("stats output")
        };
        assert!(store.counters().since(&before).faults > 0, "oracle arm reads");
        assert_eq!(got, want, "sketch-answered must be bit-identical to the scan");

        // A partially-covering range scans only its ≤2 edges.
        let h = 3600i64;
        let q = RangeQuery { lo: 500 * h, hi: 25_500 * h }; // edges in parts 0 and 12
        let query = Query::stats(q, 0);
        let plan = plan_query(&ds, index.as_ref(), &query, true).unwrap();
        assert_eq!(plan.explain.targeted, 13);
        assert_eq!(plan.explain.agg_answered, 11, "interior partitions covered");
        store.shrink(usize::MAX).unwrap();
        let before = store.counters();
        c.execute_physical(&ds, &plan, &query).unwrap();
        assert_eq!(store.counters().since(&before).faults, 2, "edge partitions only");

        c.context().unpersist(&ds);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn trend_line_merges_sketch_partials_with_scanned_edges() {
        use crate::util::stats::TrendPartial;
        use crate::storage::BatchBuilder;
        // price = 2·key + 5 exactly (keys step 3): slope/intercept known.
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..6_000i64 {
            let k = i * 3;
            b.push(k, &[(2 * k + 5) as f32, (i % 100) as f32]);
        }
        let c = coord(3);
        let ds = c.load(b.finish().unwrap(), 6).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();

        let q = RangeQuery { lo: 150, hi: 14_000 };
        let (line, explain) = c.analyze_trend_line(&ds, index.as_ref(), q, 0).unwrap();
        assert!(explain.agg_answered >= 3, "interior partitions ride sketches");
        assert!((line.slope - 2.0).abs() < 1e-6, "slope {}", line.slope);
        assert!((line.intercept - 5.0).abs() < 1e-3, "intercept {}", line.intercept);
        assert_eq!(line.nans, 0);

        // Oracle: one merged partial per partition slice, scanned raw —
        // the same association the covered/edge path uses.
        let slices = index.lookup(q);
        let mut oracle = TrendPartial::EMPTY;
        for (part, s) in c.context().resolve_slices(&ds, &slices, q).unwrap() {
            oracle = oracle.merge(TrendPartial::scan(
                &part.keys[s.row_start..s.row_end],
                &part.columns[0][s.row_start..s.row_end],
            ));
        }
        assert_eq!(line.count, oracle.n as u64);
        assert_eq!(Some(line.slope), oracle.slope(), "bit-identical fit");
        assert_eq!(Some(line.intercept), oracle.intercept());

        // Degenerate selections are clear errors.
        let one_key = RangeQuery { lo: 0, hi: 0 };
        assert!(c.analyze_trend_line(&ds, index.as_ref(), one_key, 0).is_err());
        let miss = RangeQuery { lo: i64::MAX - 5, hi: i64::MAX };
        assert!(c.analyze_trend_line(&ds, index.as_ref(), miss, 0).is_err());
    }

    #[test]
    fn live_query_through_plan_layer() {
        use crate::index::{ColumnPredicate, PredOp};
        let c = coord(2);
        let live = c
            .create_live(
                Schema::climate(),
                LiveConfig { rows_per_partition: 1_000, max_asl: 8 },
            )
            .unwrap();
        for chunk in crate::ingest::chunk_batch(&ClimateGen::default().generate(8_000), 777) {
            live.append(chunk).unwrap();
        }
        live.flush().unwrap();

        let q = q_hours(500, 6_500);
        let (want, epoch) = c.analyze_live(&live, q, 0).unwrap();
        let (out, explain, e2) =
            c.analyze_live_query(&live, &Query::stats(q, 0)).unwrap();
        assert_eq!(e2, epoch);
        assert_eq!(out.stats().unwrap(), want);
        assert!(explain.targeted > 0);
        assert!(explain.key_pruned > 0, "selective range skips partitions");

        // Predicated live query agrees with a snapshot-side oracle.
        let preds = vec![ColumnPredicate { column: 1, op: PredOp::Le, value: 60.0 }];
        let (out, _, _) = c
            .analyze_live_query(&live, &Query::stats(q, 1).filtered(preds))
            .unwrap();
        let got = out.stats().unwrap();
        let snap = c.snapshot_live(&live);
        let mut oracle = crate::util::stats::Moments::EMPTY;
        for p in snap.dataset().partitions() {
            for r in 0..p.rows {
                if (q.lo..=q.hi).contains(&p.keys[r]) && p.columns[1][r] <= 60.0 {
                    oracle.absorb(p.columns[1][r]);
                }
            }
        }
        assert_eq!(got.count, oracle.count as u64);
        assert_eq!(got.max, oracle.max);
        assert_eq!(got.min, oracle.min);
        live.close();
    }

    #[test]
    fn analyze_batch_survives_worker_failure() {
        let c = coord(4);
        let ds = c.load(ClimateGen::default().generate(20_000), 12).unwrap();
        let index = c.build_index(&ds, IndexKind::Cias).unwrap();
        let qs = vec![q_hours(0, 8_000), q_hours(6_000, 15_000)];
        let before = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        c.cluster().kill_worker(1).unwrap();
        let after = c.analyze_batch(&ds, index.as_ref(), &qs, 0).unwrap();
        for (a, b) in before.iter().zip(&after) {
            assert_stats_close(a, b, "failover");
        }
    }
}
