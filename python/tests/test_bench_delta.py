"""bench_delta gate tests: timing leaves (secs mentions, p50/p99
quantiles, quantile-suffixed and min-of-iterations microbench leaves)
regress under --fail-above, while count-style leaves never fail the
run."""

import importlib.util
import json
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[2]

spec = importlib.util.spec_from_file_location(
    "bench_delta", ROOT / "tools" / "bench_delta.py"
)
bench_delta = importlib.util.module_from_spec(spec)
spec.loader.exec_module(bench_delta)


def test_timing_leaf_detection():
    assert bench_delta.is_timing_leaf("classes[cdr].p50")
    assert bench_delta.is_timing_leaf("classes[climate].p99")
    assert bench_delta.is_timing_leaf("ops.op_stats.p999")
    assert bench_delta.is_timing_leaf("arms[block-sketch].secs_mean")
    assert bench_delta.is_timing_leaf("cias_lookup_p50_m15")
    assert bench_delta.is_timing_leaf("segment_stats_lanes_p50")
    assert bench_delta.is_timing_leaf("masked_fold_lanes_min")
    assert not bench_delta.is_timing_leaf("classes[cdr].ops")
    assert not bench_delta.is_timing_leaf("classes[cdr].p5000")
    assert not bench_delta.is_timing_leaf("masked_fold_speedup")
    assert not bench_delta.is_timing_leaf("bits_per_key")
    assert not bench_delta.is_timing_leaf("measured_fpr")


def write_doc(root, classes):
    root.mkdir(parents=True, exist_ok=True)
    doc = {"bench": "traffic", "classes": classes}
    (root / "BENCH_traffic.json").write_text(json.dumps(doc))


def run_main(monkeypatch, base, cur, fail_above):
    argv = ["bench_delta.py", "--baseline", str(base), "--current", str(cur),
            "--fail-above", str(fail_above)]
    monkeypatch.setattr(sys, "argv", argv)
    return bench_delta.main()


def test_p99_regression_fails_the_gate(monkeypatch, tmp_path, capsys):
    write_doc(tmp_path / "base", [{"name": "cdr", "ops": 200, "p99": 0.002}])
    write_doc(tmp_path / "cur", [{"name": "cdr", "ops": 200, "p99": 0.004}])
    assert run_main(monkeypatch, tmp_path / "base", tmp_path / "cur", 10) == 1
    assert "regression" in capsys.readouterr().out


def test_count_changes_never_fail(monkeypatch, tmp_path):
    write_doc(tmp_path / "base", [{"name": "cdr", "ops": 200, "p99": 0.002}])
    write_doc(tmp_path / "cur", [{"name": "cdr", "ops": 120, "p99": 0.002}])
    assert run_main(monkeypatch, tmp_path / "base", tmp_path / "cur", 10) == 0


def test_improvement_passes(monkeypatch, tmp_path):
    write_doc(tmp_path / "base", [{"name": "cdr", "p99": 0.004}])
    write_doc(tmp_path / "cur", [{"name": "cdr", "p99": 0.002}])
    assert run_main(monkeypatch, tmp_path / "base", tmp_path / "cur", 10) == 0
