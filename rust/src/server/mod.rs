//! Interactive query server: a line-delimited JSON protocol over TCP
//! (std::net + the crate's thread pool), fronting either a **fixed**
//! (loaded/opened) dataset or a **live** dataset that ingests while it
//! serves. This is the "interactive analysis" deployment shape the paper
//! motivates (§I: selective bulk analysis "usually involves interactive
//! analysis"), extended to the continuously-arriving data that motivates
//! it in the first place.
//!
//! One JSON object per line; see `docs/PROTOCOL.md` for the complete
//! reference (every op, field, error shape, and a worked `nc` session):
//!
//! ```text
//! → {"op":"stats","lo":3600,"hi":7200,"column":"temperature","method":"oseba"}
//! ← {"ok":true,"count":2,"max":21.4,"min":20.9,"mean":21.1,"std":0.2,"nans":0,"secs":0.0001}
//! → {"op":"explain","lo":3600,"hi":7200,"column":"temperature","where":"temperature > 30"}
//! ← {"ok":true,"plan":{"partitions":15,"considered":1,"key_pruned":14,"zone_pruned":1,...}}
//! → {"op":"append","keys":[3600,7200],"columns":[[21.4,20.9],[80,81],[3,4],[120,121]]}
//! ← {"ok":true,"epoch":0,"rows":2,"sealed_partitions":0,"sealed_rows":0,"unsealed_rows":2}
//! → {"op":"info"}
//! ← {"ok":true,"rows":100000,"partitions":15,"memory_bytes":...}
//! ```
//!
//! Live-mode consistency: every `stats` request pins one epoch snapshot
//! before planning, so a query observes either all of a sealed partition
//! or none of it — never a torn intermediate — and reports the epoch it
//! saw.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::{
    parse_predicates, plan_query, Coordinator, IndexKind, Method, Query,
};
use crate::engine::{Dataset, LiveDataset};
use crate::error::{OsebaError, Result};
use crate::index::{ColumnPredicate, ContentIndex, RangeQuery};
use crate::ingest::Chunk;
use crate::metrics::{PlanPhase, ServerOp, SlowEntry, Span, Timer};
use crate::util::json::Json;

/// What a server fronts.
pub enum ServerSource {
    /// An immutable (loaded or opened) dataset with a prebuilt index.
    Fixed {
        /// The dataset every query runs against.
        ds: Arc<Dataset>,
        /// The super index lookups go through.
        index: Arc<dyn ContentIndex>,
    },
    /// A mutable live dataset; every request pins its own epoch snapshot,
    /// and `append` extends the next epoch.
    Live(Arc<LiveDataset>),
}

/// Server state shared across connections.
pub struct QueryServer {
    coord: Arc<Coordinator>,
    source: Arc<ServerSource>,
    shutdown: Arc<AtomicBool>,
}

impl QueryServer {
    /// Build over an already-loaded dataset (resident or tiered; a tiered
    /// dataset's index is built from store metadata without faulting
    /// anything in).
    pub fn new(coord: Arc<Coordinator>, ds: Dataset, index_kind: IndexKind) -> Result<QueryServer> {
        let index: Arc<dyn ContentIndex> = match (ds.store(), index_kind) {
            (Some(store), IndexKind::Cias) => {
                Arc::new(crate::index::Cias::from_meta(store.metas())?)
            }
            (Some(store), IndexKind::Table) => {
                Arc::new(crate::index::TableIndex::from_meta(store.metas())?)
            }
            (None, IndexKind::Cias) => Arc::new(crate::index::Cias::build(ds.partitions())?),
            (None, IndexKind::Table) => {
                Arc::new(crate::index::TableIndex::build(ds.partitions())?)
            }
        };
        Ok(QueryServer {
            coord,
            source: Arc::new(ServerSource::Fixed { ds: Arc::new(ds), index }),
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Build over a live dataset: clients may `append` chunks while other
    /// clients query; the live index is maintained incrementally, so no
    /// per-request index build happens.
    pub fn live(coord: Arc<Coordinator>, live: Arc<LiveDataset>) -> QueryServer {
        QueryServer {
            coord,
            source: Arc::new(ServerSource::Live(live)),
            shutdown: Arc::new(AtomicBool::new(false)),
        }
    }

    /// Bind and serve until a `{"op":"shutdown"}` request arrives. Returns
    /// the bound address via `on_bound` (for tests binding port 0).
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        on_bound(listener.local_addr()?);
        listener.set_nonblocking(true)?;
        while !self.shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    // One thread per connection, connections are few and
                    // long-lived (interactive sessions / feed writers).
                    let coord = Arc::clone(&self.coord);
                    let source = Arc::clone(&self.source);
                    let shutdown = Arc::clone(&self.shutdown);
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, &coord, &source, &shutdown);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Request shutdown (used by tests and signal handling).
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }
}

fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    source: &ServerSource,
    shutdown: &AtomicBool,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = respond(&line, coord, source, shutdown);
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// One request line → one response object, never a connection teardown:
/// typed errors become `{"ok":false,"error":...}`, and a handler that
/// *panics* is caught right here at the session boundary — the panic is
/// reported as a protocol-level error with `"panic":true`, the engine's
/// `sessions_failed` counter is bumped, and the connection keeps serving.
pub fn respond(
    line: &str,
    coord: &Coordinator,
    source: &ServerSource,
    shutdown: &AtomicBool,
) -> Json {
    respond_caught(coord, std::panic::AssertUnwindSafe(|| {
        handle_request(line, coord, source, shutdown)
    }))
}

/// The catch-unwind half of [`respond`], generic over the handler so the
/// panic path itself is unit-testable without a panicking op in the
/// protocol.
fn respond_caught(
    coord: &Coordinator,
    handler: impl FnOnce() -> Result<Json> + std::panic::UnwindSafe,
) -> Json {
    match std::panic::catch_unwind(handler) {
        Ok(Ok(j)) => j,
        Ok(Err(e)) => Json::obj(vec![
            ("ok", Json::Bool(false)),
            ("error", Json::str(e.to_string())),
        ]),
        Err(payload) => {
            coord.context().record_session_failure();
            let msg = payload
                .downcast_ref::<&'static str>()
                .copied()
                .or_else(|| payload.downcast_ref::<String>().map(String::as_str))
                .unwrap_or("non-string panic payload");
            Json::obj(vec![
                ("ok", Json::Bool(false)),
                (
                    "error",
                    Json::str(format!("internal error: request handler panicked: {msg}")),
                ),
                ("panic", Json::Bool(true)),
            ])
        }
    }
}

/// Process one request line (exposed for unit tests — no socket needed).
pub fn handle_request(
    line: &str,
    coord: &Coordinator,
    source: &ServerSource,
    shutdown: &AtomicBool,
) -> Result<Json> {
    let req = Json::parse(line)?;
    let op = req
        .require("op")?
        .as_str()
        .ok_or_else(|| OsebaError::Json("op must be a string".into()))?;
    let timer = Timer::start();
    let result = match op {
        "info" => handle_info(coord, source),
        "stats" => handle_stats(&req, coord, source),
        "explain" => handle_explain(&req, coord, source),
        "append" => handle_append(&req, source),
        "snapshot" => handle_snapshot(source),
        "metrics" => handle_metrics(&req, coord, source),
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("bye", Json::Bool(true))]))
        }
        other => Err(OsebaError::Json(format!("unknown op '{other}'"))),
    };
    // Protocol-level wall time per op — errors included, so the latency
    // histograms see every answered request.
    if let Some(server_op) = ServerOp::from_op_str(op) {
        coord.context().metrics().record_op(server_op, timer.elapsed());
    }
    result
}

/// Dataset-shape fields shared by fixed and live `info`.
fn info_fields(ds: &Dataset, coord: &Coordinator, fields: &mut Vec<(&'static str, Json)>) {
    fields.push(("rows", Json::num(ds.total_rows() as f64)));
    fields.push(("partitions", Json::num(ds.num_partitions() as f64)));
    fields.push(("memory_bytes", Json::num(coord.context().memory_used() as f64)));
    // Cumulative sketch answers served by this engine (zero-data-touch
    // covered partitions) — the aggregate-pushdown win, surfaced live.
    fields.push((
        "agg_answered",
        Json::num(coord.context().counters().partitions_agg_answered as f64),
    ));
    // The full engine-counter snapshot, nested under one key with the
    // exact `EngineCounters` field names (oseba-lint's counters-surfaced
    // rule checks every field appears here).
    let ec = coord.context().counters();
    fields.push((
        "counters",
        Json::obj(vec![
            ("partitions_scanned", Json::num(ec.partitions_scanned as f64)),
            ("rows_scanned", Json::num(ec.rows_scanned as f64)),
            ("bytes_materialized", Json::num(ec.bytes_materialized as f64)),
            ("partitions_targeted", Json::num(ec.partitions_targeted as f64)),
            (
                "partitions_agg_answered",
                Json::num(ec.partitions_agg_answered as f64),
            ),
            ("blocks_covered", Json::num(ec.blocks_covered as f64)),
            ("blocks_pruned", Json::num(ec.blocks_pruned as f64)),
            ("sessions_failed", Json::num(ec.sessions_failed as f64)),
            ("degraded_answers", Json::num(ec.degraded_answers as f64)),
        ]),
    ));
    // Resident metadata cost of the per-partition membership filters
    // (0 for a store opened from a pre-v4 manifest — no filters there).
    fields.push(("filter_bytes", Json::num(ds.filter_bytes() as f64)));
    fields.push(("key_min", Json::num(ds.key_min().unwrap_or(0) as f64)));
    fields.push(("key_max", Json::num(ds.key_max().unwrap_or(0) as f64)));
    fields.push(("tiered", Json::Bool(ds.is_tiered())));
    // How many `metrics` requests this server has answered — non-zero
    // advertises the op, letting older clients discover it from `info`
    // without changing any existing field.
    fields.push((
        "metrics_ops",
        Json::num(coord.context().metrics().op(ServerOp::Metrics).count() as f64),
    ));
    if let Some(store) = ds.store() {
        let c = store.counters();
        fields.push(("resident_bytes", Json::num(store.resident_bytes() as f64)));
        fields.push(("total_bytes", Json::num(store.total_bytes() as f64)));
        fields.push(("faults", Json::num(c.faults as f64)));
        fields.push(("evictions", Json::num(c.evictions as f64)));
        fields.push(("segment_bytes_read", Json::num(c.segment_bytes_read as f64)));
    }
}

fn handle_info(coord: &Coordinator, source: &ServerSource) -> Result<Json> {
    let mut fields = vec![("ok", Json::Bool(true))];
    match source {
        ServerSource::Fixed { ds, index } => {
            fields.push(("live", Json::Bool(false)));
            info_fields(ds, coord, &mut fields);
            fields.push(("index", Json::str(index.name())));
            fields.push(("index_bytes", Json::num(index.memory_bytes() as f64)));
        }
        ServerSource::Live(live) => {
            let snap = coord.snapshot_live(live);
            let c = live.counters();
            fields.push(("live", Json::Bool(true)));
            info_fields(snap.dataset(), coord, &mut fields);
            fields.push(("index", Json::str("cias")));
            fields.push((
                "index_bytes",
                Json::num(snap.index().map_or(0, |i| i.memory_bytes()) as f64),
            ));
            // Epoch-scoped fields come from the snapshot so rows /
            // partitions / epoch / asl_len always describe one consistent
            // epoch even while appends race; the maintenance counters are
            // instantaneous by nature.
            fields.push(("epoch", Json::num(snap.epoch() as f64)));
            fields.push((
                "asl_len",
                Json::num(snap.index().map_or(0, |i| i.asl_len()) as f64),
            ));
            fields.push(("unsealed_rows", Json::num(c.unsealed_rows as f64)));
            fields.push(("appended_chunks", Json::num(c.appended_chunks as f64)));
            fields.push((
                "out_of_order_chunks",
                Json::num(c.out_of_order_chunks as f64),
            ));
            fields.push(("index_appends", Json::num(c.index_appends as f64)));
            fields.push(("asl_absorbed", Json::num(c.asl_absorbed as f64)));
            fields.push(("rebuilds", Json::num(c.rebuilds as f64)));
        }
    }
    Ok(Json::obj(fields))
}

/// Parse the optional `where` field into predicates against `ds`' schema.
fn parse_where(req: &Json, ds: &Dataset) -> Result<Vec<ColumnPredicate>> {
    match req.get("where") {
        None | Some(Json::Null) => Ok(Vec::new()),
        Some(w) => {
            let spec = w
                .as_str()
                .ok_or_else(|| OsebaError::Json("where must be a string".into()))?;
            parse_predicates(spec, ds.schema())
        }
    }
}

/// The query source pinned for one request: a fixed server borrows its
/// dataset/index; a live server pins one epoch snapshot (held here so its
/// partitions stay alive for the whole request). Shared by `stats` and
/// `explain`.
enum SourcePin<'a> {
    Fixed {
        ds: &'a Dataset,
        index: &'a dyn ContentIndex,
    },
    Live(crate::engine::EpochSnapshot),
}

impl<'a> SourcePin<'a> {
    fn pin(coord: &Coordinator, source: &'a ServerSource) -> SourcePin<'a> {
        match source {
            ServerSource::Fixed { ds, index } => {
                SourcePin::Fixed { ds: ds.as_ref(), index: index.as_ref() }
            }
            ServerSource::Live(live) => SourcePin::Live(coord.snapshot_live(live)),
        }
    }

    /// The dataset, index and (live only) pinned epoch to plan against.
    fn resolve(&self) -> Result<(&Dataset, &dyn ContentIndex, Option<u64>)> {
        match self {
            SourcePin::Fixed { ds, index } => Ok((*ds, *index, None)),
            SourcePin::Live(snap) => {
                let index = snap.index().ok_or_else(|| {
                    OsebaError::InvalidRange(
                        "live dataset has no sealed partitions yet".into(),
                    )
                })?;
                Ok((snap.dataset(), index as &dyn ContentIndex, Some(snap.epoch())))
            }
        }
    }
}

/// Parse the selection fields shared by `stats` and `explain`: the
/// inclusive key range and the column name.
fn parse_selection<'r>(req: &'r Json) -> Result<(RangeQuery, &'r str)> {
    let lo = req.require("lo")?.as_i64().ok_or_else(bad_num)?;
    let hi = req.require("hi")?.as_i64().ok_or_else(bad_num)?;
    let q = RangeQuery::new(lo, hi)?;
    let col_name = req
        .require("column")?
        .as_str()
        .ok_or_else(|| OsebaError::Json("column must be a string".into()))?;
    Ok((q, col_name))
}

fn handle_stats(req: &Json, coord: &Coordinator, source: &ServerSource) -> Result<Json> {
    let (q, col_name) = parse_selection(req)?;
    let method: Method = req
        .get("method")
        .and_then(|m| m.as_str())
        .unwrap_or("oseba")
        .parse()?;

    let pin = SourcePin::pin(coord, source);
    let (ds, index, epoch) = pin.resolve()?;
    let column = ds.schema().column_index(col_name)?;
    let predicates = parse_where(req, ds)?;
    let timer = Timer::start();
    let (stats, plan_explain, trace) = match method {
        Method::Oseba => {
            let query = Query::stats(q, column).filtered(predicates);
            let (out, explain, span) = coord.execute_plan_traced(ds, index, &query)?;
            let st = out.stats().ok_or_else(|| {
                OsebaError::Runtime("stats query produced a non-stats output".into())
            })?;
            let trace = span.to_json();
            // Every executed stats query is offered to the slow-query
            // ring; only the worst few survive.
            let m = coord.context().metrics();
            if m.enabled() {
                m.slow_log().offer(SlowEntry {
                    secs: timer.secs(),
                    op: "stats",
                    trace: trace.clone(),
                    explain: explain.to_json(),
                });
            }
            (st, Some(explain), Some(trace))
        }
        Method::Default => {
            if !predicates.is_empty() {
                return Err(OsebaError::Config(
                    "where requires method=oseba (the scan baseline filters keys only)"
                        .into(),
                ));
            }
            let (st, filtered) = coord.analyze_period_default(ds, q, column)?;
            // The server keeps memory bounded: server-side filtered
            // datasets are transient.
            coord.context().unpersist(&filtered);
            (st, None, None)
        }
    };
    let secs = timer.secs();
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("count", Json::num(stats.count as f64)),
        ("max", Json::num(stats.max as f64)),
        ("min", Json::num(stats.min as f64)),
        ("mean", Json::num(stats.mean)),
        ("std", Json::num(stats.std)),
        ("nans", Json::num(stats.nans as f64)),
        ("method", Json::str(method.label())),
        ("secs", Json::num(secs)),
    ];
    if let Some(ex) = plan_explain {
        fields.push(("zone_pruned", Json::num(ex.zone_pruned as f64)));
        fields.push(("filter_pruned", Json::num(ex.filter_pruned as f64)));
        fields.push(("agg_answered", Json::num(ex.agg_answered as f64)));
        fields.push(("rows_avoided", Json::num(ex.rows_avoided as f64)));
        fields.push(("blocks_covered", Json::num(ex.blocks_covered as f64)));
        fields.push(("blocks_pruned", Json::num(ex.blocks_pruned as f64)));
        fields.push(("degraded", Json::num(ex.degraded as f64)));
    }
    if let Some(e) = epoch {
        fields.push(("epoch", Json::num(e as f64)));
    }
    // `"trace":true` attaches the span tree. The scan baseline has no
    // plan phases, so it reports a root-only span.
    if matches!(req.get("trace"), Some(Json::Bool(true))) {
        let span_json =
            trace.unwrap_or_else(|| Span::new("query").with_secs(secs).to_json());
        fields.push(("trace", span_json));
    }
    Ok(Json::obj(fields))
}

/// `explain`: lower a stats query through the plan layer and report the
/// pruning arithmetic **without executing it** — pure metadata, so on a
/// tiered dataset nothing is faulted in.
fn handle_explain(req: &Json, coord: &Coordinator, source: &ServerSource) -> Result<Json> {
    let (q, col_name) = parse_selection(req)?;
    let pin = SourcePin::pin(coord, source);
    let (ds, index, epoch) = pin.resolve()?;
    let column = ds.schema().column_index(col_name)?;
    let predicates = parse_where(req, ds)?;
    let query = Query::stats(q, column).filtered(predicates);
    let plan = plan_query(ds, index, &query, true)?;
    let mut fields = vec![("ok", Json::Bool(true))];
    // The pruning arithmetic nests under its own key so the top level
    // stays uniform with every other response shape.
    fields.push(("plan", plan.explain.to_json()));
    // `"verify": true` runs the plan-invariant checker (DESIGN.md §12) on
    // this lowering — debug builds check every plan already; this exposes
    // the same check to release deployments. A violation fails the
    // request with the `plan invariant violated` message.
    if matches!(req.get("verify"), Some(Json::Bool(true))) {
        plan.verify(ds, &query)?;
        fields.push(("verified", Json::Bool(true)));
    }
    if let Some(e) = epoch {
        fields.push(("epoch", Json::num(e as f64)));
    }
    Ok(Json::obj(fields))
}

/// `metrics`: one snapshot of the unified observability registry — every
/// engine/live/tiered counter, the per-op and per-phase latency
/// histograms (count + p50/p95/p99/p999), and the slow-query log.
/// `{"text":true}` returns the same numbers as a Prometheus-style text
/// exposition instead. Every name registered in `OP_METRICS` /
/// `PHASE_METRICS` is listed literally here — oseba-lint's
/// counters-surfaced rule cross-checks the two, so a histogram cannot be
/// registered without being exposed.
fn handle_metrics(req: &Json, coord: &Coordinator, source: &ServerSource) -> Result<Json> {
    let m = coord.context().metrics();
    let ec = coord.context().counters();
    let counters: Vec<(&'static str, f64)> = vec![
        ("partitions_scanned", ec.partitions_scanned as f64),
        ("rows_scanned", ec.rows_scanned as f64),
        ("bytes_materialized", ec.bytes_materialized as f64),
        ("partitions_targeted", ec.partitions_targeted as f64),
        ("partitions_agg_answered", ec.partitions_agg_answered as f64),
        ("blocks_covered", ec.blocks_covered as f64),
        ("blocks_pruned", ec.blocks_pruned as f64),
        ("sessions_failed", ec.sessions_failed as f64),
        ("degraded_answers", ec.degraded_answers as f64),
    ];
    let mut live_fields: Vec<(&'static str, f64)> = Vec::new();
    let mut store_fields: Vec<(&'static str, f64)> = Vec::new();
    match source {
        ServerSource::Fixed { ds, .. } => {
            if let Some(store) = ds.store() {
                let c = store.counters();
                store_fields.push(("faults", c.faults as f64));
                store_fields.push(("evictions", c.evictions as f64));
                store_fields.push(("segment_bytes_read", c.segment_bytes_read as f64));
                store_fields.push(("segment_bytes_written", c.segment_bytes_written as f64));
                store_fields.push(("io_retries", c.io_retries as f64));
                store_fields.push(("io_retry_successes", c.io_retry_successes as f64));
                store_fields.push(("partitions_quarantined", c.quarantined as f64));
            }
        }
        ServerSource::Live(live) => {
            let c = live.counters();
            live_fields.push(("epoch", c.epoch as f64));
            live_fields.push(("appended_chunks", c.appended_chunks as f64));
            live_fields.push(("out_of_order_chunks", c.out_of_order_chunks as f64));
            live_fields.push(("sealed_partitions", c.sealed_partitions as f64));
            live_fields.push(("sealed_rows", c.sealed_rows as f64));
            live_fields.push(("unsealed_rows", c.unsealed_rows as f64));
            live_fields.push(("index_appends", c.index_appends as f64));
            live_fields.push(("asl_absorbed", c.asl_absorbed as f64));
            live_fields.push(("asl_len", c.asl_len as f64));
            live_fields.push(("rebuilds", c.rebuilds as f64));
        }
    }
    if matches!(req.get("text"), Some(Json::Bool(true))) {
        let mut gauges: Vec<(String, f64)> = Vec::new();
        for (k, v) in &counters {
            gauges.push((format!("engine_{k}"), *v));
        }
        for (k, v) in &live_fields {
            gauges.push((format!("live_{k}"), *v));
        }
        for (k, v) in &store_fields {
            gauges.push((format!("store_{k}"), *v));
        }
        return Ok(Json::obj(vec![
            ("ok", Json::Bool(true)),
            ("text", Json::str(m.prometheus_text(&gauges))),
        ]));
    }
    let to_obj = |fields: &[(&'static str, f64)]| {
        Json::obj(fields.iter().map(|&(k, v)| (k, Json::num(v))).collect())
    };
    let mut fields = vec![
        ("ok", Json::Bool(true)),
        ("enabled", Json::Bool(m.enabled())),
        ("counters", to_obj(&counters)),
    ];
    if !live_fields.is_empty() {
        fields.push(("live", to_obj(&live_fields)));
    }
    if !store_fields.is_empty() {
        fields.push(("tiered", to_obj(&store_fields)));
    }
    fields.push((
        "ops",
        Json::obj(vec![
            ("op_info", m.op(ServerOp::Info).to_json()),
            ("op_stats", m.op(ServerOp::Stats).to_json()),
            ("op_explain", m.op(ServerOp::Explain).to_json()),
            ("op_append", m.op(ServerOp::Append).to_json()),
            ("op_snapshot", m.op(ServerOp::Snapshot).to_json()),
            ("op_metrics", m.op(ServerOp::Metrics).to_json()),
        ]),
    ));
    fields.push((
        "phases",
        Json::obj(vec![
            ("phase_targeting", m.phase(PlanPhase::Targeting).to_json()),
            ("phase_zone_pruning", m.phase(PlanPhase::ZonePruning).to_json()),
            ("phase_filter_pruning", m.phase(PlanPhase::FilterPruning).to_json()),
            ("phase_sketch_classify", m.phase(PlanPhase::SketchClassify).to_json()),
            ("phase_block_classify", m.phase(PlanPhase::BlockClassify).to_json()),
            ("phase_fault_in", m.phase(PlanPhase::FaultIn).to_json()),
            ("phase_scan_merge", m.phase(PlanPhase::ScanMerge).to_json()),
            ("phase_demux", m.phase(PlanPhase::Demux).to_json()),
            ("phase_fault_recovery", m.phase(PlanPhase::FaultRecovery).to_json()),
        ]),
    ));
    fields.push(("slow_queries", m.slow_log().to_json()));
    Ok(Json::obj(fields))
}

fn handle_append(req: &Json, source: &ServerSource) -> Result<Json> {
    let ServerSource::Live(live) = source else {
        return Err(OsebaError::Ingest(
            "append requires a live server (start with `serve --live`)".into(),
        ));
    };
    let keys = req
        .require("keys")?
        .as_arr()
        .ok_or_else(|| OsebaError::Json("keys must be an array".into()))?
        .iter()
        .map(|k| {
            k.as_i64()
                .ok_or_else(|| OsebaError::Json("keys must be integers".into()))
        })
        .collect::<Result<Vec<i64>>>()?;
    let columns = req
        .require("columns")?
        .as_arr()
        .ok_or_else(|| OsebaError::Json("columns must be an array of arrays".into()))?
        .iter()
        .map(|col| {
            col.as_arr()
                .ok_or_else(|| OsebaError::Json("columns must be an array of arrays".into()))?
                .iter()
                .map(|v| {
                    v.as_f64()
                        .map(|x| x as f32)
                        .ok_or_else(|| OsebaError::Json("column values must be numbers".into()))
                })
                .collect::<Result<Vec<f32>>>()
        })
        .collect::<Result<Vec<Vec<f32>>>>()?;
    let rows = keys.len();
    let epoch = live.append(Chunk { keys, columns })?;
    let c = live.counters();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        ("epoch", Json::num(epoch as f64)),
        ("rows", Json::num(rows as f64)),
        ("sealed_partitions", Json::num(c.sealed_partitions as f64)),
        ("sealed_rows", Json::num(c.sealed_rows as f64)),
        ("unsealed_rows", Json::num(c.unsealed_rows as f64)),
    ]))
}

fn handle_snapshot(source: &ServerSource) -> Result<Json> {
    let ServerSource::Live(live) = source else {
        return Err(OsebaError::Ingest(
            "snapshot requires a live server (start with `serve --live`)".into(),
        ));
    };
    let snap = live.snapshot();
    let c = live.counters();
    Ok(Json::obj(vec![
        ("ok", Json::Bool(true)),
        // Epoch-scoped fields all come from the one snapshot (asl_len
        // included); only unsealed_rows / rebuilds are instantaneous.
        ("epoch", Json::num(snap.epoch() as f64)),
        ("partitions", Json::num(snap.num_partitions() as f64)),
        ("rows", Json::num(snap.rows() as f64)),
        ("unsealed_rows", Json::num(c.unsealed_rows as f64)),
        ("key_min", Json::num(snap.dataset().key_min().unwrap_or(0) as f64)),
        ("key_max", Json::num(snap.dataset().key_max().unwrap_or(0) as f64)),
        (
            "asl_len",
            Json::num(snap.index().map_or(0, |i| i.asl_len()) as f64),
        ),
        ("rebuilds", Json::num(c.rebuilds as f64)),
    ]))
}

fn bad_num() -> OsebaError {
    OsebaError::Json("lo/hi must be integers".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppConfig;
    use crate::coordinator::Coordinator;
    use crate::datagen::ClimateGen;
    use crate::engine::LiveConfig;
    use crate::index::Cias;
    use crate::runtime::NativeBackend;
    use crate::storage::Schema;

    fn setup() -> (Coordinator, ServerSource) {
        let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
        let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let ds = coord.load(ClimateGen::default().generate(10_000), 5).unwrap();
        let index = Cias::build(ds.partitions()).unwrap();
        let source =
            ServerSource::Fixed { ds: Arc::new(ds), index: Arc::new(index) };
        (coord, source)
    }

    fn setup_live() -> (Coordinator, ServerSource, Arc<LiveDataset>) {
        let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
        let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let live = coord
            .create_live(
                Schema::climate(),
                LiveConfig { rows_per_partition: 1_000, max_asl: 8 },
            )
            .unwrap();
        let source = ServerSource::Live(Arc::clone(&live));
        (coord, source, live)
    }

    #[test]
    fn info_request() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        let r = handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("live"), Some(&Json::Bool(false)));
        assert_eq!(r.get("rows").unwrap().as_usize(), Some(10_000));
        assert_eq!(r.get("index").unwrap().as_str(), Some("cias"));
    }

    #[test]
    fn stats_request_both_methods_agree() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        let mk = |method: &str| {
            format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature","method":"{method}"}}"#,
                3600 * 999
            )
        };
        let a = handle_request(&mk("oseba"), &coord, &source, &flag).unwrap();
        let b = handle_request(&mk("default"), &coord, &source, &flag).unwrap();
        assert_eq!(a.get("count"), b.get("count"));
        assert_eq!(a.get("max"), b.get("max"));
        // Default path must not leak server memory.
        let before = coord.context().memory_used();
        handle_request(&mk("default"), &coord, &source, &flag).unwrap();
        assert_eq!(coord.context().memory_used(), before);
    }

    #[test]
    fn stats_where_clause_filters_and_reports() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        let all = handle_request(
            &format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 9_999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let hot = handle_request(
            &format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature","where":"temperature > 15"}}"#,
                3600 * 9_999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let n_all = all.get("count").unwrap().as_usize().unwrap();
        let n_hot = hot.get("count").unwrap().as_usize().unwrap();
        assert!(n_hot < n_all, "predicate must be selective ({n_hot} vs {n_all})");
        assert!(n_hot > 0);
        assert!(hot.get("min").unwrap().as_f64().unwrap() > 15.0);
        assert_eq!(hot.get("nans").unwrap().as_usize(), Some(0));
        assert!(hot.get("zone_pruned").is_some());

        // Bad clauses are clean errors; the scan baseline rejects `where`.
        assert!(handle_request(
            r#"{"op":"stats","lo":0,"hi":10,"column":"temperature","where":"bogus > 1"}"#,
            &coord,
            &source,
            &flag
        )
        .is_err());
        assert!(handle_request(
            r#"{"op":"stats","lo":0,"hi":10,"column":"temperature","where":"temperature = 1"}"#,
            &coord,
            &source,
            &flag
        )
        .is_err());
        let err = handle_request(
            r#"{"op":"stats","lo":0,"hi":10,"column":"temperature","where":"temperature > 1","method":"default"}"#,
            &coord,
            &source,
            &flag,
        )
        .unwrap_err();
        assert!(err.to_string().contains("oseba"), "got: {err}");
    }

    #[test]
    fn stats_and_info_report_sketch_answers() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        // Full-span query: every partition is fully covered — answered
        // entirely from aggregate sketches.
        let r = handle_request(
            &format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 9_999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        assert_eq!(r.get("count").unwrap().as_usize(), Some(10_000));
        assert_eq!(r.get("agg_answered").unwrap().as_usize(), Some(5));
        assert_eq!(r.get("rows_avoided").unwrap().as_usize(), Some(10_000));

        // explain carries the same arithmetic without executing.
        let r = handle_request(
            &format!(
                r#"{{"op":"explain","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 9_999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let plan = r.get("plan").unwrap();
        assert_eq!(plan.get("agg_answered").unwrap().as_usize(), Some(5));
        assert_eq!(plan.get("estimated_rows").unwrap().as_usize(), Some(0));

        // info surfaces the cumulative engine counter.
        let r = handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(r.get("agg_answered").unwrap().as_usize(), Some(5));
    }

    #[test]
    fn explain_reports_pruning_without_executing() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        // Selective key range: 10_000 rows in 5 partitions of 2_000 rows.
        let r = handle_request(
            &format!(
                r#"{{"op":"explain","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let plan = r.get("plan").unwrap();
        assert_eq!(plan.get("partitions").unwrap().as_usize(), Some(5));
        assert_eq!(plan.get("considered").unwrap().as_usize(), Some(1));
        assert_eq!(plan.get("key_pruned").unwrap().as_usize(), Some(4));
        assert_eq!(plan.get("zone_pruned").unwrap().as_usize(), Some(0));
        assert_eq!(plan.get("filter_pruned").unwrap().as_usize(), Some(0));
        assert_eq!(plan.get("targeted").unwrap().as_usize(), Some(1));
        assert_eq!(plan.get("estimated_rows").unwrap().as_usize(), Some(1_000));
        assert_eq!(r.get("verified"), None, "verify only runs when asked");
        // `"verify": true` runs the plan-invariant checker on the lowering.
        let r = handle_request(
            &format!(
                r#"{{"op":"explain","lo":0,"hi":{},"column":"temperature","verify":true}}"#,
                3600 * 999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("verified"), Some(&Json::Bool(true)));
        // An impossible predicate zone-prunes everything, still ok:false-free.
        let r = handle_request(
            &format!(
                r#"{{"op":"explain","lo":0,"hi":{},"column":"temperature","where":"temperature > 100000"}}"#,
                3600 * 9_999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let plan = r.get("plan").unwrap();
        assert_eq!(plan.get("targeted").unwrap().as_usize(), Some(0));
        assert_eq!(
            plan.get("zone_pruned").unwrap().as_usize(),
            plan.get("considered").unwrap().as_usize()
        );
        // An equality clause lowers through the membership-filter stage;
        // `verify` re-checks considered = targeted + zone_pruned +
        // filter_pruned on the result, whatever the filters decided.
        let r = handle_request(
            &format!(
                r#"{{"op":"explain","lo":0,"hi":{},"column":"temperature","where":"temperature == 21.5","verify":true}}"#,
                3600 * 9_999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        assert_eq!(r.get("verified"), Some(&Json::Bool(true)));
        let plan = r.get("plan").unwrap();
        assert!(plan.get("filter_pruned").is_some());
        assert!(plan.get("filter_bytes").is_some());
    }

    #[test]
    fn tiered_dataset_serves_and_reports_faults() {
        let dir = crate::testing::temp_dir("srv-tiered");
        let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
        let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let ds = coord
            .load_tiered(ClimateGen::default().generate(10_000), 5, &dir)
            .unwrap();
        let index = crate::index::Cias::from_meta(ds.store().unwrap().metas()).unwrap();
        let source =
            ServerSource::Fixed { ds: Arc::new(ds), index: Arc::new(index) };
        let flag = AtomicBool::new(false);

        let r = handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(r.get("tiered"), Some(&Json::Bool(true)));
        assert_eq!(r.get("faults").unwrap().as_usize(), Some(0));

        let req = format!(
            r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#,
            3600 * 999
        );
        let r = handle_request(&req, &coord, &source, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("count").unwrap().as_usize(), Some(1000));
        let ServerSource::Fixed { ds, .. } = &source else { unreachable!() };
        coord.context().unpersist(ds);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn malformed_requests_are_errors() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        assert!(handle_request("{", &coord, &source, &flag).is_err());
        assert!(handle_request(r#"{"op":"nope"}"#, &coord, &source, &flag).is_err());
        assert!(handle_request(
            r#"{"op":"stats","lo":5,"hi":1,"column":"temperature"}"#,
            &coord,
            &source,
            &flag
        )
        .is_err());
        assert!(handle_request(
            r#"{"op":"stats","lo":0,"hi":10,"column":"bogus"}"#,
            &coord,
            &source,
            &flag
        )
        .is_err());
        // Live-only ops on a fixed server are clear errors.
        let err = handle_request(
            r#"{"op":"append","keys":[1],"columns":[[1],[1],[1],[1]]}"#,
            &coord,
            &source,
            &flag,
        )
        .unwrap_err();
        assert!(err.to_string().contains("live"), "got: {err}");
        assert!(handle_request(r#"{"op":"snapshot"}"#, &coord, &source, &flag).is_err());
    }

    #[test]
    fn panicking_handler_is_caught_at_the_session_boundary() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        assert_eq!(coord.context().counters().sessions_failed, 0);

        // A handler that dies by panic becomes a protocol-level error …
        let r = respond_caught(&coord, std::panic::AssertUnwindSafe(|| panic!("boom")));
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert_eq!(r.get("panic"), Some(&Json::Bool(true)));
        assert!(r.get("error").unwrap().as_str().unwrap().contains("boom"));
        assert_eq!(coord.context().counters().sessions_failed, 1);

        // … the session keeps serving afterwards …
        let r = respond(r#"{"op":"info"}"#, &coord, &source, &flag);
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        let c = r.get("counters").unwrap();
        assert_eq!(c.get("sessions_failed").unwrap().as_usize(), Some(1));

        // … and typed errors keep their plain (non-panic) shape.
        let r = respond("{", &coord, &source, &flag);
        assert_eq!(r.get("ok"), Some(&Json::Bool(false)));
        assert!(r.get("panic").is_none());
    }

    #[test]
    fn shutdown_sets_flag() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        let r = handle_request(r#"{"op":"shutdown"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(flag.load(Ordering::SeqCst));
    }

    /// Build an append request for `rows` hourly rows starting at `start`.
    fn append_req(start: i64, rows: usize) -> String {
        let keys: Vec<String> =
            (0..rows as i64).map(|i| (start + i * 3600).to_string()).collect();
        let col: Vec<String> = (0..rows).map(|i| format!("{}.5", i % 30)).collect();
        let cols = format!(
            "[[{0}],[{0}],[{0}],[{0}]]",
            col.join(",")
        );
        format!(
            r#"{{"op":"append","keys":[{}],"columns":{}}}"#,
            keys.join(","),
            cols
        )
    }

    #[test]
    fn live_append_then_query_round_trip() {
        let (coord, source, live) = setup_live();
        let flag = AtomicBool::new(false);

        // Empty live dataset: info works, stats is a clean error.
        let r = handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(r.get("live"), Some(&Json::Bool(true)));
        assert_eq!(r.get("epoch").unwrap().as_usize(), Some(0));
        assert!(handle_request(
            r#"{"op":"stats","lo":0,"hi":10,"column":"temperature"}"#,
            &coord,
            &source,
            &flag
        )
        .is_err());

        // 600 rows: buffered, invisible.
        let r = handle_request(&append_req(0, 600), &coord, &source, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("epoch").unwrap().as_usize(), Some(0));
        assert_eq!(r.get("unsealed_rows").unwrap().as_usize(), Some(600));

        // 600 more: one partition seals, queries see exactly 1000 rows.
        let r = handle_request(&append_req(600 * 3600, 600), &coord, &source, &flag).unwrap();
        assert_eq!(r.get("epoch").unwrap().as_usize(), Some(1));
        assert_eq!(r.get("sealed_rows").unwrap().as_usize(), Some(1000));
        assert_eq!(r.get("unsealed_rows").unwrap().as_usize(), Some(200));

        let r = handle_request(
            r#"{"op":"snapshot"}"#,
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        assert_eq!(r.get("epoch").unwrap().as_usize(), Some(1));
        assert_eq!(r.get("partitions").unwrap().as_usize(), Some(1));
        assert_eq!(r.get("rows").unwrap().as_usize(), Some(1000));

        let stats = handle_request(
            &format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 10_000
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        assert_eq!(stats.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(stats.get("count").unwrap().as_usize(), Some(1000));
        assert_eq!(stats.get("epoch").unwrap().as_usize(), Some(1));

        // Malformed appends are clear errors.
        assert!(handle_request(
            r#"{"op":"append","keys":[1],"columns":[[1]]}"#,
            &coord,
            &source,
            &flag
        )
        .is_err());
        assert!(handle_request(
            r#"{"op":"append","keys":["x"],"columns":[[1],[1],[1],[1]]}"#,
            &coord,
            &source,
            &flag
        )
        .is_err());
        live.close();
    }

    #[test]
    fn end_to_end_over_tcp() {
        let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
        let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let ds = coord.load(ClimateGen::default().generate(10_000), 5).unwrap();
        let server = QueryServer::new(Arc::new(coord), ds, IndexKind::Cias).unwrap();
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let shutdown = server.shutdown_handle();
        let handle = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", |a| addr_tx.send(a).unwrap()).unwrap();
        });
        let addr = addr_rx.recv().unwrap();

        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"{\"op\":\"stats\",\"lo\":0,\"hi\":360000,\"column\":\"humidity\"}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("count").unwrap().as_usize(), Some(101));

        stream.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("bye"));
        assert!(shutdown.load(Ordering::SeqCst));
        handle.join().unwrap();
    }

    #[test]
    fn live_server_over_tcp_ingests_and_serves() {
        let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
        let coord = Arc::new(Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap());
        let live = coord
            .create_live(
                Schema::climate(),
                LiveConfig { rows_per_partition: 500, max_asl: 8 },
            )
            .unwrap();
        let server = QueryServer::live(Arc::clone(&coord), Arc::clone(&live));
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let handle = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", |a| addr_tx.send(a).unwrap()).unwrap();
        });
        let addr = addr_rx.recv().unwrap();

        let mut stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut ask = |req: &str| -> Json {
            stream.write_all(req.as_bytes()).unwrap();
            stream.write_all(b"\n").unwrap();
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            Json::parse(line.trim()).unwrap()
        };

        let r = ask(&append_req(0, 500));
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("epoch").unwrap().as_usize(), Some(1));
        let r = ask(r#"{"op":"stats","lo":0,"hi":999999999,"column":"temperature"}"#);
        assert_eq!(r.get("count").unwrap().as_usize(), Some(500));
        let r = ask(r#"{"op":"shutdown"}"#);
        assert_eq!(r.get("bye"), Some(&Json::Bool(true)));
        handle.join().unwrap();
        live.close();
    }

    /// Top-level keys of a response, in the (sorted) order they serialize.
    fn keys_of(r: &Json) -> Vec<String> {
        r.as_obj().unwrap().keys().cloned().collect()
    }

    #[test]
    fn info_schema_is_pinned() {
        // Back-compat contract (ISSUE 7): `info` keeps its exact shape,
        // plus the `metrics_ops` discovery counter. Keys serialize sorted.
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        let r = handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(
            keys_of(&r),
            [
                "agg_answered",
                "counters",
                "filter_bytes",
                "index",
                "index_bytes",
                "key_max",
                "key_min",
                "live",
                "memory_bytes",
                "metrics_ops",
                "ok",
                "partitions",
                "rows",
                "tiered",
            ]
        );
        assert_eq!(
            keys_of(r.get("counters").unwrap()),
            [
                "blocks_covered",
                "blocks_pruned",
                "bytes_materialized",
                "degraded_answers",
                "partitions_agg_answered",
                "partitions_scanned",
                "partitions_targeted",
                "rows_scanned",
                "sessions_failed",
            ]
        );
        assert_eq!(r.get("metrics_ops").unwrap().as_usize(), Some(0));

        let (coord, source, live) = setup_live();
        handle_request(&append_req(0, 1_000), &coord, &source, &flag).unwrap();
        let r = handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(
            keys_of(&r),
            [
                "agg_answered",
                "appended_chunks",
                "asl_absorbed",
                "asl_len",
                "counters",
                "epoch",
                "filter_bytes",
                "index",
                "index_appends",
                "index_bytes",
                "key_max",
                "key_min",
                "live",
                "memory_bytes",
                "metrics_ops",
                "ok",
                "out_of_order_chunks",
                "partitions",
                "rebuilds",
                "rows",
                "tiered",
            ]
        );
        live.close();
    }

    #[test]
    fn metrics_op_unifies_counters_and_histograms() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        // Scripted session: info, two stats, one explain.
        handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        let stats_req = format!(
            r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#,
            3600 * 999
        );
        handle_request(&stats_req, &coord, &source, &flag).unwrap();
        handle_request(&stats_req, &coord, &source, &flag).unwrap();
        handle_request(
            &format!(
                r#"{{"op":"explain","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();

        let r = handle_request(r#"{"op":"metrics"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("enabled"), Some(&Json::Bool(true)));
        // Every pre-existing engine counter is present, with real traffic.
        let counters = r.get("counters").unwrap();
        assert_eq!(
            keys_of(counters),
            [
                "blocks_covered",
                "blocks_pruned",
                "bytes_materialized",
                "degraded_answers",
                "partitions_agg_answered",
                "partitions_scanned",
                "partitions_targeted",
                "rows_scanned",
                "sessions_failed",
            ]
        );
        assert!(counters.get("partitions_targeted").unwrap().as_usize().unwrap() > 0);
        // Per-op histograms: all six registered, with non-zero counts for
        // the ops the session ran.
        let ops = r.get("ops").unwrap();
        assert_eq!(
            keys_of(ops),
            ["op_append", "op_explain", "op_info", "op_metrics", "op_snapshot", "op_stats"]
        );
        let count_of = |j: &Json, key: &str| {
            j.get(key).unwrap().get("count").unwrap().as_usize().unwrap()
        };
        assert_eq!(count_of(ops, "op_stats"), 2);
        assert_eq!(count_of(ops, "op_info"), 1);
        assert_eq!(count_of(ops, "op_explain"), 1);
        assert_eq!(count_of(ops, "op_metrics"), 0, "recorded after the handler returns");
        assert!(ops.get("op_stats").unwrap().get("p50").unwrap().as_f64().unwrap() > 0.0);
        assert!(ops.get("op_stats").unwrap().get("p999").is_some());
        // Per-phase histograms: the stats queries exercised the planner.
        let phases = r.get("phases").unwrap();
        assert_eq!(
            keys_of(phases),
            [
                "phase_block_classify",
                "phase_demux",
                "phase_fault_in",
                "phase_fault_recovery",
                "phase_filter_pruning",
                "phase_scan_merge",
                "phase_sketch_classify",
                "phase_targeting",
                "phase_zone_pruning",
            ]
        );
        assert_eq!(count_of(phases, "phase_targeting"), 2);
        assert_eq!(count_of(phases, "phase_scan_merge"), 2);
        // The slow-query log retained the stats queries with their
        // traces and explains.
        let slow = r.get("slow_queries").unwrap().as_arr().unwrap();
        assert_eq!(slow.len(), 2);
        assert!(slow[0].get("trace").is_some());
        assert!(slow[0].get("explain").is_some());
        assert_eq!(slow[0].get("op").unwrap().as_str(), Some("stats"));

        // A second metrics call observes the first; info advertises both.
        let r2 = handle_request(r#"{"op":"metrics"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(count_of(r2.get("ops").unwrap(), "op_metrics"), 1);
        let info = handle_request(r#"{"op":"info"}"#, &coord, &source, &flag).unwrap();
        assert_eq!(info.get("metrics_ops").unwrap().as_usize(), Some(2));
    }

    #[test]
    fn metrics_text_exposition() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        handle_request(
            &format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let r =
            handle_request(r#"{"op":"metrics","text":true}"#, &coord, &source, &flag).unwrap();
        let text = r.get("text").unwrap().as_str().unwrap();
        assert!(text.contains("oseba_engine_partitions_targeted "), "{text}");
        assert!(text.contains("oseba_op_stats_latency_seconds_count 1"), "{text}");
        assert!(text.contains("oseba_op_stats_latency_seconds{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("oseba_phase_targeting_latency_seconds_count 1"), "{text}");
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad exposition line: {line}");
        }
    }

    #[test]
    fn trace_span_tree_matches_explain() {
        let (coord, source) = setup();
        let flag = AtomicBool::new(false);
        // Narrow range: non-trivial targeting/key-pruning arithmetic.
        let r = handle_request(
            &format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature","trace":true}}"#,
                3600 * 999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let trace = r.get("trace").unwrap();
        assert_eq!(trace.get("name").unwrap().as_str(), Some("query"));
        let plan = handle_request(
            &format!(
                r#"{{"op":"explain","lo":0,"hi":{},"column":"temperature"}}"#,
                3600 * 999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let plan = plan.get("plan").unwrap();
        let children = trace.get("children").unwrap().as_arr().unwrap();
        let names: Vec<&str> =
            children.iter().map(|c| c.get("name").unwrap().as_str().unwrap()).collect();
        assert_eq!(
            names,
            [
                "targeting",
                "zone_pruning",
                "filter_pruning",
                "sketch_classify",
                "block_classify",
                "fault_in",
                "scan_merge",
            ]
        );
        let child = |name: &str| {
            children.iter().find(|c| c.get("name").unwrap().as_str() == Some(name)).unwrap()
        };
        // Per-phase counts agree with the identical query's explain.
        for (span, key) in [
            ("targeting", "considered"),
            ("targeting", "key_pruned"),
            ("zone_pruning", "zone_pruned"),
            ("filter_pruning", "filter_pruned"),
            ("filter_pruning", "filter_bytes"),
            ("sketch_classify", "agg_answered"),
            ("sketch_classify", "rows_avoided"),
            ("block_classify", "blocks_covered"),
            ("block_classify", "blocks_pruned"),
            ("fault_in", "targeted"),
            ("scan_merge", "estimated_rows"),
        ] {
            assert_eq!(child(span).get(key), plan.get(key), "span '{span}' count '{key}'");
        }
        // Every span serializes a sane (non-negative, finite) wall time.
        for c in children {
            assert!(c.get("secs").unwrap().as_f64().unwrap() >= 0.0);
        }
        // Untraced responses carry no span tree; the scan baseline
        // reports a root-only span when asked.
        let r = handle_request(
            &format!(r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#, 3600 * 999),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        assert!(r.get("trace").is_none());
        let r = handle_request(
            &format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature","method":"default","trace":true}}"#,
                3600 * 999
            ),
            &coord,
            &source,
            &flag,
        )
        .unwrap();
        let trace = r.get("trace").unwrap();
        assert_eq!(trace.get("name").unwrap().as_str(), Some("query"));
        assert_eq!(trace.get("children").unwrap().as_arr().map(<[Json]>::len), Some(0));
    }
}
