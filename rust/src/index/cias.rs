//! CIAS — Compressed Index with Associated Search List (paper §III-B).
//!
//! The table of Fig 3 is redundant when (1) partitions hold the same number
//! of rows and (2) keys advance by a fixed step (temporal data): the whole
//! `(partition → key range)` mapping collapses to four integers,
//!
//! ```text
//! Compressed Index: base_key, rows_per_partition ^ regular_partitions, step
//! ```
//!
//! and lookups become *computation* instead of search: for key `k`, the
//! global row is `(k - base_key) / step`, its partition is `row /
//! rows_per_partition` and its in-partition offset is `row %
//! rows_per_partition` — O(1) time, O(1) space, independent of the number
//! of partitions (the paper's goal: "the overhead on metadata organization
//! and lookup does not increase with the size of real data").
//!
//! Real datasets are rarely perfectly regular: the final partial partition,
//! ingestion gaps, or re-partitioned regions break the pattern. Those
//! partitions live in the **Associated Search List** — a short, sorted
//! table searched like §III-A but whose length is the number of
//! *irregularities*, not the number of partitions.

use std::sync::Arc;

use crate::error::{OsebaError, Result};
use crate::index::builder::{ceil_div, extract_meta, slice_for_meta};
use crate::index::types::{ContentIndex, PartitionMeta, PartitionSlice, RangeQuery};
use crate::storage::Partition;

/// The compressed index plus its associated search list.
#[derive(Clone, Debug)]
pub struct Cias {
    /// Key of global row 0 of the regular region.
    base_key: i64,
    /// Key step between consecutive rows.
    step: i64,
    /// Rows per regular partition.
    rows_per_part: usize,
    /// Number of leading partitions covered by the compressed index.
    regular_parts: usize,
    /// Metadata for the irregular remainder, ordered by key range.
    asl: Vec<PartitionMeta>,
}

impl Cias {
    /// Build from loaded partitions: detect the maximal regular prefix and
    /// push the remainder onto the ASL.
    pub fn build(parts: &[Arc<Partition>]) -> Result<Cias> {
        Self::from_meta(extract_meta(parts))
    }

    /// Build from extracted metadata.
    pub fn from_meta(metas: Vec<PartitionMeta>) -> Result<Cias> {
        if metas.is_empty() {
            return Err(OsebaError::Index("empty partition set".into()));
        }
        // Ranges are *inclusive*, so a shared boundary key (key_max ==
        // next key_min) is an overlap too: a point query on that key would
        // double-count rows from both partitions.
        for w in metas.windows(2) {
            if w[0].key_max >= w[1].key_min {
                return Err(OsebaError::Index(format!(
                    "partitions {} and {} overlap ({} >= {})",
                    w[0].id, w[1].id, w[0].key_max, w[1].key_min
                )));
            }
        }

        // The candidate pattern comes from partition 0.
        let (base_key, step, rows_per_part) = match (metas[0].step, metas[0].rows) {
            (Some(s), r) if r > 0 => (metas[0].key_min, s, r),
            _ => {
                // No observable pattern — everything goes to the ASL and
                // CIAS degenerates (gracefully) into the table.
                return Ok(Cias { base_key: 0, step: 1, rows_per_part: 1, regular_parts: 0, asl: metas });
            }
        };

        let mut regular_parts = 0usize;
        for (i, m) in metas.iter().enumerate() {
            let expect_min = base_key + (i * rows_per_part) as i64 * step;
            let regular = m.id == i
                && m.rows == rows_per_part
                && m.step == Some(step)
                && m.key_min == expect_min
                && m.key_max == expect_min + (rows_per_part as i64 - 1) * step;
            if regular {
                regular_parts = i + 1;
            } else {
                break;
            }
        }
        let asl = metas[regular_parts..].to_vec();
        Ok(Cias { base_key, step, rows_per_part, regular_parts, asl })
    }

    /// Number of partitions captured by the compressed (O(1)) region.
    pub fn regular_parts(&self) -> usize {
        self.regular_parts
    }

    /// Length of the associated search list.
    pub fn asl_len(&self) -> usize {
        self.asl.len()
    }

    /// The paper's compact textual rendering, e.g. `"0, 4096^15, 3600"`.
    pub fn compressed_repr(&self) -> String {
        format!("{}, {}^{}, {}", self.base_key, self.rows_per_part, self.regular_parts, self.step)
    }

    /// Incrementally absorb the next partition's metadata (streaming
    /// ingestion). O(1): if the partition continues the regular pattern
    /// *and* the ASL is empty, the compressed region simply grows;
    /// otherwise it joins the ASL. Partitions must arrive in key order
    /// with ids continuing the existing sequence.
    pub fn append_meta(&mut self, m: PartitionMeta) -> Result<()> {
        let expected_id = self.num_partitions();
        if m.id != expected_id {
            return Err(OsebaError::Index(format!(
                "append out of order: got partition {}, expected {}",
                m.id, expected_id
            )));
        }
        // Overall maximum key covered so far. With only in-order appends
        // the last ASL entry dominates; after an out-of-order
        // [`Self::absorb_meta`] the ASL may hold entries *below* the
        // compressed region, so both maxima must be considered.
        let asl_max = self.asl.iter().map(|e| e.key_max).max();
        let prev_max = match (self.regular_max(), asl_max) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
        // Inclusive ranges: equality with the previous key_max is an
        // overlap (shared boundary key), mirroring `from_meta`.
        if let Some(pm) = prev_max {
            if m.key_min <= pm {
                return Err(OsebaError::Index(format!(
                    "append overlaps: key_min {} <= previous key_max {pm}",
                    m.key_min
                )));
            }
        }

        // First partition establishes the pattern.
        if self.regular_parts == 0 && self.asl.is_empty() {
            if let (Some(s), r) = (m.step, m.rows) {
                if r > 0 {
                    self.base_key = m.key_min;
                    self.step = s;
                    self.rows_per_part = r;
                    self.regular_parts = 1;
                    return Ok(());
                }
            }
            self.asl.push(m);
            return Ok(());
        }

        let expect_min =
            self.base_key + (self.regular_parts * self.rows_per_part) as i64 * self.step;
        let continues_pattern = self.asl.is_empty()
            && m.rows == self.rows_per_part
            && m.step == Some(self.step)
            && m.key_min == expect_min
            && m.key_max == expect_min + (self.rows_per_part as i64 - 1) * self.step;
        if continues_pattern {
            self.regular_parts += 1;
        } else {
            self.asl.push(m);
        }
        Ok(())
    }

    /// Largest key of the compressed region, `None` when it is empty.
    fn regular_max(&self) -> Option<i64> {
        (self.regular_parts > 0).then(|| {
            self.base_key
                + ((self.regular_parts * self.rows_per_part) as i64 - 1) * self.step
        })
    }

    /// Absorb an **out-of-order** (late-arriving) partition into the ASL.
    ///
    /// The partition's id must still continue the creation sequence (ids
    /// number partitions in arrival order), but its key range may fall
    /// anywhere that does not overlap the compressed region or an existing
    /// ASL entry — the gap-fill case of the paper's "irregular partitions"
    /// (§III-B). O(ASL) insertion keeps the list sorted by key so lookups
    /// stay a short binary search.
    ///
    /// Note: an index that has absorbed out-of-order partitions no longer
    /// satisfies the sequential-id invariant [`Cias::from_components`]
    /// validates, so it cannot be snapshotted to a store manifest without
    /// a rebuild that renumbers partitions in key order (the live
    /// dataset's rebuild does exactly that).
    pub fn absorb_meta(&mut self, m: PartitionMeta) -> Result<()> {
        let expected_id = self.num_partitions();
        if m.id != expected_id {
            return Err(OsebaError::Index(format!(
                "absorb out of sequence: got partition {}, expected {}",
                m.id, expected_id
            )));
        }
        if m.key_min > m.key_max {
            return Err(OsebaError::Index(format!(
                "absorbed partition has inverted range ({} > {})",
                m.key_min, m.key_max
            )));
        }
        if let Some(reg_max) = self.regular_max() {
            if m.key_min <= reg_max && m.key_max >= self.base_key {
                return Err(OsebaError::Index(format!(
                    "absorbed partition [{}, {}] overlaps the compressed region [{}, {reg_max}]",
                    m.key_min, m.key_max, self.base_key
                )));
            }
        }
        let pos = self.asl.partition_point(|e| e.key_min < m.key_min);
        if pos > 0 && self.asl[pos - 1].key_max >= m.key_min {
            return Err(OsebaError::Index(format!(
                "absorbed partition [{}, {}] overlaps partition {}",
                m.key_min,
                m.key_max,
                self.asl[pos - 1].id
            )));
        }
        if pos < self.asl.len() && self.asl[pos].key_min <= m.key_max {
            return Err(OsebaError::Index(format!(
                "absorbed partition [{}, {}] overlaps partition {}",
                m.key_min,
                m.key_max,
                self.asl[pos].id
            )));
        }
        self.asl.insert(pos, m);
        Ok(())
    }

    /// Decomposed form for persistence: `(base_key, step, rows_per_part,
    /// regular_parts, asl)` — exactly the paper's compressed tuple plus the
    /// associated search list. The store manifest snapshots this so `open`
    /// restores lookup in O(index) without touching data.
    pub fn components(&self) -> (i64, i64, usize, usize, &[PartitionMeta]) {
        (self.base_key, self.step, self.rows_per_part, self.regular_parts, &self.asl)
    }

    /// Rebuild from persisted components, re-validating the invariants
    /// [`Cias::from_meta`] establishes (a corrupted or hand-edited manifest
    /// must not produce an index that double-counts rows).
    pub fn from_components(
        base_key: i64,
        step: i64,
        rows_per_part: usize,
        regular_parts: usize,
        asl: Vec<PartitionMeta>,
    ) -> Result<Cias> {
        if regular_parts > 0 && (step <= 0 || rows_per_part == 0) {
            return Err(OsebaError::Index(format!(
                "invalid compressed region: step {step}, rows_per_part {rows_per_part}"
            )));
        }
        // Checked arithmetic throughout: components may come from an
        // untrusted manifest, and an overflow here must be a clean error,
        // not a panic or a wrapped garbage bound.
        let regular_max = if regular_parts > 0 {
            let total = regular_parts
                .checked_mul(rows_per_part)
                .filter(|&t| t <= i64::MAX as usize)
                .ok_or_else(|| {
                    OsebaError::Index(format!(
                        "compressed region too large: {regular_parts} x {rows_per_part} rows"
                    ))
                })?;
            let max = step
                .checked_mul(total as i64 - 1)
                .and_then(|x| base_key.checked_add(x))
                .ok_or_else(|| {
                    OsebaError::Index("compressed region key range overflows i64".into())
                })?;
            Some(max)
        } else {
            None
        };
        let mut prev_max = regular_max;
        for (i, m) in asl.iter().enumerate() {
            if m.id != regular_parts + i {
                return Err(OsebaError::Index(format!(
                    "asl entry {i} has id {}, expected {}",
                    m.id,
                    regular_parts + i
                )));
            }
            if m.key_min > m.key_max {
                return Err(OsebaError::Index(format!(
                    "asl entry {i} has inverted range ({} > {})",
                    m.key_min, m.key_max
                )));
            }
            if let Some(pm) = prev_max {
                if m.key_min <= pm {
                    return Err(OsebaError::Index(format!(
                        "asl entry {i} overlaps ({} <= {pm})",
                        m.key_min
                    )));
                }
            }
            prev_max = Some(m.key_max);
        }
        Ok(Cias { base_key, step, rows_per_part, regular_parts, asl })
    }

    /// O(1) point lookup within the regular region: `(partition, row)` for
    /// the first key `>= k`, or `None` if that key falls past the region.
    pub fn locate(&self, k: i64) -> Option<(usize, usize)> {
        let n_rows = (self.regular_parts * self.rows_per_part) as i64;
        if n_rows == 0 {
            return None;
        }
        let g = ceil_div(k - self.base_key, self.step).max(0);
        if g >= n_rows {
            return None;
        }
        let g = g as usize;
        Some((g / self.rows_per_part, g % self.rows_per_part))
    }
}

impl ContentIndex for Cias {
    fn name(&self) -> &'static str {
        "cias"
    }

    fn lookup(&self, q: RangeQuery) -> Vec<PartitionSlice> {
        let mut out = Vec::new();

        // --- compressed region: pure arithmetic -------------------------
        // i128 throughout: `hi - base_key` (and the `+ 1` past it) must
        // not wrap for open-ended queries like `[0, i64::MAX]` over a
        // step-1 grid — a regression the pruning bench exercises.
        let n_rows = (self.regular_parts * self.rows_per_part) as i128;
        if n_rows > 0 {
            let step = self.step as i128;
            let lo = q.lo as i128 - self.base_key as i128;
            let g_start =
                (lo.div_euclid(step) + i128::from(lo.rem_euclid(step) != 0)).max(0);
            let g_end = ((q.hi as i128 - self.base_key as i128).div_euclid(step) + 1)
                .clamp(0, n_rows);
            if g_start < g_end {
                let (gs, ge) = (g_start as usize, g_end as usize);
                let p_first = gs / self.rows_per_part;
                let p_last = (ge - 1) / self.rows_per_part;
                for p in p_first..=p_last {
                    let part_base = p * self.rows_per_part;
                    out.push(PartitionSlice {
                        partition: p,
                        row_start: gs.saturating_sub(part_base),
                        row_end: (ge - part_base).min(self.rows_per_part),
                    });
                }
            }
        }

        // --- associated search list: small binary search ----------------
        let start = self.asl.partition_point(|m| m.key_max < q.lo);
        for m in &self.asl[start..] {
            if m.key_min > q.hi {
                break;
            }
            if let Some(s) = slice_for_meta(m, q) {
                out.push(s);
            }
        }
        out
    }

    fn memory_bytes(&self) -> usize {
        // Four scalars + the ASL entries. Deliberately excludes the Vec
        // header so the O(1)-vs-O(m) comparison reads directly.
        4 * 8 + self.asl.len() * std::mem::size_of::<PartitionMeta>()
    }

    fn num_partitions(&self) -> usize {
        self.regular_parts + self.asl.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::index::table::TableIndex;
    use crate::storage::{partition_batch_uniform, BatchBuilder, Schema};
    use crate::util::rng::Xoshiro256;

    fn uniform_parts(rows: usize, per: usize, step: i64) -> Vec<Arc<Partition>> {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..rows {
            b.push(500 + i as i64 * step, &[i as f32, 0.0]);
        }
        partition_batch_uniform(&b.finish().unwrap(), per).unwrap()
    }

    #[test]
    fn fully_regular_dataset_compresses_everything_but_tail() {
        // 100 rows, 25/partition → 4 regular partitions, empty ASL.
        let cias = Cias::build(&uniform_parts(100, 25, 10)).unwrap();
        assert_eq!(cias.regular_parts(), 4);
        assert_eq!(cias.asl_len(), 0);
        assert_eq!(cias.compressed_repr(), "500, 25^4, 10");
    }

    #[test]
    fn partial_tail_lands_in_asl() {
        // 90 rows, 25/partition → 3 regular + 1 partial (15 rows) in ASL.
        let cias = Cias::build(&uniform_parts(90, 25, 10)).unwrap();
        assert_eq!(cias.regular_parts(), 3);
        assert_eq!(cias.asl_len(), 1);
    }

    #[test]
    fn memory_constant_in_partition_count() {
        let small = Cias::build(&uniform_parts(100, 25, 10)).unwrap();
        let large = Cias::build(&uniform_parts(100_000, 25, 10)).unwrap();
        assert_eq!(small.memory_bytes(), large.memory_bytes());
        // ... unlike the table:
        let ts = TableIndex::build(&uniform_parts(100, 25, 10)).unwrap();
        let tl = TableIndex::build(&uniform_parts(100_000, 25, 10)).unwrap();
        assert!(tl.memory_bytes() > 100 * ts.memory_bytes());
    }

    #[test]
    fn lookup_matches_table_on_regular_data() {
        let parts = uniform_parts(1000, 64, 7);
        let cias = Cias::build(&parts).unwrap();
        let table = TableIndex::build(&parts).unwrap();
        let mut rng = Xoshiro256::seeded(99);
        for _ in 0..500 {
            let a = rng.range_u64(0, 9000) as i64 + 400;
            let b = rng.range_u64(0, 9000) as i64 + 400;
            let q = RangeQuery { lo: a.min(b), hi: a.max(b) };
            assert_eq!(cias.lookup(q), table.lookup(q), "q={q:?}");
        }
    }

    #[test]
    fn locate_point_arithmetic() {
        let cias = Cias::build(&uniform_parts(100, 25, 10)).unwrap();
        // keys 500, 510, ... partition 25 rows each.
        assert_eq!(cias.locate(500), Some((0, 0)));
        assert_eq!(cias.locate(505), Some((0, 1))); // first key ≥ 505 is 510
        assert_eq!(cias.locate(750), Some((1, 0)));
        assert_eq!(cias.locate(1490), Some((3, 24)));
        assert_eq!(cias.locate(1491), None); // past the regular region
        assert_eq!(cias.locate(-100), Some((0, 0)));
    }

    #[test]
    fn irregular_gap_splits_regular_prefix() {
        // Two regular partitions, then a key gap, then more partitions.
        let mut metas = extract_like(&uniform_parts(50, 25, 10));
        // Shift the tail by a gap of 1000.
        metas.push(PartitionMeta { id: 2, key_min: 5000, key_max: 5240, rows: 25, step: Some(10) });
        let cias = Cias::from_meta(metas).unwrap();
        assert_eq!(cias.regular_parts(), 2);
        assert_eq!(cias.asl_len(), 1);
        // Query hitting the ASL region still resolves.
        let got = cias.lookup(RangeQuery { lo: 5100, hi: 5130 });
        assert_eq!(got, vec![PartitionSlice { partition: 2, row_start: 10, row_end: 14 }]);
    }

    fn extract_like(parts: &[Arc<Partition>]) -> Vec<PartitionMeta> {
        crate::index::builder::extract_meta(parts)
    }

    #[test]
    fn no_pattern_degenerates_to_table() {
        let metas = vec![
            PartitionMeta { id: 0, key_min: 0, key_max: 90, rows: 5, step: None },
            PartitionMeta { id: 1, key_min: 100, key_max: 220, rows: 9, step: None },
        ];
        let cias = Cias::from_meta(metas.clone()).unwrap();
        assert_eq!(cias.regular_parts(), 0);
        assert_eq!(cias.asl_len(), 2);
        let table = TableIndex::from_meta(metas).unwrap();
        let q = RangeQuery { lo: 50, hi: 150 };
        assert_eq!(cias.lookup(q), table.lookup(q));
    }

    #[test]
    fn straddling_query_hits_regular_and_asl() {
        let cias = Cias::build(&uniform_parts(90, 25, 10)).unwrap();
        // Regular covers rows 0..75 (keys 500..1240), ASL rows 75..90
        // (keys 1250..1390). Query [1200, 1300] straddles.
        let got = cias.lookup(RangeQuery { lo: 1200, hi: 1300 });
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], PartitionSlice { partition: 2, row_start: 20, row_end: 25 });
        assert_eq!(got[1], PartitionSlice { partition: 3, row_start: 0, row_end: 6 });
    }

    #[test]
    fn empty_metas_rejected() {
        assert!(Cias::from_meta(vec![]).is_err());
    }

    #[test]
    fn incremental_append_equals_batch_build() {
        for (rows, per) in [(100, 25), (90, 25), (1000, 64)] {
            let parts = uniform_parts(rows, per, 10);
            let metas = extract_like(&parts);
            let batch = Cias::from_meta(metas.clone()).unwrap();
            let mut inc = Cias {
                base_key: 0,
                step: 1,
                rows_per_part: 1,
                regular_parts: 0,
                asl: Vec::new(),
            };
            for m in metas {
                inc.append_meta(m).unwrap();
            }
            assert_eq!(inc.regular_parts(), batch.regular_parts(), "rows={rows}");
            assert_eq!(inc.asl_len(), batch.asl_len());
            let q = RangeQuery { lo: 700, hi: 5_000 };
            assert_eq!(inc.lookup(q), batch.lookup(q));
        }
    }

    #[test]
    fn append_rejects_out_of_order_and_overlap() {
        let parts = uniform_parts(50, 25, 10);
        let metas = extract_like(&parts);
        let mut c = Cias::from_meta(metas.clone()).unwrap();
        // Wrong id.
        let bad = PartitionMeta { id: 5, key_min: 10_000, key_max: 10_100, rows: 11, step: Some(10) };
        assert!(c.append_meta(bad).is_err());
        // Overlapping keys.
        let overlap = PartitionMeta { id: 2, key_min: 0, key_max: 100, rows: 11, step: Some(10) };
        assert!(c.append_meta(overlap).is_err());
        // Valid gap append → ASL.
        let gapped = PartitionMeta { id: 2, key_min: 99_000, key_max: 99_240, rows: 25, step: Some(10) };
        c.append_meta(gapped).unwrap();
        assert_eq!(c.regular_parts(), 2);
        assert_eq!(c.asl_len(), 1);
        // A further regular-looking partition must still go to the ASL
        // (the compressed region cannot skip over ASL entries).
        let next = PartitionMeta { id: 3, key_min: 99_250, key_max: 99_490, rows: 25, step: Some(10) };
        c.append_meta(next).unwrap();
        assert_eq!(c.asl_len(), 2);
    }

    #[test]
    fn shared_boundary_key_rejected() {
        // Regression: inclusive partition ranges sharing a boundary key
        // used to be accepted, double-counting that key on point queries.
        let metas = vec![
            PartitionMeta { id: 0, key_min: 0, key_max: 100, rows: 11, step: Some(10) },
            PartitionMeta { id: 1, key_min: 100, key_max: 200, rows: 11, step: Some(10) },
        ];
        assert!(Cias::from_meta(metas).is_err());
    }

    #[test]
    fn append_shared_boundary_key_rejected() {
        let parts = uniform_parts(50, 25, 10); // keys 500, 510, ..., 990
        let mut c = Cias::from_meta(extract_like(&parts)).unwrap();
        // Previous key_max is 990: an equal key_min is an overlap now.
        let touching =
            PartitionMeta { id: 2, key_min: 990, key_max: 1090, rows: 11, step: Some(10) };
        assert!(c.append_meta(touching).is_err());
        // The next grid key (1000) is fine.
        let next =
            PartitionMeta { id: 2, key_min: 1000, key_max: 1100, rows: 11, step: Some(10) };
        c.append_meta(next).unwrap();
    }

    #[test]
    fn components_roundtrip_and_validate() {
        for (rows, per) in [(100, 25), (90, 25), (1000, 64)] {
            let cias = Cias::build(&uniform_parts(rows, per, 10)).unwrap();
            let (bk, st, rpp, rp, asl) = cias.components();
            let back = Cias::from_components(bk, st, rpp, rp, asl.to_vec()).unwrap();
            assert_eq!(back.regular_parts(), cias.regular_parts());
            assert_eq!(back.asl_len(), cias.asl_len());
            for q in [RangeQuery { lo: 400, hi: 900 }, RangeQuery { lo: 0, hi: 20_000 }] {
                assert_eq!(back.lookup(q), cias.lookup(q), "rows={rows} q={q:?}");
            }
        }
        // A tampered snapshot must be rejected, not trusted.
        let cias = Cias::build(&uniform_parts(90, 25, 10)).unwrap();
        let (bk, st, rpp, rp, asl) = cias.components();
        assert!(Cias::from_components(bk, 0, rpp, rp, asl.to_vec()).is_err());
        let mut bad = asl.to_vec();
        bad[0].key_min = bk; // overlaps the compressed region
        assert!(Cias::from_components(bk, st, rpp, rp, bad).is_err());
        let mut bad_id = asl.to_vec();
        bad_id[0].id += 1;
        assert!(Cias::from_components(bk, st, rpp, rp, bad_id).is_err());
    }

    #[test]
    fn absorb_out_of_order_fills_gaps_and_stays_sorted() {
        // Regular region: keys 500..990 (2 partitions of 25 rows, step 10).
        let parts = uniform_parts(50, 25, 10);
        let mut c = Cias::from_meta(extract_like(&parts)).unwrap();
        // In-order append with a gap → ASL.
        let gapped =
            PartitionMeta { id: 2, key_min: 5_000, key_max: 5_240, rows: 25, step: Some(10) };
        c.append_meta(gapped).unwrap();
        // Late partition landing in the gap between 990 and 5000.
        let late =
            PartitionMeta { id: 3, key_min: 2_000, key_max: 2_100, rows: 11, step: Some(10) };
        c.absorb_meta(late).unwrap();
        assert_eq!(c.asl_len(), 2);
        // Even later partition *before* the compressed region.
        let early = PartitionMeta { id: 4, key_min: 0, key_max: 400, rows: 41, step: Some(10) };
        c.absorb_meta(early).unwrap();
        assert_eq!(c.asl_len(), 3);
        // Lookups across all regions resolve the right partitions.
        let got = c.lookup(RangeQuery { lo: 0, hi: 10_000 });
        let ids: Vec<usize> = got.iter().map(|s| s.partition).collect();
        // Compressed region first (0, 1), then ASL in key order (4, 3, 2).
        assert_eq!(ids, vec![0, 1, 4, 3, 2]);
        let hit = c.lookup(RangeQuery { lo: 2_050, hi: 2_060 });
        assert_eq!(hit, vec![PartitionSlice { partition: 3, row_start: 5, row_end: 7 }]);
    }

    #[test]
    fn absorb_rejects_overlap_and_bad_sequence() {
        let parts = uniform_parts(50, 25, 10); // keys 500..990
        let mut c = Cias::from_meta(extract_like(&parts)).unwrap();
        // Wrong id.
        let bad_id =
            PartitionMeta { id: 7, key_min: 2_000, key_max: 2_100, rows: 11, step: Some(10) };
        assert!(c.absorb_meta(bad_id).is_err());
        // Overlaps the compressed region.
        let overlap_reg =
            PartitionMeta { id: 2, key_min: 600, key_max: 700, rows: 11, step: Some(10) };
        assert!(c.absorb_meta(overlap_reg).is_err());
        // Valid absorb, then overlaps with the absorbed entry (both sides).
        let ok = PartitionMeta { id: 2, key_min: 2_000, key_max: 2_100, rows: 11, step: Some(10) };
        c.absorb_meta(ok).unwrap();
        let left =
            PartitionMeta { id: 3, key_min: 1_500, key_max: 2_000, rows: 2, step: None };
        assert!(c.absorb_meta(left).is_err());
        let right =
            PartitionMeta { id: 3, key_min: 2_100, key_max: 2_300, rows: 2, step: None };
        assert!(c.absorb_meta(right).is_err());
        // Inverted range.
        let inverted = PartitionMeta { id: 3, key_min: 9, key_max: 5, rows: 1, step: None };
        assert!(c.absorb_meta(inverted).is_err());
    }

    #[test]
    fn append_after_early_absorb_checks_true_maximum() {
        // Regression shape: an absorbed entry *below* the regular region
        // must not shadow the regular region's maximum in append_meta's
        // overlap check.
        let parts = uniform_parts(50, 25, 10); // regular keys 500..990
        let mut c = Cias::from_meta(extract_like(&parts)).unwrap();
        let early = PartitionMeta { id: 2, key_min: 0, key_max: 400, rows: 41, step: Some(10) };
        c.absorb_meta(early).unwrap();
        // An "append" inside the regular region must be rejected even
        // though the ASL's last key_max (400) is below its key_min.
        let overlapping =
            PartitionMeta { id: 3, key_min: 700, key_max: 800, rows: 11, step: Some(10) };
        assert!(c.append_meta(overlapping).is_err());
        // A genuinely new maximum is accepted (ASL, since asl non-empty).
        let next =
            PartitionMeta { id: 3, key_min: 1_000, key_max: 1_100, rows: 11, step: Some(10) };
        c.append_meta(next).unwrap();
        assert_eq!(c.asl_len(), 2);
        assert_eq!(c.regular_parts(), 2);
    }

    #[test]
    fn open_ended_query_on_step_one_grid_does_not_overflow() {
        // Regression: `(hi - base_key).div_euclid(step) + 1` used to wrap
        // for `hi = i64::MAX` on a step-1 grid (debug panic / release
        // wrap-to-empty). Open-ended queries must resolve the full region.
        let metas = vec![PartitionMeta {
            id: 0,
            key_min: 0,
            key_max: 99,
            rows: 100,
            step: Some(1),
        }];
        let cias = Cias::from_meta(metas).unwrap();
        let got = cias.lookup(RangeQuery { lo: 0, hi: i64::MAX });
        assert_eq!(
            got,
            vec![PartitionSlice { partition: 0, row_start: 0, row_end: 100 }]
        );
        let wide = cias.lookup(RangeQuery { lo: i64::MIN + 1, hi: i64::MAX });
        assert_eq!(wide, got);
    }

    #[test]
    fn single_row_partitions_fall_back() {
        // Single-row partitions expose no step → all-ASL degeneration.
        let metas = vec![
            PartitionMeta { id: 0, key_min: 5, key_max: 5, rows: 1, step: None },
            PartitionMeta { id: 1, key_min: 8, key_max: 8, rows: 1, step: None },
        ];
        let cias = Cias::from_meta(metas).unwrap();
        assert_eq!(cias.regular_parts(), 0);
        let got = cias.lookup(RangeQuery { lo: 6, hi: 9 });
        assert_eq!(got, vec![PartitionSlice { partition: 1, row_start: 0, row_end: 1 }]);
    }
}
