#!/usr/bin/env python3
"""Compare two sets of BENCH_*.json files and print a delta table.

Standard library only. Both sides are searched recursively for files
named ``BENCH_<name>.json`` (the machine-readable documents every
paper-claim bench emits via ``benches/common::write_bench_json``).
Documents present on only one side are listed but not compared.

For each bench present on both sides, the two JSON trees are walked in
lockstep and every numeric leaf with the same path is compared. Timing
leaves are gated: paths mentioning ``secs``, latency-quantile leaves (a
final path segment like ``p50`` / ``p99`` / ``p999``, as the traffic
harness emits), quantile-suffixed leaves (``segment_stats_lanes_p50``),
and min-of-iterations leaves (``masked_fold_lanes_min``, as the
microbench fold arms emit). The delta column shows the relative change,
and ``--fail-above PCT`` turns a slowdown beyond PCT percent on any
such leaf into exit code 1. Other numeric leaves (byte counts, row
counts, speedups) are shown for context but never fail the run.

With no baseline documents the script prints how to record one and
exits 0 — the delta gate only arms itself once someone has committed
real measured numbers (never fabricate them; see bench_results/README).

Usage:
    tools/bench_delta.py [--baseline DIR] [--current DIR] [--fail-above PCT]
"""

import argparse
import json
import re
import sys
from pathlib import Path

QUANTILE_RE = re.compile(r"^p\d{2,3}$")
QUANTILE_TOKEN_RE = re.compile(r"(^|_)p\d{2,3}(_|$)")


def is_timing_leaf(path):
    """True for leaves holding wall-clock timings: any ``secs`` mention,
    a bare-quantile final segment (p50..p999), a quantile token inside
    the final segment (``cias_lookup_p50_m15``), or a min-of-iterations
    suffix (``masked_fold_lanes_min``)."""
    last = path.rsplit(".", 1)[-1]
    return ("secs" in path
            or bool(QUANTILE_RE.match(last))
            or bool(QUANTILE_TOKEN_RE.search(last))
            or last.endswith("_min"))


def find_docs(root):
    """Map bench name -> parsed JSON for every BENCH_*.json under root."""
    docs = {}
    root = Path(root)
    if not root.is_dir():
        return docs
    for path in sorted(root.rglob("BENCH_*.json")):
        name = path.stem[len("BENCH_"):]
        try:
            docs[name] = json.loads(path.read_text())
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {path}: {e}", file=sys.stderr)
    return docs


def numeric_leaves(node, prefix=""):
    """Yield (dotted path, value) for every numeric leaf of a JSON tree.

    Array elements are keyed by their "name" field when present (bench
    rows are name-tagged objects), else by index — so reordering rows
    does not misalign the comparison.
    """
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        yield prefix, float(node)
    elif isinstance(node, dict):
        for k, v in node.items():
            yield from numeric_leaves(v, f"{prefix}.{k}" if prefix else k)
    elif isinstance(node, list):
        for i, v in enumerate(node):
            key = v.get("name", i) if isinstance(v, dict) else i
            yield from numeric_leaves(v, f"{prefix}[{key}]")


def compare(name, base_doc, cur_doc, fail_above):
    """Print the delta rows of one bench; return the timing regressions."""
    base = dict(numeric_leaves(base_doc))
    cur = dict(numeric_leaves(cur_doc))
    regressions = []
    rows = []
    for path in sorted(base.keys() & cur.keys()):
        b, c = base[path], cur[path]
        timing = is_timing_leaf(path)
        if b == c:
            continue
        if b != 0:
            pct = 100.0 * (c - b) / b
            delta = f"{pct:+8.1f}%"
        else:
            pct = None
            delta = "     new"
        flag = ""
        if timing and pct is not None and pct > fail_above:
            flag = "  << regression"
            regressions.append((f"{name}:{path}", pct))
        rows.append((path, b, c, delta, flag))
    missing = sorted(base.keys() ^ cur.keys())
    print(f"\n{name}: {len(rows)} changed leaves, "
          f"{len(missing)} present on one side only")
    for path, b, c, delta, flag in rows:
        print(f"  {path:<60} {b:>14.6g} -> {c:>14.6g} {delta}{flag}")
    for path in missing:
        side = "baseline" if path in base else "current"
        print(f"  {path:<60} ({side} only)")
    return regressions


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", default="bench_results",
                    help="directory holding recorded BENCH_*.json baselines")
    ap.add_argument("--current", default="rust",
                    help="directory holding freshly-emitted BENCH_*.json")
    ap.add_argument("--fail-above", type=float, default=None, metavar="PCT",
                    help="exit 1 if any timing leaf slowed by more than "
                         "PCT percent (default: informational only)")
    args = ap.parse_args()

    base = find_docs(args.baseline)
    cur = find_docs(args.current)
    if not base:
        print(f"no recorded baselines under {args.baseline!r} — nothing to "
              "compare.\nTo record one: run the benches on the reference "
              "machine and copy the emitted\nBENCH_*.json files into "
              f"{args.baseline!r} (see bench_results/README.md).")
        return 0
    if not cur:
        print(f"no BENCH_*.json found under {args.current!r} — run the "
              "benches first.")
        return 0

    fail_above = args.fail_above if args.fail_above is not None else float("inf")
    regressions = []
    for name in sorted(base.keys() & cur.keys()):
        regressions += compare(name, base[name], cur[name], fail_above)
    for name in sorted(base.keys() ^ cur.keys()):
        side = "baseline" if name in base else "current"
        print(f"\n{name}: {side} only — not compared")

    if regressions:
        print(f"\n{len(regressions)} timing/quantile leaf(s) regressed beyond "
              f"{fail_above:.1f}%:")
        for path, pct in regressions:
            print(f"  {path}: {pct:+.1f}%")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
