//! **§III-A vs §III-B micro-benchmark**: table vs CIAS build time, lookup
//! latency and metadata footprint as the partition count m grows
//! 15 → 1M.
//!
//! Expected shape (the paper's complexity argument): table space grows
//! linearly in m and lookup ~log m; CIAS space and lookup stay flat (all
//! regular partitions collapse into the compressed index).
//!
//! Run: `cargo bench --bench index_micro`.

mod common;

use oseba::bench::{bench, table, BenchConfig};
use oseba::index::{Cias, ContentIndex, PartitionMeta, RangeQuery, TableIndex};
use oseba::util::humansize;
use oseba::util::rng::Xoshiro256;

/// Synthetic regular metadata for m partitions (no data needed: the index
/// operates on metadata only — that is the point).
fn metas(m: usize, rows_per: usize, step: i64) -> Vec<PartitionMeta> {
    (0..m)
        .map(|i| {
            let key_min = (i * rows_per) as i64 * step;
            PartitionMeta {
                id: i,
                key_min,
                key_max: key_min + (rows_per as i64 - 1) * step,
                rows: rows_per,
                step: Some(step),
            }
        })
        .collect()
}

fn main() {
    let cfg = BenchConfig::from_env();
    let rows_per = 4096;
    let step = 3600i64;
    let sizes = [15usize, 100, 1_000, 10_000, 100_000, 1_000_000];

    oseba::bench::section("index build + footprint vs partition count");
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "m", "table bytes", "cias bytes", "table build", "cias build", "asl"
    );
    for &m in &sizes {
        let ms = metas(m, rows_per, step);
        let t_build = {
            let ms = ms.clone();
            bench(&cfg, "t", move || {
                let _ = TableIndex::from_meta(ms.clone()).unwrap();
            })
        };
        let c_build = {
            let ms = ms.clone();
            bench(&cfg, "c", move || {
                let _ = Cias::from_meta(ms.clone()).unwrap();
            })
        };
        let t = TableIndex::from_meta(ms.clone()).unwrap();
        let c = Cias::from_meta(ms).unwrap();
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12} {:>8}",
            m,
            humansize::bytes(t.memory_bytes()),
            humansize::bytes(c.memory_bytes()),
            humansize::secs(t_build.summary.p50),
            humansize::secs(c_build.summary.p50),
            c.asl_len()
        );
        assert!(c.memory_bytes() <= 128, "cias stays O(1) on regular data");
    }

    oseba::bench::section("point-range lookup latency (1000 random queries/iter)");
    let mut results = Vec::new();
    for &m in &sizes {
        let ms = metas(m, rows_per, step);
        let span = (m * rows_per) as i64 * step;
        let t = TableIndex::from_meta(ms.clone()).unwrap();
        let c = Cias::from_meta(ms).unwrap();
        // Narrow queries: lookup cost, not output size, dominates.
        let queries: Vec<RangeQuery> = {
            let mut rng = Xoshiro256::seeded(m as u64);
            (0..1000)
                .map(|_| {
                    let lo = rng.below(span as u64) as i64;
                    RangeQuery { lo, hi: lo + step * 64 }
                })
                .collect()
        };
        let qs = queries.clone();
        results.push(bench(&cfg, &format!("table  m={m}"), move || {
            let mut acc = 0usize;
            for q in &qs {
                acc += t.lookup(*q).len();
            }
            std::hint::black_box(acc);
        }));
        let qs = queries.clone();
        results.push(bench(&cfg, &format!("cias   m={m}"), move || {
            let mut acc = 0usize;
            for q in &qs {
                acc += c.lookup(*q).len();
            }
            std::hint::black_box(acc);
        }));
    }
    println!("{}", table(&results));

    // Shape: cias lookup time must not grow with m (compare first vs last).
    let cias_first = results[1].summary.p50;
    let cias_last = results[results.len() - 1].summary.p50;
    println!(
        "cias p50 at m=15: {} | at m=1M: {} (flat-ness ratio {:.2})",
        humansize::secs(cias_first),
        humansize::secs(cias_last),
        cias_last / cias_first
    );
}
