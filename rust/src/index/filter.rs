//! Per-partition **membership filters** for point lookups: a
//! dependency-free growable cuckoo filter over the bit patterns of f32
//! values, built per partition × per value column at seal time and
//! consulted by the planner to prune partitions for equality predicates
//! (`where col == v`) *before* a cold partition is faulted in.
//!
//! Design (DESIGN.md §14):
//!
//! * **Partial-key bucketed fingerprints.** Each inserted value is
//!   canonicalized (`-0.0` folds into `+0.0`; NaN is skipped — an IEEE
//!   equality never matches NaN) and hashed to 64 bits. A short non-zero
//!   fingerprint of `fbits` bits lands in one of two buckets of
//!   [`SLOTS`] slots each; the alternate bucket is derived from the
//!   current bucket and the fingerprint alone (XOR of a fingerprint
//!   spread), so relocation never needs the original key.
//! * **Stashed-eviction insert.** A full bucket pair triggers the classic
//!   cuckoo eviction walk; a walk that exceeds [`MAX_KICKS`] parks the
//!   homeless fingerprint in a small stash instead of failing. The walk
//!   is journaled and rolled back if even the stash is full, so a failed
//!   insert never drops a previously stored member.
//! * **Size-aligned doubling growth.** [`FilterBuilder`] retains the
//!   64-bit hashes of the distinct members while the filter is mutable;
//!   when an insert fails it rebuilds the table at double the
//!   (power-of-two) bucket count and replays every member. Growth is a
//!   rebuild from exact hashes, so it preserves all prior members —
//!   the **never-false-negative** contract survives every growth step.
//!   `finish()` drops the hash journal and returns the compact,
//!   immutable filter that partitions and the store slot table carry.
//!
//! The filter is probabilistic in one direction only: `contains` may
//! return `true` for an absent value (a false positive costs one wasted
//! partition scan) but never returns `false` for a stored one (a false
//! negative would silently drop rows). The planner therefore treats
//! "no filter" and "filter says maybe" identically: always consider.

use crate::error::{OsebaError, Result};

/// Slots per bucket. Four is the classic cuckoo-filter arity: high load
/// factors (~0.95) before eviction walks start failing.
pub const SLOTS: usize = 4;

/// Maximum eviction-walk length before the homeless fingerprint is
/// stashed (or, stash full, the insert reports failure for growth).
const MAX_KICKS: usize = 128;

/// Stash capacity: a handful of overflow fingerprints checked linearly.
const STASH_MAX: usize = 8;

/// Default fingerprint width in bits. 12 bits ≈ 0.2% false-positive
/// bound at full load (`2 * SLOTS / 2^12`) for ~14 bits/key of table.
pub const DEFAULT_FBITS: u32 = 12;

/// Serialized codec version stamped into [`MembershipFilter::to_bytes`].
const CODEC_VERSION: u8 = 1;

/// Canonical bit pattern of a probe/insert value: `None` for NaN (an
/// equality predicate never matches NaN, so NaNs are not members), and
/// `-0.0` folded into `+0.0` (IEEE `-0.0 == 0.0`, but the bit patterns
/// differ — without folding, a `-0.0` probe against a stored `0.0`
/// would be a false negative).
fn canonical(x: f32) -> Option<u32> {
    if x.is_nan() {
        return None;
    }
    Some(if x == 0.0 { 0 } else { x.to_bits() })
}

/// SplitMix64 finalizer over the canonical bits: the one hash both
/// bucket indices and the fingerprint are carved from.
fn hash_bits(bits: u32) -> u64 {
    let mut z = (bits as u64).wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Spread a fingerprint over the bucket-index space (for the alternate
/// bucket derivation `i2 = i1 ^ spread(fp)`; XOR keeps it self-inverse).
fn fp_spread(fp: u32) -> usize {
    let mut z = (fp as u64).wrapping_mul(0xFF51_AFD7_ED55_8CCD);
    z ^= z >> 33;
    z as usize
}

/// An immutable, compact membership filter over f32 values — see the
/// module docs for the structure. Built via [`FilterBuilder`] (or the
/// [`MembershipFilter::build`] convenience) and serialized into store
/// manifest v4.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MembershipFilter {
    /// Fingerprint width in bits (4..=16).
    fbits: u32,
    /// Power-of-two bucket count.
    nbuckets: usize,
    /// Packed fingerprint slots: `nbuckets * SLOTS` fields of `fbits`
    /// bits each, little-endian within each u64 word. Zero = empty.
    words: Vec<u64>,
    /// Overflow fingerprints (membership checked linearly).
    stash: Vec<u32>,
    /// Number of distinct members stored.
    len: usize,
}

impl MembershipFilter {
    /// An empty filter with `nbuckets` buckets (rounded up to a power of
    /// two, at least 1) and `fbits`-bit fingerprints (clamped to 4..=16).
    fn empty(nbuckets: usize, fbits: u32) -> MembershipFilter {
        let fbits = fbits.clamp(4, 16);
        let nbuckets = nbuckets.max(1).next_power_of_two();
        let bits = nbuckets * SLOTS * fbits as usize;
        MembershipFilter {
            fbits,
            nbuckets,
            words: vec![0u64; bits.div_ceil(64)],
            stash: Vec::new(),
            len: 0,
        }
    }

    /// Build a filter over a value slice at the default fingerprint
    /// width: the seal-time entry point. NaNs are skipped; duplicates
    /// count once. Sized up front for the slice, so growth is rare.
    pub fn build(values: &[f32]) -> MembershipFilter {
        let mut b = FilterBuilder::with_capacity(values.len(), DEFAULT_FBITS);
        for &x in values {
            b.insert(x);
        }
        b.finish()
    }

    /// Whether `x` may be a member. `false` is definitive (never a false
    /// negative for an inserted value); `true` may be a false positive
    /// with probability ≲ [`MembershipFilter::fpr_bound`].
    pub fn contains(&self, x: f32) -> bool {
        match canonical(x) {
            Some(bits) => self.contains_hash(hash_bits(bits)),
            // NaN is never inserted and `v == NaN` matches no row.
            None => false,
        }
    }

    fn contains_hash(&self, h: u64) -> bool {
        let fp = self.fingerprint(h);
        let i1 = (h as usize) & self.mask();
        let i2 = self.alt(i1, fp);
        self.bucket_has(i1, fp) || self.bucket_has(i2, fp) || self.stash.contains(&fp)
    }

    /// Number of distinct members.
    pub fn len(&self) -> usize {
        self.len
    }

    /// Whether the filter holds no members (then `contains` is always
    /// `false` — e.g. an all-NaN column).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Configured fingerprint width in bits.
    pub fn fbits(&self) -> u32 {
        self.fbits
    }

    /// The configured false-positive bound at full load:
    /// `2 * SLOTS / 2^fbits` (two buckets of [`SLOTS`] candidate
    /// fingerprints each). The measured rate sits below this; the
    /// property battery asserts `measured ≤ 2 × bound`.
    pub fn fpr_bound(&self) -> f64 {
        (2 * SLOTS) as f64 / (1u64 << self.fbits) as f64
    }

    /// Resident footprint in bytes (table + stash + header), the cost
    /// surfaced as `filter_bytes` in plan explains.
    pub fn memory_bytes(&self) -> usize {
        self.words.len() * 8 + self.stash.len() * 4 + 24
    }

    fn mask(&self) -> usize {
        self.nbuckets - 1
    }

    /// Non-zero fingerprint of `fbits` bits carved from the hash's upper
    /// half (the lower half feeds the bucket index).
    fn fingerprint(&self, h: u64) -> u32 {
        let m = (1u32 << self.fbits) - 1;
        ((h >> 32) as u32 % m) + 1
    }

    fn alt(&self, i: usize, fp: u32) -> usize {
        (i ^ fp_spread(fp)) & self.mask()
    }

    fn slot_get(&self, s: usize) -> u32 {
        let fbits = self.fbits as usize;
        let bit = s * fbits;
        let (w, off) = (bit / 64, bit % 64);
        let mask = (1u64 << fbits) - 1;
        let mut v = self.words[w] >> off;
        if off + fbits > 64 {
            v |= self.words[w + 1] << (64 - off);
        }
        (v & mask) as u32
    }

    fn slot_set(&mut self, s: usize, fp: u32) {
        let fbits = self.fbits as usize;
        let bit = s * fbits;
        let (w, off) = (bit / 64, bit % 64);
        let mask = (1u64 << fbits) - 1;
        self.words[w] &= !(mask << off);
        self.words[w] |= (fp as u64) << off;
        if off + fbits > 64 {
            let hi = off + fbits - 64;
            self.words[w + 1] &= !((1u64 << hi) - 1);
            self.words[w + 1] |= (fp as u64) >> (fbits - hi);
        }
    }

    fn bucket_has(&self, i: usize, fp: u32) -> bool {
        (0..SLOTS).any(|s| self.slot_get(i * SLOTS + s) == fp)
    }

    /// Place `fp` in an empty slot of bucket `i`; false if full.
    fn bucket_place(&mut self, i: usize, fp: u32) -> bool {
        for s in 0..SLOTS {
            if self.slot_get(i * SLOTS + s) == 0 {
                self.slot_set(i * SLOTS + s, fp);
                return true;
            }
        }
        false
    }

    /// Insert by hash. Returns `false` when the table needs growth — in
    /// that case the eviction walk has been rolled back, so the filter
    /// still holds exactly its prior members.
    fn try_insert_hash(&mut self, h: u64) -> bool {
        let fp0 = self.fingerprint(h);
        let i1 = (h as usize) & self.mask();
        let i2 = self.alt(i1, fp0);
        if self.bucket_place(i1, fp0) || self.bucket_place(i2, fp0) {
            self.len += 1;
            return true;
        }
        // Eviction walk, journaled for rollback.
        let mut i = if h & (1 << 63) != 0 { i1 } else { i2 };
        let mut fp = fp0;
        let mut rot = h | 1;
        let mut journal: Vec<(usize, u32)> = Vec::with_capacity(MAX_KICKS);
        for _ in 0..MAX_KICKS {
            rot = rot.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            let s = i * SLOTS + (rot >> 61) as usize % SLOTS;
            let old = self.slot_get(s);
            journal.push((s, old));
            self.slot_set(s, fp);
            fp = old;
            i = self.alt(i, fp);
            if self.bucket_place(i, fp) {
                self.len += 1;
                return true;
            }
        }
        if self.stash.len() < STASH_MAX {
            // The homeless fingerprint (an evicted prior member) parks in
            // the stash; the new member sits in the table. No loss.
            self.stash.push(fp);
            self.len += 1;
            return true;
        }
        // Roll the walk back (reverse order restores the original slots)
        // and ask the builder to grow.
        for &(s, old) in journal.iter().rev() {
            self.slot_set(s, old);
        }
        false
    }

    /// Serialize to the byte layout persisted (hex-encoded, CRC-wrapped)
    /// in store manifest v4. Little-endian throughout; round-trips
    /// bit-exactly through [`MembershipFilter::from_bytes`].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(16 + self.words.len() * 8 + self.stash.len() * 4);
        out.push(CODEC_VERSION);
        out.push(self.fbits as u8);
        out.extend_from_slice(&(self.stash.len() as u16).to_le_bytes());
        out.extend_from_slice(&(self.nbuckets as u32).to_le_bytes());
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        for w in &self.words {
            out.extend_from_slice(&w.to_le_bytes());
        }
        for fp in &self.stash {
            out.extend_from_slice(&fp.to_le_bytes());
        }
        out
    }

    /// Decode a filter serialized by [`MembershipFilter::to_bytes`],
    /// validating the header, the exact byte length, and every stash
    /// fingerprint. Truncated or tampered bytes are a hard
    /// [`OsebaError::Store`].
    pub fn from_bytes(bytes: &[u8]) -> Result<MembershipFilter> {
        let fail = |why: &str| OsebaError::Store(format!("membership filter: {why}"));
        if bytes.len() < 16 {
            return Err(fail(&format!("truncated header ({} bytes)", bytes.len())));
        }
        if bytes[0] != CODEC_VERSION {
            return Err(fail(&format!("unknown codec version {}", bytes[0])));
        }
        let fbits = bytes[1] as u32;
        if !(4..=16).contains(&fbits) {
            return Err(fail(&format!("fingerprint width {fbits} out of range")));
        }
        let stash_len = u16::from_le_bytes([bytes[2], bytes[3]]) as usize;
        if stash_len > STASH_MAX {
            return Err(fail(&format!("stash length {stash_len} exceeds capacity")));
        }
        let nbuckets = u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]) as usize;
        if nbuckets == 0 || !nbuckets.is_power_of_two() {
            return Err(fail(&format!("bucket count {nbuckets} not a power of two")));
        }
        let len = u64::from_le_bytes([
            bytes[8], bytes[9], bytes[10], bytes[11], bytes[12], bytes[13], bytes[14], bytes[15],
        ]) as usize;
        let nwords = (nbuckets * SLOTS * fbits as usize).div_ceil(64);
        let want = 16 + nwords * 8 + stash_len * 4;
        if bytes.len() != want {
            return Err(fail(&format!("length {} != expected {want}", bytes.len())));
        }
        let mut words = Vec::with_capacity(nwords);
        for i in 0..nwords {
            let at = 16 + i * 8;
            let mut w = [0u8; 8];
            w.copy_from_slice(&bytes[at..at + 8]);
            words.push(u64::from_le_bytes(w));
        }
        let mut stash = Vec::with_capacity(stash_len);
        let base = 16 + nwords * 8;
        for i in 0..stash_len {
            let at = base + i * 4;
            let fp = u32::from_le_bytes([bytes[at], bytes[at + 1], bytes[at + 2], bytes[at + 3]]);
            if fp == 0 || fp >= (1 << fbits) {
                return Err(fail(&format!("stash fingerprint {fp} out of range")));
            }
            stash.push(fp);
        }
        Ok(MembershipFilter { fbits, nbuckets, words, stash, len })
    }
}

/// Incremental construction of a [`MembershipFilter`] with exact
/// doubling growth: retains the distinct member hashes while mutable so
/// a rebuild at double size replays every member (see module docs).
#[derive(Clone, Debug)]
pub struct FilterBuilder {
    filter: MembershipFilter,
    /// Distinct member hashes, in insertion order — the growth journal.
    hashes: Vec<u64>,
    seen: std::collections::HashSet<u64>,
    growths: usize,
}

impl FilterBuilder {
    /// A builder pre-sized for `capacity` members at `fbits`-bit
    /// fingerprints (target load ~0.84 over 4-slot buckets).
    pub fn with_capacity(capacity: usize, fbits: u32) -> FilterBuilder {
        let nbuckets = (capacity.max(1)).div_ceil(SLOTS * 84 / 100).max(1);
        FilterBuilder {
            filter: MembershipFilter::empty(nbuckets, fbits),
            hashes: Vec::new(),
            seen: std::collections::HashSet::new(),
            growths: 0,
        }
    }

    /// A small builder (growth exercises start immediately) — test and
    /// bench entry point.
    pub fn new(fbits: u32) -> FilterBuilder {
        FilterBuilder::with_capacity(SLOTS * 4, fbits)
    }

    /// Insert one value. NaN is a no-op; duplicates count once; a full
    /// table doubles (rebuilding from the exact member hashes) until the
    /// insert lands.
    pub fn insert(&mut self, x: f32) {
        let Some(bits) = canonical(x) else { return };
        let h = hash_bits(bits);
        if !self.seen.insert(h) {
            return;
        }
        self.hashes.push(h);
        while !self.filter.try_insert_hash(h) {
            self.grow();
        }
    }

    /// Rebuild at the next power-of-two size that fits every member.
    fn grow(&mut self) {
        let mut nbuckets = self.filter.nbuckets * 2;
        'outer: loop {
            let mut f = MembershipFilter::empty(nbuckets, self.filter.fbits);
            for &h in &self.hashes[..self.hashes.len() - 1] {
                if !f.try_insert_hash(h) {
                    nbuckets *= 2;
                    continue 'outer;
                }
            }
            self.filter = f;
            self.growths += 1;
            return;
        }
    }

    /// How many doubling rebuilds have happened (test/bench telemetry).
    pub fn growths(&self) -> usize {
        self.growths
    }

    /// A read view of the filter as built so far (members inserted up to
    /// now are all queryable — growth preserved them).
    pub fn filter(&self) -> &MembershipFilter {
        &self.filter
    }

    /// Drop the growth journal and return the immutable filter.
    pub fn finish(self) -> MembershipFilter {
        self.filter
    }
}

/// Build one filter per value column over a partition's valid rows —
/// the seal-time companion to [`crate::index::sketches_of`]. `columns`
/// may be padded past `rows`; padding is excluded.
pub fn filters_of(columns: &[Vec<f32>], rows: usize) -> Vec<MembershipFilter> {
    columns.iter().map(|c| MembershipFilter::build(&c[..rows.min(c.len())])).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    /// Random finite f32 from raw bits (NaN patterns redrawn).
    fn random_finite(rng: &mut Xoshiro256) -> f32 {
        loop {
            let x = f32::from_bits(rng.next_u64() as u32);
            if !x.is_nan() {
                return x;
            }
        }
    }

    #[test]
    fn no_false_negatives_after_seeded_fuzz_inserts() {
        let mut rng = Xoshiro256::seeded(0xF11E);
        let mut b = FilterBuilder::new(DEFAULT_FBITS);
        let values: Vec<f32> = (0..20_000).map(|_| random_finite(&mut rng)).collect();
        for (i, &x) in values.iter().enumerate() {
            b.insert(x);
            // Spot-check mid-build so the growth steps are covered too.
            if i % 977 == 0 {
                assert!(b.filter().contains(x), "member {x} lost at step {i}");
            }
        }
        assert!(b.growths() > 0, "small initial table must grow under 20k inserts");
        let f = b.finish();
        for &x in &values {
            assert!(f.contains(x), "false negative for inserted value {x}");
        }
    }

    #[test]
    fn growth_preserves_all_prior_members() {
        let mut b = FilterBuilder::new(8);
        let mut grown_at = Vec::new();
        for i in 0..4_000 {
            b.insert(i as f32);
            if b.growths() > grown_at.len() {
                grown_at.push(i);
                // Immediately after a doubling rebuild, every member
                // inserted so far must still be present.
                for j in 0..=i {
                    assert!(b.filter().contains(j as f32), "lost {j} at growth after {i}");
                }
            }
        }
        assert!(grown_at.len() >= 2, "expected multiple growth steps, got {grown_at:?}");
        assert_eq!(b.filter().len(), 4_000);
    }

    #[test]
    fn measured_fpr_within_twice_configured_bound_at_each_growth_step() {
        let fbits = 8;
        let mut b = FilterBuilder::new(fbits);
        let probes = 50_000usize;
        let mut checked_steps = 0usize;
        let mut last_growths = 0usize;
        let measure = |f: &MembershipFilter| {
            // Probe values disjoint from the inserted range.
            let hits = (0..probes).filter(|&i| f.contains(1.0e9 + i as f32)).count();
            hits as f64 / probes as f64
        };
        for i in 0..30_000 {
            b.insert(i as f32);
            if b.growths() > last_growths {
                last_growths = b.growths();
                let fpr = measure(b.filter());
                let bound = b.filter().fpr_bound();
                assert!(
                    fpr <= 2.0 * bound,
                    "after growth {last_growths}: measured fpr {fpr} > 2 × bound {bound}"
                );
                checked_steps += 1;
            }
        }
        assert!(checked_steps >= 3, "growth steps checked: {checked_steps}");
        // And at the final (highest-load) state.
        let f = b.finish();
        let fpr = measure(&f);
        assert!(fpr <= 2.0 * f.fpr_bound(), "final fpr {fpr} > 2 × {}", f.fpr_bound());
        assert!(fpr > 0.0, "50k probes at 8-bit fingerprints must see some false positive");
    }

    #[test]
    fn serialize_deserialize_round_trips_bit_exactly() {
        let mut rng = Xoshiro256::seeded(0x5EDE);
        let mut b = FilterBuilder::new(DEFAULT_FBITS);
        for _ in 0..5_000 {
            b.insert(random_finite(&mut rng));
        }
        let f = b.finish();
        let bytes = f.to_bytes();
        let g = MembershipFilter::from_bytes(&bytes).expect("round trip");
        assert_eq!(f, g, "decoded filter differs structurally");
        assert_eq!(bytes, g.to_bytes(), "re-encoded bytes differ");
        // An empty filter round-trips too.
        let e = MembershipFilter::build(&[]);
        assert_eq!(e, MembershipFilter::from_bytes(&e.to_bytes()).expect("empty"));
    }

    #[test]
    fn tampered_bytes_are_rejected() {
        let f = MembershipFilter::build(&[1.0, 2.0, 3.0]);
        let bytes = f.to_bytes();
        // Truncation at every boundary shorter than the full payload.
        for cut in [0, 1, 8, 15, bytes.len() - 1] {
            assert!(
                MembershipFilter::from_bytes(&bytes[..cut]).is_err(),
                "truncation to {cut} bytes must fail"
            );
        }
        // Bad codec version / fingerprint width / bucket count.
        let mut bad = bytes.clone();
        bad[0] = 9;
        assert!(MembershipFilter::from_bytes(&bad).is_err());
        let mut bad = bytes.clone();
        bad[1] = 33;
        assert!(MembershipFilter::from_bytes(&bad).is_err());
        let mut bad = bytes.clone();
        bad[4..8].copy_from_slice(&3u32.to_le_bytes());
        assert!(MembershipFilter::from_bytes(&bad).is_err());
        // Oversized stash length claims more bytes than present.
        let mut bad = bytes;
        bad[2..4].copy_from_slice(&2u16.to_le_bytes());
        assert!(MembershipFilter::from_bytes(&bad).is_err());
    }

    #[test]
    fn negative_zero_folds_into_positive_zero() {
        let f = MembershipFilter::build(&[0.0]);
        assert!(f.contains(-0.0), "-0.0 == 0.0 must not be a false negative");
        let g = MembershipFilter::build(&[-0.0]);
        assert!(g.contains(0.0));
        assert_eq!(g.len(), 1);
    }

    #[test]
    fn nan_is_never_a_member() {
        let f = MembershipFilter::build(&[f32::NAN, f32::NAN, 5.0]);
        assert_eq!(f.len(), 1, "NaNs are skipped at build");
        assert!(!f.contains(f32::NAN), "v == NaN matches no row");
        assert!(f.contains(5.0));
        let all_nan = MembershipFilter::build(&[f32::NAN; 16]);
        assert!(all_nan.is_empty());
        assert!(!all_nan.contains(0.0));
    }

    #[test]
    fn duplicates_count_once_and_do_not_force_growth() {
        let mut b = FilterBuilder::new(DEFAULT_FBITS);
        for _ in 0..10_000 {
            b.insert(42.5);
        }
        assert_eq!(b.filter().len(), 1);
        assert_eq!(b.growths(), 0, "duplicate inserts must not grow the table");
        assert!(b.finish().contains(42.5));
    }

    #[test]
    fn empty_filter_contains_nothing() {
        let f = MembershipFilter::build(&[]);
        assert!(f.is_empty());
        for x in [0.0f32, -1.5, 3.25e7, f32::INFINITY, f32::NEG_INFINITY] {
            assert!(!f.contains(x), "{x}");
        }
        assert!(f.memory_bytes() > 0);
    }

    #[test]
    fn distinct_members_and_absent_probes_on_exact_values() {
        // Exact probes on stored values (the "quantized to bit pattern"
        // contract) — including infinities and denormals.
        let values = [1.0f32, -1.0, f32::INFINITY, f32::NEG_INFINITY, f32::MIN_POSITIVE, 1.0e-40];
        let f = MembershipFilter::build(&values);
        assert_eq!(f.len(), values.len());
        for &x in &values {
            assert!(f.contains(x), "{x}");
        }
        // A value differing by one ULP is a different member.
        let near = f32::from_bits(1.0f32.to_bits() + 1);
        // (May be a false positive, but with 12-bit fingerprints over 6
        // members the chance is ~2^-9 — deterministic here by seed-free
        // construction; assert only the never-false-negative direction.)
        let _ = f.contains(near);
    }

    #[test]
    fn filters_of_covers_every_column_excluding_padding() {
        let cols = vec![vec![1.0, 2.0, 99.0, 99.0], vec![7.0, f32::NAN, 99.0, 99.0]];
        let fs = filters_of(&cols, 2);
        assert_eq!(fs.len(), 2);
        assert!(fs[0].contains(1.0) && fs[0].contains(2.0));
        assert!(!fs[0].is_empty());
        assert_eq!(fs[1].len(), 1, "NaN skipped, padding excluded");
        assert!(fs[1].contains(7.0));
    }
}
