//! `OsebaContext` — the driver-side engine context (SparkContext analogue).
//!
//! Owns the block manager, the scan thread pool, dataset ids and lineage.
//! Provides the two competing access paths the paper compares:
//!
//! * [`OsebaContext::filter_range`] — the **default/baseline** path: scan
//!   *every* partition, materialize the selected rows as a new cached
//!   dataset (compute + memory cost grows per query, Fig 4/6 "without
//!   Oseba");
//! * [`OsebaContext::select_slices`] — the **Oseba** path: given index
//!   lookup results, return zero-copy views into the original partitions
//!   (no scan of non-target partitions, no materialization).

use std::path::Path;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::ContextConfig;
use crate::engine::block_manager::{BlockManager, DatasetId};
use crate::engine::dataset::{Dataset, Lineage, PinnedSlice, PinnedSlices};
use crate::engine::live::LiveDataset;
use crate::engine::memory::MemoryTracker;
use crate::error::{OsebaError, Result};
use crate::index::types::{PartitionSlice, RangeQuery};
use crate::index::Cias;
use crate::metrics::MetricsRegistry;
use crate::storage::{partition_batch_uniform, Partition, RecordBatch};
use crate::store::TieredStore;
use crate::util::sync::MutexExt;
use crate::util::threadpool::ThreadPool;

/// Per-context scan/materialization counters — the computation-cost signal
/// Fig 6 aggregates.
#[derive(Debug, Default)]
pub struct EngineCounters {
    /// Partitions whose keys were scanned by filter operations.
    pub partitions_scanned: AtomicUsize,
    /// Rows examined by filter scans.
    pub rows_scanned: AtomicUsize,
    /// Bytes materialized into new (filtered) datasets.
    pub bytes_materialized: AtomicUsize,
    /// Partitions touched via the indexed (Oseba) path.
    pub partitions_targeted: AtomicUsize,
    /// Targeted partitions answered from their aggregate sketches —
    /// counted in `partitions_targeted` too, but with zero data touch.
    pub partitions_agg_answered: AtomicUsize,
    /// Kernel blocks answered by merging their retained seal-time
    /// partials (block-sketch hierarchy) — zero data touch per block.
    pub blocks_covered: AtomicUsize,
    /// Kernel blocks skipped because their block-level zone cannot
    /// satisfy the query's predicate conjunction.
    pub blocks_pruned: AtomicUsize,
    /// Server request handlers that died by panic and were caught at the
    /// session boundary (the connection survives; the request errors).
    pub sessions_failed: AtomicUsize,
    /// Selection slices served degraded: their partition was quarantined
    /// (or failed verification mid-query) and was dropped from the answer
    /// instead of failing it (DESIGN.md §16).
    pub degraded_answers: AtomicUsize,
}

impl EngineCounters {
    /// Point-in-time copy of the counters.
    pub fn snapshot(&self) -> CounterSnapshot {
        CounterSnapshot {
            partitions_scanned: self.partitions_scanned.load(Ordering::Relaxed),
            rows_scanned: self.rows_scanned.load(Ordering::Relaxed),
            bytes_materialized: self.bytes_materialized.load(Ordering::Relaxed),
            partitions_targeted: self.partitions_targeted.load(Ordering::Relaxed),
            partitions_agg_answered: self.partitions_agg_answered.load(Ordering::Relaxed),
            blocks_covered: self.blocks_covered.load(Ordering::Relaxed),
            blocks_pruned: self.blocks_pruned.load(Ordering::Relaxed),
            sessions_failed: self.sessions_failed.load(Ordering::Relaxed),
            degraded_answers: self.degraded_answers.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time copy of [`EngineCounters`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CounterSnapshot {
    /// Partitions whose keys were scanned by filter operations.
    pub partitions_scanned: usize,
    /// Rows examined by filter scans.
    pub rows_scanned: usize,
    /// Bytes materialized into new (filtered) datasets.
    pub bytes_materialized: usize,
    /// Partitions touched via the indexed (Oseba) path.
    pub partitions_targeted: usize,
    /// Targeted partitions answered from their aggregate sketches
    /// (a subset of `partitions_targeted`; zero data touch).
    pub partitions_agg_answered: usize,
    /// Kernel blocks answered from retained block-sketch partials.
    pub blocks_covered: usize,
    /// Kernel blocks skipped by block-level predicate pruning.
    pub blocks_pruned: usize,
    /// Server request handlers caught panicking at the session boundary.
    pub sessions_failed: usize,
    /// Selection slices served degraded around quarantined partitions.
    pub degraded_answers: usize,
}

/// The engine context.
pub struct OsebaContext {
    block_manager: Arc<BlockManager>,
    pool: ThreadPool,
    next_id: AtomicU64,
    lineage: Mutex<Vec<(DatasetId, String, Lineage)>>,
    counters: EngineCounters,
    metrics: MetricsRegistry,
}

impl OsebaContext {
    /// Build a context from engine-level configuration.
    pub fn new(cfg: ContextConfig) -> OsebaContext {
        let tracker = match cfg.memory_budget {
            Some(b) => MemoryTracker::with_budget(b),
            None => MemoryTracker::unbounded(),
        };
        OsebaContext {
            block_manager: Arc::new(BlockManager::new(tracker)),
            pool: ThreadPool::new(cfg.num_workers),
            next_id: AtomicU64::new(1),
            lineage: Mutex::new(Vec::new()),
            counters: EngineCounters::default(),
            metrics: MetricsRegistry::new(),
        }
    }

    fn fresh_id(&self) -> DatasetId {
        self.next_id.fetch_add(1, Ordering::SeqCst)
    }

    fn register(&self, id: DatasetId, name: &str, lineage: &Lineage) {
        self.lineage.lock_recover().push((id, name.to_string(), lineage.clone()));
    }

    /// Load a batch into memory as a uniformly-partitioned, cached dataset
    /// (the paper's "load/reside the data into memory" step).
    pub fn load(&self, batch: RecordBatch, num_partitions: usize) -> Result<Dataset> {
        if num_partitions == 0 {
            return Err(OsebaError::Schema("num_partitions must be > 0".into()));
        }
        if batch.rows() == 0 {
            // Without this check an empty batch computes `rows_per == 0`
            // and surfaces as a misleading "rows_per_partition must be > 0"
            // from partition_batch_uniform.
            return Err(OsebaError::Schema("cannot load empty batch".into()));
        }
        let rows_per = batch.rows().div_ceil(num_partitions);
        let parts = partition_batch_uniform(&batch, rows_per)?;
        self.adopt(batch.schema.clone(), parts, Lineage::Source { name: "load".into() })
    }

    /// Register externally-built partitions as a cached dataset.
    pub fn adopt(
        &self,
        schema: crate::storage::Schema,
        parts: Vec<Arc<Partition>>,
        lineage: Lineage,
    ) -> Result<Dataset> {
        let id = self.fresh_id();
        self.block_manager.cache(id, parts.clone())?;
        let name = match &lineage {
            Lineage::Source { name } => name.clone(),
            Lineage::Derived { op, .. } => op.clone(),
        };
        self.register(id, &name, &lineage);
        Ok(Dataset { id, schema, parts, lineage, store: None, visible: None })
    }

    /// Load a batch as a **tiered** dataset: partitions live in a
    /// [`TieredStore`] rooted at `dir` and spill to `.oseg` segments under
    /// memory pressure instead of failing the load. This is how datasets
    /// larger than the memory budget come in.
    pub fn load_tiered(
        &self,
        batch: RecordBatch,
        num_partitions: usize,
        dir: impl AsRef<Path>,
    ) -> Result<Dataset> {
        if num_partitions == 0 {
            return Err(OsebaError::Schema("num_partitions must be > 0".into()));
        }
        if batch.rows() == 0 {
            return Err(OsebaError::Schema("cannot load empty batch".into()));
        }
        let rows_per = batch.rows().div_ceil(num_partitions);
        let parts = partition_batch_uniform(&batch, rows_per)?;
        let store = Arc::new(TieredStore::create(
            dir,
            batch.schema.clone(),
            self.block_manager.tracker(),
        )?);
        for p in parts {
            if let Err(e) = store.insert(p) {
                // The store is not registered yet, so nothing else will
                // ever release the bytes its Hot partitions charged.
                store.release_resident();
                return Err(e);
            }
        }
        self.adopt_tiered(
            batch.schema.clone(),
            store,
            Lineage::Source { name: "load_tiered".into() },
        )
    }

    /// Register an externally-built tiered store as a dataset.
    pub fn adopt_tiered(
        &self,
        schema: crate::storage::Schema,
        store: Arc<TieredStore>,
        lineage: Lineage,
    ) -> Result<Dataset> {
        let id = self.fresh_id();
        self.block_manager.register_store(id, Arc::clone(&store))?;
        let name = match &lineage {
            Lineage::Source { name } => name.clone(),
            Lineage::Derived { op, .. } => op.clone(),
        };
        self.register(id, &name, &lineage);
        Ok(Dataset {
            id,
            schema,
            parts: Vec::new(),
            lineage,
            store: Some(store),
            visible: None,
        })
    }

    /// Open a saved store directory as a tiered dataset, restoring the
    /// super index from the manifest snapshot — O(index), no segment data
    /// is read until a query faults partitions in.
    pub fn open_tiered(&self, dir: impl AsRef<Path>) -> Result<(Dataset, Cias)> {
        let (store, index) =
            TieredStore::open(dir, self.block_manager.tracker())?;
        let store = Arc::new(store);
        let schema = store.schema().clone();
        let ds = self.adopt_tiered(
            schema,
            store,
            Lineage::Source { name: "open".into() },
        )?;
        Ok((ds, index))
    }

    /// Create a **live** (append-while-serving) dataset: writers stream
    /// chunks in via [`LiveDataset::append`] while readers pin epochs via
    /// [`LiveDataset::snapshot`]. Sealed partitions stay memory-resident;
    /// unsealed chunk bytes are charged to the block manager.
    pub fn create_live(
        &self,
        schema: crate::storage::Schema,
        cfg: crate::engine::live::LiveConfig,
    ) -> Result<Arc<LiveDataset>> {
        let id = self.fresh_id();
        let lineage = Lineage::Source { name: "live".into() };
        self.register(id, "live", &lineage);
        Ok(Arc::new(LiveDataset::new(
            id,
            schema,
            cfg,
            Arc::clone(&self.block_manager),
            None,
        )?))
    }

    /// [`Self::create_live`], but sealed partitions go to a
    /// [`TieredStore`] rooted at `dir`: under memory pressure cold sealed
    /// partitions spill to `.oseg` segments instead of the append failing,
    /// so a live feed larger than the budget keeps ingesting. The store is
    /// registered with the block manager, so unrelated cache pressure can
    /// reclaim from it too. Spilling live datasets reject out-of-order
    /// appends (segment ids pin partition order).
    pub fn create_live_spilling(
        &self,
        schema: crate::storage::Schema,
        cfg: crate::engine::live::LiveConfig,
        dir: impl AsRef<Path>,
    ) -> Result<Arc<LiveDataset>> {
        let store = Arc::new(TieredStore::create(
            dir,
            schema.clone(),
            self.block_manager.tracker(),
        )?);
        let id = self.fresh_id();
        self.block_manager.register_store(id, Arc::clone(&store))?;
        let lineage = Lineage::Source { name: "live".into() };
        self.register(id, "live", &lineage);
        Ok(Arc::new(LiveDataset::new(
            id,
            schema,
            cfg,
            Arc::clone(&self.block_manager),
            Some(store),
        )?))
    }

    /// Handles to every partition of `ds`, faulting in the full dataset
    /// when tiered — the deliberate *full reload* the scan-everything
    /// baseline pays (the tiered bench's comparison arm).
    ///
    /// Budget semantics: the tracker accounts *storage* residency (what
    /// the store keeps Hot). Handles returned here — like the pins from
    /// [`Self::resolve_slices`] — are the caller's transient working set
    /// (Spark's "execution memory") and stay alive outside that budget
    /// until dropped, even if the store evicts the slot meanwhile. A full
    /// scan of an over-budget dataset therefore still materializes the
    /// whole dataset in process memory — exactly the baseline cost the
    /// selective path avoids.
    pub fn partition_handles(&self, ds: &Dataset) -> Result<Vec<Arc<Partition>>> {
        match ds.store() {
            // `ds.num_partitions()` (not the store's count) so a live
            // snapshot's scan stays pinned to its epoch even while the
            // shared store grows.
            Some(store) => (0..ds.num_partitions()).map(|i| store.fetch(i)).collect(),
            None => Ok(ds.parts.clone()),
        }
    }

    /// **Baseline path.** Scan all partitions of `ds` and materialize the
    /// rows with key in `q` as a new cached dataset. Cost: every partition
    /// is scanned (compute), and the selection is copied + cached (memory)
    /// — exactly Spark's `filter` + default residency.
    pub fn filter_range(&self, ds: &Dataset, q: RangeQuery) -> Result<Dataset> {
        let handles = self.partition_handles(ds)?;
        let num_parts = handles.len();
        let tasks: Vec<_> = handles
            .into_iter()
            .map(|p| move || filter_partition(&p, q))
            .collect();
        let filtered = self.pool.scope_execute(tasks);

        let mut scanned_rows = 0usize;
        let mut new_parts: Vec<Arc<Partition>> = Vec::new();
        for (keys, cols, rows_examined) in filtered {
            scanned_rows += rows_examined;
            if !keys.is_empty() {
                let id = new_parts.len();
                new_parts.push(Arc::new(Partition::from_rows(id, keys, cols)));
            }
        }
        self.counters.partitions_scanned.fetch_add(num_parts, Ordering::Relaxed);
        self.counters.rows_scanned.fetch_add(scanned_rows, Ordering::Relaxed);

        if new_parts.is_empty() {
            // Preserve Spark semantics: an empty filter result is still a
            // dataset (with a single empty partition for schema fidelity).
            new_parts.push(Arc::new(Partition::from_rows(
                0,
                Vec::new(),
                vec![Vec::new(); ds.schema.width()],
            )));
        }
        let bytes: usize = new_parts.iter().map(|p| p.bytes()).sum();
        self.counters.bytes_materialized.fetch_add(bytes, Ordering::Relaxed);

        self.adopt(
            ds.schema.clone(),
            new_parts,
            Lineage::Derived { parent: ds.id, op: format!("filter[{}..={}]", q.lo, q.hi) },
        )
    }

    /// **Index-targeted filter.** Materialize the rows with key in `q` as
    /// a new cached dataset, but resolve the selection through the super
    /// index instead of scanning: only the targeted partitions are read
    /// (and, when tiered, faulted in) — the plan-layer variant of
    /// [`Self::filter_range`]. The scan baseline above is kept unchanged
    /// as the benches' comparison arm.
    pub fn filter_range_indexed(
        &self,
        ds: &Dataset,
        index: &dyn crate::index::ContentIndex,
        q: RangeQuery,
    ) -> Result<Dataset> {
        let owned = self.resolve_slices(ds, &index.lookup(q), q)?;
        let mut new_parts: Vec<Arc<Partition>> = Vec::new();
        for (part, s) in owned {
            let keys = part.keys[s.row_start..s.row_end].to_vec();
            let cols = part
                .columns
                .iter()
                .map(|c| c[s.row_start..s.row_end].to_vec())
                .collect();
            let id = new_parts.len();
            new_parts.push(Arc::new(Partition::from_rows(id, keys, cols)));
        }
        if new_parts.is_empty() {
            new_parts.push(Arc::new(Partition::from_rows(
                0,
                Vec::new(),
                vec![Vec::new(); ds.schema.width()],
            )));
        }
        let bytes: usize = new_parts.iter().map(|p| p.bytes()).sum();
        self.counters.bytes_materialized.fetch_add(bytes, Ordering::Relaxed);
        self.adopt(
            ds.schema.clone(),
            new_parts,
            Lineage::Derived {
                parent: ds.id,
                op: format!("filter_indexed[{}..={}]", q.lo, q.hi),
            },
        )
    }

    /// Generic predicate filter over `(key, row_values)` — the fully
    /// general Spark baseline (always scans everything; used by tests and
    /// the events example for non-range predicates).
    pub fn filter<F>(&self, ds: &Dataset, op_name: &str, pred: F) -> Result<Dataset>
    where
        F: Fn(i64, &[f32]) -> bool + Send + Sync + 'static,
    {
        let pred = Arc::new(pred);
        let width = ds.schema.width();
        let handles = self.partition_handles(ds)?;
        let num_parts = handles.len();
        let tasks: Vec<_> = handles
            .into_iter()
            .map(|p| {
                let pred = Arc::clone(&pred);
                move || {
                    let mut keys = Vec::new();
                    let mut cols = vec![Vec::new(); width];
                    let mut row = vec![0f32; width];
                    for r in 0..p.rows {
                        for (c, slot) in row.iter_mut().enumerate() {
                            *slot = p.columns[c][r];
                        }
                        if pred(p.keys[r], &row) {
                            keys.push(p.keys[r]);
                            for (c, col) in cols.iter_mut().enumerate() {
                                col.push(row[c]);
                            }
                        }
                    }
                    (keys, cols, p.rows)
                }
            })
            .collect();
        let filtered = self.pool.scope_execute(tasks);

        let mut new_parts: Vec<Arc<Partition>> = Vec::new();
        let mut scanned = 0usize;
        for (keys, cols, rows) in filtered {
            scanned += rows;
            if !keys.is_empty() {
                let id = new_parts.len();
                new_parts.push(Arc::new(Partition::from_rows(id, keys, cols)));
            }
        }
        self.counters.partitions_scanned.fetch_add(num_parts, Ordering::Relaxed);
        self.counters.rows_scanned.fetch_add(scanned, Ordering::Relaxed);
        if new_parts.is_empty() {
            new_parts.push(Arc::new(Partition::from_rows(
                0,
                Vec::new(),
                vec![Vec::new(); width],
            )));
        }
        let bytes: usize = new_parts.iter().map(|p| p.bytes()).sum();
        self.counters.bytes_materialized.fetch_add(bytes, Ordering::Relaxed);
        self.adopt(
            ds.schema.clone(),
            new_parts,
            Lineage::Derived { parent: ds.id, op: op_name.to_string() },
        )
    }

    /// **Oseba path.** Resolve index-provided slices into pinned views of
    /// the targeted partitions only — resident partitions for free, cold
    /// (tiered) partitions faulted in from their segments. Slices whose
    /// partition has an unknown internal step are refined here with a
    /// binary search over that partition's keys only.
    pub fn select_slices(
        &self,
        ds: &Dataset,
        slices: &[PartitionSlice],
        q: RangeQuery,
    ) -> Result<PinnedSlices> {
        Ok(PinnedSlices(
            self.resolve_slices(ds, slices, q)?
                .into_iter()
                .map(|(part, s)| PinnedSlice {
                    part,
                    row_start: s.row_start,
                    row_end: s.row_end,
                })
                .collect(),
        ))
    }

    /// Raw variant of [`Self::select_slices`] for dispatch to worker
    /// threads: returns `(partition handle, refined slice)` pairs. Only
    /// the index-targeted partitions are touched (and, when tiered,
    /// faulted in) — never the rest of the dataset.
    pub fn resolve_slices(
        &self,
        ds: &Dataset,
        slices: &[PartitionSlice],
        q: RangeQuery,
    ) -> Result<Vec<(Arc<Partition>, PartitionSlice)>> {
        self.counters.partitions_targeted.fetch_add(slices.len(), Ordering::Relaxed);
        let mut out = Vec::with_capacity(slices.len());
        for s in slices {
            let part = match ds.store() {
                Some(store) => store.fetch(s.partition)?,
                None => Arc::clone(&ds.parts[s.partition]),
            };
            // Refine conservative whole-partition slices (irregular
            // partitions) against the actual keys.
            let (row_start, row_end) =
                if s.row_start == 0 && s.row_end == part.rows && part.rows > 0 {
                    (part.lower_bound(q.lo), part.upper_bound(q.hi))
                } else {
                    (s.row_start, s.row_end)
                };
            if row_start < row_end {
                out.push((
                    part,
                    PartitionSlice { partition: s.partition, row_start, row_end },
                ));
            }
        }
        Ok(out)
    }

    /// Record `n` sketch-answered (covered) partitions: they count as
    /// targeted — the index proposed them — but touched no data.
    pub(crate) fn note_agg_answered(&self, n: usize) {
        if n > 0 {
            self.counters.partitions_targeted.fetch_add(n, Ordering::Relaxed);
            self.counters.partitions_agg_answered.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Record `n` slices answered entirely from block partials: their
    /// partitions count as targeted — the index proposed them — but were
    /// never resolved, so no fault-in (and no sketch answer) is booked.
    pub(crate) fn note_targeted(&self, n: usize) {
        if n > 0 {
            self.counters.partitions_targeted.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Record `n` selection slices served degraded: their quarantined
    /// partitions were dropped from the answer instead of failing it.
    pub(crate) fn note_degraded(&self, n: usize) {
        if n > 0 {
            self.counters.degraded_answers.fetch_add(n, Ordering::Relaxed);
        }
    }

    /// Record block-level outcomes from the sub-partition hierarchy:
    /// `covered` blocks answered by merging retained partials, `pruned`
    /// blocks skipped by block-zone predicate checks. Neither touches
    /// column data.
    pub(crate) fn note_blocks(&self, covered: usize, pruned: usize) {
        if covered > 0 {
            self.counters.blocks_covered.fetch_add(covered, Ordering::Relaxed);
        }
        if pruned > 0 {
            self.counters.blocks_pruned.fetch_add(pruned, Ordering::Relaxed);
        }
    }

    /// Drop a dataset from the cache, releasing its memory.
    pub fn unpersist(&self, ds: &Dataset) -> bool {
        self.block_manager.unpersist(ds.id)
    }

    /// Cached bytes right now — the Fig 4 y-axis.
    pub fn memory_used(&self) -> usize {
        self.block_manager.used_bytes()
    }

    /// Cached-bytes high-water mark.
    pub fn memory_peak(&self) -> usize {
        self.block_manager.peak_bytes()
    }

    /// Scan/materialization counters.
    pub fn counters(&self) -> CounterSnapshot {
        self.counters.snapshot()
    }

    /// Record one request handler caught panicking at the server's
    /// session boundary (surfaced as `sessions_failed` in server info).
    pub fn record_session_failure(&self) {
        self.counters.sessions_failed.fetch_add(1, Ordering::Relaxed);
    }

    /// The unified observability registry: per-op / per-phase latency
    /// histograms and the slow-query log (surfaced by the server's
    /// `metrics` op).
    pub fn metrics(&self) -> &MetricsRegistry {
        &self.metrics
    }

    /// Lineage log: `(id, name, lineage)` in creation order (Fig 2).
    pub fn lineage_log(&self) -> Vec<(DatasetId, String, Lineage)> {
        self.lineage.lock_recover().clone()
    }

    /// The shared scan pool (used by the coordinator for analysis tasks).
    pub fn pool(&self) -> &ThreadPool {
        &self.pool
    }

    /// The block manager (cluster/coordinator integration).
    pub fn block_manager(&self) -> &Arc<BlockManager> {
        &self.block_manager
    }
}

/// Scan one partition for keys in `q`; returns (keys, columns, rows
/// examined). This is the real per-partition cost of the baseline: every
/// valid row's key is inspected.
fn filter_partition(p: &Partition, q: RangeQuery) -> (Vec<i64>, Vec<Vec<f32>>, usize) {
    let mut keys = Vec::new();
    let mut cols: Vec<Vec<f32>> = vec![Vec::new(); p.columns.len()];
    for r in 0..p.rows {
        let k = p.keys[r];
        if k >= q.lo && k <= q.hi {
            keys.push(k);
            for (c, col) in cols.iter_mut().enumerate() {
                col.push(p.columns[c][r]);
            }
        }
    }
    (keys, cols, p.rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::ClimateGen;
    use crate::index::{Cias, ContentIndex};
    use crate::storage::Schema;

    fn ctx() -> OsebaContext {
        OsebaContext::new(ContextConfig { num_workers: 4, memory_budget: None })
    }

    fn load_climate(ctx: &OsebaContext, rows: usize, nparts: usize) -> Dataset {
        let batch = ClimateGen::default().generate(rows);
        ctx.load(batch, nparts).unwrap()
    }

    #[test]
    fn load_caches_and_accounts() {
        let c = ctx();
        let ds = load_climate(&c, 10_000, 5);
        assert_eq!(ds.num_partitions(), 5);
        assert_eq!(ds.total_rows(), 10_000);
        assert_eq!(c.memory_used(), ds.bytes());
    }

    #[test]
    fn filter_range_selects_exactly_and_grows_memory() {
        let c = ctx();
        let ds = load_climate(&c, 10_000, 5);
        let before = c.memory_used();
        // Keys are hourly (step 3600): select rows 100..=199.
        let q = RangeQuery { lo: 100 * 3600, hi: 199 * 3600 };
        let f = c.filter_range(&ds, q).unwrap();
        assert_eq!(f.total_rows(), 100);
        assert!(c.memory_used() > before, "filtered RDD must be resident");
        let snap = c.counters();
        assert_eq!(snap.partitions_scanned, 5);
        assert_eq!(snap.rows_scanned, 10_000);
        assert!(snap.bytes_materialized > 0);
        // Values preserved.
        assert_eq!(f.key_min(), Some(100 * 3600));
        assert_eq!(f.key_max(), Some(199 * 3600));
    }

    #[test]
    fn filter_range_empty_result_is_valid_dataset() {
        let c = ctx();
        let ds = load_climate(&c, 1000, 4);
        let f = c.filter_range(&ds, RangeQuery { lo: i64::MAX - 10, hi: i64::MAX }).unwrap();
        assert_eq!(f.total_rows(), 0);
        assert_eq!(f.num_partitions(), 1);
        assert_eq!(f.schema(), &Schema::climate());
    }

    #[test]
    fn oseba_path_matches_baseline_rows_without_memory_growth() {
        let c = ctx();
        let ds = load_climate(&c, 50_000, 15);
        let index = Cias::build(ds.partitions()).unwrap();
        let q = RangeQuery { lo: 7_000 * 3600, hi: 21_000 * 3600 };

        let baseline = c.filter_range(&ds, q).unwrap();
        let baseline_rows = baseline.total_rows();
        c.unpersist(&baseline);

        let before = c.memory_used();
        let views = c.select_slices(&ds, &index.lookup(q), q).unwrap();
        assert_eq!(views.rows(), baseline_rows);
        assert_eq!(c.memory_used(), before, "no materialization on the Oseba path");
    }

    #[test]
    fn select_slices_refines_irregular_partitions() {
        let c = ctx();
        let ds = load_climate(&c, 1000, 4);
        // Conservative full-partition slice (as an index returns for
        // step-less partitions) must be narrowed to the actual keys.
        let q = RangeQuery { lo: 10 * 3600, hi: 20 * 3600 };
        let slices = vec![PartitionSlice { partition: 0, row_start: 0, row_end: ds.partitions()[0].rows }];
        let views = c.select_slices(&ds, &slices, q).unwrap();
        assert_eq!(views.len(), 1);
        assert_eq!(views[0].rows(), 11);
        assert_eq!(views[0].view().keys().first(), Some(&(10 * 3600)));
    }

    #[test]
    fn unpersist_frees_memory() {
        let c = ctx();
        let ds = load_climate(&c, 5000, 3);
        let used = c.memory_used();
        assert!(used > 0);
        assert!(c.unpersist(&ds));
        assert_eq!(c.memory_used(), 0);
        assert!(!c.unpersist(&ds));
        assert_eq!(c.memory_peak(), used);
    }

    #[test]
    fn generic_filter_matches_range_filter() {
        let c = ctx();
        let ds = load_climate(&c, 2000, 4);
        let q = RangeQuery { lo: 500 * 3600, hi: 800 * 3600 };
        let a = c.filter_range(&ds, q).unwrap();
        let b = c
            .filter(&ds, "pred", move |k, _| (500 * 3600..=800 * 3600).contains(&k))
            .unwrap();
        assert_eq!(a.total_rows(), b.total_rows());
        assert_eq!(a.key_min(), b.key_min());
        assert_eq!(a.key_max(), b.key_max());
    }

    #[test]
    fn lineage_records_dataflow() {
        let c = ctx();
        let ds = load_climate(&c, 1000, 2);
        let f = c.filter_range(&ds, RangeQuery { lo: 0, hi: 3600 * 10 }).unwrap();
        let log = c.lineage_log();
        assert_eq!(log.len(), 2);
        assert_eq!(log[0].0, ds.id());
        assert!(matches!(&log[1].2, Lineage::Derived { parent, .. } if *parent == ds.id()));
        assert!(log[1].1.starts_with("filter["));
        assert!(matches!(f.lineage(), Lineage::Derived { .. }));
    }

    #[test]
    fn empty_batch_load_is_a_clear_schema_error() {
        // Regression: used to fall into partition_batch_uniform's
        // "rows_per_partition must be > 0" failure path.
        let c = ctx();
        let empty = crate::storage::BatchBuilder::new(Schema::climate()).finish().unwrap();
        let err = c.load(empty, 4).unwrap_err();
        assert!(
            err.to_string().contains("cannot load empty batch"),
            "got: {err}"
        );
        assert_eq!(c.memory_used(), 0);
    }

    #[test]
    fn memory_budget_rejects_oversized_load() {
        let c = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: Some(1024) });
        let batch = ClimateGen::default().generate(10_000);
        assert!(c.load(batch, 4).is_err());
        assert_eq!(c.memory_used(), 0);
    }

    #[test]
    fn tiered_load_fits_dataset_exceeding_budget() {
        let dir = crate::testing::temp_dir("ctx-tiered");
        let batch = ClimateGen::default().generate(40_000);
        // The same load that `memory_budget_rejects_oversized_load` proves
        // impossible resident works tiered: budget ~2 of 10 partitions.
        let one = crate::storage::partition_batch_uniform(&batch, 4_000).unwrap()[0].bytes();
        let c = OsebaContext::new(ContextConfig {
            num_workers: 2,
            memory_budget: Some(2 * one + one / 2),
        });
        let ds = c.load_tiered(batch, 10, &dir).unwrap();
        assert!(ds.is_tiered());
        assert_eq!(ds.num_partitions(), 10);
        assert_eq!(ds.total_rows(), 40_000);
        assert!(c.memory_used() <= 2 * one + one / 2);
        let store = ds.store().unwrap();
        assert!(store.counters().evictions >= 8, "load must spill");

        // A selective query faults in only the targeted partition.
        let index = Cias::from_meta(store.metas()).unwrap();
        let q = RangeQuery { lo: 0, hi: 100 * 3600 };
        let before = store.counters();
        let views = c.select_slices(&ds, &index.lookup(q), q).unwrap();
        assert_eq!(views.rows(), 101);
        let d = store.counters().since(&before);
        assert!(d.faults <= 1, "one partition targeted, faults={}", d.faults);

        // The scan baseline on the same dataset is a full reload.
        let before = store.counters();
        let filtered = c.filter_range(&ds, q).unwrap();
        assert_eq!(filtered.total_rows(), 101);
        let d = store.counters().since(&before);
        assert!(d.faults >= 7, "full scan faults everything, faults={}", d.faults);
        c.unpersist(&filtered);
        c.unpersist(&ds);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn filter_range_indexed_matches_scan_without_scanning() {
        let c = ctx();
        let ds = load_climate(&c, 10_000, 5);
        let index = Cias::build(ds.partitions()).unwrap();
        let q = RangeQuery { lo: 700 * 3600, hi: 1_900 * 3600 };
        let scan = c.filter_range(&ds, q).unwrap();
        let before = c.counters();
        let fast = c.filter_range_indexed(&ds, &index, q).unwrap();
        let after = c.counters();
        // Same rows, same bounds, zero scanning — only targeting.
        assert_eq!(fast.total_rows(), scan.total_rows());
        assert_eq!(fast.key_min(), scan.key_min());
        assert_eq!(fast.key_max(), scan.key_max());
        assert_eq!(after.partitions_scanned, before.partitions_scanned);
        assert_eq!(after.rows_scanned, before.rows_scanned);
        assert!(after.partitions_targeted > before.partitions_targeted);
        assert!(after.bytes_materialized > before.bytes_materialized);
        // Values identical row-for-row.
        let a: Vec<f32> = fast
            .partitions()
            .iter()
            .flat_map(|p| p.columns[0][..p.rows].to_vec())
            .collect();
        let b: Vec<f32> = scan
            .partitions()
            .iter()
            .flat_map(|p| p.columns[0][..p.rows].to_vec())
            .collect();
        assert_eq!(a, b);
        // A miss is still a valid (empty) dataset.
        let miss = c
            .filter_range_indexed(&ds, &index, RangeQuery { lo: i64::MAX - 5, hi: i64::MAX })
            .unwrap();
        assert_eq!(miss.total_rows(), 0);
        assert_eq!(miss.num_partitions(), 1);
    }

    #[test]
    fn tiered_filter_range_indexed_faults_only_targets() {
        let dir = crate::testing::temp_dir("ctx-filter-idx");
        let batch = ClimateGen::default().generate(40_000);
        let one = crate::storage::partition_batch_uniform(&batch, 4_000).unwrap()[0].bytes();
        let c = OsebaContext::new(ContextConfig {
            num_workers: 2,
            memory_budget: Some(2 * one + one / 2),
        });
        let ds = c.load_tiered(batch, 10, &dir).unwrap();
        let store = ds.store().unwrap();
        let index = Cias::from_meta(store.metas()).unwrap();
        let q = RangeQuery { lo: 0, hi: 100 * 3600 };
        let before = store.counters();
        let filtered = c.filter_range_indexed(&ds, &index, q).unwrap();
        assert_eq!(filtered.total_rows(), 101);
        let d = store.counters().since(&before);
        assert!(d.faults <= 1, "only the targeted partition faults, got {}", d.faults);
        c.unpersist(&filtered);
        c.unpersist(&ds);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn tiered_save_open_roundtrip_restores_lookup() {
        let dir = crate::testing::temp_dir("ctx-roundtrip");
        let c = ctx();
        let batch = ClimateGen::default().generate(10_000);
        let ds = c.load_tiered(batch, 5, &dir).unwrap();
        ds.store().unwrap().save().unwrap();
        c.unpersist(&ds);

        let c2 = ctx();
        let (ds2, index) = c2.open_tiered(&dir).unwrap();
        assert_eq!(ds2.total_rows(), 10_000);
        assert_eq!(ds2.schema(), &crate::storage::Schema::climate());
        let q = RangeQuery { lo: 500 * 3600, hi: 900 * 3600 };
        let views = c2.select_slices(&ds2, &index.lookup(q), q).unwrap();
        assert_eq!(views.rows(), 401);
        c2.unpersist(&ds2);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
