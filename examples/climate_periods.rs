//! **End-to-end driver** (DESIGN.md §5, EXPERIMENTS.md): the paper's §IV
//! experiment — five interactive period analyses (max/mean/std of
//! temperature) over a climate time series, run with both methods,
//! reporting the Fig 4 (accumulated memory) and Fig 6 (accumulated time)
//! series side by side.
//!
//! ```bash
//! cargo run --release --example climate_periods            # 64 MiB default
//! OSEBA_BYTES=480m cargo run --release --example climate_periods  # paper scale
//! ```

use oseba::analysis::five_periods;
use oseba::config::{parse_bytes, AppConfig, BackendKind};
use oseba::coordinator::{run_session, Coordinator, IndexKind, Method, SessionReport};
use oseba::datagen::ClimateGen;
use oseba::runtime::make_backend;
use oseba::util::humansize;

fn run_one(cfg: &AppConfig, method: Method) -> oseba::Result<(SessionReport, usize)> {
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let coord = Coordinator::new(cfg, backend)?;
    let batch =
        ClimateGen { seed: cfg.seed, ..Default::default() }.generate_bytes(cfg.dataset_bytes);
    let raw = batch.raw_bytes();
    let ds = coord.load(batch, cfg.num_partitions)?;
    let report = run_session(&coord, &ds, method, IndexKind::Cias, &five_periods(), 0, false)?;
    Ok((report, raw))
}

fn main() -> oseba::Result<()> {
    let mut cfg = AppConfig {
        dataset_bytes: std::env::var("OSEBA_BYTES")
            .ok()
            .map(|v| parse_bytes(&v))
            .transpose()?
            .unwrap_or(64 << 20),
        num_partitions: 15,
        ..AppConfig::default()
    };
    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        eprintln!("(artifacts not built; using the native backend)");
        cfg.backend = BackendKind::Native;
    }

    println!(
        "== Oseba §IV reproduction: {} over {} partitions, backend {:?} ==",
        humansize::bytes(cfg.dataset_bytes),
        cfg.num_partitions,
        cfg.backend
    );

    let (default, raw) = run_one(&cfg, Method::Default)?;
    let (oseba, _) = run_one(&cfg, Method::Oseba)?;

    // Per-phase stats must agree.
    for (i, (a, b)) in default.stats.iter().zip(&oseba.stats).enumerate() {
        assert_eq!(a.count, b.count, "phase {i}");
        assert_eq!(a.max, b.max, "phase {i}");
        println!(
            "phase {}: keys [{}, {}]  n={}  max={:.2} min={:.2} mean={:.2} std={:.2}",
            i + 1,
            default.queries[i].lo,
            default.queries[i].hi,
            a.count,
            a.max,
            a.min,
            a.mean,
            a.std
        );
    }

    // ---- Fig 4: accumulated memory after each phase --------------------
    println!(
        "\n-- Fig 4: memory after each phase (raw input = {}) --",
        humansize::bytes(raw)
    );
    println!("{:<7} {:>14} {:>14} {:>9} {:>9}", "phase", "default", "oseba", "def/raw", "def/oseba");
    let dm = default.metrics.memory_series();
    let om = oseba.metrics.memory_series();
    for i in 0..5 {
        println!(
            "{:<7} {:>14} {:>14} {:>8.2}x {:>8.2}x",
            i + 1,
            humansize::bytes(dm[i]),
            humansize::bytes(om[i]),
            dm[i] as f64 / raw as f64,
            dm[i] as f64 / om[i] as f64
        );
    }

    // ---- Fig 6: accumulated processing time -----------------------------
    println!("\n-- Fig 6: accumulated time --");
    println!("{:<7} {:>12} {:>12} {:>9}", "phase", "default", "oseba", "speedup");
    let dt = default.metrics.accumulated_time();
    let ot = oseba.metrics.accumulated_time();
    for i in 0..5 {
        println!(
            "{:<7} {:>12} {:>12} {:>8.2}x",
            i + 1,
            humansize::secs(dt[i]),
            humansize::secs(ot[i]),
            dt[i] / ot[i]
        );
    }

    println!("\n-- detail --");
    println!("default:\n{}", default.metrics.table());
    println!("oseba (index: {} bytes):\n{}", oseba.index_bytes, oseba.metrics.table());

    // Machine-readable dump for EXPERIMENTS.md.
    println!("JSON default: {}", default.metrics.to_json());
    println!("JSON oseba:   {}", oseba.metrics.to_json());
    Ok(())
}
