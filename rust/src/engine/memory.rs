//! Storage-memory accounting — the instrument behind Fig 4.
//!
//! Every cached partition's bytes are charged to a [`MemoryTracker`];
//! releasing (unpersist) credits it back. An optional budget turns
//! over-allocation into [`OsebaError::OutOfMemory`], modelling a Spark
//! executor's bounded storage memory.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use crate::error::{OsebaError, Result};

/// Thread-safe byte accountant.
#[derive(Debug, Default)]
pub struct MemoryTracker {
    used: AtomicUsize,
    peak: AtomicUsize,
    budget: Option<usize>,
}

impl MemoryTracker {
    /// Unbounded tracker.
    pub fn unbounded() -> Arc<MemoryTracker> {
        Arc::new(MemoryTracker::default())
    }

    /// Tracker that rejects allocations beyond `budget` bytes.
    pub fn with_budget(budget: usize) -> Arc<MemoryTracker> {
        Arc::new(MemoryTracker { budget: Some(budget), ..Default::default() })
    }

    /// Charge `bytes`; fails (without charging) if the budget would be
    /// exceeded.
    pub fn allocate(&self, bytes: usize) -> Result<()> {
        let mut cur = self.used.load(Ordering::SeqCst);
        loop {
            let next = cur + bytes;
            if let Some(b) = self.budget {
                if next > b {
                    return Err(OsebaError::OutOfMemory { requested: bytes, budget: b });
                }
            }
            match self.used.compare_exchange(cur, next, Ordering::SeqCst, Ordering::SeqCst) {
                Ok(_) => {
                    self.peak.fetch_max(next, Ordering::SeqCst);
                    return Ok(());
                }
                Err(actual) => cur = actual,
            }
        }
    }

    /// Credit `bytes` back.
    pub fn release(&self, bytes: usize) {
        let prev = self.used.fetch_sub(bytes, Ordering::SeqCst);
        debug_assert!(prev >= bytes, "release underflow: {prev} - {bytes}");
    }

    /// Currently charged bytes.
    pub fn used(&self) -> usize {
        self.used.load(Ordering::SeqCst)
    }

    /// High-water mark.
    pub fn peak(&self) -> usize {
        self.peak.load(Ordering::SeqCst)
    }

    /// Configured budget, if any.
    pub fn budget(&self) -> Option<usize> {
        self.budget
    }

    /// Bytes still allocatable before the budget is hit (`None` when
    /// unbounded). Advisory only — [`Self::allocate`] is the authority.
    pub fn headroom(&self) -> Option<usize> {
        self.budget.map(|b| b.saturating_sub(self.used()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tracks_use_and_peak() {
        let t = MemoryTracker::unbounded();
        t.allocate(100).unwrap();
        t.allocate(50).unwrap();
        assert_eq!(t.used(), 150);
        t.release(100);
        assert_eq!(t.used(), 50);
        assert_eq!(t.peak(), 150);
    }

    #[test]
    fn budget_enforced() {
        let t = MemoryTracker::with_budget(100);
        t.allocate(80).unwrap();
        let err = t.allocate(30).unwrap_err();
        assert!(matches!(err, OsebaError::OutOfMemory { requested: 30, budget: 100 }));
        // Failed allocation did not charge.
        assert_eq!(t.used(), 80);
        t.release(80);
        t.allocate(100).unwrap();
    }

    #[test]
    fn concurrent_allocation_consistent() {
        let t = MemoryTracker::unbounded();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        t.allocate(3).unwrap();
                        t.release(3);
                    }
                });
            }
        });
        assert_eq!(t.used(), 0);
        assert!(t.peak() >= 3);
    }
}
