//! Core index vocabulary: range queries, partition slices, per-column
//! value-domain zone maps with the predicates that consult them, the
//! per-column aggregate sketches the planner answers covered partitions
//! from, and the [`ContentIndex`] trait both index implementations
//! satisfy.

use std::sync::Arc;

use crate::error::{OsebaError, Result};
use crate::util::stats::{fold_stats_f32, Moments, TrendPartial};

/// An inclusive key-range selection `[lo, hi]` — the paper's "data ranging
/// from index i to j" (§III-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RangeQuery {
    /// Lower key bound, inclusive.
    pub lo: i64,
    /// Upper key bound, inclusive.
    pub hi: i64,
}

impl RangeQuery {
    /// Validate `lo <= hi`.
    pub fn new(lo: i64, hi: i64) -> Result<RangeQuery> {
        if lo > hi {
            return Err(OsebaError::InvalidRange(format!("lo {lo} > hi {hi}")));
        }
        Ok(RangeQuery { lo, hi })
    }
}

/// A targeted region of one partition: valid-row indices `[row_start,
/// row_end)` of partition `partition`. The unit of work the coordinator
/// dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionSlice {
    /// Target partition id.
    pub partition: usize,
    /// First valid row (inclusive).
    pub row_start: usize,
    /// One past the last valid row.
    pub row_end: usize,
}

impl PartitionSlice {
    /// Number of rows the slice covers.
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Content-aware metadata over a partitioned dataset: maps key ranges to
/// the partitions (and row ranges) that hold them, without touching data.
pub trait ContentIndex: Send + Sync {
    /// Human-readable implementation name (bench labels).
    fn name(&self) -> &'static str;

    /// All slices intersecting `q`, ordered by partition id; empty when the
    /// query misses the dataset entirely.
    fn lookup(&self, q: RangeQuery) -> Vec<PartitionSlice>;

    /// Resident metadata footprint in bytes — the §III space-complexity
    /// comparison (table: O(m); CIAS: O(1) + ASL).
    fn memory_bytes(&self) -> usize;

    /// Number of partitions the index covers.
    fn num_partitions(&self) -> usize;
}

/// Per-column value-domain statistics of one partition: min/max over the
/// non-NaN values plus a NaN count. This is the zone map predicate
/// pruning consults — pure metadata, so a cold (spilled) partition can be
/// ruled out *before* it is faulted in.
///
/// Zone maps ride next to [`PartitionMeta`] (in partitions, store slots
/// and the manifest) rather than inside it: the CIAS compressed region
/// keeps no per-partition metadata at all, so storing zones in the index
/// would reintroduce the O(m) footprint §III-B eliminates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ZoneMap {
    /// Smallest non-NaN value (`f32::INFINITY` when none).
    pub min: f32,
    /// Largest non-NaN value (`f32::NEG_INFINITY` when none).
    pub max: f32,
    /// Number of NaN values in the column.
    pub nans: usize,
}

impl ZoneMap {
    /// The empty zone map (identity for [`ZoneMap::absorb`]).
    pub const EMPTY: ZoneMap =
        ZoneMap { min: f32::INFINITY, max: f32::NEG_INFINITY, nans: 0 };

    /// Zone map of a value slice (one pass; NaNs counted, not folded).
    pub fn of(values: &[f32]) -> ZoneMap {
        let mut z = ZoneMap::EMPTY;
        for &x in values {
            z.absorb(x);
        }
        z
    }

    /// Fold one value in.
    pub fn absorb(&mut self, x: f32) {
        if x.is_nan() {
            self.nans += 1;
        } else {
            self.min = self.min.min(x);
            self.max = self.max.max(x);
        }
    }

    /// Whether the column holds no non-NaN value.
    pub fn is_empty(&self) -> bool {
        self.min > self.max
    }
}

/// Associative **aggregate sketch** of one value column of one partition:
/// the full [`Moments`] partial (max/min/sum/sumsq/count/nans — a strict
/// superset of the min/max-only [`ZoneMap`]) plus the linear-trend
/// regression partial over (key, value) pairs.
///
/// Sketches are computed once at seal time and carried wherever partition
/// metadata lives (resident partitions, the tiered store's slot table,
/// manifest v3), so a query whose key range *fully covers* a partition —
/// and carries no value predicates — is answered by merging the sketch
/// instead of scanning (or, when the partition is cold, faulting in) the
/// data. The stats moments are folded block-by-block through
/// [`crate::util::stats::fold_stats_f32`] — the same function the native
/// backend's `segment_stats` kernel uses — so on the native backend a
/// sketch partial is **bit-identical** to the partial a full scan of the
/// partition would produce, and merged results cannot drift (the property
/// tests assert exact equality). The AOT HLO kernels (non-default `xla`
/// feature) may regroup their f32 reductions, so there — as for every
/// other HLO-vs-native comparison in the crate — sketch-vs-scan agreement
/// is tolerance-level, not bitwise. On NaN-bearing columns the gap is
/// wider still: the HLO kernels fold NaN into their sums (the known
/// kernel-path limitation, DESIGN.md §10) while sketches enforce the
/// crate-wide counted-out policy — a sketch-answered partition therefore
/// reports the *correct* statistics where the kernel scan would poison
/// them, and a query straddling the covered/edge boundary can observe
/// that difference.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColumnSketch {
    /// Raw-moment partial over the column's valid rows.
    pub moments: Moments,
    /// Linear-regression partial over (key, value) pairs.
    pub trend: TrendPartial,
}

impl ColumnSketch {
    /// The identity sketch (empty partition).
    pub const EMPTY: ColumnSketch =
        ColumnSketch { moments: Moments::EMPTY, trend: TrendPartial::EMPTY };

    /// Sketch one column: `keys` are the partition's valid keys and
    /// `values` the parallel column slice (`values.len() >= keys.len()`;
    /// padding beyond the keys is ignored). `block_rows` is the kernel
    /// block size the moments are folded in — pass
    /// [`crate::storage::BLOCK_ROWS`] so the partial matches the scan
    /// path's block decomposition exactly.
    pub fn of(keys: &[i64], values: &[f32], block_rows: usize) -> ColumnSketch {
        ColumnSketch::with_blocks(keys, values, block_rows).0
    }

    /// [`Self::of`], also **retaining** the per-block [`Moments`] partials
    /// the merged sketch is folded from (one per `block_rows` chunk of the
    /// valid rows, in block order). The merged sketch is exactly the
    /// fixed-order merge of the returned partials, so answering a block
    /// from its partial is bit-identical to scanning it — the invariant
    /// the sub-partition (block-sketch) pushdown rests on.
    pub fn with_blocks(
        keys: &[i64],
        values: &[f32],
        block_rows: usize,
    ) -> (ColumnSketch, Vec<Moments>) {
        let rows = keys.len().min(values.len());
        let values = &values[..rows];
        let blocks: Vec<Moments> = values
            .chunks(block_rows.max(1))
            .map(|block| {
                let (mx, mn, sum, sumsq, nans) = fold_stats_f32(block);
                let mut m =
                    Moments::from_kernel(mx, mn, sum, sumsq, (block.len() - nans) as f32);
                m.nans = nans as f64;
                m
            })
            .collect();
        let moments = blocks.iter().copied().fold(Moments::EMPTY, Moments::merge);
        (ColumnSketch { moments, trend: TrendPartial::scan(keys, values) }, blocks)
    }

    /// The zone map this sketch subsumes (min/max/nans), for predicate
    /// pruning. Empty sketches map to the unbounded-empty sentinel.
    pub fn zone(&self) -> ZoneMap {
        if self.moments.is_empty() {
            return ZoneMap { nans: self.moments.nans as usize, ..ZoneMap::EMPTY };
        }
        ZoneMap {
            min: self.moments.min,
            max: self.moments.max,
            nans: self.moments.nans as usize,
        }
    }
}

/// Aggregate sketches for every value column of a partition's valid rows.
pub fn sketches_of(
    keys: &[i64],
    columns: &[Vec<f32>],
    block_rows: usize,
) -> Vec<ColumnSketch> {
    columns.iter().map(|c| ColumnSketch::of(keys, c, block_rows)).collect()
}

/// [`sketches_of`] plus the retained [`BlockSketches`] — one fold at seal
/// time produces both the merged per-partition sketches and the per-block
/// partials they were merged from.
pub fn sketches_with_blocks(
    keys: &[i64],
    columns: &[Vec<f32>],
    block_rows: usize,
) -> (Vec<ColumnSketch>, BlockSketches) {
    let mut sketches = Vec::with_capacity(columns.len());
    let mut blocks = Vec::with_capacity(columns.len());
    for c in columns {
        let (sk, b) = ColumnSketch::with_blocks(keys, c, block_rows);
        sketches.push(sk);
        blocks.push(b);
    }
    (sketches, BlockSketches::from_parts(block_rows, blocks))
}

/// **Sub-partition sketch hierarchy**: the per-block [`Moments`] partials
/// of every value column of one partition, retained from the seal-time
/// fold instead of being discarded after the merge (DESIGN.md §15).
///
/// Each partial covers one `block_rows`-sized chunk of the partition's
/// *valid* rows (the last block may be shorter; padding is never folded),
/// and subsumes a per-block zone map ([`Self::zone`]). Because the
/// partials come from the same [`fold_stats_f32`] the scan path uses,
/// answering a fully-selected block by its partial is bit-identical to
/// scanning it on the native backend.
///
/// Like the partition-level sketches and membership filters, block
/// sketches are metadata: they ride in an `Arc` next to the data (the
/// partition, the tiered store's slot table, manifest v5) so a cold
/// partition's blocks can be classified without faulting anything in.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockSketches {
    /// Rows per block the partials were folded in.
    block_rows: usize,
    /// Per-column, per-block partials (`columns[c][b]`); every column has
    /// the same number of blocks.
    columns: Vec<Vec<Moments>>,
}

/// Hard cap on the column count [`BlockSketches::from_bytes`] accepts
/// (matches the segment codec's width bound).
const MAX_BLOCK_SKETCH_COLUMNS: usize = 1 << 12;
/// Hard cap on the per-column block count [`BlockSketches::from_bytes`]
/// accepts (`MAX_ROWS / BLOCK_ROWS`).
const MAX_BLOCK_SKETCH_BLOCKS: usize = 1 << 28;
/// Encoded size of one [`Moments`] partial in the block-sketch codec.
const MOMENTS_WIRE_BYTES: usize = 4 + 4 + 8 + 8 + 8 + 8;

impl BlockSketches {
    /// Assemble from per-column partial vectors, as returned by
    /// [`ColumnSketch::with_blocks`] (every `columns[c]` must hold the
    /// same number of blocks). Partition construction folds column by
    /// column and assembles with this; prefer [`sketches_with_blocks`]
    /// when the columns are already gathered.
    pub fn from_parts(block_rows: usize, columns: Vec<Vec<Moments>>) -> BlockSketches {
        debug_assert!(
            columns.windows(2).all(|w| w[0].len() == w[1].len()),
            "ragged block-sketch columns"
        );
        BlockSketches { block_rows, columns }
    }

    /// Rows per block the partials were folded in.
    pub fn block_rows(&self) -> usize {
        self.block_rows
    }

    /// Number of value columns covered.
    pub fn num_columns(&self) -> usize {
        self.columns.len()
    }

    /// Number of blocks per column (every column has the same count).
    pub fn num_blocks(&self) -> usize {
        self.columns.first().map_or(0, Vec::len)
    }

    /// The partial of one block of one column.
    pub fn moments(&self, column: usize, block: usize) -> Option<Moments> {
        self.columns.get(column).and_then(|c| c.get(block)).copied()
    }

    /// The zone map one block's partial subsumes (min/max/nans), for
    /// block-level predicate pruning. Out-of-range coordinates yield the
    /// unbounded-empty sentinel (which satisfies no comparison — callers
    /// must bounds-check first if they want "unknown → keep").
    pub fn zone(&self, column: usize, block: usize) -> ZoneMap {
        let Some(m) = self.moments(column, block) else {
            return ZoneMap::EMPTY;
        };
        if m.is_empty() {
            return ZoneMap { nans: m.nans as usize, ..ZoneMap::EMPTY };
        }
        ZoneMap { min: m.min, max: m.max, nans: m.nans as usize }
    }

    /// Whether block `block` could hold a row satisfying every predicate
    /// of the conjunction, judged from its per-block zones alone. A
    /// predicate on a column the sketches do not cover never prunes.
    pub fn satisfiable(&self, preds: &[ColumnPredicate], block: usize) -> bool {
        preds.iter().all(|p| match self.columns.get(p.column) {
            Some(c) if block < c.len() => p.satisfiable(&self.zone(p.column, block)),
            _ => true,
        })
    }

    /// Resident metadata footprint in bytes (slot-table accounting).
    pub fn bytes(&self) -> usize {
        std::mem::size_of::<BlockSketches>()
            + self
                .columns
                .iter()
                .map(|c| c.len() * std::mem::size_of::<Moments>())
                .sum::<usize>()
    }

    /// Serialize for the manifest's block-sketch section: a fixed little-
    /// endian layout (`block_rows`, column count, per-column block count,
    /// then every partial in column-major order). Binary, so non-finite
    /// partials round-trip exactly — no JSON opt-out like the sketch
    /// section needs.
    pub fn to_bytes(&self) -> Vec<u8> {
        let blocks = self.num_blocks();
        let mut out = Vec::with_capacity(
            12 + self.columns.len() * blocks * MOMENTS_WIRE_BYTES,
        );
        out.extend_from_slice(&(self.block_rows as u32).to_le_bytes());
        out.extend_from_slice(&(self.columns.len() as u32).to_le_bytes());
        out.extend_from_slice(&(blocks as u32).to_le_bytes());
        for col in &self.columns {
            for m in col {
                out.extend_from_slice(&m.max.to_le_bytes());
                out.extend_from_slice(&m.min.to_le_bytes());
                out.extend_from_slice(&m.sum.to_le_bytes());
                out.extend_from_slice(&m.sumsq.to_le_bytes());
                out.extend_from_slice(&m.count.to_le_bytes());
                out.extend_from_slice(&m.nans.to_le_bytes());
            }
        }
        out
    }

    /// Decode a [`Self::to_bytes`] payload, validating the header bounds
    /// and the exact payload length before allocating anything.
    pub fn from_bytes(bytes: &[u8]) -> Result<BlockSketches> {
        let err = |msg: &str| OsebaError::Store(format!("block sketches: {msg}"));
        if bytes.len() < 12 {
            return Err(err("truncated header"));
        }
        let u32_at = |i: usize| {
            let mut b = [0u8; 4];
            b.copy_from_slice(&bytes[i..i + 4]);
            u32::from_le_bytes(b) as usize
        };
        let block_rows = u32_at(0);
        let ncols = u32_at(4);
        let nblocks = u32_at(8);
        if block_rows == 0 {
            return Err(err("block_rows must be > 0"));
        }
        if ncols > MAX_BLOCK_SKETCH_COLUMNS {
            return Err(err("column count out of bounds"));
        }
        if nblocks > MAX_BLOCK_SKETCH_BLOCKS {
            return Err(err("block count out of bounds"));
        }
        let want = 12 + ncols * nblocks * MOMENTS_WIRE_BYTES;
        if bytes.len() != want {
            return Err(err(&format!(
                "payload length {} != expected {want}",
                bytes.len()
            )));
        }
        let f32_at = |i: usize| {
            let mut b = [0u8; 4];
            b.copy_from_slice(&bytes[i..i + 4]);
            f32::from_le_bytes(b)
        };
        let f64_at = |i: usize| {
            let mut b = [0u8; 8];
            b.copy_from_slice(&bytes[i..i + 8]);
            f64::from_le_bytes(b)
        };
        let mut columns = Vec::with_capacity(ncols);
        let mut pos = 12usize;
        for _ in 0..ncols {
            let mut col = Vec::with_capacity(nblocks);
            for _ in 0..nblocks {
                col.push(Moments {
                    max: f32_at(pos),
                    min: f32_at(pos + 4),
                    sum: f64_at(pos + 8),
                    sumsq: f64_at(pos + 16),
                    count: f64_at(pos + 24),
                    nans: f64_at(pos + 32),
                });
                pos += MOMENTS_WIRE_BYTES;
            }
            columns.push(col);
        }
        Ok(BlockSketches { block_rows, columns })
    }
}

/// How one kernel block of a planned slice is handled by the block-sketch
/// pushdown (DESIGN.md §15).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BlockClass {
    /// Fully selected, predicate-free: answered by merging the retained
    /// block partial — no data read.
    Covered,
    /// The block's zones cannot satisfy the predicate conjunction: no row
    /// can match, so the block is skipped without being read.
    Pruned,
    /// Must be scanned (a partially-selected remainder block, or a block
    /// whose zones admit matches).
    Scanned,
}

/// Walk the kernel blocks of valid rows `[row_start, row_end)` of a
/// partition holding `rows` valid rows, classifying each block against
/// its retained sketches: `visit(block, s, e, class)` receives the block
/// index, the absolute valid-row bounds of the intersection, and the
/// class. `cover_ok` gates the [`BlockClass::Covered`] answer (only a
/// predicate-free moments fold may use a partial); block-zone pruning
/// fires only when `preds` is non-empty. Classification is shared by the
/// planner (explain arithmetic), the plan verifier, and the executor, so
/// the three can never disagree.
pub fn for_each_block_class(
    blocks: &BlockSketches,
    rows: usize,
    row_start: usize,
    row_end: usize,
    preds: &[ColumnPredicate],
    cover_ok: bool,
    mut visit: impl FnMut(usize, usize, usize, BlockClass),
) {
    let row_end = row_end.min(rows);
    if row_start >= row_end {
        return;
    }
    let br = blocks.block_rows().max(1);
    let first = row_start / br;
    let last = ((row_end - 1) / br).min(blocks.num_blocks().saturating_sub(1));
    for b in first..=last {
        let bs = b * br;
        let be = (bs + br).min(rows);
        let s = row_start.max(bs);
        let e = row_end.min(be);
        if s >= e {
            continue;
        }
        let class = if !preds.is_empty() && !blocks.satisfiable(preds, b) {
            BlockClass::Pruned
        } else if cover_ok && preds.is_empty() && s == bs && e == be {
            BlockClass::Covered
        } else {
            BlockClass::Scanned
        };
        visit(b, s, e, class);
    }
}

/// Summed outcome of classifying one slice's blocks — the explain/verify
/// arithmetic (`covered + pruned + scanned = considered`, and the same
/// identity over rows).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct BlockCounts {
    /// Blocks answered from their retained partial.
    pub covered: usize,
    /// Blocks skipped by block-zone pruning.
    pub pruned: usize,
    /// Blocks that must be scanned.
    pub scanned: usize,
    /// Selected rows inside covered or pruned blocks (not folded by a scan).
    pub rows_avoided: usize,
    /// Selected rows inside scanned blocks.
    pub rows_scanned: usize,
}

impl BlockCounts {
    /// Total blocks the slice intersects.
    pub fn considered(&self) -> usize {
        self.covered + self.pruned + self.scanned
    }
}

/// Classify a slice's blocks and return only the counts (the plan-time /
/// verify-time arithmetic; the executor uses [`for_each_block_class`]
/// directly). `blocks` sketches whose `block_rows` disagree with the
/// caller's kernel block size must be rejected by the caller beforehand.
pub fn count_block_classes(
    blocks: &BlockSketches,
    rows: usize,
    row_start: usize,
    row_end: usize,
    preds: &[ColumnPredicate],
    cover_ok: bool,
) -> BlockCounts {
    let mut counts = BlockCounts::default();
    for_each_block_class(blocks, rows, row_start, row_end, preds, cover_ok, |_, s, e, class| {
        match class {
            BlockClass::Covered => {
                counts.covered += 1;
                counts.rows_avoided += e - s;
            }
            BlockClass::Pruned => {
                counts.pruned += 1;
                counts.rows_avoided += e - s;
            }
            BlockClass::Scanned => {
                counts.scanned += 1;
                counts.rows_scanned += e - s;
            }
        }
    });
    counts
}

/// An `Arc`'d [`BlockSketches`] usable with kernel block size
/// `block_rows`, or `None` when absent or mis-sized — the conservative
/// "no block sketches → scan" gate every consumer goes through (a
/// manifest written with a different block size must not steer a scan
/// decomposed at this build's [`crate::storage::BLOCK_ROWS`]).
pub fn usable_blocks(
    blocks: Option<Arc<BlockSketches>>,
    block_rows: usize,
) -> Option<Arc<BlockSketches>> {
    blocks.filter(|b| b.block_rows() == block_rows && b.num_blocks() > 0)
}

/// Comparison operator of a value predicate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PredOp {
    /// `column > value`
    Gt,
    /// `column >= value`
    Ge,
    /// `column < value`
    Lt,
    /// `column <= value`
    Le,
    /// `column == value` — the point-lookup operator. The only operator
    /// membership-filter pruning fires for (DESIGN.md §14).
    Eq,
}

impl PredOp {
    /// The operator's source spelling (`">"`, `">="`, ...).
    pub fn symbol(&self) -> &'static str {
        match self {
            PredOp::Gt => ">",
            PredOp::Ge => ">=",
            PredOp::Lt => "<",
            PredOp::Le => "<=",
            PredOp::Eq => "==",
        }
    }
}

/// One `column OP value` predicate over a value column. A conjunction of
/// these is the `where` clause of a selective analysis; rows whose value
/// is NaN never match (IEEE comparison semantics).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ColumnPredicate {
    /// Index of the value column the predicate reads.
    pub column: usize,
    /// Comparison operator.
    pub op: PredOp,
    /// Comparison constant (finite).
    pub value: f32,
}

impl ColumnPredicate {
    /// Whether one row value satisfies the predicate (NaN never does).
    pub fn matches(&self, x: f32) -> bool {
        match self.op {
            PredOp::Gt => x > self.value,
            PredOp::Ge => x >= self.value,
            PredOp::Lt => x < self.value,
            PredOp::Le => x <= self.value,
            PredOp::Eq => x == self.value,
        }
    }

    /// Whether *any* row of a partition could satisfy the predicate,
    /// judged from its zone map alone. `false` means the partition can be
    /// pruned without reading it: the zone bounds cover every non-NaN
    /// value, and NaN rows never match a comparison.
    pub fn satisfiable(&self, z: &ZoneMap) -> bool {
        match self.op {
            PredOp::Gt => z.max > self.value,
            PredOp::Ge => z.max >= self.value,
            PredOp::Lt => z.min < self.value,
            PredOp::Le => z.min <= self.value,
            PredOp::Eq => z.min <= self.value && self.value <= z.max,
        }
    }
}

/// Whether a row (given by its per-column values accessor) satisfies every
/// predicate of a conjunction.
pub fn row_matches(preds: &[ColumnPredicate], value_of: impl Fn(usize) -> f32) -> bool {
    preds.iter().all(|p| p.matches(value_of(p.column)))
}

/// Whether a partition survives zone-map pruning for a conjunction:
/// every predicate must be satisfiable under the partition's zones.
pub fn zones_satisfiable(preds: &[ColumnPredicate], zones: &[ZoneMap]) -> bool {
    preds.iter().all(|p| match zones.get(p.column) {
        Some(z) => p.satisfiable(z),
        // Unknown zone (column out of range): never prune on it.
        None => true,
    })
}

/// Shared per-partition metadata record extracted at load time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionMeta {
    /// Partition id within its dataset.
    pub id: usize,
    /// Smallest key the partition holds.
    pub key_min: i64,
    /// Largest key the partition holds.
    pub key_max: i64,
    /// Valid row count.
    pub rows: usize,
    /// Key step within the partition; `None` if irregular or single-row.
    pub step: Option<i64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_query_validates() {
        assert!(RangeQuery::new(5, 5).is_ok());
        assert!(RangeQuery::new(5, 4).is_err());
        assert_eq!(RangeQuery::new(1, 9).unwrap(), RangeQuery { lo: 1, hi: 9 });
    }

    #[test]
    fn slice_rows() {
        let s = PartitionSlice { partition: 0, row_start: 10, row_end: 25 };
        assert_eq!(s.rows(), 15);
    }

    #[test]
    fn zone_map_excludes_nans_from_bounds() {
        let z = ZoneMap::of(&[3.0, f32::NAN, -1.0, 7.5, f32::NAN]);
        assert_eq!(z.min, -1.0);
        assert_eq!(z.max, 7.5);
        assert_eq!(z.nans, 2);
        assert!(!z.is_empty());

        let all_nan = ZoneMap::of(&[f32::NAN, f32::NAN]);
        assert!(all_nan.is_empty());
        assert_eq!(all_nan.nans, 2);

        assert!(ZoneMap::of(&[]).is_empty());
    }

    #[test]
    fn derived_zone_maps_cover_valid_rows_only() {
        // Zones are a view of the sketches: padding rows (beyond the two
        // keys) must stay invisible to the derived bounds.
        let keys = vec![1, 2];
        let cols = vec![vec![1.0, 2.0, 99.0, 99.0], vec![5.0, f32::NAN, 99.0, 99.0]];
        let zs: Vec<ZoneMap> =
            sketches_of(&keys, &cols, 4096).iter().map(ColumnSketch::zone).collect();
        assert_eq!(zs.len(), 2);
        assert_eq!((zs[0].min, zs[0].max), (1.0, 2.0));
        assert_eq!((zs[1].min, zs[1].max), (5.0, 5.0));
        assert_eq!(zs[1].nans, 1);
    }

    #[test]
    fn predicate_matches_and_nan_never_does() {
        let p = ColumnPredicate { column: 0, op: PredOp::Gt, value: 30.0 };
        assert!(p.matches(30.5));
        assert!(!p.matches(30.0));
        assert!(!p.matches(f32::NAN));
        let p = ColumnPredicate { column: 0, op: PredOp::Le, value: 2.0 };
        assert!(p.matches(2.0));
        assert!(!p.matches(2.1));
        assert!(!p.matches(f32::NAN));
        let p = ColumnPredicate { column: 0, op: PredOp::Eq, value: 2.0 };
        assert!(p.matches(2.0));
        assert!(p.matches(-0.0 + 2.0));
        assert!(!p.matches(2.0000002));
        assert!(!p.matches(f32::NAN));
        assert_eq!(PredOp::Ge.symbol(), ">=");
        assert_eq!(PredOp::Eq.symbol(), "==");
    }

    #[test]
    fn predicate_satisfiable_against_zone_bounds() {
        let z = ZoneMap { min: 10.0, max: 20.0, nans: 3 };
        let pred = |op, value| ColumnPredicate { column: 0, op, value };
        assert!(pred(PredOp::Gt, 19.9).satisfiable(&z));
        assert!(!pred(PredOp::Gt, 20.0).satisfiable(&z));
        assert!(pred(PredOp::Ge, 20.0).satisfiable(&z));
        assert!(pred(PredOp::Lt, 10.1).satisfiable(&z));
        assert!(!pred(PredOp::Lt, 10.0).satisfiable(&z));
        assert!(pred(PredOp::Le, 10.0).satisfiable(&z));
        // Eq is satisfiable exactly inside the closed zone interval.
        assert!(pred(PredOp::Eq, 10.0).satisfiable(&z));
        assert!(pred(PredOp::Eq, 15.0).satisfiable(&z));
        assert!(pred(PredOp::Eq, 20.0).satisfiable(&z));
        assert!(!pred(PredOp::Eq, 9.9).satisfiable(&z));
        assert!(!pred(PredOp::Eq, 20.1).satisfiable(&z));
        // An all-NaN partition satisfies no comparison: always prunable.
        let empty = ZoneMap::EMPTY;
        for op in [PredOp::Gt, PredOp::Ge, PredOp::Lt, PredOp::Le, PredOp::Eq] {
            assert!(!pred(op, 0.0).satisfiable(&empty), "{op:?}");
        }
    }

    #[test]
    fn column_sketch_matches_blockwise_fold_and_zone() {
        use crate::util::stats::fold_stats_f32;
        let keys: Vec<i64> = (0..10_000).map(|i| i * 3).collect();
        let values: Vec<f32> =
            (0..10_000).map(|i| if i == 77 { f32::NAN } else { (i % 311) as f32 }).collect();
        let block = 4096usize;
        let sk = ColumnSketch::of(&keys, &values, block);

        // Oracle: the same blockwise kernel fold, merged in block order.
        let mut want = Moments::EMPTY;
        for b in values.chunks(block) {
            let (mx, mn, sum, sumsq, nans) = fold_stats_f32(b);
            let mut m = Moments::from_kernel(mx, mn, sum, sumsq, (b.len() - nans) as f32);
            m.nans = nans as f64;
            want = want.merge(m);
        }
        assert_eq!(sk.moments, want);
        assert_eq!(sk.moments.count, 9_999.0);
        assert_eq!(sk.moments.nans, 1.0);

        // Trend matches a direct scan; padding past the keys is ignored.
        assert_eq!(sk.trend, crate::util::stats::TrendPartial::scan(&keys, &values));
        let mut padded = values.clone();
        padded.extend([9e9, 9e9]);
        assert_eq!(ColumnSketch::of(&keys, &padded, block), sk);

        // The derived zone subsumes ZoneMap::of.
        let z = sk.zone();
        let direct = ZoneMap::of(&values);
        assert_eq!((z.min, z.max, z.nans), (direct.min, direct.max, direct.nans));

        // Empty and all-NaN sketches degrade to the empty zone.
        assert!(ColumnSketch::EMPTY.zone().is_empty());
        let nan_sk = ColumnSketch::of(&[1, 2], &[f32::NAN, f32::NAN], block);
        assert!(nan_sk.zone().is_empty());
        assert_eq!(nan_sk.zone().nans, 2);
        assert!(nan_sk.moments.is_empty());
        assert!(nan_sk.trend.is_empty());
    }

    #[test]
    fn sketches_of_covers_every_column() {
        let keys = vec![10, 20, 30];
        let cols = vec![vec![1.0, 2.0, 3.0, 99.0], vec![5.0, 5.0, 5.0, 99.0]];
        let sks = sketches_of(&keys, &cols, 4096);
        assert_eq!(sks.len(), 2);
        assert_eq!(sks[0].moments.count, 3.0);
        assert_eq!(sks[0].moments.max, 3.0, "padding row 3 excluded");
        assert_eq!(sks[1].moments.min, 5.0);
        assert!((sks[0].trend.slope().unwrap() - 0.1).abs() < 1e-9);
        assert_eq!(sks[1].trend.slope(), Some(0.0), "flat column fits a flat line");
    }

    #[test]
    fn block_sketches_retain_the_fold_the_merged_sketch_uses() {
        // The merged sketch must be exactly the fixed-order merge of the
        // retained partials — the invariant covered-block answers rest on.
        let keys: Vec<i64> = (0..10_000).collect();
        let cols = vec![
            (0..10_000)
                .map(|i| if i % 997 == 0 { f32::NAN } else { (i % 173) as f32 })
                .collect::<Vec<f32>>(),
            (0..10_000).map(|i| (i as f32).sin() * 40.0).collect(),
        ];
        let block = 4096usize;
        let (sks, blocks) = sketches_with_blocks(&keys, &cols, block);
        assert_eq!(sks, sketches_of(&keys, &cols, block));
        assert_eq!(blocks.block_rows(), block);
        assert_eq!(blocks.num_columns(), 2);
        assert_eq!(blocks.num_blocks(), 10_000usize.div_ceil(block));
        for (c, sk) in sks.iter().enumerate() {
            let merged = (0..blocks.num_blocks())
                .map(|b| blocks.moments(c, b).unwrap())
                .fold(Moments::EMPTY, Moments::merge);
            assert_eq!(merged, sk.moments, "column {c}");
            // Each partial matches a direct kernel fold of its block.
            for (b, chunk) in cols[c].chunks(block).enumerate() {
                let (mx, mn, sum, sumsq, nans) = fold_stats_f32(chunk);
                let mut want =
                    Moments::from_kernel(mx, mn, sum, sumsq, (chunk.len() - nans) as f32);
                want.nans = nans as f64;
                assert_eq!(blocks.moments(c, b), Some(want), "col {c} block {b}");
            }
        }
        // Per-block zones subsume the partials; out-of-range is empty.
        let z = blocks.zone(0, 0);
        assert_eq!(z.max, 172.0);
        assert!(blocks.zone(0, 99).is_empty());
        assert!(blocks.zone(9, 0).is_empty());
        assert!(blocks.bytes() > 0);
        assert_eq!(blocks.moments(0, 99), None);
    }

    #[test]
    fn block_sketches_codec_round_trips_including_non_finite() {
        let keys: Vec<i64> = (0..9_000).collect();
        let cols = vec![
            (0..9_000).map(|i| (i % 59) as f32).collect::<Vec<f32>>(),
            vec![f32::NAN; 9_000], // all-NaN column → sentinel bounds
        ];
        let (_, blocks) = sketches_with_blocks(&keys, &cols, 4096);
        let bytes = blocks.to_bytes();
        let back = BlockSketches::from_bytes(&bytes).unwrap();
        assert_eq!(back, blocks);
        // Empty sketch set round-trips too.
        let (_, empty) = sketches_with_blocks(&[], &[], 4096);
        assert_eq!(BlockSketches::from_bytes(&empty.to_bytes()).unwrap(), empty);
    }

    #[test]
    fn block_sketches_codec_rejects_garbage() {
        let keys: Vec<i64> = (0..100).collect();
        let cols = vec![(0..100).map(|i| i as f32).collect::<Vec<f32>>()];
        let (_, blocks) = sketches_with_blocks(&keys, &cols, 64);
        let good = blocks.to_bytes();

        // Truncated header and truncated payload.
        assert!(BlockSketches::from_bytes(&good[..4]).is_err());
        assert!(BlockSketches::from_bytes(&good[..good.len() - 1]).is_err());
        // Trailing junk.
        let mut long = good.clone();
        long.push(0);
        assert!(BlockSketches::from_bytes(&long).is_err());
        // Zero block_rows.
        let mut zeroed = good.clone();
        zeroed[..4].copy_from_slice(&0u32.to_le_bytes());
        assert!(BlockSketches::from_bytes(&zeroed).is_err());
        // Hostile header counts must be rejected before allocation.
        let mut huge = good.clone();
        huge[4..8].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(BlockSketches::from_bytes(&huge).is_err());
        let mut huge = good;
        huge[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(BlockSketches::from_bytes(&huge).is_err());
    }

    #[test]
    fn block_classification_covers_prunes_and_scans() {
        // 3 blocks of 4 rows; 10 valid rows (last block is a 2-row stub).
        let keys: Vec<i64> = (0..10).collect();
        let cols = vec![vec![
            1.0,
            1.0,
            1.0,
            1.0, // block 0: zone [1,1]
            5.0,
            6.0,
            7.0,
            8.0, // block 1: zone [5,8]
            2.0,
            f32::NAN, // block 2 (stub): zone [2,2], 1 NaN
        ]];
        let (_, blocks) = sketches_with_blocks(&keys, &cols, 4);
        let classify = |s, e, preds: &[ColumnPredicate], cover| {
            let mut out = Vec::new();
            for_each_block_class(&blocks, 10, s, e, preds, cover, |b, bs, be, c| {
                out.push((b, bs, be, c));
            });
            out
        };

        // Predicate-free full range: interior blocks covered, stub scanned
        // only if partially selected — here fully selected, so covered.
        assert_eq!(
            classify(0, 10, &[], true),
            vec![
                (0, 0, 4, BlockClass::Covered),
                (1, 4, 8, BlockClass::Covered),
                (2, 8, 10, BlockClass::Covered),
            ]
        );
        // Edge slice: remainder blocks scanned, interior covered.
        assert_eq!(
            classify(2, 9, &[], true),
            vec![
                (0, 2, 4, BlockClass::Scanned),
                (1, 4, 8, BlockClass::Covered),
                (2, 8, 9, BlockClass::Scanned),
            ]
        );
        // cover_ok = false downgrades covered to scanned.
        assert_eq!(
            classify(4, 8, &[], false),
            vec![(1, 4, 8, BlockClass::Scanned)]
        );
        // Predicate prunes blocks whose zone cannot satisfy it — even
        // partially-selected ones — and NaN rows never rescue a block.
        let gt4 = [ColumnPredicate { column: 0, op: PredOp::Gt, value: 4.0 }];
        assert_eq!(
            classify(2, 10, &gt4, true),
            vec![
                (0, 2, 4, BlockClass::Pruned),
                (1, 4, 8, BlockClass::Scanned),
                (2, 8, 10, BlockClass::Pruned),
            ]
        );
        // Conjunction: satisfiable per-zone on different blocks only.
        let conj = [
            ColumnPredicate { column: 0, op: PredOp::Gt, value: 4.0 },
            ColumnPredicate { column: 0, op: PredOp::Lt, value: 6.0 },
        ];
        assert_eq!(
            classify(0, 10, &conj, true),
            vec![
                (0, 0, 4, BlockClass::Pruned),
                (1, 4, 8, BlockClass::Scanned),
                (2, 8, 10, BlockClass::Pruned),
            ]
        );
        // Unknown predicate column never prunes.
        let unknown = [ColumnPredicate { column: 7, op: PredOp::Gt, value: 1e9 }];
        assert_eq!(classify(8, 10, &unknown, true), vec![(2, 8, 10, BlockClass::Scanned)]);
        // Over-long row_end clamps to rows; empty range visits nothing.
        assert_eq!(classify(8, 400, &[], true), vec![(2, 8, 10, BlockClass::Covered)]);
        assert!(classify(5, 5, &[], true).is_empty());

        // Counts agree with the walker and satisfy the invariant.
        let counts = count_block_classes(&blocks, 10, 2, 10, &gt4, true);
        assert_eq!(counts.pruned, 2);
        assert_eq!(counts.scanned, 1);
        assert_eq!(counts.covered, 0);
        assert_eq!(counts.considered(), 3);
        assert_eq!(counts.rows_avoided, 2 + 2);
        assert_eq!(counts.rows_scanned, 4);
        let full = count_block_classes(&blocks, 10, 0, 10, &[], true);
        assert_eq!((full.covered, full.rows_avoided, full.rows_scanned), (3, 10, 0));
    }

    #[test]
    fn usable_blocks_gates_on_block_size() {
        let keys: Vec<i64> = (0..100).collect();
        let cols = vec![(0..100).map(|i| i as f32).collect::<Vec<f32>>()];
        let (_, blocks) = sketches_with_blocks(&keys, &cols, 64);
        let arc = Arc::new(blocks);
        assert!(usable_blocks(Some(Arc::clone(&arc)), 64).is_some());
        assert!(usable_blocks(Some(Arc::clone(&arc)), 4096).is_none(), "mis-sized");
        assert!(usable_blocks(None, 64).is_none());
        let (_, empty) = sketches_with_blocks(&[], &[], 64);
        assert!(usable_blocks(Some(Arc::new(empty)), 64).is_none(), "no blocks");
    }

    #[test]
    fn conjunction_helpers() {
        let preds = vec![
            ColumnPredicate { column: 0, op: PredOp::Gt, value: 1.0 },
            ColumnPredicate { column: 1, op: PredOp::Lt, value: 5.0 },
        ];
        let row = [2.0f32, 4.0];
        assert!(row_matches(&preds, |c| row[c]));
        let row = [2.0f32, 6.0];
        assert!(!row_matches(&preds, |c| row[c]));

        let zones = vec![
            ZoneMap { min: 0.0, max: 3.0, nans: 0 },
            ZoneMap { min: 4.0, max: 9.0, nans: 0 },
        ];
        assert!(zones_satisfiable(&preds, &zones));
        let blocked = vec![
            ZoneMap { min: 0.0, max: 1.0, nans: 0 }, // col0 > 1 impossible
            ZoneMap { min: 4.0, max: 9.0, nans: 0 },
        ];
        assert!(!zones_satisfiable(&preds, &blocked));
        // Empty conjunction never prunes, always matches.
        assert!(zones_satisfiable(&[], &zones));
        assert!(row_matches(&[], |_| 0.0));
    }
}
