//! Workload definitions: the paper's five-period interactive analysis
//! (Fig 5) and randomized period workloads for the scaling/ablation
//! benches.

use crate::error::{OsebaError, Result};
use crate::index::RangeQuery;
use crate::util::rng::Xoshiro256;

/// One selective period, as a fraction of the dataset's key span.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PeriodSpec {
    /// Start, as a fraction of the key span in `[0, 1)`.
    pub start_frac: f64,
    /// End fraction in `(start_frac, 1]`.
    pub end_frac: f64,
}

impl PeriodSpec {
    /// Resolve against a concrete key span.
    pub fn resolve(&self, key_min: i64, key_max: i64) -> Result<RangeQuery> {
        if key_max < key_min {
            return Err(OsebaError::InvalidRange("empty dataset".into()));
        }
        let span = (key_max - key_min) as f64;
        let lo = key_min + (span * self.start_frac).round() as i64;
        let hi = key_min + (span * self.end_frac).round() as i64;
        RangeQuery::new(lo, hi)
    }
}

/// The Fig 5 workload: five disjoint periods of varying width spread over
/// the series (eyeballed from the paper's figure; the widths grow toward
/// the middle and shrink again, covering ~45% of the data in total).
pub fn five_periods() -> Vec<PeriodSpec> {
    vec![
        PeriodSpec { start_frac: 0.05, end_frac: 0.13 },
        PeriodSpec { start_frac: 0.20, end_frac: 0.30 },
        PeriodSpec { start_frac: 0.38, end_frac: 0.50 },
        PeriodSpec { start_frac: 0.60, end_frac: 0.70 },
        PeriodSpec { start_frac: 0.82, end_frac: 0.90 },
    ]
}

/// Randomized disjoint periods for sweeps: `n` periods, each covering
/// `width_frac` of the span, uniformly placed without overlap.
pub fn random_periods(n: usize, width_frac: f64, seed: u64) -> Vec<PeriodSpec> {
    assert!(n as f64 * width_frac <= 1.0, "periods would overlap");
    let mut rng = Xoshiro256::seeded(seed);
    // Distribute the leftover space as random gaps between periods.
    let slack = 1.0 - n as f64 * width_frac;
    let mut cuts: Vec<f64> = (0..=n).map(|_| rng.next_f64()).collect();
    let total: f64 = cuts.iter().sum();
    for c in &mut cuts {
        *c = *c / total * slack;
    }
    let mut out = Vec::with_capacity(n);
    let mut pos = 0.0;
    for &gap in cuts.iter().take(n) {
        pos += gap;
        out.push(PeriodSpec { start_frac: pos, end_frac: pos + width_frac });
        pos += width_frac;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn five_periods_disjoint_and_ordered() {
        let ps = five_periods();
        assert_eq!(ps.len(), 5);
        for w in ps.windows(2) {
            assert!(w[0].end_frac < w[1].start_frac);
        }
        let cover: f64 = ps.iter().map(|p| p.end_frac - p.start_frac).sum();
        assert!((0.3..0.6).contains(&cover), "cover={cover}");
    }

    #[test]
    fn resolve_maps_fractions_to_keys() {
        let p = PeriodSpec { start_frac: 0.25, end_frac: 0.75 };
        let q = p.resolve(0, 1000).unwrap();
        assert_eq!(q, RangeQuery { lo: 250, hi: 750 });
        let q = p.resolve(1000, 1000).unwrap(); // single-key span
        assert_eq!(q, RangeQuery { lo: 1000, hi: 1000 });
    }

    #[test]
    fn random_periods_disjoint() {
        for seed in [1u64, 7, 42] {
            let ps = random_periods(8, 0.05, seed);
            assert_eq!(ps.len(), 8);
            for p in &ps {
                assert!((p.end_frac - p.start_frac - 0.05).abs() < 1e-9);
                assert!(p.start_frac >= 0.0 && p.end_frac <= 1.0 + 1e-9);
            }
            for w in ps.windows(2) {
                assert!(w[0].end_frac <= w[1].start_frac + 1e-9);
            }
        }
    }

    #[test]
    fn random_periods_deterministic() {
        assert_eq!(random_periods(3, 0.1, 5), random_periods(3, 0.1, 5));
    }
}
