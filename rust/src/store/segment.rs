//! `.oseg` — the on-disk segment holding one partition, in a
//! dependency-free binary columnar layout (DESIGN.md §8):
//!
//! ```text
//! [magic "OSEG"][version u32][id u64][rows u64][padded_rows u64][width u32]
//! [header crc32]
//! [keys: rows × i64]                [keys crc32]
//! [column 0: padded_rows × f32]     [column crc32]
//! ...
//! [column width-1: ...]             [column crc32]
//! ```
//!
//! All integers and floats are little-endian. Keys are stored unpadded;
//! value columns are stored padded to the kernel block size so a faulted-in
//! partition is bit-identical to the one that was spilled (the AOT
//! static-shape contract, DESIGN.md §3). Every section carries its own
//! hand-rolled CRC-32 ([`crate::store::crc32`]): a flipped byte anywhere is
//! rejected at read time with an error naming the file.

use std::path::Path;
use std::sync::Arc;

use crate::error::{OsebaError, Result};
use crate::index::filter::{filters_of, MembershipFilter};
use crate::index::types::{sketches_with_blocks, BlockSketches, ColumnSketch};
use crate::storage::{Partition, BLOCK_ROWS};
use crate::store::crc32::{crc32, Crc32};
use crate::store::fault::{site, StoreIo};

/// File magic: the first four bytes of every segment.
pub const MAGIC: [u8; 4] = *b"OSEG";
/// Current format version.
pub const VERSION: u32 = 1;

/// Upper bound on row counts accepted from disk — generous (2^40), but
/// small enough that byte-size arithmetic on untrusted headers can never
/// overflow. Shared with the manifest's limit.
pub const MAX_ROWS: usize = 1 << 40;
/// Upper bound on value-column counts accepted from disk.
pub const MAX_WIDTH: usize = 1 << 12;

const HEADER_LEN: usize = 4 + 4 + 8 + 8 + 8 + 4;

/// Serialized size in bytes of a partition's segment (header + sections +
/// per-section CRCs). Used for manifest bookkeeping without re-reading.
pub fn segment_len(rows: usize, padded_rows: usize, width: usize) -> usize {
    HEADER_LEN + 4 + (rows * 8 + 4) + width * (padded_rows * 4 + 4)
}

fn corrupt(path: &Path, detail: impl std::fmt::Display) -> OsebaError {
    OsebaError::Store(format!("segment '{}': {detail}", path.display()))
}

/// Serialize one partition into the `.oseg` byte layout.
pub fn encode_segment(part: &Partition) -> Vec<u8> {
    let width = part.columns.len();
    let mut out = Vec::with_capacity(segment_len(part.rows, part.padded_rows, width));

    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.extend_from_slice(&(part.id as u64).to_le_bytes());
    out.extend_from_slice(&(part.rows as u64).to_le_bytes());
    out.extend_from_slice(&(part.padded_rows as u64).to_le_bytes());
    out.extend_from_slice(&(width as u32).to_le_bytes());
    let hcrc = crc32(&out);
    out.extend_from_slice(&hcrc.to_le_bytes());

    let mut crc = Crc32::new();
    for k in &part.keys {
        let b = k.to_le_bytes();
        crc.update(&b);
        out.extend_from_slice(&b);
    }
    out.extend_from_slice(&crc.finish().to_le_bytes());

    for col in &part.columns {
        let mut crc = Crc32::new();
        for v in col {
            let b = v.to_le_bytes();
            crc.update(&b);
            out.extend_from_slice(&b);
        }
        out.extend_from_slice(&crc.finish().to_le_bytes());
    }
    out
}

/// Write a partition to `path`, returning the bytes written.
pub fn write_segment(path: impl AsRef<Path>, part: &Partition) -> Result<usize> {
    write_segment_with(path, part, &StoreIo::disabled())
}

/// [`write_segment`] through an explicit [`StoreIo`] — the tiered store's
/// spill/save entry point. Follows the crash-safe commit protocol (durable
/// tmp write + rename + directory sync), so a crash mid-spill can leave at
/// most an orphaned `.tmp` for the open-time recovery scan, never a torn
/// `.oseg`.
pub(crate) fn write_segment_with(
    path: impl AsRef<Path>,
    part: &Partition,
    io: &StoreIo,
) -> Result<usize> {
    let path = path.as_ref();
    let bytes = encode_segment(part);
    io.commit(site::SEGMENT_WRITE, path, &bytes)?;
    Ok(bytes.len())
}

struct Reader<'a> {
    path: &'a Path,
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(corrupt(
                self.path,
                format!("truncated while reading {what} (need {n} bytes at offset {})", self.pos),
            ));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }
}

/// Decode one partition from the `.oseg` byte layout. `path` is only used
/// to name the file in errors.
pub fn decode_segment(path: &Path, buf: &[u8]) -> Result<Partition> {
    decode_segment_with(path, buf, None, None, None)
}

/// [`decode_segment`], optionally reusing already-known aggregate
/// sketches, membership filters, and block sketches (the tiered store's
/// slot table keeps the seal-time metadata resident) instead of
/// recomputing them from the decoded data — the fault-in fast path. Pass
/// `None` to recompute; a `Some` whose shape does not match the decoded
/// partition (column count, and for block sketches the kernel block size
/// and block count) is ignored (recomputed), so a caller can never attach
/// mismatched metadata.
pub(crate) fn decode_segment_with(
    path: &Path,
    buf: &[u8],
    known_sketches: Option<Vec<ColumnSketch>>,
    known_filters: Option<Arc<Vec<MembershipFilter>>>,
    known_blocks: Option<Arc<BlockSketches>>,
) -> Result<Partition> {
    let mut r = Reader { path, buf, pos: 0 };

    let magic = r.take(4, "magic")?;
    if magic != MAGIC {
        return Err(corrupt(path, "bad magic (not an .oseg segment)"));
    }
    let version = r.u32("version")?;
    if version != VERSION {
        return Err(corrupt(path, format!("unsupported version {version} (want {VERSION})")));
    }
    let id = r.u64("partition id")? as usize;
    let rows = r.u64("rows")? as usize;
    let padded_rows = r.u64("padded_rows")? as usize;
    let width = r.u32("width")? as usize;
    let stored_hcrc = r.u32("header crc")?;
    let computed_hcrc = crc32(&buf[..HEADER_LEN]);
    if stored_hcrc != computed_hcrc {
        return Err(corrupt(
            path,
            format!("header crc mismatch (stored {stored_hcrc:08x}, computed {computed_hcrc:08x})"),
        ));
    }
    // Bound the (CRC-valid but still untrusted) header fields before any
    // size arithmetic: a crafted header must be a clean error, not an
    // overflow panic or a wrapped length check.
    if rows > MAX_ROWS || width > MAX_WIDTH {
        return Err(corrupt(
            path,
            format!("header out of range (rows {rows}, width {width})"),
        ));
    }
    let expect_padded = rows.div_ceil(BLOCK_ROWS).max(1) * BLOCK_ROWS;
    if padded_rows != expect_padded || rows > padded_rows {
        return Err(corrupt(
            path,
            format!("inconsistent row counts (rows {rows}, padded {padded_rows})"),
        ));
    }
    if buf.len() != segment_len(rows, padded_rows, width) {
        return Err(corrupt(
            path,
            format!(
                "length mismatch (file {} bytes, layout needs {})",
                buf.len(),
                segment_len(rows, padded_rows, width)
            ),
        ));
    }

    let keys_bytes = r.take(rows * 8, "keys")?;
    let stored_kcrc = r.u32("keys crc")?;
    let computed_kcrc = crc32(keys_bytes);
    if stored_kcrc != computed_kcrc {
        return Err(corrupt(
            path,
            format!("keys crc mismatch (stored {stored_kcrc:08x}, computed {computed_kcrc:08x})"),
        ));
    }
    let mut keys = Vec::with_capacity(rows);
    for c in keys_bytes.chunks_exact(8) {
        keys.push(i64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]));
    }
    if keys.windows(2).any(|w| w[0] > w[1]) {
        return Err(corrupt(path, "keys not sorted"));
    }

    let mut columns = Vec::with_capacity(width);
    for ci in 0..width {
        let col_bytes = r.take(padded_rows * 4, "column data")?;
        let stored = r.u32("column crc")?;
        let computed = crc32(col_bytes);
        if stored != computed {
            return Err(corrupt(
                path,
                format!("column {ci} crc mismatch (stored {stored:08x}, computed {computed:08x})"),
            ));
        }
        let mut col = Vec::with_capacity(padded_rows);
        for c in col_bytes.chunks_exact(4) {
            col.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        columns.push(col);
    }

    // Every `Partition` carries valid sketches as an invariant: a decoded
    // partition handed to `TieredStore::insert` (or any future consumer
    // of `Partition::sketches`) must not smuggle in empty metadata that
    // would mis-prune. The fault-in fast path attaches the seal-time
    // sketches the store's slot table already holds (bit-identical by the
    // shared-fold construction); without them — bare `read_segment`, or a
    // store opened from a pre-v3 manifest — they are recomputed from the
    // verified data (one extra O(rows) pass beside the CRC + parse; the
    // blockwise fold matches seal time exactly).
    // Block sketches share the fold with the merged sketches, so a single
    // recompute pass refreshes whichever of the two is missing or
    // mis-shaped (e.g. a store opened from a pre-v5 manifest attaches
    // sketches but must rebuild the per-block partials).
    let good_sketches = known_sketches.filter(|s| s.len() == width);
    let good_blocks = known_blocks.filter(|b| {
        b.block_rows() == BLOCK_ROWS
            && b.num_columns() == width
            && b.num_blocks() == rows.div_ceil(BLOCK_ROWS)
    });
    let (sketches, block_sketches) = match (good_sketches, good_blocks) {
        (Some(sks), Some(bs)) => (sks, bs),
        (sks, bs) => {
            let (rsks, rbs) = sketches_with_blocks(&keys, &columns, BLOCK_ROWS);
            (sks.unwrap_or(rsks), bs.unwrap_or_else(|| Arc::new(rbs)))
        }
    };
    // Membership filters follow the same invariant: attach the resident
    // seal-time filters when the widths agree, else rebuild from the
    // verified data (deterministic, so the rebuild is bit-identical to
    // the seal-time construction over the same values).
    let filters = match known_filters {
        Some(fs) if fs.len() == width => fs,
        _ => Arc::new(filters_of(&columns, rows)),
    };
    Ok(Partition { id, keys, columns, rows, padded_rows, sketches, filters, block_sketches })
}

/// Read a partition back from `path`, verifying every section CRC.
pub fn read_segment(path: impl AsRef<Path>) -> Result<Partition> {
    read_segment_with(path, &StoreIo::disabled(), None, None, None)
}

/// [`read_segment`] through an explicit [`StoreIo`], with optional known
/// sketches, filters, and block sketches (see [`decode_segment_with`]) —
/// the tiered store's fault-in entry point.
pub(crate) fn read_segment_with(
    path: impl AsRef<Path>,
    io: &StoreIo,
    known_sketches: Option<Vec<ColumnSketch>>,
    known_filters: Option<Arc<Vec<MembershipFilter>>>,
    known_blocks: Option<Arc<BlockSketches>>,
) -> Result<Partition> {
    let path = path.as_ref();
    let buf = io.read(site::SEGMENT_READ, path)?;
    decode_segment_with(path, &buf, known_sketches, known_filters, known_blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{partition_batch_uniform, BatchBuilder, Schema};
    use crate::testing::temp_dir;
    use std::sync::Arc;

    fn parts(rows: usize, per: usize) -> Vec<Arc<Partition>> {
        let mut b = BatchBuilder::new(Schema::climate());
        for i in 0..rows {
            b.push(
                i as i64 * 3600,
                &[i as f32 * 0.5, 80.0 - i as f32 * 0.01, 3.0, 180.0],
            );
        }
        partition_batch_uniform(&b.finish().unwrap(), per).unwrap()
    }

    #[test]
    fn roundtrips_bit_for_bit() {
        let dir = temp_dir("seg-rt");
        for (i, p) in parts(10_000, 4096).iter().enumerate() {
            let path = dir.join(format!("p{i}.oseg"));
            let written = write_segment(&path, p).unwrap();
            assert_eq!(written, segment_len(p.rows, p.padded_rows, p.columns.len()));
            let back = read_segment(&path).unwrap();
            assert_eq!(back.id, p.id);
            assert_eq!(back.rows, p.rows);
            assert_eq!(back.padded_rows, p.padded_rows);
            assert_eq!(back.keys, p.keys);
            for (a, b) in back.columns.iter().zip(&p.columns) {
                assert_eq!(
                    a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
                );
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn every_flipped_byte_region_is_caught() {
        let dir = temp_dir("seg-flip");
        let p = &parts(100, 100)[0];
        let path = dir.join("p.oseg");
        write_segment(&path, p).unwrap();
        let clean = std::fs::read(&path).unwrap();
        // One offset in each section: header, keys, a value column.
        for &off in &[5usize, HEADER_LEN + 4 + 11, clean.len() - 9] {
            let mut bad = clean.clone();
            bad[off] ^= 0x40;
            std::fs::write(&path, &bad).unwrap();
            let err = read_segment(&path).unwrap_err();
            let msg = err.to_string();
            assert!(
                msg.contains("p.oseg"),
                "error must name the file, got: {msg}"
            );
            assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_truncation_and_bad_magic() {
        let dir = temp_dir("seg-trunc");
        let p = &parts(50, 50)[0];
        let path = dir.join("p.oseg");
        write_segment(&path, p).unwrap();
        let clean = std::fs::read(&path).unwrap();
        std::fs::write(&path, &clean[..clean.len() / 2]).unwrap();
        assert!(read_segment(&path).is_err());
        std::fs::write(&path, b"NOPE").unwrap();
        assert!(read_segment(&path).is_err());
        let missing = dir.join("missing.oseg");
        let err = read_segment(&missing).unwrap_err();
        assert!(err.to_string().contains("missing.oseg"), "got: {err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
