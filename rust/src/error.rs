//! Crate-wide error type.
//!
//! Every public fallible API in the crate returns [`Result`]. Variants are
//! grouped by subsystem so callers can match on the failure domain (e.g. a
//! server can map `Query*` errors to client-visible messages while treating
//! `Runtime`/`Io` as internal).

use thiserror::Error;

/// Errors produced by the Oseba engine, indexes, runtime and coordinator.
#[derive(Error, Debug)]
pub enum OsebaError {
    /// Dataset construction / schema violations.
    #[error("schema error: {0}")]
    Schema(String),

    /// A query referenced a column that does not exist.
    #[error("unknown column: {0}")]
    UnknownColumn(String),

    /// A range query that cannot be satisfied (e.g. inverted bounds).
    #[error("invalid range: {0}")]
    InvalidRange(String),

    /// Index construction failed (unsorted keys, empty dataset, ...).
    #[error("index error: {0}")]
    Index(String),

    /// The PJRT runtime failed to load/compile/execute an artifact.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// An artifact or its manifest is missing or malformed.
    #[error("artifact error: {0}")]
    Artifact(String),

    /// Cluster/scheduler failures (worker death without reassignment, ...).
    #[error("cluster error: {0}")]
    Cluster(String),

    /// Configuration parse/validation failures.
    #[error("config error: {0}")]
    Config(String),

    /// JSON parse errors (manifest, server protocol).
    #[error("json error: {0}")]
    Json(String),

    /// Memory budget exhausted and eviction could not reclaim enough.
    #[error("out of storage memory: requested {requested} bytes, budget {budget}")]
    OutOfMemory { requested: usize, budget: usize },

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, OsebaError>;

impl From<xla::Error> for OsebaError {
    fn from(e: xla::Error) -> Self {
        OsebaError::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = OsebaError::UnknownColumn("wind".into());
        assert!(e.to_string().contains("unknown column"));
        let e = OsebaError::OutOfMemory { requested: 10, budget: 5 };
        assert!(e.to_string().contains("requested 10"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OsebaError = io.into();
        assert!(matches!(e, OsebaError::Io(_)));
    }
}
