//! Metadata extraction: build [`PartitionMeta`] records from loaded
//! partitions (the "record the metadata of each data block" step, §III-A)
//! and the shared per-partition range-intersection arithmetic.

use std::sync::Arc;

use crate::index::types::{PartitionMeta, PartitionSlice, RangeQuery};
use crate::storage::Partition;

/// Extract per-partition metadata in partition order. Detects the
/// within-partition key step when the grid is uniform (the common case for
/// temporal data, paper §III-B fact (2)).
pub fn extract_meta(parts: &[Arc<Partition>]) -> Vec<PartitionMeta> {
    parts
        .iter()
        .map(|p| {
            let key_min = p.key_min().unwrap_or(0);
            let key_max = p.key_max().unwrap_or(0);
            let step = detect_step(&p.keys);
            PartitionMeta { id: p.id, key_min, key_max, rows: p.rows, step }
        })
        .collect()
}

/// Uniform step of a sorted key vector, or `None` when irregular. A
/// single-row partition reports `None` (no step is observable).
pub fn detect_step(keys: &[i64]) -> Option<i64> {
    if keys.len() < 2 {
        return None;
    }
    let s = keys[1] - keys[0];
    if s <= 0 {
        return None;
    }
    keys.windows(2).all(|w| w[1] - w[0] == s).then_some(s)
}

/// Ceiling division for a possibly-negative numerator, positive divisor.
pub fn ceil_div(a: i64, b: i64) -> i64 {
    debug_assert!(b > 0);
    a.div_euclid(b) + i64::from(a.rem_euclid(b) != 0)
}

/// Intersect an inclusive key query with one partition's metadata,
/// producing the valid-row slice. When the partition's internal step is
/// unknown (`meta.step == None`), the index cannot compute row offsets and
/// conservatively returns the whole partition — the engine refines it with
/// a binary search over that partition's keys (both index implementations
/// share this behaviour, keeping the table-vs-CIAS comparison fair).
pub fn slice_for_meta(meta: &PartitionMeta, q: RangeQuery) -> Option<PartitionSlice> {
    if meta.rows == 0 || q.hi < meta.key_min || q.lo > meta.key_max {
        return None;
    }
    match meta.step {
        Some(s) => {
            let row_start = if q.lo <= meta.key_min {
                0
            } else {
                ceil_div(q.lo - meta.key_min, s).max(0) as usize
            };
            let row_end = if q.hi >= meta.key_max {
                meta.rows
            } else {
                ((q.hi - meta.key_min).div_euclid(s) + 1).max(0) as usize
            };
            let row_end = row_end.min(meta.rows);
            (row_start < row_end).then_some(PartitionSlice {
                partition: meta.id,
                row_start,
                row_end,
            })
        }
        None => Some(PartitionSlice { partition: meta.id, row_start: 0, row_end: meta.rows }),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{BatchBuilder, Schema};

    fn parts(rows: usize, per: usize) -> Vec<Arc<Partition>> {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..rows {
            b.push(100 + i as i64 * 5, &[i as f32, 0.0]);
        }
        let batch = b.finish().unwrap();
        crate::storage::partition_batch_uniform(&batch, per).unwrap()
    }

    #[test]
    fn extract_detects_step_and_bounds() {
        let metas = extract_meta(&parts(100, 40));
        assert_eq!(metas.len(), 3);
        assert_eq!(metas[0], PartitionMeta { id: 0, key_min: 100, key_max: 100 + 39 * 5, rows: 40, step: Some(5) });
        assert_eq!(metas[2].rows, 20);
        assert_eq!(metas[2].step, Some(5));
    }

    #[test]
    fn detect_step_irregular() {
        assert_eq!(detect_step(&[1, 2, 4]), None);
        assert_eq!(detect_step(&[1]), None);
        assert_eq!(detect_step(&[]), None);
        assert_eq!(detect_step(&[3, 3]), None); // zero step is "irregular"
        assert_eq!(detect_step(&[0, 7, 14]), Some(7));
    }

    #[test]
    fn ceil_div_negatives() {
        assert_eq!(ceil_div(7, 2), 4);
        assert_eq!(ceil_div(6, 2), 3);
        assert_eq!(ceil_div(-7, 2), -3);
        assert_eq!(ceil_div(0, 5), 0);
    }

    #[test]
    fn slice_exact_interior() {
        let m = PartitionMeta { id: 3, key_min: 100, key_max: 195, rows: 20, step: Some(5) };
        // Keys 100,105,...,195. Query [110, 120] → rows 2..5.
        let s = slice_for_meta(&m, RangeQuery { lo: 110, hi: 120 }).unwrap();
        assert_eq!(s, PartitionSlice { partition: 3, row_start: 2, row_end: 5 });
    }

    #[test]
    fn slice_unaligned_bounds() {
        let m = PartitionMeta { id: 0, key_min: 100, key_max: 195, rows: 20, step: Some(5) };
        // [111, 119] → first key ≥111 is 115 (row 3); last key ≤119 is 115.
        let s = slice_for_meta(&m, RangeQuery { lo: 111, hi: 119 }).unwrap();
        assert_eq!((s.row_start, s.row_end), (3, 4));
        // [111, 113] → no key inside.
        assert!(slice_for_meta(&m, RangeQuery { lo: 111, hi: 113 }).is_none());
    }

    #[test]
    fn slice_covers_whole_partition() {
        let m = PartitionMeta { id: 1, key_min: 100, key_max: 195, rows: 20, step: Some(5) };
        let s = slice_for_meta(&m, RangeQuery { lo: 0, hi: 10_000 }).unwrap();
        assert_eq!((s.row_start, s.row_end), (0, 20));
    }

    #[test]
    fn slice_disjoint_is_none() {
        let m = PartitionMeta { id: 1, key_min: 100, key_max: 195, rows: 20, step: Some(5) };
        assert!(slice_for_meta(&m, RangeQuery { lo: 0, hi: 99 }).is_none());
        assert!(slice_for_meta(&m, RangeQuery { lo: 196, hi: 300 }).is_none());
    }

    #[test]
    fn slice_irregular_returns_full_partition() {
        let m = PartitionMeta { id: 2, key_min: 10, key_max: 50, rows: 7, step: None };
        let s = slice_for_meta(&m, RangeQuery { lo: 20, hi: 30 }).unwrap();
        assert_eq!((s.row_start, s.row_end), (0, 7));
    }

    #[test]
    fn slice_boundary_keys_inclusive() {
        let m = PartitionMeta { id: 0, key_min: 100, key_max: 195, rows: 20, step: Some(5) };
        let s = slice_for_meta(&m, RangeQuery { lo: 195, hi: 195 }).unwrap();
        assert_eq!((s.row_start, s.row_end), (19, 20));
        let s = slice_for_meta(&m, RangeQuery { lo: 100, hi: 100 }).unwrap();
        assert_eq!((s.row_start, s.row_end), (0, 1));
    }
}
