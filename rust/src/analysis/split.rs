//! Model-training data splits (paper §II: "data are usually grouped into
//! three parts: Training, Tests and Validation ... randomly select 10
//! years weather data to training a model").
//!
//! A split is expressed as *period assignments*: the key span is divided
//! into equal period-sized units (e.g. years) and each unit is randomly
//! assigned to train/test/validation. The output is three lists of
//! [`RangeQuery`]s — which Oseba then serves without any scan.

use crate::error::{OsebaError, Result};
use crate::index::RangeQuery;
use crate::util::rng::Xoshiro256;

/// Split specification.
#[derive(Clone, Copy, Debug)]
pub struct SplitSpec {
    /// Unit length in key units (e.g. one year of seconds).
    pub unit_keys: i64,
    /// Fraction of units assigned to training.
    pub train_frac: f64,
    /// Fraction assigned to test (validation gets the rest).
    pub test_frac: f64,
    /// RNG seed for the unit shuffle.
    pub seed: u64,
}

/// The three query lists.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Split {
    /// Training-period queries, sorted by key.
    pub train: Vec<RangeQuery>,
    /// Test-period queries, sorted by key.
    pub test: Vec<RangeQuery>,
    /// Validation-period queries, sorted by key.
    pub validation: Vec<RangeQuery>,
}

/// Assign whole units across `[key_min, key_max]` to train/test/validation.
pub fn train_test_split(key_min: i64, key_max: i64, spec: SplitSpec) -> Result<Split> {
    if spec.unit_keys <= 0 {
        return Err(OsebaError::InvalidRange("unit_keys must be > 0".into()));
    }
    if !(0.0..=1.0).contains(&spec.train_frac)
        || !(0.0..=1.0).contains(&spec.test_frac)
        || spec.train_frac + spec.test_frac > 1.0
    {
        return Err(OsebaError::InvalidRange("bad split fractions".into()));
    }
    let span = key_max
        .checked_sub(key_min)
        .filter(|s| *s >= 0)
        .ok_or_else(|| OsebaError::InvalidRange("key_max < key_min".into()))?;
    let units = (span / spec.unit_keys + 1).max(1) as usize;

    let mut order: Vec<usize> = (0..units).collect();
    let mut rng = Xoshiro256::seeded(spec.seed);
    rng.shuffle(&mut order);

    let n_train = (units as f64 * spec.train_frac).round() as usize;
    let n_test = (units as f64 * spec.test_frac).round() as usize;

    let mut split = Split::default();
    for (rank, &u) in order.iter().enumerate() {
        let lo = key_min + u as i64 * spec.unit_keys;
        let hi = (lo + spec.unit_keys - 1).min(key_max);
        let q = RangeQuery::new(lo, hi)?;
        if rank < n_train {
            split.train.push(q);
        } else if rank < n_train + n_test {
            split.test.push(q);
        } else {
            split.validation.push(q);
        }
    }
    // Deterministic presentation order.
    for v in [&mut split.train, &mut split.test, &mut split.validation] {
        v.sort_by_key(|q| q.lo);
    }
    Ok(split)
}

#[cfg(test)]
mod tests {
    use super::*;

    const YEAR: i64 = 365 * 24 * 3600;

    fn spec(seed: u64) -> SplitSpec {
        SplitSpec { unit_keys: YEAR, train_frac: 0.6, test_frac: 0.2, seed }
    }

    #[test]
    fn partitions_all_units_disjointly() {
        let s = train_test_split(0, 20 * YEAR - 1, spec(3)).unwrap();
        let total = s.train.len() + s.test.len() + s.validation.len();
        assert_eq!(total, 20);
        assert_eq!(s.train.len(), 12);
        assert_eq!(s.test.len(), 4);
        assert_eq!(s.validation.len(), 4);
        // Disjoint coverage of the whole span.
        let mut all: Vec<RangeQuery> =
            s.train.iter().chain(&s.test).chain(&s.validation).cloned().collect();
        all.sort_by_key(|q| q.lo);
        assert_eq!(all[0].lo, 0);
        for w in all.windows(2) {
            assert_eq!(w[0].hi + 1, w[1].lo);
        }
        assert_eq!(all.last().unwrap().hi, 20 * YEAR - 1);
    }

    #[test]
    fn deterministic_per_seed_and_differs_across_seeds() {
        let a = train_test_split(0, 10 * YEAR, spec(1)).unwrap();
        let b = train_test_split(0, 10 * YEAR, spec(1)).unwrap();
        assert_eq!(a, b);
        let c = train_test_split(0, 10 * YEAR, spec(2)).unwrap();
        assert_ne!(a.train, c.train);
    }

    #[test]
    fn rejects_bad_specs() {
        assert!(train_test_split(0, YEAR, SplitSpec { unit_keys: 0, ..spec(1) }).is_err());
        assert!(train_test_split(
            0,
            YEAR,
            SplitSpec { train_frac: 0.9, test_frac: 0.3, ..spec(1) }
        )
        .is_err());
        assert!(train_test_split(10, 0, spec(1)).is_err());
    }

    #[test]
    fn single_unit_goes_somewhere() {
        let s = train_test_split(0, 100, SplitSpec { unit_keys: 1000, ..spec(1) }).unwrap();
        assert_eq!(s.train.len() + s.test.len() + s.validation.len(), 1);
    }
}
