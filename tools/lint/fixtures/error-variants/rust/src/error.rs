//! Seeded violation: `NeverBuilt` is declared but no code constructs it.

/// Error enum with a dead variant.
pub enum OsebaError {
    /// Constructed in uses.rs.
    Used(String),
    /// Constructed nowhere — the seeded violation.
    NeverBuilt(String),
}
