//! Quickstart: load a dataset, build the CIAS index, and run one selective
//! period analysis both ways — showing the memory and scan savings.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//! Uses the HLO backend when `artifacts/` exists, else falls back to the
//! native backend.

use oseba::config::{AppConfig, BackendKind};
use oseba::coordinator::Coordinator;
use oseba::datagen::ClimateGen;
use oseba::index::{Cias, ContentIndex, RangeQuery};
use oseba::runtime::make_backend;
use oseba::util::humansize;

fn main() -> oseba::Result<()> {
    // 1. Configuration: ~32 MiB of synthetic hourly climate data over 15
    //    partitions (the paper's partition count, scaled-down volume).
    let mut cfg = AppConfig { dataset_bytes: 32 << 20, ..AppConfig::default() };
    let backend_kind = if std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        BackendKind::Hlo
    } else {
        eprintln!("(artifacts not built; using the native backend)");
        BackendKind::Native
    };
    let backend = make_backend(backend_kind, &cfg.artifacts_dir)?;
    let coord = Coordinator::new(&cfg, backend)?;

    // 2. Load.
    let batch = ClimateGen::default().generate_bytes(cfg.dataset_bytes);
    println!("dataset: {} rows, {}", batch.rows(), humansize::bytes(batch.raw_bytes()));
    let ds = coord.load(batch, cfg.num_partitions)?;
    println!(
        "loaded into {} partitions, cached {}",
        ds.num_partitions(),
        humansize::bytes(coord.context().memory_used())
    );

    // 3. Index: the whole partition table compresses to four integers.
    let index = Cias::build(ds.partitions())?;
    println!(
        "CIAS: \"{}\" + {} ASL entries = {}",
        index.compressed_repr(),
        index.asl_len(),
        humansize::bytes(index.memory_bytes())
    );

    // 4. One selective analysis: days 100..160 of the series.
    let q = RangeQuery::new(100 * 24 * 3600, 160 * 24 * 3600)?;

    let mem0 = coord.context().memory_used();
    let t = std::time::Instant::now();
    let (stats_default, filtered) = coord.analyze_period_default(&ds, q, 0)?;
    let default_secs = t.elapsed().as_secs_f64();
    let default_mem_growth = coord.context().memory_used() - mem0;

    let t = std::time::Instant::now();
    let stats_oseba = coord.analyze_period_oseba(&ds, &index, q, 0)?;
    let oseba_secs = t.elapsed().as_secs_f64();
    let oseba_mem_growth = coord.context().memory_used() - mem0 - filtered.bytes();

    println!("\n{:<22} {:>14} {:>14}", "", "default", "oseba");
    println!(
        "{:<22} {:>14} {:>14}",
        "time",
        humansize::secs(default_secs),
        humansize::secs(oseba_secs)
    );
    println!(
        "{:<22} {:>14} {:>14}",
        "memory growth",
        humansize::bytes(default_mem_growth),
        humansize::bytes(oseba_mem_growth)
    );
    let c = coord.context().counters();
    println!(
        "{:<22} {:>14} {:>14}",
        "partitions touched", c.partitions_scanned, c.partitions_targeted
    );

    println!(
        "\ntemperature over days 100..160: n={} max={:.2} min={:.2} mean={:.2} std={:.2}",
        stats_oseba.count, stats_oseba.max, stats_oseba.min, stats_oseba.mean, stats_oseba.std
    );
    assert_eq!(stats_default.count, stats_oseba.count);
    assert_eq!(stats_default.max, stats_oseba.max);
    println!("(both methods agree exactly)");

    // 5. Clean up the baseline's residue — the step Spark users forget,
    //    and the reason Fig 4's default curve climbs.
    coord.context().unpersist(&filtered);
    Ok(())
}
