//! Seeded violation: panicking macros outside test scope.

pub fn f(x: u32) -> u32 {
    if x == 0 {
        panic!("zero");
    }
    match x {
        1 => unreachable!("one"),
        _ => x,
    }
}
