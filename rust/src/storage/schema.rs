//! Dataset schema: an ordered key column (i64, e.g. a timestamp) plus named
//! f32 value columns. This mirrors the paper's experimental data layout
//! ("time, temperature, humidity, wind speed and direction", §IV-A).

use crate::error::{OsebaError, Result};

/// Schema of a columnar time-series dataset.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Schema {
    /// Name of the ordering key column (monotonically non-decreasing i64).
    pub key: String,
    /// Names of the f32 value columns, in storage order.
    pub columns: Vec<String>,
}

impl Schema {
    /// Build a schema; column names must be unique and non-empty.
    pub fn new(key: impl Into<String>, columns: &[&str]) -> Result<Schema> {
        let key = key.into();
        if key.is_empty() {
            return Err(OsebaError::Schema("empty key column name".into()));
        }
        let mut seen = std::collections::HashSet::new();
        for &c in columns {
            if c.is_empty() {
                return Err(OsebaError::Schema("empty column name".into()));
            }
            if c == key || !seen.insert(c) {
                return Err(OsebaError::Schema(format!("duplicate column '{c}'")));
            }
        }
        Ok(Schema { key, columns: columns.iter().map(|s| s.to_string()).collect() })
    }

    /// The paper's climate schema (§IV-A).
    pub fn climate() -> Schema {
        Schema::new("time", &["temperature", "humidity", "wind_speed", "wind_dir"])
            // lint: allow(no-unwrap) -- static column list, provably valid.
            .expect("static schema")
    }

    /// A stock-tick schema for the moving-average example.
    pub fn stock() -> Schema {
        // lint: allow(no-unwrap) -- static column list, provably valid.
        Schema::new("time", &["price", "volume"]).expect("static schema")
    }

    /// A call-detail-record schema for the events-analysis example.
    pub fn cdr() -> Schema {
        Schema::new("time", &["duration", "dest_prefix", "hour_of_day"])
            // lint: allow(no-unwrap) -- static column list, provably valid.
            .expect("static schema")
    }

    /// Index of a value column by name.
    pub fn column_index(&self, name: &str) -> Result<usize> {
        self.columns
            .iter()
            .position(|c| c == name)
            .ok_or_else(|| OsebaError::UnknownColumn(name.to_string()))
    }

    /// Number of value columns.
    pub fn width(&self) -> usize {
        self.columns.len()
    }

    /// Bytes per row (key + values) — the raw-data sizing used by Fig 4.
    pub fn row_bytes(&self) -> usize {
        8 + 4 * self.columns.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_indexes() {
        let s = Schema::climate();
        assert_eq!(s.width(), 4);
        assert_eq!(s.column_index("temperature").unwrap(), 0);
        assert_eq!(s.column_index("wind_dir").unwrap(), 3);
        assert!(s.column_index("nope").is_err());
    }

    #[test]
    fn rejects_duplicates_and_empties() {
        assert!(Schema::new("t", &["a", "a"]).is_err());
        assert!(Schema::new("t", &["t"]).is_err());
        assert!(Schema::new("", &["a"]).is_err());
        assert!(Schema::new("t", &[""]).is_err());
    }

    #[test]
    fn row_bytes_counts_key_and_values() {
        assert_eq!(Schema::climate().row_bytes(), 8 + 16);
        assert_eq!(Schema::stock().row_bytes(), 8 + 8);
    }
}
