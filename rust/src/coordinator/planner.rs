//! Query planning: the access-method / index vocabulary a session uses,
//! plus the multi-query batch planner.
//!
//! [`plan_batch`] turns N possibly-overlapping selective queries (many
//! interactive users hitting the same dataset) into a minimal set of
//! disjoint merged ranges, so the cluster is routed **once** per merged
//! range — overlapping queries target each intersecting partition once
//! per merged range instead of once per query. [`PlannedQuery::segments`]
//! then cuts a merged
//! range into maximal sub-ranges on which the covering query set is
//! constant, which is what lets the coordinator demultiplex exact
//! per-query statistics from shared partials.

use crate::error::{OsebaError, Result};
use crate::index::RangeQuery;

/// Index implementation selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    /// §III-A table (O(m) space, O(log m) lookup).
    Table,
    /// §III-B compressed index + associated search list.
    Cias,
}

impl std::str::FromStr for IndexKind {
    type Err = OsebaError;

    fn from_str(s: &str) -> Result<IndexKind> {
        match s {
            "table" => Ok(IndexKind::Table),
            "cias" => Ok(IndexKind::Cias),
            other => Err(OsebaError::Config(format!("unknown index kind '{other}'"))),
        }
    }
}

/// Access-path selector for a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Spark-style scan-filter-materialize (the paper's baseline).
    Default,
    /// Index-targeted zero-copy access (the paper's contribution).
    Oseba,
}

impl Method {
    /// Short label used in metrics tables and the JSON protocol.
    pub fn label(&self) -> &'static str {
        match self {
            Method::Default => "default",
            Method::Oseba => "oseba",
        }
    }
}

impl std::str::FromStr for Method {
    type Err = OsebaError;

    fn from_str(s: &str) -> Result<Method> {
        match s {
            "default" => Ok(Method::Default),
            "oseba" => Ok(Method::Oseba),
            other => Err(OsebaError::Config(format!("unknown method '{other}'"))),
        }
    }
}

/// One merged range of a batch plan: a disjoint inclusive key range plus
/// the indices (into the input batch) of the queries it covers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlannedQuery {
    /// The merged range routed to the cluster.
    pub range: RangeQuery,
    /// Indices of the input queries whose union this range is, ascending.
    pub sources: Vec<usize>,
}

impl PlannedQuery {
    /// Cut this merged range into maximal *elementary segments*: disjoint
    /// sub-ranges on which the set of covering source queries is constant.
    /// Returns `(segment, covering source indices)` in key order; the
    /// segments partition `self.range` exactly (the merged range is the
    /// union of its sources, so no sub-range is uncovered).
    pub fn segments(&self, queries: &[RangeQuery]) -> Vec<(RangeQuery, Vec<usize>)> {
        // Cut positions in i128 so `hi + 1` cannot overflow at i64::MAX.
        let mut cuts: Vec<i128> = Vec::with_capacity(2 * self.sources.len());
        for &i in &self.sources {
            cuts.push(queries[i].lo as i128);
            cuts.push(queries[i].hi as i128 + 1);
        }
        cuts.sort_unstable();
        cuts.dedup();
        let mut out = Vec::new();
        for w in cuts.windows(2) {
            let seg = RangeQuery { lo: w[0] as i64, hi: (w[1] - 1) as i64 };
            let covering: Vec<usize> = self
                .sources
                .iter()
                .copied()
                .filter(|&i| queries[i].lo <= seg.lo && seg.hi <= queries[i].hi)
                .collect();
            if !covering.is_empty() {
                out.push((seg, covering));
            }
        }
        out
    }
}

/// Plan a batch of selective queries: sort by range, drop inverted
/// (`lo > hi`) inputs, dedupe identical/contained ranges, and merge
/// overlapping or adjacent ones (inclusive integer ranges: `[a, b]` and
/// `[b + 1, c]` merge into `[a, c]`).
///
/// Invariants of the output:
/// * planned ranges are sorted, pairwise disjoint and non-adjacent;
/// * their union equals the union of the (valid) input ranges;
/// * every valid input index appears in exactly one `sources` list.
pub fn plan_batch(queries: &[RangeQuery]) -> Vec<PlannedQuery> {
    let mut order: Vec<usize> =
        (0..queries.len()).filter(|&i| queries[i].lo <= queries[i].hi).collect();
    order.sort_by_key(|&i| (queries[i].lo, queries[i].hi));
    let mut out: Vec<PlannedQuery> = Vec::new();
    for i in order {
        let q = queries[i];
        match out.last_mut() {
            // i128 so `hi + 1` cannot overflow when a range ends at i64::MAX.
            Some(last) if (q.lo as i128) <= (last.range.hi as i128) + 1 => {
                if q.hi > last.range.hi {
                    last.range.hi = q.hi;
                }
                last.sources.push(i);
            }
            _ => out.push(PlannedQuery { range: q, sources: vec![i] }),
        }
    }
    for pq in &mut out {
        pq.sources.sort_unstable();
    }
    out
}

/// Check the invariants [`plan_batch`] and [`PlannedQuery::segments`]
/// promise (the batch half of DESIGN.md §12), against the original input
/// batch:
///
/// * planned ranges are sorted, pairwise disjoint and non-adjacent, none
///   inverted;
/// * every valid input query appears in exactly one `sources` list
///   (ascending, no duplicates), is contained in its merged range, and
///   the merged range is exactly the hull of its sources;
/// * the elementary segments tile each merged range: they start at its
///   `lo`, end at its `hi`, leave no gaps, and every covering query
///   really contains its segment.
///
/// Violations surface as [`OsebaError::Plan`] — always a planner bug.
/// Pure metadata; the coordinator runs this on every batch in debug
/// builds.
pub fn verify_batch(queries: &[RangeQuery], plan: &[PlannedQuery]) -> Result<()> {
    let err = |m: String| Err(OsebaError::Plan(m));
    for w in plan.windows(2) {
        // i128: `hi + 1` must not overflow when a range ends at i64::MAX.
        if (w[1].range.lo as i128) <= (w[0].range.hi as i128) + 1 {
            return err(format!(
                "batch ranges not sorted/disjoint/non-adjacent: [{}, {}] then [{}, {}]",
                w[0].range.lo, w[0].range.hi, w[1].range.lo, w[1].range.hi
            ));
        }
    }
    let mut owner: Vec<Option<usize>> = vec![None; queries.len()];
    for (pi, pq) in plan.iter().enumerate() {
        if pq.range.lo > pq.range.hi {
            return err(format!(
                "batch range [{}, {}] is inverted",
                pq.range.lo, pq.range.hi
            ));
        }
        if pq.sources.is_empty() {
            return err(format!(
                "batch range [{}, {}] has no source queries",
                pq.range.lo, pq.range.hi
            ));
        }
        if pq.sources.windows(2).any(|w| w[0] >= w[1]) {
            return err(format!(
                "sources of batch range {pi} are not strictly ascending: {:?}",
                pq.sources
            ));
        }
        let (mut lo, mut hi) = (i64::MAX, i64::MIN);
        for &i in &pq.sources {
            let Some(q) = queries.get(i) else {
                return err(format!(
                    "batch range {pi} references query {i}, but the batch has {}",
                    queries.len()
                ));
            };
            if q.lo > q.hi {
                return err(format!(
                    "batch range {pi} claims inverted input query {i}"
                ));
            }
            if let Some(prev) = owner[i].replace(pi) {
                return err(format!(
                    "query {i} appears in batch ranges {prev} and {pi}"
                ));
            }
            if q.lo < pq.range.lo || pq.range.hi < q.hi {
                return err(format!(
                    "query {i} [{}, {}] is not contained in its merged range [{}, {}]",
                    q.lo, q.hi, pq.range.lo, pq.range.hi
                ));
            }
            lo = lo.min(q.lo);
            hi = hi.max(q.hi);
        }
        if lo != pq.range.lo || hi != pq.range.hi {
            return err(format!(
                "merged range [{}, {}] is not the hull of its sources ([{lo}, {hi}])",
                pq.range.lo, pq.range.hi
            ));
        }
        // The demux segments must tile the merged range exactly.
        let segs = pq.segments(queries);
        match (segs.first(), segs.last()) {
            (Some(first), Some(last))
                if first.0.lo == pq.range.lo && last.0.hi == pq.range.hi => {}
            _ => {
                return err(format!(
                    "segments of batch range {pi} do not span [{}, {}]",
                    pq.range.lo, pq.range.hi
                ));
            }
        }
        for w in segs.windows(2) {
            if (w[1].0.lo as i128) != (w[0].0.hi as i128) + 1 {
                return err(format!(
                    "segments of batch range {pi} leave a gap between key {} and key {}",
                    w[0].0.hi, w[1].0.lo
                ));
            }
        }
        for (seg, covering) in &segs {
            for &i in covering {
                if queries[i].lo > seg.lo || seg.hi > queries[i].hi {
                    return err(format!(
                        "segment [{}, {}] lists query {i} [{}, {}] as covering, \
                         but the query does not contain it",
                        seg.lo, seg.hi, queries[i].lo, queries[i].hi
                    ));
                }
            }
        }
    }
    for (i, q) in queries.iter().enumerate() {
        if q.lo <= q.hi && owner[i].is_none() {
            return err(format!("valid query {i} was dropped by the batch plan"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q(lo: i64, hi: i64) -> RangeQuery {
        RangeQuery { lo, hi }
    }

    #[test]
    fn parsing() {
        assert_eq!("cias".parse::<IndexKind>().unwrap(), IndexKind::Cias);
        assert_eq!("table".parse::<IndexKind>().unwrap(), IndexKind::Table);
        assert!("btree".parse::<IndexKind>().is_err());
        assert_eq!("oseba".parse::<Method>().unwrap(), Method::Oseba);
        assert_eq!("default".parse::<Method>().unwrap(), Method::Default);
        assert!("spark".parse::<Method>().is_err());
        assert_eq!(Method::Oseba.label(), "oseba");
    }

    #[test]
    fn plan_empty_and_single() {
        assert!(plan_batch(&[]).is_empty());
        let plan = plan_batch(&[q(5, 9)]);
        assert_eq!(plan, vec![PlannedQuery { range: q(5, 9), sources: vec![0] }]);
    }

    #[test]
    fn plan_merges_overlapping_and_adjacent() {
        // [0,10] ∪ [5,20] overlap; [21,30] is adjacent to [0,20]; [50,60]
        // stands alone.
        let plan = plan_batch(&[q(50, 60), q(0, 10), q(21, 30), q(5, 20)]);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].range, q(0, 30));
        assert_eq!(plan[0].sources, vec![1, 2, 3]);
        assert_eq!(plan[1].range, q(50, 60));
        assert_eq!(plan[1].sources, vec![0]);
    }

    #[test]
    fn plan_keeps_gapped_ranges_apart() {
        // [0,10] and [12,20] leave key 11 unselected: no merge.
        let plan = plan_batch(&[q(12, 20), q(0, 10)]);
        assert_eq!(plan.len(), 2);
        assert_eq!(plan[0].range, q(0, 10));
        assert_eq!(plan[1].range, q(12, 20));
    }

    #[test]
    fn plan_dedupes_identical_and_contained() {
        let plan = plan_batch(&[q(0, 100), q(0, 100), q(30, 40)]);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].range, q(0, 100));
        assert_eq!(plan[0].sources, vec![0, 1, 2]);
    }

    #[test]
    fn plan_skips_inverted_ranges() {
        let plan = plan_batch(&[q(9, 1), q(2, 4)]);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].sources, vec![1]);
    }

    #[test]
    fn plan_handles_extreme_bounds() {
        let plan = plan_batch(&[q(i64::MAX - 10, i64::MAX), q(i64::MAX - 3, i64::MAX)]);
        assert_eq!(plan.len(), 1);
        assert_eq!(plan[0].range, q(i64::MAX - 10, i64::MAX));
    }

    #[test]
    fn plan_sources_partition_the_inputs() {
        let qs = [q(0, 5), q(100, 200), q(3, 40), q(150, 160), q(300, 300)];
        let plan = plan_batch(&qs);
        let mut seen: Vec<usize> = plan.iter().flat_map(|p| p.sources.clone()).collect();
        seen.sort_unstable();
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
        // Disjoint and non-adjacent.
        for w in plan.windows(2) {
            assert!(w[0].range.hi + 1 < w[1].range.lo, "{plan:?}");
        }
    }

    #[test]
    fn segments_split_on_constant_covering_sets() {
        // [0,10] and [5,20] merge into [0,20] with three elementary
        // segments: [0,4] covered by {0}, [5,10] by {0,1}, [11,20] by {1}.
        let qs = [q(0, 10), q(5, 20)];
        let plan = plan_batch(&qs);
        assert_eq!(plan.len(), 1);
        let segs = plan[0].segments(&qs);
        assert_eq!(
            segs,
            vec![
                (q(0, 4), vec![0]),
                (q(5, 10), vec![0, 1]),
                (q(11, 20), vec![1]),
            ]
        );
    }

    #[test]
    fn verify_batch_accepts_planner_output() {
        let cases: Vec<Vec<RangeQuery>> = vec![
            vec![],
            vec![q(5, 9)],
            vec![q(50, 60), q(0, 10), q(21, 30), q(5, 20)],
            vec![q(12, 20), q(0, 10)],
            vec![q(0, 100), q(0, 100), q(30, 40)],
            vec![q(9, 1), q(2, 4)],
            vec![q(i64::MAX - 10, i64::MAX), q(i64::MAX - 3, i64::MAX)],
            vec![q(0, 5), q(100, 200), q(3, 40), q(150, 160), q(300, 300)],
        ];
        for qs in &cases {
            verify_batch(qs, &plan_batch(qs)).unwrap();
        }
        // Seeded fuzz: random batches must always verify.
        use crate::util::rng::Xoshiro256;
        for seed in 0..64u64 {
            let mut rng = Xoshiro256::seeded(seed);
            let n = rng.range_u64(1, 24) as usize;
            let qs: Vec<RangeQuery> = (0..n)
                .map(|_| {
                    let a = rng.range_u64(0, 10_000) as i64;
                    let b = rng.range_u64(0, 10_000) as i64;
                    // Leave ~1 in 8 inverted to exercise the drop path.
                    if rng.below(8) == 0 { q(a.max(b), a.min(b).min(a.max(b) - 1)) } else { q(a.min(b), a.max(b)) }
                })
                .collect();
            verify_batch(&qs, &plan_batch(&qs))
                .unwrap_or_else(|e| panic!("seed {seed}: {e}\nbatch: {qs:?}"));
        }
    }

    #[test]
    fn verify_batch_rejects_corrupted_plans() {
        let qs = [q(0, 10), q(5, 20), q(50, 60)];
        let plan = plan_batch(&qs);
        assert_eq!(plan.len(), 2);
        verify_batch(&qs, &plan).unwrap();

        let expect = |p: &[PlannedQuery], needle: &str| {
            let msg = verify_batch(&qs, p).unwrap_err().to_string();
            assert!(msg.contains("plan invariant"), "got: {msg}");
            assert!(msg.contains(needle), "wanted '{needle}' in: {msg}");
        };

        // Out of order.
        let mut bad = plan.clone();
        bad.swap(0, 1);
        expect(&bad, "not sorted");

        // Adjacent ranges that should have merged.
        let bad = vec![
            PlannedQuery { range: q(0, 20), sources: vec![0, 1] },
            PlannedQuery { range: q(21, 60), sources: vec![2] },
        ];
        expect(&bad, "non-adjacent");

        // A dropped valid query.
        let bad = vec![plan[0].clone()];
        expect(&bad, "dropped");

        // The same query claimed twice.
        let mut bad = plan.clone();
        bad[1].sources = vec![0, 2];
        expect(&bad, "appears in batch ranges");

        // Source not contained in its merged range.
        let mut bad = plan.clone();
        bad[0].range.hi = 15;
        expect(&bad, "not contained");

        // Merged range wider than the hull of its sources.
        let mut bad = plan.clone();
        bad[1].range.hi = 99;
        expect(&bad, "hull");

        // Unsorted sources.
        let mut bad = plan.clone();
        bad[0].sources = vec![1, 0];
        expect(&bad, "ascending");

        // Out-of-bounds source index.
        let mut bad = plan.clone();
        bad[1].sources = vec![7];
        expect(&bad, "references query 7");
    }

    #[test]
    fn segments_partition_the_merged_range() {
        let qs = [q(0, 100), q(20, 30), q(25, 60), q(90, 120)];
        let plan = plan_batch(&qs);
        assert_eq!(plan.len(), 1);
        let segs = plan[0].segments(&qs);
        // Contiguous cover of [0, 120].
        assert_eq!(segs.first().unwrap().0.lo, 0);
        assert_eq!(segs.last().unwrap().0.hi, 120);
        for w in segs.windows(2) {
            assert_eq!(w[0].0.hi + 1, w[1].0.lo);
        }
        // Each source query is exactly the union of the segments it covers.
        for (i, src) in qs.iter().enumerate() {
            let mine: Vec<_> = segs.iter().filter(|(_, c)| c.contains(&i)).collect();
            assert_eq!(mine.first().unwrap().0.lo, src.lo, "query {i}");
            assert_eq!(mine.last().unwrap().0.hi, src.hi, "query {i}");
        }
    }
}
