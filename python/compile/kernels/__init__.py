"""Layer-1 Pallas kernels for Oseba's selective bulk analyses.

Every kernel operates on a fixed-shape *block* of ``BLOCK_ROWS`` f32 values
(one column of one partition, zero-padded at the tail) plus ``(start, end)``
i32 scalars delimiting the selected half-open row range ``[start, end)``.
This is the AOT contract with the rust runtime: one static-shaped PJRT
executable serves every partition and every partial-partition selection.

All kernels are lowered with ``interpret=True`` — the CPU PJRT plugin cannot
execute Mosaic custom-calls (see DESIGN.md §6).
"""

BLOCK_ROWS = 4096
HIST_BINS = 64
MA_WINDOWS = (4, 16, 64)

from .segment_stats import segment_stats  # noqa: E402,F401
from .moving_average import moving_average  # noqa: E402,F401
from .distance import distance  # noqa: E402,F401
from .histogram import histogram64  # noqa: E402,F401
