//! `LiveIngestor` — the long-lived writer half of a live dataset.
//!
//! Where [`crate::ingest::run_pipeline`] drives a *finish-once* load (the
//! source ends, the tail seals, the dataset is done), a live ingestor
//! stays up for the lifetime of a feed: chunks are sent into a bounded
//! channel (backpressure when the sealer falls behind) and a consumer
//! thread appends them to the shared [`LiveDataset`], which publishes
//! epochs that concurrent queries snapshot. Spill-to-disk of sealed cold
//! partitions comes for free when the live dataset was created with
//! [`crate::engine::OsebaContext::create_live_spilling`].

use std::sync::mpsc::{Receiver, SyncSender, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;

use crate::engine::LiveDataset;
use crate::error::{OsebaError, Result};
use crate::ingest::Chunk;
use crate::storage::RecordBatch;

/// Cut a batch into `chunk_rows`-sized chunks (the last may be shorter) —
/// the standard way tests, benches and the CSV streamer feed a live
/// pipeline.
pub fn chunk_batch(batch: &RecordBatch, chunk_rows: usize) -> Vec<Chunk> {
    let chunk_rows = chunk_rows.max(1);
    let mut out = Vec::new();
    let mut lo = 0;
    while lo < batch.rows() {
        let hi = (lo + chunk_rows).min(batch.rows());
        out.push(Chunk {
            keys: batch.keys[lo..hi].to_vec(),
            columns: batch.columns.iter().map(|c| c[lo..hi].to_vec()).collect(),
        });
        lo = hi;
    }
    out
}

/// A running ingest pipeline into a [`LiveDataset`].
///
/// Producers call [`LiveIngestor::send`] (blocking once `queue_depth`
/// chunks are in flight — the standard streaming-orchestrator contract);
/// a dedicated consumer thread drains the channel into
/// [`LiveDataset::append`]. [`LiveIngestor::finish`] closes the channel,
/// joins the consumer, and seals the unsealed tail — but unlike the
/// one-shot pipeline the dataset itself stays open for a later ingestor
/// (or direct appends).
pub struct LiveIngestor {
    live: Arc<LiveDataset>,
    tx: Option<SyncSender<Chunk>>,
    consumer: Option<JoinHandle<Result<usize>>>,
}

impl LiveIngestor {
    /// Spawn the consumer thread over `live` with a channel of depth
    /// `queue_depth` (clamped to ≥ 1).
    pub fn spawn(live: Arc<LiveDataset>, queue_depth: usize) -> LiveIngestor {
        let (tx, rx): (SyncSender<Chunk>, Receiver<Chunk>) =
            std::sync::mpsc::sync_channel(queue_depth.max(1));
        let sink = Arc::clone(&live);
        let consumer = std::thread::Builder::new()
            .name("oseba-live-ingest".into())
            .spawn(move || -> Result<usize> {
                let mut rows = 0usize;
                for chunk in rx {
                    rows += chunk.rows();
                    sink.append(chunk)?;
                }
                Ok(rows)
            })
            // The pipeline cannot exist without its consumer thread.
            // lint: allow(no-unwrap) -- spawn fails only on OS thread exhaustion
            .expect("spawn live-ingest consumer");
        LiveIngestor { live, tx: Some(tx), consumer: Some(consumer) }
    }

    /// The dataset this ingestor feeds.
    pub fn live(&self) -> &Arc<LiveDataset> {
        &self.live
    }

    /// Queue one chunk, blocking while the channel is full. Fails once the
    /// consumer has died (its error is reported by [`Self::finish`]).
    pub fn send(&self, chunk: Chunk) -> Result<()> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| OsebaError::Ingest("send after finish".into()))?;
        tx.send(chunk).map_err(|_| {
            OsebaError::Ingest(
                "live-ingest consumer stopped (append failed; see finish())".into(),
            )
        })
    }

    /// Queue one chunk without blocking. Returns `Ok(false)` when the
    /// channel is full (caller may drop, retry or throttle), `Ok(true)`
    /// when queued.
    pub fn try_send(&self, chunk: Chunk) -> Result<bool> {
        let tx = self
            .tx
            .as_ref()
            .ok_or_else(|| OsebaError::Ingest("send after finish".into()))?;
        match tx.try_send(chunk) {
            Ok(()) => Ok(true),
            Err(TrySendError::Full(_)) => Ok(false),
            Err(TrySendError::Disconnected(_)) => Err(OsebaError::Ingest(
                "live-ingest consumer stopped (append failed; see finish())".into(),
            )),
        }
    }

    /// Close the channel, wait for the consumer to drain, and seal the
    /// unsealed tail. Returns the total rows this ingestor appended. The
    /// first append error from the consumer surfaces here.
    pub fn finish(mut self) -> Result<usize> {
        self.tx = None; // closes the channel; the consumer's loop ends
        let handle = match self.consumer.take() {
            Some(h) => h,
            // Unreachable in practice (`finish` consumes `self`), but a
            // typed error beats dying if that ever changes.
            None => return Err(OsebaError::Ingest("live ingestor already finished".into())),
        };
        let rows = handle
            .join()
            .map_err(|_| OsebaError::Cluster("live-ingest consumer panicked".into()))??;
        self.live.flush()?;
        Ok(rows)
    }
}

impl Drop for LiveIngestor {
    fn drop(&mut self) {
        // Close the channel and reap the consumer so a dropped (not
        // finished) ingestor cannot leak a thread; errors are discarded —
        // callers who care use `finish`.
        self.tx = None;
        if let Some(handle) = self.consumer.take() {
            let _ = handle.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ContextConfig;
    use crate::datagen::ClimateGen;
    use crate::engine::{LiveConfig, OsebaContext};
    use crate::index::{ContentIndex, RangeQuery};
    use crate::storage::Schema;

    fn ctx() -> OsebaContext {
        OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None })
    }

    #[test]
    fn pipeline_matches_batch_loaded_reference() {
        let c = ctx();
        let live = c
            .create_live(
                Schema::climate(),
                LiveConfig { rows_per_partition: 1024, max_asl: 8 },
            )
            .unwrap();
        let batch = ClimateGen::default().generate(10_000);
        let ing = LiveIngestor::spawn(Arc::clone(&live), 2);
        for chunk in chunk_batch(&batch, 333) {
            ing.send(chunk).unwrap();
        }
        let rows = ing.finish().unwrap();
        assert_eq!(rows, 10_000);

        let snap = live.snapshot();
        assert_eq!(snap.rows(), 10_000);
        assert_eq!(snap.num_partitions(), 10);
        // Index equals the batch-built reference.
        let ref_parts = crate::storage::partition_batch_uniform(&batch, 1024).unwrap();
        let ref_index = crate::index::Cias::build(&ref_parts).unwrap();
        for q in [
            RangeQuery { lo: 0, hi: 3600 * 999 },
            RangeQuery { lo: 3600 * 2000, hi: 3600 * 8000 },
        ] {
            assert_eq!(snap.index().unwrap().lookup(q), ref_index.lookup(q), "{q:?}");
        }
        // Data identical too.
        for (a, b) in snap.dataset().partitions().iter().zip(&ref_parts) {
            assert_eq!(a.keys, b.keys);
            assert_eq!(a.columns[0], b.columns[0]);
        }
        live.close();
    }

    #[test]
    fn consumer_error_surfaces_at_finish() {
        let c = ctx();
        let live = c
            .create_live(Schema::stock(), LiveConfig::default())
            .unwrap();
        let ing = LiveIngestor::spawn(Arc::clone(&live), 1);
        let ok = Chunk { keys: vec![10, 20], columns: vec![vec![0.0; 2], vec![0.0; 2]] };
        ing.send(ok).unwrap();
        // Wrong width: the consumer's append fails and the pipeline stops.
        let bad = Chunk { keys: vec![30], columns: vec![vec![0.0]] };
        ing.send(bad).unwrap();
        let err = ing.finish().unwrap_err();
        assert!(err.to_string().contains("schema"), "got: {err}");
        live.close();
    }

    #[test]
    fn dataset_outlives_ingestor_sessions() {
        let c = ctx();
        let live = c
            .create_live(
                Schema::stock(),
                LiveConfig { rows_per_partition: 4, max_asl: 8 },
            )
            .unwrap();
        let mk = |start: i64| Chunk {
            keys: (0..4).map(|i| start + i).collect(),
            columns: vec![vec![1.0; 4], vec![2.0; 4]],
        };
        let ing = LiveIngestor::spawn(Arc::clone(&live), 1);
        ing.send(mk(0)).unwrap();
        assert_eq!(ing.finish().unwrap(), 4);
        // A second session keeps appending to the same dataset.
        let ing = LiveIngestor::spawn(Arc::clone(&live), 1);
        ing.send(mk(10)).unwrap();
        assert_eq!(ing.finish().unwrap(), 4);
        let snap = live.snapshot();
        assert_eq!(snap.rows(), 8);
        assert_eq!(snap.num_partitions(), 2);
        live.close();
    }
}
