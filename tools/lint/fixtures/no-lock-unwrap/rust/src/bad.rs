//! Seeded violation: `.lock().unwrap()` — the poisoning cascade.

use std::sync::Mutex;

pub fn f(m: &Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
