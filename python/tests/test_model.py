"""L2 graph shape/semantics checks + AOT entry registry sanity."""

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

N = 128


def test_entries_registry_complete():
    reg = model.entries()
    assert "segment_stats" in reg
    assert "distance" in reg
    assert "histogram64" in reg
    for w in model.MA_WINDOWS:
        assert f"moving_average_w{w}" in reg
        assert f"ma_stats_w{w}" in reg


def test_entries_are_lowerable():
    """Every registry entry must trace: eval_shape is the cheap proxy for
    the full lowering that aot.py performs."""
    for name, (fn, args) in model.entries().items():
        out = jax.eval_shape(fn, *args)
        leaves = jax.tree_util.tree_leaves(out)
        assert leaves, name
        for leaf in leaves:
            assert leaf.dtype == jnp.float32, name


def test_ma_stats_fused_pipeline_matches_composition():
    rng = np.random.default_rng(3)
    x = rng.normal(10, 2, N).astype(np.float32)
    w = 4
    fused = model.block_ma_stats(x, 8, 120, window=w)
    ma = ref.moving_average_ref(x, 8, 120, w)
    want = ref.segment_stats_ref(ma, 8 + w - 1, 120)
    for g, ww in zip(fused, want):
        np.testing.assert_allclose(g, ww, rtol=1e-5, atol=1e-3)


def test_block_stats_roundtrip_means():
    x = np.linspace(-1, 1, N).astype(np.float32)
    mx, mn, s, ss, n = model.block_stats(x, 0, N)
    fx = ref.finalize_stats(mx, mn, s, ss, n)
    np.testing.assert_allclose(fx[2], x.mean(), atol=1e-6)
    np.testing.assert_allclose(fx[3], x.std(), atol=1e-5)


def test_block_histogram_shape():
    x = np.zeros(N, np.float32)
    (h,) = model.block_histogram(x, 0, N, -1.0, 1.0)
    assert h.shape == (model.HIST_BINS,)
    assert float(h.sum()) == N
