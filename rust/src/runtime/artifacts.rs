//! Artifact manifest: the contract `python/compile/aot.py` writes and the
//! rust runtime honours (entry names, HLO file paths, parameter/result
//! shapes, global constants like `block_rows`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::error::{OsebaError, Result};
use crate::util::json::Json;

/// Shape + dtype of one parameter or result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpecDesc {
    /// Dimension sizes (empty for a scalar).
    pub shape: Vec<usize>,
    /// Element dtype name (e.g. "float32").
    pub dtype: String,
}

impl SpecDesc {
    fn from_json(j: &Json) -> Result<SpecDesc> {
        let shape = j
            .require("shape")?
            .as_arr()
            .ok_or_else(|| OsebaError::Artifact("shape not an array".into()))?
            .iter()
            .map(|d| d.as_usize().ok_or_else(|| OsebaError::Artifact("bad dim".into())))
            .collect::<Result<Vec<_>>>()?;
        let dtype = j
            .require("dtype")?
            .as_str()
            .ok_or_else(|| OsebaError::Artifact("dtype not a string".into()))?
            .to_string();
        Ok(SpecDesc { shape, dtype })
    }
}

/// One AOT entry point.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EntryDesc {
    /// Entry-point name (manifest key).
    pub name: String,
    /// HLO text file, absolute.
    pub path: PathBuf,
    /// Parameter specs, in call order.
    pub params: Vec<SpecDesc>,
    /// Result specs, in return order.
    pub results: Vec<SpecDesc>,
}

/// The parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Rows per kernel block (must match `storage::BLOCK_ROWS`).
    pub block_rows: usize,
    /// Histogram bin count the kernels were lowered with.
    pub hist_bins: usize,
    /// Moving-average windows with dedicated fused kernels.
    pub ma_windows: Vec<usize>,
    /// Hash of the lowering inputs (artifact staleness check).
    pub fingerprint: String,
    /// Entry points by name.
    pub entries: BTreeMap<String, EntryDesc>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            OsebaError::Artifact(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                path.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text; `dir` anchors relative HLO file names.
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let j = Json::parse(text)?;
        let block_rows = j
            .require("block_rows")?
            .as_usize()
            .ok_or_else(|| OsebaError::Artifact("block_rows not an int".into()))?;
        let hist_bins = j
            .require("hist_bins")?
            .as_usize()
            .ok_or_else(|| OsebaError::Artifact("hist_bins not an int".into()))?;
        let ma_windows = j
            .require("ma_windows")?
            .as_arr()
            .ok_or_else(|| OsebaError::Artifact("ma_windows not an array".into()))?
            .iter()
            .map(|w| w.as_usize().ok_or_else(|| OsebaError::Artifact("bad window".into())))
            .collect::<Result<Vec<_>>>()?;
        let fingerprint = j
            .require("fingerprint")?
            .as_str()
            .unwrap_or_default()
            .to_string();
        let mut entries = BTreeMap::new();
        let raw = j
            .require("entries")?
            .as_obj()
            .ok_or_else(|| OsebaError::Artifact("entries not an object".into()))?;
        for (name, e) in raw {
            let file = e
                .require("file")?
                .as_str()
                .ok_or_else(|| OsebaError::Artifact("file not a string".into()))?;
            let params = e
                .require("params")?
                .as_arr()
                .ok_or_else(|| OsebaError::Artifact("params not an array".into()))?
                .iter()
                .map(SpecDesc::from_json)
                .collect::<Result<Vec<_>>>()?;
            let results = e
                .require("results")?
                .as_arr()
                .ok_or_else(|| OsebaError::Artifact("results not an array".into()))?
                .iter()
                .map(SpecDesc::from_json)
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                EntryDesc { name: name.clone(), path: dir.join(file), params, results },
            );
        }
        if entries.is_empty() {
            return Err(OsebaError::Artifact("manifest has no entries".into()));
        }
        Ok(Manifest { block_rows, hist_bins, ma_windows, fingerprint, entries })
    }

    /// Entry lookup with a helpful error.
    pub fn entry(&self, name: &str) -> Result<&EntryDesc> {
        self.entries
            .get(name)
            .ok_or_else(|| OsebaError::Artifact(format!("no artifact entry '{name}'")))
    }

    /// The moving-average entry name for `window`, validated against the
    /// lowered window set.
    pub fn ma_entry(&self, window: usize) -> Result<String> {
        if self.ma_windows.contains(&window) {
            Ok(format!("moving_average_w{window}"))
        } else {
            Err(OsebaError::Artifact(format!(
                "window {window} not AOT-compiled (available: {:?})",
                self.ma_windows
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "block_rows": 4096,
      "hist_bins": 64,
      "ma_windows": [4, 16, 64],
      "fingerprint": "abc123",
      "entries": {
        "segment_stats": {
          "file": "segment_stats.hlo.txt",
          "params": [
            {"shape": [4096], "dtype": "float32"},
            {"shape": [], "dtype": "int32"},
            {"shape": [], "dtype": "int32"}
          ],
          "results": [
            {"shape": [], "dtype": "float32"},
            {"shape": [], "dtype": "float32"},
            {"shape": [], "dtype": "float32"},
            {"shape": [], "dtype": "float32"},
            {"shape": [], "dtype": "float32"}
          ]
        }
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.block_rows, 4096);
        assert_eq!(m.ma_windows, vec![4, 16, 64]);
        let e = m.entry("segment_stats").unwrap();
        assert_eq!(e.path, Path::new("/x/segment_stats.hlo.txt"));
        assert_eq!(e.params.len(), 3);
        assert_eq!(e.params[0].shape, vec![4096]);
        assert_eq!(e.results.len(), 5);
    }

    #[test]
    fn missing_entry_is_error() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn ma_entry_validates_window() {
        let m = Manifest::parse(SAMPLE, Path::new("/x")).unwrap();
        assert_eq!(m.ma_entry(16).unwrap(), "moving_average_w16");
        assert!(m.ma_entry(5).is_err());
    }

    #[test]
    fn rejects_empty_entries() {
        let text = r#"{"block_rows":1,"hist_bins":1,"ma_windows":[],"fingerprint":"","entries":{}}"#;
        assert!(Manifest::parse(text, Path::new("/x")).is_err());
    }

    #[test]
    fn loads_real_manifest_if_built() {
        // Exercised against the actual artifacts when present (CI builds
        // them via `make artifacts` before `cargo test`).
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert_eq!(m.block_rows, 4096);
            assert!(m.entries.contains_key("segment_stats"));
            assert!(m.entries.contains_key("distance"));
            assert!(m.entries.contains_key("histogram64"));
            for w in &m.ma_windows {
                assert!(m.entries.contains_key(&format!("moving_average_w{w}")));
            }
        }
    }
}
