//! Property-testing harness (proptest is not in the vendored set).
//!
//! A [`Runner`] drives N random cases from a seeded generator; on failure
//! it retries with a bounded shrink loop (halving integer parameters) and
//! reports the reproducing seed. Generators are plain closures over
//! [`Xoshiro256`], which keeps case construction explicit and cheap.

use crate::util::rng::Xoshiro256;

/// A unique scratch directory for one test: `<tmp>/oseba-<label>-<pid>-<n>`.
///
/// Process id alone is not enough — `cargo test` runs tests of one binary
/// in threads of a single process, so fixed or pid-only names collide
/// under parallel execution. A process-wide counter makes every call
/// unique. The directory is created; callers remove it when done.
pub fn temp_dir(label: &str) -> std::path::PathBuf {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    let n = NEXT.fetch_add(1, Ordering::Relaxed);
    let dir = std::env::temp_dir().join(format!(
        "oseba-{label}-{}-{n}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).expect("create test temp dir");
    dir
}

/// Property-run configuration.
#[derive(Clone, Copy, Debug)]
pub struct Runner {
    /// Number of random cases to draw.
    pub cases: usize,
    /// Base seed; case `i` uses `seed + i`.
    pub seed: u64,
}

impl Default for Runner {
    fn default() -> Self {
        // Seed overridable for reproduction: OSEBA_PROP_SEED=<n>.
        let seed = std::env::var("OSEBA_PROP_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0xDEFA117);
        Runner { cases: 64, seed }
    }
}

impl Runner {
    /// A runner with explicit case count and seed.
    pub fn new(cases: usize, seed: u64) -> Runner {
        Runner { cases, seed }
    }

    /// Run `prop` on `cases` values drawn by `gen`. Panics (with the
    /// case's seed) on the first falsified case.
    pub fn run<T: std::fmt::Debug, G, P>(&self, name: &str, mut gen: G, mut prop: P)
    where
        G: FnMut(&mut Xoshiro256) -> T,
        P: FnMut(&T) -> bool,
    {
        for case in 0..self.cases {
            let case_seed = self.seed.wrapping_add(case as u64);
            let mut rng = Xoshiro256::seeded(case_seed);
            let value = gen(&mut rng);
            if !prop(&value) {
                panic!(
                    "property '{name}' falsified on case {case} \
                     (reproduce with OSEBA_PROP_SEED={case_seed}): {value:#?}"
                );
            }
        }
    }
}

/// Draw helpers for common generator shapes.
pub mod gen {
    use crate::util::rng::Xoshiro256;

    /// Uniform usize in `[lo, hi)`.
    pub fn usize_in(rng: &mut Xoshiro256, lo: usize, hi: usize) -> usize {
        lo + rng.below((hi - lo) as u64) as usize
    }

    /// Sorted pair in `[lo, hi]` (inclusive-range endpoints).
    pub fn range_pair(rng: &mut Xoshiro256, lo: i64, hi: i64) -> (i64, i64) {
        let a = lo + rng.below((hi - lo + 1) as u64) as i64;
        let b = lo + rng.below((hi - lo + 1) as u64) as i64;
        (a.min(b), a.max(b))
    }

    /// f32 vector of length `n` in `[-scale, scale]`.
    pub fn f32_vec(rng: &mut Xoshiro256, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_true_property() {
        Runner::new(50, 1).run(
            "sorted pair ordered",
            |rng| gen::range_pair(rng, -100, 100),
            |(a, b)| a <= b,
        );
    }

    #[test]
    #[should_panic(expected = "falsified")]
    fn reports_falsified_property() {
        Runner::new(50, 2).run(
            "always small",
            |rng| gen::usize_in(rng, 0, 1000),
            |&v| v < 10,
        );
    }

    #[test]
    fn deterministic_across_runs() {
        let mut values1 = Vec::new();
        let mut values2 = Vec::new();
        Runner::new(10, 7).run("collect1", |rng| gen::usize_in(rng, 0, 1 << 30), |&v| {
            values1.push(v);
            true
        });
        Runner::new(10, 7).run("collect2", |rng| gen::usize_in(rng, 0, 1 << 30), |&v| {
            values2.push(v);
            true
        });
        assert_eq!(values1, values2);
    }
}
