//! Registry anchor: `op_info` and `phase_targeting` are surfaced by the
//! fixture server, `op_ghost` is registered but never listed — fires.

pub const OP_METRICS: [&str; 2] = ["op_info", "op_ghost"];
pub const PHASE_METRICS: [&str; 1] = ["phase_targeting"];
