//! **Fig 6 reproduction**: accumulated processing time over the five
//! phases, default vs Oseba.
//!
//! Paper result (480 MB): default >120 s total vs Oseba ≈70 s (~1.7×),
//! with the gap widening after phase 1 (phase 1 is close because Oseba's
//! index build happens there). Absolute numbers here are milliseconds —
//! the substrate is an in-process engine, not a JVM cluster — but the
//! *shape* must match: default's slope stays constant (every phase pays a
//! full scan) while Oseba's flattens, and the cumulative gap widens
//! monotonically.
//!
//! Run: `cargo bench --bench fig6_time` (OSEBA_BYTES / OSEBA_BENCH_ITERS).

mod common;

use oseba::analysis::five_periods;
use oseba::bench::BenchConfig;
use oseba::config::parse_bytes;
use oseba::coordinator::{run_session, IndexKind, Method};
use oseba::util::humansize;

fn main() {
    let bytes = std::env::var("OSEBA_BYTES")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_BYTES"))
        .unwrap_or(64 << 20);
    let cfg = BenchConfig::from_env();
    let backend = common::backend_kind();
    let periods = five_periods();

    oseba::bench::section(&format!(
        "Fig 6: accumulated time ({} raw, 15 partitions, backend {:?}, {} iters)",
        humansize::bytes(bytes),
        backend,
        cfg.iters
    ));

    // Average the per-phase time over `iters` fresh sessions per method.
    let mut acc: [[f64; 5]; 2] = [[0.0; 5]; 2];
    for (mi, method) in [Method::Default, Method::Oseba].into_iter().enumerate() {
        for _ in 0..cfg.iters.max(1) {
            let (coord, ds, _) = common::setup(bytes, 15, backend);
            let report = run_session(&coord, &ds, method, IndexKind::Cias, &periods, 0, false)
                .expect("session");
            for (i, t) in report.metrics.accumulated_time().iter().enumerate() {
                acc[mi][i] += t;
            }
        }
        for t in &mut acc[mi] {
            *t /= cfg.iters.max(1) as f64;
        }
    }

    println!(
        "{:<7} {:>12} {:>12} {:>9} {:>12}",
        "phase", "default", "oseba", "speedup", "paper"
    );
    // Paper accumulated-time curve eyeballed from Fig 6 (seconds).
    let paper = [(25.0, 22.0), (50.0, 35.0), (75.0, 47.0), (100.0, 58.0), (124.0, 70.0)];
    for i in 0..5 {
        println!(
            "{:<7} {:>12} {:>12} {:>8.2}x {:>7.0}s/{:<4.0}s",
            i + 1,
            humansize::secs(acc[0][i]),
            humansize::secs(acc[1][i]),
            acc[0][i] / acc[1][i],
            paper[i].0,
            paper[i].1
        );
    }

    // Shape assertions.
    let gap: Vec<f64> = (0..5).map(|i| acc[0][i] - acc[1][i]).collect();
    assert!(gap.windows(2).all(|w| w[1] > w[0]), "cumulative gap must widen: {gap:?}");
    assert!(acc[0][4] > acc[1][4], "default slower overall");
    println!(
        "\nshape check: gap widens ✓ ({} → {}), total speedup {:.2}x (paper ≈1.7x)",
        humansize::secs(gap[0]),
        humansize::secs(gap[4]),
        acc[0][4] / acc[1][4]
    );

    use oseba::util::json::Json;
    let series = |xs: &[f64; 5]| Json::arr(xs.iter().map(|&t| Json::num(t)).collect());
    common::write_bench_json(
        "fig6_time",
        Json::obj(vec![
            ("bench", Json::str("fig6_time")),
            ("raw_bytes", Json::num(bytes as f64)),
            ("default_acc_secs", series(&acc[0])),
            ("oseba_acc_secs", series(&acc[1])),
            ("total_speedup", Json::num(acc[0][4] / acc[1][4])),
        ]),
    );
}
