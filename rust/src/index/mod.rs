//! Content-aware data organization — the paper's contribution (§III).
//!
//! Two [`ContentIndex`] implementations:
//! * [`TableIndex`] — the intuitive O(m)-space, O(log m)-lookup table of
//!   §III-A / Fig 3;
//! * [`Cias`] — the Compressed Index with Associated Search List of §III-B:
//!   O(1) space and computation for the regular region, with a short
//!   search list absorbing irregularities.

pub mod builder;
pub mod cias;
pub mod filter;
pub mod table;
pub mod types;

pub use builder::extract_meta;
pub use cias::Cias;
pub use filter::{filters_of, FilterBuilder, MembershipFilter};
pub use table::TableIndex;
pub use types::{
    count_block_classes, for_each_block_class, row_matches, sketches_of,
    sketches_with_blocks, usable_blocks, zones_satisfiable, BlockClass, BlockCounts,
    BlockSketches, ColumnPredicate, ColumnSketch, ContentIndex, PartitionMeta,
    PartitionSlice, PredOp, RangeQuery, ZoneMap,
};
