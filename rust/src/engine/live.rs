//! Live (append-while-serving) datasets — the epoch machinery that turns
//! the engine's core invariant from *"datasets are immutable after load"*
//! into *"readers see immutable epochs of a mutable dataset"*.
//!
//! The paper motivates Oseba with continuously arriving temporal data
//! (weather feeds, transaction streams); CIAS's associated search list
//! exists precisely to absorb the irregular, late-arriving partitions such
//! feeds produce (§III-B). A [`LiveDataset`] accepts appended record
//! chunks while concurrently serving selective queries:
//!
//! * **Writers** extend the *next* epoch: chunks accumulate in an unsealed
//!   buffer (charged to the block manager, invisible to queries) until
//!   `rows_per_partition` rows seal into a partition, which is published
//!   atomically under epoch `N + 1`.
//! * **Readers** pin an epoch: [`LiveDataset::snapshot`] returns a cheap
//!   immutable [`EpochSnapshot`] (`Arc`-shared partitions + the index as
//!   of that epoch). A query planned against epoch `N` can never see a
//!   half-published partition, torn rows, or a retroactively renumbered
//!   index — later epochs are separate objects.
//!
//! Index maintenance is incremental: an in-order sealed partition is
//! absorbed in O(1) by [`Cias::append_meta`] (growing the compressed
//! region or the ASL); an out-of-order (late) chunk seals immediately and
//! lands in the ASL at its sorted position via [`Cias::absorb_meta`]. Only
//! when the ASL exceeds [`LiveConfig::max_asl`] *and* a re-sort would
//! actually shrink it does the writer fall back to a rebuild that
//! renumbers partitions in key order — readers keep serving the previous
//! epoch throughout. See DESIGN.md §9 for the state diagram.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::engine::block_manager::{BlockManager, DatasetId};
use crate::engine::dataset::{Dataset, Lineage};
use crate::error::{OsebaError, Result};
use crate::index::builder::detect_step;
use crate::index::{Cias, PartitionMeta};
use crate::ingest::Chunk;
use crate::storage::{Partition, Schema};
use crate::store::TieredStore;
use crate::util::sync::MutexExt;

/// Tuning knobs for a live dataset.
#[derive(Clone, Copy, Debug)]
pub struct LiveConfig {
    /// Rows per sealed partition — the uniform layout CIAS compresses.
    pub rows_per_partition: usize,
    /// Rebuild threshold: when the ASL grows beyond this many entries and
    /// a key-order re-sort would shrink it, the writer rebuilds the index
    /// (renumbering partitions). Resident datasets only; a spilling live
    /// dataset never rebuilds (segment ids pin partition order).
    pub max_asl: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig { rows_per_partition: 4096, max_asl: 8 }
    }
}

/// Point-in-time ingest/index-maintenance counters for a live dataset.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct LiveCounters {
    /// Epoch of the currently published state.
    pub epoch: u64,
    /// Chunks accepted by [`LiveDataset::append`].
    pub appended_chunks: usize,
    /// Chunks that arrived out of key order (sealed straight to the ASL).
    pub out_of_order_chunks: usize,
    /// Partitions sealed and published so far.
    pub sealed_partitions: usize,
    /// Rows visible at the current epoch.
    pub sealed_rows: usize,
    /// Buffered rows not yet sealed (invisible to every snapshot).
    pub unsealed_rows: usize,
    /// O(1) in-order index maintenance operations ([`Cias::append_meta`]).
    pub index_appends: usize,
    /// Out-of-order partitions absorbed by the ASL ([`Cias::absorb_meta`]).
    pub asl_absorbed: usize,
    /// Current associated-search-list length.
    pub asl_len: usize,
    /// Full index rebuilds (ASL exceeded `max_asl` and a re-sort helped).
    pub rebuilds: usize,
}

/// One immutable published state. Snapshots share it via `Arc`.
#[derive(Debug)]
struct Published {
    epoch: u64,
    /// Sealed partitions (empty when spilling — the store owns them).
    parts: Vec<Arc<Partition>>,
    index: Option<Arc<Cias>>,
    rows: usize,
    partitions: usize,
}

/// Writer-side mutable state, guarded by one mutex.
struct WriteState {
    pending_keys: Vec<i64>,
    pending_cols: Vec<Vec<f32>>,
    /// Bytes charged to the block manager for the unsealed buffer.
    pending_charged: usize,
    /// Bytes charged to the tracker for resident sealed partitions.
    sealed_charged: usize,
    /// Last key of the in-order stream; chunks starting below it are
    /// out-of-order.
    watermark: Option<i64>,
    closed: bool,
}

/// An immutable view of a [`LiveDataset`] at one epoch.
///
/// Holding a snapshot pins its partitions in memory (resident mode) or its
/// visible store prefix (spilling mode) regardless of later appends,
/// rebuilds, or `close` — the standard reader contract.
#[derive(Clone, Debug)]
pub struct EpochSnapshot {
    epoch: u64,
    rows: usize,
    index: Option<Arc<Cias>>,
    dataset: Dataset,
}

impl EpochSnapshot {
    /// The epoch this snapshot pinned.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Rows visible at the pinned epoch.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Partitions visible at the pinned epoch.
    pub fn num_partitions(&self) -> usize {
        self.dataset.num_partitions()
    }

    /// The dataset view to analyze — safe for both the indexed path and
    /// the scan baseline (a spilling snapshot caps the store at its
    /// visible prefix).
    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    /// The super index as of the pinned epoch (`None` while no partition
    /// has been sealed).
    pub fn index(&self) -> Option<&Cias> {
        self.index.as_deref()
    }
}

/// A writable dataset serving snapshot-consistent selective queries while
/// ingesting. See the module docs for the epoch contract.
pub struct LiveDataset {
    id: DatasetId,
    schema: Schema,
    cfg: LiveConfig,
    block_manager: Arc<BlockManager>,
    /// When set, sealed partitions go to the tiered store (spilling under
    /// memory pressure) instead of being pinned in memory.
    spill: Option<Arc<TieredStore>>,
    write: Mutex<WriteState>,
    current: Mutex<Arc<Published>>,
    appended_chunks: AtomicUsize,
    ooo_chunks: AtomicUsize,
    index_appends: AtomicUsize,
    asl_absorbed: AtomicUsize,
    rebuilds: AtomicUsize,
}

impl LiveDataset {
    /// Build a live dataset. Use
    /// [`crate::engine::OsebaContext::create_live`] (or the spilling
    /// variant) rather than calling this directly — the context hands out
    /// the dataset id and registers spill stores for memory-pressure
    /// reclaim.
    pub(crate) fn new(
        id: DatasetId,
        schema: Schema,
        cfg: LiveConfig,
        block_manager: Arc<BlockManager>,
        spill: Option<Arc<TieredStore>>,
    ) -> Result<LiveDataset> {
        if cfg.rows_per_partition == 0 {
            return Err(OsebaError::Schema("rows_per_partition must be > 0".into()));
        }
        if let Some(store) = &spill {
            if *store.schema() != schema {
                return Err(OsebaError::Schema(format!(
                    "store schema {:?} != live schema {:?}",
                    store.schema(),
                    schema
                )));
            }
        }
        let width = schema.width();
        Ok(LiveDataset {
            id,
            schema,
            cfg,
            block_manager,
            spill,
            write: Mutex::new(WriteState {
                pending_keys: Vec::new(),
                pending_cols: vec![Vec::new(); width],
                pending_charged: 0,
                sealed_charged: 0,
                watermark: None,
                closed: false,
            }),
            current: Mutex::new(Arc::new(Published {
                epoch: 0,
                parts: Vec::new(),
                index: None,
                rows: 0,
                partitions: 0,
            })),
            appended_chunks: AtomicUsize::new(0),
            ooo_chunks: AtomicUsize::new(0),
            index_appends: AtomicUsize::new(0),
            asl_absorbed: AtomicUsize::new(0),
            rebuilds: AtomicUsize::new(0),
        })
    }

    /// The dataset id the context assigned.
    pub fn id(&self) -> DatasetId {
        self.id
    }

    /// The schema every appended chunk must match.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// The tiered store sealed partitions spill to, if any.
    pub fn spill_store(&self) -> Option<&Arc<TieredStore>> {
        self.spill.as_ref()
    }

    /// Epoch of the currently published state.
    pub fn epoch(&self) -> u64 {
        self.published().epoch
    }

    /// Append one chunk of **strictly increasing** keys.
    ///
    /// A chunk whose first key continues the in-order stream (above the
    /// watermark) extends the unsealed buffer, sealing
    /// `rows_per_partition`-sized partitions as they complete (each an
    /// O(1) [`Cias::append_meta`]). A chunk whose keys fall *below* the
    /// watermark is out-of-order: it seals immediately as its own
    /// (irregular) partition, absorbed by the ASL, provided its key range
    /// overlaps nothing already visible or buffered. Returns the epoch
    /// after the append (unchanged when no partition sealed — unsealed
    /// rows are invisible by design).
    ///
    /// Live streams reject duplicate keys outright (within a chunk, at the
    /// watermark, or inside an absorbed range): partitions carry
    /// *inclusive* key ranges, so a duplicate landing on a seal boundary
    /// could never be published — better a clear error at append time
    /// than rows the index can never admit.
    pub fn append(&self, chunk: Chunk) -> Result<u64> {
        if chunk.columns.len() != self.schema.width() {
            return Err(OsebaError::Schema(format!(
                "chunk has {} columns, schema {}",
                chunk.columns.len(),
                self.schema.width()
            )));
        }
        for c in &chunk.columns {
            if c.len() != chunk.keys.len() {
                return Err(OsebaError::Schema(format!(
                    "ragged chunk: column of {} values for {} keys",
                    c.len(),
                    chunk.keys.len()
                )));
            }
        }
        if chunk.keys.windows(2).any(|w| w[0] >= w[1]) {
            return Err(OsebaError::Schema(
                "live chunk keys must be strictly increasing".into(),
            ));
        }
        let mut w = self.write.lock_recover();
        if w.closed {
            return Err(OsebaError::Ingest("append to a closed live dataset".into()));
        }
        let Some(&first) = chunk.keys.first() else {
            // Empty chunk: a no-op, not an error.
            return Ok(self.published().epoch);
        };
        // Strictly above the watermark continues the stream; a first key
        // *equal* to the watermark is a duplicate and goes down the
        // out-of-order path, whose overlap checks reject it cleanly.
        let in_order = w.watermark.map_or(true, |wm| first > wm);
        if in_order {
            let add = chunk.raw_bytes();
            self.block_manager.charge_unsealed(self.id, add)?;
            // The chunk is accepted from here on: a later seal failure
            // (e.g. transient memory pressure) keeps the rows buffered
            // for retry, so it still counts as appended.
            self.appended_chunks.fetch_add(1, Ordering::Relaxed);
            w.pending_charged += add;
            w.watermark = Some(chunk.keys.last().copied().unwrap_or(first));
            w.pending_keys.extend_from_slice(&chunk.keys);
            for (p, c) in w.pending_cols.iter_mut().zip(&chunk.columns) {
                p.extend_from_slice(c);
            }
            self.seal_full(&mut w)?;
        } else {
            if self.spill.is_some() {
                return Err(OsebaError::Ingest(
                    "out-of-order append on a spilling live dataset \
                     (segment ids pin partition order; use a resident live dataset)"
                        .into(),
                ));
            }
            let last = chunk.keys.last().copied().unwrap_or(first);
            if let Some(&pending_first) = w.pending_keys.first() {
                if last >= pending_first {
                    return Err(OsebaError::Ingest(format!(
                        "out-of-order chunk [{first}, {last}] overlaps the \
                         unsealed tail starting at {pending_first}"
                    )));
                }
            }
            self.seal_ooo(&mut w, chunk)?;
            // Counted only once sealed and published — a rejected overlap
            // is not an accepted chunk.
            self.appended_chunks.fetch_add(1, Ordering::Relaxed);
            self.ooo_chunks.fetch_add(1, Ordering::Relaxed);
        }
        Ok(self.published().epoch)
    }

    /// Seal the unsealed tail as a final (shorter, hence ASL) partition,
    /// making the buffered rows visible. The dataset stays appendable.
    pub fn flush(&self) -> Result<u64> {
        let mut w = self.write.lock_recover();
        if w.closed {
            return Err(OsebaError::Ingest("flush of a closed live dataset".into()));
        }
        if !w.pending_keys.is_empty() {
            let keys = w.pending_keys.clone();
            let cols = w.pending_cols.clone();
            self.seal_one(&mut w, keys, cols, SealKind::InOrder)?;
            w.pending_keys.clear();
            for c in &mut w.pending_cols {
                c.clear();
            }
            let release = w.pending_charged;
            self.block_manager.release_unsealed(self.id, release);
            w.pending_charged = 0;
        }
        Ok(self.published().epoch)
    }

    /// Pin the current epoch: an immutable snapshot of the sealed
    /// partitions and the index. O(partitions) `Arc` clones — no data is
    /// copied, no lock is held after return.
    pub fn snapshot(&self) -> EpochSnapshot {
        let cur = self.published();
        let dataset = Dataset {
            id: self.id,
            schema: self.schema.clone(),
            parts: cur.parts.clone(),
            lineage: Lineage::Source { name: format!("live@epoch{}", cur.epoch) },
            store: self.spill.clone(),
            visible: self.spill.as_ref().map(|_| cur.partitions),
        };
        EpochSnapshot {
            epoch: cur.epoch,
            rows: cur.rows,
            index: cur.index.clone(),
            dataset,
        }
    }

    /// Point-in-time ingest/index counters.
    pub fn counters(&self) -> LiveCounters {
        let w = self.write.lock_recover();
        let cur = self.published();
        LiveCounters {
            epoch: cur.epoch,
            appended_chunks: self.appended_chunks.load(Ordering::Relaxed),
            out_of_order_chunks: self.ooo_chunks.load(Ordering::Relaxed),
            sealed_partitions: cur.partitions,
            sealed_rows: cur.rows,
            unsealed_rows: w.pending_keys.len(),
            index_appends: self.index_appends.load(Ordering::Relaxed),
            asl_absorbed: self.asl_absorbed.load(Ordering::Relaxed),
            asl_len: cur.index.as_ref().map_or(0, |i| i.asl_len()),
            rebuilds: self.rebuilds.load(Ordering::Relaxed),
        }
    }

    /// Stop accepting appends and release this dataset's storage charges
    /// (sealed residents and the unsealed buffer). Existing snapshots keep
    /// their pinned data alive — like `unpersist`, closing releases
    /// *accounting*, not borrowed working sets. Idempotent.
    pub fn close(&self) {
        let mut w = self.write.lock_recover();
        if w.closed {
            return;
        }
        w.closed = true;
        let pending = w.pending_charged;
        self.block_manager.release_unsealed(self.id, pending);
        w.pending_charged = 0;
        w.pending_keys.clear();
        for c in &mut w.pending_cols {
            c.clear();
        }
        if self.spill.is_some() {
            // Registered with the block manager at creation; dropping the
            // registration releases the store's Hot residency.
            self.block_manager.unpersist(self.id);
        } else {
            self.block_manager.tracker().release(w.sealed_charged);
            w.sealed_charged = 0;
        }
    }

    fn published(&self) -> Arc<Published> {
        Arc::clone(&*self.current.lock_recover())
    }

    fn publish(&self, p: Published) {
        *self.current.lock_recover() = Arc::new(p);
    }

    /// Seal every complete `rows_per_partition` span of the buffer.
    fn seal_full(&self, w: &mut WriteState) -> Result<()> {
        let n = self.cfg.rows_per_partition;
        while w.pending_keys.len() >= n {
            let keys: Vec<i64> = w.pending_keys[..n].to_vec();
            let cols: Vec<Vec<f32>> = w.pending_cols.iter().map(|c| c[..n].to_vec()).collect();
            self.seal_one(w, keys, cols, SealKind::InOrder)?;
            // Only drain (and credit the unsealed charge) after the seal
            // succeeded — a failed seal must not lose rows.
            w.pending_keys.drain(..n);
            for c in &mut w.pending_cols {
                c.drain(..n);
            }
            let sealed_raw = (n * (8 + 4 * self.schema.width())).min(w.pending_charged);
            self.block_manager.release_unsealed(self.id, sealed_raw);
            w.pending_charged -= sealed_raw;
        }
        Ok(())
    }

    /// Seal an out-of-order chunk as one irregular partition.
    fn seal_ooo(&self, w: &mut WriteState, chunk: Chunk) -> Result<()> {
        self.seal_one(w, chunk.keys, chunk.columns, SealKind::OutOfOrder)
    }

    /// Build, index, charge and publish one partition under a new epoch.
    fn seal_one(
        &self,
        w: &mut WriteState,
        keys: Vec<i64>,
        cols: Vec<Vec<f32>>,
        kind: SealKind,
    ) -> Result<()> {
        let cur = self.published();
        let id = cur.partitions;
        let part = Arc::new(Partition::from_rows(id, keys, cols));
        let meta = PartitionMeta {
            id,
            key_min: part.key_min().unwrap_or(0),
            key_max: part.key_max().unwrap_or(0),
            rows: part.rows,
            step: detect_step(&part.keys),
        };
        // Extend a *clone* of the published index; the published one stays
        // untouched until the new epoch swaps in, so a failure here (or a
        // reader mid-query) never sees partial maintenance.
        let mut index = match &cur.index {
            Some(ix) => {
                let mut clone = (**ix).clone();
                match kind {
                    SealKind::InOrder => clone.append_meta(meta)?,
                    SealKind::OutOfOrder => clone.absorb_meta(meta)?,
                }
                clone
            }
            None => Cias::from_meta(vec![meta])?,
        };
        let mut parts = cur.parts.clone();
        match &self.spill {
            Some(store) => {
                store.insert(Arc::clone(&part))?;
            }
            None => {
                self.block_manager.allocate_reclaiming(part.bytes())?;
                w.sealed_charged += part.bytes();
                parts.push(Arc::clone(&part));
            }
        }
        // Past the last fallible step: the maintenance op will publish.
        match kind {
            SealKind::InOrder => self.index_appends.fetch_add(1, Ordering::Relaxed),
            SealKind::OutOfOrder => self.asl_absorbed.fetch_add(1, Ordering::Relaxed),
        };
        if self.spill.is_none() && index.asl_len() > self.cfg.max_asl {
            self.maybe_rebuild(&mut parts, &mut index);
        }
        self.publish(Published {
            epoch: cur.epoch + 1,
            rows: cur.rows + part.rows,
            partitions: cur.partitions + 1,
            parts,
            index: Some(Arc::new(index)),
        });
        Ok(())
    }

    /// Re-sort partitions by key, renumber, and rebuild the index — but
    /// only when the rebuilt index actually shrinks the ASL (growth from
    /// genuinely irregular partition *sizes* cannot be compressed away,
    /// and retrying on every seal would thrash). The trial runs on
    /// metadata alone; partition data is cloned only for ids that change.
    /// Readers keep serving the previous epoch untouched. Byte accounting
    /// is unchanged: renumbered clones are the same size as the originals
    /// they replace.
    fn maybe_rebuild(&self, parts: &mut Vec<Arc<Partition>>, index: &mut Cias) {
        let mut order: Vec<usize> = (0..parts.len()).collect();
        order.sort_by_key(|&i| parts[i].key_min().unwrap_or(i64::MIN));
        let metas: Vec<PartitionMeta> = order
            .iter()
            .enumerate()
            .map(|(new_id, &i)| PartitionMeta {
                id: new_id,
                key_min: parts[i].key_min().unwrap_or(0),
                key_max: parts[i].key_max().unwrap_or(0),
                rows: parts[i].rows,
                step: detect_step(&parts[i].keys),
            })
            .collect();
        let Ok(rebuilt) = Cias::from_meta(metas) else { return };
        if rebuilt.asl_len() >= index.asl_len() {
            return;
        }
        let renumbered: Vec<Arc<Partition>> = order
            .iter()
            .enumerate()
            .map(|(new_id, &i)| {
                let p = &parts[i];
                if p.id == new_id {
                    Arc::clone(p)
                } else {
                    Arc::new(Partition { id: new_id, ..(**p).clone() })
                }
            })
            .collect();
        *parts = renumbered;
        *index = rebuilt;
        self.rebuilds.fetch_add(1, Ordering::Relaxed);
    }
}

/// How a partition entered the index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SealKind {
    InOrder,
    OutOfOrder,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::MemoryTracker;
    use crate::index::{ContentIndex, RangeQuery};
    use crate::testing::temp_dir;

    fn live(rows_per: usize, max_asl: usize) -> LiveDataset {
        LiveDataset::new(
            1,
            Schema::stock(),
            LiveConfig { rows_per_partition: rows_per, max_asl },
            Arc::new(BlockManager::new(MemoryTracker::unbounded())),
            None,
        )
        .unwrap()
    }

    /// `rows` consecutive rows starting at key `start` (step 1).
    fn chunk(start: i64, rows: usize) -> Chunk {
        let keys: Vec<i64> = (0..rows as i64).map(|i| start + i).collect();
        let price: Vec<f32> = keys.iter().map(|&k| k as f32).collect();
        let volume = vec![1.0; rows];
        Chunk { keys, columns: vec![price, volume] }
    }

    #[test]
    fn epochs_advance_only_on_seal() {
        let live = live(100, 8);
        assert_eq!(live.epoch(), 0);
        // 60 rows buffered: nothing visible.
        let e = live.append(chunk(0, 60)).unwrap();
        assert_eq!(e, 0);
        let snap = live.snapshot();
        assert_eq!(snap.rows(), 0);
        assert!(snap.index().is_none());
        // 60 more → one partition seals (100), 20 stay buffered.
        let e = live.append(chunk(60, 60)).unwrap();
        assert_eq!(e, 1);
        let snap = live.snapshot();
        assert_eq!(snap.rows(), 100);
        assert_eq!(snap.num_partitions(), 1);
        let c = live.counters();
        assert_eq!(c.unsealed_rows, 20);
        assert_eq!(c.sealed_partitions, 1);
        assert_eq!(c.index_appends, 1);
        // Flush publishes the tail as a (shorter) ASL partition.
        let e = live.flush().unwrap();
        assert_eq!(e, 2);
        let snap = live.snapshot();
        assert_eq!(snap.rows(), 120);
        assert_eq!(snap.index().unwrap().asl_len(), 1);
        live.close();
    }

    #[test]
    fn snapshots_are_immutable_under_later_appends() {
        let live = live(50, 8);
        live.append(chunk(0, 150)).unwrap(); // 3 partitions
        let old = live.snapshot();
        assert_eq!(old.epoch(), 3);
        assert_eq!(old.rows(), 150);
        let q = RangeQuery { lo: 0, hi: 10_000 };
        let old_slices = old.index().unwrap().lookup(q);

        live.append(chunk(150, 100)).unwrap(); // 2 more partitions
        let new = live.snapshot();
        assert_eq!(new.epoch(), 5);
        assert_eq!(new.rows(), 250);
        // The pinned snapshot still sees exactly its epoch's state.
        assert_eq!(old.rows(), 150);
        assert_eq!(old.num_partitions(), 3);
        assert_eq!(old.index().unwrap().lookup(q), old_slices);
        assert_eq!(old.dataset().total_rows(), 150);
        assert!(new.index().unwrap().lookup(q).len() > old_slices.len());
        live.close();
    }

    #[test]
    fn out_of_order_chunk_is_absorbed_and_queryable() {
        let live = live(100, 8);
        live.append(chunk(0, 100)).unwrap(); // keys 0..99
        live.append(chunk(300, 100)).unwrap(); // keys 300..399 (gap)
        // Late chunk fills part of the gap.
        let e = live.append(chunk(150, 30)).unwrap(); // keys 150..179
        assert_eq!(e, 3);
        let c = live.counters();
        assert_eq!(c.out_of_order_chunks, 1);
        assert_eq!(c.asl_absorbed, 1);
        assert_eq!(c.sealed_rows, 230);

        let snap = live.snapshot();
        let hits = snap.index().unwrap().lookup(RangeQuery { lo: 160, hi: 170 });
        assert_eq!(hits.len(), 1);
        let s = hits[0];
        let part = &snap.dataset().partitions()[s.partition];
        assert_eq!(&part.keys[s.row_start..s.row_end], &(160..=170).collect::<Vec<i64>>()[..]);
        live.close();
    }

    #[test]
    fn out_of_order_rejects_overlap_with_sealed_and_pending() {
        let live = live(100, 8);
        live.append(chunk(0, 100)).unwrap(); // sealed keys 0..99
        live.append(chunk(200, 50)).unwrap(); // pending keys 200..249
        let before = live.counters();
        // Overlaps the sealed partition.
        assert!(live.append(chunk(50, 10)).is_err());
        // Overlaps the unsealed tail.
        let err = live.append(chunk(150, 100)).unwrap_err(); // 150..249
        assert!(err.to_string().contains("unsealed tail"), "got: {err}");
        // State unchanged by the failures.
        let after = live.counters();
        assert_eq!(after.epoch, before.epoch);
        assert_eq!(after.sealed_rows, before.sealed_rows);
        assert_eq!(after.unsealed_rows, before.unsealed_rows);
        live.close();
    }

    #[test]
    fn asl_over_bound_triggers_rebuild_when_it_helps() {
        // One-partition chunks arriving 0, 2, 3, 4, then 1 late: the ASL
        // grows past max_asl=2 but only compresses once the hole is
        // filled — exactly one rebuild, and the rebuilt index is fully
        // regular again.
        let live = live(100, 2);
        live.append(chunk(0, 100)).unwrap();
        live.append(chunk(200, 100)).unwrap(); // gap → ASL
        live.append(chunk(300, 100)).unwrap(); // ASL
        live.append(chunk(400, 100)).unwrap(); // ASL (len 3 > 2, rebuild refused: hole)
        assert_eq!(live.counters().rebuilds, 0);
        live.append(chunk(100, 100)).unwrap(); // fills the hole → rebuild helps
        let c = live.counters();
        assert_eq!(c.rebuilds, 1);
        assert_eq!(c.asl_len, 0, "fully regular after rebuild");
        assert_eq!(c.sealed_partitions, 5);

        // Renumbered partitions are consistent: parts[i].id == i and data
        // is in key order.
        let snap = live.snapshot();
        let parts = snap.dataset().partitions();
        for (i, p) in parts.iter().enumerate() {
            assert_eq!(p.id, i);
            assert_eq!(p.key_min(), Some(i as i64 * 100));
        }
        // And lookups match a freshly built reference.
        let reference = Cias::build(parts).unwrap();
        for q in [RangeQuery { lo: 50, hi: 450 }, RangeQuery { lo: 120, hi: 130 }] {
            assert_eq!(snap.index().unwrap().lookup(q), reference.lookup(q), "{q:?}");
        }
        live.close();
    }

    #[test]
    fn unsealed_buffer_charged_and_released() {
        let bm = Arc::new(BlockManager::new(MemoryTracker::unbounded()));
        let live = LiveDataset::new(
            7,
            Schema::stock(),
            LiveConfig { rows_per_partition: 100, max_asl: 8 },
            Arc::clone(&bm),
            None,
        )
        .unwrap();
        live.append(chunk(0, 40)).unwrap();
        // 40 unsealed rows × (8 + 2×4) bytes.
        assert_eq!(bm.unsealed_bytes(), 40 * 16);
        live.append(chunk(40, 60)).unwrap(); // seals 100, 0 pending
        assert_eq!(bm.unsealed_bytes(), 0);
        assert!(bm.used_bytes() > 0, "sealed partition stays charged");
        live.close();
        assert_eq!(bm.used_bytes(), 0, "close releases everything");
        // Closed dataset rejects further use.
        assert!(live.append(chunk(100, 10)).is_err());
        assert!(live.flush().is_err());
        live.close(); // idempotent
    }

    #[test]
    fn spilling_live_seals_into_store_and_pins_snapshots() {
        let dir = temp_dir("live-spill");
        let tracker = MemoryTracker::unbounded();
        let bm = Arc::new(BlockManager::new(Arc::clone(&tracker)));
        let store =
            Arc::new(TieredStore::create(&dir, Schema::stock(), tracker).unwrap());
        bm.register_store(3, Arc::clone(&store)).unwrap();
        let live = LiveDataset::new(
            3,
            Schema::stock(),
            LiveConfig { rows_per_partition: 100, max_asl: 8 },
            bm,
            Some(Arc::clone(&store)),
        )
        .unwrap();

        live.append(chunk(0, 200)).unwrap(); // 2 partitions into the store
        let old = live.snapshot();
        assert_eq!(old.num_partitions(), 2);
        assert_eq!(old.rows(), 200);
        assert!(old.dataset().is_tiered());

        live.append(chunk(200, 100)).unwrap(); // a third, after the snapshot
        assert_eq!(store.num_partitions(), 3);
        // The pinned snapshot still reports its epoch's prefix even though
        // the shared store grew.
        assert_eq!(old.num_partitions(), 2);
        assert_eq!(old.dataset().total_rows(), 200);
        assert_eq!(old.dataset().key_max(), Some(199));
        let hits = old.index().unwrap().lookup(RangeQuery { lo: 0, hi: 10_000 });
        assert_eq!(hits.len(), 2, "index pinned at the snapshot epoch");
        // Data is fetchable through the store.
        let p = store.fetch(hits[1].partition).unwrap();
        assert_eq!(p.key_min(), Some(100));

        // Out-of-order appends are rejected in spilling mode.
        live.append(chunk(1_000, 10)).unwrap();
        let err = live.append(chunk(500, 10)).unwrap_err();
        assert!(err.to_string().contains("out-of-order"), "got: {err}");
        live.close();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn duplicate_boundary_key_is_rejected_not_wedged() {
        // Regression: a chunk starting exactly at the watermark used to be
        // classified in-order, then wedge the dataset forever when the
        // seal hit the index's inclusive-range overlap check. It must be
        // a clear, stateless rejection instead.
        let live = live(2, 8);
        live.append(chunk(1, 2)).unwrap(); // seals [1, 2], watermark 2
        let before = live.counters();
        let dup = chunk(2, 2); // starts at the watermark
        let err = live.append(dup).unwrap_err();
        assert!(matches!(err, OsebaError::Index(_) | OsebaError::Ingest(_)), "got: {err}");
        // Nothing buffered, nothing charged, nothing counted: the stream
        // continues cleanly past the rejection.
        let after = live.counters();
        assert_eq!(after, before);
        live.append(chunk(3, 2)).unwrap(); // seals [3, 4]
        assert_eq!(live.counters().sealed_rows, 4);
        // Duplicates inside one chunk are rejected up front too.
        let inside = Chunk { keys: vec![10, 10], columns: vec![vec![0.0; 2], vec![0.0; 2]] };
        assert!(live.append(inside).is_err());
        live.close();
    }

    #[test]
    fn rejects_malformed_chunks() {
        let live = live(100, 8);
        // Wrong width.
        let bad = Chunk { keys: vec![1], columns: vec![vec![0.0]] };
        assert!(live.append(bad).is_err());
        // Ragged.
        let bad = Chunk { keys: vec![1, 2], columns: vec![vec![0.0; 2], vec![0.0]] };
        assert!(live.append(bad).is_err());
        // Unsorted.
        let bad = Chunk { keys: vec![5, 3], columns: vec![vec![0.0; 2], vec![0.0; 2]] };
        assert!(live.append(bad).is_err());
        // Empty chunk is a no-op, not an error.
        let empty = Chunk { keys: vec![], columns: vec![vec![], vec![]] };
        assert_eq!(live.append(empty).unwrap(), 0);
        live.close();
    }
}
