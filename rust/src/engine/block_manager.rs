//! The block manager: registry of cached (memory-resident) datasets and
//! tiered (spillable) dataset stores.
//!
//! Mirrors Spark's BlockManager at the granularity this reproduction
//! needs: datasets cache their partitions here, bytes are charged to the
//! [`MemoryTracker`], and `unpersist` releases them. The Fig 4 "default
//! method" curve is exactly this registry filling up with filter-RDDs.
//!
//! Tiered datasets register their [`TieredStore`] instead of partitions.
//! They share the tracker, so when a resident cache allocation would
//! exceed the budget the manager first asks the registered stores to
//! spill cold-able partitions to disk ([`TieredStore::shrink`]) — memory
//! pressure evicts to segments instead of erroring, and only truly
//! unreclaimable pressure still surfaces `OutOfMemory`.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::engine::memory::MemoryTracker;
use crate::error::{OsebaError, Result};
use crate::storage::Partition;
use crate::store::TieredStore;
use crate::util::sync::MutexExt;

/// Identifier of a cached dataset.
pub type DatasetId = u64;

#[derive(Debug)]
struct CacheEntry {
    parts: Vec<Arc<Partition>>,
    bytes: usize,
}

/// Thread-safe cached-dataset registry with byte accounting.
#[derive(Debug)]
pub struct BlockManager {
    tracker: Arc<MemoryTracker>,
    cache: Mutex<HashMap<DatasetId, CacheEntry>>,
    /// Tiered datasets by id — the spill targets under memory pressure.
    stores: Mutex<HashMap<DatasetId, Arc<TieredStore>>>,
    /// Bytes charged for live datasets' *unsealed* chunk buffers, by
    /// dataset — rows that have arrived but are not yet sealed into a
    /// partition (and so are invisible to every epoch snapshot).
    unsealed: Mutex<HashMap<DatasetId, usize>>,
}

impl BlockManager {
    /// Build over a (possibly budgeted) memory tracker.
    pub fn new(tracker: Arc<MemoryTracker>) -> BlockManager {
        BlockManager {
            tracker,
            cache: Mutex::new(HashMap::new()),
            stores: Mutex::new(HashMap::new()),
            unsealed: Mutex::new(HashMap::new()),
        }
    }

    /// Charge `bytes` to the tracker; under budget pressure registered
    /// tiered stores are asked to spill before the allocation is declared
    /// impossible. The shared admission path for caches, live seals and
    /// unsealed chunk buffers.
    pub(crate) fn allocate_reclaiming(&self, bytes: usize) -> Result<()> {
        match self.tracker.allocate(bytes) {
            Ok(()) => Ok(()),
            Err(e @ OsebaError::OutOfMemory { .. }) => {
                let shortfall =
                    bytes.saturating_sub(self.tracker.headroom().unwrap_or(0));
                self.reclaim(shortfall)?;
                // Retry once; still-unreclaimable pressure keeps the
                // original error semantics.
                if self.tracker.allocate(bytes).is_err() {
                    return Err(e);
                }
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Cache a dataset's partitions, charging their bytes. Under budget
    /// pressure, registered tiered stores are asked to spill before the
    /// allocation is declared impossible.
    pub fn cache(&self, id: DatasetId, parts: Vec<Arc<Partition>>) -> Result<()> {
        let bytes: usize = parts.iter().map(|p| p.bytes()).sum();
        let mut cache = self.cache.lock_recover();
        if cache.contains_key(&id) || self.stores.lock_recover().contains_key(&id) {
            return Err(OsebaError::Schema(format!("dataset {id} already cached")));
        }
        self.allocate_reclaiming(bytes)?;
        cache.insert(id, CacheEntry { parts, bytes });
        Ok(())
    }

    /// Charge `bytes` of unsealed live-chunk buffer to dataset `id`. Like
    /// [`Self::cache`], budget pressure spills registered stores first.
    pub fn charge_unsealed(&self, id: DatasetId, bytes: usize) -> Result<()> {
        self.allocate_reclaiming(bytes)?;
        *self.unsealed.lock_recover().entry(id).or_insert(0) += bytes;
        Ok(())
    }

    /// Credit back up to `bytes` of dataset `id`'s unsealed charge (rows
    /// were sealed into a partition, or the live dataset closed).
    pub fn release_unsealed(&self, id: DatasetId, bytes: usize) {
        let mut unsealed = self.unsealed.lock_recover();
        if let Some(slot) = unsealed.get_mut(&id) {
            let take = bytes.min(*slot);
            *slot -= take;
            if *slot == 0 {
                unsealed.remove(&id);
            }
            self.tracker.release(take);
        }
    }

    /// Total bytes currently charged for unsealed live-chunk buffers.
    pub fn unsealed_bytes(&self) -> usize {
        self.unsealed.lock_recover().values().sum()
    }

    /// Register a tiered dataset's store (no bytes charged here — the
    /// store charges the shared tracker as partitions go Hot).
    pub fn register_store(&self, id: DatasetId, store: Arc<TieredStore>) -> Result<()> {
        // Lock order everywhere is cache → stores (see `cache`/`reclaim`).
        let cache = self.cache.lock_recover();
        let mut stores = self.stores.lock_recover();
        if stores.contains_key(&id) || cache.contains_key(&id) {
            return Err(OsebaError::Schema(format!("dataset {id} already cached")));
        }
        stores.insert(id, store);
        Ok(())
    }

    /// Ask registered stores to spill until `needed` bytes are freed (or
    /// nothing spillable remains).
    fn reclaim(&self, needed: usize) -> Result<usize> {
        let stores: Vec<Arc<TieredStore>> =
            self.stores.lock_recover().values().cloned().collect();
        let mut freed = 0usize;
        for store in stores {
            if freed >= needed {
                break;
            }
            freed += store.shrink(needed - freed)?;
        }
        Ok(freed)
    }

    /// Fetch a cached dataset's partitions (resident datasets only).
    pub fn get(&self, id: DatasetId) -> Option<Vec<Arc<Partition>>> {
        self.cache.lock_recover().get(&id).map(|e| e.parts.clone())
    }

    /// The tiered store backing dataset `id`, if registered.
    pub fn get_store(&self, id: DatasetId) -> Option<Arc<TieredStore>> {
        self.stores.lock_recover().get(&id).cloned()
    }

    /// Evict a dataset, crediting its bytes. Returns whether it was cached.
    /// For a tiered dataset this drops the Hot partitions (segments on
    /// disk are untouched).
    pub fn unpersist(&self, id: DatasetId) -> bool {
        // Any unsealed live-buffer charge dies with the registration.
        if let Some(bytes) = self.unsealed.lock_recover().remove(&id) {
            self.tracker.release(bytes);
        }
        let entry = self.cache.lock_recover().remove(&id);
        if let Some(e) = entry {
            self.tracker.release(e.bytes);
            return true;
        }
        match self.stores.lock_recover().remove(&id) {
            Some(store) => {
                store.release_resident();
                true
            }
            None => false,
        }
    }

    /// Total bytes currently charged (resident caches + Hot store bytes).
    pub fn used_bytes(&self) -> usize {
        self.tracker.used()
    }

    /// High-water mark of charged bytes.
    pub fn peak_bytes(&self) -> usize {
        self.tracker.peak()
    }

    /// Number of registered datasets (resident + tiered).
    pub fn num_cached(&self) -> usize {
        self.cache.lock_recover().len() + self.stores.lock_recover().len()
    }

    /// The shared tracker (for coordinator metrics).
    pub fn tracker(&self) -> Arc<MemoryTracker> {
        Arc::clone(&self.tracker)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{BatchBuilder, Schema};
    use crate::testing::temp_dir;

    fn one_part(rows: usize) -> Vec<Arc<Partition>> {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..rows {
            b.push(i as i64, &[0.0, 0.0]);
        }
        crate::storage::partition_batch(&b.finish().unwrap(), 1).unwrap()
    }

    #[test]
    fn cache_charges_and_unpersist_credits() {
        let bm = BlockManager::new(MemoryTracker::unbounded());
        let parts = one_part(100);
        let bytes: usize = parts.iter().map(|p| p.bytes()).sum();
        bm.cache(1, parts).unwrap();
        assert_eq!(bm.used_bytes(), bytes);
        assert_eq!(bm.num_cached(), 1);
        assert!(bm.unpersist(1));
        assert_eq!(bm.used_bytes(), 0);
        assert!(!bm.unpersist(1));
    }

    #[test]
    fn duplicate_cache_rejected() {
        let bm = BlockManager::new(MemoryTracker::unbounded());
        bm.cache(7, one_part(10)).unwrap();
        assert!(bm.cache(7, one_part(10)).is_err());
    }

    #[test]
    fn get_returns_same_partitions() {
        let bm = BlockManager::new(MemoryTracker::unbounded());
        let parts = one_part(10);
        bm.cache(3, parts.clone()).unwrap();
        let got = bm.get(3).unwrap();
        assert_eq!(got.len(), parts.len());
        assert!(Arc::ptr_eq(&got[0], &parts[0]));
        assert!(bm.get(99).is_none());
    }

    #[test]
    fn budget_propagates_to_cache() {
        let bm = BlockManager::new(MemoryTracker::with_budget(10));
        assert!(bm.cache(1, one_part(100)).is_err());
        assert_eq!(bm.num_cached(), 0);
        assert_eq!(bm.used_bytes(), 0);
    }

    #[test]
    fn pressure_spills_registered_store_before_failing() {
        let dir = temp_dir("bm-pressure");
        let parts = one_part(100);
        let bytes: usize = parts.iter().map(|p| p.bytes()).sum();
        // Budget fits the store's partition OR the cache entry, not both.
        let tracker = MemoryTracker::with_budget(bytes + bytes / 2);
        let bm = BlockManager::new(Arc::clone(&tracker));
        let store = Arc::new(
            TieredStore::create(&dir, Schema::stock(), Arc::clone(&tracker)).unwrap(),
        );
        store.insert(Arc::clone(&parts[0])).unwrap();
        bm.register_store(9, Arc::clone(&store)).unwrap();
        assert_eq!(bm.used_bytes(), bytes);
        assert_eq!(bm.num_cached(), 1);

        // Without the store this would be OutOfMemory; with it, the store
        // spills its partition to disk and the cache fits.
        bm.cache(1, one_part(100)).unwrap();
        assert_eq!(store.counters().evictions, 1);
        assert_eq!(
            store.residency(0),
            Some(crate::store::Residency::Cold)
        );
        assert_eq!(bm.used_bytes(), bytes);

        // Unpersisting the tiered dataset releases nothing extra (already
        // cold) but removes the registration.
        assert!(bm.unpersist(9));
        assert!(!bm.unpersist(9));
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn unsealed_accounting_charges_and_credits() {
        let bm = BlockManager::new(MemoryTracker::with_budget(1000));
        bm.charge_unsealed(5, 300).unwrap();
        bm.charge_unsealed(5, 200).unwrap();
        bm.charge_unsealed(6, 100).unwrap();
        assert_eq!(bm.unsealed_bytes(), 600);
        assert_eq!(bm.used_bytes(), 600);
        // Budget applies to unsealed buffers too.
        assert!(bm.charge_unsealed(5, 500).is_err());
        bm.release_unsealed(5, 450);
        assert_eq!(bm.unsealed_bytes(), 150);
        assert_eq!(bm.used_bytes(), 150);
        // Over-release clamps to what was charged.
        bm.release_unsealed(5, 10_000);
        bm.release_unsealed(6, 100);
        assert_eq!(bm.unsealed_bytes(), 0);
        assert_eq!(bm.used_bytes(), 0);
        // Releasing an unknown id is a no-op.
        bm.release_unsealed(99, 10);
        assert_eq!(bm.used_bytes(), 0);
    }

    #[test]
    fn duplicate_store_registration_rejected() {
        let dir = temp_dir("bm-dup");
        let tracker = MemoryTracker::unbounded();
        let bm = BlockManager::new(Arc::clone(&tracker));
        let store =
            Arc::new(TieredStore::create(&dir, Schema::stock(), tracker).unwrap());
        bm.register_store(2, Arc::clone(&store)).unwrap();
        assert!(bm.register_store(2, store).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
