//! Seeded violation: `ghost_counter` is declared but nothing updates it
//! and the server never surfaces it.

pub struct EngineCounters {
    pub partitions_scanned: usize,
    pub ghost_counter: usize,
}

pub fn bump(c: &mut EngineCounters) {
    c.partitions_scanned += 1;
    let _ = c.partitions_scanned;
}
