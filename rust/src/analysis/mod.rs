//! Selective bulk analyses (paper §II, Fig 1): period statistics, moving
//! average, distance comparison, events analysis (histograms) and model
//! train/test splitting — all expressed over partition slices so both the
//! default (filtered-dataset) and Oseba (indexed-view) access paths feed
//! the same compute.

pub mod ops;
pub mod split;
pub mod trend;
pub mod workload;

pub use ops::{Analyzer, DistanceResult, PeriodStats};
pub use trend::StationarityReport;
pub use split::{train_test_split, SplitSpec};
pub use workload::{five_periods, random_periods, PeriodSpec};
