//! Crate-wide error type.
//!
//! Every public fallible API in the crate returns [`Result`]. Variants are
//! grouped by subsystem so callers can match on the failure domain (e.g. a
//! server can map `Query*` errors to client-visible messages while treating
//! `Runtime`/`Io` as internal).
//!
//! `Display`/`Error` are implemented by hand: the vendored dependency set
//! has no `thiserror` (see DESIGN.md §4).

use std::fmt;

/// Errors produced by the Oseba engine, indexes, runtime and coordinator.
#[derive(Debug)]
pub enum OsebaError {
    /// Dataset construction / schema violations.
    Schema(String),

    /// A query referenced a column that does not exist.
    UnknownColumn(String),

    /// A range query that cannot be satisfied (e.g. inverted bounds).
    InvalidRange(String),

    /// Index construction failed (unsorted keys, empty dataset, ...).
    Index(String),

    /// The PJRT runtime failed to load/compile/execute an artifact.
    Runtime(String),

    /// An artifact or its manifest is missing or malformed.
    Artifact(String),

    /// Cluster/scheduler failures (worker death without reassignment, ...).
    Cluster(String),

    /// Configuration parse/validation failures.
    Config(String),

    /// JSON parse errors (manifest, server protocol).
    Json(String),

    /// Memory budget exhausted and eviction could not reclaim enough.
    OutOfMemory { requested: usize, budget: usize },

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for OsebaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OsebaError::Schema(m) => write!(f, "schema error: {m}"),
            OsebaError::UnknownColumn(m) => write!(f, "unknown column: {m}"),
            OsebaError::InvalidRange(m) => write!(f, "invalid range: {m}"),
            OsebaError::Index(m) => write!(f, "index error: {m}"),
            OsebaError::Runtime(m) => write!(f, "runtime error: {m}"),
            OsebaError::Artifact(m) => write!(f, "artifact error: {m}"),
            OsebaError::Cluster(m) => write!(f, "cluster error: {m}"),
            OsebaError::Config(m) => write!(f, "config error: {m}"),
            OsebaError::Json(m) => write!(f, "json error: {m}"),
            OsebaError::OutOfMemory { requested, budget } => write!(
                f,
                "out of storage memory: requested {requested} bytes, budget {budget}"
            ),
            OsebaError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for OsebaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            OsebaError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for OsebaError {
    fn from(e: std::io::Error) -> Self {
        OsebaError::Io(e)
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, OsebaError>;

#[cfg(feature = "xla")]
impl From<xla::Error> for OsebaError {
    fn from(e: xla::Error) -> Self {
        OsebaError::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = OsebaError::UnknownColumn("wind".into());
        assert!(e.to_string().contains("unknown column"));
        let e = OsebaError::OutOfMemory { requested: 10, budget: 5 };
        assert!(e.to_string().contains("requested 10"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OsebaError = io.into();
        assert!(matches!(e, OsebaError::Io(_)));
    }

    #[test]
    fn io_source_is_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OsebaError = io.into();
        let src = std::error::Error::source(&e).expect("io source");
        assert!(src.to_string().contains("gone"));
    }
}
