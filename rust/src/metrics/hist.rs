//! Fixed-bucket log-scale latency histograms.
//!
//! The recorder ([`LatencyHistogram`]) is lock-free and float-free: one
//! atomic increment per sample on a power-of-two bucket grid over
//! nanoseconds. Bucket `0` holds exactly the value `0`; bucket `i`
//! (`1 <= i < 63`) holds `[2^(i-1), 2^i - 1]`; the top bucket is
//! open-ended. Quantiles are extracted from a [`HistSnapshot`] by exact
//! rank over the bucket counts and reported as the bucket's upper bound,
//! so the returned value is never below the true sample and less than 2x
//! above it (a factor-2 error bound, one bucket of resolution).
//!
//! Snapshots are plain vectors: merging two is element-wise addition,
//! which makes merge trivially associative and commutative — per-thread
//! recording with a fold at the end is exact.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::util::json::Json;

/// Number of buckets: one per possible `u64` bit length, plus bucket 0
/// for the value zero (the top two bit lengths share the last bucket).
pub const BUCKETS: usize = 64;

/// Bucket index of a nanosecond value: its bit length, clamped into the
/// open-ended top bucket.
pub fn bucket_of(nanos: u64) -> usize {
    ((u64::BITS - nanos.leading_zeros()) as usize).min(BUCKETS - 1)
}

/// Inclusive upper bound of a bucket (what quantile extraction reports).
pub fn bucket_hi(bucket: usize) -> u64 {
    if bucket == 0 {
        0
    } else if bucket >= BUCKETS - 1 {
        u64::MAX
    } else {
        (1u64 << bucket) - 1
    }
}

/// Concurrent fixed-bucket histogram of nanosecond latencies.
///
/// `record` is wait-free (two relaxed `fetch_add`s) and allocation-free;
/// readers take a [`HistSnapshot`] and do all arithmetic off the hot
/// path.
#[derive(Debug)]
pub struct LatencyHistogram {
    buckets: [AtomicU64; BUCKETS],
    /// Sum of recorded nanoseconds (for mean extraction).
    sum: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> LatencyHistogram {
        LatencyHistogram::new()
    }
}

impl LatencyHistogram {
    /// An empty histogram.
    pub fn new() -> LatencyHistogram {
        LatencyHistogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one sample, in nanoseconds.
    pub fn record(&self, nanos: u64) {
        self.buckets[bucket_of(nanos)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(nanos, Ordering::Relaxed);
    }

    /// Record one sample from a [`Duration`] (saturating past ~584 years).
    pub fn record_duration(&self, d: Duration) {
        self.record(u64::try_from(d.as_nanos()).unwrap_or(u64::MAX));
    }

    /// A point-in-time copy of the bucket counts.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            sum_nanos: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// Immutable copy of a [`LatencyHistogram`]: bucket counts plus the
/// nanosecond sum. All quantile/merge arithmetic lives here, off the
/// recording path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HistSnapshot {
    /// Per-bucket sample counts ([`BUCKETS`] entries).
    pub buckets: Vec<u64>,
    /// Sum of recorded nanoseconds.
    pub sum_nanos: u64,
}

impl Default for HistSnapshot {
    fn default() -> HistSnapshot {
        HistSnapshot { buckets: vec![0; BUCKETS], sum_nanos: 0 }
    }
}

impl HistSnapshot {
    /// Total samples recorded.
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Element-wise sum of two snapshots (associative and commutative).
    pub fn merge(&self, other: &HistSnapshot) -> HistSnapshot {
        let n = self.buckets.len().max(other.buckets.len());
        let get = |v: &[u64], i: usize| v.get(i).copied().unwrap_or(0);
        HistSnapshot {
            buckets: (0..n)
                .map(|i| get(&self.buckets, i).saturating_add(get(&other.buckets, i)))
                .collect(),
            sum_nanos: self.sum_nanos.saturating_add(other.sum_nanos),
        }
    }

    /// Exact-rank quantile in nanoseconds: the upper bound of the bucket
    /// holding the sample of rank `ceil(q * count)` (nearest-rank, the
    /// same convention as [`crate::util::stats::percentile`]). Returns 0
    /// for an empty snapshot.
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, c) in self.buckets.iter().enumerate() {
            cum = cum.saturating_add(*c);
            if cum >= rank {
                return bucket_hi(i);
            }
        }
        bucket_hi(BUCKETS - 1)
    }

    /// Median, in nanoseconds.
    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    /// 95th percentile, in nanoseconds.
    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    /// 99th percentile, in nanoseconds.
    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// 99.9th percentile, in nanoseconds.
    pub fn p999(&self) -> u64 {
        self.quantile(0.999)
    }

    /// Mean sample, in seconds (0 for an empty snapshot).
    pub fn mean_secs(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_nanos as f64 / n as f64 / 1e9
        }
    }

    /// JSON rendering: the count plus p50/p95/p99/p999 and the mean, all
    /// quantiles in seconds (the unit every other bench leaf uses).
    pub fn to_json(&self) -> Json {
        let secs = |nanos: u64| Json::num(nanos as f64 / 1e9);
        Json::obj(vec![
            ("count", Json::num(self.count() as f64)),
            ("p50", secs(self.p50())),
            ("p95", secs(self.p95())),
            ("p99", secs(self.p99())),
            ("p999", secs(self.p999())),
            ("mean_secs", Json::num(self.mean_secs())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use super::*;
    use crate::util::rng::Xoshiro256;
    use crate::util::stats::percentile;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(1024), 11);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_hi(0), 0);
        assert_eq!(bucket_hi(1), 1);
        assert_eq!(bucket_hi(2), 3);
        assert_eq!(bucket_hi(11), 2047);
        assert_eq!(bucket_hi(BUCKETS - 1), u64::MAX);
        // Every value's bucket upper bound is >= the value and < 2x it.
        for v in [1u64, 2, 3, 5, 100, 999, 4096, 1 << 40] {
            let hi = bucket_hi(bucket_of(v));
            assert!(hi >= v && hi < v.saturating_mul(2), "v={v} hi={hi}");
        }
    }

    /// Quantiles must land in the same bucket as an exact-sort oracle.
    fn check_against_oracle(samples: &[u64]) {
        let h = LatencyHistogram::new();
        for &s in samples {
            h.record(s);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), samples.len() as u64);
        let mut sorted: Vec<f64> = samples.iter().map(|&s| s as f64).collect();
        sorted.sort_by(f64::total_cmp);
        for q in [0.5, 0.95, 0.99, 0.999] {
            let oracle = percentile(&sorted, q) as u64;
            let got = snap.quantile(q);
            assert_eq!(bucket_of(got), bucket_of(oracle), "q={q} oracle={oracle} got={got}");
            assert!(got >= oracle, "q={q} oracle={oracle} got={got}");
            assert!(
                oracle == 0 || got < oracle.saturating_mul(2),
                "q={q} oracle={oracle} got={got}"
            );
        }
    }

    #[test]
    fn quantiles_match_oracle_uniform() {
        let mut rng = Xoshiro256::seeded(7);
        let samples: Vec<u64> = (0..10_000).map(|_| rng.below(1_000_000)).collect();
        check_against_oracle(&samples);
    }

    #[test]
    fn quantiles_match_oracle_log_normal() {
        let mut rng = Xoshiro256::seeded(11);
        let samples: Vec<u64> =
            (0..10_000).map(|_| (rng.normal_with(8.0, 2.0).exp()) as u64).collect();
        check_against_oracle(&samples);
    }

    #[test]
    fn quantiles_match_oracle_point_mass() {
        check_against_oracle(&vec![12_345u64; 5_000]);
        check_against_oracle(&vec![0u64; 100]);
    }

    fn random_snapshot(seed: u64) -> HistSnapshot {
        let mut rng = Xoshiro256::seeded(seed);
        let h = LatencyHistogram::new();
        for _ in 0..1_000 {
            h.record(rng.below(1 << 30));
        }
        h.snapshot()
    }

    #[test]
    fn merge_is_associative_and_commutative() {
        let (a, b, c) = (random_snapshot(1), random_snapshot(2), random_snapshot(3));
        assert_eq!(a.merge(&b), b.merge(&a));
        assert_eq!(a.merge(&b).merge(&c), a.merge(&b.merge(&c)));
        assert_eq!(a.merge(&b).count(), a.count() + b.count());
        assert_eq!(a.merge(&HistSnapshot::default()), a);
    }

    #[test]
    fn concurrent_recording_loses_no_samples() {
        let h = Arc::new(LatencyHistogram::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1_000 + (i % 97));
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().expect("recorder thread");
        }
        assert_eq!(h.snapshot().count(), 80_000);
    }

    #[test]
    fn empty_and_extreme_values() {
        let h = LatencyHistogram::new();
        assert_eq!(h.snapshot().quantile(0.5), 0);
        assert_eq!(h.snapshot().mean_secs(), 0.0);
        h.record(0);
        h.record(u64::MAX);
        let snap = h.snapshot();
        assert_eq!(snap.count(), 2);
        assert_eq!(snap.quantile(0.5), 0);
        assert_eq!(snap.p999(), u64::MAX);
        h.record_duration(Duration::from_micros(3));
        assert_eq!(h.snapshot().count(), 3);
    }

    #[test]
    fn json_has_quantile_keys() {
        let h = LatencyHistogram::new();
        for i in 0..100 {
            h.record(i * 1_000);
        }
        let j = h.snapshot().to_json().to_string();
        for key in ["\"count\":", "\"p50\":", "\"p95\":", "\"p99\":", "\"p999\":", "\"mean_secs\":"]
        {
            assert!(j.contains(key), "{key} missing from {j}");
        }
    }
}
