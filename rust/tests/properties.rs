//! Property-based tests on the system invariants (DESIGN.md §7), via the
//! crate's own `testing` harness:
//!
//! 1. Index equivalence: for random datasets and queries, CIAS lookup ==
//!    table lookup == linear-scan ground truth.
//! 2. Moments algebra: merge of any split == whole-scan.
//! 3. Engine: Oseba's selected rows == the baseline filter's rows.
//! 4. Routing: every slice is assigned to exactly one live worker.
//! 5. CIAS compression: memory is O(ASL), never O(partitions), on regular
//!    data.

use std::sync::Arc;

use oseba::cluster::{Cluster, NetworkModel};
use oseba::config::ContextConfig;
use oseba::datagen::ClimateGen;
use oseba::engine::OsebaContext;
use oseba::index::{extract_meta, Cias, ContentIndex, PartitionSlice, RangeQuery, TableIndex};
use oseba::storage::{partition_batch_uniform, BatchBuilder, Partition, Schema};
use oseba::testing::{gen, Runner};
use oseba::util::rng::Xoshiro256;
use oseba::util::stats::Moments;

/// A random dataset layout: uniform-grid keys, random partition sizing,
/// optionally an irregular (gapped) tail region.
#[derive(Debug)]
struct Layout {
    parts: Vec<Arc<Partition>>,
    key_min: i64,
    key_max: i64,
}

fn random_layout(rng: &mut Xoshiro256) -> Layout {
    let rows = gen::usize_in(rng, 50, 3000);
    let per = gen::usize_in(rng, 10, rows.max(11));
    let step = 1 + rng.below(100) as i64;
    let base = rng.below(10_000) as i64 - 5_000;
    let gap = if rng.below(2) == 0 { 0 } else { step * (1 + rng.below(50) as i64) };

    let mut b = BatchBuilder::new(Schema::stock());
    let mut key = base;
    let gap_at = rows / 2;
    for i in 0..rows {
        if gap > 0 && i == gap_at {
            key += gap; // irregularity in the middle → exercises the ASL
        }
        b.push(key, &[i as f32, 1.0]);
        key += step;
    }
    let batch = b.finish().unwrap();
    let key_min = batch.keys[0];
    let key_max = *batch.keys.last().unwrap();
    let parts = partition_batch_uniform(&batch, per).unwrap();
    Layout { parts, key_min, key_max }
}

/// Ground truth by scanning every partition's keys.
fn scan_lookup(parts: &[Arc<Partition>], q: RangeQuery) -> Vec<PartitionSlice> {
    parts
        .iter()
        .filter_map(|p| {
            let rs = p.lower_bound(q.lo);
            let re = p.upper_bound(q.hi);
            (rs < re).then_some(PartitionSlice { partition: p.id, row_start: rs, row_end: re })
        })
        .collect()
}

/// Indexes may return conservative whole-partition slices for step-less
/// partitions; normalize through the same refinement the engine applies.
fn refine(parts: &[Arc<Partition>], slices: &[PartitionSlice], q: RangeQuery) -> Vec<PartitionSlice> {
    slices
        .iter()
        .filter_map(|s| {
            let p = &parts[s.partition];
            let (rs, re) = if s.row_start == 0 && s.row_end == p.rows && p.rows > 0 {
                (p.lower_bound(q.lo), p.upper_bound(q.hi))
            } else {
                (s.row_start, s.row_end)
            };
            (rs < re).then_some(PartitionSlice { partition: s.partition, row_start: rs, row_end: re })
        })
        .collect()
}

#[test]
fn prop_cias_equals_table_equals_scan() {
    Runner::default().run(
        "cias == table == scan",
        |rng| {
            let layout = random_layout(rng);
            let span = layout.key_max - layout.key_min;
            let (lo, hi) =
                gen::range_pair(rng, layout.key_min - span / 4, layout.key_max + span / 4);
            (layout, RangeQuery { lo, hi })
        },
        |(layout, q)| {
            let truth = scan_lookup(&layout.parts, *q);
            let table = TableIndex::build(&layout.parts).unwrap();
            let cias = Cias::build(&layout.parts).unwrap();
            let t = refine(&layout.parts, &table.lookup(*q), *q);
            let c = refine(&layout.parts, &cias.lookup(*q), *q);
            t == truth && c == truth
        },
    );
}

#[test]
fn prop_moments_merge_any_split() {
    Runner::default().run(
        "moments merge == whole scan",
        |rng| {
            let n = gen::usize_in(rng, 1, 2000);
            let xs = gen::f32_vec(rng, n, 1e3);
            let cut = gen::usize_in(rng, 0, n + 1);
            (xs, cut)
        },
        |(xs, cut)| {
            let whole = Moments::scan(xs);
            let merged = Moments::scan(&xs[..*cut]).merge(Moments::scan(&xs[*cut..]));
            whole.max == merged.max
                && whole.min == merged.min
                && whole.count == merged.count
                && (whole.sum - merged.sum).abs() <= 1e-6 * whole.sum.abs().max(1.0)
        },
    );
}

#[test]
fn prop_oseba_selects_same_rows_as_filter() {
    let ctx = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
    Runner::new(24, 0xFEED).run(
        "indexed selection == filter selection",
        |rng| {
            let rows = gen::usize_in(rng, 100, 5000);
            let nparts = gen::usize_in(rng, 1, 16);
            let (lo_h, hi_h) = gen::range_pair(rng, -10, rows as i64 + 10);
            (rows, nparts, lo_h, hi_h)
        },
        |&(rows, nparts, lo_h, hi_h)| {
            let gen_cfg = ClimateGen { seed: rows as u64, ..Default::default() };
            let ds = ctx.load(gen_cfg.generate(rows), nparts).unwrap();
            let q = RangeQuery { lo: lo_h * 3600, hi: hi_h * 3600 };
            let index = Cias::build(ds.partitions()).unwrap();
            let views = ctx.select_slices(&ds, &index.lookup(q), q).unwrap();
            let indexed_keys: Vec<i64> = views
                .views()
                .iter()
                .flat_map(|v| v.keys().iter().copied())
                .collect();
            let filtered = ctx.filter_range(&ds, q).unwrap();
            let filter_keys: Vec<i64> = filtered
                .partitions()
                .iter()
                .flat_map(|p| p.keys.iter().copied())
                .collect();
            ctx.unpersist(&filtered);
            ctx.unpersist(&ds);
            indexed_keys == filter_keys
        },
    );
}

#[test]
fn prop_routing_partitions_every_slice_once() {
    Runner::default().run(
        "routing covers each slice exactly once on live workers",
        |rng| {
            let workers = gen::usize_in(rng, 1, 12);
            let nparts = gen::usize_in(rng, 1, 64);
            let nslices = gen::usize_in(rng, 0, 64);
            let slices: Vec<PartitionSlice> = (0..nslices)
                .map(|_| PartitionSlice {
                    partition: gen::usize_in(rng, 0, nparts),
                    row_start: 0,
                    row_end: 1,
                })
                .collect();
            let kill = if workers > 1 { Some(gen::usize_in(rng, 0, workers)) } else { None };
            (workers, nparts, slices, kill)
        },
        |(workers, nparts, slices, kill)| {
            let c = Cluster::new(*workers, *nparts, NetworkModel::default()).unwrap();
            if let Some(k) = kill {
                c.kill_worker(*k).unwrap();
            }
            let groups = c.route(slices).unwrap();
            let routed: usize = groups.iter().map(|(_, g)| g.len()).sum();
            let all_live = groups.iter().all(|(w, _)| c.is_alive(*w));
            routed == slices.len() && all_live
        },
    );
}

#[test]
fn prop_cias_memory_constant_for_regular_layouts() {
    Runner::new(32, 0xC1A5).run(
        "cias space independent of partition count on regular data",
        |rng| {
            let per = gen::usize_in(rng, 8, 256);
            let nparts_small = gen::usize_in(rng, 2, 8);
            let nparts_large = nparts_small * gen::usize_in(rng, 10, 50);
            let step = 1 + rng.below(1000) as i64;
            (per, nparts_small, nparts_large, step)
        },
        |&(per, nparts_small, nparts_large, step)| {
            let make = |nparts: usize| {
                let mut b = BatchBuilder::new(Schema::stock());
                for i in 0..per * nparts {
                    b.push(i as i64 * step, &[0.0, 0.0]);
                }
                let parts = partition_batch_uniform(&b.finish().unwrap(), per).unwrap();
                Cias::build(&parts).unwrap()
            };
            let small = make(nparts_small);
            let large = make(nparts_large);
            small.memory_bytes() == large.memory_bytes()
                && large.asl_len() == 0
                && large.regular_parts() == nparts_large
        },
    );
}

#[test]
fn prop_extract_meta_consistent_with_partitions() {
    Runner::default().run(
        "extract_meta mirrors partition bounds",
        |rng| random_layout(rng),
        |layout| {
            let metas = extract_meta(&layout.parts);
            metas.len() == layout.parts.len()
                && metas.iter().zip(&layout.parts).all(|(m, p)| {
                    m.id == p.id
                        && m.rows == p.rows
                        && Some(m.key_min) == p.key_min()
                        && Some(m.key_max) == p.key_max()
                })
        },
    );
}
