//! `Dataset` — the RDD analogue: an immutable, partitioned, memory-resident
//! collection with lineage.
//!
//! Transformations are *eager* and, matching the paper's observation about
//! Spark's defaults ("after each phase, more RDDs are created and they are
//! resident in memory by default", §IV-A), every transformation result is
//! registered with the block manager until explicitly unpersisted. This is
//! precisely the cost model the Fig 4 baseline measures.

use std::sync::Arc;

use crate::engine::block_manager::DatasetId;
use crate::index::types::PartitionSlice;
use crate::storage::{Partition, Schema};

/// How a dataset came to exist — the lineage record (paper Fig 2's
/// dataflow; inspectable via `OsebaContext::lineage`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lineage {
    /// Loaded from a generator / external source.
    Source { name: String },
    /// Produced by a transformation of `parent`.
    Derived { parent: DatasetId, op: String },
}

/// An immutable partitioned dataset handle.
///
/// Cloning is cheap (`Arc`'d partitions). Dropping the handle does *not*
/// free the cached blocks — like Spark, residency is controlled by
/// `unpersist`, not scope.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub(crate) id: DatasetId,
    pub(crate) schema: Schema,
    pub(crate) parts: Vec<Arc<Partition>>,
    pub(crate) lineage: Lineage,
}

impl Dataset {
    /// Unique id within its context.
    pub fn id(&self) -> DatasetId {
        self.id
    }

    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    pub fn partitions(&self) -> &[Arc<Partition>] {
        &self.parts
    }

    pub fn num_partitions(&self) -> usize {
        self.parts.len()
    }

    /// Total valid rows across partitions.
    pub fn total_rows(&self) -> usize {
        self.parts.iter().map(|p| p.rows).sum()
    }

    /// Cached byte footprint (keys + padded columns).
    pub fn bytes(&self) -> usize {
        self.parts.iter().map(|p| p.bytes()).sum()
    }

    pub fn lineage(&self) -> &Lineage {
        &self.lineage
    }

    /// Smallest key in the dataset.
    pub fn key_min(&self) -> Option<i64> {
        self.parts.iter().filter_map(|p| p.key_min()).min()
    }

    /// Largest key in the dataset.
    pub fn key_max(&self) -> Option<i64> {
        self.parts.iter().filter_map(|p| p.key_max()).max()
    }

    /// Resolve a [`PartitionSlice`] into the backing partition plus the
    /// slice bounds — the zero-copy access path Oseba uses instead of
    /// materializing a filtered dataset.
    pub fn slice_view(&self, s: &PartitionSlice) -> SliceView<'_> {
        let part = &self.parts[s.partition];
        debug_assert!(s.row_end <= part.rows);
        SliceView { part, row_start: s.row_start, row_end: s.row_end }
    }
}

/// A borrowed view of a row range of one partition.
#[derive(Clone, Copy, Debug)]
pub struct SliceView<'a> {
    pub part: &'a Arc<Partition>,
    pub row_start: usize,
    pub row_end: usize,
}

impl<'a> SliceView<'a> {
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }

    /// The valid keys of this view.
    pub fn keys(&self) -> &'a [i64] {
        &self.part.keys[self.row_start..self.row_end]
    }

    /// A value-column slice of this view.
    pub fn column(&self, col: usize) -> &'a [f32] {
        &self.part.columns[col][self.row_start..self.row_end]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{partition_batch_uniform, BatchBuilder};

    fn ds() -> Dataset {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..100 {
            b.push(i as i64 * 2, &[i as f32, 1.0]);
        }
        let parts = partition_batch_uniform(&b.finish().unwrap(), 30).unwrap();
        Dataset {
            id: 1,
            schema: Schema::stock(),
            parts,
            lineage: Lineage::Source { name: "test".into() },
        }
    }

    #[test]
    fn totals() {
        let d = ds();
        assert_eq!(d.num_partitions(), 4);
        assert_eq!(d.total_rows(), 100);
        assert_eq!(d.key_min(), Some(0));
        assert_eq!(d.key_max(), Some(198));
    }

    #[test]
    fn slice_view_reads_expected_rows() {
        let d = ds();
        let s = PartitionSlice { partition: 1, row_start: 5, row_end: 10 };
        let v = d.slice_view(&s);
        assert_eq!(v.rows(), 5);
        // Partition 1 holds rows 30..60 → global rows 35..40.
        assert_eq!(v.keys(), &[70, 72, 74, 76, 78]);
        assert_eq!(v.column(0), &[35.0, 36.0, 37.0, 38.0, 39.0]);
    }

    #[test]
    fn lineage_is_recorded() {
        let d = ds();
        assert_eq!(d.lineage(), &Lineage::Source { name: "test".into() });
    }
}
