//! The unified metrics registry: per-server-op and per-plan-phase
//! latency histograms plus the slow-query log, behind one snapshot.
//!
//! The registry lives on the engine context, so every execution path —
//! server ops, direct coordinator calls, batch sessions — records into
//! the same histograms. Recording can be disabled at runtime
//! ([`MetricsRegistry::set_enabled`]); the disabled path is one relaxed
//! atomic load, which is also how the overhead bench measures the
//! uninstrumented arm.
//!
//! Histogram names are registered in [`OP_METRICS`] / [`PHASE_METRICS`],
//! index-aligned with [`ServerOp`] / [`PlanPhase`]. `oseba-lint`'s
//! `counters-surfaced` rule cross-checks these constants against the
//! server's `metrics` response builder, so a histogram cannot be
//! registered here and silently dropped from exposition.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::metrics::hist::{HistSnapshot, LatencyHistogram};
use crate::metrics::trace::SlowQueryLog;

/// Registered per-server-op histogram names, index-aligned with
/// [`ServerOp`]. Every name must appear in the server's `metrics` op
/// output (enforced by `oseba-lint`).
pub const OP_METRICS: [&str; 6] =
    ["op_info", "op_stats", "op_explain", "op_append", "op_snapshot", "op_metrics"];

/// Registered per-plan-phase histogram names, index-aligned with
/// [`PlanPhase`]. Every name must appear in the server's `metrics` op
/// output (enforced by `oseba-lint`).
pub const PHASE_METRICS: [&str; 9] = [
    "phase_targeting",
    "phase_zone_pruning",
    "phase_filter_pruning",
    "phase_sketch_classify",
    "phase_block_classify",
    "phase_fault_in",
    "phase_scan_merge",
    "phase_demux",
    "phase_fault_recovery",
];

/// Instrumented server ops (everything except `shutdown`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ServerOp {
    /// `info` — dataset/server summary.
    Info,
    /// `stats` — range statistics query.
    Stats,
    /// `explain` — plan a query without executing it.
    Explain,
    /// `append` — live ingest of a chunk.
    Append,
    /// `snapshot` — pin the current live epoch.
    Snapshot,
    /// `metrics` — observability snapshot (this subsystem).
    Metrics,
}

impl ServerOp {
    /// All ops, index-aligned with [`OP_METRICS`].
    pub const ALL: [ServerOp; 6] = [
        ServerOp::Info,
        ServerOp::Stats,
        ServerOp::Explain,
        ServerOp::Append,
        ServerOp::Snapshot,
        ServerOp::Metrics,
    ];

    /// Registered histogram name for this op.
    pub fn name(self) -> &'static str {
        OP_METRICS[self as usize]
    }

    /// Map a protocol `"op"` string to its instrumented op, if any.
    pub fn from_op_str(op: &str) -> Option<ServerOp> {
        match op {
            "info" => Some(ServerOp::Info),
            "stats" => Some(ServerOp::Stats),
            "explain" => Some(ServerOp::Explain),
            "append" => Some(ServerOp::Append),
            "snapshot" => Some(ServerOp::Snapshot),
            "metrics" => Some(ServerOp::Metrics),
            _ => None,
        }
    }
}

/// Instrumented plan/execution phases of a single query or batch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlanPhase {
    /// Key-index lookup proposing candidate slices.
    Targeting,
    /// Zone-map predicate checks over proposed slices.
    ZonePruning,
    /// Membership-filter probes for equality predicates over
    /// zone-surviving slices.
    FilterPruning,
    /// Sketch coverage classification of surviving slices.
    SketchClassify,
    /// Block-level classification of scan-path slices against the
    /// sub-partition sketch hierarchy (covered/pruned/scanned).
    BlockClassify,
    /// Resolving slices against the tiered store (cold faults included).
    FaultIn,
    /// Scanning resident data and merging partial moments.
    ScanMerge,
    /// Distributing merged segment results back to batch queries.
    Demux,
    /// Time the tiered store spent inside fault handling while resolving
    /// this query's slices: retry backoff sleeps, re-reads after an I/O
    /// error, and quarantine bookkeeping. Zero on a healthy store.
    FaultRecovery,
}

impl PlanPhase {
    /// All phases, index-aligned with [`PHASE_METRICS`].
    pub const ALL: [PlanPhase; 9] = [
        PlanPhase::Targeting,
        PlanPhase::ZonePruning,
        PlanPhase::FilterPruning,
        PlanPhase::SketchClassify,
        PlanPhase::BlockClassify,
        PlanPhase::FaultIn,
        PlanPhase::ScanMerge,
        PlanPhase::Demux,
        PlanPhase::FaultRecovery,
    ];

    /// Registered histogram name for this phase.
    pub fn name(self) -> &'static str {
        PHASE_METRICS[self as usize]
    }

    /// Span-tree node name: the histogram name minus the `phase_` prefix.
    pub fn span_name(self) -> &'static str {
        match self {
            PlanPhase::Targeting => "targeting",
            PlanPhase::ZonePruning => "zone_pruning",
            PlanPhase::FilterPruning => "filter_pruning",
            PlanPhase::SketchClassify => "sketch_classify",
            PlanPhase::BlockClassify => "block_classify",
            PlanPhase::FaultIn => "fault_in",
            PlanPhase::ScanMerge => "scan_merge",
            PlanPhase::Demux => "demux",
            PlanPhase::FaultRecovery => "fault_recovery",
        }
    }
}

/// One registry of every latency histogram plus the slow-query log.
#[derive(Debug)]
pub struct MetricsRegistry {
    enabled: AtomicBool,
    ops: [LatencyHistogram; OP_METRICS.len()],
    phases: [LatencyHistogram; PHASE_METRICS.len()],
    slow: SlowQueryLog,
}

impl Default for MetricsRegistry {
    fn default() -> MetricsRegistry {
        MetricsRegistry::new()
    }
}

impl MetricsRegistry {
    /// A fresh registry, enabled.
    pub fn new() -> MetricsRegistry {
        MetricsRegistry {
            enabled: AtomicBool::new(true),
            ops: std::array::from_fn(|_| LatencyHistogram::new()),
            phases: std::array::from_fn(|_| LatencyHistogram::new()),
            slow: SlowQueryLog::default(),
        }
    }

    /// Turn recording on or off (off: `record_*` are one atomic load).
    pub fn set_enabled(&self, on: bool) {
        self.enabled.store(on, Ordering::Relaxed);
    }

    /// Whether recording is currently enabled.
    pub fn enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    /// Record one server-op latency.
    pub fn record_op(&self, op: ServerOp, d: Duration) {
        if self.enabled() {
            self.ops[op as usize].record_duration(d);
        }
    }

    /// Record one plan-phase latency.
    pub fn record_phase(&self, phase: PlanPhase, d: Duration) {
        if self.enabled() {
            self.phases[phase as usize].record_duration(d);
        }
    }

    /// Snapshot of one server-op histogram.
    pub fn op(&self, op: ServerOp) -> HistSnapshot {
        self.ops[op as usize].snapshot()
    }

    /// Snapshot of one plan-phase histogram.
    pub fn phase(&self, phase: PlanPhase) -> HistSnapshot {
        self.phases[phase as usize].snapshot()
    }

    /// The slow-query log fed by traced server queries.
    pub fn slow_log(&self) -> &SlowQueryLog {
        &self.slow
    }

    /// Prometheus-style text exposition: one `oseba_<name>` gauge line
    /// per supplied counter, then summary-style quantile/count/sum lines
    /// for every registered op and phase histogram.
    pub fn prometheus_text(&self, gauges: &[(String, f64)]) -> String {
        let mut out = String::new();
        out.push_str("# oseba metrics (text exposition)\n");
        for (name, value) in gauges {
            out.push_str(&format!("oseba_{name} {value}\n"));
        }
        let mut summary = |name: &str, snap: HistSnapshot| {
            for (q, nanos) in [("0.5", snap.p50()), ("0.95", snap.p95()), ("0.99", snap.p99())] {
                out.push_str(&format!(
                    "oseba_{name}_latency_seconds{{quantile=\"{q}\"}} {}\n",
                    nanos as f64 / 1e9
                ));
            }
            out.push_str(&format!("oseba_{name}_latency_seconds_count {}\n", snap.count()));
            out.push_str(&format!(
                "oseba_{name}_latency_seconds_sum {}\n",
                snap.sum_nanos as f64 / 1e9
            ));
        };
        for op in ServerOp::ALL {
            summary(op.name(), self.op(op));
        }
        for phase in PlanPhase::ALL {
            summary(phase.name(), self.phase(phase));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_are_index_aligned() {
        for (i, op) in ServerOp::ALL.iter().enumerate() {
            assert_eq!(op.name(), OP_METRICS[i]);
        }
        for (i, phase) in PlanPhase::ALL.iter().enumerate() {
            assert_eq!(phase.name(), PHASE_METRICS[i]);
            assert_eq!(format!("phase_{}", phase.span_name()), PHASE_METRICS[i]);
        }
        assert_eq!(ServerOp::from_op_str("stats"), Some(ServerOp::Stats));
        assert_eq!(ServerOp::from_op_str("shutdown"), None);
    }

    #[test]
    fn disabled_registry_records_nothing() {
        let m = MetricsRegistry::new();
        assert!(m.enabled());
        m.record_op(ServerOp::Stats, Duration::from_micros(5));
        m.set_enabled(false);
        m.record_op(ServerOp::Stats, Duration::from_micros(5));
        m.record_phase(PlanPhase::Targeting, Duration::from_micros(5));
        m.set_enabled(true);
        assert_eq!(m.op(ServerOp::Stats).count(), 1);
        assert_eq!(m.phase(PlanPhase::Targeting).count(), 0);
    }

    #[test]
    fn prometheus_text_exposes_every_registered_name() {
        let m = MetricsRegistry::new();
        m.record_op(ServerOp::Info, Duration::from_micros(3));
        let text = m.prometheus_text(&[("engine_partitions_scanned".to_string(), 4.0)]);
        assert!(text.contains("oseba_engine_partitions_scanned 4\n"));
        for name in OP_METRICS.iter().chain(PHASE_METRICS.iter()) {
            assert!(
                text.contains(&format!("oseba_{name}_latency_seconds_count")),
                "{name} missing"
            );
        }
        assert!(text.contains("oseba_op_info_latency_seconds{quantile=\"0.5\"}"));
        // Every non-comment line is `name value`.
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            assert_eq!(line.split_whitespace().count(), 2, "bad line: {line}");
        }
    }
}
