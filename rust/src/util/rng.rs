//! Deterministic PRNG (no `rand` crate in the vendored set).
//!
//! [`SplitMix64`] seeds [`Xoshiro256`] (xoshiro256**), the generator used by
//! the data generators, the property-test harness and the bench workloads.
//! Determinism matters: every experiment in EXPERIMENTS.md records its seed,
//! and property-test failures print a reproducing seed.

/// SplitMix64 — used to expand a single `u64` seed into generator state.
#[derive(Clone, Debug)]
pub struct SplitMix64(pub u64);

impl SplitMix64 {
    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via SplitMix64 (never produces the all-zero state).
    pub fn seeded(seed: u64) -> Self {
        let mut sm = SplitMix64(seed);
        Self { s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()] }
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)`.
    pub fn next_f64(&mut self) -> f64 {
        // 53 top bits → exactly representable dyadic rational in [0,1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` via Lemire's multiply-shift (unbiased
    /// enough for simulation workloads; not for cryptography).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(f64::MIN_POSITIVE);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation.
    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Exponential with rate `lambda` (inter-arrival times for CDR gen).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -(1.0 - self.next_f64()).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Xoshiro256::seeded(42);
        let mut b = Xoshiro256::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::seeded(1);
        let mut b = Xoshiro256::seeded(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::seeded(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Xoshiro256::seeded(9);
        let mut seen = [false; 10];
        for _ in 0..10_000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear");
    }

    #[test]
    fn normal_moments_roughly_standard() {
        let mut r = Xoshiro256::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::seeded(13);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Xoshiro256::seeded(17);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }
}
