//! Interactive query server: a line-delimited JSON protocol over TCP
//! (std::net + the crate's thread pool), fronting a loaded dataset with
//! both access paths. This is the "interactive analysis" deployment shape
//! the paper motivates (§I: selective bulk analysis "usually involves
//! interactive analysis").
//!
//! Protocol (one JSON object per line):
//!
//! ```text
//! → {"op":"stats","lo":3600,"hi":7200,"column":"temperature","method":"oseba"}
//! ← {"ok":true,"count":2,"max":21.4,"min":20.9,"mean":21.1,"std":0.2,"secs":0.0001}
//! → {"op":"info"}
//! ← {"ok":true,"rows":100000,"partitions":15,"memory_bytes":...}
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::{Coordinator, IndexKind, Method};
use crate::engine::Dataset;
use crate::error::{OsebaError, Result};
use crate::index::{ContentIndex, RangeQuery};
use crate::metrics::Timer;
use crate::util::json::Json;

/// Server state shared across connections.
pub struct QueryServer {
    coord: Arc<Coordinator>,
    ds: Arc<Dataset>,
    index: Arc<dyn ContentIndex>,
    shutdown: Arc<AtomicBool>,
}

impl QueryServer {
    /// Build over an already-loaded dataset (resident or tiered; a tiered
    /// dataset's index is built from store metadata without faulting
    /// anything in).
    pub fn new(coord: Arc<Coordinator>, ds: Dataset, index_kind: IndexKind) -> Result<QueryServer> {
        let index: Arc<dyn ContentIndex> = match (ds.store(), index_kind) {
            (Some(store), IndexKind::Cias) => {
                Arc::new(crate::index::Cias::from_meta(store.metas())?)
            }
            (Some(store), IndexKind::Table) => {
                Arc::new(crate::index::TableIndex::from_meta(store.metas())?)
            }
            (None, IndexKind::Cias) => Arc::new(crate::index::Cias::build(ds.partitions())?),
            (None, IndexKind::Table) => {
                Arc::new(crate::index::TableIndex::build(ds.partitions())?)
            }
        };
        Ok(QueryServer {
            coord,
            ds: Arc::new(ds),
            index,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    /// Bind and serve until a `{"op":"shutdown"}` request arrives. Returns
    /// the bound address via `on_bound` (for tests binding port 0).
    pub fn serve(&self, addr: &str, on_bound: impl FnOnce(std::net::SocketAddr)) -> Result<()> {
        let listener = TcpListener::bind(addr)?;
        on_bound(listener.local_addr()?);
        listener.set_nonblocking(true)?;
        while !self.shutdown.load(Ordering::SeqCst) {
            match listener.accept() {
                Ok((stream, _)) => {
                    // One thread per connection, connections are few and
                    // long-lived (interactive sessions).
                    let coord = Arc::clone(&self.coord);
                    let ds = Arc::clone(&self.ds);
                    let index = Arc::clone(&self.index);
                    let shutdown = Arc::clone(&self.shutdown);
                    std::thread::spawn(move || {
                        let _ = handle_conn(stream, &coord, &ds, index.as_ref(), &shutdown);
                    });
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(10));
                }
                Err(e) => return Err(e.into()),
            }
        }
        Ok(())
    }

    /// Request shutdown (used by tests and signal handling).
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }
}

fn handle_conn(
    stream: TcpStream,
    coord: &Coordinator,
    ds: &Dataset,
    index: &dyn ContentIndex,
    shutdown: &AtomicBool,
) -> Result<()> {
    let mut writer = stream.try_clone()?;
    let reader = BufReader::new(stream);
    for line in reader.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let response = match handle_request(&line, coord, ds, index, shutdown) {
            Ok(j) => j,
            Err(e) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(e.to_string())),
            ]),
        };
        writer.write_all(response.to_string().as_bytes())?;
        writer.write_all(b"\n")?;
        if shutdown.load(Ordering::SeqCst) {
            break;
        }
    }
    Ok(())
}

/// Process one request line (exposed for unit tests — no socket needed).
pub fn handle_request(
    line: &str,
    coord: &Coordinator,
    ds: &Dataset,
    index: &dyn ContentIndex,
    shutdown: &AtomicBool,
) -> Result<Json> {
    let req = Json::parse(line)?;
    let op = req
        .require("op")?
        .as_str()
        .ok_or_else(|| OsebaError::Json("op must be a string".into()))?;
    match op {
        "info" => {
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("rows", Json::num(ds.total_rows() as f64)),
                ("partitions", Json::num(ds.num_partitions() as f64)),
                ("memory_bytes", Json::num(coord.context().memory_used() as f64)),
                ("index", Json::str(index.name())),
                ("index_bytes", Json::num(index.memory_bytes() as f64)),
                ("key_min", Json::num(ds.key_min().unwrap_or(0) as f64)),
                ("key_max", Json::num(ds.key_max().unwrap_or(0) as f64)),
                ("tiered", Json::Bool(ds.is_tiered())),
            ];
            if let Some(store) = ds.store() {
                let c = store.counters();
                fields.push(("resident_bytes", Json::num(store.resident_bytes() as f64)));
                fields.push(("total_bytes", Json::num(store.total_bytes() as f64)));
                fields.push(("faults", Json::num(c.faults as f64)));
                fields.push(("evictions", Json::num(c.evictions as f64)));
                fields.push((
                    "segment_bytes_read",
                    Json::num(c.segment_bytes_read as f64),
                ));
            }
            Ok(Json::obj(fields))
        }
        "stats" => {
            let lo = req.require("lo")?.as_i64().ok_or_else(bad_num)?;
            let hi = req.require("hi")?.as_i64().ok_or_else(bad_num)?;
            let col_name = req
                .require("column")?
                .as_str()
                .ok_or_else(|| OsebaError::Json("column must be a string".into()))?;
            let column = ds.schema().column_index(col_name)?;
            let method: Method = req
                .get("method")
                .and_then(|m| m.as_str())
                .unwrap_or("oseba")
                .parse()?;
            let q = RangeQuery::new(lo, hi)?;
            let timer = Timer::start();
            let stats = match method {
                Method::Oseba => coord.analyze_period_oseba(ds, index, q, column)?,
                Method::Default => {
                    let (st, filtered) = coord.analyze_period_default(ds, q, column)?;
                    // The server keeps memory bounded: server-side filtered
                    // datasets are transient.
                    coord.context().unpersist(&filtered);
                    st
                }
            };
            Ok(Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("count", Json::num(stats.count as f64)),
                ("max", Json::num(stats.max as f64)),
                ("min", Json::num(stats.min as f64)),
                ("mean", Json::num(stats.mean)),
                ("std", Json::num(stats.std)),
                ("method", Json::str(method.label())),
                ("secs", Json::num(timer.secs())),
            ]))
        }
        "shutdown" => {
            shutdown.store(true, Ordering::SeqCst);
            Ok(Json::obj(vec![("ok", Json::Bool(true)), ("bye", Json::Bool(true))]))
        }
        other => Err(OsebaError::Json(format!("unknown op '{other}'"))),
    }
}

fn bad_num() -> OsebaError {
    OsebaError::Json("lo/hi must be integers".into())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::AppConfig;
    use crate::coordinator::Coordinator;
    use crate::datagen::ClimateGen;
    use crate::index::Cias;
    use crate::runtime::NativeBackend;

    fn setup() -> (Coordinator, Dataset, Cias) {
        let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
        let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let ds = coord.load(ClimateGen::default().generate(10_000), 5).unwrap();
        let index = Cias::build(ds.partitions()).unwrap();
        (coord, ds, index)
    }

    #[test]
    fn info_request() {
        let (coord, ds, index) = setup();
        let flag = AtomicBool::new(false);
        let r = handle_request(r#"{"op":"info"}"#, &coord, &ds, &index, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("rows").unwrap().as_usize(), Some(10_000));
        assert_eq!(r.get("index").unwrap().as_str(), Some("cias"));
    }

    #[test]
    fn stats_request_both_methods_agree() {
        let (coord, ds, index) = setup();
        let flag = AtomicBool::new(false);
        let mk = |method: &str| {
            format!(
                r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature","method":"{method}"}}"#,
                3600 * 999
            )
        };
        let a = handle_request(&mk("oseba"), &coord, &ds, &index, &flag).unwrap();
        let b = handle_request(&mk("default"), &coord, &ds, &index, &flag).unwrap();
        assert_eq!(a.get("count"), b.get("count"));
        assert_eq!(a.get("max"), b.get("max"));
        // Default path must not leak server memory.
        let before = coord.context().memory_used();
        handle_request(&mk("default"), &coord, &ds, &index, &flag).unwrap();
        assert_eq!(coord.context().memory_used(), before);
    }

    #[test]
    fn tiered_dataset_serves_and_reports_faults() {
        let dir = crate::testing::temp_dir("srv-tiered");
        let cfg = AppConfig { cluster_workers: 2, ..Default::default() };
        let coord = Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap();
        let ds = coord
            .load_tiered(ClimateGen::default().generate(10_000), 5, &dir)
            .unwrap();
        let index = crate::index::Cias::from_meta(ds.store().unwrap().metas()).unwrap();
        let flag = AtomicBool::new(false);

        let r = handle_request(r#"{"op":"info"}"#, &coord, &ds, &index, &flag).unwrap();
        assert_eq!(r.get("tiered"), Some(&Json::Bool(true)));
        assert_eq!(r.get("faults").unwrap().as_usize(), Some(0));

        let req = format!(
            r#"{{"op":"stats","lo":0,"hi":{},"column":"temperature"}}"#,
            3600 * 999
        );
        let r = handle_request(&req, &coord, &ds, &index, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(r.get("count").unwrap().as_usize(), Some(1000));
        coord.context().unpersist(&ds);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn malformed_requests_are_errors() {
        let (coord, ds, index) = setup();
        let flag = AtomicBool::new(false);
        assert!(handle_request("{", &coord, &ds, &index, &flag).is_err());
        assert!(handle_request(r#"{"op":"nope"}"#, &coord, &ds, &index, &flag).is_err());
        assert!(handle_request(
            r#"{"op":"stats","lo":5,"hi":1,"column":"temperature"}"#,
            &coord,
            &ds,
            &index,
            &flag
        )
        .is_err());
        assert!(handle_request(
            r#"{"op":"stats","lo":0,"hi":10,"column":"bogus"}"#,
            &coord,
            &ds,
            &index,
            &flag
        )
        .is_err());
    }

    #[test]
    fn shutdown_sets_flag() {
        let (coord, ds, index) = setup();
        let flag = AtomicBool::new(false);
        let r = handle_request(r#"{"op":"shutdown"}"#, &coord, &ds, &index, &flag).unwrap();
        assert_eq!(r.get("ok"), Some(&Json::Bool(true)));
        assert!(flag.load(Ordering::SeqCst));
    }

    #[test]
    fn end_to_end_over_tcp() {
        let (coord, ds, _index) = setup();
        let server = QueryServer::new(Arc::new(coord), ds, IndexKind::Cias).unwrap();
        let (addr_tx, addr_rx) = std::sync::mpsc::channel();
        let shutdown = server.shutdown_handle();
        let handle = std::thread::spawn(move || {
            server.serve("127.0.0.1:0", |a| addr_tx.send(a).unwrap()).unwrap();
        });
        let addr = addr_rx.recv().unwrap();

        let mut stream = TcpStream::connect(addr).unwrap();
        stream
            .write_all(b"{\"op\":\"stats\",\"lo\":0,\"hi\":360000,\"column\":\"humidity\"}\n")
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let resp = Json::parse(line.trim()).unwrap();
        assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(resp.get("count").unwrap().as_usize(), Some(101));

        stream.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
        let mut line2 = String::new();
        reader.read_line(&mut line2).unwrap();
        assert!(line2.contains("bye"));
        assert!(shutdown.load(Ordering::SeqCst));
        handle.join().unwrap();
    }
}
