//! LiveCounters side of the fixture: `epoch` is healthy (updated, read,
//! and surfaced) so only `ghost_counter` in context.rs fires.

pub struct LiveCounters {
    pub epoch: u64,
}

pub fn read(c: &LiveCounters) -> u64 {
    let e = c.epoch;
    e + c.epoch
}
