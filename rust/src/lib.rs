//! # Oseba
//!
//! A reproduction of *"Oseba: Optimization for Selective Bulk Analysis in
//! Big Data Processing"* (Wang & Wang, CS.DC 2017) as a three-layer
//! rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — a Spark-like in-memory partitioned data
//!   engine ([`engine`]), the paper's content-aware indexes ([`index`]:
//!   table-based and CIAS, extended with per-partition value-domain zone
//!   maps), a leader/worker coordinator ([`coordinator`]) with a unified
//!   query-plan layer ([`coordinator::plan`]: logical query → key
//!   targeting → zone-map predicate pruning → masked execution) and a
//!   concurrent multi-query batch planner, tiered persistent
//!   storage ([`store`]: spill-to-disk `.oseg` segments with Hot/Cold
//!   residency and super-index manifest snapshots), **live ingestion**
//!   ([`engine::LiveDataset`] / [`ingest::LiveIngestor`]: append while
//!   serving, with epoch-pinned snapshots and incremental super-index
//!   maintenance), all over a simulated cluster ([`cluster`]), and the
//!   PJRT runtime ([`runtime`]) that executes AOT-compiled analysis
//!   kernels (behind the `xla` feature; the default build uses the
//!   pure-rust native backend).
//! * **Layer 2 (python/compile/model.py)** — JAX analysis graphs, lowered
//!   once to `artifacts/*.hlo.txt`.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the masked
//!   per-block statistics the analyses hot-loop on.
//!
//! See the repository-root `DESIGN.md` for the system inventory,
//! `README.md` for the build/test/bench quickstart, and `docs/PROTOCOL.md`
//! for the server wire protocol; the `rust/benches/` targets reproduce the
//! paper's Fig 4 / Fig 6 measurements.

#![warn(missing_docs)]

pub mod analysis;
pub mod bench;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod datagen;
pub mod engine;
pub mod error;
pub mod index;
pub mod ingest;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod storage;
pub mod store;
pub mod testing;
pub mod util;

pub use error::{OsebaError, Result};

/// Convenience re-exports for the common workflow.
pub mod prelude {
    pub use crate::analysis::{Analyzer, PeriodStats};
    pub use crate::config::ContextConfig;
    pub use crate::coordinator::{
        parse_predicates, plan_batch, plan_query, Coordinator, Explain, IndexKind,
        Method, PhysicalPlan, PlannedQuery, Query, QueryOp, QueryOutput,
    };
    pub use crate::engine::{
        Dataset, EpochSnapshot, LiveConfig, LiveCounters, LiveDataset, OsebaContext,
    };
    pub use crate::error::{OsebaError, Result};
    pub use crate::index::{
        Cias, ColumnPredicate, ContentIndex, PredOp, RangeQuery, TableIndex, ZoneMap,
    };
    pub use crate::ingest::{chunk_batch, Chunk, LiveIngestor};
    pub use crate::runtime::AnalysisBackend;
    pub use crate::storage::Schema;
    pub use crate::store::{Residency, StoreCounters, TieredStore};
}
