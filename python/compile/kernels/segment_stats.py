"""Fused masked-moments kernel: max/min/sum/sumsq/count in one pass.

The Oseba analysis programs (paper §IV) compute max, mean and standard
deviation over a selected key range. Mean and stddev derive from the raw
moments (sum, sum of squares, count), which — unlike mean/std themselves —
merge associatively across partitions, so the rust coordinator can combine
per-partition partials in any order (DESIGN.md §3).

TPU shaping (DESIGN.md §6): one VMEM tile holds the whole 4096-row block
(16 KiB), the selection mask is a ``broadcasted_iota`` compare (VPU-friendly,
no gather/scatter), and all five reductions happen in a single pass so HBM
traffic is exactly one read per element.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK_ROWS = 4096

# Identity elements chosen so a fully-masked block merges as a no-op.
# Plain python floats: module-level jnp arrays would be captured as pallas
# kernel constants, which pallas_call rejects.
NEG_INF = -3.4e38
POS_INF = 3.4e38


def _segment_stats_kernel(x_ref, start_ref, end_ref, max_ref, min_ref,
                          sum_ref, sumsq_ref, count_ref):
    x = x_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 0)
    mask = (idx >= start_ref[0]) & (idx < end_ref[0])
    maskf = mask.astype(jnp.float32)
    xm = x * maskf
    max_ref[0] = jnp.max(jnp.where(mask, x, NEG_INF))
    min_ref[0] = jnp.min(jnp.where(mask, x, POS_INF))
    sum_ref[0] = jnp.sum(xm)
    sumsq_ref[0] = jnp.sum(xm * x)
    count_ref[0] = jnp.sum(maskf)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def segment_stats(x, start, end, *, block_rows=None):
    """Masked moments of ``x[start:end]``.

    Args:
      x: f32[n] — one padded column block (n is static under jit; the
        ``block_rows`` kwarg, if given, just asserts the expectation).
      start, end: i32 scalars, half-open row range (clamped by caller).

    Returns:
      ``(max, min, sum, sumsq, count)`` f32 scalars. For an empty range,
      max/min are the identity sentinels and sum/sumsq/count are 0 — the
      merge in rust treats count==0 partials as absorbing.
    """
    assert block_rows is None or x.shape[0] == block_rows
    start = jnp.asarray(start, jnp.int32).reshape((1,))
    end = jnp.asarray(end, jnp.int32).reshape((1,))
    out = pl.pallas_call(
        _segment_stats_kernel,
        out_shape=tuple(jax.ShapeDtypeStruct((1,), jnp.float32)
                        for _ in range(5)),
        interpret=True,
    )(x, start, end)
    return tuple(o[0] for o in out)


def segment_stats_ref(x, start, end):
    """Oracle wrapper (pure jnp, no pallas) — see kernels/ref.py."""
    return ref.segment_stats_ref(x, start, end)


# --- grid-batched variant (perf: amortize PJRT dispatch) --------------------

STATS_BATCH = 16
# All batch sizes lowered by aot.py; the rust service packs tasks greedily
# into the largest size with <50% padding waste (EXPERIMENTS.md §Perf it.3).
STATS_BATCHES = (16, 128)


def _segment_stats_batched_kernel(x_ref, start_ref, end_ref, max_ref, min_ref,
                                  sum_ref, sumsq_ref, count_ref):
    # One 2-D VMEM tile holds the whole (B, N) batch; every moment is a
    # row-wise (axis=1) reduction, so the lowered HLO is straight fused
    # elementwise + reduce — no per-block loop. (A grid=(B,) formulation
    # lowers interpret-mode pallas to an HLO while-loop whose per-step
    # dynamic-slice overhead dominated at this block size; see
    # EXPERIMENTS.md §Perf iteration 2.)
    x = x_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
    mask = (idx >= start_ref[...][:, None]) & (idx < end_ref[...][:, None])
    maskf = mask.astype(jnp.float32)
    xm = x * maskf
    max_ref[...] = jnp.max(jnp.where(mask, x, NEG_INF), axis=1)
    min_ref[...] = jnp.min(jnp.where(mask, x, POS_INF), axis=1)
    sum_ref[...] = jnp.sum(xm, axis=1)
    sumsq_ref[...] = jnp.sum(xm * x, axis=1)
    count_ref[...] = jnp.sum(maskf, axis=1)


@functools.partial(jax.jit, static_argnames=())
def segment_stats_grid(xs, starts, ends):
    """Masked moments of ``B`` blocks in one dispatch.

    Args:
      xs: f32[B, block_rows] — stacked blocks.
      starts, ends: i32[B] — per-block half-open row ranges. A padded task
        uses ``start == end`` and yields the identity partial.

    Returns:
      ``(max, min, sum, sumsq, count)``, each f32[B].

    The rust kernel service packs up to ``STATS_BATCH`` block tasks into
    one execution of this kernel, amortizing PJRT dispatch ~B×
    (EXPERIMENTS.md §Perf). VMEM: the (16, 4096) f32 tile is 256 KiB —
    comfortably within a TPU core's ~16 MiB VMEM, leaving the same
    double-buffering headroom as the single-block kernel (DESIGN.md §6).
    """
    b, n = xs.shape
    assert starts.shape == (b,) and ends.shape == (b,)
    from jax.experimental import pallas as pl  # local: keep module import light

    out = pl.pallas_call(
        _segment_stats_batched_kernel,
        out_shape=tuple(jax.ShapeDtypeStruct((b,), jnp.float32) for _ in range(5)),
        interpret=True,
    )(xs, starts, ends)
    return out
