//! Streaming ingestion: a bounded pipeline from a chunked source into
//! uniformly-sized partitions with **incremental CIAS maintenance**.
//!
//! The paper indexes a dataset loaded once; real temporal data arrives
//! continuously. Because CIAS absorbs a pattern-continuing partition in
//! O(1) ([`crate::index::Cias::append_meta`]), the index stays current at
//! ingestion speed — no rebuild, no table growth — and selective analyses
//! can run against a consistent snapshot at any time.
//!
//! Backpressure: the source feeds a bounded channel; when the builder
//! (or a memory budget) falls behind, the producer blocks — the standard
//! streaming-orchestrator contract.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, SyncSender};
use std::sync::{Arc, Mutex};

use crate::engine::MemoryTracker;
use crate::error::{OsebaError, Result};
use crate::index::builder::detect_step;
use crate::index::{Cias, PartitionMeta};
use crate::storage::{Partition, RecordBatch, Schema};
use crate::store::TieredStore;
use crate::util::sync::MutexExt;

pub mod live;

pub use live::{chunk_batch, LiveIngestor};

/// A chunk of rows flowing through the pipeline (columnar, sorted keys).
#[derive(Clone, Debug)]
pub struct Chunk {
    /// Ordering keys of the chunk's rows, non-decreasing.
    pub keys: Vec<i64>,
    /// One vector per schema column.
    pub columns: Vec<Vec<f32>>,
}

impl Chunk {
    /// Copy a whole batch into one chunk.
    pub fn from_batch(b: &RecordBatch) -> Chunk {
        Chunk { keys: b.keys.clone(), columns: b.columns.clone() }
    }

    /// Number of rows in the chunk.
    pub fn rows(&self) -> usize {
        self.keys.len()
    }

    /// Raw (unpadded) byte footprint of the buffered rows: 8 bytes of key
    /// plus 4 bytes per value column per row — what an unsealed chunk
    /// charges the block manager.
    pub fn raw_bytes(&self) -> usize {
        self.rows() * (8 + 4 * self.columns.len())
    }
}

/// Shared, queryable ingestion state: the partitions so far plus the
/// incrementally-maintained index.
#[derive(Default)]
struct State {
    parts: Vec<Arc<Partition>>,
    index: Option<Cias>,
    rows: usize,
    /// Partitions sealed so far (equals `parts.len()` unless spilling to a
    /// tiered store, where the store owns the partitions).
    sealed: usize,
}

/// The consumer half: builds partitions from chunks and maintains CIAS.
pub struct Ingestor {
    schema: Schema,
    rows_per_partition: usize,
    state: Mutex<State>,
    tracker: Arc<MemoryTracker>,
    /// When set, sealed partitions go to the tiered store (which spills
    /// under pressure) instead of being pinned in memory.
    spill: Option<Arc<TieredStore>>,
    ingested_rows: AtomicUsize,
    // Partial-partition buffer.
    pending: Mutex<Chunk>,
    /// Set by [`Self::finish`]; a finished ingestor rejects further pushes
    /// (they used to be buffered and silently dropped).
    finished: AtomicBool,
}

impl Ingestor {
    /// `rows_per_partition` fixes the uniform layout CIAS compresses.
    pub fn new(
        schema: Schema,
        rows_per_partition: usize,
        tracker: Arc<MemoryTracker>,
    ) -> Result<Ingestor> {
        if rows_per_partition == 0 {
            return Err(OsebaError::Schema("rows_per_partition must be > 0".into()));
        }
        let width = schema.width();
        Ok(Ingestor {
            schema,
            rows_per_partition,
            state: Mutex::new(State::default()),
            tracker,
            spill: None,
            ingested_rows: AtomicUsize::new(0),
            pending: Mutex::new(Chunk { keys: Vec::new(), columns: vec![Vec::new(); width] }),
            finished: AtomicBool::new(false),
        })
    }

    /// An ingestor that seals partitions into `store`: under memory
    /// pressure the store spills cold partitions to segments, so ingestion
    /// of datasets beyond the budget proceeds instead of erroring.
    pub fn spilling(
        schema: Schema,
        rows_per_partition: usize,
        store: Arc<TieredStore>,
    ) -> Result<Ingestor> {
        if *store.schema() != schema {
            return Err(OsebaError::Schema(format!(
                "store schema {:?} != ingest schema {:?}",
                store.schema(),
                schema
            )));
        }
        let tracker = Arc::clone(store.tracker());
        let mut ing = Ingestor::new(schema, rows_per_partition, tracker)?;
        ing.spill = Some(store);
        Ok(ing)
    }

    /// The tiered store sealed partitions go to, if spilling.
    pub fn spill_store(&self) -> Option<&Arc<TieredStore>> {
        self.spill.as_ref()
    }

    /// Feed one chunk. Completed partitions are sealed, charged to the
    /// memory tracker, and appended to the index. Keys must continue
    /// non-decreasing across chunks.
    pub fn push(&self, chunk: Chunk) -> Result<()> {
        if chunk.columns.len() != self.schema.width() {
            return Err(OsebaError::Schema(format!(
                "chunk has {} columns, schema {}",
                chunk.columns.len(),
                self.schema.width()
            )));
        }
        if chunk.keys.windows(2).any(|w| w[0] > w[1]) {
            return Err(OsebaError::Schema("chunk keys not sorted".into()));
        }
        let mut pending = self.pending.lock_recover();
        if self.finished.load(Ordering::SeqCst) {
            // Used to be accepted: the rows were buffered after the final
            // seal and silently never flushed. Misuse is now a clear error.
            return Err(OsebaError::Ingest(
                "push after finish: the ingestor has sealed its final partition".into(),
            ));
        }
        if let (Some(&last), Some(&first)) = (pending.keys.last(), chunk.keys.first()) {
            if first < last {
                return Err(OsebaError::Schema(format!(
                    "chunk regresses: {first} < {last}"
                )));
            }
        }
        self.ingested_rows.fetch_add(chunk.rows(), Ordering::Relaxed);
        pending.keys.extend_from_slice(&chunk.keys);
        for (p, c) in pending.columns.iter_mut().zip(&chunk.columns) {
            p.extend_from_slice(c);
        }
        while pending.keys.len() >= self.rows_per_partition {
            let keys: Vec<i64> = pending.keys.drain(..self.rows_per_partition).collect();
            let cols: Vec<Vec<f32>> = pending
                .columns
                .iter_mut()
                .map(|c| c.drain(..self.rows_per_partition).collect())
                .collect();
            self.seal(keys, cols)?;
        }
        Ok(())
    }

    /// Flush the partial tail as a final (shorter) partition. Idempotent;
    /// after the first call the ingestor is sealed and [`Self::push`]
    /// returns [`OsebaError::Ingest`].
    pub fn finish(&self) -> Result<()> {
        let mut pending = self.pending.lock_recover();
        self.finished.store(true, Ordering::SeqCst);
        if pending.keys.is_empty() {
            return Ok(());
        }
        let keys = std::mem::take(&mut pending.keys);
        let width = pending.columns.len();
        let cols = std::mem::replace(&mut pending.columns, vec![Vec::new(); width]);
        drop(pending);
        self.seal(keys, cols)
    }

    fn seal(&self, keys: Vec<i64>, cols: Vec<Vec<f32>>) -> Result<()> {
        let mut state = self.state.lock_recover();
        let id = state.sealed;
        let part = Arc::new(Partition::from_rows(id, keys, cols));
        // The store extracts metadata (including the O(rows) step scan)
        // as part of insert; reuse it rather than rescanning the keys.
        let meta = match &self.spill {
            Some(store) => store.insert(Arc::clone(&part))?,
            None => {
                self.tracker.allocate(part.bytes())?;
                PartitionMeta {
                    id,
                    key_min: part.key_min().unwrap_or(0),
                    key_max: part.key_max().unwrap_or(0),
                    rows: part.rows,
                    step: detect_step(&part.keys),
                }
            }
        };
        match &mut state.index {
            Some(ix) => ix.append_meta(meta)?,
            None => state.index = Some(Cias::from_meta(vec![meta])?),
        }
        state.rows += part.rows;
        state.sealed += 1;
        if self.spill.is_none() {
            state.parts.push(part);
        }
        Ok(())
    }

    /// A consistent snapshot: sealed partitions + a clone of the index.
    /// (The pending tail is not yet visible — standard watermark
    /// semantics.) When spilling, the partitions live in the store
    /// ([`Self::spill_store`]) and the vec is empty.
    pub fn snapshot(&self) -> (Vec<Arc<Partition>>, Option<Cias>) {
        let state = self.state.lock_recover();
        (state.parts.clone(), state.index.clone())
    }

    /// Sealed partition count / row count / total ingested rows.
    pub fn progress(&self) -> (usize, usize, usize) {
        let state = self.state.lock_recover();
        (state.sealed, state.rows, self.ingested_rows.load(Ordering::Relaxed))
    }
}

/// Run a bounded producer→ingestor pipeline: `source` pulls chunks on a
/// producer thread into a channel of depth `queue_depth`; the calling
/// thread drains into `ingestor`. Returns total rows ingested.
pub fn run_pipeline<I>(
    ingestor: &Ingestor,
    source: I,
    queue_depth: usize,
) -> Result<usize>
where
    I: Iterator<Item = Chunk> + Send + 'static,
{
    let (tx, rx): (SyncSender<Chunk>, Receiver<Chunk>) =
        std::sync::mpsc::sync_channel(queue_depth.max(1));
    let producer = std::thread::spawn(move || {
        for chunk in source {
            if tx.send(chunk).is_err() {
                break; // consumer gone
            }
        }
    });
    let mut rows = 0usize;
    for chunk in rx {
        rows += chunk.rows();
        ingestor.push(chunk)?;
    }
    producer.join().map_err(|_| OsebaError::Cluster("producer panicked".into()))?;
    ingestor.finish()?;
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::ClimateGen;
    use crate::index::{ContentIndex, RangeQuery};

    fn chunks_of(batch: &RecordBatch, chunk_rows: usize) -> Vec<Chunk> {
        let mut out = Vec::new();
        let mut lo = 0;
        while lo < batch.rows() {
            let hi = (lo + chunk_rows).min(batch.rows());
            out.push(Chunk {
                keys: batch.keys[lo..hi].to_vec(),
                columns: batch.columns.iter().map(|c| c[lo..hi].to_vec()).collect(),
            });
            lo = hi;
        }
        out
    }

    #[test]
    fn streamed_index_matches_batch_built() {
        let batch = ClimateGen::default().generate(10_000);
        let ing = Ingestor::new(Schema::climate(), 1024, MemoryTracker::unbounded()).unwrap();
        for c in chunks_of(&batch, 333) {
            ing.push(c).unwrap();
        }
        ing.finish().unwrap();
        let (parts, index) = ing.snapshot();
        let index = index.unwrap();
        assert_eq!(parts.len(), 10);
        assert_eq!(index.regular_parts(), 9);
        assert_eq!(index.asl_len(), 1); // 784-row tail

        // Compare against the batch-loaded reference.
        let ref_parts = crate::storage::partition_batch_uniform(&batch, 1024).unwrap();
        let ref_index = Cias::build(&ref_parts).unwrap();
        for q in [
            RangeQuery { lo: 0, hi: 3600 * 999 },
            RangeQuery { lo: 3600 * 2000, hi: 3600 * 8000 },
            RangeQuery { lo: 3600 * 9990, hi: i64::MAX },
        ] {
            assert_eq!(index.lookup(q), ref_index.lookup(q), "{q:?}");
        }
        // Data identical too.
        for (a, b) in parts.iter().zip(&ref_parts) {
            assert_eq!(a.keys, b.keys);
            assert_eq!(a.columns[0], b.columns[0]);
        }
    }

    #[test]
    fn snapshot_queryable_mid_stream() {
        let batch = ClimateGen::default().generate(5_000);
        let ing = Ingestor::new(Schema::climate(), 1000, MemoryTracker::unbounded()).unwrap();
        let chunks = chunks_of(&batch, 1500);
        ing.push(chunks[0].clone()).unwrap();
        let (parts, index) = ing.snapshot();
        assert_eq!(parts.len(), 1); // 1500 rows → one sealed partition
        let hits = index.unwrap().lookup(RangeQuery { lo: 0, hi: 3600 * 100 });
        assert_eq!(hits.len(), 1);
        ing.push(chunks[1].clone()).unwrap();
        let (parts, _) = ing.snapshot();
        assert_eq!(parts.len(), 3);
    }

    #[test]
    fn pipeline_with_backpressure_ingests_everything() {
        let batch = ClimateGen::default().generate(20_000);
        let ing = Ingestor::new(Schema::climate(), 4096, MemoryTracker::unbounded()).unwrap();
        let chunks = chunks_of(&batch, 700);
        let n = chunks.len();
        let rows = run_pipeline(&ing, chunks.into_iter(), 2).unwrap();
        assert_eq!(rows, 20_000);
        let (sealed, total, ingested) = ing.progress();
        assert_eq!(total, 20_000);
        assert_eq!(ingested, 20_000);
        assert_eq!(sealed, 5);
        assert!(n > 2, "queue depth forced backpressure");
    }

    #[test]
    fn memory_budget_applies_backpressure_failure() {
        let batch = ClimateGen::default().generate(10_000);
        // Budget fits ~2 partitions.
        let ing = Ingestor::new(
            Schema::climate(),
            1000,
            MemoryTracker::with_budget(2 * 1000 * 24 + 64 * 1024),
        )
        .unwrap();
        let mut failed = false;
        for c in chunks_of(&batch, 1000) {
            if ing.push(c).is_err() {
                failed = true;
                break;
            }
        }
        assert!(failed, "budget must stop ingestion");
    }

    #[test]
    fn spilling_ingest_survives_budget_and_matches_reference() {
        let dir = crate::testing::temp_dir("ingest-spill");
        let batch = ClimateGen::default().generate(10_000);
        // The budget that stops the plain ingestor (see
        // `memory_budget_applies_backpressure_failure`) ...
        let tracker = MemoryTracker::with_budget(2 * 1000 * 24 + 64 * 1024);
        let store = Arc::new(
            TieredStore::create(&dir, Schema::climate(), tracker).unwrap(),
        );
        let ing = Ingestor::spilling(Schema::climate(), 1000, Arc::clone(&store)).unwrap();
        // ... does not stop the spilling one.
        for c in chunks_of(&batch, 1000) {
            ing.push(c).unwrap();
        }
        ing.finish().unwrap();
        let (sealed, rows, _) = ing.progress();
        assert_eq!(sealed, 10);
        assert_eq!(rows, 10_000);
        assert_eq!(store.num_partitions(), 10);
        assert!(store.counters().evictions > 0, "budget forced spills");

        // The incrementally-built index matches the batch reference, and
        // faulted-in data is identical to the source.
        let (_, index) = ing.snapshot();
        let index = index.unwrap();
        let ref_parts = crate::storage::partition_batch_uniform(&batch, 1000).unwrap();
        let ref_index = Cias::build(&ref_parts).unwrap();
        let q = RangeQuery { lo: 3600 * 1500, hi: 3600 * 4200 };
        assert_eq!(index.lookup(q), ref_index.lookup(q));
        let p3 = store.fetch(3).unwrap();
        assert_eq!(p3.keys, ref_parts[3].keys);
        assert_eq!(p3.columns, ref_parts[3].columns);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn spilling_rejects_schema_mismatch() {
        let dir = crate::testing::temp_dir("ingest-schema");
        let store = Arc::new(
            TieredStore::create(&dir, Schema::stock(), MemoryTracker::unbounded()).unwrap(),
        );
        assert!(Ingestor::spilling(Schema::climate(), 100, store).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rejects_disordered_input() {
        let ing = Ingestor::new(Schema::stock(), 100, MemoryTracker::unbounded()).unwrap();
        let good = Chunk { keys: vec![1, 2, 3], columns: vec![vec![0.0; 3], vec![0.0; 3]] };
        ing.push(good).unwrap();
        let regress = Chunk { keys: vec![0], columns: vec![vec![0.0], vec![0.0]] };
        assert!(ing.push(regress).is_err());
        let unsorted = Chunk { keys: vec![9, 4], columns: vec![vec![0.0; 2], vec![0.0; 2]] };
        assert!(ing.push(unsorted).is_err());
        let ragged = Chunk { keys: vec![9], columns: vec![vec![0.0]] };
        assert!(ing.push(ragged).is_err());
    }

    #[test]
    fn finish_on_empty_is_noop() {
        let ing = Ingestor::new(Schema::stock(), 100, MemoryTracker::unbounded()).unwrap();
        ing.finish().unwrap();
        let (parts, index) = ing.snapshot();
        assert!(parts.is_empty());
        assert!(index.is_none());
    }

    #[test]
    fn push_after_finish_is_a_clear_error() {
        // Regression: pushes after finish used to be buffered and silently
        // dropped (never sealed); now they fail loudly.
        let ing = Ingestor::new(Schema::stock(), 100, MemoryTracker::unbounded()).unwrap();
        let chunk = Chunk { keys: vec![1, 2], columns: vec![vec![0.0; 2], vec![0.0; 2]] };
        ing.push(chunk.clone()).unwrap();
        ing.finish().unwrap();
        let err = ing.push(Chunk {
            keys: vec![3],
            columns: vec![vec![0.0], vec![0.0]],
        })
        .unwrap_err();
        assert!(
            matches!(err, OsebaError::Ingest(_)),
            "want Ingest error, got: {err}"
        );
        assert!(err.to_string().contains("finish"), "got: {err}");
        // The sealed state is unchanged and finish stays idempotent.
        ing.finish().unwrap();
        let (parts, _) = ing.snapshot();
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].rows, 2);
    }
}
