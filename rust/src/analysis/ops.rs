//! The analysis operators. Each decomposes its input slices into
//! AOT-shaped kernel blocks, dispatches to the configured
//! [`AnalysisBackend`], and merges the associative partials in rust
//! (DESIGN.md §3).

use std::sync::Arc;

use crate::engine::{Dataset, SliceView};
use crate::error::{OsebaError, Result};
use crate::index::ColumnPredicate;
use crate::runtime::backend::AnalysisBackend;
use crate::storage::BLOCK_ROWS;
use crate::util::stats::{fold_stats_f32_masked, DistancePartial, Moments};

/// Finalized period statistics — the paper's per-phase analysis output
/// ("computing the max, mean and standard deviation", §IV-A).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PeriodStats {
    /// Selected non-NaN rows.
    pub count: u64,
    /// Largest selected value.
    pub max: f32,
    /// Smallest selected value.
    pub min: f32,
    /// Arithmetic mean.
    pub mean: f64,
    /// Population standard deviation.
    pub std: f64,
    /// Selected rows excluded because their value was NaN (the crate-wide
    /// NaN policy: counted and surfaced, never folded into the moments).
    pub nans: u64,
}

impl PeriodStats {
    /// Finalize merged moments; `None` for an empty selection.
    pub fn from_moments(m: Moments) -> Option<PeriodStats> {
        if m.is_empty() {
            return None;
        }
        Some(PeriodStats {
            count: m.count as u64,
            max: m.max,
            min: m.min,
            mean: m.mean(),
            std: m.std(),
            nans: m.nans as u64,
        })
    }
}

/// Finalized distance-comparison output.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DistanceResult {
    /// Compared (non-NaN) pairs.
    pub count: u64,
    /// Manhattan (sum of absolute differences) distance.
    pub l1: f64,
    /// Euclidean distance.
    pub l2: f64,
    /// Chebyshev (max absolute difference) distance.
    pub linf: f32,
    /// Mean absolute difference.
    pub mad: f64,
    /// Pairs excluded because their difference was NaN.
    pub nans: u64,
}

/// The analysis engine: a backend plus the block-decomposition logic.
#[derive(Clone)]
pub struct Analyzer {
    backend: Arc<dyn AnalysisBackend>,
}

impl Analyzer {
    /// An analyzer dispatching to `backend`.
    pub fn new(backend: Arc<dyn AnalysisBackend>) -> Analyzer {
        Analyzer { backend }
    }

    /// The backend's implementation name ("native" / "hlo").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Execution-engine counters, if the backend keeps them.
    pub fn backend_stats(&self) -> Option<crate::runtime::service::ServiceStats> {
        self.backend.service_stats()
    }

    /// Views covering every valid row of a dataset (the baseline path runs
    /// analyses over the *filtered* dataset in full).
    pub fn full_views<'a>(ds: &'a Dataset) -> Vec<SliceView<'a>> {
        ds.partitions()
            .iter()
            .filter(|p| p.rows > 0)
            .map(|p| SliceView { part: p, row_start: 0, row_end: p.rows })
            .collect()
    }

    /// Period statistics over the selected views of `column`.
    pub fn period_stats(&self, views: &[SliceView<'_>], column: usize) -> Result<PeriodStats> {
        let mut merged = Moments::EMPTY;
        for v in views {
            merged = merged.merge(slice_moments(
                self.backend.as_ref(),
                v.part,
                v.row_start,
                v.row_end,
                column,
                true,
            )?);
        }
        PeriodStats::from_moments(merged)
            .ok_or_else(|| OsebaError::InvalidRange("empty selection".into()))
    }

    /// Trailing moving average over the *concatenated* selection. Returns
    /// one value per valid MA point (`n - window + 1` values for `n`
    /// selected rows).
    ///
    /// Selections spanning multiple blocks are stitched with `window - 1`
    /// overlap so windows crossing block boundaries are exact.
    pub fn moving_average(
        &self,
        views: &[SliceView<'_>],
        column: usize,
        window: usize,
    ) -> Result<Vec<f32>> {
        self.moving_average_of(&gather(views, column), window)
    }

    /// [`Self::moving_average`] over an already-gathered series — the
    /// shared body for the view path and the predicate-filtered plan path.
    pub fn moving_average_of(&self, series: &[f32], window: usize) -> Result<Vec<f32>> {
        if window == 0 {
            return Err(OsebaError::InvalidRange("window must be > 0".into()));
        }
        let n = series.len();
        if n < window {
            return Ok(Vec::new());
        }
        let chunk_rows = self.backend.block_rows().unwrap_or(BLOCK_ROWS);
        if window > chunk_rows {
            return Err(OsebaError::InvalidRange(format!(
                "window {window} exceeds block size {chunk_rows}"
            )));
        }
        let mut out = Vec::with_capacity(n - window + 1);
        let stride = chunk_rows - (window - 1);
        let mut pos = 0usize;
        let mut chunk = vec![0f32; chunk_rows];
        while pos + window <= n {
            let take = (n - pos).min(chunk_rows);
            chunk[..take].copy_from_slice(&series[pos..pos + take]);
            chunk[take..].fill(0.0);
            let ma = self.backend.moving_average(&chunk, 0, take, window)?;
            // Valid MA points of this chunk: rows [window-1, take).
            out.extend_from_slice(&ma[window - 1..take]);
            pos += stride;
        }
        out.truncate(n - window + 1);
        Ok(out)
    }

    /// Moments of the moving-average series (fused trend statistics via
    /// the `ma_stats` artifact when the whole selection fits one block).
    pub fn ma_stats(
        &self,
        views: &[SliceView<'_>],
        column: usize,
        window: usize,
    ) -> Result<PeriodStats> {
        self.ma_stats_of(&gather(views, column), window)
    }

    /// [`Self::ma_stats`] over an already-gathered series.
    pub fn ma_stats_of(&self, series: &[f32], window: usize) -> Result<PeriodStats> {
        let chunk_rows = self.backend.block_rows().unwrap_or(BLOCK_ROWS);
        if series.len() <= chunk_rows {
            // Fused single-kernel path.
            let mut chunk = vec![0f32; chunk_rows];
            chunk[..series.len()].copy_from_slice(series);
            let m = self.backend.ma_stats(&chunk, 0, series.len(), window)?;
            return PeriodStats::from_moments(m)
                .ok_or_else(|| OsebaError::InvalidRange("selection smaller than window".into()));
        }
        // General path: stitched MA then stats over it.
        let ma = self.moving_average_of(series, window)?;
        if ma.is_empty() {
            return Err(OsebaError::InvalidRange("selection smaller than window".into()));
        }
        let mut merged = Moments::EMPTY;
        for c in ma.chunks(chunk_rows) {
            let mut chunk = vec![0f32; chunk_rows];
            chunk[..c.len()].copy_from_slice(c);
            merged = merged.merge(self.backend.segment_stats(&chunk, 0, c.len())?);
        }
        PeriodStats::from_moments(merged)
            .ok_or_else(|| OsebaError::InvalidRange("empty selection".into()))
    }

    /// Distance comparison between two equally-long selections (paper §II:
    /// "the temperatures in Florida throughout 1940 and 2014").
    pub fn distance(
        &self,
        a: &[SliceView<'_>],
        b: &[SliceView<'_>],
        column: usize,
    ) -> Result<DistanceResult> {
        self.distance_of(&gather(a, column), &gather(b, column))
    }

    /// Distance between two already-gathered, equally-long series — the
    /// shared finisher for both the view path ([`Self::distance`]) and the
    /// predicate-filtered plan path.
    pub fn distance_of(&self, sa: &[f32], sb: &[f32]) -> Result<DistanceResult> {
        if sa.len() != sb.len() {
            return Err(OsebaError::InvalidRange(format!(
                "distance requires equal selections ({} vs {} rows)",
                sa.len(),
                sb.len()
            )));
        }
        if sa.is_empty() {
            return Err(OsebaError::InvalidRange("empty selection".into()));
        }
        let chunk_rows = self.backend.block_rows().unwrap_or(BLOCK_ROWS);
        let mut merged = DistancePartial::EMPTY;
        let mut ca = vec![0f32; chunk_rows];
        let mut cb = vec![0f32; chunk_rows];
        for (pa, pb) in sa.chunks(chunk_rows).zip(sb.chunks(chunk_rows)) {
            ca[..pa.len()].copy_from_slice(pa);
            ca[pa.len()..].fill(0.0);
            cb[..pb.len()].copy_from_slice(pb);
            cb[pb.len()..].fill(0.0);
            merged = merged.merge(self.backend.distance(&ca, &cb, 0, pa.len())?);
        }
        if merged.count == 0.0 {
            return Err(OsebaError::InvalidRange(
                "every compared pair is NaN".into(),
            ));
        }
        Ok(DistanceResult {
            count: merged.count as u64,
            l1: merged.l1,
            l2: merged.l2(),
            linf: merged.linf,
            mad: merged.l1 / merged.count,
            nans: merged.nans as u64,
        })
    }

    /// 64-bin histogram of the selection over `[lo, hi)` (events analysis).
    pub fn histogram(
        &self,
        views: &[SliceView<'_>],
        column: usize,
        lo: f32,
        hi: f32,
    ) -> Result<Vec<f32>> {
        if !(hi > lo) {
            return Err(OsebaError::InvalidRange(format!("bad histogram bounds [{lo}, {hi})")));
        }
        let mut merged: Option<Vec<f32>> = None;
        for v in views {
            for (block, s, e) in block_ranges(v, column) {
                let h = self.backend.histogram64(block, s, e, lo, hi)?;
                merged = Some(match merged {
                    None => h,
                    Some(mut acc) => {
                        for (a, x) in acc.iter_mut().zip(&h) {
                            *a += x;
                        }
                        acc
                    }
                });
            }
        }
        merged.ok_or_else(|| OsebaError::InvalidRange("empty selection".into()))
    }
}

/// Masked moments of rows `[row_start, row_end)` of one partition column —
/// the per-worker task body the coordinator dispatches. With `batch` set,
/// all kernel blocks go to the backend as one submission (one service
/// queue message); otherwise one request per block (the ablation's
/// unbatched arm).
pub fn slice_moments(
    backend: &dyn AnalysisBackend,
    part: &crate::storage::Partition,
    row_start: usize,
    row_end: usize,
    column: usize,
    batch: bool,
) -> Result<Moments> {
    // Clamp to the valid rows: the last block is zero-padded to
    // BLOCK_ROWS, and an over-long range must not fold that padding.
    let row_end = row_end.min(part.rows);
    let first = row_start / BLOCK_ROWS;
    let last = row_end.saturating_sub(1) / BLOCK_ROWS;
    let mut tasks: Vec<(&[f32], usize, usize)> = Vec::new();
    for b in first..=last.min(part.num_blocks().saturating_sub(1)) {
        let base = b * BLOCK_ROWS;
        let s = row_start.saturating_sub(base);
        let e = (row_end - base).min(BLOCK_ROWS);
        if s < e {
            tasks.push((part.block(column, b), s, e));
        }
    }
    if batch {
        let partials = backend.segment_stats_batch(&tasks)?;
        Ok(partials.into_iter().fold(Moments::EMPTY, Moments::merge))
    } else {
        let mut merged = Moments::EMPTY;
        for (block, s, e) in tasks {
            merged = merged.merge(backend.segment_stats(block, s, e)?);
        }
        Ok(merged)
    }
}

/// Predicate-masked variant of [`slice_moments`]: the per-worker task body
/// when a plan carries value predicates. Rows of `[row_start, row_end)`
/// whose predicate-column values all match fold their `column` value into
/// the moments (NaNs counted out as usual). The mask breaks the AOT
/// static-shape contract, so this path folds on the engine — but with the
/// same blockwise structure as the kernel path: per kernel block, the
/// mask is built once from the hoisted predicate-column blocks (one pass
/// per predicate, no per-row closure dispatch), then one branchless
/// [`fold_stats_f32_masked`] pass folds the target block; the per-block
/// partials merge in block order. That structure is what makes block-
/// sketch pruning exact — a block whose mask selects nothing merges as
/// the identity — and deterministic (fixed lane-order combine). With an
/// empty conjunction it defers to the kernel path unchanged — zero cost
/// when no `where` clause is present.
pub fn slice_moments_filtered(
    backend: &dyn AnalysisBackend,
    part: &crate::storage::Partition,
    row_start: usize,
    row_end: usize,
    column: usize,
    preds: &[ColumnPredicate],
    batch: bool,
) -> Result<Moments> {
    if preds.is_empty() {
        return slice_moments(backend, part, row_start, row_end, column, batch);
    }
    let row_end = row_end.min(part.rows);
    if row_start >= row_end {
        return Ok(Moments::EMPTY);
    }
    let mut merged = Moments::EMPTY;
    let mut mask = vec![false; BLOCK_ROWS];
    let first = row_start / BLOCK_ROWS;
    let last = (row_end - 1) / BLOCK_ROWS;
    for b in first..=last.min(part.num_blocks().saturating_sub(1)) {
        let base = b * BLOCK_ROWS;
        let s = row_start.saturating_sub(base);
        let e = (row_end - base).min(BLOCK_ROWS);
        if s >= e {
            continue;
        }
        mask[..s].fill(false);
        mask[s..e].fill(true);
        for p in preds {
            let col = part.block(p.column, b);
            for (keep, &x) in mask[s..e].iter_mut().zip(&col[s..e]) {
                *keep &= p.matches(x);
            }
        }
        let xs = part.block(column, b);
        let (mx, mn, sum, sumsq, selected, nans) =
            fold_stats_f32_masked(&xs[..e], &mask[..e]);
        let mut m = Moments::from_kernel(mx, mn, sum, sumsq, (selected - nans) as f32);
        m.nans = nans as f64;
        merged = merged.merge(m);
    }
    Ok(merged)
}

/// Gather the selected rows of `column` across views, keeping only rows
/// that satisfy every predicate *and* whose target value is not NaN — the
/// series prep for the trend (moving-average) analysis under a `where`
/// clause. Unlike [`slice_moments_filtered`], NaN target values are
/// dropped here outright (a windowed average has no way to count a NaN
/// out without poisoning its whole window); the second return value is
/// how many predicate-passing rows were dropped that way, so the caller
/// can still surface them per the NaN policy. (Distance does **not** use
/// this: dropping rows per side would shift the pairing — it pairs the
/// raw selections positionally and drops *pairs* via [`selection_mask`].)
pub fn gather_filtered(
    views: &[SliceView<'_>],
    column: usize,
    preds: &[ColumnPredicate],
) -> (Vec<f32>, usize) {
    let total: usize = views.iter().map(|v| v.rows()).sum();
    let mut out = Vec::with_capacity(total);
    let mut nans = 0usize;
    for v in views {
        let target = v.column(column);
        // One column lookup per predicate per view, not per row.
        let cols: Vec<&[f32]> = preds.iter().map(|p| v.column(p.column)).collect();
        for (r, &x) in target.iter().enumerate() {
            if !preds.iter().zip(&cols).all(|(p, col)| p.matches(col[r])) {
                continue;
            }
            if x.is_nan() {
                nans += 1;
                continue;
            }
            out.push(x);
        }
    }
    (out, nans)
}

/// Per-row predicate mask of a selection, in gather order (one flag per
/// selected row: does the row satisfy every predicate?). The distance
/// path combines the masks of both sides so a pair is compared only when
/// *both* rows pass — dropping pairs positionally instead of shifting
/// one side's series.
pub fn selection_mask(views: &[SliceView<'_>], preds: &[ColumnPredicate]) -> Vec<bool> {
    let total: usize = views.iter().map(|v| v.rows()).sum();
    let mut out = Vec::with_capacity(total);
    for v in views {
        // Column-at-a-time: start all-true for the view's rows, then AND
        // each predicate in one pass over its hoisted column slice. Every
        // row keeps its flag — positional alignment is the whole point.
        let base = out.len();
        out.resize(base + v.rows(), true);
        for p in preds {
            let col = v.column(p.column);
            for (keep, &x) in out[base..].iter_mut().zip(col) {
                *keep &= p.matches(x);
            }
        }
    }
    out
}

/// Decompose one view into `(padded block, start, end)` kernel tasks. The
/// blocks come straight from the partition's padded column storage — no
/// copying on the stats/histogram path.
fn block_ranges<'a>(
    v: &SliceView<'a>,
    column: usize,
) -> impl Iterator<Item = (&'a [f32], usize, usize)> {
    let part = v.part;
    let (rs, re) = (v.row_start, v.row_end);
    let first = rs / BLOCK_ROWS;
    let last = (re.saturating_sub(1)) / BLOCK_ROWS;
    (first..=last).filter_map(move |b| {
        let base = b * BLOCK_ROWS;
        let s = rs.saturating_sub(base);
        let e = (re - base).min(BLOCK_ROWS);
        (s < e).then(|| (part.block(column, b), s, e))
    })
}

/// Concatenate the selected rows of `column` across views (the series-prep
/// step for order-dependent analyses like MA and distance).
pub(crate) fn gather(views: &[SliceView<'_>], column: usize) -> Vec<f32> {
    let total: usize = views.iter().map(|v| v.rows()).sum();
    let mut out = Vec::with_capacity(total);
    for v in views {
        out.extend_from_slice(v.column(column));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ContextConfig;
    use crate::datagen::ClimateGen;
    use crate::engine::OsebaContext;
    use crate::index::{Cias, ContentIndex, RangeQuery};
    use crate::runtime::NativeBackend;

    fn setup(rows: usize, parts: usize) -> (OsebaContext, Dataset, Analyzer) {
        let ctx = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
        let ds = ctx.load(ClimateGen::default().generate(rows), parts).unwrap();
        (ctx, ds, Analyzer::new(Arc::new(NativeBackend)))
    }

    fn naive_stats(xs: &[f32]) -> (f32, f32, f64, f64) {
        let n = xs.len() as f64;
        let mx = xs.iter().cloned().fold(f32::MIN, f32::max);
        let mn = xs.iter().cloned().fold(f32::MAX, f32::min);
        let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
        let var = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>() / n;
        (mx, mn, mean, var.sqrt())
    }

    #[test]
    fn period_stats_match_naive_over_indexed_views() {
        let (ctx, ds, an) = setup(20_000, 7);
        let index = Cias::build(ds.partitions()).unwrap();
        let q = RangeQuery { lo: 2_000 * 3600, hi: 11_000 * 3600 };
        let pins = ctx.select_slices(&ds, &index.lookup(q), q).unwrap();
        let got = an.period_stats(&pins.views(), 0).unwrap();

        // Ground truth from the raw generator output.
        let batch = ClimateGen::default().generate(20_000);
        let sel: Vec<f32> = batch.column("temperature").unwrap()[2_000..=11_000].to_vec();
        let (mx, mn, mean, std) = naive_stats(&sel);
        assert_eq!(got.count, sel.len() as u64);
        assert_eq!(got.max, mx);
        assert_eq!(got.min, mn);
        assert!((got.mean - mean).abs() < 1e-3, "{} vs {mean}", got.mean);
        assert!((got.std - std).abs() < 1e-2);
    }

    #[test]
    fn stats_same_on_full_views_vs_slices_covering_all() {
        let (ctx, ds, an) = setup(9_000, 4);
        let full = an.period_stats(&Analyzer::full_views(&ds), 1).unwrap();
        let index = Cias::build(ds.partitions()).unwrap();
        let q = RangeQuery { lo: i64::MIN + 1, hi: i64::MAX };
        let pins = ctx.select_slices(&ds, &index.lookup(q), q).unwrap();
        let via_index = an.period_stats(&pins.views(), 1).unwrap();
        assert_eq!(full.count, via_index.count);
        assert_eq!(full.max, via_index.max);
        assert!((full.mean - via_index.mean).abs() < 1e-6);
    }

    #[test]
    fn moving_average_stitches_across_blocks() {
        let (_ctx, ds, an) = setup(10_000, 2); // 5000-row partitions → 2 blocks each
        let views = Analyzer::full_views(&ds);
        let w = 16;
        let got = an.moving_average(&views, 0, w).unwrap();
        assert_eq!(got.len(), 10_000 - w + 1);

        // Naive oracle over the gathered series.
        let series = gather(&views, 0);
        for &i in &[0usize, 100, 4080, 4081, 4095, 4096, 5000, 9984] {
            let want: f32 = series[i..i + w].iter().sum::<f32>() / w as f32;
            assert!(
                (got[i] - want).abs() < 1e-2,
                "i={i} got={} want={want}",
                got[i]
            );
        }
    }

    #[test]
    fn moving_average_window_edge_cases() {
        let (_ctx, ds, an) = setup(100, 1);
        let views = Analyzer::full_views(&ds);
        assert!(an.moving_average(&views, 0, 0).is_err());
        assert_eq!(an.moving_average(&views, 0, 101).unwrap(), Vec::<f32>::new());
        let exact = an.moving_average(&views, 0, 100).unwrap();
        assert_eq!(exact.len(), 1);
    }

    #[test]
    fn distance_self_is_zero_and_shifted_is_not() {
        let (ctx, ds, an) = setup(8_000, 3);
        let index = Cias::build(ds.partitions()).unwrap();
        let q1 = RangeQuery { lo: 0, hi: 999 * 3600 };
        let q2 = RangeQuery { lo: 4000 * 3600, hi: 4999 * 3600 };
        let p1 = ctx.select_slices(&ds, &index.lookup(q1), q1).unwrap();
        let p2 = ctx.select_slices(&ds, &index.lookup(q2), q2).unwrap();
        let (v1, v2) = (p1.views(), p2.views());

        let self_d = an.distance(&v1, &v1, 0).unwrap();
        assert_eq!(self_d.l1, 0.0);
        assert_eq!(self_d.l2, 0.0);
        assert_eq!(self_d.count, 1000);

        let cross = an.distance(&v1, &v2, 0).unwrap();
        assert!(cross.l1 > 0.0);
        assert!(cross.mad > 0.0);
        assert!(cross.linf >= (cross.mad as f32));
    }

    #[test]
    fn distance_requires_equal_lengths() {
        let (_ctx, ds, an) = setup(1000, 2);
        let views = Analyzer::full_views(&ds);
        let short = vec![views[0]];
        assert!(an.distance(&views, &short, 0).is_err());
    }

    #[test]
    fn histogram_total_mass() {
        let (_ctx, ds, an) = setup(5_000, 3);
        let views = Analyzer::full_views(&ds);
        let h = an.histogram(&views, 1, 0.0, 100.0).unwrap(); // humidity ∈ [5,100]
        assert_eq!(h.len(), 64);
        assert_eq!(h.iter().sum::<f32>() as usize, 5_000);
        assert!(an.histogram(&views, 1, 5.0, 5.0).is_err());
    }

    #[test]
    fn ma_stats_fused_matches_general() {
        let (_ctx, ds, an) = setup(3_000, 1); // fits one block? 3000 < 4096 ✓
        let views = Analyzer::full_views(&ds);
        let fused = an.ma_stats(&views, 0, 16).unwrap();
        // General path oracle: explicit MA + naive stats.
        let ma = an.moving_average(&views, 0, 16).unwrap();
        let (mx, mn, mean, std) = naive_stats(&ma);
        assert_eq!(fused.count, ma.len() as u64);
        assert!((fused.max - mx).abs() < 1e-4);
        assert!((fused.min - mn).abs() < 1e-4);
        assert!((fused.mean - mean).abs() < 1e-3);
        assert!((fused.std - std).abs() < 1e-3);
    }

    #[test]
    fn filtered_moments_match_scan_oracle() {
        use crate::index::{ColumnPredicate, PredOp};
        let (_ctx, ds, _an) = setup(9_000, 2); // 4500-row partitions: two blocks each
        let part = &ds.partitions()[1];
        let preds = vec![ColumnPredicate { column: 1, op: PredOp::Gt, value: 50.0 }];
        let (rs, re) = (10, part.rows - 7);
        let got =
            slice_moments_filtered(&NativeBackend, part, rs, re, 0, &preds, true).unwrap();
        // Exact oracle: the same per-block masked kernel folds, merged in
        // block order — the filtered path must be bit-identical to it.
        let mut want = crate::util::stats::Moments::EMPTY;
        for b in rs / BLOCK_ROWS..=(re - 1) / BLOCK_ROWS {
            let base = b * BLOCK_ROWS;
            let s = rs.saturating_sub(base);
            let e = (re - base).min(BLOCK_ROWS);
            let mask: Vec<bool> =
                (0..e).map(|r| r >= s && part.columns[1][base + r] > 50.0).collect();
            let (mx, mn, sum, sumsq, selected, nans) =
                fold_stats_f32_masked(&part.block(0, b)[..e], &mask);
            let mut m = crate::util::stats::Moments::from_kernel(
                mx,
                mn,
                sum,
                sumsq,
                (selected - nans) as f32,
            );
            m.nans = nans as f64;
            want = want.merge(m);
        }
        assert_eq!(got, want);
        assert!(got.count > 0.0, "some humidity rows exceed 50");
        assert!(got.count < (re - rs) as f64, "predicate is selective");
        // Semantics oracle: a sequential row loop agrees exactly on the
        // counts and extrema, to tolerance on the folded sum.
        let mut seq = crate::util::stats::Moments::EMPTY;
        for r in rs..re {
            if part.columns[1][r] > 50.0 {
                seq.absorb(part.columns[0][r]);
            }
        }
        assert_eq!(got.count, seq.count);
        assert_eq!(got.nans, seq.nans);
        assert_eq!(got.max, seq.max);
        assert_eq!(got.min, seq.min);
        assert!((got.sum - seq.sum).abs() < 1e-3 * seq.sum.abs().max(1.0));

        // Empty conjunction defers to the kernel path.
        let unmasked =
            slice_moments_filtered(&NativeBackend, part, 0, part.rows, 0, &[], true)
                .unwrap();
        let direct = slice_moments(&NativeBackend, part, 0, part.rows, 0, true).unwrap();
        assert_eq!(unmasked, direct);
    }

    #[test]
    fn slice_moments_clamps_row_end_to_valid_rows() {
        use crate::index::{ColumnPredicate, PredOp};
        let (_ctx, ds, _an) = setup(8_200, 1); // 3 blocks; 8 valid rows in the last
        let part = &ds.partitions()[0];
        let clamped =
            slice_moments(&NativeBackend, part, 4_000, usize::MAX, 0, true).unwrap();
        let exact = slice_moments(&NativeBackend, part, 4_000, part.rows, 0, true).unwrap();
        assert_eq!(clamped, exact, "rows past the end must not fold the zero padding");
        assert_eq!(clamped.count + clamped.nans, (part.rows - 4_000) as f64);
        // The filtered path clamps the same way (ClimateGen humidity is
        // always >= 0, so the predicate keeps every valid row).
        let preds = vec![ColumnPredicate { column: 1, op: PredOp::Ge, value: 0.0 }];
        let filtered =
            slice_moments_filtered(&NativeBackend, part, 4_000, usize::MAX, 0, &preds, true)
                .unwrap();
        assert_eq!(filtered.count + filtered.nans, clamped.count + clamped.nans);
    }

    #[test]
    fn gather_filtered_drops_nan_and_nonmatching() {
        use crate::index::{ColumnPredicate, PredOp};
        let part = crate::storage::Partition::from_rows(
            0,
            vec![1, 2, 3, 4],
            vec![vec![1.0, f32::NAN, 3.0, 4.0], vec![0.0, 9.0, 9.0, 0.0]],
        );
        let part = Arc::new(part);
        let views = vec![SliceView { part: &part, row_start: 0, row_end: 4 }];
        let preds = vec![ColumnPredicate { column: 1, op: PredOp::Ge, value: 5.0 }];
        // Row 1 matches the predicate but its target is NaN (counted);
        // row 2 passes both.
        assert_eq!(gather_filtered(&views, 0, &preds), (vec![3.0], 1));
        // No predicates: only the NaN row drops, and it is counted.
        assert_eq!(gather_filtered(&views, 0, &[]), (vec![1.0, 3.0, 4.0], 1));
    }

    #[test]
    fn block_ranges_decomposition() {
        let (_ctx, ds, _an) = setup(10_000, 1); // one partition, 3 blocks padded
        let v = SliceView { part: &ds.partitions()[0], row_start: 4000, row_end: 8200 };
        let ranges: Vec<(usize, usize)> =
            block_ranges(&v, 0).map(|(_, s, e)| (s, e)).collect();
        // Block 0: rows 4000..4096; block 1: rows 0..4096 of block; block 2: 0..8200-8192.
        assert_eq!(ranges, vec![(4000, 4096), (0, 4096), (0, 8)]);
    }
}
