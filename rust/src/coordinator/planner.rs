//! Planner vocabulary: which access method and which index a session uses.

use crate::error::{OsebaError, Result};

/// Index implementation selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum IndexKind {
    /// §III-A table (O(m) space, O(log m) lookup).
    Table,
    /// §III-B compressed index + associated search list.
    Cias,
}

impl std::str::FromStr for IndexKind {
    type Err = OsebaError;

    fn from_str(s: &str) -> Result<IndexKind> {
        match s {
            "table" => Ok(IndexKind::Table),
            "cias" => Ok(IndexKind::Cias),
            other => Err(OsebaError::Config(format!("unknown index kind '{other}'"))),
        }
    }
}

/// Access-path selector for a session.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// Spark-style scan-filter-materialize (the paper's baseline).
    Default,
    /// Index-targeted zero-copy access (the paper's contribution).
    Oseba,
}

impl Method {
    pub fn label(&self) -> &'static str {
        match self {
            Method::Default => "default",
            Method::Oseba => "oseba",
        }
    }
}

impl std::str::FromStr for Method {
    type Err = OsebaError;

    fn from_str(s: &str) -> Result<Method> {
        match s {
            "default" => Ok(Method::Default),
            "oseba" => Ok(Method::Oseba),
            other => Err(OsebaError::Config(format!("unknown method '{other}'"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parsing() {
        assert_eq!("cias".parse::<IndexKind>().unwrap(), IndexKind::Cias);
        assert_eq!("table".parse::<IndexKind>().unwrap(), IndexKind::Table);
        assert!("btree".parse::<IndexKind>().is_err());
        assert_eq!("oseba".parse::<Method>().unwrap(), Method::Oseba);
        assert_eq!("default".parse::<Method>().unwrap(), Method::Default);
        assert!("spark".parse::<Method>().is_err());
        assert_eq!(Method::Oseba.label(), "oseba");
    }
}
