//! Interactive analysis server + scripted client session (paper §I:
//! selective bulk analysis "usually involves interactive analysis").
//!
//! Starts the TCP query server on an ephemeral port, then drives it as a
//! client: info, a few range-stat queries on both paths, and shutdown —
//! printing the per-query latency the server reports.
//!
//! ```bash
//! cargo run --release --example interactive_server
//! ```

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;

use oseba::config::{AppConfig, BackendKind};
use oseba::coordinator::{Coordinator, IndexKind};
use oseba::datagen::ClimateGen;
use oseba::runtime::make_backend;
use oseba::server::QueryServer;
use oseba::util::json::Json;

fn main() -> oseba::Result<()> {
    let mut cfg = AppConfig { dataset_bytes: 16 << 20, ..AppConfig::default() };
    if !std::path::Path::new(&cfg.artifacts_dir).join("manifest.json").exists() {
        eprintln!("(artifacts not built; using the native backend)");
        cfg.backend = BackendKind::Native;
    }
    let backend = make_backend(cfg.backend, &cfg.artifacts_dir)?;
    let coord = Arc::new(Coordinator::new(&cfg, backend)?);
    let ds = coord.load(
        ClimateGen::default().generate_bytes(cfg.dataset_bytes),
        cfg.num_partitions,
    )?;
    let key_max = ds.key_max().unwrap();
    let server = QueryServer::new(coord, ds, IndexKind::Cias)?;

    let (addr_tx, addr_rx) = std::sync::mpsc::channel();
    let server_thread = std::thread::spawn(move || {
        server.serve("127.0.0.1:0", |a| addr_tx.send(a).unwrap()).unwrap();
    });
    let addr = addr_rx.recv().expect("server bound");
    println!("server on {addr}\n");

    let mut stream = TcpStream::connect(addr)?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut ask = |req: String| -> oseba::Result<Json> {
        stream.write_all(req.as_bytes())?;
        stream.write_all(b"\n")?;
        let mut line = String::new();
        reader.read_line(&mut line)?;
        print!("→ {req}\n← {line}\n");
        Json::parse(line.trim())
    };

    ask(r#"{"op":"info"}"#.to_string())?;

    // Interactive session: three selective queries, both methods.
    let spans = [(0.1, 0.2), (0.45, 0.5), (0.8, 0.95)];
    for method in ["oseba", "default"] {
        for (a, b) in spans {
            let lo = (key_max as f64 * a) as i64;
            let hi = (key_max as f64 * b) as i64;
            let resp = ask(format!(
                r#"{{"op":"stats","lo":{lo},"hi":{hi},"column":"temperature","method":"{method}"}}"#
            ))?;
            assert_eq!(resp.get("ok"), Some(&Json::Bool(true)));
        }
    }

    // Bad request → structured error, connection stays usable.
    let resp = ask(r#"{"op":"stats","lo":9,"hi":1,"column":"temperature"}"#.to_string())?;
    assert_eq!(resp.get("ok"), Some(&Json::Bool(false)));

    ask(r#"{"op":"shutdown"}"#.to_string())?;
    server_thread.join().expect("server exits cleanly");
    println!("session complete");
    Ok(())
}
