"""Pairwise period-distance Pallas kernel.

Paper §II: "Distance Comparison is used to study how two or more time series
differ at specific periods of time" (e.g. Florida temperatures in 1940 vs
2014, day by day). The rust coordinator aligns the two periods' blocks and
calls this kernel per aligned block pair; L1/L2/L∞ partials merge
associatively across block pairs.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_ROWS = 4096


def _distance_kernel(a_ref, b_ref, start_ref, end_ref,
                     l1_ref, l2sq_ref, linf_ref, count_ref):
    a = a_ref[...]
    b = b_ref[...]
    idx = jax.lax.broadcasted_iota(jnp.int32, a.shape, 0)
    mask = (idx >= start_ref[0]) & (idx < end_ref[0])
    maskf = mask.astype(jnp.float32)
    d = (a - b) * maskf
    ad = jnp.abs(d)
    l1_ref[0] = jnp.sum(ad)
    l2sq_ref[0] = jnp.sum(d * d)
    linf_ref[0] = jnp.max(ad)
    count_ref[0] = jnp.sum(maskf)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def distance(a, b, start, end, *, block_rows=None):
    """Masked distance partials between aligned blocks ``a`` and ``b``.

    Returns ``(l1, l2sq, linf, count)`` f32 scalars over rows
    ``[start, end)``. ``l2sq`` is the *squared* L2 partial so partials stay
    associative; the coordinator takes the final sqrt.
    """
    assert block_rows is None or a.shape[0] == block_rows
    start = jnp.asarray(start, jnp.int32).reshape((1,))
    end = jnp.asarray(end, jnp.int32).reshape((1,))
    out = pl.pallas_call(
        _distance_kernel,
        out_shape=tuple(jax.ShapeDtypeStruct((1,), jnp.float32)
                        for _ in range(4)),
        interpret=True,
    )(a, b, start, end)
    return tuple(o[0] for o in out)
