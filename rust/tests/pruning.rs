//! Seeded property tests for the unified query-plan layer: zone-map- and
//! membership-filter-pruned execution must be **bit-identical** to
//! unpruned execution across random key ranges, value predicates, and
//! equality point probes, on fixed, tiered, and live-snapshot datasets.
//! Pruning only ever removes partitions whose masked moments are the empty
//! partial (the merge identity), so every float of the final statistics
//! must match exactly — any drift is a planner or filter bug (a filter
//! false negative shows up here as a count mismatch vs the scan oracle).

use std::sync::Arc;

use oseba::config::{AppConfig, ContextConfig};
use oseba::coordinator::{
    plan_query, plan_query_opts, Coordinator, PlanOptions, Query, QueryOutput,
};
use oseba::engine::{Dataset, LiveConfig};
use oseba::index::{Cias, ColumnPredicate, ContentIndex, PredOp, RangeQuery};
use oseba::ingest::Chunk;
use oseba::runtime::NativeBackend;
use oseba::storage::{BatchBuilder, RecordBatch, Schema, BLOCK_ROWS};
use oseba::util::rng::Xoshiro256;

const ROWS: usize = 12_000;
const PARTS: usize = 8;
const STEP: i64 = 10;

fn coordinator(budget: Option<usize>) -> Coordinator {
    let cfg = AppConfig {
        ctx: ContextConfig { num_workers: 4, memory_budget: budget },
        cluster_workers: 3,
        ..Default::default()
    };
    Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
}

/// A batch whose `price` column trends upward (so partitions have disjoint
/// value domains — zone maps can prune) and whose `volume` column
/// oscillates (so zone maps usually cannot). A sprinkle of NaNs exercises
/// the NaN policy end to end.
fn dataset(seed: u64) -> RecordBatch {
    trending_batch(seed, ROWS, 0.001)
}

/// The same trending shape at any row count and NaN density — the block
/// battery uses multi-block partitions (rows/partition > BLOCK_ROWS) and
/// a denser NaN sprinkle.
fn trending_batch(seed: u64, rows: usize, nan_rate: f64) -> RecordBatch {
    let mut rng = Xoshiro256::seeded(seed);
    let mut b = BatchBuilder::new(Schema::stock());
    for i in 0..rows {
        let trend = i as f32 + (rng.next_f32() - 0.5) * 20.0;
        let wave = (i as f32 / 50.0).sin() * 100.0;
        let price = if rng.next_f64() < nan_rate { f32::NAN } else { trend };
        b.push(i as i64 * STEP, &[price, wave]);
    }
    b.finish().unwrap()
}

/// Random conjunction of 0..=2 predicates over the stock columns.
/// Equality probes get a rounded value — over these continuous columns
/// they rarely match anything, which is exactly the case membership
/// filters prune, and the scan oracle keeps them honest either way.
fn random_predicates(rng: &mut Xoshiro256) -> Vec<ColumnPredicate> {
    let n = rng.range_u64(0, 3) as usize;
    (0..n)
        .map(|_| {
            let column = rng.range_u64(0, 2) as usize;
            let op = match rng.range_u64(0, 5) {
                0 => PredOp::Gt,
                1 => PredOp::Ge,
                2 => PredOp::Lt,
                3 => PredOp::Le,
                _ => PredOp::Eq,
            };
            let value = match column {
                0 => rng.next_f64() as f32 * (ROWS as f32 + 200.0) - 100.0,
                _ => rng.next_f64() as f32 * 240.0 - 120.0,
            };
            let value = if op == PredOp::Eq { value.round() } else { value };
            ColumnPredicate { column, op, value }
        })
        .collect()
}

fn random_range(rng: &mut Xoshiro256) -> RangeQuery {
    random_range_rows(rng, ROWS)
}

fn random_range_rows(rng: &mut Xoshiro256, rows: usize) -> RangeQuery {
    let span = rows as i64 * STEP;
    let a = rng.range_u64(0, span as u64) as i64;
    let b = rng.range_u64(0, span as u64) as i64;
    RangeQuery { lo: a.min(b), hi: a.max(b) }
}

/// Run one query through the pruned and unpruned arms and demand exact
/// agreement; cross-check the row count against a direct scan oracle over
/// the source batch. Returns how many slices zone pruning removed.
fn check_one(
    c: &Coordinator,
    ds: &Dataset,
    index: &dyn ContentIndex,
    batch: &RecordBatch,
    q: RangeQuery,
    preds: &[ColumnPredicate],
    visible_rows: usize,
    label: &str,
) -> usize {
    let query = Query::stats(q, 0).filtered(preds.to_vec());
    let pruned_plan = plan_query(ds, index, &query, true).unwrap();
    let unpruned_plan = plan_query(ds, index, &query, false).unwrap();
    assert_eq!(unpruned_plan.explain.zone_pruned, 0);
    assert_eq!(unpruned_plan.explain.filter_pruned, 0);
    assert!(pruned_plan.explain.targeted <= unpruned_plan.explain.targeted);

    let got = c.execute_physical(ds, &pruned_plan, &query);
    let want = c.execute_physical(ds, &unpruned_plan, &query);

    // Scan oracle over the raw batch (restricted to the rows visible to
    // this dataset): exact count, exact extremes.
    let mut count = 0u64;
    let mut nans = 0u64;
    let mut mx = f32::MIN;
    let mut mn = f32::MAX;
    for r in 0..visible_rows {
        let k = batch.keys[r];
        if k < q.lo || k > q.hi {
            continue;
        }
        if !preds
            .iter()
            .all(|p| p.matches(batch.columns[p.column][r]))
        {
            continue;
        }
        let x = batch.columns[0][r];
        if x.is_nan() {
            nans += 1;
            continue;
        }
        count += 1;
        mx = mx.max(x);
        mn = mn.min(x);
    }

    match (got, want) {
        (Ok(QueryOutput::Stats(g)), Ok(QueryOutput::Stats(w))) => {
            assert_eq!(g, w, "{label}: pruned vs unpruned differ for q={q:?} preds={preds:?}");
            assert_eq!(g.count, count, "{label}: count vs oracle for q={q:?} preds={preds:?}");
            assert_eq!(g.nans, nans, "{label}: nan count vs oracle");
            if count > 0 {
                assert_eq!(g.max, mx, "{label}: max vs oracle");
                assert_eq!(g.min, mn, "{label}: min vs oracle");
            }
        }
        (Err(_), Err(_)) => {
            // An all-NaN selection also finalizes as "empty": no non-NaN
            // value means no statistics to report.
            assert_eq!(count, 0, "{label}: both arms errored but oracle counts rows");
        }
        (g, w) => panic!(
            "{label}: arms disagree on success for q={q:?} preds={preds:?}: \
             pruned={g:?} unpruned={w:?}"
        ),
    }
    pruned_plan.explain.zone_pruned
}

/// Value domain of the point-probe datasets: equal to ROWS and coprime
/// with the permutation step 37, so `price[i] = (i * 37) % DOMAIN` is a
/// bijection — every partition's zone map spans essentially the whole
/// domain (zone maps cannot prune an equality probe) while each value
/// occurs in exactly one partition (filters can).
const DOMAIN: u64 = 12_000;

/// Integer-valued permuted `price` plus an oscillating `volume`, with a
/// sprinkle of NaNs so the Eq-never-matches-NaN policy stays in the loop.
fn probe_dataset(seed: u64) -> RecordBatch {
    let mut rng = Xoshiro256::seeded(seed);
    let mut b = BatchBuilder::new(Schema::stock());
    for i in 0..ROWS as u64 {
        let v = (i * 37 % DOMAIN) as f32;
        let price = if rng.next_f64() < 0.001 { f32::NAN } else { v };
        let wave = (i as f32 / 50.0).sin() * 100.0;
        b.push(i as i64 * STEP, &[price, wave]);
    }
    b.finish().unwrap()
}

/// One full-span equality probe through three arms — filters on, zone
/// maps only, fully unpruned — plus a raw-batch scan oracle. All arms
/// must agree bit-exactly (a filter false negative would show up as a
/// dropped match here). Returns how many partitions the filter stage
/// pruned.
fn check_point(
    c: &Coordinator,
    ds: &Dataset,
    index: &dyn ContentIndex,
    batch: &RecordBatch,
    value: f32,
    visible_rows: usize,
    label: &str,
) -> usize {
    let query = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
        .filtered(vec![ColumnPredicate { column: 0, op: PredOp::Eq, value }]);
    let on = plan_query(ds, index, &query, true).unwrap();
    let zones = plan_query_opts(
        ds,
        index,
        &query,
        PlanOptions {
            zone_pruning: true,
            filter_pruning: false,
            agg_pushdown: true,
            block_pruning: true,
        },
    )
    .unwrap();
    let raw = plan_query(ds, index, &query, false).unwrap();
    assert_eq!(zones.explain.filter_pruned, 0);
    assert_eq!(zones.explain.filter_bytes, 0);
    assert!(on.explain.targeted <= zones.explain.targeted);

    // Raw scan oracle (full key span, so only the predicate selects).
    let mut count = 0u64;
    let mut mx = f32::MIN;
    let mut mn = f32::MAX;
    for r in 0..visible_rows {
        let x = batch.columns[0][r];
        if x == value {
            count += 1;
            mx = mx.max(x);
            mn = mn.min(x);
        }
    }

    let got = c.execute_physical(ds, &on, &query);
    let via_zones = c.execute_physical(ds, &zones, &query);
    let want = c.execute_physical(ds, &raw, &query);
    match (got, via_zones, want) {
        (
            Ok(QueryOutput::Stats(g)),
            Ok(QueryOutput::Stats(z)),
            Ok(QueryOutput::Stats(w)),
        ) => {
            assert_eq!(g, w, "{label}: filters-on vs unpruned differ for probe {value}");
            assert_eq!(z, w, "{label}: zones-only vs unpruned differ for probe {value}");
            assert_eq!(g.count, count, "{label}: count vs oracle for probe {value}");
            assert_eq!(g.nans, 0, "{label}: Eq never selects a NaN row");
            if count > 0 {
                assert_eq!(g.max, mx, "{label}: max vs oracle");
                assert_eq!(g.min, mn, "{label}: min vs oracle");
            }
        }
        (Err(_), Err(_), Err(_)) => {
            // An empty selection finalizes as "no statistics to report" in
            // every arm alike.
            assert_eq!(count, 0, "{label}: all arms errored but oracle counts rows");
        }
        (g, z, w) => panic!(
            "{label}: arms disagree on success for probe {value}: \
             filters={g:?} zones={z:?} unpruned={w:?}"
        ),
    }
    on.explain.filter_pruned
}

/// Run one predicate-free stats query through the sketch-answered arm
/// (aggregate pushdown on) and the edge-scanned arm (pushdown off) and
/// demand **bit-for-bit** agreement — a sketch partial is the partial the
/// scan computes, merged in the same structure, so any drift is a bug.
/// Cross-checks count/nans/extremes against a raw-batch scan oracle.
/// Returns how many partitions the sketch answered.
fn check_agg(
    c: &Coordinator,
    ds: &Dataset,
    index: &dyn ContentIndex,
    batch: &RecordBatch,
    q: RangeQuery,
    visible_rows: usize,
    label: &str,
) -> usize {
    let query = Query::stats(q, 0);
    let on = plan_query(ds, index, &query, true).unwrap();
    let off = plan_query_opts(
        ds,
        index,
        &query,
        // The oracle arm is fully blind: no sketch answers and no block
        // assist, so `estimated_rows` books every targeted row.
        PlanOptions {
            zone_pruning: true,
            filter_pruning: true,
            agg_pushdown: false,
            block_pruning: false,
        },
    )
    .unwrap();
    assert_eq!(off.explain.agg_answered, 0);
    assert_eq!(on.explain.targeted, off.explain.targeted, "{label}: same targeting");
    assert_eq!(
        on.explain.estimated_rows + on.explain.rows_avoided,
        off.explain.estimated_rows,
        "{label}: covered rows move from estimated to avoided"
    );

    let got = c.execute_physical(ds, &on, &query);
    let want = c.execute_physical(ds, &off, &query);

    // Raw-batch scan oracle over the visible rows.
    let mut count = 0u64;
    let mut nans = 0u64;
    let mut mx = f32::MIN;
    let mut mn = f32::MAX;
    for r in 0..visible_rows {
        let k = batch.keys[r];
        if k < q.lo || k > q.hi {
            continue;
        }
        let x = batch.columns[0][r];
        if x.is_nan() {
            nans += 1;
            continue;
        }
        count += 1;
        mx = mx.max(x);
        mn = mn.min(x);
    }

    match (got, want) {
        (Ok(QueryOutput::Stats(g)), Ok(QueryOutput::Stats(w))) => {
            assert_eq!(g, w, "{label}: sketch-answered vs edge-scanned differ for q={q:?}");
            assert_eq!(g.count, count, "{label}: count vs oracle for q={q:?}");
            assert_eq!(g.nans, nans, "{label}: nan count vs oracle");
            if count > 0 {
                assert_eq!(g.max, mx, "{label}: max vs oracle");
                assert_eq!(g.min, mn, "{label}: min vs oracle");
            }
        }
        (Err(_), Err(_)) => {
            assert_eq!(count, 0, "{label}: both arms errored but oracle counts rows");
        }
        (g, w) => panic!("{label}: arms disagree on success for q={q:?}: {g:?} vs {w:?}"),
    }
    on.explain.agg_answered
}

#[test]
fn sketch_answered_matches_scan_on_fixed_dataset() {
    let batch = dataset(52);
    let c = coordinator(None);
    let ds = c.load(batch.clone(), PARTS).unwrap();
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let mut rng = Xoshiro256::seeded(11);
    let mut answered = 0usize;
    for _ in 0..60 {
        let q = random_range(&mut rng);
        answered += check_agg(&c, &ds, index.as_ref(), &batch, q, ROWS, "fixed");
    }
    // Plus the guaranteed-covered full span (NaN-bearing column included).
    answered += check_agg(
        &c,
        &ds,
        index.as_ref(),
        &batch,
        RangeQuery { lo: 0, hi: i64::MAX },
        ROWS,
        "fixed-full",
    );
    assert!(answered > 0, "wide ranges must cover whole partitions");
}

#[test]
fn sketch_answered_matches_scan_on_cold_tiered_dataset() {
    let dir = oseba::testing::temp_dir("agg-tiered");
    let batch = dataset(53);
    let probe = oseba::storage::partition_batch_uniform(&batch, ROWS / PARTS).unwrap();
    let one = probe[0].bytes();
    let c = coordinator(Some(2 * one + one / 2));
    let ds = c.load_tiered(batch.clone(), PARTS, &dir).unwrap();
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let store = ds.store().unwrap().clone();
    let mut rng = Xoshiro256::seeded(12);
    let mut answered = 0usize;
    for _ in 0..20 {
        let q = random_range(&mut rng);
        store.shrink(usize::MAX).unwrap(); // every partition Cold
        answered += check_agg(&c, &ds, index.as_ref(), &batch, q, ROWS, "tiered");
    }
    // Plus a guaranteed-covered interior range (partitions 2..=5 whole).
    store.shrink(usize::MAX).unwrap();
    let part_keys = (ROWS / PARTS) as i64 * STEP;
    let interior = RangeQuery { lo: 2 * part_keys, hi: 6 * part_keys - 1 };
    answered += check_agg(&c, &ds, index.as_ref(), &batch, interior, ROWS, "tiered-int");
    assert!(answered >= 4);

    // The acceptance shape: a fully-covered query on an all-Cold store
    // answers with zero faults and zero segment bytes.
    store.shrink(usize::MAX).unwrap();
    let before = store.counters();
    let query = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0);
    let plan = plan_query(&ds, index.as_ref(), &query, true).unwrap();
    assert_eq!(plan.explain.agg_answered, PARTS);
    c.execute_physical(&ds, &plan, &query).unwrap();
    let d = store.counters().since(&before);
    assert_eq!((d.faults, d.segment_bytes_read), (0, 0), "covered query touches no data");
    c.context().unpersist(&ds);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn sketch_answered_matches_scan_on_live_snapshot() {
    let batch = dataset(54);
    let c = coordinator(None);
    let live = c
        .create_live(
            Schema::stock(),
            LiveConfig { rows_per_partition: ROWS / PARTS, max_asl: 8 },
        )
        .unwrap();
    let mut lo = 0usize;
    let mut rng = Xoshiro256::seeded(13);
    while lo < ROWS {
        let hi = (lo + 400 + rng.range_u64(0, 1_100) as usize).min(ROWS);
        live.append(Chunk {
            keys: batch.keys[lo..hi].to_vec(),
            columns: batch.columns.iter().map(|c| c[lo..hi].to_vec()).collect(),
        })
        .unwrap();
        lo = hi;
    }
    let snap = c.snapshot_live(&live);
    let index = snap.index().expect("sealed partitions exist");
    let visible_rows = snap.rows();
    assert!(visible_rows > 0);
    let mut answered = 0usize;
    for _ in 0..20 {
        let q = random_range(&mut rng);
        answered +=
            check_agg(&c, snap.dataset(), index, &batch, q, visible_rows, "live");
    }
    answered += check_agg(
        &c,
        snap.dataset(),
        index,
        &batch,
        RangeQuery { lo: 0, hi: i64::MAX },
        visible_rows,
        "live-full",
    );
    assert!(answered > 0);
    live.close();
}

/// Row count of the block-battery datasets: three kernel blocks per
/// partition, so edge slices cross block boundaries and block-level zones
/// are strictly finer than the partition zone.
const BROWS: usize = PARTS * 3 * BLOCK_ROWS;

/// Random conjunction of 0..=2 comparison predicates scaled to a
/// `rows`-row trending batch (no Eq — the point-probe battery owns those;
/// comparisons are what block zones prune).
fn random_block_predicates(rng: &mut Xoshiro256, rows: usize) -> Vec<ColumnPredicate> {
    let n = rng.range_u64(0, 3) as usize;
    (0..n)
        .map(|_| {
            let column = rng.range_u64(0, 2) as usize;
            let op = match rng.range_u64(0, 4) {
                0 => PredOp::Gt,
                1 => PredOp::Ge,
                2 => PredOp::Lt,
                _ => PredOp::Le,
            };
            let value = match column {
                0 => rng.next_f64() as f32 * (rows as f32 + 200.0) - 100.0,
                _ => rng.next_f64() as f32 * 240.0 - 120.0,
            };
            ColumnPredicate { column, op, value }
        })
        .collect()
}

/// Run one query with block sketches on (the default plan) and off, and
/// demand **bit-exact** agreement plus a raw-batch scan oracle — a
/// covered block's retained partial is the partial the scan would fold,
/// and a pruned block's masked fold is the merge identity, so every float
/// must match. Also checks the explain arithmetic (`blocks_covered +
/// blocks_pruned + blocks_scanned = blocks_considered`; the blind arm
/// classifies nothing). Returns the assisted plan's (covered, pruned).
fn check_blocks(
    c: &Coordinator,
    ds: &Dataset,
    index: &dyn ContentIndex,
    batch: &RecordBatch,
    q: RangeQuery,
    preds: &[ColumnPredicate],
    visible_rows: usize,
    label: &str,
) -> (usize, usize) {
    let query = Query::stats(q, 0).filtered(preds.to_vec());
    let on = plan_query(ds, index, &query, true).unwrap();
    let off = plan_query_opts(
        ds,
        index,
        &query,
        PlanOptions { block_pruning: false, ..PlanOptions::default() },
    )
    .unwrap();
    let ex = &on.explain;
    assert_eq!(
        ex.blocks_covered + ex.blocks_pruned + ex.blocks_scanned,
        ex.blocks_considered,
        "{label}: block arithmetic for q={q:?} preds={preds:?}"
    );
    assert_eq!(
        off.explain.blocks_considered, 0,
        "{label}: blind arm must classify no blocks"
    );
    assert!(
        ex.estimated_rows <= off.explain.estimated_rows,
        "{label}: block assist only shrinks the folded-row estimate"
    );

    let got = c.execute_physical(ds, &on, &query);
    let want = c.execute_physical(ds, &off, &query);

    // Scan oracle over the raw batch: exact count, NaNs and extremes.
    let mut count = 0u64;
    let mut nans = 0u64;
    let mut mx = f32::MIN;
    let mut mn = f32::MAX;
    for r in 0..visible_rows {
        let k = batch.keys[r];
        if k < q.lo || k > q.hi {
            continue;
        }
        if !preds
            .iter()
            .all(|p| p.matches(batch.columns[p.column][r]))
        {
            continue;
        }
        let x = batch.columns[0][r];
        if x.is_nan() {
            nans += 1;
            continue;
        }
        count += 1;
        mx = mx.max(x);
        mn = mn.min(x);
    }

    match (got, want) {
        (Ok(QueryOutput::Stats(g)), Ok(QueryOutput::Stats(w))) => {
            assert_eq!(
                g, w,
                "{label}: blocks-on vs blocks-off differ for q={q:?} preds={preds:?}"
            );
            assert_eq!(g.count, count, "{label}: count vs oracle for q={q:?} preds={preds:?}");
            assert_eq!(g.nans, nans, "{label}: nan count vs oracle");
            if count > 0 {
                assert_eq!(g.max, mx, "{label}: max vs oracle");
                assert_eq!(g.min, mn, "{label}: min vs oracle");
            }
        }
        (Err(_), Err(_)) => {
            assert_eq!(count, 0, "{label}: both arms errored but oracle counts rows");
        }
        (g, w) => panic!(
            "{label}: arms disagree on success for q={q:?} preds={preds:?}: {g:?} vs {w:?}"
        ),
    }
    (ex.blocks_covered, ex.blocks_pruned)
}

#[test]
fn block_assisted_matches_blind_on_fixed_dataset() {
    let batch = trending_batch(71, BROWS, 0.01);
    let c = coordinator(None);
    let ds = c.load(batch.clone(), PARTS).unwrap();
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let mut rng = Xoshiro256::seeded(31);
    for _ in 0..30 {
        let q = random_range_rows(&mut rng, BROWS);
        let preds = random_block_predicates(&mut rng, BROWS);
        check_blocks(&c, &ds, index.as_ref(), &batch, q, &preds, BROWS, "fixed");
    }
    // Deterministic shapes. A predicate-free window starting one block
    // into partition 0 covers its two interior blocks...
    let aligned =
        RangeQuery { lo: BLOCK_ROWS as i64 * STEP, hi: (3 * BLOCK_ROWS as i64 - 1) * STEP };
    let (cv, _) =
        check_blocks(&c, &ds, index.as_ref(), &batch, aligned, &[], BROWS, "fixed-aligned");
    assert_eq!(cv, 2, "grid-aligned edge window answers from covered blocks");
    // ...and a price cutoff above partition 0's first two blocks prunes
    // exactly those (the trending column makes block zones disjoint).
    let cut = vec![ColumnPredicate {
        column: 0,
        op: PredOp::Ge,
        value: 2.0 * BLOCK_ROWS as f32 + 200.0,
    }];
    let (_, pr) = check_blocks(
        &c,
        &ds,
        index.as_ref(),
        &batch,
        RangeQuery { lo: 0, hi: i64::MAX },
        &cut,
        BROWS,
        "fixed-cut",
    );
    assert_eq!(pr, 2, "block zones prune below the cutoff");
}

#[test]
fn block_assisted_matches_blind_on_cold_tiered_dataset() {
    let dir = oseba::testing::temp_dir("blocks-tiered");
    let batch = trending_batch(72, BROWS, 0.01);
    // Budget ~2.5 of 8 partitions: most of the dataset lives on disk.
    let probe = oseba::storage::partition_batch_uniform(&batch, BROWS / PARTS).unwrap();
    let one = probe[0].bytes();
    let c = coordinator(Some(2 * one + one / 2));
    let ds = c.load_tiered(batch.clone(), PARTS, &dir).unwrap();
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let store = ds.store().unwrap().clone();
    let mut rng = Xoshiro256::seeded(32);
    for _ in 0..12 {
        store.shrink(usize::MAX).unwrap(); // every partition Cold
        let q = random_range_rows(&mut rng, BROWS);
        let preds = random_block_predicates(&mut rng, BROWS);
        check_blocks(&c, &ds, index.as_ref(), &batch, q, &preds, BROWS, "tiered");
    }

    // The acceptance shape: a grid-aligned edge window on an all-Cold
    // store answers from the slot table's block partials without faulting
    // a single byte in — the blind arm pays the fault and must agree
    // bit-for-bit.
    store.shrink(usize::MAX).unwrap();
    let q = RangeQuery { lo: BLOCK_ROWS as i64 * STEP, hi: (3 * BLOCK_ROWS as i64 - 1) * STEP };
    let query = Query::stats(q, 0);
    let plan = plan_query(&ds, index.as_ref(), &query, true).unwrap();
    assert_eq!(plan.explain.blocks_covered, 2, "{:?}", plan.explain);
    assert_eq!(plan.explain.estimated_rows, 0, "{:?}", plan.explain);
    let before = store.counters();
    let on = c.execute_physical(&ds, &plan, &query).unwrap();
    let d = store.counters().since(&before);
    assert_eq!((d.faults, d.segment_bytes_read), (0, 0), "covered blocks touch no data");
    store.shrink(usize::MAX).unwrap();
    let blind = plan_query_opts(
        &ds,
        index.as_ref(),
        &query,
        PlanOptions { block_pruning: false, ..PlanOptions::default() },
    )
    .unwrap();
    let before = store.counters();
    let off = c.execute_physical(&ds, &blind, &query).unwrap();
    assert!(store.counters().since(&before).faults > 0, "blind edge scan must fault");
    match (on, off) {
        (QueryOutput::Stats(a), QueryOutput::Stats(b)) => assert_eq!(a, b),
        other => panic!("stats outputs expected: {other:?}"),
    }
    c.context().unpersist(&ds);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn block_assisted_matches_blind_on_live_snapshot() {
    let batch = trending_batch(73, BROWS, 0.01);
    let c = coordinator(None);
    let live = c
        .create_live(
            Schema::stock(),
            LiveConfig { rows_per_partition: BROWS / PARTS, max_asl: 8 },
        )
        .unwrap();
    let mut lo = 0usize;
    let mut rng = Xoshiro256::seeded(33);
    while lo < BROWS {
        let hi = (lo + 2_000 + rng.range_u64(0, 3_000) as usize).min(BROWS);
        live.append(Chunk {
            keys: batch.keys[lo..hi].to_vec(),
            columns: batch.columns.iter().map(|c| c[lo..hi].to_vec()).collect(),
        })
        .unwrap();
        lo = hi;
    }
    let snap = c.snapshot_live(&live);
    let index = snap.index().expect("sealed partitions exist");
    let visible_rows = snap.rows();
    assert!(visible_rows >= 3 * BLOCK_ROWS, "at least one partition sealed");
    for _ in 0..10 {
        let q = random_range_rows(&mut rng, BROWS);
        let preds = random_block_predicates(&mut rng, BROWS);
        check_blocks(&c, snap.dataset(), index, &batch, q, &preds, visible_rows, "live");
    }
    // Live-sealed partitions retain their seal-time block partials too:
    // the aligned edge window over partition 0 is block-covered.
    let aligned =
        RangeQuery { lo: BLOCK_ROWS as i64 * STEP, hi: (3 * BLOCK_ROWS as i64 - 1) * STEP };
    let (cv, _) = check_blocks(
        &c,
        snap.dataset(),
        index,
        &batch,
        aligned,
        &[],
        visible_rows,
        "live-aligned",
    );
    assert_eq!(cv, 2, "sealed partitions carry block partials");
    live.close();
}

#[test]
fn pruned_matches_unpruned_on_fixed_dataset() {
    let batch = dataset(42);
    let c = coordinator(None);
    let ds = c.load(batch.clone(), PARTS).unwrap();
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let mut rng = Xoshiro256::seeded(1);
    let mut total_pruned = 0usize;
    for _ in 0..60 {
        let q = random_range(&mut rng);
        let preds = random_predicates(&mut rng);
        total_pruned +=
            check_one(&c, &ds, index.as_ref(), &batch, q, &preds, ROWS, "fixed");
    }
    assert!(total_pruned > 0, "trending column must trigger some zone pruning");
}

#[test]
fn pruned_matches_unpruned_on_tiered_dataset() {
    let dir = oseba::testing::temp_dir("pruning-tiered");
    let batch = dataset(43);
    // Budget ~2 of 8 partitions: most of the dataset lives on disk.
    let probe = oseba::storage::partition_batch_uniform(&batch, ROWS / PARTS).unwrap();
    let one = probe[0].bytes();
    let c = coordinator(Some(2 * one + one / 2));
    let ds = c.load_tiered(batch.clone(), PARTS, &dir).unwrap();
    assert!(ds.is_tiered());
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let mut rng = Xoshiro256::seeded(2);
    for _ in 0..25 {
        let q = random_range(&mut rng);
        let preds = random_predicates(&mut rng);
        check_one(&c, &ds, index.as_ref(), &batch, q, &preds, ROWS, "tiered");
    }

    // Deterministic fault check: a full-span query admitting only the top
    // price quartile must fault in strictly fewer partitions than the
    // partition count.
    let store = ds.store().unwrap();
    let preds =
        vec![ColumnPredicate { column: 0, op: PredOp::Ge, value: ROWS as f32 - 1_000.0 }];
    let query =
        Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0).filtered(preds);
    let plan = plan_query(&ds, index.as_ref(), &query, true).unwrap();
    assert!(plan.explain.zone_pruned >= PARTS / 2, "{:?}", plan.explain);
    let before = store.counters();
    c.execute_physical(&ds, &plan, &query).unwrap();
    let faults = store.counters().since(&before).faults;
    assert!(
        faults <= plan.explain.targeted,
        "faults ({faults}) bounded by targeted ({})",
        plan.explain.targeted
    );
    c.context().unpersist(&ds);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn pruned_matches_unpruned_on_live_snapshot() {
    let batch = dataset(44);
    let c = coordinator(None);
    let live = c
        .create_live(
            Schema::stock(),
            LiveConfig { rows_per_partition: ROWS / PARTS, max_asl: 8 },
        )
        .unwrap();
    // Stream the batch in as uneven chunks; keys are strictly increasing.
    let mut lo = 0usize;
    let mut rng = Xoshiro256::seeded(3);
    while lo < ROWS {
        let hi = (lo + 500 + rng.range_u64(0, 900) as usize).min(ROWS);
        live.append(Chunk {
            keys: batch.keys[lo..hi].to_vec(),
            columns: batch.columns.iter().map(|c| c[lo..hi].to_vec()).collect(),
        })
        .unwrap();
        lo = hi;
    }
    // Do NOT flush: the snapshot pins only sealed partitions, exactly the
    // epoch semantics queries see in production.
    let snap = c.snapshot_live(&live);
    let index = snap.index().expect("sealed partitions exist");
    let visible_rows = snap.rows();
    assert!(visible_rows > 0);
    for _ in 0..25 {
        let q = random_range(&mut rng);
        let preds = random_predicates(&mut rng);
        check_one(
            &c,
            snap.dataset(),
            index,
            &batch,
            q,
            &preds,
            visible_rows,
            "live",
        );
    }
    live.close();
}

/// The index kind must not matter to planning: table and CIAS produce the
/// same pruned results.
#[test]
fn table_and_cias_plans_agree_under_predicates() {
    let batch = dataset(45);
    let c = coordinator(None);
    let ds = c.load(batch, PARTS).unwrap();
    let cias = Cias::build(ds.partitions()).unwrap();
    let table = oseba::index::TableIndex::build(ds.partitions()).unwrap();
    let mut rng = Xoshiro256::seeded(4);
    for _ in 0..20 {
        let q = random_range(&mut rng);
        let preds = random_predicates(&mut rng);
        let query = Query::stats(q, 0).filtered(preds);
        let a = plan_query(&ds, &cias, &query, true).unwrap();
        let b = plan_query(&ds, &table, &query, true).unwrap();
        let ra = c.execute_physical(&ds, &a, &query);
        let rb = c.execute_physical(&ds, &b, &query);
        match (ra, rb) {
            (Ok(QueryOutput::Stats(x)), Ok(QueryOutput::Stats(y))) => {
                assert_eq!(x.count, y.count, "q={q:?}");
                assert_eq!(x.max, y.max);
                assert_eq!(x.min, y.min);
                assert!((x.mean - y.mean).abs() < 1e-9);
            }
            (Err(_), Err(_)) => {}
            (x, y) => panic!("index kinds disagree: {x:?} vs {y:?}"),
        }
    }
}

#[test]
fn filter_pruned_matches_unpruned_on_fixed_point_probes() {
    let batch = probe_dataset(61);
    let c = coordinator(None);
    let ds = c.load(batch.clone(), PARTS).unwrap();
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let mut rng = Xoshiro256::seeded(21);
    let mut filter_pruned = 0usize;
    for _ in 0..20 {
        let v = rng.range_u64(0, DOMAIN) as f32;
        filter_pruned += check_point(&c, &ds, index.as_ref(), &batch, v, ROWS, "fixed");
        // The absent twin: x + 0.5 never occurs (stored values are
        // integers), so filters should prune everything but false
        // positives.
        filter_pruned +=
            check_point(&c, &ds, index.as_ref(), &batch, v + 0.5, ROWS, "fixed-absent");
    }
    assert!(filter_pruned > 0, "point probes must trigger filter pruning");
}

#[test]
fn filter_pruned_matches_unpruned_on_cold_tiered_point_probes() {
    let dir = oseba::testing::temp_dir("filter-tiered");
    let batch = probe_dataset(62);
    // Budget ~2 of 8 partitions: most of the dataset lives on disk.
    let probe = oseba::storage::partition_batch_uniform(&batch, ROWS / PARTS).unwrap();
    let one = probe[0].bytes();
    let c = coordinator(Some(2 * one + one / 2));
    let ds = c.load_tiered(batch.clone(), PARTS, &dir).unwrap();
    let index = c.build_index(&ds, oseba::coordinator::IndexKind::Cias).unwrap();
    let store = ds.store().unwrap().clone();
    let mut rng = Xoshiro256::seeded(22);
    let mut filter_pruned = 0usize;
    for _ in 0..10 {
        let v = rng.range_u64(0, DOMAIN) as f32;
        store.shrink(usize::MAX).unwrap(); // every partition Cold
        filter_pruned += check_point(&c, &ds, index.as_ref(), &batch, v, ROWS, "tiered");
        store.shrink(usize::MAX).unwrap();
        filter_pruned +=
            check_point(&c, &ds, index.as_ref(), &batch, v + 0.5, ROWS, "tiered-absent");
    }
    assert!(filter_pruned > 0);

    // The acceptance shape: an equality probe on an all-Cold store faults
    // in only the partitions its filters admit — O(1), not O(partitions) —
    // because filters live in the slot table, not in the evicted segments.
    store.shrink(usize::MAX).unwrap();
    let v = (4_321u64 * 37 % DOMAIN) as f32;
    let query = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
        .filtered(vec![ColumnPredicate { column: 0, op: PredOp::Eq, value: v }]);
    let plan = plan_query(&ds, index.as_ref(), &query, true).unwrap();
    assert!(plan.explain.zone_pruned == 0, "zones are blind here: {:?}", plan.explain);
    assert!(plan.explain.filter_pruned >= PARTS / 2, "{:?}", plan.explain);
    assert!(plan.explain.targeted <= 3, "{:?}", plan.explain);
    let before = store.counters();
    let _ = c.execute_physical(&ds, &plan, &query);
    let faults = store.counters().since(&before).faults;
    assert!(
        faults <= plan.explain.targeted,
        "faults ({faults}) bounded by targeted ({})",
        plan.explain.targeted
    );
    c.context().unpersist(&ds);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn filter_pruned_matches_unpruned_on_live_snapshot_point_probes() {
    let batch = probe_dataset(63);
    let c = coordinator(None);
    let live = c
        .create_live(
            Schema::stock(),
            LiveConfig { rows_per_partition: ROWS / PARTS, max_asl: 8 },
        )
        .unwrap();
    // Stream the batch in as uneven chunks; keys are strictly increasing.
    let mut lo = 0usize;
    let mut rng = Xoshiro256::seeded(23);
    while lo < ROWS {
        let hi = (lo + 500 + rng.range_u64(0, 900) as usize).min(ROWS);
        live.append(Chunk {
            keys: batch.keys[lo..hi].to_vec(),
            columns: batch.columns.iter().map(|c| c[lo..hi].to_vec()).collect(),
        })
        .unwrap();
        lo = hi;
    }
    let snap = c.snapshot_live(&live);
    let index = snap.index().expect("sealed partitions exist");
    let visible_rows = snap.rows();
    assert!(visible_rows > 0);
    let mut filter_pruned = 0usize;
    for _ in 0..10 {
        let v = rng.range_u64(0, DOMAIN) as f32;
        filter_pruned +=
            check_point(&c, snap.dataset(), index, &batch, v, visible_rows, "live");
        filter_pruned += check_point(
            &c,
            snap.dataset(),
            index,
            &batch,
            v + 0.5,
            visible_rows,
            "live-absent",
        );
    }
    assert!(filter_pruned > 0, "live-sealed partitions must carry filters");
    live.close();
}
