//! The unified query-plan layer: one logical [`Query`] (key range(s) ×
//! optional value predicates × analysis op) that every entry point —
//! single-period stats, batches, live snapshots, server requests — lowers
//! through the same optimizer into a [`PhysicalPlan`].
//!
//! Lowering is a pure-metadata pipeline (DESIGN.md §10):
//!
//! 1. **Key targeting** — the super index (CIAS/ASL or table) maps each
//!    merged key range to the partitions and row ranges that can hold it;
//!    everything else is *key-pruned* without being touched.
//! 2. **Zone-map pruning** — each surviving partition's per-column
//!    [`crate::index::ZoneMap`]s are checked against the query's value
//!    predicates; a partition whose value domain cannot satisfy the
//!    conjunction is *zone-pruned*. For a tiered dataset the zones live in
//!    the store's slot table (and the manifest), so cold partitions are
//!    ruled out **before any fault-in** — fewer `faults`, fewer
//!    `segment_bytes_read`.
//! 3. **Filter pruning** — equality predicates (`col == v`) probe each
//!    zone-surviving partition's per-column
//!    [`crate::index::MembershipFilter`]; a miss is definite (filters
//!    never report false negatives), so the partition is dropped. Like
//!    zones, filters for cold partitions live in the store's slot table —
//!    a point lookup faults in only the partitions that can hold the
//!    needle.
//! 4. **Batch merge** — multiple ranges go through
//!    [`crate::coordinator::plan_batch`] first, so overlapping ranges
//!    resolve each partition once.
//!
//! The [`Explain`] report carries the pruning arithmetic (partitions
//! considered / key-pruned / zone-pruned / targeted, estimated bytes) for
//! the CLI, the server's `explain` op, and the pruning bench.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::analysis::{DistanceResult, PeriodStats};
use crate::coordinator::planner::plan_batch;
use crate::engine::Dataset;
use crate::error::{OsebaError, Result};
use crate::index::{
    count_block_classes, usable_blocks, zones_satisfiable, BlockCounts, BlockSketches,
    ColumnPredicate, ContentIndex, PartitionSlice, PredOp, RangeQuery,
};
use crate::metrics::phase_mark;
use crate::storage::{Schema, BLOCK_ROWS};
use crate::util::json::Json;

/// The analysis an optimized query executes over its selection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueryOp {
    /// Period statistics (count/max/min/mean/std) of one column.
    Stats {
        /// Value column to analyze.
        column: usize,
    },
    /// Moments of the trailing moving average over the selection.
    Trend {
        /// Value column to analyze.
        column: usize,
        /// Moving-average window (rows).
        window: usize,
    },
    /// Distance comparison between the selection and a second key range
    /// of equal length. Pairs are positional in the raw key selections;
    /// predicates drop *pairs* (compared only when both rows pass), so
    /// distance plans are key-targeted but never zone-pruned — removing a
    /// partition from one side would shift the alignment.
    Distance {
        /// Value column to compare.
        column: usize,
        /// The comparison selection's key range (same predicates apply).
        baseline: RangeQuery,
    },
}

impl QueryOp {
    /// The value column the op reads.
    pub fn column(&self) -> usize {
        match *self {
            QueryOp::Stats { column }
            | QueryOp::Trend { column, .. }
            | QueryOp::Distance { column, .. } => column,
        }
    }
}

/// A logical selective-analysis query: *what* to compute over *which*
/// keys and *which* value domain — independent of partition layout,
/// residency, or index implementation.
#[derive(Clone, Debug, PartialEq)]
pub struct Query {
    /// Inclusive key ranges whose union is the selection. Overlapping or
    /// adjacent ranges are merged during lowering.
    pub ranges: Vec<RangeQuery>,
    /// Conjunction of value predicates (`temperature > 30.0 AND ...`);
    /// empty means key-only selection.
    pub predicates: Vec<ColumnPredicate>,
    /// The analysis to run.
    pub op: QueryOp,
}

impl Query {
    /// A key-range stats query (the paper's selective period analysis).
    pub fn stats(range: RangeQuery, column: usize) -> Query {
        Query { ranges: vec![range], predicates: Vec::new(), op: QueryOp::Stats { column } }
    }

    /// Attach a `where` conjunction (builder style).
    pub fn filtered(mut self, predicates: Vec<ColumnPredicate>) -> Query {
        self.predicates = predicates;
        self
    }
}

/// The result of executing a [`Query`], matching its [`QueryOp`].
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum QueryOutput {
    /// Output of [`QueryOp::Stats`].
    Stats(PeriodStats),
    /// Output of [`QueryOp::Trend`] (moments of the MA series).
    Trend(PeriodStats),
    /// Output of [`QueryOp::Distance`].
    Distance(DistanceResult),
}

impl QueryOutput {
    /// The period statistics, when this is a stats/trend output.
    pub fn stats(&self) -> Option<PeriodStats> {
        match self {
            QueryOutput::Stats(s) | QueryOutput::Trend(s) => Some(*s),
            QueryOutput::Distance(_) => None,
        }
    }
}

/// One merged key range of a physical plan with its surviving (post-prune)
/// partition slices.
#[derive(Clone, Debug, PartialEq)]
pub struct PrunedRange {
    /// The merged inclusive key range.
    pub range: RangeQuery,
    /// Index-targeted, zone-surviving slices, ordered by partition id.
    /// Partitions listed in [`Self::covered`] keep their slice here (the
    /// execution structure is identical either way); the slice is simply
    /// answered from the sketch instead of being resolved.
    pub slices: Vec<PartitionSlice>,
    /// Partition ids (a sorted subset of [`Self::slices`]) whose key range
    /// is **fully contained** in [`Self::range`] and whose aggregate
    /// sketch for the query's column exists: execution merges the sketch
    /// partial instead of reading — zero data touch, zero fault-in when
    /// cold. Empty for predicated queries and for ops that need raw rows
    /// (trend moving averages, distance).
    pub covered: Vec<usize>,
}

impl PrunedRange {
    /// Whether `partition` is answered from its sketch in this range.
    pub fn is_covered(&self, partition: usize) -> bool {
        self.covered.binary_search(&partition).is_ok()
    }
}

/// Optimizer switches for [`plan_query_opts`]. Every stage defaults to on;
/// the off arms exist for the oracle comparisons the property tests and
/// benches run through the *identical* execution path.
#[derive(Clone, Copy, Debug)]
pub struct PlanOptions {
    /// Drop partitions whose zone maps cannot satisfy the predicates.
    pub zone_pruning: bool,
    /// Probe per-partition membership filters for equality predicates and
    /// drop partitions whose filter definitely excludes the probe value.
    pub filter_pruning: bool,
    /// Answer fully-covered partitions from their aggregate sketches.
    pub agg_pushdown: bool,
    /// Classify scan-path `Stats` slices at kernel-block granularity:
    /// interior blocks of an edge partition are answered from their
    /// retained block partials, and blocks whose block-level zones cannot
    /// satisfy the predicate conjunction are skipped.
    pub block_pruning: bool,
}

impl Default for PlanOptions {
    fn default() -> Self {
        PlanOptions {
            zone_pruning: true,
            filter_pruning: true,
            agg_pushdown: true,
            block_pruning: true,
        }
    }
}

/// The pruning arithmetic of one lowering — what the planner skipped and
/// what execution will touch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Explain {
    /// Partitions visible in the dataset.
    pub partitions: usize,
    /// Disjoint merged ranges after batch-merging the input ranges.
    pub merged_ranges: usize,
    /// `(merged range, partition)` pairs the key index proposed.
    pub considered: usize,
    /// Partitions no merged range ever proposed (skipped by key metadata).
    pub key_pruned: usize,
    /// Proposed pairs removed because their zone maps cannot satisfy the
    /// predicate conjunction.
    pub zone_pruned: usize,
    /// Zone-surviving pairs removed because a membership filter proved an
    /// equality predicate's probe value is absent from the partition.
    pub filter_pruned: usize,
    /// Total in-memory bytes of the membership filters the planner probed
    /// (the metadata cost paid to avoid the pruned fault-ins).
    pub filter_bytes: usize,
    /// Surviving pairs execution will resolve (and, when tiered, fault in).
    /// Sketch-answered pairs are counted here too — they are targeted by
    /// the plan, just with zero data touch (see [`Self::agg_answered`]).
    pub targeted: usize,
    /// Targeted pairs answered by merging the partition's aggregate
    /// sketch: the key range fully covers the partition and no predicate
    /// masks it, so execution reads **no data** for it (and, when the
    /// partition is cold, faults **nothing** in).
    pub agg_answered: usize,
    /// Rows the sketch answers avoided reading.
    pub rows_avoided: usize,
    /// Raw bytes the sketch answers avoided reading (`rows_avoided ×
    /// row_bytes`).
    pub bytes_avoided: usize,
    /// Upper-bound rows execution will actually read (pre-mask; covered
    /// partitions and covered/pruned blocks excluded).
    pub estimated_rows: usize,
    /// Upper-bound raw bytes execution will actually read (`rows ×
    /// row_bytes`).
    pub estimated_bytes: usize,
    /// Kernel blocks the hierarchy classified across scan-path slices
    /// (always `blocks_covered + blocks_pruned + blocks_scanned`).
    pub blocks_considered: usize,
    /// Classified blocks answered by merging their retained seal-time
    /// partial — the edge-partition interior the hierarchy rescues from
    /// the scan path. Their rows land in [`Self::rows_avoided`].
    pub blocks_covered: usize,
    /// Classified blocks skipped because their block-level zones cannot
    /// satisfy the predicate conjunction. Rows also in
    /// [`Self::rows_avoided`].
    pub blocks_pruned: usize,
    /// Classified blocks execution must still fold row-by-row (remainder
    /// blocks of an edge, predicate-satisfiable blocks).
    pub blocks_scanned: usize,
    /// Proposed pairs the plan dropped because their partition is
    /// quarantined (its segment failed verification after retries) and no
    /// retained sketch covers it for this query. The answer is computed
    /// over the remaining selection — exact on what survives, silent on
    /// the quarantined rows. Always zero when the store is in strict mode
    /// (lowering fails with [`OsebaError::Store`] instead).
    pub degraded: usize,
}

impl Explain {
    /// One-line human rendering for CLI output.
    pub fn line(&self) -> String {
        let mut line = format!(
            "plan: {} partitions -> {} merged ranges, {} considered \
             ({} key-pruned), {} zone-pruned, {} filter-pruned, {} targeted \
             (~{} rows, ~{} bytes)",
            self.partitions,
            self.merged_ranges,
            self.considered,
            self.key_pruned,
            self.zone_pruned,
            self.filter_pruned,
            self.targeted,
            self.estimated_rows,
            self.estimated_bytes,
        );
        if self.filter_bytes > 0 {
            line.push_str(&format!(" | filter bytes probed: {}", self.filter_bytes));
        }
        if self.agg_answered > 0 {
            line.push_str(&format!(
                " | agg-answered: {} ({} rows, {} bytes avoided)",
                self.agg_answered, self.rows_avoided, self.bytes_avoided,
            ));
        }
        if self.blocks_considered > 0 {
            line.push_str(&format!(
                " | blocks: {} covered, {} pruned, {} scanned of {}",
                self.blocks_covered,
                self.blocks_pruned,
                self.blocks_scanned,
                self.blocks_considered,
            ));
        }
        if self.degraded > 0 {
            line.push_str(&format!(
                " | DEGRADED: {} quarantined partition(s) skipped",
                self.degraded
            ));
        }
        line
    }

    /// JSON rendering (the server's `explain` response body).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("partitions", Json::num(self.partitions as f64)),
            ("merged_ranges", Json::num(self.merged_ranges as f64)),
            ("considered", Json::num(self.considered as f64)),
            ("key_pruned", Json::num(self.key_pruned as f64)),
            ("zone_pruned", Json::num(self.zone_pruned as f64)),
            ("filter_pruned", Json::num(self.filter_pruned as f64)),
            ("filter_bytes", Json::num(self.filter_bytes as f64)),
            ("targeted", Json::num(self.targeted as f64)),
            ("agg_answered", Json::num(self.agg_answered as f64)),
            ("rows_avoided", Json::num(self.rows_avoided as f64)),
            ("bytes_avoided", Json::num(self.bytes_avoided as f64)),
            ("estimated_rows", Json::num(self.estimated_rows as f64)),
            ("estimated_bytes", Json::num(self.estimated_bytes as f64)),
            ("blocks_considered", Json::num(self.blocks_considered as f64)),
            ("blocks_covered", Json::num(self.blocks_covered as f64)),
            ("blocks_pruned", Json::num(self.blocks_pruned as f64)),
            ("blocks_scanned", Json::num(self.blocks_scanned as f64)),
            ("degraded", Json::num(self.degraded as f64)),
        ])
    }
}

/// Wall-clock spent in each optimizer phase of one lowering, measured
/// with monotonic-safe arithmetic ([`phase_mark`]) so a zero-width phase
/// can never record a negative duration. Fed into the per-phase latency
/// histograms and the `"trace":true` span tree.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PlanTimings {
    /// Key-index lookups (CIAS/ASL targeting).
    pub targeting: Duration,
    /// Zone-map predicate checks over proposed slices.
    pub zone_pruning: Duration,
    /// Membership-filter probes for equality predicates over
    /// zone-surviving slices.
    pub filter_pruning: Duration,
    /// Sketch coverage classification of surviving slices.
    pub sketch_classify: Duration,
    /// Kernel-block classification (covered / pruned / scanned) of the
    /// slices the sketch stage left on the scan path.
    pub block_classify: Duration,
}

/// A lowered query: merged ranges with surviving slices (plus the baseline
/// selection for distance ops) and the pruning report.
#[derive(Clone, Debug)]
pub struct PhysicalPlan {
    /// Merged, pruned selection ranges, in key order.
    pub ranges: Vec<PrunedRange>,
    /// The distance baseline's pruned ranges (empty for other ops).
    pub baseline: Vec<PrunedRange>,
    /// Pruning arithmetic over the whole plan (baseline included).
    pub explain: Explain,
    /// Wall-clock per optimizer phase (observability only).
    pub timings: PlanTimings,
    /// Whether kernel-block classification ran on this lowering (`Stats`
    /// op with [`PlanOptions::block_pruning`] on). Execution and
    /// [`Self::verify`] replay the identical classification when set.
    pub block_assist: bool,
}

/// Plan identity is structural — ranges, baseline, explain. `timings` is
/// a measurement of one lowering, not part of what the plan *is*: two
/// identical lowerings are the same plan however long each took.
impl PartialEq for PhysicalPlan {
    fn eq(&self, other: &Self) -> bool {
        self.ranges == other.ranges
            && self.baseline == other.baseline
            && self.explain == other.explain
    }
}

impl PhysicalPlan {
    /// Check every structural invariant a lowering must satisfy (the plan
    /// half of DESIGN.md §12). A violation is always a planner bug, never
    /// bad user input, so it surfaces as [`OsebaError::Plan`] rather than
    /// a panic: a release server degrades to one failed request.
    ///
    /// Checked, per range list (selection and baseline independently):
    ///
    /// * merged ranges are sorted and pairwise disjoint, none inverted;
    /// * every slice is non-empty and each partition appears at most once
    ///   per merged range;
    /// * `covered` is strictly sorted, a subset of the range's slice
    ///   partitions, and non-empty only for predicate-free `Stats` plans;
    /// * every covered partition's key bounds are fully contained in its
    ///   merged range and its sketch for the analysis column exists.
    ///
    /// Plus the [`Explain`] arithmetic: `merged_ranges`, `targeted`,
    /// `agg_answered`, `estimated_rows` and `rows_avoided` are recomputed
    /// from the plan itself; `considered = targeted + zone_pruned +
    /// filter_pruned + degraded`; the byte figures are the row figures
    /// times the schema row width. When [`Self::block_assist`] is set the kernel-
    /// block classification is replayed slice by slice and the block
    /// counts must match, including `blocks_covered + blocks_pruned +
    /// blocks_scanned = blocks_considered`.
    ///
    /// Pure metadata — no partition is read or faulted in. Called on every
    /// plan in debug builds; the server's `explain` op exposes it in
    /// release builds via the `verify` flag.
    pub fn verify(&self, ds: &Dataset, query: &Query) -> Result<()> {
        let err = |m: String| Err(OsebaError::Plan(m));
        let column = query.op.column();
        let sketchable =
            matches!(query.op, QueryOp::Stats { .. }) && query.predicates.is_empty();
        let mut targeted = 0usize;
        let mut agg_answered = 0usize;
        let mut est_rows = 0usize;
        let mut rows_avoided = 0usize;
        let mut blocks = BlockCounts::default();
        for (label, ranges, covered_allowed) in [
            ("selection", &self.ranges, sketchable),
            ("baseline", &self.baseline, false),
        ] {
            for w in ranges.windows(2) {
                if w[1].range.lo <= w[0].range.hi {
                    return err(format!(
                        "{label} ranges not sorted/disjoint: [{}, {}] then [{}, {}]",
                        w[0].range.lo, w[0].range.hi, w[1].range.lo, w[1].range.hi
                    ));
                }
            }
            for pr in ranges.iter() {
                if pr.range.lo > pr.range.hi {
                    return err(format!(
                        "{label} range [{}, {}] is inverted",
                        pr.range.lo, pr.range.hi
                    ));
                }
                let mut parts = std::collections::BTreeSet::new();
                for s in &pr.slices {
                    if s.row_start >= s.row_end {
                        return err(format!(
                            "{label} slice of partition {} is empty ([{}, {}))",
                            s.partition, s.row_start, s.row_end
                        ));
                    }
                    if !parts.insert(s.partition) {
                        return err(format!(
                            "partition {} appears twice in one {label} range",
                            s.partition
                        ));
                    }
                }
                targeted += pr.slices.len();
                if pr.covered.windows(2).any(|w| w[0] >= w[1]) {
                    return err(format!(
                        "{label} covered list is not strictly sorted: {:?}",
                        pr.covered
                    ));
                }
                if !covered_allowed && !pr.covered.is_empty() {
                    return err(format!(
                        "sketch-covered partitions on a plan that cannot use sketches \
                         ({label}, op {:?}, {} predicate(s))",
                        query.op,
                        query.predicates.len()
                    ));
                }
                for &p in &pr.covered {
                    if !parts.contains(&p) {
                        return err(format!(
                            "covered partition {p} has no slice in its {label} range"
                        ));
                    }
                    let Some((kmin, kmax, _)) = ds.partition_bounds(p) else {
                        return err(format!("covered partition {p} has no key bounds"));
                    };
                    if kmin < pr.range.lo || pr.range.hi < kmax {
                        return err(format!(
                            "covered partition {p} keys [{kmin}, {kmax}] are not \
                             contained in merged range [{}, {}]",
                            pr.range.lo, pr.range.hi
                        ));
                    }
                    if ds.sketch(p, column).is_none() {
                        return err(format!(
                            "covered partition {p} has no sketch for column {column}"
                        ));
                    }
                }
                agg_answered += pr.covered.len();
                for s in &pr.slices {
                    if pr.is_covered(s.partition) {
                        rows_avoided += s.rows();
                    } else if let Some(b) = self
                        .block_assist
                        .then(|| block_counts_for(ds, s, pr.range, &query.predicates, column))
                        .flatten()
                    {
                        // Replay the exact block classification the
                        // lowering ran (same helper, same inputs).
                        blocks.covered += b.covered;
                        blocks.pruned += b.pruned;
                        blocks.scanned += b.scanned;
                        rows_avoided += b.rows_avoided;
                        est_rows += b.rows_scanned;
                    } else {
                        est_rows += s.rows();
                    }
                }
            }
        }
        let ex = &self.explain;
        let row_bytes = ds.schema().row_bytes();
        let checks = [
            ("merged_ranges", ex.merged_ranges, self.ranges.len() + self.baseline.len()),
            ("targeted", ex.targeted, targeted),
            ("agg_answered", ex.agg_answered, agg_answered),
            (
                "considered",
                ex.considered,
                ex.targeted + ex.zone_pruned + ex.filter_pruned + ex.degraded,
            ),
            ("estimated_rows", ex.estimated_rows, est_rows),
            ("rows_avoided", ex.rows_avoided, rows_avoided),
            ("estimated_bytes", ex.estimated_bytes, ex.estimated_rows * row_bytes),
            ("bytes_avoided", ex.bytes_avoided, ex.rows_avoided * row_bytes),
            ("blocks_covered", ex.blocks_covered, blocks.covered),
            ("blocks_pruned", ex.blocks_pruned, blocks.pruned),
            ("blocks_scanned", ex.blocks_scanned, blocks.scanned),
            (
                "blocks_considered",
                ex.blocks_considered,
                ex.blocks_covered + ex.blocks_pruned + ex.blocks_scanned,
            ),
        ];
        for (name, got, want) in checks {
            if got != want {
                return err(format!("explain.{name} = {got}, recomputed {want}"));
            }
        }
        if ex.key_pruned > ex.partitions {
            return err(format!(
                "explain.key_pruned {} exceeds partition count {}",
                ex.key_pruned, ex.partitions
            ));
        }
        Ok(())
    }
}

/// The single prune decision both the plan layer and the batch path use:
/// does `partition` survive zone-map pruning for `predicates` on `ds`?
/// `true` when there is nothing to prune by (no predicates, or no zones).
pub(crate) fn zone_keep(
    ds: &Dataset,
    predicates: &[ColumnPredicate],
    partition: usize,
) -> bool {
    predicates.is_empty()
        || match ds.zone_maps(partition) {
            Some(zones) => zones_satisfiable(predicates, &zones),
            // Unknown zones (shouldn't happen): never prune blind.
            None => true,
        }
}

/// The membership-filter prune decision both the plan layer and the batch
/// path use: does `partition` survive its per-column filters for the
/// equality predicates in `predicates`? Returns `(keep, bytes)` where
/// `bytes` is the in-memory size of every filter actually probed — the
/// metadata cost of the decision. Only [`PredOp::Eq`] predicates probe; a
/// partition without filters (pre-v4 manifests) or without a filter for
/// the predicate's column is always kept — "no filter" means "always
/// consider", never "absent".
pub(crate) fn filter_keep(
    ds: &Dataset,
    predicates: &[ColumnPredicate],
    partition: usize,
) -> (bool, usize) {
    if !predicates.iter().any(|p| p.op == PredOp::Eq) {
        return (true, 0);
    }
    let Some(filters) = ds.filters(partition) else {
        return (true, 0);
    };
    let mut bytes = 0usize;
    for p in predicates {
        if p.op != PredOp::Eq {
            continue;
        }
        let Some(f) = filters.get(p.column) else {
            continue;
        };
        bytes += f.memory_bytes();
        if !f.contains(p.value) {
            // A filter miss is definite: the probe value is not in the
            // partition, so the conjunction cannot match any of its rows.
            return (false, bytes);
        }
    }
    (true, bytes)
}

/// The one covered/edge decision of the aggregate-pushdown lowering
/// stage, shared by the plan layer (one candidate range per merged range)
/// and the batch path (the elementary demux segments as candidates):
/// `Some((range index, rows, sketch))` when every row of `partition` lies
/// inside one of `ranges` (judged from O(1) key-bounds metadata — no data
/// touch) *and* a sketch for `column` exists, so the partition can be
/// answered by merging that sketch. Pure metadata on every backing,
/// including cold tiered slots.
pub(crate) fn covered_in(
    ds: &Dataset,
    partition: usize,
    column: usize,
    ranges: &[RangeQuery],
) -> Option<(usize, usize, crate::index::ColumnSketch)> {
    let (kmin, kmax, rows) = ds.partition_bounds(partition)?;
    let idx = ranges.iter().position(|r| r.lo <= kmin && kmax <= r.hi)?;
    let sketch = ds.sketch(partition, column)?;
    Some((idx, rows, sketch))
}

/// The one block-hierarchy decision the plan layer, [`PhysicalPlan::verify`]
/// and the executor all share, so their classifications can never drift:
/// `Some((blocks, rows, cover_ok))` when the slice's partition has usable
/// block sketches — present, non-empty, and at the kernel block size
/// ([`BLOCK_ROWS`]), so planner metadata and any faulted-in partition
/// describe the same grid — *and* the slice bounds are exact. A
/// whole-partition slice is conservative (an unknown-step index returns
/// it unrefined; resolve narrows it against the actual keys later), so
/// it is trusted only when the partition's key bounds are contained in
/// `range`, which makes the refinement the identity. `cover_ok` says
/// whether whole in-range blocks may be *covered* (answered by merging
/// their retained partial), which needs a predicate-free selection and
/// partials for the analysis column. Pure metadata on every backing —
/// cold slots classify before fault-in.
pub(crate) fn block_assist_for(
    ds: &Dataset,
    s: &PartitionSlice,
    range: RangeQuery,
    predicates: &[ColumnPredicate],
    column: usize,
) -> Option<(Arc<BlockSketches>, usize, bool)> {
    let (kmin, kmax, rows) = ds.partition_bounds(s.partition)?;
    let exact = s.row_start > 0
        || s.row_end < rows
        || (range.lo <= kmin && kmax <= range.hi);
    if !exact {
        return None;
    }
    let blocks = usable_blocks(ds.block_sketches(s.partition), BLOCK_ROWS)?;
    let cover_ok = predicates.is_empty() && column < blocks.num_columns();
    Some((blocks, rows, cover_ok))
}

/// Block-classification arithmetic of one scan-path slice (`None` when
/// its partition has no usable hierarchy or the slice is conservative):
/// what [`prune_ranges`] books into [`Explain`] and
/// [`PhysicalPlan::verify`] recomputes.
pub(crate) fn block_counts_for(
    ds: &Dataset,
    s: &PartitionSlice,
    range: RangeQuery,
    predicates: &[ColumnPredicate],
    column: usize,
) -> Option<BlockCounts> {
    let (blocks, rows, cover_ok) = block_assist_for(ds, s, range, predicates, column)?;
    Some(count_block_classes(&blocks, rows, s.row_start, s.row_end, predicates, cover_ok))
}

/// Key-target, zone-prune and (for sketch-answerable ops) classify one set
/// of ranges, accumulating counts into `ex` and per-phase wall time into
/// `timings`. `agg_column` is `Some(column)` when covered partitions may
/// be answered from their aggregate sketches.
#[allow(clippy::too_many_arguments)]
fn prune_ranges(
    ds: &Dataset,
    index: &dyn ContentIndex,
    ranges: &[RangeQuery],
    predicates: &[ColumnPredicate],
    zone_pruning: bool,
    filter_pruning: bool,
    agg_column: Option<usize>,
    block_column: Option<usize>,
    seen: &mut [bool],
    ex: &mut Explain,
    timings: &mut PlanTimings,
) -> Result<Vec<PrunedRange>> {
    let mut out = Vec::new();
    for pq in plan_batch(ranges) {
        ex.merged_ranges += 1;
        // Phase 1 — targeting: the super index proposes candidate slices.
        let mark = Instant::now();
        let proposed = index.lookup(pq.range);
        ex.considered += proposed.len();
        for s in &proposed {
            if let Some(flag) = seen.get_mut(s.partition) {
                *flag = true;
            }
        }
        let mark = phase_mark(&mut timings.targeting, mark);
        // Phase 2 — zone pruning: drop slices whose zone maps cannot
        // satisfy the predicate conjunction.
        let mut survivors = Vec::with_capacity(proposed.len());
        for s in proposed {
            if !zone_pruning || zone_keep(ds, predicates, s.partition) {
                survivors.push(s);
            } else {
                ex.zone_pruned += 1;
            }
        }
        let mark = phase_mark(&mut timings.zone_pruning, mark);
        // Phase 3 — filter pruning: equality predicates probe each
        // survivor's per-column membership filter; a miss is definite, so
        // the partition is dropped before any fault-in. Pure metadata —
        // for a tiered dataset the filters live in the store's slot table.
        let mut kept = Vec::with_capacity(survivors.len());
        for s in survivors {
            let (keep, bytes) = if filter_pruning {
                filter_keep(ds, predicates, s.partition)
            } else {
                (true, 0)
            };
            ex.filter_bytes += bytes;
            if keep {
                kept.push(s);
            } else {
                ex.filter_pruned += 1;
            }
        }
        let survivors = kept;
        let mark = phase_mark(&mut timings.filter_pruning, mark);
        // Phase 4 — sketch classification: covered survivors are answered
        // from their aggregate sketches, the rest go to the scan path. A
        // quarantined partition (its segment failed verification after
        // retries) can still be *covered* — the sketch is retained planner
        // metadata, so the answer stays exact with zero fault-in — but it
        // cannot be scanned: in strict mode the lowering fails, otherwise
        // the slice is dropped and booked as `degraded`.
        let mut covered = Vec::new();
        let mut kept = Vec::with_capacity(survivors.len());
        let mut edges = Vec::new();
        for s in survivors {
            match agg_column
                .and_then(|c| covered_in(ds, s.partition, c, std::slice::from_ref(&pq.range)))
            {
                Some(_) => {
                    // Answered from the sketch: no rows will be read.
                    ex.targeted += 1;
                    ex.agg_answered += 1;
                    ex.rows_avoided += s.rows();
                    covered.push(s.partition);
                    kept.push(s);
                }
                None if ds.quarantined(s.partition) => {
                    if ds.strict_faults() {
                        return Err(OsebaError::Store(format!(
                            "partition {} is quarantined and the store is strict",
                            s.partition
                        )));
                    }
                    ex.degraded += 1;
                }
                None => {
                    ex.targeted += 1;
                    edges.push(s);
                    kept.push(s);
                }
            }
        }
        let survivors = kept;
        let mark = phase_mark(&mut timings.sketch_classify, mark);
        // Phase 5 — block classification: slices the sketch stage left on
        // the scan path drop to kernel-block granularity. Interior blocks
        // of an edge partition merge their retained partials (covered);
        // blocks whose block-level zones cannot satisfy the conjunction
        // are skipped (pruned); only the rest book estimated rows. Pure
        // metadata — cold partitions classify before any fault-in.
        for s in &edges {
            match block_column.and_then(|c| block_counts_for(ds, s, pq.range, predicates, c)) {
                Some(b) => {
                    ex.blocks_considered += b.considered();
                    ex.blocks_covered += b.covered;
                    ex.blocks_pruned += b.pruned;
                    ex.blocks_scanned += b.scanned;
                    ex.rows_avoided += b.rows_avoided;
                    ex.estimated_rows += b.rows_scanned;
                }
                None => ex.estimated_rows += s.rows(),
            }
        }
        phase_mark(&mut timings.block_classify, mark);
        // Lookup yields the compressed region in id order but ASL entries
        // in *key* order — sort so `is_covered` can binary-search.
        covered.sort_unstable();
        out.push(PrunedRange { range: pq.range, slices: survivors, covered });
    }
    Ok(out)
}

/// Lower a logical [`Query`] against a dataset and its super index into a
/// [`PhysicalPlan`]: batch-merge the ranges, key-target each merged range
/// through the index, and (when `prune` is set) drop partitions whose
/// zone maps cannot satisfy the predicates or whose membership filters
/// exclude an equality probe. Aggregate pushdown stays on; use
/// [`plan_query_opts`] to switch it off for oracle comparisons.
/// Pure metadata — no partition is read or faulted in. `prune: false`
/// switches off both zone-map and membership-filter pruning — the oracle
/// arm the property tests and the pruning bench compare against.
pub fn plan_query(
    ds: &Dataset,
    index: &dyn ContentIndex,
    query: &Query,
    prune: bool,
) -> Result<PhysicalPlan> {
    plan_query_opts(
        ds,
        index,
        query,
        PlanOptions {
            zone_pruning: prune,
            filter_pruning: prune,
            agg_pushdown: true,
            block_pruning: true,
        },
    )
}

/// [`plan_query`] with every optimizer stage switchable — the entry point
/// for oracle arms (`agg_pushdown: false` forces every targeted partition
/// down the scan path, reproducing the pre-sketch plans).
pub fn plan_query_opts(
    ds: &Dataset,
    index: &dyn ContentIndex,
    query: &Query,
    opts: PlanOptions,
) -> Result<PhysicalPlan> {
    let width = ds.schema().width();
    for (i, r) in query.ranges.iter().enumerate() {
        if r.lo > r.hi {
            return Err(OsebaError::InvalidRange(format!(
                "query range {i}: lo {} > hi {}",
                r.lo, r.hi
            )));
        }
    }
    for p in &query.predicates {
        if p.column >= width {
            return Err(OsebaError::Schema(format!(
                "predicate column {} out of range (schema has {width} value columns)",
                p.column
            )));
        }
        if !p.value.is_finite() {
            return Err(OsebaError::InvalidRange(format!(
                "predicate value {} is not finite",
                p.value
            )));
        }
    }
    if query.op.column() >= width {
        return Err(OsebaError::Schema(format!(
            "analysis column {} out of range (schema has {width} value columns)",
            query.op.column()
        )));
    }
    if let QueryOp::Trend { window, .. } = query.op {
        if window == 0 {
            return Err(OsebaError::InvalidRange("window must be > 0".into()));
        }
    }

    // Distance pairs the two selections positionally, so zone pruning —
    // which removes rows from one side only — would shift the alignment.
    // Distance plans are key-targeted only; predicates drop *pairs* at
    // execution instead. The same applies to filter pruning.
    let is_distance = matches!(query.op, QueryOp::Distance { .. });
    let zone_pruning = opts.zone_pruning && !is_distance;
    let filter_pruning = opts.filter_pruning && !is_distance;
    // Aggregate pushdown applies only to `Stats` — the one op whose
    // result is a pure fold of the sketch algebra. Trend needs the raw
    // series (a moving average is order-dependent) and distance needs
    // positional pairs; a predicate conjunction masks rows the sketch
    // cannot un-fold, so any `where` clause also forces the scan path.
    let agg_column = match query.op {
        QueryOp::Stats { column }
            if opts.agg_pushdown && query.predicates.is_empty() =>
        {
            Some(column)
        }
        _ => None,
    };
    // Block classification applies to `Stats` only, like the sketch
    // stage, but survives a `where` clause: a masked fold still skips
    // blocks whose block-level zones rule the conjunction out. Trend and
    // distance read raw ordered rows, so dropping interior blocks would
    // corrupt them.
    let block_column = match query.op {
        QueryOp::Stats { column } if opts.block_pruning => Some(column),
        _ => None,
    };
    let mut ex = Explain { partitions: ds.num_partitions(), ..Explain::default() };
    let mut seen = vec![false; ex.partitions];
    let mut timings = PlanTimings::default();
    let ranges = prune_ranges(
        ds,
        index,
        &query.ranges,
        &query.predicates,
        zone_pruning,
        filter_pruning,
        agg_column,
        block_column,
        &mut seen,
        &mut ex,
        &mut timings,
    )?;
    let baseline = match query.op {
        QueryOp::Distance { baseline, .. } => {
            if baseline.lo > baseline.hi {
                return Err(OsebaError::InvalidRange(format!(
                    "baseline range: lo {} > hi {}",
                    baseline.lo, baseline.hi
                )));
            }
            prune_ranges(
                ds,
                index,
                &[baseline],
                &query.predicates,
                zone_pruning,
                filter_pruning,
                None,
                None,
                &mut seen,
                &mut ex,
                &mut timings,
            )?
        }
        _ => Vec::new(),
    };
    ex.key_pruned = ex.partitions - seen.iter().filter(|&&s| s).count();
    let row_bytes = ds.schema().row_bytes();
    ex.estimated_bytes = ex.estimated_rows * row_bytes;
    ex.bytes_avoided = ex.rows_avoided * row_bytes;
    let plan = PhysicalPlan {
        ranges,
        baseline,
        explain: ex,
        timings,
        block_assist: block_column.is_some(),
    };
    // Every lowering self-checks in debug builds (tests, benches run with
    // `--release` skip it; the server's `explain {verify}` runs it on
    // demand in any build).
    #[cfg(debug_assertions)]
    plan.verify(ds, query)?;
    Ok(plan)
}

/// Parse a `where` conjunction like `"temperature > 30, humidity <= 50"`
/// (clauses joined by `,` or `and`; operators `>`, `>=`, `<`, `<=`,
/// `==`) against a schema. Rejects unknown columns, unknown operators
/// (including bare `=`) and non-finite constants.
pub fn parse_predicates(spec: &str, schema: &Schema) -> Result<Vec<ColumnPredicate>> {
    let mut out = Vec::new();
    for clause in spec.split(',').flat_map(|c| c.split(" and ")) {
        let clause = clause.trim();
        if clause.is_empty() {
            continue;
        }
        let mut found = None;
        // `==` must be matched before the single-char operators — none of
        // the others are its prefix, but keeping it first makes that
        // invariant obvious.
        for (sym, op) in [
            ("==", PredOp::Eq),
            (">=", PredOp::Ge),
            ("<=", PredOp::Le),
            (">", PredOp::Gt),
            ("<", PredOp::Lt),
        ] {
            if let Some(i) = clause.find(sym) {
                found = Some((i, sym, op));
                break;
            }
        }
        let Some((i, sym, op)) = found else {
            return Err(OsebaError::Config(format!(
                "predicate '{clause}' has no operator (supported: > >= < <= ==)"
            )));
        };
        let name = clause[..i].trim();
        let value: f32 = clause[i + sym.len()..]
            .trim()
            .parse()
            .map_err(|_| {
                OsebaError::Config(format!("predicate '{clause}': bad numeric constant"))
            })?;
        if !value.is_finite() {
            return Err(OsebaError::Config(format!(
                "predicate '{clause}': constant must be finite"
            )));
        }
        let column = schema.column_index(name)?;
        out.push(ColumnPredicate { column, op, value });
    }
    if out.is_empty() {
        return Err(OsebaError::Config("empty where clause".into()));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ContextConfig;
    use crate::engine::OsebaContext;
    use crate::index::Cias;
    use crate::storage::{BatchBuilder, Schema};

    /// 1000 rows in 4 partitions; `price` trends upward (0..1000) so each
    /// partition has a disjoint price domain; `volume` is constant 7.
    fn trending() -> (OsebaContext, Dataset, Cias) {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..1000 {
            b.push(i as i64 * 10, &[i as f32, 7.0]);
        }
        let ctx = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
        let ds = ctx.load(b.finish().unwrap(), 4).unwrap();
        let index = Cias::build(ds.partitions()).unwrap();
        (ctx, ds, index)
    }

    fn pred(column: usize, op: PredOp, value: f32) -> ColumnPredicate {
        ColumnPredicate { column, op, value }
    }

    /// 1000 rows in 4 partitions; `price` walks the multiples of 37
    /// modulo 1000 (a permutation of 0..1000), so every partition's zone
    /// map spans almost the whole domain — only the membership filters
    /// can rule a specific value out.
    fn cycling() -> (OsebaContext, Dataset, Cias) {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..1000u64 {
            b.push(i as i64 * 10, &[(i * 37 % 1000) as f32, 7.0]);
        }
        let ctx = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
        let ds = ctx.load(b.finish().unwrap(), 4).unwrap();
        let index = Cias::build(ds.partitions()).unwrap();
        (ctx, ds, index)
    }

    #[test]
    fn filter_pruning_drops_partitions_zone_maps_cannot() {
        let (_ctx, ds, index) = cycling();
        // 500.0 exists only in partition 2, but every partition's price
        // zone spans it: zones keep all four, filters keep (at least) the
        // one that holds it. False positives may keep an extra partition
        // but can never drop the true one, so the asserts bound rather
        // than pin the counts.
        let q = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
            .filtered(vec![pred(0, PredOp::Eq, 500.0)]);
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert_eq!(plan.explain.considered, 4);
        assert_eq!(plan.explain.zone_pruned, 0);
        assert!(plan.explain.filter_pruned >= 2, "explain: {:?}", plan.explain);
        assert_eq!(plan.explain.targeted, 4 - plan.explain.filter_pruned);
        assert!(plan.explain.filter_bytes > 0);
        assert!(
            plan.ranges[0].slices.iter().any(|s| s.partition == 2),
            "the partition that truly holds the needle must survive"
        );

        // A value no row holds (all prices are integers) prunes
        // everything, modulo at most a stray false positive.
        let absent = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
            .filtered(vec![pred(0, PredOp::Eq, 500.5)]);
        let plan = plan_query(&ds, &index, &absent, true).unwrap();
        assert!(plan.explain.targeted <= 1, "explain: {:?}", plan.explain);

        // The oracle arm keeps everything the zones keep and probes no
        // filter bytes.
        let opts = PlanOptions {
            zone_pruning: true,
            filter_pruning: false,
            agg_pushdown: true,
            block_pruning: true,
        };
        let plan = plan_query_opts(&ds, &index, &q, opts).unwrap();
        assert_eq!(plan.explain.filter_pruned, 0);
        assert_eq!(plan.explain.filter_bytes, 0);
        assert_eq!(plan.explain.targeted, 4);

        // Non-equality predicates never probe filters.
        let ranged = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
            .filtered(vec![pred(0, PredOp::Ge, 0.0)]);
        let plan = plan_query(&ds, &index, &ranged, true).unwrap();
        assert_eq!(plan.explain.filter_pruned, 0);
        assert_eq!(plan.explain.filter_bytes, 0);
    }

    #[test]
    fn key_only_plan_prunes_nothing_by_zones() {
        let (_ctx, ds, index) = trending();
        let q = Query::stats(RangeQuery { lo: 0, hi: 2_490 }, 0);
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert_eq!(plan.explain.partitions, 4);
        assert_eq!(plan.explain.merged_ranges, 1);
        assert_eq!(plan.explain.considered, 1, "one partition holds keys 0..=2490");
        assert_eq!(plan.explain.key_pruned, 3);
        assert_eq!(plan.explain.zone_pruned, 0);
        assert_eq!(plan.explain.targeted, 1);
        // [0, 2490] contains the whole first partition (keys 0..=2490), so
        // the sketch answers it: nothing will be read.
        assert_eq!(plan.explain.agg_answered, 1);
        assert_eq!(plan.explain.rows_avoided, 250);
        assert_eq!(plan.explain.bytes_avoided, 250 * ds.schema().row_bytes());
        assert_eq!(plan.explain.estimated_rows, 0);
        assert_eq!(plan.explain.estimated_bytes, 0);
        assert_eq!(plan.explain.blocks_considered, 0, "covered slices skip blocks");
        assert_eq!(plan.ranges[0].covered, vec![0]);
        assert!(plan.ranges[0].is_covered(0));
        assert!(plan.baseline.is_empty());

        // Shrinking the range by one key turns it into an edge: the
        // remainder block must now be scanned (and the estimates book it).
        let q = Query::stats(RangeQuery { lo: 0, hi: 2_480 }, 0);
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert_eq!(plan.explain.agg_answered, 0);
        assert_eq!(plan.explain.estimated_rows, 249);
        assert_eq!(plan.explain.blocks_considered, 1, "250 rows fit one block");
        assert_eq!(plan.explain.blocks_scanned, 1);
        assert!(plan.ranges[0].covered.is_empty());

        // With sketch pushdown off but block assist on, the hierarchy
        // still answers the fully-contained block from its partial.
        let q = Query::stats(RangeQuery { lo: 0, hi: 2_490 }, 0);
        let opts = PlanOptions {
            zone_pruning: true,
            filter_pruning: true,
            agg_pushdown: false,
            block_pruning: true,
        };
        let plan = plan_query_opts(&ds, &index, &q, opts).unwrap();
        assert_eq!(plan.explain.agg_answered, 0);
        assert_eq!(plan.explain.blocks_covered, 1);
        assert_eq!(plan.explain.estimated_rows, 0);
        assert_eq!(plan.explain.rows_avoided, 250);
        assert!(plan.ranges[0].covered.is_empty());

        // The full oracle arm forces the partition down the scan path.
        let opts = PlanOptions {
            zone_pruning: true,
            filter_pruning: true,
            agg_pushdown: false,
            block_pruning: false,
        };
        let plan = plan_query_opts(&ds, &index, &q, opts).unwrap();
        assert_eq!(plan.explain.agg_answered, 0);
        assert_eq!(plan.explain.estimated_rows, 250);
        assert_eq!(plan.explain.blocks_considered, 0);
        assert!(plan.ranges[0].covered.is_empty());
        assert!(!plan.block_assist);
    }

    #[test]
    fn block_classification_books_edges_and_predicates() {
        // One partition spanning three kernel blocks (4096 + 4096 + 1808
        // rows), price = row index, keys stepping by 10.
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..(2 * BLOCK_ROWS + 1808) {
            b.push(i as i64 * 10, &[i as f32, 7.0]);
        }
        let ctx = OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
        let ds = ctx.load(b.finish().unwrap(), 1).unwrap();
        let index = Cias::build(ds.partitions()).unwrap();

        // An edge range covering rows 0..6000: block 0 is fully interior
        // (answered from its partial), block 1 is the remainder scan,
        // block 2 is outside the selection.
        let q = Query::stats(RangeQuery { lo: 0, hi: 59_990 }, 0);
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert!(plan.block_assist);
        assert_eq!(plan.explain.agg_answered, 0);
        assert_eq!(plan.explain.blocks_considered, 2);
        assert_eq!(plan.explain.blocks_covered, 1);
        assert_eq!(plan.explain.blocks_pruned, 0);
        assert_eq!(plan.explain.blocks_scanned, 1);
        assert_eq!(plan.explain.rows_avoided, BLOCK_ROWS);
        assert_eq!(plan.explain.estimated_rows, 6000 - BLOCK_ROWS);
        assert!(plan.explain.line().contains("blocks: 1 covered"), "{}", plan.explain.line());

        // A predicate only the last block can satisfy prunes the first
        // two at block granularity even though the partition-level zone
        // map keeps the partition.
        let q = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
            .filtered(vec![pred(0, PredOp::Gt, 8200.0)]);
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert_eq!(plan.explain.zone_pruned, 0);
        assert_eq!(plan.explain.targeted, 1);
        assert_eq!(plan.explain.blocks_considered, 3);
        assert_eq!(plan.explain.blocks_pruned, 2);
        assert_eq!(plan.explain.blocks_covered, 0, "predicates disable coverage");
        assert_eq!(plan.explain.blocks_scanned, 1);
        assert_eq!(plan.explain.rows_avoided, 2 * BLOCK_ROWS);
        assert_eq!(plan.explain.estimated_rows, 1808);

        // The off arm books the whole slice as a scan.
        let opts = PlanOptions { block_pruning: false, ..PlanOptions::default() };
        let plan = plan_query_opts(&ds, &index, &q, opts).unwrap();
        assert!(!plan.block_assist);
        assert_eq!(plan.explain.blocks_considered, 0);
        assert_eq!(plan.explain.estimated_rows, 2 * BLOCK_ROWS + 1808);
    }

    #[test]
    fn predicates_and_raw_row_ops_never_classify_covered() {
        let (_ctx, ds, index) = trending();
        // Full-span query: every partition is contained — all covered.
        let all = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0);
        let plan = plan_query(&ds, &index, &all, true).unwrap();
        assert_eq!(plan.explain.agg_answered, 4);
        assert_eq!(plan.explain.rows_avoided, 1000);

        // Any `where` clause forces the scan path (the sketch cannot
        // un-fold masked rows).
        let filtered = all.clone().filtered(vec![pred(1, PredOp::Ge, 0.0)]);
        let plan = plan_query(&ds, &index, &filtered, true).unwrap();
        assert_eq!(plan.explain.agg_answered, 0);
        assert_eq!(plan.explain.estimated_rows, 1000);

        // Trend needs the raw series; distance needs positional pairs.
        let trend = Query {
            ranges: vec![RangeQuery { lo: 0, hi: i64::MAX }],
            predicates: Vec::new(),
            op: QueryOp::Trend { column: 0, window: 4 },
        };
        assert_eq!(plan_query(&ds, &index, &trend, true).unwrap().explain.agg_answered, 0);
        let dist = Query {
            ranges: vec![RangeQuery { lo: 0, hi: 2_490 }],
            predicates: Vec::new(),
            op: QueryOp::Distance { column: 0, baseline: RangeQuery { lo: 2_500, hi: 4_990 } },
        };
        assert_eq!(plan_query(&ds, &index, &dist, true).unwrap().explain.agg_answered, 0);
    }

    #[test]
    fn zone_pruning_drops_partitions_key_targeting_cannot() {
        let (_ctx, ds, index) = trending();
        // Full key span, but only prices >= 750 exist in the last partition.
        let q = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
            .filtered(vec![pred(0, PredOp::Ge, 750.0)]);
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert_eq!(plan.explain.considered, 4);
        assert_eq!(plan.explain.key_pruned, 0);
        assert_eq!(plan.explain.zone_pruned, 3);
        assert_eq!(plan.explain.targeted, 1);
        assert_eq!(plan.ranges.len(), 1);
        assert_eq!(plan.ranges[0].slices.len(), 1);
        assert_eq!(plan.ranges[0].slices[0].partition, 3);

        // The oracle arm keeps everything.
        let unpruned = plan_query(&ds, &index, &q, false).unwrap();
        assert_eq!(unpruned.explain.zone_pruned, 0);
        assert_eq!(unpruned.explain.targeted, 4);

        // An unsatisfiable conjunction prunes every partition.
        let impossible = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
            .filtered(vec![pred(0, PredOp::Gt, 1e9)]);
        let plan = plan_query(&ds, &index, &impossible, true).unwrap();
        assert_eq!(plan.explain.targeted, 0);
        assert_eq!(plan.explain.zone_pruned, 4);
    }

    #[test]
    fn multi_range_merge_and_distance_baseline() {
        let (_ctx, ds, index) = trending();
        let q = Query {
            ranges: vec![
                RangeQuery { lo: 0, hi: 1_000 },
                RangeQuery { lo: 500, hi: 2_000 }, // overlaps → merges
            ],
            predicates: Vec::new(),
            op: QueryOp::Distance {
                column: 0,
                baseline: RangeQuery { lo: 7_500, hi: 9_500 },
            },
        };
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert_eq!(plan.explain.merged_ranges, 2, "primary merge + baseline");
        assert_eq!(plan.ranges.len(), 1);
        assert_eq!(plan.baseline.len(), 1);
        assert_eq!(plan.baseline[0].slices[0].partition, 3);
        assert_eq!(plan.explain.key_pruned, 2, "partitions 1 and 2 untouched");
    }

    #[test]
    fn plan_validates_inputs() {
        let (_ctx, ds, index) = trending();
        let bad_range = Query::stats(RangeQuery { lo: 9, hi: 1 }, 0);
        assert!(plan_query(&ds, &index, &bad_range, true).is_err());
        let bad_col = Query::stats(RangeQuery { lo: 0, hi: 1 }, 9);
        assert!(plan_query(&ds, &index, &bad_col, true).is_err());
        let bad_pred = Query::stats(RangeQuery { lo: 0, hi: 1 }, 0)
            .filtered(vec![pred(5, PredOp::Gt, 0.0)]);
        assert!(plan_query(&ds, &index, &bad_pred, true).is_err());
        let nan_pred = Query::stats(RangeQuery { lo: 0, hi: 1 }, 0)
            .filtered(vec![pred(0, PredOp::Gt, f32::NAN)]);
        assert!(plan_query(&ds, &index, &nan_pred, true).is_err());
        let zero_window = Query {
            ranges: vec![RangeQuery { lo: 0, hi: 1 }],
            predicates: Vec::new(),
            op: QueryOp::Trend { column: 0, window: 0 },
        };
        assert!(plan_query(&ds, &index, &zero_window, true).is_err());
    }

    #[test]
    fn explain_renders() {
        let (_ctx, ds, index) = trending();
        let q = Query::stats(RangeQuery { lo: 0, hi: 2_490 }, 0);
        let ex = plan_query(&ds, &index, &q, true).unwrap().explain;
        let line = ex.line();
        assert!(line.contains("4 partitions"), "{line}");
        assert!(line.contains("zone-pruned"), "{line}");
        assert!(line.contains("filter-pruned"), "{line}");
        let j = ex.to_json().to_string();
        assert!(j.contains("\"key_pruned\":3"), "{j}");
        assert!(j.contains("\"targeted\":1"), "{j}");
        assert!(j.contains("\"filter_pruned\":0"), "{j}");
        assert!(j.contains("\"filter_bytes\":"), "{j}");
        assert!(j.contains("\"blocks_considered\":0"), "{j}");
        assert!(j.contains("\"blocks_pruned\":0"), "{j}");
        assert!(j.contains("\"degraded\":0"), "{j}");
        assert!(!line.contains("DEGRADED"), "{line}");
        let mut degraded = ex;
        degraded.degraded = 2;
        assert!(degraded.line().contains("DEGRADED: 2"), "{}", degraded.line());
    }

    #[test]
    fn parse_predicates_accepts_conjunctions() {
        let s = Schema::climate();
        let ps = parse_predicates("temperature > 30, humidity <= 50", &s).unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0], pred(0, PredOp::Gt, 30.0));
        assert_eq!(ps[1], pred(1, PredOp::Le, 50.0));
        let ps = parse_predicates("wind_speed >= 1.5 and wind_dir < 180", &s).unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0], pred(2, PredOp::Ge, 1.5));
        assert_eq!(ps[1], pred(3, PredOp::Lt, 180.0));
        let ps = parse_predicates("temperature == 21.5 and humidity > 10", &s).unwrap();
        assert_eq!(ps.len(), 2);
        assert_eq!(ps[0], pred(0, PredOp::Eq, 21.5));
        assert_eq!(ps[1], pred(1, PredOp::Gt, 10.0));

        assert!(parse_predicates("", &s).is_err());
        // Bare `=` stays an error — only `==` is the equality operator.
        assert!(parse_predicates("temperature = 3", &s).is_err());
        assert!(parse_predicates("bogus > 3", &s).is_err());
        assert!(parse_predicates("temperature > banana", &s).is_err());
        assert!(parse_predicates("temperature > inf", &s).is_err());
    }

    #[test]
    fn query_builders() {
        let q = Query::stats(RangeQuery { lo: 1, hi: 2 }, 3)
            .filtered(vec![pred(0, PredOp::Lt, 1.0)]);
        assert_eq!(q.ranges.len(), 1);
        assert_eq!(q.predicates.len(), 1);
        assert_eq!(q.op.column(), 3);
        assert_eq!(QueryOp::Trend { column: 2, window: 5 }.column(), 2);
    }

    #[test]
    fn verify_accepts_every_lowering_shape() {
        let (_ctx, ds, index) = trending();
        let queries = [
            Query::stats(RangeQuery { lo: 0, hi: 2_490 }, 0),
            Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0),
            Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0)
                .filtered(vec![pred(0, PredOp::Ge, 750.0)]),
            Query {
                ranges: vec![RangeQuery { lo: 0, hi: 1_000 }, RangeQuery { lo: 5_000, hi: 6_000 }],
                predicates: Vec::new(),
                op: QueryOp::Trend { column: 0, window: 4 },
            },
            Query {
                ranges: vec![RangeQuery { lo: 0, hi: 2_490 }],
                predicates: Vec::new(),
                op: QueryOp::Distance {
                    column: 0,
                    baseline: RangeQuery { lo: 2_500, hi: 4_990 },
                },
            },
        ];
        for q in &queries {
            for (zp, ap) in [(true, true), (true, false), (false, true), (false, false)] {
                for (fp, bp) in
                    [(true, true), (true, false), (false, true), (false, false)]
                {
                    let opts = PlanOptions {
                        zone_pruning: zp,
                        filter_pruning: fp,
                        agg_pushdown: ap,
                        block_pruning: bp,
                    };
                    let plan = plan_query_opts(&ds, &index, q, opts).unwrap();
                    plan.verify(&ds, q).unwrap();
                }
            }
        }
    }

    #[test]
    fn verify_rejects_corrupted_plans() {
        let (_ctx, ds, index) = trending();
        // Two disjoint merged ranges, both sketch-covered.
        let q = Query {
            ranges: vec![RangeQuery { lo: 0, hi: 2_490 }, RangeQuery { lo: 5_000, hi: 7_490 }],
            predicates: Vec::new(),
            op: QueryOp::Stats { column: 0 },
        };
        let plan = plan_query(&ds, &index, &q, true).unwrap();
        assert_eq!(plan.ranges.len(), 2);
        plan.verify(&ds, &q).unwrap();

        let expect = |p: &PhysicalPlan, needle: &str| {
            let msg = p.verify(&ds, &q).unwrap_err().to_string();
            assert!(msg.contains("plan invariant"), "got: {msg}");
            assert!(msg.contains(needle), "wanted '{needle}' in: {msg}");
        };

        // Out-of-order merged ranges.
        let mut bad = plan.clone();
        bad.ranges.swap(0, 1);
        expect(&bad, "not sorted/disjoint");

        // Inverted range bounds.
        let mut bad = plan.clone();
        bad.ranges.truncate(1);
        bad.ranges[0].range = RangeQuery { lo: 10, hi: 0 };
        bad.explain.merged_ranges = 1;
        expect(&bad, "inverted");

        // An empty slice.
        let mut bad = plan.clone();
        bad.ranges[0].slices[0].row_end = bad.ranges[0].slices[0].row_start;
        expect(&bad, "is empty");

        // The same partition targeted twice in one range.
        let mut bad = plan.clone();
        let dup = bad.ranges[0].slices[0];
        bad.ranges[0].slices.push(dup);
        expect(&bad, "appears twice");

        // Covered set not sorted.
        let mut bad = plan.clone();
        bad.ranges[0].covered = vec![0, 0];
        expect(&bad, "not strictly sorted");

        // Covered partition without a slice.
        let mut bad = plan.clone();
        bad.ranges[0].covered = vec![3];
        expect(&bad, "no slice");

        // Covered partition whose keys spill outside the merged range.
        let mut bad = plan.clone();
        bad.ranges[0].range.hi = 100;
        expect(&bad, "not contained in merged range");

        // Explain arithmetic drift.
        let mut bad = plan.clone();
        bad.explain.targeted += 1;
        expect(&bad, "explain.targeted");
        let mut bad = plan.clone();
        bad.explain.estimated_bytes += 1;
        expect(&bad, "explain.estimated_bytes");
        let mut bad = plan.clone();
        bad.explain.key_pruned = bad.explain.partitions + 1;
        expect(&bad, "key_pruned");
    }

    #[test]
    fn verify_rejects_sketches_on_raw_row_ops() {
        let (_ctx, ds, index) = trending();
        let q = Query {
            ranges: vec![RangeQuery { lo: 0, hi: 2_490 }],
            predicates: Vec::new(),
            op: QueryOp::Trend { column: 0, window: 4 },
        };
        let mut plan = plan_query(&ds, &index, &q, true).unwrap();
        assert!(plan.ranges[0].covered.is_empty());
        plan.ranges[0].covered = vec![0];
        plan.explain.agg_answered = 1;
        let msg = plan.verify(&ds, &q).unwrap_err().to_string();
        assert!(msg.contains("cannot use sketches"), "got: {msg}");
    }

    /// Seeded fuzz harness: random datasets × random queries, every
    /// lowering must verify. A failure prints the reproducing seed.
    #[test]
    fn fuzzed_lowerings_always_verify() {
        use crate::util::rng::Xoshiro256;
        for seed in 0..48u64 {
            let mut rng = Xoshiro256::seeded(seed);
            // Random sorted-key dataset over the stock schema.
            let rows = rng.range_u64(50, 2_000) as usize;
            let mut b = BatchBuilder::new(Schema::stock());
            let mut key = 0i64;
            for _ in 0..rows {
                key += rng.range_u64(1, 20) as i64;
                b.push(key, &[rng.uniform(-100.0, 100.0) as f32, rng.next_f32()]);
            }
            let ctx =
                OsebaContext::new(ContextConfig { num_workers: 2, memory_budget: None });
            let parts = rng.range_u64(1, 9) as usize;
            let ds = ctx.load(b.finish().unwrap(), parts).unwrap();
            let index = Cias::build(ds.partitions()).unwrap();
            let span = key;

            for case in 0..8 {
                let mut ranges = Vec::new();
                for _ in 0..rng.range_u64(1, 4) {
                    let a = rng.range_u64(0, span as u64 + 1) as i64;
                    let bnd = rng.range_u64(0, span as u64 + 1) as i64;
                    ranges.push(RangeQuery { lo: a.min(bnd), hi: a.max(bnd) });
                }
                let mut predicates = Vec::new();
                for _ in 0..rng.below(3) {
                    let op = match rng.below(5) {
                        0 => PredOp::Gt,
                        1 => PredOp::Ge,
                        2 => PredOp::Lt,
                        3 => PredOp::Eq,
                        _ => PredOp::Le,
                    };
                    predicates.push(pred(
                        rng.below(2) as usize,
                        op,
                        rng.uniform(-120.0, 120.0) as f32,
                    ));
                }
                let op = match rng.below(3) {
                    0 => QueryOp::Stats { column: rng.below(2) as usize },
                    1 => QueryOp::Trend {
                        column: rng.below(2) as usize,
                        window: rng.range_u64(1, 12) as usize,
                    },
                    _ => {
                        let a = rng.range_u64(0, span as u64 + 1) as i64;
                        let bnd = rng.range_u64(0, span as u64 + 1) as i64;
                        QueryOp::Distance {
                            column: rng.below(2) as usize,
                            baseline: RangeQuery { lo: a.min(bnd), hi: a.max(bnd) },
                        }
                    }
                };
                let query = Query { ranges, predicates, op };
                let opts = PlanOptions {
                    zone_pruning: rng.below(2) == 0,
                    filter_pruning: rng.below(2) == 0,
                    agg_pushdown: rng.below(2) == 0,
                    block_pruning: rng.below(2) == 0,
                };
                let plan = plan_query_opts(&ds, &index, &query, opts)
                    .unwrap_or_else(|e| panic!("seed {seed} case {case}: plan failed: {e}"));
                plan.verify(&ds, &query).unwrap_or_else(|e| {
                    panic!("seed {seed} case {case}: verify failed: {e}\nquery: {query:?}")
                });
            }
        }
    }
}
