"""L1 kernel vs pure-jnp oracle — the core correctness signal.

Hypothesis sweeps randomize block contents, selection endpoints (including
empty / full / degenerate ranges), windows and histogram bounds; every case
asserts the pallas kernel matches kernels/ref.py.
"""

import numpy as np
import pytest

pytest.importorskip("jax")
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import (BLOCK_ROWS, HIST_BINS, distance, histogram64,
                             moving_average, segment_stats)
from compile.kernels import ref

# Small block size keeps interpret-mode pallas fast; the kernels are
# shape-polymorphic via the block_rows kwarg so correctness at 128 implies
# correctness at 4096 (same graph, different static dim).
N = 128

floats = st.floats(min_value=-1e4, max_value=1e4, allow_nan=False,
                   width=32)


def block(draw, n=N):
    data = draw(st.lists(floats, min_size=n, max_size=n))
    return np.asarray(data, np.float32)


ranges = st.tuples(st.integers(0, N), st.integers(0, N))


@st.composite
def block_and_range(draw):
    x = block(draw)
    s, e = draw(ranges)
    return x, s, e


@st.composite
def two_blocks_and_range(draw):
    a = block(draw)
    b = block(draw)
    s, e = draw(ranges)
    return a, b, s, e


class TestSegmentStats:
    @settings(max_examples=40, deadline=None)
    @given(block_and_range())
    def test_matches_ref(self, case):
        x, s, e = case
        got = segment_stats(x, s, e, block_rows=N)
        want = ref.segment_stats_ref(x, s, e)
        for g, w, name in zip(got, want, ["max", "min", "sum", "sumsq", "count"]):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-3,
                                       err_msg=name)

    def test_full_range(self):
        x = np.arange(N, dtype=np.float32)
        mx, mn, s, ss, n = segment_stats(x, 0, N, block_rows=N)
        assert mx == N - 1 and mn == 0 and n == N
        np.testing.assert_allclose(s, x.sum())

    def test_empty_range_is_identity(self):
        x = np.ones(N, np.float32)
        mx, mn, s, ss, n = segment_stats(x, 10, 10, block_rows=N)
        assert n == 0 and s == 0 and ss == 0
        assert mx < -1e38 and mn > 1e38

    def test_single_element(self):
        x = np.zeros(N, np.float32)
        x[7] = -42.5
        mx, mn, s, ss, n = segment_stats(x, 7, 8, block_rows=N)
        assert mx == -42.5 and mn == -42.5 and n == 1
        np.testing.assert_allclose(ss, 42.5 * 42.5)

    def test_inverted_range_counts_zero(self):
        x = np.ones(N, np.float32)
        *_, n = segment_stats(x, 100, 4, block_rows=N)
        assert n == 0

    def test_mean_std_finalization(self):
        rng = np.random.default_rng(0)
        x = rng.normal(20.0, 5.0, N).astype(np.float32)
        mx, mn, s, ss, n = segment_stats(x, 16, 112, block_rows=N)
        mean = float(s) / float(n)
        var = float(ss) / float(n) - mean * mean
        sel = x[16:112]
        np.testing.assert_allclose(mean, sel.mean(), rtol=1e-5)
        np.testing.assert_allclose(np.sqrt(max(var, 0.0)), sel.std(),
                                   rtol=1e-4)


class TestMovingAverage:
    @settings(max_examples=25, deadline=None)
    @given(block_and_range(), st.sampled_from([4, 16, 64]))
    def test_matches_ref(self, case, w):
        x, s, e = case
        got = moving_average(x, s, e, window=w, block_rows=N)
        want = ref.moving_average_ref(x, s, e, w)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-3)

    def test_constant_series(self):
        x = np.full(N, 3.0, np.float32)
        got = np.asarray(moving_average(x, 0, N, window=4, block_rows=N))
        np.testing.assert_allclose(got[3:], 3.0, rtol=1e-6)
        np.testing.assert_allclose(got[:3], 0.0)

    def test_window_larger_than_selection_all_zero(self):
        x = np.ones(N, np.float32)
        got = np.asarray(moving_average(x, 10, 12, window=16, block_rows=N))
        np.testing.assert_allclose(got, 0.0)

    def test_linear_ramp(self):
        x = np.arange(N, dtype=np.float32)
        got = np.asarray(moving_average(x, 0, N, window=4, block_rows=N))
        # MA of ramp at i = i - 1.5
        idx = np.arange(3, N)
        np.testing.assert_allclose(got[3:], idx - 1.5, rtol=1e-6)


class TestDistance:
    @settings(max_examples=40, deadline=None)
    @given(two_blocks_and_range())
    def test_matches_ref(self, case):
        a, b, s, e = case
        got = distance(a, b, s, e, block_rows=N)
        want = ref.distance_ref(a, b, s, e)
        for g, w, name in zip(got, want, ["l1", "l2sq", "linf", "count"]):
            np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-2,
                                       err_msg=name)

    def test_identical_series_zero_distance(self):
        a = np.linspace(0, 50, N).astype(np.float32)
        l1, l2sq, linf, n = distance(a, a.copy(), 0, N, block_rows=N)
        assert l1 == 0 and l2sq == 0 and linf == 0 and n == N

    def test_unit_offset(self):
        a = np.zeros(N, np.float32)
        b = np.ones(N, np.float32)
        l1, l2sq, linf, n = distance(a, b, 32, 96, block_rows=N)
        assert l1 == 64 and l2sq == 64 and linf == 1 and n == 64


class TestHistogram:
    @settings(max_examples=30, deadline=None)
    @given(block_and_range(),
           st.floats(-100, 0, width=32), st.floats(1, 100, width=32))
    def test_matches_ref(self, case, lo, hi):
        x, s, e = case
        got = histogram64(x, s, e, lo, hi, block_rows=N)
        want = ref.histogram64_ref(x, s, e, lo, hi)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_total_mass_equals_selection(self):
        rng = np.random.default_rng(1)
        x = rng.uniform(-5, 5, N).astype(np.float32)
        got = np.asarray(histogram64(x, 20, 110, -5.0, 5.0, block_rows=N))
        assert got.sum() == 90

    def test_out_of_range_clamps_to_edges(self):
        x = np.concatenate([np.full(N // 2, -1e6, np.float32),
                            np.full(N - N // 2, 1e6, np.float32)])
        got = np.asarray(histogram64(x, 0, N, 0.0, 1.0, block_rows=N))
        assert got[0] == N // 2 and got[HIST_BINS - 1] == N - N // 2
        assert got[1:-1].sum() == 0

    def test_uniform_fill(self):
        # One value per bin center → exactly one count per bin.
        centers = (np.arange(HIST_BINS, dtype=np.float32) + 0.5) / HIST_BINS
        x = np.concatenate([centers,
                            np.zeros(N - HIST_BINS, np.float32)])
        got = np.asarray(histogram64(x, 0, HIST_BINS, 0.0, 1.0, block_rows=N))
        np.testing.assert_array_equal(got, np.ones(HIST_BINS))
