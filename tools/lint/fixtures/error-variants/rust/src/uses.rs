//! Constructs only one of the two variants.

pub fn g() -> OsebaError {
    OsebaError::Used(String::from("x"))
}
