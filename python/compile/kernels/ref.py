"""Pure-jnp correctness oracles for every L1 kernel.

These are the ground truth the pallas kernels (and, transitively, the HLO
artifacts the rust runtime executes) are validated against in
``python/tests/``. They intentionally use the most direct jnp formulation —
no pallas, no cumsum tricks — so a bug in a kernel's optimization cannot
also hide in its oracle.
"""

import jax
import jax.numpy as jnp

NEG_INF = jnp.float32(-3.4e38)
POS_INF = jnp.float32(3.4e38)
HIST_BINS = 64


def _mask(x, start, end):
    idx = jnp.arange(x.shape[0], dtype=jnp.int32)
    return (idx >= start) & (idx < end)


def segment_stats_ref(x, start, end):
    """(max, min, sum, sumsq, count) over x[start:end], identity-padded."""
    m = _mask(x, start, end)
    mf = m.astype(jnp.float32)
    return (
        jnp.max(jnp.where(m, x, NEG_INF)),
        jnp.min(jnp.where(m, x, POS_INF)),
        jnp.sum(x * mf),
        jnp.sum(x * x * mf),
        jnp.sum(mf),
    )


def moving_average_ref(x, start, end, window):
    """Trailing MA; row i valid iff [i-window+1, i] ⊆ [start, end)."""
    n = x.shape[0]
    idx = jnp.arange(n, dtype=jnp.int32)
    sel = (idx >= start) & (idx < end)
    xm = x * sel.astype(jnp.float32)

    def at(i):
        # mean of xm[i-window+1 : i+1] via explicit dot with a window mask
        w = (idx > i - window) & (idx <= i)
        return jnp.sum(xm * w.astype(jnp.float32)) / jnp.float32(window)

    vals = jax.vmap(at)(idx)
    valid = (idx >= start + window - 1) & (idx < end)
    return jnp.where(valid, vals, 0.0)


def distance_ref(a, b, start, end):
    """(l1, l2sq, linf, count) over rows [start, end)."""
    m = _mask(a, start, end)
    mf = m.astype(jnp.float32)
    d = (a - b) * mf
    ad = jnp.abs(d)
    return jnp.sum(ad), jnp.sum(d * d), jnp.max(ad), jnp.sum(mf)


def histogram64_ref(x, start, end, lo, hi):
    """64 equal-width bins over [lo, hi); out-of-range clamps to edge bins."""
    m = _mask(x, start, end)
    width = (hi - lo) / HIST_BINS
    bin_id = jnp.clip(((x - lo) / width).astype(jnp.int32), 0, HIST_BINS - 1)
    onehot = bin_id[:, None] == jnp.arange(HIST_BINS, dtype=jnp.int32)[None, :]
    return jnp.sum(onehot.astype(jnp.float32) * m.astype(jnp.float32)[:, None],
                   axis=0)


# --- final-statistics helpers (mirror the rust-side merge math) -----------

def finalize_stats(mx, mn, s, ss, n):
    """(max, min, mean, stddev_pop) from raw moments."""
    mean = s / n
    var = jnp.maximum(ss / n - mean * mean, 0.0)
    return mx, mn, mean, jnp.sqrt(var)
