//! Call-detail-record generator for the events-analysis example
//! (paper §II: "fraud can be detected by comparing the distributions of
//! typical phone calls and of calls made from a stolen phone").
//!
//! Each row is one call aggregated onto a regular per-second key grid:
//! `duration` (seconds), `dest_prefix` (coarse destination bucket, 0-99),
//! `hour_of_day` (0-23 as f32). A configurable *fraud window* switches the
//! behavioural distribution: long international calls at odd hours — the
//! distribution shift the histogram kernel must expose.

use crate::storage::{BatchBuilder, RecordBatch, Schema};
use crate::util::rng::Xoshiro256;

/// Configurable CDR generator.
#[derive(Clone, Debug)]
pub struct CdrGen {
    /// RNG seed (deterministic output per seed).
    pub seed: u64,
    /// First key (seconds).
    pub start_key: i64,
    /// Key step (seconds) — one aggregated call record per step.
    pub step_secs: i64,
    /// Optional fraud window `[lo, hi)` in *row index* space.
    pub fraud_rows: Option<(usize, usize)>,
}

impl Default for CdrGen {
    fn default() -> Self {
        CdrGen { seed: 0xCD12, start_key: 0, step_secs: 30, fraud_rows: None }
    }
}

impl CdrGen {
    /// Generate `rows` call records.
    pub fn generate(&self, rows: usize) -> RecordBatch {
        let mut rng = Xoshiro256::seeded(self.seed);
        let mut b = BatchBuilder::with_capacity(Schema::cdr(), rows);
        for i in 0..rows {
            let key = self.start_key + i as i64 * self.step_secs;
            let fraud = self.fraud_rows.is_some_and(|(lo, hi)| i >= lo && i < hi);
            let hour = ((key / 3600) % 24) as f64;
            let (duration, prefix) = if fraud {
                // Stolen phone: long calls, international prefixes.
                (rng.exponential(1.0 / 600.0).min(7200.0), rng.uniform(80.0, 100.0))
            } else {
                // Typical usage: short calls, domestic prefixes, day-skewed.
                let daytime = (6.0..22.0).contains(&hour);
                let mean = if daytime { 180.0 } else { 60.0 };
                (rng.exponential(1.0 / mean).min(3600.0), rng.uniform(0.0, 40.0))
            };
            b.push(key, &[duration as f32, prefix as f32, hour as f32]);
        }
        b.finish().expect("sorted keys by construction")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g = CdrGen::default();
        assert_eq!(g.generate(64).columns[0], g.generate(64).columns[0]);
    }

    #[test]
    fn fraud_window_shifts_distribution() {
        let g = CdrGen { fraud_rows: Some((1000, 2000)), ..Default::default() };
        let rb = g.generate(3000);
        let dur = rb.column("duration").unwrap();
        let mean = |s: &[f32]| s.iter().map(|&x| x as f64).sum::<f64>() / s.len() as f64;
        let normal = mean(&dur[..1000]);
        let fraud = mean(&dur[1000..2000]);
        assert!(fraud > 2.0 * normal, "fraud={fraud} normal={normal}");
        let pre = rb.column("dest_prefix").unwrap();
        assert!(pre[1000..2000].iter().all(|&p| p >= 80.0));
        assert!(pre[..1000].iter().all(|&p| p < 40.0));
    }

    #[test]
    fn durations_nonnegative_and_capped() {
        let rb = CdrGen::default().generate(5000);
        assert!(rb.column("duration").unwrap().iter().all(|&d| (0.0..=3600.0).contains(&d)));
    }

    #[test]
    fn hour_of_day_in_range() {
        let rb = CdrGen::default().generate(5000);
        assert!(rb.column("hour_of_day").unwrap().iter().all(|&h| (0.0..24.0).contains(&h)));
    }
}
