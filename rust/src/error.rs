//! Crate-wide error type.
//!
//! Every public fallible API in the crate returns [`Result`]. Variants are
//! grouped by subsystem so callers can match on the failure domain (e.g. a
//! server can map `Query*` errors to client-visible messages while treating
//! `Runtime`/`Io` as internal).
//!
//! `Display`/`Error` are implemented by hand: the vendored dependency set
//! has no `thiserror` (see DESIGN.md §4).

use std::fmt;
use std::path::{Path, PathBuf};

/// Errors produced by the Oseba engine, indexes, runtime and coordinator.
#[derive(Debug)]
pub enum OsebaError {
    /// Dataset construction / schema violations.
    Schema(String),

    /// A query referenced a column that does not exist.
    UnknownColumn(String),

    /// A range query that cannot be satisfied (e.g. inverted bounds).
    InvalidRange(String),

    /// Index construction failed (unsorted keys, empty dataset, ...).
    Index(String),

    /// The PJRT runtime failed to load/compile/execute an artifact.
    Runtime(String),

    /// An artifact or its manifest is missing or malformed.
    Artifact(String),

    /// Cluster/scheduler failures (worker death without reassignment, ...).
    Cluster(String),

    /// Configuration parse/validation failures.
    Config(String),

    /// JSON parse errors (manifest, server protocol).
    Json(String),

    /// On-disk store corruption: bad magic/version, CRC mismatch, or a
    /// manifest that disagrees with its segments. The message names the
    /// offending file.
    Store(String),

    /// Ingestion-pipeline misuse or ordering violations: pushing into a
    /// finished [`crate::ingest::Ingestor`], appending to a closed live
    /// dataset, or an out-of-order chunk that overlaps existing data.
    Ingest(String),

    /// A lowered physical plan violated a structural invariant (disjoint
    /// merged ranges, covered ⊆ targeted, demux segments tiling, ...).
    /// Always a planner bug, never bad user input — surfaced as a typed
    /// error so a release server degrades to a failed request instead of
    /// dying. Checked on every plan in debug builds.
    Plan(String),

    /// Memory budget exhausted and eviction could not reclaim enough.
    OutOfMemory {
        /// Bytes the failing allocation asked for.
        requested: usize,
        /// The configured storage budget in bytes.
        budget: usize,
    },

    /// Underlying I/O failure. `path` names the offending file when known
    /// (empty for pathless sources such as sockets).
    Io { path: PathBuf, source: std::io::Error },
}

impl OsebaError {
    /// An I/O error naming the file it occurred on.
    pub fn io(path: impl AsRef<Path>, source: std::io::Error) -> OsebaError {
        OsebaError::Io { path: path.as_ref().to_path_buf(), source }
    }
}

impl fmt::Display for OsebaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            OsebaError::Schema(m) => write!(f, "schema error: {m}"),
            OsebaError::UnknownColumn(m) => write!(f, "unknown column: {m}"),
            OsebaError::InvalidRange(m) => write!(f, "invalid range: {m}"),
            OsebaError::Index(m) => write!(f, "index error: {m}"),
            OsebaError::Runtime(m) => write!(f, "runtime error: {m}"),
            OsebaError::Artifact(m) => write!(f, "artifact error: {m}"),
            OsebaError::Cluster(m) => write!(f, "cluster error: {m}"),
            OsebaError::Config(m) => write!(f, "config error: {m}"),
            OsebaError::Json(m) => write!(f, "json error: {m}"),
            OsebaError::Store(m) => write!(f, "store error: {m}"),
            OsebaError::Ingest(m) => write!(f, "ingest error: {m}"),
            OsebaError::Plan(m) => write!(f, "plan invariant violated: {m}"),
            OsebaError::OutOfMemory { requested, budget } => write!(
                f,
                "out of storage memory: requested {requested} bytes, budget {budget}"
            ),
            OsebaError::Io { path, source } => {
                if path.as_os_str().is_empty() {
                    write!(f, "io error: {source}")
                } else {
                    write!(f, "io error on '{}': {source}", path.display())
                }
            }
        }
    }
}

impl std::error::Error for OsebaError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            OsebaError::Io { source, .. } => Some(source),
            _ => None,
        }
    }
}

impl From<std::io::Error> for OsebaError {
    fn from(e: std::io::Error) -> Self {
        OsebaError::Io { path: PathBuf::new(), source: e }
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, OsebaError>;

#[cfg(feature = "xla")]
impl From<xla::Error> for OsebaError {
    fn from(e: xla::Error) -> Self {
        OsebaError::Runtime(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_domain() {
        let e = OsebaError::UnknownColumn("wind".into());
        assert!(e.to_string().contains("unknown column"));
        let e = OsebaError::OutOfMemory { requested: 10, budget: 5 };
        assert!(e.to_string().contains("requested 10"));
        let e = OsebaError::Ingest("push after finish".into());
        assert!(e.to_string().contains("ingest error"));
        let e = OsebaError::Plan("ranges overlap".into());
        assert!(e.to_string().contains("plan invariant"));
    }

    #[test]
    fn io_error_converts() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OsebaError = io.into();
        assert!(matches!(e, OsebaError::Io { .. }));
    }

    #[test]
    fn io_source_is_preserved() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: OsebaError = io.into();
        let src = std::error::Error::source(&e).expect("io source");
        assert!(src.to_string().contains("gone"));
    }

    #[test]
    fn io_error_names_the_path() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e = OsebaError::io("/data/climate.csv", io);
        let msg = e.to_string();
        assert!(msg.contains("/data/climate.csv"), "got: {msg}");
        assert!(msg.contains("gone"));
        // Pathless conversions stay terse.
        let io = std::io::Error::new(std::io::ErrorKind::Other, "sock");
        let e: OsebaError = io.into();
        assert!(!e.to_string().contains("''"), "got: {e}");
    }
}
