//! Server anchor: surfaces `partitions_scanned`, `epoch`, `op_info` and
//! `phase_targeting` but neither `ghost_counter` nor `op_ghost`.

pub fn info() -> String {
    let mut s = String::from("partitions_scanned");
    s.push_str("epoch");
    s.push_str("op_info");
    s.push_str("phase_targeting");
    s
}
