//! `Dataset` — the RDD analogue: an immutable, partitioned, memory-resident
//! collection with lineage.
//!
//! Transformations are *eager* and, matching the paper's observation about
//! Spark's defaults ("after each phase, more RDDs are created and they are
//! resident in memory by default", §IV-A), every transformation result is
//! registered with the block manager until explicitly unpersisted. This is
//! precisely the cost model the Fig 4 baseline measures.

use std::sync::Arc;

use crate::engine::block_manager::DatasetId;
use crate::index::types::PartitionSlice;
use crate::storage::{Partition, Schema};
use crate::store::TieredStore;

/// How a dataset came to exist — the lineage record (paper Fig 2's
/// dataflow; inspectable via `OsebaContext::lineage`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lineage {
    /// Loaded from a generator / external source.
    Source { name: String },
    /// Produced by a transformation of `parent`.
    Derived { parent: DatasetId, op: String },
}

/// An immutable partitioned dataset handle.
///
/// Cloning is cheap (`Arc`'d partitions). Dropping the handle does *not*
/// free the cached blocks — like Spark, residency is controlled by
/// `unpersist`, not scope.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub(crate) id: DatasetId,
    pub(crate) schema: Schema,
    pub(crate) parts: Vec<Arc<Partition>>,
    pub(crate) lineage: Lineage,
    /// Tiered residency backing, when the dataset lives in a
    /// [`TieredStore`] instead of being fully memory-resident. `parts` is
    /// empty then; access goes through the store (fault-in on demand).
    pub(crate) store: Option<Arc<TieredStore>>,
    /// Visible-partition cap for store-backed **live snapshots**: the
    /// backing store may keep growing after this snapshot was taken, but
    /// every accessor (and the scan baseline) must see only the first
    /// `visible` partitions — the epoch the snapshot pinned. `None` means
    /// the whole store is visible (ordinary tiered datasets).
    pub(crate) visible: Option<usize>,
}

impl Dataset {
    /// Unique id within its context.
    pub fn id(&self) -> DatasetId {
        self.id
    }

    /// The dataset's column schema.
    pub fn schema(&self) -> &Schema {
        &self.schema
    }

    /// The memory-resident partitions. Empty for a tiered dataset — use
    /// [`crate::engine::OsebaContext::resolve_slices`] /
    /// [`crate::engine::OsebaContext::partition_handles`], which fault
    /// partitions in as needed.
    pub fn partitions(&self) -> &[Arc<Partition>] {
        &self.parts
    }

    /// The tiered backing store, if any.
    pub fn store(&self) -> Option<&Arc<TieredStore>> {
        self.store.as_ref()
    }

    /// Whether this dataset is backed by a tiered store.
    pub fn is_tiered(&self) -> bool {
        self.store.is_some()
    }

    /// Metadata of the partitions this handle may see: the store's
    /// metadata truncated to the snapshot's visible prefix. Store-backed
    /// datasets only.
    fn visible_metas(&self, st: &TieredStore) -> Vec<crate::index::PartitionMeta> {
        let mut metas = st.metas();
        if let Some(n) = self.visible {
            metas.truncate(n);
        }
        metas
    }

    /// Number of partitions visible to this handle.
    pub fn num_partitions(&self) -> usize {
        match &self.store {
            Some(st) => {
                let n = st.num_partitions();
                self.visible.map_or(n, |v| v.min(n))
            }
            None => self.parts.len(),
        }
    }

    /// Total valid rows across visible partitions.
    pub fn total_rows(&self) -> usize {
        match &self.store {
            Some(st) => match self.visible {
                Some(_) => self.visible_metas(st).iter().map(|m| m.rows).sum(),
                None => st.total_rows(),
            },
            None => self.parts.iter().map(|p| p.rows).sum(),
        }
    }

    /// Byte footprint (keys + padded columns) of the visible dataset —
    /// resident bytes for an in-memory dataset, total (Hot + Cold) for a
    /// tiered one.
    pub fn bytes(&self) -> usize {
        match &self.store {
            Some(st) => match self.visible {
                Some(_) => {
                    let width = self.schema.width();
                    self.visible_metas(st)
                        .iter()
                        .map(|m| crate::store::tiered::partition_bytes(m.rows, width))
                        .sum()
                }
                None => st.total_bytes(),
            },
            None => self.parts.iter().map(|p| p.bytes()).sum(),
        }
    }

    /// How this dataset came to exist.
    pub fn lineage(&self) -> &Lineage {
        &self.lineage
    }

    /// Smallest key in the visible dataset.
    pub fn key_min(&self) -> Option<i64> {
        match &self.store {
            Some(st) => match self.visible {
                Some(_) => self.visible_metas(st).first().map(|m| m.key_min),
                None => st.key_min(),
            },
            None => self.parts.iter().filter_map(|p| p.key_min()).min(),
        }
    }

    /// Largest key in the visible dataset.
    pub fn key_max(&self) -> Option<i64> {
        match &self.store {
            Some(st) => match self.visible {
                Some(_) => self.visible_metas(st).last().map(|m| m.key_max),
                None => st.key_max(),
            },
            None => self.parts.iter().filter_map(|p| p.key_max()).max(),
        }
    }

    /// Whether `partition` is outside this handle's visible prefix (live
    /// snapshots over a shared, still-growing store).
    fn hidden(&self, partition: usize) -> bool {
        matches!(self.visible, Some(v) if partition >= v)
    }

    /// Per-column zone maps of partition `partition` — pure metadata on
    /// every backing (resident partitions carry them; a tiered store keeps
    /// them in its slot table, so **no fault-in happens here**). `None`
    /// for an id outside the visible dataset. This is what the query
    /// planner consults for value-predicate pruning.
    pub fn zone_maps(&self, partition: usize) -> Option<Vec<crate::index::ZoneMap>> {
        if self.hidden(partition) {
            return None;
        }
        match &self.store {
            Some(st) => st.zone_maps(partition),
            None => self.parts.get(partition).map(|p| p.zone_maps()),
        }
    }

    /// The aggregate sketch of one value column of one partition — pure
    /// metadata, like [`Self::zone_maps`]: resident partitions carry
    /// sketches from seal time, a tiered store keeps them in its slot
    /// table (they survive eviction), so **no fault-in happens here**.
    /// `None` for an id outside the visible dataset, an out-of-range
    /// column, or a store opened from a pre-v3 manifest (whose partitions
    /// then always scan — the conservative sentinel).
    pub fn sketch(&self, partition: usize, column: usize) -> Option<crate::index::ColumnSketch> {
        if self.hidden(partition) {
            return None;
        }
        match &self.store {
            Some(st) => st.sketch(partition, column),
            None => self.parts.get(partition).and_then(|p| p.sketches.get(column).copied()),
        }
    }

    /// The per-column membership filters of one partition — pure
    /// metadata, like [`Self::sketch`]: resident partitions carry filters
    /// from seal time, a tiered store keeps them in its slot table (they
    /// survive eviction), so **no fault-in happens here** — an equality
    /// probe can rule a Cold partition out before any segment read.
    /// `None` for an id outside the visible dataset or a store opened
    /// from a pre-v4 manifest (no filter → the planner always considers
    /// the partition).
    pub fn filters(
        &self,
        partition: usize,
    ) -> Option<Arc<Vec<crate::index::MembershipFilter>>> {
        if self.hidden(partition) {
            return None;
        }
        match &self.store {
            Some(st) => st.filters(partition),
            None => self.parts.get(partition).map(|p| Arc::clone(&p.filters)),
        }
    }

    /// The per-block sketch hierarchy of one partition — pure metadata,
    /// like [`Self::sketch`]: resident partitions carry block sketches
    /// from seal time, a tiered store keeps them in its slot table (they
    /// survive eviction), so **no fault-in happens here** — the planner
    /// classifies a Cold partition's blocks before any segment read.
    /// `None` for an id outside the visible dataset or a store opened
    /// from a pre-v5 manifest (no hierarchy → every block scans).
    pub fn block_sketches(
        &self,
        partition: usize,
    ) -> Option<Arc<crate::index::BlockSketches>> {
        if self.hidden(partition) {
            return None;
        }
        match &self.store {
            Some(st) => st.block_sketches(partition),
            None => self.parts.get(partition).map(|p| Arc::clone(&p.block_sketches)),
        }
    }

    /// Total resident footprint of the membership filters across visible
    /// partitions, in bytes — the metadata cost `explain`/`info` surface
    /// as `filter_bytes`.
    pub fn filter_bytes(&self) -> usize {
        (0..self.num_partitions())
            .filter_map(|i| self.filters(i))
            .map(|fs| {
                fs.iter().map(crate::index::MembershipFilter::memory_bytes).sum::<usize>()
            })
            .sum()
    }

    /// Whether `partition` is quarantined in the tiered backing (its
    /// segment failed verification after retries, DESIGN.md §16). Always
    /// `false` for resident datasets and hidden partitions — both can
    /// never serve corrupt bytes.
    pub fn quarantined(&self, partition: usize) -> bool {
        if self.hidden(partition) {
            return false;
        }
        match &self.store {
            Some(st) => st.is_quarantined(partition),
            None => false,
        }
    }

    /// Whether the tiered backing demands strict fault handling: `true`
    /// makes a query over a quarantined partition a hard error instead of
    /// a degraded answer. Resident datasets have nothing to degrade over;
    /// they report `false`.
    pub fn strict_faults(&self) -> bool {
        self.store.as_ref().map(|st| st.strict()).unwrap_or(false)
    }

    /// Key bounds and row count of one visible partition —
    /// `(key_min, key_max, rows)`, O(1) metadata on every backing (no
    /// fault-in). This is what the planner's covered/edge classification
    /// consults: a merged range containing `[key_min, key_max]` covers
    /// every row of the partition.
    pub fn partition_bounds(&self, partition: usize) -> Option<(i64, i64, usize)> {
        if self.hidden(partition) {
            return None;
        }
        match &self.store {
            Some(st) => st.meta(partition).map(|m| (m.key_min, m.key_max, m.rows)),
            None => {
                let p = self.parts.get(partition)?;
                Some((p.key_min()?, p.key_max()?, p.rows))
            }
        }
    }

    /// Resolve a [`PartitionSlice`] into the backing partition plus the
    /// slice bounds — the zero-copy access path Oseba uses instead of
    /// materializing a filtered dataset. Resident datasets only; tiered
    /// access goes through the context's resolve/select APIs.
    pub fn slice_view(&self, s: &PartitionSlice) -> SliceView<'_> {
        debug_assert!(self.store.is_none(), "slice_view needs a resident dataset");
        let part = &self.parts[s.partition];
        debug_assert!(s.row_end <= part.rows);
        SliceView { part, row_start: s.row_start, row_end: s.row_end }
    }
}

/// A borrowed view of a row range of one partition.
#[derive(Clone, Copy, Debug)]
pub struct SliceView<'a> {
    /// The partition the view reads.
    pub part: &'a Arc<Partition>,
    /// First valid row of the view (inclusive).
    pub row_start: usize,
    /// One past the last valid row of the view.
    pub row_end: usize,
}

impl<'a> SliceView<'a> {
    /// Number of rows the view covers.
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }

    /// The valid keys of this view.
    pub fn keys(&self) -> &'a [i64] {
        &self.part.keys[self.row_start..self.row_end]
    }

    /// A value-column slice of this view.
    pub fn column(&self, col: usize) -> &'a [f32] {
        &self.part.columns[col][self.row_start..self.row_end]
    }
}

/// An *owned* targeted region of one partition: the `Arc` pins the
/// partition in memory for as long as the handle lives, so the selection
/// stays valid even if the tiered store evicts that partition afterwards.
#[derive(Clone, Debug)]
pub struct PinnedSlice {
    /// The pinned partition (kept alive by this handle).
    pub part: Arc<Partition>,
    /// First valid row of the selection (inclusive).
    pub row_start: usize,
    /// One past the last valid row of the selection.
    pub row_end: usize,
}

impl PinnedSlice {
    /// Number of rows the pin covers.
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }

    /// Borrow this pin as a [`SliceView`] (the analysis operators' input).
    pub fn view(&self) -> SliceView<'_> {
        SliceView { part: &self.part, row_start: self.row_start, row_end: self.row_end }
    }
}

/// The result of a selective lookup: pinned slices over the targeted
/// partitions — resident ones borrowed for free, cold ones faulted in by
/// the store. Dereferences to `[PinnedSlice]`.
#[derive(Clone, Debug, Default)]
pub struct PinnedSlices(pub Vec<PinnedSlice>);

impl PinnedSlices {
    /// Total selected rows across all slices.
    pub fn rows(&self) -> usize {
        self.0.iter().map(|p| p.rows()).sum()
    }

    /// Borrowed views over every pin, in order — pass to the analyzers.
    pub fn views(&self) -> Vec<SliceView<'_>> {
        self.0.iter().map(|p| p.view()).collect()
    }
}

impl std::ops::Deref for PinnedSlices {
    type Target = [PinnedSlice];

    fn deref(&self) -> &[PinnedSlice] {
        &self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::{partition_batch_uniform, BatchBuilder};

    fn ds() -> Dataset {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..100 {
            b.push(i as i64 * 2, &[i as f32, 1.0]);
        }
        let parts = partition_batch_uniform(&b.finish().unwrap(), 30).unwrap();
        Dataset {
            id: 1,
            schema: Schema::stock(),
            parts,
            lineage: Lineage::Source { name: "test".into() },
            store: None,
            visible: None,
        }
    }

    #[test]
    fn totals() {
        let d = ds();
        assert_eq!(d.num_partitions(), 4);
        assert_eq!(d.total_rows(), 100);
        assert_eq!(d.key_min(), Some(0));
        assert_eq!(d.key_max(), Some(198));
    }

    #[test]
    fn slice_view_reads_expected_rows() {
        let d = ds();
        let s = PartitionSlice { partition: 1, row_start: 5, row_end: 10 };
        let v = d.slice_view(&s);
        assert_eq!(v.rows(), 5);
        // Partition 1 holds rows 30..60 → global rows 35..40.
        assert_eq!(v.keys(), &[70, 72, 74, 76, 78]);
        assert_eq!(v.column(0), &[35.0, 36.0, 37.0, 38.0, 39.0]);
    }

    #[test]
    fn lineage_is_recorded() {
        let d = ds();
        assert_eq!(d.lineage(), &Lineage::Source { name: "test".into() });
    }
}
