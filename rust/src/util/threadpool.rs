//! Fixed-size thread pool with a shared injector queue (no `tokio`/`rayon`
//! in the vendored set).
//!
//! Used by the simulated cluster's workers and the interactive server. Jobs
//! are boxed closures; `scope_execute` provides the common "run N tasks,
//! wait for all" pattern with panic propagation, which is what the
//! coordinator's stage execution needs.

use crate::util::sync::recover;
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

struct Shared {
    queue: Mutex<VecDeque<Job>>,
    available: Condvar,
    shutdown: AtomicBool,
    in_flight: AtomicUsize,
    idle: Condvar,
    idle_lock: Mutex<()>,
}

/// A fixed-size pool of worker threads.
pub struct ThreadPool {
    shared: Arc<Shared>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl ThreadPool {
    /// Spawn `size` worker threads (`size >= 1` enforced).
    pub fn new(size: usize) -> ThreadPool {
        let size = size.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(VecDeque::new()),
            available: Condvar::new(),
            shutdown: AtomicBool::new(false),
            in_flight: AtomicUsize::new(0),
            idle: Condvar::new(),
            idle_lock: Mutex::new(()),
        });
        let handles = (0..size)
            .map(|i| {
                let sh = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("oseba-worker-{i}"))
                    .spawn(move || worker_loop(sh))
                    // No caller can make progress without workers.
                    // lint: allow(no-unwrap) -- spawn fails only on OS thread exhaustion
                    .expect("spawn worker thread")
            })
            .collect();
        ThreadPool { shared, handles, size }
    }

    /// Number of worker threads.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Enqueue a job; it runs on some worker thread.
    pub fn execute<F: FnOnce() + Send + 'static>(&self, job: F) {
        self.shared.in_flight.fetch_add(1, Ordering::SeqCst);
        recover(self.shared.queue.lock()).push_back(Box::new(job));
        self.shared.available.notify_one();
    }

    /// Block until every queued job has completed.
    pub fn wait_idle(&self) {
        let mut guard = recover(self.shared.idle_lock.lock());
        while self.shared.in_flight.load(Ordering::SeqCst) != 0 {
            guard = recover(self.shared.idle.wait(guard));
        }
    }

    /// Run all `tasks` on the pool and collect results in input order.
    /// Panics in tasks are propagated (first panic wins).
    ///
    /// Waits on *this call's* completion count, not pool-wide idleness, so
    /// concurrent `scope_execute` callers sharing one pool do not block on
    /// each other's work (the batch coordinator relies on this).
    pub fn scope_execute<T, F>(&self, tasks: Vec<F>) -> Vec<T>
    where
        T: Send + 'static,
        F: FnOnce() -> T + Send + 'static,
    {
        let n = tasks.len();
        // (result slots, tasks remaining) guarded together; the condvar
        // signals when remaining hits zero.
        let state: Arc<(Mutex<(Vec<Option<std::thread::Result<T>>>, usize)>, Condvar)> =
            Arc::new((Mutex::new(((0..n).map(|_| None).collect(), n)), Condvar::new()));
        for (i, task) in tasks.into_iter().enumerate() {
            let state = Arc::clone(&state);
            self.execute(move || {
                let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
                let (lock, done) = &*state;
                let mut guard = recover(lock.lock());
                guard.0[i] = Some(r);
                guard.1 -= 1;
                if guard.1 == 0 {
                    done.notify_all();
                }
            });
        }
        let (lock, done) = &*state;
        let mut guard = recover(lock.lock());
        while guard.1 != 0 {
            guard = recover(done.wait(guard));
        }
        let slots = std::mem::take(&mut guard.0);
        drop(guard);
        slots
            .into_iter()
            // lint: allow(no-unwrap) -- the barrier waited for remaining == 0, so every slot is filled
            .map(|slot| match slot.expect("task completed") {
                Ok(v) => v,
                Err(p) => std::panic::resume_unwind(p),
            })
            .collect()
    }
}

/// Decrements `in_flight` on drop, so a panicking job can never leak its
/// slot: without this, a panic unwinding through `worker_loop` would skip
/// the decrement and every later `wait_idle()` would hang forever.
struct InFlightGuard<'a>(&'a Shared);

impl Drop for InFlightGuard<'_> {
    fn drop(&mut self) {
        if self.0.in_flight.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _guard = recover(self.0.idle_lock.lock());
            self.0.idle.notify_all();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        let job = {
            let mut q = recover(sh.queue.lock());
            loop {
                if let Some(job) = q.pop_front() {
                    break job;
                }
                if sh.shutdown.load(Ordering::SeqCst) {
                    return;
                }
                q = recover(sh.available.wait(q));
            }
        };
        // Contain panics so the worker thread survives a panicking job
        // (`scope_execute` already catches and re-raises on the caller
        // side; raw `execute` jobs that panic are contained here). The
        // guard decrements `in_flight` whether the job returns or unwinds.
        let guard = InFlightGuard(sh.as_ref());
        let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
        drop(guard);
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        self.shared.shutdown.store(true, Ordering::SeqCst);
        self.shared.available.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_all_jobs() {
        let pool = ThreadPool::new(4);
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..100 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 100);
    }

    #[test]
    fn scope_execute_preserves_order() {
        let pool = ThreadPool::new(3);
        let tasks: Vec<_> = (0..50).map(|i| move || i * 2).collect();
        let out = pool.scope_execute(tasks);
        assert_eq!(out, (0..50).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn scope_execute_actually_parallel() {
        // With 4 threads and 4 sleeping tasks, wall time ≈ one task.
        let pool = ThreadPool::new(4);
        let t0 = std::time::Instant::now();
        let tasks: Vec<_> = (0..4)
            .map(|_| move || std::thread::sleep(std::time::Duration::from_millis(50)))
            .collect();
        pool.scope_execute(tasks);
        assert!(t0.elapsed() < std::time::Duration::from_millis(160));
    }

    #[test]
    #[should_panic(expected = "task boom")]
    fn scope_execute_propagates_panic() {
        let pool = ThreadPool::new(2);
        let tasks: Vec<Box<dyn FnOnce() -> i32 + Send>> = vec![
            Box::new(|| 1),
            Box::new(|| panic!("task boom")),
        ];
        pool.scope_execute(tasks);
    }

    #[test]
    fn wait_idle_on_empty_pool_returns() {
        let pool = ThreadPool::new(2);
        pool.wait_idle();
    }

    #[test]
    fn size_clamped_to_one() {
        let pool = ThreadPool::new(0);
        assert_eq!(pool.size(), 1);
        let out = pool.scope_execute(vec![|| 7]);
        assert_eq!(out, vec![7]);
    }

    #[test]
    fn panicking_job_does_not_leak_in_flight() {
        // Regression: a panic used to kill the worker before the
        // `in_flight` decrement, so the next `wait_idle()` hung forever.
        let pool = ThreadPool::new(2);
        pool.execute(|| panic!("contained panic"));
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..20 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // must return, not hang
        assert_eq!(counter.load(Ordering::SeqCst), 20);
    }

    #[test]
    fn worker_survives_panic_and_pool_stays_usable() {
        // With 1 worker, a dead worker thread would strand every later job.
        let pool = ThreadPool::new(1);
        pool.execute(|| panic!("boom once"));
        pool.wait_idle();
        let tasks: Vec<fn() -> i32> = vec![|| 1, || 2, || 3];
        assert_eq!(pool.scope_execute(tasks), vec![1, 2, 3]);
    }

    #[test]
    fn concurrent_scopes_complete_independently() {
        // Each scope waits on its own completion count, not pool-wide
        // idleness, so scopes sharing one pool all finish with correct,
        // separately-ordered results.
        let pool = ThreadPool::new(4);
        std::thread::scope(|s| {
            for t in 0..3i32 {
                let pool = &pool;
                s.spawn(move || {
                    for _ in 0..5 {
                        let tasks: Vec<_> = (0..8).map(|i| move || i * 10 + t).collect();
                        let out = pool.scope_execute(tasks);
                        assert_eq!(out, (0..8).map(|i| i * 10 + t).collect::<Vec<_>>());
                    }
                });
            }
        });
    }

    #[test]
    fn pool_survives_poisoned_queue_mutex() {
        // Poison the queue mutex from a foreign thread (panic while holding
        // the guard), then prove the pool still accepts, runs, and drains
        // work. Without `recover` every later `execute`/`worker_loop` lock
        // would panic on `PoisonError` and the pool would be bricked.
        let pool = ThreadPool::new(2);
        let sh = Arc::clone(&pool.shared);
        let poisoner = std::thread::spawn(move || {
            let _guard = sh.queue.lock().unwrap();
            panic!("poison the queue mutex");
        });
        assert!(poisoner.join().is_err(), "poisoner must have panicked");
        assert!(pool.shared.queue.is_poisoned(), "mutex really is poisoned");

        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle();
        assert_eq!(counter.load(Ordering::SeqCst), 10);
        // scope_execute (its own barrier mutex) works too.
        let tasks: Vec<fn() -> i32> = vec![|| 1, || 2, || 3];
        assert_eq!(pool.scope_execute(tasks), vec![1, 2, 3]);
    }

    #[test]
    fn pool_survives_poisoned_idle_lock() {
        // Same drill for the idle/wait_idle condvar mutex.
        let pool = ThreadPool::new(2);
        let sh = Arc::clone(&pool.shared);
        let poisoner = std::thread::spawn(move || {
            let _guard = sh.idle_lock.lock().unwrap();
            panic!("poison the idle mutex");
        });
        assert!(poisoner.join().is_err());
        assert!(pool.shared.idle_lock.is_poisoned());
        let counter = Arc::new(AtomicU64::new(0));
        for _ in 0..5 {
            let c = Arc::clone(&counter);
            pool.execute(move || {
                c.fetch_add(1, Ordering::SeqCst);
            });
        }
        pool.wait_idle(); // must return despite the poisoned idle lock
        assert_eq!(counter.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn scope_execute_after_sibling_panic_completes() {
        // A panicking task must not prevent its siblings from finishing
        // nor deadlock the barrier; the panic is re-raised afterwards.
        let pool = ThreadPool::new(3);
        let done = Arc::new(AtomicU64::new(0));
        let tasks: Vec<Box<dyn FnOnce() + Send>> = (0..6)
            .map(|i| {
                let done = Arc::clone(&done);
                Box::new(move || {
                    if i == 2 {
                        panic!("sibling panic");
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope_execute(tasks);
        }));
        assert!(caught.is_err(), "panic propagates to the caller");
        assert_eq!(done.load(Ordering::SeqCst), 5, "siblings all ran");
        pool.wait_idle(); // pool healthy afterwards
    }
}
