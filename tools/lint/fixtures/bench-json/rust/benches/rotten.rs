//! Seeded violation: a bench target that never emits its BENCH_*.json
//! artifact via write_bench_json.

fn main() {
    println!("silent bench: no machine-readable output");
}
