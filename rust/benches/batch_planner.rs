//! **Batch planner benchmark**: N overlapping selective queries executed
//! naively (one cluster pass per query) vs through `analyze_batch` (one
//! pass per merged range, concurrent worker tasks, per-query demux).
//!
//! Expected shape: as overlap grows, the naive path re-targets the same
//! partitions once per query while the planned path touches each once per
//! batch — both the partitions-targeted counter and the wall clock should
//! separate.
//!
//! Run: `cargo bench --bench batch_planner`
//! (OSEBA_BYTES / OSEBA_BENCH_ITERS to rescale).

mod common;

use oseba::bench::{bench, table, BenchConfig};
use oseba::config::parse_bytes;
use oseba::coordinator::{plan_batch, IndexKind};
use oseba::index::RangeQuery;
use oseba::util::rng::Xoshiro256;

fn main() {
    let bytes = std::env::var("OSEBA_BYTES")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_BYTES"))
        .unwrap_or(32 << 20);
    let cfg = BenchConfig::from_env();
    let backend = common::backend_kind();

    oseba::bench::section(&format!(
        "batch planner: naive per-query vs planned batch ({} raw, 15 partitions)",
        oseba::util::humansize::bytes(bytes)
    ));

    let mut scales = Vec::new();
    for &n_queries in &[4usize, 16, 64] {
        let (coord, ds, _) = common::setup(bytes, 15, backend);
        let index = coord.build_index(&ds, IndexKind::Cias).expect("index");
        let key_min = ds.key_min().unwrap();
        let key_max = ds.key_max().unwrap();
        let span = (key_max - key_min) as f64;

        // 20%-wide queries placed uniformly: heavy overlap at high N.
        let queries: Vec<RangeQuery> = {
            let mut rng = Xoshiro256::seeded(n_queries as u64);
            (0..n_queries)
                .map(|_| {
                    let lo = key_min + (rng.next_f64() * span * 0.8) as i64;
                    RangeQuery { lo, hi: lo + (span * 0.2) as i64 }
                })
                .collect()
        };
        let plan = plan_batch(&queries);

        let before = coord.context().counters();
        let naive = {
            let (coord, ds, index, queries) = (&coord, &ds, &index, &queries);
            bench(&cfg, &format!("naive   n={n_queries}"), move || {
                for q in queries {
                    coord
                        .analyze_period_oseba(ds, index.as_ref(), *q, 0)
                        .expect("query");
                }
            })
        };
        let mid = coord.context().counters();
        let planned = {
            let (coord, ds, index, queries) = (&coord, &ds, &index, &queries);
            bench(&cfg, &format!("planned n={n_queries}"), move || {
                coord
                    .analyze_batch(ds, index.as_ref(), queries, 0)
                    .expect("batch");
            })
        };
        let after = coord.context().counters();

        let iters = (cfg.iters + cfg.warmup_iters).max(1);
        let naive_touched = (mid.partitions_targeted - before.partitions_targeted) / iters;
        let batch_touched = (after.partitions_targeted - mid.partitions_targeted) / iters;

        println!(
            "  {n_queries} queries -> {} merged ranges | partitions targeted per run: \
             naive {naive_touched}, planned {batch_touched}",
            plan.len()
        );
        assert!(
            batch_touched <= naive_touched,
            "planning must never touch more partitions"
        );
        use oseba::util::json::Json;
        scales.push(Json::obj(vec![
            ("queries", Json::num(n_queries as f64)),
            ("merged_ranges", Json::num(plan.len() as f64)),
            ("naive_partitions_targeted", Json::num(naive_touched as f64)),
            ("planned_partitions_targeted", Json::num(batch_touched as f64)),
            ("naive_secs_p50", Json::num(naive.summary.p50)),
            ("planned_secs_p50", Json::num(planned.summary.p50)),
        ]));
        println!("{}", table(&[naive, planned]));
    }
    use oseba::util::json::Json;
    common::write_bench_json(
        "batch_planner",
        Json::obj(vec![
            ("bench", Json::str("batch_planner")),
            ("raw_bytes", Json::num(bytes as f64)),
            ("scales", Json::arr(scales)),
        ]),
    );
}
