//! **Zone-map pruning bench**: a selective *value*-predicate query over a
//! tiered dataset ~4× the memory budget. Key-only targeting must fault in
//! every partition the key range admits (here: all of them — the key span
//! is the whole dataset); zone-map pruning consults resident metadata and
//! faults in only the partitions whose value domain can satisfy the
//! predicate — measurably fewer `faults` and `segment_bytes_read`, with
//! results identical to the unpruned oracle.
//!
//! Emits `BENCH_pruning.json` (machine-readable: faults, bytes read, wall
//! time per arm) for the perf trajectory.
//!
//! Run: `cargo bench --bench pruning`
//! (OSEBA_PRUNING_BUDGET rescales; dataset is 4× the budget.)

mod common;

use oseba::bench::{bench, section, table, BenchConfig};
use oseba::config::{parse_bytes, BackendKind, ContextConfig};
use oseba::coordinator::{plan_query, Coordinator, Query, QueryOutput};
use oseba::engine::Dataset;
use oseba::index::{ColumnPredicate, PredOp, RangeQuery};
use oseba::runtime::make_backend;
use oseba::storage::{BatchBuilder, Schema};
use oseba::util::humansize;
use oseba::util::json::Json;
use oseba::util::rng::Xoshiro256;

const PARTITIONS: usize = 32;

fn coordinator(budget: usize) -> Coordinator {
    let mut cfg = common::app_cfg(BackendKind::Native);
    cfg.ctx = ContextConfig { num_workers: 4, memory_budget: Some(budget) };
    let be = make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend");
    Coordinator::new(&cfg, be).expect("coordinator")
}

/// Trending `price` (≈ row index, so partitions carry disjoint value
/// domains) + oscillating `volume`.
fn trending_batch(rows: usize) -> oseba::storage::RecordBatch {
    let mut rng = Xoshiro256::seeded(7);
    let mut b = BatchBuilder::new(Schema::stock());
    for i in 0..rows {
        let price = i as f32 + (rng.next_f32() - 0.5) * 8.0;
        let volume = (i as f32 / 64.0).sin() * 1_000.0;
        b.push(i as i64, &[price, volume]);
    }
    b.finish().unwrap()
}

fn run_stats(c: &Coordinator, ds: &Dataset, plan: &oseba::coordinator::PhysicalPlan, q: &Query) -> oseba::analysis::PeriodStats {
    match c.execute_physical(ds, plan, q).expect("execute") {
        QueryOutput::Stats(s) => s,
        _ => unreachable!(),
    }
}

fn main() {
    let budget = std::env::var("OSEBA_PRUNING_BUDGET")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_PRUNING_BUDGET"))
        .unwrap_or(8 << 20);
    let raw = 4 * budget;
    let rows = raw / Schema::stock().row_bytes();
    let dir = std::env::temp_dir().join(format!("oseba-pruning-bench-{}", std::process::id()));

    section(&format!(
        "Zone-map pruning: {} tiered dataset under a {} budget ({} partitions)",
        humansize::bytes(raw),
        humansize::bytes(budget),
        PARTITIONS
    ));

    let coord = coordinator(budget);
    let ds = coord
        .load_tiered(trending_batch(rows), PARTITIONS, &dir)
        .expect("tiered load");
    let store = ds.store().expect("tiered").clone();
    let index = coord
        .build_index(&ds, oseba::coordinator::IndexKind::Cias)
        .expect("index");

    // Full key span; the predicate admits only the top ~1/8 of prices —
    // key targeting alone cannot skip anything, zone maps can.
    let threshold = (rows as f32) * 7.0 / 8.0;
    let query = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0).filtered(vec![
        ColumnPredicate { column: 0, op: PredOp::Ge, value: threshold },
    ]);
    let pruned_plan = plan_query(&ds, index.as_ref(), &query, true).expect("plan");
    let oracle_plan = plan_query(&ds, index.as_ref(), &query, false).expect("plan");
    println!("{}", pruned_plan.explain.line());
    assert!(
        pruned_plan.explain.zone_pruned > PARTITIONS / 2,
        "trending data must zone-prune most partitions: {:?}",
        pruned_plan.explain
    );

    // Correctness first: identical results from both arms, cold cache.
    store.shrink(usize::MAX).expect("evict all");
    let want = run_stats(&coord, &ds, &oracle_plan, &query);
    store.shrink(usize::MAX).expect("evict all");
    let got = run_stats(&coord, &ds, &pruned_plan, &query);
    assert_eq!(got, want, "zone pruning must not change results");

    // Counters per arm, measured over one cold run each.
    let mut arms: Vec<(&str, &oseba::coordinator::PhysicalPlan)> =
        vec![("key-only (unpruned oracle)", &oracle_plan), ("zone-pruned", &pruned_plan)];
    let cfg = BenchConfig::from_env();
    let mut results = Vec::new();
    let mut json_arms = Vec::new();
    for (name, plan) in arms.drain(..) {
        store.shrink(usize::MAX).expect("evict all");
        let before = store.counters();
        let stats = run_stats(&coord, &ds, plan, &query);
        let delta = store.counters().since(&before);

        let r = bench(&cfg, name, || {
            store.shrink(usize::MAX).expect("evict all");
            run_stats(&coord, &ds, plan, &query);
        });
        println!(
            "  {name}: {} faults, {} read, count={}",
            delta.faults,
            humansize::bytes(delta.segment_bytes_read),
            stats.count
        );
        json_arms.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("faults", Json::num(delta.faults as f64)),
            ("segment_bytes_read", Json::num(delta.segment_bytes_read as f64)),
            ("partitions_targeted", Json::num(plan.explain.targeted as f64)),
            ("zone_pruned", Json::num(plan.explain.zone_pruned as f64)),
            ("rows_selected", Json::num(stats.count as f64)),
            ("secs_mean", Json::num(r.summary.mean)),
            ("secs_p50", Json::num(r.summary.p50)),
            ("secs_p95", Json::num(r.summary.p95)),
        ]));
        results.push(r);
    }
    println!("\n{}", table(&results));

    // The acceptance gate: fewer faults, fewer bytes, same answer.
    let (oracle, pruned) = (&json_arms[0], &json_arms[1]);
    let f = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap();
    assert!(
        f(pruned, "faults") < f(oracle, "faults"),
        "zone pruning must fault in fewer partitions"
    );
    assert!(
        f(pruned, "segment_bytes_read") < f(oracle, "segment_bytes_read"),
        "zone pruning must read fewer segment bytes"
    );

    common::write_bench_json(
        "pruning",
        Json::obj(vec![
            ("bench", Json::str("pruning")),
            ("raw_bytes", Json::num(raw as f64)),
            ("budget_bytes", Json::num(budget as f64)),
            ("partitions", Json::num(PARTITIONS as f64)),
            ("rows", Json::num(rows as f64)),
            ("arms", Json::arr(json_arms)),
        ]),
    );

    coord.context().unpersist(&ds);
    let _ = std::fs::remove_dir_all(&dir);
}
