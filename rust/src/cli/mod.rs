//! Minimal subcommand/flag argument parser (clap is not in the vendored
//! set). Supports `--flag value`, `--flag=value`, boolean `--flag`, and
//! positional arguments, with generated usage text.

use std::collections::BTreeMap;

use crate::error::{OsebaError, Result};

/// A declared flag.
#[derive(Clone, Debug)]
pub struct FlagSpec {
    /// Flag name (without the leading `--`).
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Boolean flags take no value.
    pub boolean: bool,
    /// Default value applied when the flag is absent.
    pub default: Option<&'static str>,
}

/// A declared subcommand.
#[derive(Clone, Debug)]
pub struct CommandSpec {
    /// Subcommand name.
    pub name: &'static str,
    /// One-line help text.
    pub help: &'static str,
    /// Flags the subcommand accepts.
    pub flags: Vec<FlagSpec>,
}

/// Parsed invocation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Parsed {
    /// The matched subcommand.
    pub command: String,
    /// Flag values (defaults merged in).
    pub flags: BTreeMap<String, String>,
    /// Non-flag arguments, in order.
    pub positionals: Vec<String>,
}

impl Parsed {
    /// Raw flag value, if present (or defaulted).
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// Whether a boolean flag was passed.
    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true"))
    }

    /// Flag value that must be present — flags declared with a default
    /// always are, so this only errors on a spec/lookup mismatch (a typed
    /// error, where an `unwrap` would take the whole process down).
    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| OsebaError::Config(format!("missing required --{name}")))
    }

    /// Parse a required flag value into `T`.
    pub fn require_parse<T: std::str::FromStr>(&self, name: &str) -> Result<T> {
        self.get_parse(name)?
            .ok_or_else(|| OsebaError::Config(format!("missing required --{name}")))
    }

    /// Parse a flag value into `T`; `None` when the flag is absent.
    pub fn get_parse<T: std::str::FromStr>(&self, name: &str) -> Result<Option<T>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| OsebaError::Config(format!("invalid value for --{name}: '{v}'"))),
        }
    }
}

/// The CLI definition.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    /// Program name shown in usage text.
    pub program: &'static str,
    /// One-line program description.
    pub about: &'static str,
    /// Declared subcommands.
    pub commands: Vec<CommandSpec>,
}

impl Cli {
    /// Start a CLI definition.
    pub fn new(program: &'static str, about: &'static str) -> Cli {
        Cli { program, about, commands: Vec::new() }
    }

    /// Declare a subcommand (builder style).
    pub fn command(mut self, name: &'static str, help: &'static str, flags: Vec<FlagSpec>) -> Cli {
        self.commands.push(CommandSpec { name, help, flags });
        self
    }

    /// Parse argv (without the program name).
    pub fn parse(&self, args: &[String]) -> Result<Parsed> {
        let cmd_name = args
            .first()
            .ok_or_else(|| OsebaError::Config(format!("missing command\n\n{}", self.usage())))?;
        if cmd_name == "help" || cmd_name == "--help" || cmd_name == "-h" {
            return Err(OsebaError::Config(self.usage()));
        }
        let spec = self
            .commands
            .iter()
            .find(|c| c.name == cmd_name)
            .ok_or_else(|| {
                OsebaError::Config(format!("unknown command '{cmd_name}'\n\n{}", self.usage()))
            })?;

        let mut flags = BTreeMap::new();
        for f in &spec.flags {
            if let Some(d) = f.default {
                flags.insert(f.name.to_string(), d.to_string());
            }
        }
        let mut positionals = Vec::new();
        let mut i = 1;
        while i < args.len() {
            let a = &args[i];
            if let Some(raw) = a.strip_prefix("--") {
                let (name, inline) = match raw.split_once('=') {
                    Some((n, v)) => (n, Some(v.to_string())),
                    None => (raw, None),
                };
                let f = spec.flags.iter().find(|f| f.name == name).ok_or_else(|| {
                    OsebaError::Config(format!(
                        "unknown flag --{name} for '{cmd_name}'\n\n{}",
                        self.command_usage(spec)
                    ))
                })?;
                let value = if f.boolean {
                    inline.unwrap_or_else(|| "true".to_string())
                } else {
                    match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            args.get(i)
                                .cloned()
                                .ok_or_else(|| {
                                    OsebaError::Config(format!("--{name} needs a value"))
                                })?
                        }
                    }
                };
                flags.insert(name.to_string(), value);
            } else {
                positionals.push(a.clone());
            }
            i += 1;
        }
        Ok(Parsed { command: cmd_name.clone(), flags, positionals })
    }

    /// Full usage text.
    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nUSAGE:\n  {} <command> [flags]\n\nCOMMANDS:\n",
            self.program, self.about, self.program);
        for c in &self.commands {
            out.push_str(&format!("  {:<14} {}\n", c.name, c.help));
        }
        out.push_str(&format!("\nRun '{} <command> --help' semantics via 'help'.\n", self.program));
        out
    }

    fn command_usage(&self, spec: &CommandSpec) -> String {
        let mut out = format!("{} {} — {}\n\nFLAGS:\n", self.program, spec.name, spec.help);
        for f in &spec.flags {
            let d = f.default.map(|d| format!(" (default: {d})")).unwrap_or_default();
            out.push_str(&format!("  --{:<18} {}{}\n", f.name, f.help, d));
        }
        out
    }
}

/// Convenience flag constructors.
pub fn flag(name: &'static str, help: &'static str, default: Option<&'static str>) -> FlagSpec {
    FlagSpec { name, help, boolean: false, default }
}

/// A boolean (valueless) flag spec.
pub fn bool_flag(name: &'static str, help: &'static str) -> FlagSpec {
    FlagSpec { name, help, boolean: true, default: None }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("oseba", "test").command(
            "run",
            "run things",
            vec![
                flag("size", "dataset size", Some("100")),
                flag("backend", "backend", None),
                bool_flag("verbose", "log more"),
            ],
        )
    }

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_defaults() {
        let p = cli().parse(&argv(&["run", "--backend", "native", "--verbose"])).unwrap();
        assert_eq!(p.command, "run");
        assert_eq!(p.get("size"), Some("100")); // default
        assert_eq!(p.get("backend"), Some("native"));
        assert!(p.get_bool("verbose"));
    }

    #[test]
    fn parses_equals_form_and_positionals() {
        let p = cli().parse(&argv(&["run", "--size=42", "input.dat"])).unwrap();
        assert_eq!(p.get("size"), Some("42"));
        assert_eq!(p.positionals, vec!["input.dat"]);
        assert_eq!(p.get_parse::<usize>("size").unwrap(), Some(42));
    }

    #[test]
    fn rejects_unknown_command_and_flag() {
        assert!(cli().parse(&argv(&["nope"])).is_err());
        assert!(cli().parse(&argv(&["run", "--bogus", "1"])).is_err());
        assert!(cli().parse(&argv(&[])).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(cli().parse(&argv(&["run", "--backend"])).is_err());
    }

    #[test]
    fn invalid_typed_value_is_error() {
        let p = cli().parse(&argv(&["run", "--size", "abc"])).unwrap();
        assert!(p.get_parse::<usize>("size").is_err());
    }

    #[test]
    fn require_errors_instead_of_panicking() {
        let p = cli().parse(&argv(&["run"])).unwrap();
        assert_eq!(p.require("size").unwrap(), "100"); // default applies
        assert_eq!(p.require_parse::<usize>("size").unwrap(), 100);
        assert!(p.require("backend").is_err()); // no default, absent
        assert!(p.require_parse::<usize>("backend").is_err());
    }

    #[test]
    fn usage_lists_commands() {
        let u = cli().usage();
        assert!(u.contains("run"));
        assert!(u.contains("oseba"));
    }
}
