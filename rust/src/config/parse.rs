//! `key = value` config-file parser (TOML subset: comments, blank lines,
//! bare or quoted string values, one `[section]` level flattened to
//! `section.key`).

use crate::error::{OsebaError, Result};

/// Ordered key→value pairs from a config file.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ConfigMap {
    entries: Vec<(String, String)>,
}

impl ConfigMap {
    /// Iterate entries in file order.
    pub fn iter(&self) -> impl Iterator<Item = &(String, String)> {
        self.entries.iter()
    }

    /// Last value for `key` (later duplicates win).
    pub fn get(&self, key: &str) -> Option<&str> {
        self.entries.iter().rev().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Append an entry (CLI `--set` overrides).
    pub fn insert(&mut self, key: impl Into<String>, value: impl Into<String>) {
        self.entries.push((key.into(), value.into()));
    }

    /// Number of entries (duplicates counted).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the map holds no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// Parse config text. Later duplicate keys override earlier ones (via
/// `get`); `apply` consumers see them in order.
pub fn parse_config_text(text: &str) -> Result<ConfigMap> {
    let mut map = ConfigMap::default();
    let mut section = String::new();
    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']').ok_or_else(|| {
                OsebaError::Config(format!("line {}: unterminated section", lineno + 1))
            })?;
            section = name.trim().to_string();
            continue;
        }
        let (k, v) = line.split_once('=').ok_or_else(|| {
            OsebaError::Config(format!("line {}: expected key = value", lineno + 1))
        })?;
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        if key.is_empty() || key.ends_with('.') {
            return Err(OsebaError::Config(format!("line {}: empty key", lineno + 1)));
        }
        map.insert(key, unquote(v.trim()));
    }
    Ok(map)
}

fn strip_comment(line: &str) -> &str {
    // Respect '#' inside quotes.
    let mut in_quote = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_quote = !in_quote,
            '#' if !in_quote => return &line[..i],
            _ => {}
        }
    }
    line
}

fn unquote(v: &str) -> String {
    if v.len() >= 2 && v.starts_with('"') && v.ends_with('"') {
        v[1..v.len() - 1].to_string()
    } else {
        v.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basics() {
        let m = parse_config_text("a = 1\nb = \"two words\" # comment\n\n# full comment\nc=3")
            .unwrap();
        assert_eq!(m.get("a"), Some("1"));
        assert_eq!(m.get("b"), Some("two words"));
        assert_eq!(m.get("c"), Some("3"));
        assert_eq!(m.len(), 3);
    }

    #[test]
    fn sections_flatten() {
        let m = parse_config_text("[cluster]\nworkers = 8\n[bench]\niters = 3").unwrap();
        assert_eq!(m.get("cluster.workers"), Some("8"));
        assert_eq!(m.get("bench.iters"), Some("3"));
    }

    #[test]
    fn later_duplicates_win() {
        let m = parse_config_text("a = 1\na = 2").unwrap();
        assert_eq!(m.get("a"), Some("2"));
    }

    #[test]
    fn hash_inside_quotes_kept() {
        let m = parse_config_text("path = \"/tmp/#x\"").unwrap();
        assert_eq!(m.get("path"), Some("/tmp/#x"));
    }

    #[test]
    fn errors_have_line_numbers() {
        let e = parse_config_text("ok = 1\nbroken").unwrap_err();
        assert!(e.to_string().contains("line 2"));
        let e = parse_config_text("[open").unwrap_err();
        assert!(e.to_string().contains("line 1"));
    }
}
