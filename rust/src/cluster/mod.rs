//! Simulated cluster: worker registry, partition placement, a network cost
//! model, and failure injection.
//!
//! The paper ran on Marmot (128 nodes, GbE, Spark 1.0.2); here workers are
//! logical nodes whose tasks execute on the engine's thread pool
//! (DESIGN.md §2's substitution). What is preserved: per-worker task
//! routing (a partition's task runs "where the partition lives"),
//! per-dispatch network latency, and the failure/reassignment behaviour a
//! driver must implement.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::Duration;

use crate::error::{OsebaError, Result};
use crate::index::PartitionSlice;
use crate::util::sync::MutexExt;

/// Network cost model applied per dispatched message.
#[derive(Clone, Copy, Debug, Default)]
pub struct NetworkModel {
    /// One-way message latency in microseconds (0 disables sleeping).
    pub latency_us: u64,
}

impl NetworkModel {
    /// Pay the cost of one control message.
    pub fn message(&self) {
        if self.latency_us > 0 {
            std::thread::sleep(Duration::from_micros(self.latency_us));
        }
    }
}

/// Cluster state: placement + liveness.
#[derive(Debug)]
pub struct Cluster {
    num_workers: usize,
    /// partition id → worker id.
    placement: Mutex<Vec<usize>>,
    alive: Vec<AtomicBool>,
    /// The per-message network cost model tasks pay on dispatch/return.
    pub net: NetworkModel,
}

impl Cluster {
    /// Round-robin placement of `num_partitions` over `num_workers`.
    pub fn new(num_workers: usize, num_partitions: usize, net: NetworkModel) -> Result<Cluster> {
        if num_workers == 0 {
            return Err(OsebaError::Cluster("need at least one worker".into()));
        }
        Ok(Cluster {
            num_workers,
            placement: Mutex::new((0..num_partitions).map(|p| p % num_workers).collect()),
            alive: (0..num_workers).map(|_| AtomicBool::new(true)).collect(),
            net,
        })
    }

    /// Total registered workers (alive or not).
    pub fn num_workers(&self) -> usize {
        self.num_workers
    }

    /// Workers currently alive.
    pub fn num_alive(&self) -> usize {
        self.alive.iter().filter(|a| a.load(Ordering::SeqCst)).count()
    }

    /// Whether worker `w` is alive.
    pub fn is_alive(&self, w: usize) -> bool {
        self.alive.get(w).is_some_and(|a| a.load(Ordering::SeqCst))
    }

    /// Worker owning a partition.
    pub fn owner(&self, partition: usize) -> Result<usize> {
        self.placement
            .lock_recover()
            .get(partition)
            .copied()
            .ok_or_else(|| OsebaError::Cluster(format!("unknown partition {partition}")))
    }

    /// Kill a worker: its partitions are reassigned round-robin over the
    /// survivors. Fails if it is the last one standing.
    pub fn kill_worker(&self, w: usize) -> Result<usize> {
        if w >= self.num_workers || !self.is_alive(w) {
            return Err(OsebaError::Cluster(format!("worker {w} not alive")));
        }
        if self.num_alive() <= 1 {
            return Err(OsebaError::Cluster("cannot kill the last worker".into()));
        }
        self.alive[w].store(false, Ordering::SeqCst);
        let survivors: Vec<usize> =
            (0..self.num_workers).filter(|&i| self.is_alive(i)).collect();
        let mut placement = self.placement.lock_recover();
        let mut moved = 0usize;
        for slot in placement.iter_mut().filter(|s| **s == w) {
            *slot = survivors[moved % survivors.len()];
            moved += 1;
        }
        Ok(moved)
    }

    /// Extend the placement map to cover at least `n` partitions (derived
    /// datasets create fresh partition ids). New partitions go round-robin
    /// over *live* workers.
    pub fn ensure_partitions(&self, n: usize) {
        let mut placement = self.placement.lock_recover();
        if placement.len() >= n {
            return;
        }
        let live: Vec<usize> = (0..self.num_workers).filter(|&i| self.is_alive(i)).collect();
        let mut i = placement.len();
        while placement.len() < n {
            placement.push(live[i % live.len()]);
            i += 1;
        }
    }

    /// Revive a worker (it owns nothing until new placements/loads).
    pub fn revive_worker(&self, w: usize) -> Result<()> {
        if w >= self.num_workers {
            return Err(OsebaError::Cluster(format!("unknown worker {w}")));
        }
        self.alive[w].store(true, Ordering::SeqCst);
        Ok(())
    }

    /// Route slices to their owning workers: returns `(worker, slices)`
    /// groups, workers in ascending order, slice order preserved.
    pub fn route(&self, slices: &[PartitionSlice]) -> Result<Vec<(usize, Vec<PartitionSlice>)>> {
        self.route_tagged(slices.iter().map(|s| (s.partition, *s)).collect())
    }

    /// Route arbitrary per-partition work items to their owning workers:
    /// each item pairs a partition id with a payload (the batch planner
    /// tags sub-slices with segment ids this way). Returns `(worker,
    /// payloads)` groups, workers ascending, item order preserved.
    pub fn route_tagged<T>(&self, items: Vec<(usize, T)>) -> Result<Vec<(usize, Vec<T>)>> {
        let placement = self.placement.lock_recover();
        let mut groups: Vec<Vec<T>> = (0..self.num_workers).map(|_| Vec::new()).collect();
        for (p, t) in items {
            let w = *placement
                .get(p)
                .ok_or_else(|| OsebaError::Cluster(format!("unknown partition {p}")))?;
            groups[w].push(t);
        }
        Ok(groups
            .into_iter()
            .enumerate()
            .filter(|(_, g)| !g.is_empty())
            .collect())
    }

    /// Placement snapshot (tests / inspection).
    pub fn placement(&self) -> Vec<usize> {
        self.placement.lock_recover().clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slices(parts: &[usize]) -> Vec<PartitionSlice> {
        parts
            .iter()
            .map(|&p| PartitionSlice { partition: p, row_start: 0, row_end: 1 })
            .collect()
    }

    #[test]
    fn round_robin_placement() {
        let c = Cluster::new(3, 7, NetworkModel::default()).unwrap();
        assert_eq!(c.placement(), vec![0, 1, 2, 0, 1, 2, 0]);
        assert_eq!(c.owner(4).unwrap(), 1);
        assert!(c.owner(99).is_err());
    }

    #[test]
    fn route_groups_by_owner() {
        let c = Cluster::new(2, 6, NetworkModel::default()).unwrap();
        let groups = c.route(&slices(&[0, 1, 2, 3, 5])).unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0, 0);
        assert_eq!(groups[0].1.iter().map(|s| s.partition).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(groups[1].1.iter().map(|s| s.partition).collect::<Vec<_>>(), vec![1, 3, 5]);
    }

    #[test]
    fn route_preserves_every_slice_exactly_once() {
        let c = Cluster::new(4, 20, NetworkModel::default()).unwrap();
        let input = slices(&(0..20).collect::<Vec<_>>());
        let groups = c.route(&input).unwrap();
        let mut got: Vec<usize> =
            groups.iter().flat_map(|(_, g)| g.iter().map(|s| s.partition)).collect();
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn route_tagged_groups_payloads_by_owner() {
        let c = Cluster::new(2, 4, NetworkModel::default()).unwrap();
        let items = vec![(0usize, "a"), (1, "b"), (2, "c"), (0, "d")];
        let groups = c.route_tagged(items).unwrap();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], (0, vec!["a", "c", "d"]));
        assert_eq!(groups[1], (1, vec!["b"]));
        assert!(c.route_tagged(vec![(99usize, ())]).is_err());
    }

    #[test]
    fn kill_reassigns_partitions() {
        let c = Cluster::new(3, 9, NetworkModel::default()).unwrap();
        let moved = c.kill_worker(1).unwrap();
        assert_eq!(moved, 3);
        assert_eq!(c.num_alive(), 2);
        assert!(c.placement().iter().all(|&w| w != 1));
        // Routing after failure touches only live workers.
        let groups = c.route(&slices(&[1, 4, 7])).unwrap();
        assert!(groups.iter().all(|(w, _)| *w != 1));
        let total: usize = groups.iter().map(|(_, g)| g.len()).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn cannot_kill_last_worker_or_dead_worker() {
        let c = Cluster::new(2, 4, NetworkModel::default()).unwrap();
        c.kill_worker(0).unwrap();
        assert!(c.kill_worker(0).is_err());
        assert!(c.kill_worker(1).is_err());
        c.revive_worker(0).unwrap();
        assert_eq!(c.num_alive(), 2);
    }

    #[test]
    fn zero_workers_rejected() {
        assert!(Cluster::new(0, 4, NetworkModel::default()).is_err());
    }
}
