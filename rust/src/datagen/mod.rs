//! Synthetic dataset generators.
//!
//! The paper evaluates on a ~480 MB climate-like time series ("similar data
//! format to the climate data, e.g, time, temperature, humidity, wind speed
//! and direction", §IV-A) that we do not have; these generators are the
//! documented substitution (DESIGN.md §2). Each produces a sorted
//! [`RecordBatch`] with a *uniform key step* — the regularity CIAS
//! compresses — plus knobs to inject irregularities for the index's
//! associated-search-list path.

pub mod cdr;
pub mod climate;
pub mod stock;

pub use cdr::CdrGen;
pub use climate::ClimateGen;
pub use stock::StockGen;
