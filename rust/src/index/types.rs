//! Core index vocabulary: range queries, partition slices and the
//! [`ContentIndex`] trait both index implementations satisfy.

use crate::error::{OsebaError, Result};

/// An inclusive key-range selection `[lo, hi]` — the paper's "data ranging
/// from index i to j" (§III-A).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RangeQuery {
    /// Lower key bound, inclusive.
    pub lo: i64,
    /// Upper key bound, inclusive.
    pub hi: i64,
}

impl RangeQuery {
    /// Validate `lo <= hi`.
    pub fn new(lo: i64, hi: i64) -> Result<RangeQuery> {
        if lo > hi {
            return Err(OsebaError::InvalidRange(format!("lo {lo} > hi {hi}")));
        }
        Ok(RangeQuery { lo, hi })
    }
}

/// A targeted region of one partition: valid-row indices `[row_start,
/// row_end)` of partition `partition`. The unit of work the coordinator
/// dispatches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionSlice {
    /// Target partition id.
    pub partition: usize,
    /// First valid row (inclusive).
    pub row_start: usize,
    /// One past the last valid row.
    pub row_end: usize,
}

impl PartitionSlice {
    /// Number of rows the slice covers.
    pub fn rows(&self) -> usize {
        self.row_end - self.row_start
    }
}

/// Content-aware metadata over a partitioned dataset: maps key ranges to
/// the partitions (and row ranges) that hold them, without touching data.
pub trait ContentIndex: Send + Sync {
    /// Human-readable implementation name (bench labels).
    fn name(&self) -> &'static str;

    /// All slices intersecting `q`, ordered by partition id; empty when the
    /// query misses the dataset entirely.
    fn lookup(&self, q: RangeQuery) -> Vec<PartitionSlice>;

    /// Resident metadata footprint in bytes — the §III space-complexity
    /// comparison (table: O(m); CIAS: O(1) + ASL).
    fn memory_bytes(&self) -> usize;

    /// Number of partitions the index covers.
    fn num_partitions(&self) -> usize;
}

/// Shared per-partition metadata record extracted at load time.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PartitionMeta {
    /// Partition id within its dataset.
    pub id: usize,
    /// Smallest key the partition holds.
    pub key_min: i64,
    /// Largest key the partition holds.
    pub key_max: i64,
    /// Valid row count.
    pub rows: usize,
    /// Key step within the partition; `None` if irregular or single-row.
    pub step: Option<i64>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn range_query_validates() {
        assert!(RangeQuery::new(5, 5).is_ok());
        assert!(RangeQuery::new(5, 4).is_err());
        assert_eq!(RangeQuery::new(1, 9).unwrap(), RangeQuery { lo: 1, hi: 9 });
    }

    #[test]
    fn slice_rows() {
        let s = PartitionSlice { partition: 0, row_start: 10, row_end: 25 };
        assert_eq!(s.rows(), 15);
    }
}
