//! Ablations over the design choices DESIGN.md §5 calls out:
//!
//! * A1 — residency policy: default-cached vs default-unpersist vs Oseba
//!   (isolates "don't materialize" from "don't cache what you materialize").
//! * A2 — backend: HLO (AOT kernels via PJRT) vs native rust on the same
//!   session (the cost/benefit of the accelerator path at this block size).
//! * A3 — kernel batching: one service submission per worker-batch vs one
//!   per block.
//! * A4 — index: table vs CIAS end-to-end (lookup cost is tiny vs compute;
//!   the win is footprint — reported alongside).
//!
//! Run: `cargo bench --bench ablations`.

mod common;

use oseba::analysis::five_periods;
use oseba::bench::{bench, table, BenchConfig, BenchResult};
use oseba::config::BackendKind;
use oseba::coordinator::{run_session, IndexKind, Method};
use oseba::util::humansize;
use oseba::util::json::Json;

const BYTES: usize = 32 << 20;

/// Timing rows as a JSON array for the bench's result document.
fn rows_json(rows: &[BenchResult]) -> Json {
    Json::arr(
        rows.iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("mean_secs", Json::num(r.summary.mean)),
                    ("p50_secs", Json::num(r.summary.p50)),
                ])
            })
            .collect(),
    )
}

fn main() {
    let cfg = BenchConfig::from_env();
    let periods = five_periods();
    let backend = common::backend_kind();
    let mut doc: Vec<(&str, Json)> = vec![("bench", Json::str("ablations"))];

    // --- A1: residency policy -------------------------------------------
    oseba::bench::section("A1: residency policy (32 MiB, native backend)");
    // Generate once; each iteration loads a fresh context so cached
    // filter-RDDs do not leak between iterations. Both arms pay the same
    // load cost; the delta is the policy.
    let batch = oseba::datagen::ClimateGen::default().generate_bytes(BYTES);
    let mut rows = Vec::new();
    let mut mems = Vec::new();
    for (label, method, unpersist) in [
        ("default, cache filtered (Spark behaviour)", Method::Default, false),
        ("default, unpersist filtered", Method::Default, true),
        ("oseba (no materialization)", Method::Oseba, false),
    ] {
        let periods = periods.clone();
        let batch = batch.clone();
        let mut mem_after = 0usize;
        let r = bench(&cfg, label, || {
            let coord = common::make_coord(oseba::config::BackendKind::Native);
            let ds = coord.load(batch.clone(), 15).unwrap();
            let rep =
                run_session(&coord, &ds, method, IndexKind::Cias, &periods, 0, unpersist)
                    .unwrap();
            mem_after = *rep.metrics.memory_series().last().unwrap();
        });
        rows.push(r);
        mems.push((label, mem_after));
    }
    println!("{}", table(&rows));
    for (label, m) in &mems {
        println!("  {label:<44} final memory {}", humansize::bytes(*m));
    }
    assert!(mems[0].1 > mems[2].1, "cached default must hold more memory than oseba");
    assert!(mems[1].1 == mems[2].1, "unpersist restores the raw footprint");
    doc.push(("a1_residency", rows_json(&rows)));
    doc.push((
        "a1_final_memory_bytes",
        Json::arr(
            mems.iter()
                .map(|&(label, m)| {
                    Json::obj(vec![
                        ("name", Json::str(label)),
                        ("bytes", Json::num(m as f64)),
                    ])
                })
                .collect(),
        ),
    ));

    // --- A2: backend ------------------------------------------------------
    oseba::bench::section("A2: backend HLO vs native (oseba method, 32 MiB)");
    let mut rows = Vec::new();
    let kinds: Vec<(&str, BackendKind)> = if backend == BackendKind::Hlo {
        vec![("hlo (AOT pallas→PJRT)", BackendKind::Hlo), ("native rust", BackendKind::Native)]
    } else {
        vec![("native rust", BackendKind::Native)]
    };
    for (label, kind) in kinds {
        // Setup outside the timed region: session compute only.
        let (coord, ds, _) = common::setup(BYTES, 15, kind);
        let periods = periods.clone();
        rows.push(bench(&cfg, label, move || {
            let rep = run_session(&coord, &ds, Method::Oseba, IndexKind::Cias, &periods, 0, false)
                .unwrap();
            std::hint::black_box(rep.stats.len());
        }));
    }
    println!("{}", table(&rows));
    doc.push(("a2_backend", rows_json(&rows)));

    // --- A3: kernel batching ----------------------------------------------
    oseba::bench::section("A3: kernel-service batching (oseba, hlo backend)");
    if backend == BackendKind::Hlo {
        let mut rows = Vec::new();
        for (label, batched) in [("batched submissions", true), ("one request per block", false)] {
            let (mut coord, _, _) = {
                let (c, d, r) = common::setup(BYTES, 15, BackendKind::Hlo);
                (c, d, r)
            };
            coord.batch_kernel_calls = batched;
            let ds = coord.load(
                oseba::datagen::ClimateGen { seed: 7, ..Default::default() }
                    .generate_bytes(BYTES),
                15,
            )
            .unwrap();
            let periods = periods.clone();
            rows.push(bench(&cfg, label, move || {
                let rep =
                    run_session(&coord, &ds, Method::Oseba, IndexKind::Cias, &periods, 0, false)
                        .unwrap();
                std::hint::black_box(rep.stats.len());
            }));
        }
        println!("{}", table(&rows));
        doc.push(("a3_kernel_batching", rows_json(&rows)));
    } else {
        println!("(skipped: requires artifacts)");
    }

    // --- A4: index kind end-to-end ----------------------------------------
    oseba::bench::section("A4: table vs CIAS end-to-end (oseba method)");
    let mut rows = Vec::new();
    let mut footprints = Vec::new();
    for (label, kind) in [("table index", IndexKind::Table), ("cias index", IndexKind::Cias)] {
        let (coord, ds, _) = common::setup_native(BYTES, 15);
        let periods = periods.clone();
        let ix = coord.build_index(&ds, kind).unwrap();
        footprints.push((label, ix.memory_bytes()));
        rows.push(bench(&cfg, label, move || {
            let rep = run_session(&coord, &ds, Method::Oseba, kind, &periods, 0, false).unwrap();
            std::hint::black_box(rep.stats.len());
        }));
    }
    println!("{}", table(&rows));
    for (label, b) in &footprints {
        println!("  {label:<20} metadata footprint: {b} bytes");
    }
    doc.push(("a4_index_kind", rows_json(&rows)));
    doc.push((
        "a4_index_footprint_bytes",
        Json::arr(
            footprints
                .iter()
                .map(|&(label, b)| {
                    Json::obj(vec![
                        ("name", Json::str(label)),
                        ("bytes", Json::num(b as f64)),
                    ])
                })
                .collect(),
        ),
    ));
    common::write_bench_json("ablations", Json::obj(doc));
}
