//! Degraded-mode serving end to end (DESIGN.md §16): a query over a
//! store whose segment fails verification must still answer — exact on
//! the non-quarantined remainder, with the gap surfaced through
//! `Explain::degraded` / `BatchReport::degraded` — while strict mode
//! restores the hard error. The oracle is the identical query run over
//! a fully resident copy of the surviving selection.

use std::sync::Arc;

use oseba::analysis::PeriodStats;
use oseba::config::{AppConfig, ContextConfig};
use oseba::coordinator::{Coordinator, IndexKind, Query, QueryOutput};
use oseba::datagen::ClimateGen;
use oseba::error::OsebaError;
use oseba::index::RangeQuery;
use oseba::metrics::PlanPhase;
use oseba::runtime::NativeBackend;
use oseba::storage::partition_batch_uniform;
use oseba::store::{StoreManifest, TieredStore};
use oseba::testing::temp_dir;

const H: i64 = 3_600;

fn coordinator() -> Coordinator {
    let cfg = AppConfig {
        ctx: ContextConfig { num_workers: 4, memory_budget: None },
        cluster_workers: 3,
        ..Default::default()
    };
    Coordinator::new(&cfg, Arc::new(NativeBackend)).unwrap()
}

fn assert_bit_equal(a: &PeriodStats, b: &PeriodStats, ctx: &str) {
    assert_eq!(a.count, b.count, "{ctx}: count");
    assert_eq!(a.max.to_bits(), b.max.to_bits(), "{ctx}: max");
    assert_eq!(a.min.to_bits(), b.min.to_bits(), "{ctx}: min");
    assert_eq!(a.mean.to_bits(), b.mean.to_bits(), "{ctx}: mean");
    assert_eq!(a.std.to_bits(), b.std.to_bits(), "{ctx}: std");
}

/// Save a generated dataset as a segment store under `dir`, then flip
/// one byte in the middle of partition `victim`'s segment so its first
/// scan fails CRC verification and quarantines it.
fn save_corrupted_store(
    dir: &std::path::Path,
    rows: usize,
    nparts: usize,
    seed: u64,
    victim: usize,
) {
    let batch = ClimateGen { seed, ..Default::default() }.generate(rows);
    let store = TieredStore::create(
        dir,
        batch.schema.clone(),
        oseba::engine::MemoryTracker::unbounded(),
    )
    .unwrap();
    for part in partition_batch_uniform(&batch, rows.div_ceil(nparts)).unwrap() {
        store.insert(part).unwrap();
    }
    store.save().unwrap();

    let manifest = StoreManifest::load(dir).unwrap();
    let path = dir.join(&manifest.segments[victim].file);
    let mut bytes = std::fs::read(&path).unwrap();
    let off = bytes.len() * 3 / 5;
    bytes[off] ^= 0x01;
    std::fs::write(&path, &bytes).unwrap();
}

#[test]
fn quarantined_partition_degrades_query_and_matches_remainder_oracle() {
    // 12 000 rows over 6 partitions of 2 000 rows: partition 2 holds rows
    // 4 000..6 000 → keys 4 000h..5 999h. Its segment is corrupted on
    // disk before open.
    let rows = 12_000;
    let dir = temp_dir("faults-degraded");
    save_corrupted_store(&dir, rows, 6, 0xFA17, 2);

    let c = coordinator();
    let (ds, index) = c.open_store(&dir).unwrap();
    let store = ds.store().unwrap().clone();

    // [3 000h, 4 500h] needs scans of partition 1 (rows 3 000..4 000) and
    // corrupt partition 2 (rows 4 000..4 501). The first execution hits
    // the CRC failure mid-query, retries, quarantines, and answers from
    // the remainder.
    let q = Query::stats(RangeQuery { lo: 3_000 * H, hi: 4_500 * H }, 0);
    let before = store.counters();
    let (out, explain) = c.execute_plan(&ds, index.as_ref(), &q).unwrap();
    let QueryOutput::Stats(got) = out else { panic!("stats output") };
    assert_eq!(explain.degraded, 1, "one slice served degraded");
    let d = store.counters().since(&before);
    assert_eq!(d.quarantined, 1, "the corrupt partition was quarantined");
    assert!(d.io_retries >= 1, "verification failure was retried first");
    assert!(d.recovery_nanos > 0, "recovery time was accounted");
    assert_eq!(store.quarantined_ids(), vec![2]);
    assert!(ds.quarantined(2) && !ds.quarantined(1));
    assert!(c.context().counters().degraded_answers >= 1);
    assert!(
        c.context().metrics().phase(PlanPhase::FaultRecovery).count() >= 1,
        "fault-recovery phase histogram saw the affected query"
    );

    // Oracle: the same selection minus the quarantined partition, on a
    // fully resident dataset — keys 3 000h..3 999h survive.
    let cr = coordinator();
    let rds = cr
        .load(ClimateGen { seed: 0xFA17, ..Default::default() }.generate(rows), 6)
        .unwrap();
    let rindex = cr.build_index(&rds, IndexKind::Cias).unwrap();
    let want = cr
        .analyze_period_oseba(
            &rds,
            rindex.as_ref(),
            RangeQuery { lo: 3_000 * H, hi: 3_999 * H },
            0,
        )
        .unwrap();
    assert_bit_equal(&got, &want, "degraded vs remainder oracle");

    // Re-running the same query now degrades at *plan* time: the lowering
    // drops the known-quarantined slice, execution never touches it, and
    // the answer is unchanged.
    let (out, explain) = c.execute_plan(&ds, index.as_ref(), &q).unwrap();
    let QueryOutput::Stats(again) = out else { panic!("stats output") };
    assert_eq!(explain.degraded, 1, "plan-time degraded accounting");
    assert_bit_equal(&again, &got, "plan-time vs execution-time degraded");

    // A fully-covered query is still answered *exactly*: the manifest
    // sketches were retained through quarantine, so the quarantined
    // partition contributes its aggregate with zero data touch.
    let full = Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0);
    let (out, explain) = c.execute_plan(&ds, index.as_ref(), &full).unwrap();
    let QueryOutput::Stats(covered) = out else { panic!("stats output") };
    assert_eq!(explain.degraded, 0, "sketch coverage avoids degradation");
    assert_eq!(covered.count, rows as u64);
    let wantf = cr
        .analyze_period_oseba(&rds, rindex.as_ref(), RangeQuery { lo: 0, hi: i64::MAX }, 0)
        .unwrap();
    assert_bit_equal(&covered, &wantf, "covered query over quarantined store");

    // A selection entirely inside the quarantined partition has no
    // remainder to serve — that stays an error, not a silent zero.
    let inside = Query::stats(RangeQuery { lo: 4_100 * H, hi: 4_200 * H }, 0);
    assert!(c.execute_plan(&ds, index.as_ref(), &inside).is_err());

    // Strict mode restores the hard error for the partially-covering
    // query; lifting it restores the degraded answer.
    store.set_strict(true);
    let err = c.execute_plan(&ds, index.as_ref(), &q).unwrap_err();
    assert!(matches!(err, OsebaError::Store(_)), "got: {err:?}");
    assert!(err.to_string().contains("quarantined"), "got: {err}");
    store.set_strict(false);
    let (out, _) = c.execute_plan(&ds, index.as_ref(), &q).unwrap();
    let QueryOutput::Stats(relaxed) = out else { panic!("stats output") };
    assert_bit_equal(&relaxed, &got, "strict off again");
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn batch_report_carries_degraded_count() {
    // Partition 3 of 5 (rows 6 000..8 000) is corrupt. A batch mixing a
    // clean query with one that needs the corrupt partition's data must
    // answer both — the clean one bit-identical to the resident oracle,
    // the other degraded — and report the gap.
    let rows = 10_000;
    let dir = temp_dir("faults-batch");
    save_corrupted_store(&dir, rows, 5, 0xBA7C4, 3);

    let c = coordinator();
    let (ds, index) = c.open_store(&dir).unwrap();
    let qs = vec![
        RangeQuery { lo: 500 * H, hi: 1_500 * H },
        RangeQuery { lo: 5_500 * H, hi: 6_500 * H },
    ];
    let (got, report) =
        c.analyze_batch_with_report(&ds, index.as_ref(), &qs, 0).unwrap();
    assert_eq!(report.degraded, 1, "one selection degraded in the batch");
    assert_eq!(ds.store().unwrap().quarantined_ids(), vec![3]);

    // Oracle: the same batch on a fully resident dataset, with the
    // degraded selection trimmed to its surviving keys 5 500h..5 999h
    // (partition 2's half) — the same elementary-segment merge shape.
    let cr = coordinator();
    let rds = cr
        .load(ClimateGen { seed: 0xBA7C4, ..Default::default() }.generate(rows), 5)
        .unwrap();
    let rindex = cr.build_index(&rds, IndexKind::Cias).unwrap();
    let oracle_qs = vec![qs[0], RangeQuery { lo: 5_500 * H, hi: 5_999 * H }];
    let want = cr.analyze_batch(&rds, rindex.as_ref(), &oracle_qs, 0).unwrap();
    assert_bit_equal(&got[0], &want[0], "clean batch entry");
    assert_bit_equal(&got[1], &want[1], "degraded batch entry");
    std::fs::remove_dir_all(&dir).unwrap();
}
