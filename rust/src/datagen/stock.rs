//! Stock-tick generator for the moving-average / distance examples
//! (paper §II: "a 10-day MA would average out the closing prices of a
//! stock…", "stock price prediction").
//!
//! Prices follow a geometric random walk with mild mean-reversion and
//! regime-switching volatility; volume is spiky log-normal. Keys are a
//! regular per-minute grid.

use crate::storage::{BatchBuilder, RecordBatch, Schema};
use crate::util::rng::Xoshiro256;

/// Configurable stock-tick generator.
#[derive(Clone, Debug)]
pub struct StockGen {
    /// RNG seed (deterministic output per seed).
    pub seed: u64,
    /// First key (seconds).
    pub start_key: i64,
    /// Key step (seconds). 60 = per-minute bars.
    pub step_secs: i64,
    /// Initial price.
    pub s0: f64,
    /// Per-step drift.
    pub drift: f64,
    /// Base per-step volatility.
    pub vol: f64,
}

impl Default for StockGen {
    fn default() -> Self {
        StockGen { seed: 0x570C4, start_key: 0, step_secs: 60, s0: 100.0, drift: 1e-6, vol: 4e-4 }
    }
}

impl StockGen {
    /// Generate `rows` bars.
    pub fn generate(&self, rows: usize) -> RecordBatch {
        let mut rng = Xoshiro256::seeded(self.seed);
        let mut b = BatchBuilder::with_capacity(Schema::stock(), rows);
        let mut logp = self.s0.ln();
        let mut vol_regime = 1.0f64;
        for i in 0..rows {
            let key = self.start_key + i as i64 * self.step_secs;
            // Occasional volatility regime switch.
            if rng.next_f64() < 0.001 {
                vol_regime = if vol_regime > 1.5 { 1.0 } else { 3.0 };
            }
            logp += self.drift + self.vol * vol_regime * rng.normal();
            // Soft mean reversion keeps long runs bounded.
            logp += 1e-5 * (self.s0.ln() - logp);
            let vol_shares = (rng.normal_with(0.0, 1.0).exp() * 1e4).min(1e7);
            b.push(key, &[logp.exp() as f32, vol_shares as f32]);
        }
        b.finish().expect("sorted keys by construction")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let g = StockGen::default();
        assert_eq!(g.generate(100).columns[0], g.generate(100).columns[0]);
    }

    #[test]
    fn prices_positive_and_bounded() {
        let rb = StockGen::default().generate(50_000);
        let prices = rb.column("price").unwrap();
        assert!(prices.iter().all(|&p| p > 0.0));
        // Mean reversion keeps prices within an order of magnitude of s0.
        assert!(prices.iter().all(|&p| (10.0..1000.0).contains(&p)));
    }

    #[test]
    fn regular_minute_grid() {
        let rb = StockGen::default().generate(1000);
        assert!(rb.keys.windows(2).all(|w| w[1] - w[0] == 60));
    }

    #[test]
    fn volume_positive() {
        let rb = StockGen::default().generate(5000);
        assert!(rb.column("volume").unwrap().iter().all(|&v| v > 0.0));
    }
}
