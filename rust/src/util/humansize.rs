//! Byte-count and duration pretty-printing for logs, bench tables and the
//! Fig 4 memory report.

/// Format a byte count with a binary-prefix unit, e.g. `1536 → "1.50 KiB"`.
pub fn bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.2} {}", UNITS[u])
    }
}

/// Format seconds adaptively: `0.0000012 → "1.20 µs"`, `75.0 → "75.0 s"`.
pub fn secs(t: f64) -> String {
    if t < 1e-6 {
        format!("{:.0} ns", t * 1e9)
    } else if t < 1e-3 {
        format!("{:.2} µs", t * 1e6)
    } else if t < 1.0 {
        format!("{:.2} ms", t * 1e3)
    } else {
        format!("{t:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_units() {
        assert_eq!(bytes(0), "0 B");
        assert_eq!(bytes(512), "512 B");
        assert_eq!(bytes(1536), "1.50 KiB");
        assert_eq!(bytes(32 * 1024 * 1024), "32.00 MiB");
        assert_eq!(bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn secs_units() {
        assert_eq!(secs(2.5), "2.50 s");
        assert_eq!(secs(0.0025), "2.50 ms");
        assert_eq!(secs(2.5e-6), "2.50 µs");
        assert_eq!(secs(5e-9), "5 ns");
    }
}
