//! **Point-lookup bench**: equality predicates (`col == v`) over a tiered
//! dataset ~4× the memory budget, where the value column is a permutation
//! of the row index — every partition's zone map spans essentially the
//! whole value domain (zone pruning is blind), but each probe value lives
//! in exactly one partition. Per-partition membership filters prune from
//! resident metadata **before fault-in**, so a needle query faults O(1)
//! partitions instead of all of them.
//!
//! Two arms, identical queries, cold cache each run:
//!   * zone-only  — `PlanOptions { filter_pruning: false, .. }`
//!   * filter-on  — the default plan
//! plus a measured false-positive-rate curve vs `fbits` for the filter
//! itself, checked against its analytic bound.
//!
//! Emits `BENCH_point_lookup.json` (faults, bytes read, partitions
//! targeted, wall time per arm; the FPR curve) for the perf trajectory.
//!
//! Run: `cargo bench --bench point_lookup`
//! (OSEBA_POINT_LOOKUP_BUDGET rescales; dataset is 4× the budget.)

mod common;

use oseba::bench::{bench, section, table, BenchConfig};
use oseba::config::{parse_bytes, BackendKind, ContextConfig};
use oseba::coordinator::{
    plan_query_opts, Coordinator, PlanOptions, Query, QueryOutput,
};
use oseba::engine::Dataset;
use oseba::index::{ColumnPredicate, FilterBuilder, PredOp, RangeQuery};
use oseba::runtime::make_backend;
use oseba::storage::{BatchBuilder, Schema};
use oseba::util::humansize;
use oseba::util::json::Json;

const PARTITIONS: usize = 32;
/// Multiplicative step of the value permutation (prime, so it is coprime
/// with any domain size that is not a multiple of it).
const STEP: u64 = 37;

fn coordinator(budget: usize) -> Coordinator {
    let mut cfg = common::app_cfg(BackendKind::Native);
    cfg.ctx = ContextConfig { num_workers: 4, memory_budget: Some(budget) };
    let be = make_backend(cfg.backend, &cfg.artifacts_dir).expect("backend");
    Coordinator::new(&cfg, be).expect("coordinator")
}

/// `price[i] = (i * STEP) % domain` — a permutation of `0..domain` when
/// `gcd(STEP, domain) = 1`. Consecutive rows jump by STEP and wrap, so a
/// partition of contiguous rows sees values spread over the whole domain
/// (zone maps are useless for equality), yet each value occurs in only
/// `rows / domain` ≈ 1 partition.
fn permuted_batch(rows: usize, domain: u64) -> oseba::storage::RecordBatch {
    let mut b = BatchBuilder::new(Schema::stock());
    for i in 0..rows as u64 {
        let price = (i * STEP % domain) as f32;
        b.push(i as i64, &[price, 7.0]);
    }
    b.finish().unwrap()
}

fn run_stats(
    c: &Coordinator,
    ds: &Dataset,
    plan: &oseba::coordinator::PhysicalPlan,
    q: &Query,
) -> oseba::analysis::PeriodStats {
    match c.execute_physical(ds, plan, q).expect("execute") {
        QueryOutput::Stats(s) => s,
        _ => unreachable!(),
    }
}

fn needle_query(value: f32) -> Query {
    Query::stats(RangeQuery { lo: 0, hi: i64::MAX }, 0).filtered(vec![
        ColumnPredicate { column: 0, op: PredOp::Eq, value },
    ])
}

fn main() {
    let budget = std::env::var("OSEBA_POINT_LOOKUP_BUDGET")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_POINT_LOOKUP_BUDGET"))
        .unwrap_or(8 << 20);
    let raw = 4 * budget;
    let mut rows = raw / Schema::stock().row_bytes();
    if rows as u64 % STEP == 0 {
        rows += 1; // keep gcd(STEP, domain) = 1
    }
    // Values must be exactly representable as f32 integers.
    let domain = (rows as u64).min((1 << 24) - 1);
    let dir =
        std::env::temp_dir().join(format!("oseba-point-lookup-bench-{}", std::process::id()));

    section(&format!(
        "Point lookups: {} tiered dataset under a {} budget ({} partitions)",
        humansize::bytes(raw),
        humansize::bytes(budget),
        PARTITIONS
    ));

    let coord = coordinator(budget);
    let ds = coord
        .load_tiered(permuted_batch(rows, domain), PARTITIONS, &dir)
        .expect("tiered load");
    let store = ds.store().expect("tiered").clone();
    let index = coord
        .build_index(&ds, oseba::coordinator::IndexKind::Cias)
        .expect("index");
    println!(
        "  filters: {} across {} partitions",
        humansize::bytes(ds.filter_bytes()),
        PARTITIONS
    );
    assert!(ds.filter_bytes() > 0, "tiered load must build membership filters");

    // 8 present needles spread across the key space, plus their absent
    // twins (x + 0.5 never occurs: every stored value is an integer).
    let present: Vec<f32> = (0..8u64)
        .map(|p| ((p * rows as u64 / 8 + 123) * STEP % domain) as f32)
        .collect();
    let absent: Vec<f32> = present.iter().map(|v| v + 0.5).collect();
    let needles: Vec<f32> = present.iter().chain(absent.iter()).copied().collect();

    let zone_only =
        PlanOptions {
            zone_pruning: true,
            filter_pruning: false,
            agg_pushdown: true,
            block_pruning: true,
        };
    let filter_on = PlanOptions::default();

    // Correctness first, cold cache: identical answers from both arms on
    // present needles; identical (zero) match counts on absent ones. The
    // moment fields of an empty selection are NaN, so absent needles
    // compare counts only.
    for (k, &v) in needles.iter().enumerate() {
        let q = needle_query(v);
        let zp = plan_query_opts(&ds, index.as_ref(), &q, zone_only).expect("plan");
        let fp = plan_query_opts(&ds, index.as_ref(), &q, filter_on).expect("plan");
        store.shrink(usize::MAX).expect("evict all");
        let want = run_stats(&coord, &ds, &zp, &q);
        store.shrink(usize::MAX).expect("evict all");
        let got = run_stats(&coord, &ds, &fp, &q);
        assert!(
            fp.explain.targeted <= 4,
            "needle {v} must touch O(1) partitions: {:?}",
            fp.explain
        );
        if k < present.len() {
            assert!(want.count >= 1, "present needle {v} must match");
            assert_eq!(got, want, "filter pruning must not change results");
        } else {
            assert_eq!(want.count, 0, "absent needle {v} must not match");
            assert_eq!(got.count, want.count);
        }
    }

    // Counters + wall time per arm: all needles, cold cache per pass.
    let cfg = BenchConfig::from_env();
    let mut results = Vec::new();
    let mut json_arms = Vec::new();
    for (name, opts) in [("zone-map-only", zone_only), ("membership-filters", filter_on)] {
        let plans: Vec<(Query, oseba::coordinator::PhysicalPlan)> = needles
            .iter()
            .map(|&v| {
                let q = needle_query(v);
                let p = plan_query_opts(&ds, index.as_ref(), &q, opts).expect("plan");
                (q, p)
            })
            .collect();
        let targeted: usize = plans.iter().map(|(_, p)| p.explain.targeted).sum();
        let filter_pruned: usize = plans.iter().map(|(_, p)| p.explain.filter_pruned).sum();

        store.shrink(usize::MAX).expect("evict all");
        let before = store.counters();
        for (q, p) in &plans {
            run_stats(&coord, &ds, p, q);
        }
        let delta = store.counters().since(&before);

        let r = bench(&cfg, name, || {
            store.shrink(usize::MAX).expect("evict all");
            for (q, p) in &plans {
                run_stats(&coord, &ds, p, q);
            }
        });
        println!(
            "  {name}: {} faults, {} read, {} partitions targeted, {} filter-pruned",
            delta.faults,
            humansize::bytes(delta.segment_bytes_read),
            targeted,
            filter_pruned
        );
        json_arms.push(Json::obj(vec![
            ("name", Json::str(name)),
            ("faults", Json::num(delta.faults as f64)),
            ("segment_bytes_read", Json::num(delta.segment_bytes_read as f64)),
            ("partitions_targeted", Json::num(targeted as f64)),
            ("filter_pruned", Json::num(filter_pruned as f64)),
            ("needles", Json::num(needles.len() as f64)),
            ("secs_mean", Json::num(r.summary.mean)),
            ("secs_p50", Json::num(r.summary.p50)),
            ("secs_p95", Json::num(r.summary.p95)),
        ]));
        results.push(r);
    }
    println!("\n{}", table(&results));

    // The acceptance gate: fewer faults, fewer bytes, same answers.
    let (zone, filt) = (&json_arms[0], &json_arms[1]);
    let f = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_f64()).unwrap();
    assert!(
        f(filt, "faults") < f(zone, "faults") / 4.0,
        "filters must fault in far fewer partitions ({} vs {})",
        f(filt, "faults"),
        f(zone, "faults")
    );
    assert!(
        f(filt, "segment_bytes_read") < f(zone, "segment_bytes_read"),
        "filters must read fewer segment bytes"
    );

    // Measured FPR vs bits/key: 100k distinct integer values in, 100k
    // never-inserted probes (x + 0.5), against the analytic bound
    // 2·SLOTS/2^fbits. Growth leaves the table ≥ half loaded, so the
    // measured rate sits below the full-table bound.
    section("False-positive rate vs fingerprint bits");
    let n = 100_000u32;
    let mut fpr_curve = Vec::new();
    for fbits in [6u32, 8, 10, 12, 14, 16] {
        let mut b = FilterBuilder::new(fbits);
        for i in 0..n {
            b.insert(i as f32);
        }
        let filter = b.finish();
        let false_pos =
            (0..n).filter(|&i| filter.contains(i as f32 + 0.5)).count();
        let measured = false_pos as f64 / n as f64;
        let bound = filter.fpr_bound();
        let bits_per_key = filter.memory_bytes() as f64 * 8.0 / filter.len() as f64;
        println!(
            "  fbits={fbits:2}: measured {measured:.5}, bound {bound:.5}, {bits_per_key:.1} bits/key"
        );
        assert!(
            measured <= bound + 0.003,
            "fbits={fbits}: measured FPR {measured} exceeds bound {bound}"
        );
        fpr_curve.push(Json::obj(vec![
            ("fbits", Json::num(fbits as f64)),
            ("measured_fpr", Json::num(measured)),
            ("fpr_bound", Json::num(bound)),
            ("bits_per_key", Json::num(bits_per_key)),
        ]));
    }

    common::write_bench_json(
        "point_lookup",
        Json::obj(vec![
            ("bench", Json::str("point_lookup")),
            ("raw_bytes", Json::num(raw as f64)),
            ("budget_bytes", Json::num(budget as f64)),
            ("partitions", Json::num(PARTITIONS as f64)),
            ("rows", Json::num(rows as f64)),
            ("filter_bytes", Json::num(ds.filter_bytes() as f64)),
            ("arms", Json::arr(json_arms)),
            ("fpr_curve", Json::arr(fpr_curve)),
        ]),
    );

    coord.context().unpersist(&ds);
    let _ = std::fs::remove_dir_all(&dir);
}
