"""AOT lowering smoke tests: HLO text is produced, parseable-looking, and
the manifest describes every entry with the shapes rust expects."""

import json
import os

import pytest

pytest.importorskip("jax")
from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    manifest = aot.build(str(out))
    return str(out), manifest


def test_manifest_contract(built):
    out, manifest = built
    assert manifest["block_rows"] == model.BLOCK_ROWS
    assert manifest["hist_bins"] == model.HIST_BINS
    assert sorted(manifest["ma_windows"]) == sorted(model.MA_WINDOWS)
    assert len(manifest["fingerprint"]) == 16
    assert set(manifest["entries"]) == set(model.entries())


def test_hlo_files_exist_and_are_hlo_text(built):
    out, manifest = built
    for name, ent in manifest["entries"].items():
        path = os.path.join(out, ent["file"])
        assert os.path.exists(path), name
        text = open(path).read()
        assert "HloModule" in text, name
        assert "ENTRY" in text, name


def test_manifest_shapes(built):
    _, manifest = built
    ent = manifest["entries"]["segment_stats"]
    assert ent["params"][0] == {"shape": [model.BLOCK_ROWS],
                                "dtype": "float32"}
    assert ent["params"][1]["dtype"] == "int32"
    assert len(ent["results"]) == 5
    ent = manifest["entries"]["histogram64"]
    assert ent["results"][0]["shape"] == [model.HIST_BINS]


def test_manifest_json_roundtrip(built):
    out, manifest = built
    loaded = json.load(open(os.path.join(out, "manifest.json")))
    assert loaded == json.loads(json.dumps(manifest))


def test_fingerprint_stable(built):
    _, manifest = built
    assert aot.source_fingerprint() == manifest["fingerprint"]
