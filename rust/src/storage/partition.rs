//! `Partition`: the in-memory distributed block (the paper's "rdd" block).
//!
//! Value columns are zero-padded to a multiple of [`BLOCK_ROWS`] so every
//! kernel dispatch operates on a full, static-shaped block (the AOT
//! contract, DESIGN.md §3). Keys are kept unpadded; `rows` is the valid
//! count.

use std::sync::Arc;

use crate::error::{OsebaError, Result};
use crate::index::filter::MembershipFilter;
use crate::index::types::{BlockSketches, ColumnSketch, ZoneMap};
use crate::storage::batch::RecordBatch;

/// Rows per kernel block — must match `python/compile/kernels/BLOCK_ROWS`.
pub const BLOCK_ROWS: usize = 4096;

/// One in-memory data partition of a dataset.
#[derive(Clone, Debug)]
pub struct Partition {
    /// Partition index within its dataset.
    pub id: usize,
    /// Ordering keys of the valid rows (`len == rows`).
    pub keys: Vec<i64>,
    /// Padded value columns (`len == padded_rows` each).
    pub columns: Vec<Vec<f32>>,
    /// Valid row count.
    pub rows: usize,
    /// `rows` rounded up to a multiple of `BLOCK_ROWS`.
    pub padded_rows: usize,
    /// Per-column **aggregate sketches** over the valid rows (padding
    /// excluded), computed once at construction: full moments partials
    /// (superseding the min/max-only zone maps, which [`Self::zone_maps`]
    /// derives from them) plus linear-trend regression partials. The
    /// planner answers fully-covered partitions from these without
    /// touching the data. Excluded from [`Self::bytes`] (metadata, not
    /// storage-budget data). Moments are folded with the kernel-block
    /// algorithm, so a sketch is bit-identical to a full scan's partial.
    pub sketches: Vec<ColumnSketch>,
    /// Per-column **membership filters** over the valid rows (padding and
    /// NaNs excluded), built once at seal time: growable cuckoo filters
    /// over exact f32 bit patterns that the planner probes for equality
    /// predicates (`col == v`) — a `false` proves the partition holds no
    /// matching row and prunes it without a scan (DESIGN.md §14). Shared
    /// via `Arc` so the tiered store's slot table keeps them resident
    /// after the data itself is evicted. Metadata, excluded from
    /// [`Self::bytes`] like the sketches.
    pub filters: Arc<Vec<MembershipFilter>>,
    /// Per-column **block sketches**: the per-[`BLOCK_ROWS`]-block
    /// [`crate::util::stats::Moments`] partials the merged [`Self::sketches`]
    /// are folded from, retained at seal time (DESIGN.md §15). The
    /// executor answers fully-selected, predicate-free blocks by merging
    /// these, and skips blocks whose block-level zones cannot satisfy a
    /// predicate conjunction. Shared via `Arc` for the same reason as the
    /// filters; metadata, excluded from [`Self::bytes`].
    pub block_sketches: Arc<BlockSketches>,
}

impl Partition {
    /// Build a partition from row range `[lo, hi)` of a batch.
    pub fn from_batch_range(id: usize, batch: &RecordBatch, lo: usize, hi: usize) -> Partition {
        let rows = hi - lo;
        let padded_rows = rows.div_ceil(BLOCK_ROWS).max(1) * BLOCK_ROWS;
        let keys = batch.keys[lo..hi].to_vec();
        let mut sketches = Vec::with_capacity(batch.columns.len());
        let mut block_cols = Vec::with_capacity(batch.columns.len());
        for c in &batch.columns {
            let (sk, b) = ColumnSketch::with_blocks(&keys, &c[lo..hi], BLOCK_ROWS);
            sketches.push(sk);
            block_cols.push(b);
        }
        let block_sketches = Arc::new(BlockSketches::from_parts(BLOCK_ROWS, block_cols));
        let filters = Arc::new(
            batch.columns.iter().map(|c| MembershipFilter::build(&c[lo..hi])).collect(),
        );
        let columns = batch
            .columns
            .iter()
            .map(|c| {
                let mut v = Vec::with_capacity(padded_rows);
                v.extend_from_slice(&c[lo..hi]);
                v.resize(padded_rows, 0.0);
                v
            })
            .collect();
        Partition { id, keys, columns, rows, padded_rows, sketches, filters, block_sketches }
    }

    /// Build directly from owned columns (used by the filter baseline when
    /// materializing a filtered partition).
    pub fn from_rows(id: usize, keys: Vec<i64>, mut columns: Vec<Vec<f32>>) -> Partition {
        let rows = keys.len();
        let padded_rows = rows.div_ceil(BLOCK_ROWS).max(1) * BLOCK_ROWS;
        let mut sketches = Vec::with_capacity(columns.len());
        let mut block_cols = Vec::with_capacity(columns.len());
        for c in &columns {
            let (sk, b) = ColumnSketch::with_blocks(&keys, &c[..rows], BLOCK_ROWS);
            sketches.push(sk);
            block_cols.push(b);
        }
        let block_sketches = Arc::new(BlockSketches::from_parts(BLOCK_ROWS, block_cols));
        let filters =
            Arc::new(columns.iter().map(|c| MembershipFilter::build(&c[..rows])).collect());
        for c in &mut columns {
            debug_assert_eq!(c.len(), rows);
            c.resize(padded_rows, 0.0);
        }
        Partition { id, keys, columns, rows, padded_rows, sketches, filters, block_sketches }
    }

    /// Per-column zone maps (min/max/nans), derived from the aggregate
    /// sketches — the value-domain metadata predicate pruning consults.
    pub fn zone_maps(&self) -> Vec<ZoneMap> {
        self.sketches.iter().map(ColumnSketch::zone).collect()
    }

    /// Smallest key (None when empty).
    pub fn key_min(&self) -> Option<i64> {
        self.keys.first().copied()
    }

    /// Largest key (None when empty).
    pub fn key_max(&self) -> Option<i64> {
        self.keys.last().copied()
    }

    /// Number of `BLOCK_ROWS`-sized kernel blocks.
    pub fn num_blocks(&self) -> usize {
        self.padded_rows / BLOCK_ROWS
    }

    /// Byte footprint as accounted by the block manager: unpadded keys plus
    /// padded value columns.
    pub fn bytes(&self) -> usize {
        self.keys.len() * 8 + self.columns.iter().map(|c| c.len() * 4).sum::<usize>()
    }

    /// The `b`-th kernel block of a column (always exactly `BLOCK_ROWS` long).
    pub fn block(&self, column: usize, b: usize) -> &[f32] {
        &self.columns[column][b * BLOCK_ROWS..(b + 1) * BLOCK_ROWS]
    }

    /// Locate the first valid row with `key >= k` (binary search; used by
    /// the engine to slice targeted partitions).
    pub fn lower_bound(&self, k: i64) -> usize {
        self.keys.partition_point(|&x| x < k)
    }

    /// Locate the first valid row with `key > k`.
    pub fn upper_bound(&self, k: i64) -> usize {
        self.keys.partition_point(|&x| x <= k)
    }
}

/// Split a batch into `num_partitions` near-equal contiguous partitions —
/// the "load/reside the data into memory" step (paper §IV-A: 480 MB into
/// 15 partitions).
pub fn partition_batch(batch: &RecordBatch, num_partitions: usize) -> Result<Vec<Arc<Partition>>> {
    if num_partitions == 0 {
        return Err(OsebaError::Schema("num_partitions must be > 0".into()));
    }
    let rows = batch.rows();
    if rows == 0 {
        return Err(OsebaError::Schema("cannot partition an empty batch".into()));
    }
    let per = rows.div_ceil(num_partitions);
    let mut parts = Vec::new();
    let mut lo = 0usize;
    let mut id = 0usize;
    while lo < rows {
        let hi = (lo + per).min(rows);
        parts.push(Arc::new(Partition::from_batch_range(id, batch, lo, hi)));
        id += 1;
        lo = hi;
    }
    Ok(parts)
}

/// Split a batch so every partition holds exactly `rows_per_partition` rows
/// (except a shorter tail). This is the regular layout CIAS compresses —
/// the paper's assumption (1): "distributed blocks in Spark usually have
/// the same size".
pub fn partition_batch_uniform(
    batch: &RecordBatch,
    rows_per_partition: usize,
) -> Result<Vec<Arc<Partition>>> {
    if rows_per_partition == 0 {
        return Err(OsebaError::Schema("rows_per_partition must be > 0".into()));
    }
    let rows = batch.rows();
    if rows == 0 {
        return Err(OsebaError::Schema("cannot partition an empty batch".into()));
    }
    let n = rows.div_ceil(rows_per_partition);
    let mut parts = Vec::with_capacity(n);
    for id in 0..n {
        let lo = id * rows_per_partition;
        let hi = ((id + 1) * rows_per_partition).min(rows);
        parts.push(Arc::new(Partition::from_batch_range(id, batch, lo, hi)));
    }
    Ok(parts)
}

/// Unused-capacity check shared by tests: all partitions cover the batch,
/// in order, without overlap.
pub fn partitions_cover(parts: &[Arc<Partition>], total_rows: usize) -> bool {
    parts.iter().map(|p| p.rows).sum::<usize>() == total_rows
        && parts.iter().enumerate().all(|(i, p)| p.id == i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::storage::batch::BatchBuilder;
    use crate::storage::schema::Schema;

    fn batch(rows: usize) -> RecordBatch {
        let mut b = BatchBuilder::new(Schema::stock());
        for i in 0..rows {
            b.push(1000 + i as i64 * 10, &[i as f32, (i * 2) as f32]);
        }
        b.finish().unwrap()
    }

    #[test]
    fn partition_padding_and_blocks() {
        let rb = batch(5000);
        let p = Partition::from_batch_range(0, &rb, 0, 5000);
        assert_eq!(p.rows, 5000);
        assert_eq!(p.padded_rows, 2 * BLOCK_ROWS);
        assert_eq!(p.num_blocks(), 2);
        assert_eq!(p.block(0, 0).len(), BLOCK_ROWS);
        // Padding is zero.
        assert!(p.columns[0][5000..].iter().all(|&x| x == 0.0));
        // Valid data preserved.
        assert_eq!(p.columns[0][4999], 4999.0);
    }

    #[test]
    fn tiny_partition_still_one_block() {
        let rb = batch(3);
        let p = Partition::from_batch_range(0, &rb, 0, 3);
        assert_eq!(p.padded_rows, BLOCK_ROWS);
        assert_eq!(p.num_blocks(), 1);
    }

    #[test]
    fn partition_batch_covers_all_rows() {
        let rb = batch(10_000);
        let parts = partition_batch(&rb, 7).unwrap();
        assert!(partitions_cover(&parts, 10_000));
        assert_eq!(parts.len(), 7);
    }

    #[test]
    fn partition_batch_uniform_layout() {
        let rb = batch(10_000);
        let parts = partition_batch_uniform(&rb, 4096).unwrap();
        assert_eq!(parts.len(), 3);
        assert_eq!(parts[0].rows, 4096);
        assert_eq!(parts[1].rows, 4096);
        assert_eq!(parts[2].rows, 10_000 - 2 * 4096);
        assert!(partitions_cover(&parts, 10_000));
    }

    #[test]
    fn key_bounds_and_search() {
        let rb = batch(100);
        let p = Partition::from_batch_range(0, &rb, 10, 60);
        assert_eq!(p.key_min(), Some(1100));
        assert_eq!(p.key_max(), Some(1590));
        assert_eq!(p.lower_bound(1100), 0);
        assert_eq!(p.lower_bound(1101), 1);
        assert_eq!(p.upper_bound(1590), 50);
        assert_eq!(p.lower_bound(9999), 50);
        assert_eq!(p.lower_bound(0), 0);
    }

    #[test]
    fn sketches_cover_valid_rows_not_padding() {
        let rb = batch(100);
        let p = Partition::from_batch_range(0, &rb, 10, 60);
        assert_eq!(p.sketches.len(), 2);
        let zones = p.zone_maps();
        // Column 0 holds 10.0..=59.0 over the valid rows; padding zeros
        // must not drag min down.
        assert_eq!(zones[0].min, 10.0);
        assert_eq!(zones[0].max, 59.0);
        assert_eq!(zones[0].nans, 0);
        // The sketch moments carry the full fold, not just the bounds.
        assert_eq!(p.sketches[0].moments.count, 50.0);
        assert_eq!(p.sketches[0].moments.sum, (10..60).sum::<i32>() as f64);
        // Keys step by 10, values by 1 → slope 0.1.
        assert!((p.sketches[0].trend.slope().unwrap() - 0.1).abs() < 1e-9);

        let q = Partition::from_rows(
            1,
            vec![1, 2, 3],
            vec![vec![5.0, f32::NAN, -2.0], vec![0.0, 0.0, 0.0]],
        );
        let zones = q.zone_maps();
        assert_eq!(zones[0].min, -2.0);
        assert_eq!(zones[0].max, 5.0);
        assert_eq!(zones[0].nans, 1);
        assert_eq!(q.sketches[0].moments.nans, 1.0);
    }

    #[test]
    fn bytes_accounts_padding() {
        let rb = batch(100);
        let p = Partition::from_batch_range(0, &rb, 0, 100);
        // Sketches, filters, and block sketches are metadata — excluded.
        assert_eq!(p.bytes(), 100 * 8 + 2 * BLOCK_ROWS * 4);
    }

    #[test]
    fn block_sketches_retained_and_consistent() {
        use crate::util::stats::Moments;
        let rb = batch(10_000);
        let p = Partition::from_batch_range(0, &rb, 0, 10_000);
        let bs = &p.block_sketches;
        assert_eq!(bs.block_rows(), BLOCK_ROWS);
        assert_eq!(bs.num_columns(), 2);
        // Blocks cover valid rows only: ceil(10000 / 4096) = 3, even
        // though padding makes three full kernel blocks.
        assert_eq!(bs.num_blocks(), 10_000usize.div_ceil(BLOCK_ROWS));
        for c in 0..2 {
            let merged = (0..bs.num_blocks())
                .map(|b| bs.moments(c, b).unwrap())
                .fold(Moments::EMPTY, Moments::merge);
            assert_eq!(merged, p.sketches[c].moments, "column {c}");
        }
        // from_rows retains them too.
        let q = Partition::from_rows(1, vec![1, 2, 3], vec![vec![5.0, f32::NAN, -2.0]]);
        assert_eq!(q.block_sketches.num_blocks(), 1);
        assert_eq!(q.block_sketches.moments(0, 0).unwrap().nans, 1.0);
    }

    #[test]
    fn zero_partitions_rejected() {
        let rb = batch(10);
        assert!(partition_batch(&rb, 0).is_err());
        assert!(partition_batch_uniform(&rb, 0).is_err());
    }
}
