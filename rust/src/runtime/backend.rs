//! [`AnalysisBackend`] — the per-block kernel interface both execution
//! engines implement:
//!
//! * [`crate::runtime::native::NativeBackend`] — pure rust, mirrors
//!   `python/compile/kernels/ref.py` exactly; needs no artifacts.
//! * [`crate::runtime::service::KernelHandle`] — dispatches to the
//!   AOT-compiled HLO executables on the PJRT service thread (the paper's
//!   three-layer path).
//!
//! All operations are *masked block* operations: `block` is one column
//! block, `[start, end)` delimits the selected rows, and outputs follow the
//! kernel contracts in `python/compile/kernels/` (identity sentinels for
//! empty ranges, zeros outside the valid MA region, ...).

use crate::error::Result;
use crate::util::stats::{DistancePartial, Moments};

/// Block-level analysis kernels.
pub trait AnalysisBackend: Send + Sync {
    /// Implementation name ("native" / "hlo") for metrics and bench labels.
    fn name(&self) -> &'static str;

    /// Required block length, or `None` if any length is accepted.
    fn block_rows(&self) -> Option<usize>;

    /// Masked moments of `block[start..end]`.
    fn segment_stats(&self, block: &[f32], start: usize, end: usize) -> Result<Moments>;

    /// Trailing moving average; output has `block.len()` entries, zero
    /// outside `[start+window-1, end)`.
    fn moving_average(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        window: usize,
    ) -> Result<Vec<f32>>;

    /// Fused moments-of-moving-average (trend statistics).
    fn ma_stats(&self, block: &[f32], start: usize, end: usize, window: usize)
        -> Result<Moments>;

    /// Distance partials between aligned blocks over `[start, end)`.
    fn distance(&self, a: &[f32], b: &[f32], start: usize, end: usize)
        -> Result<DistancePartial>;

    /// 64-bin histogram of `block[start..end]` over `[lo, hi)`.
    fn histogram64(
        &self,
        block: &[f32],
        start: usize,
        end: usize,
        lo: f32,
        hi: f32,
    ) -> Result<Vec<f32>>;

    /// Batched moments over many blocks (amortizes dispatch overhead; the
    /// default loops, the HLO service overrides with one queue submission).
    fn segment_stats_batch(&self, blocks: &[(&[f32], usize, usize)]) -> Result<Vec<Moments>> {
        blocks.iter().map(|(b, s, e)| self.segment_stats(b, *s, *e)).collect()
    }

    /// Execution-engine counters, when the backend keeps them (the HLO
    /// kernel service does; the native backend has none).
    fn service_stats(&self) -> Option<crate::runtime::service::ServiceStats> {
        None
    }
}

/// Shared argument validation for implementations with fixed block length.
pub fn check_block_len(expected: usize, got: usize, what: &str) -> Result<()> {
    if expected != got {
        return Err(crate::error::OsebaError::Runtime(format!(
            "{what}: block length {got} != AOT block_rows {expected}"
        )));
    }
    Ok(())
}
