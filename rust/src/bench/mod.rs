//! Benchmark harness (criterion is not in the vendored set): warmup +
//! timed iterations with robust summary statistics and aligned table
//! output. Used by every target in `rust/benches/`.

use std::time::Instant;

use crate::util::humansize;
use crate::util::stats::Summary;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// Untimed warmup iterations before measuring.
    pub warmup_iters: usize,
    /// Timed iterations contributing samples.
    pub iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_iters: 2, iters: 10 }
    }
}

impl BenchConfig {
    /// Honour `OSEBA_BENCH_ITERS` / `OSEBA_BENCH_WARMUP` env overrides
    /// (handy for quick smoke runs of `cargo bench`).
    pub fn from_env() -> BenchConfig {
        let mut c = BenchConfig::default();
        if let Ok(v) = std::env::var("OSEBA_BENCH_ITERS") {
            if let Ok(n) = v.parse() {
                c.iters = n;
            }
        }
        if let Ok(v) = std::env::var("OSEBA_BENCH_WARMUP") {
            if let Ok(n) = v.parse() {
                c.warmup_iters = n;
            }
        }
        c
    }
}

/// One benchmark's timing result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    /// Benchmark label (table row name).
    pub name: String,
    /// Timing summary over the measured iterations.
    pub summary: Summary,
}

/// Time `f` under the config; `f` is called once per iteration.
pub fn bench<F: FnMut()>(cfg: &BenchConfig, name: &str, mut f: F) -> BenchResult {
    for _ in 0..cfg.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(cfg.iters);
    for _ in 0..cfg.iters.max(1) {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64());
    }
    // lint: allow(no-unwrap) -- `iters.max(1)` guarantees a non-empty sample.
    BenchResult { name: name.to_string(), summary: Summary::of(&samples).unwrap() }
}

/// Render results as an aligned table.
pub fn table(results: &[BenchResult]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<44} {:>10} {:>10} {:>10} {:>10} {:>6}\n",
        "benchmark", "mean", "p50", "p95", "max", "n"
    ));
    for r in results {
        out.push_str(&format!(
            "{:<44} {:>10} {:>10} {:>10} {:>10} {:>6}\n",
            r.name,
            humansize::secs(r.summary.mean),
            humansize::secs(r.summary.p50),
            humansize::secs(r.summary.p95),
            humansize::secs(r.summary.max),
            r.summary.n,
        ));
    }
    out
}

/// Print a labelled section header (bench binaries' stdout structure).
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_expected_iterations() {
        let mut count = 0;
        let cfg = BenchConfig { warmup_iters: 2, iters: 5 };
        let r = bench(&cfg, "noop", || count += 1);
        assert_eq!(count, 7);
        assert_eq!(r.summary.n, 5);
        assert!(r.summary.mean >= 0.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let cfg = BenchConfig { warmup_iters: 0, iters: 3 };
        let rs = vec![bench(&cfg, "a", || {}), bench(&cfg, "b", || {})];
        let t = table(&rs);
        assert!(t.contains("a"));
        assert!(t.contains("b"));
        assert_eq!(t.lines().count(), 3);
    }
}
