//! Observability: experiment metrics, per-query trace spans, latency
//! histograms, and the unified metrics registry.
//!
//! The original instruments live in this module: [`SessionMetrics`] /
//! [`BatchReport`] emit the paper's Fig 4 (accumulated memory) and Fig 6
//! (accumulated time) series, and [`Timer`] is the shared wall-clock.
//! PR 7 grew the subsystem into three layers (see docs/OBSERVABILITY.md):
//!
//! * [`trace`] — per-query span trees and the bounded slow-query log;
//! * [`hist`] — lock-free fixed-bucket log-scale latency histograms
//!   with exact-rank quantile extraction;
//! * [`registry`] — one registry unifying every counter and histogram,
//!   surfaced by the server's `metrics` op.

pub mod hist;
pub mod registry;
pub mod trace;

pub use hist::{bucket_hi, bucket_of, HistSnapshot, LatencyHistogram, BUCKETS};
pub use registry::{MetricsRegistry, PlanPhase, ServerOp, OP_METRICS, PHASE_METRICS};
pub use trace::{phase_mark, sane_secs, SlowEntry, SlowQueryLog, Span, SLOW_LOG_CAPACITY};

use std::time::{Duration, Instant};

use crate::engine::CounterSnapshot;
use crate::util::humansize;
use crate::util::json::Json;

/// One analysis phase's measurements.
#[derive(Clone, Debug)]
pub struct PhaseRecord {
    /// Phase number (1-based, matching the paper's five periods).
    pub phase: usize,
    /// "default" or "oseba".
    pub method: String,
    /// Wall-clock seconds for this phase.
    pub secs: f64,
    /// Total cached bytes *after* the phase (Fig 4 y-axis).
    pub memory_bytes: usize,
    /// Partitions scanned during the phase (baseline cost signal).
    pub partitions_scanned: usize,
    /// Partitions targeted via the index during the phase.
    pub partitions_targeted: usize,
    /// Rows examined by scans.
    pub rows_scanned: usize,
    /// Bytes materialized into filtered datasets.
    pub bytes_materialized: usize,
}

/// Collects phase records for one method run and renders the series.
#[derive(Clone, Debug, Default)]
pub struct SessionMetrics {
    /// Recorded phases, in execution order.
    pub records: Vec<PhaseRecord>,
}

impl SessionMetrics {
    /// An empty collector.
    pub fn new() -> SessionMetrics {
        SessionMetrics::default()
    }

    /// Record a phase from raw observations.
    ///
    /// Counter deltas saturate at zero and `secs` is clamped through
    /// [`sane_secs`]: snapshots taken out of order across threads (or a
    /// zero-width phase) record as zero instead of underflowing.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        phase: usize,
        method: &str,
        secs: f64,
        memory_bytes: usize,
        before: CounterSnapshot,
        after: CounterSnapshot,
    ) {
        self.records.push(PhaseRecord {
            phase,
            method: method.to_string(),
            secs: sane_secs(secs),
            memory_bytes,
            partitions_scanned: after.partitions_scanned.saturating_sub(before.partitions_scanned),
            partitions_targeted: after
                .partitions_targeted
                .saturating_sub(before.partitions_targeted),
            rows_scanned: after.rows_scanned.saturating_sub(before.rows_scanned),
            bytes_materialized: after.bytes_materialized.saturating_sub(before.bytes_materialized),
        });
    }

    /// Accumulated seconds after each phase (Fig 6 series).
    pub fn accumulated_time(&self) -> Vec<f64> {
        let mut acc = 0.0;
        self.records
            .iter()
            .map(|r| {
                acc += r.secs;
                acc
            })
            .collect()
    }

    /// Memory after each phase (Fig 4 series).
    pub fn memory_series(&self) -> Vec<usize> {
        self.records.iter().map(|r| r.memory_bytes).collect()
    }

    /// Render as an aligned text table.
    pub fn table(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<6} {:<8} {:>10} {:>12} {:>8} {:>8} {:>12} {:>12}\n",
            "phase", "method", "time", "acc_time", "scans", "targets", "memory", "materialized"
        ));
        let mut acc = 0.0;
        for r in &self.records {
            acc += r.secs;
            out.push_str(&format!(
                "{:<6} {:<8} {:>10} {:>12} {:>8} {:>8} {:>12} {:>12}\n",
                r.phase,
                r.method,
                humansize::secs(r.secs),
                humansize::secs(acc),
                r.partitions_scanned,
                r.partitions_targeted,
                humansize::bytes(r.memory_bytes),
                humansize::bytes(r.bytes_materialized),
            ));
        }
        out
    }

    /// JSON dump (consumed by EXPERIMENTS.md tooling / plotting).
    pub fn to_json(&self) -> Json {
        Json::arr(
            self.records
                .iter()
                .map(|r| {
                    Json::obj(vec![
                        ("phase", Json::num(r.phase as f64)),
                        ("method", Json::str(r.method.clone())),
                        ("secs", Json::num(r.secs)),
                        ("memory_bytes", Json::num(r.memory_bytes as f64)),
                        ("partitions_scanned", Json::num(r.partitions_scanned as f64)),
                        ("partitions_targeted", Json::num(r.partitions_targeted as f64)),
                        ("rows_scanned", Json::num(r.rows_scanned as f64)),
                        ("bytes_materialized", Json::num(r.bytes_materialized as f64)),
                    ])
                })
                .collect(),
        )
    }
}

/// Per-batch planner/execution counters — the instrument for the
/// concurrent multi-query path (`Coordinator::analyze_batch`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BatchReport {
    /// Queries in the input batch.
    pub queries: usize,
    /// Disjoint merged ranges after planning.
    pub merged_ranges: usize,
    /// Elementary demux segments across all merged ranges.
    pub segments: usize,
    /// Partition slices resolved: one per intersecting partition per
    /// merged range (overlapping queries share a single touch; a
    /// partition hit by several disjoint merged ranges counts once each).
    pub partitions_touched: usize,
    /// Index-proposed slices dropped by zone-map predicate pruning before
    /// resolve (0 for a batch without value predicates).
    pub zone_pruned: usize,
    /// Zone-surviving slices dropped because a per-partition membership
    /// filter proved an equality predicate's probe value absent (0 for a
    /// batch without `==` predicates).
    pub filter_pruned: usize,
    /// Surviving slices answered by merging their partition's aggregate
    /// sketch: the partition lies fully inside one elementary segment, so
    /// no data was read (and no cold segment faulted in) for it.
    pub agg_answered: usize,
    /// Rows never read: sketch answers plus partitions dropped whole by
    /// block-level predicate pruning before resolve.
    pub rows_avoided: usize,
    /// Raw bytes those avoided rows would have occupied.
    pub bytes_avoided: usize,
    /// Blocks answered by merging their retained block partial instead
    /// of folding their rows (block-sketch hierarchy, predicate-free).
    pub blocks_covered: usize,
    /// Blocks skipped because their block-level zones cannot satisfy the
    /// predicate conjunction — including every block of partitions
    /// dropped before resolve.
    pub blocks_pruned: usize,
    /// Worker task dispatches submitted to the pool.
    pub tasks: usize,
    /// Cold partitions faulted in from the tiered store (0 when the
    /// dataset is fully resident).
    pub faults: usize,
    /// Hot partitions evicted (spilled) during the batch.
    pub evictions: usize,
    /// Segment bytes read from disk by the batch's faults.
    pub segment_bytes_read: usize,
    /// Slices skipped because their partition is quarantined (its segment
    /// failed verification after retries) and no retained sketch covers
    /// it. The batch's results are exact over the remaining selection;
    /// non-zero only when the store allows degraded serving.
    pub degraded: usize,
    /// Wall-clock seconds for planning + execution + demux.
    pub secs: f64,
}

impl BatchReport {
    /// One-line human rendering for CLI/bench output.
    pub fn line(&self) -> String {
        let mut line = format!(
            "batch: {} queries -> {} merged ranges, {} segments, \
             {} partition slices, {} tasks in {}",
            self.queries,
            self.merged_ranges,
            self.segments,
            self.partitions_touched,
            self.tasks,
            humansize::secs(self.secs),
        );
        if self.zone_pruned > 0 {
            line.push_str(&format!(" | zone-pruned: {}", self.zone_pruned));
        }
        if self.filter_pruned > 0 {
            line.push_str(&format!(" | filter-pruned: {}", self.filter_pruned));
        }
        if self.agg_answered > 0 {
            line.push_str(&format!(
                " | agg-answered: {} ({} avoided)",
                self.agg_answered,
                humansize::bytes(self.bytes_avoided),
            ));
        }
        if self.blocks_covered > 0 || self.blocks_pruned > 0 {
            line.push_str(&format!(
                " | blocks: {} covered, {} pruned",
                self.blocks_covered, self.blocks_pruned,
            ));
        }
        if self.faults > 0 || self.evictions > 0 {
            line.push_str(&format!(
                " | tiered: {} faults, {} evictions, {} read",
                self.faults,
                self.evictions,
                humansize::bytes(self.segment_bytes_read),
            ));
        }
        if self.degraded > 0 {
            line.push_str(&format!(
                " | DEGRADED: {} quarantined slice(s) skipped",
                self.degraded
            ));
        }
        line
    }

    /// JSON dump, matching the session-metrics conventions.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("queries", Json::num(self.queries as f64)),
            ("merged_ranges", Json::num(self.merged_ranges as f64)),
            ("segments", Json::num(self.segments as f64)),
            ("partitions_touched", Json::num(self.partitions_touched as f64)),
            ("zone_pruned", Json::num(self.zone_pruned as f64)),
            ("filter_pruned", Json::num(self.filter_pruned as f64)),
            ("agg_answered", Json::num(self.agg_answered as f64)),
            ("rows_avoided", Json::num(self.rows_avoided as f64)),
            ("bytes_avoided", Json::num(self.bytes_avoided as f64)),
            ("blocks_covered", Json::num(self.blocks_covered as f64)),
            ("blocks_pruned", Json::num(self.blocks_pruned as f64)),
            ("tasks", Json::num(self.tasks as f64)),
            ("faults", Json::num(self.faults as f64)),
            ("evictions", Json::num(self.evictions as f64)),
            ("segment_bytes_read", Json::num(self.segment_bytes_read as f64)),
            ("degraded", Json::num(self.degraded as f64)),
            ("secs", Json::num(self.secs)),
        ])
    }
}

/// Simple scoped timer over the monotonic clock. `Instant::elapsed`
/// saturates at zero, so readings can never be negative.
pub struct Timer(Instant);

impl Timer {
    /// Start timing now.
    pub fn start() -> Timer {
        Timer(Instant::now())
    }

    /// Seconds elapsed since [`Timer::start`].
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }

    /// Time elapsed since [`Timer::start`], for histogram recording.
    pub fn elapsed(&self) -> Duration {
        self.0.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn snap(scanned: usize) -> CounterSnapshot {
        CounterSnapshot {
            partitions_scanned: scanned,
            rows_scanned: scanned * 100,
            bytes_materialized: scanned * 1000,
            ..CounterSnapshot::default()
        }
    }

    #[test]
    fn records_deltas() {
        let mut m = SessionMetrics::new();
        m.record(1, "default", 0.5, 1 << 20, snap(0), snap(15));
        m.record(2, "default", 0.7, 2 << 20, snap(15), snap(30));
        assert_eq!(m.records[0].partitions_scanned, 15);
        assert_eq!(m.records[1].partitions_scanned, 15);
        assert_eq!(m.records[1].rows_scanned, 1500);
    }

    #[test]
    fn accumulated_time_monotone() {
        let mut m = SessionMetrics::new();
        for i in 1..=5 {
            m.record(i, "oseba", 0.1 * i as f64, i << 20, snap(0), snap(0));
        }
        let acc = m.accumulated_time();
        assert_eq!(acc.len(), 5);
        assert!(acc.windows(2).all(|w| w[1] > w[0]));
        assert!((acc[4] - 1.5).abs() < 1e-9);
        assert_eq!(m.memory_series(), vec![1 << 20, 2 << 20, 3 << 20, 4 << 20, 5 << 20]);
    }

    #[test]
    fn json_and_table_render() {
        let mut m = SessionMetrics::new();
        m.record(1, "oseba", 0.25, 42, snap(0), snap(1));
        let j = m.to_json().to_string();
        assert!(j.contains("\"phase\":1"));
        assert!(j.contains("\"method\":\"oseba\""));
        let t = m.table();
        assert!(t.contains("oseba"));
        assert!(t.contains("phase"));
    }

    #[test]
    fn batch_report_renders() {
        let r = BatchReport {
            queries: 8,
            merged_ranges: 3,
            segments: 11,
            partitions_touched: 9,
            tasks: 6,
            secs: 0.125,
            ..BatchReport::default()
        };
        let line = r.line();
        assert!(line.contains("8 queries"));
        assert!(line.contains("3 merged ranges"));
        assert!(!line.contains("tiered"), "resident batches stay terse");
        assert!(!line.contains("zone-pruned"), "predicate-free batches stay terse");
        assert!(!line.contains("agg-answered"), "scan-only batches stay terse");
        let j = r.to_json().to_string();
        assert!(j.contains("\"merged_ranges\":3"));
        assert!(j.contains("\"partitions_touched\":9"));
        assert!(j.contains("\"zone_pruned\":0"));
        assert!(j.contains("\"agg_answered\":0"));
        let tiered = BatchReport { faults: 2, segment_bytes_read: 1 << 20, ..r };
        assert!(tiered.line().contains("2 faults"), "{}", tiered.line());
        assert!(tiered.to_json().to_string().contains("\"faults\":2"));
        let pruned = BatchReport { zone_pruned: 4, ..r };
        assert!(pruned.line().contains("zone-pruned: 4"), "{}", pruned.line());
        assert!(!pruned.line().contains("filter-pruned"), "equality-free stays terse");
        assert!(pruned.to_json().to_string().contains("\"filter_pruned\":0"));
        let fpruned = BatchReport { filter_pruned: 3, ..r };
        assert!(fpruned.line().contains("filter-pruned: 3"), "{}", fpruned.line());
        assert!(fpruned.to_json().to_string().contains("\"filter_pruned\":3"));
        let answered =
            BatchReport { agg_answered: 5, rows_avoided: 100, bytes_avoided: 2400, ..r };
        assert!(answered.line().contains("agg-answered: 5"), "{}", answered.line());
        assert!(answered.to_json().to_string().contains("\"rows_avoided\":100"));
        assert!(!r.line().contains("blocks:"), "block-free batches stay terse");
        assert!(r.to_json().to_string().contains("\"blocks_covered\":0"));
        let blocks = BatchReport { blocks_covered: 7, blocks_pruned: 2, ..r };
        assert!(blocks.line().contains("blocks: 7 covered, 2 pruned"), "{}", blocks.line());
        assert!(blocks.to_json().to_string().contains("\"blocks_pruned\":2"));
        assert!(!r.line().contains("DEGRADED"), "healthy batches stay terse");
        assert!(r.to_json().to_string().contains("\"degraded\":0"));
        let degraded = BatchReport { degraded: 1, ..r };
        assert!(degraded.line().contains("DEGRADED: 1"), "{}", degraded.line());
        assert!(degraded.to_json().to_string().contains("\"degraded\":1"));
    }

    #[test]
    fn timer_runs() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
        assert!(t.elapsed() >= std::time::Duration::from_millis(5));
    }

    #[test]
    fn record_is_monotonic_safe() {
        // Snapshots captured out of order across threads: `after` is
        // behind `before`. Deltas must clamp to zero, not underflow.
        let mut m = SessionMetrics::new();
        m.record(1, "oseba", -0.5, 0, snap(30), snap(10));
        let r = &m.records[0];
        assert_eq!(r.partitions_scanned, 0);
        assert_eq!(r.rows_scanned, 0);
        assert_eq!(r.bytes_materialized, 0);
        assert_eq!(r.secs, 0.0, "negative wall readings clamp to zero");
        // Zero-width phase: identical snapshots, zero seconds.
        m.record(2, "oseba", 0.0, 0, snap(10), snap(10));
        assert_eq!(m.records[1].partitions_scanned, 0);
        let j = m.to_json().to_string();
        assert!(!j.contains('-'), "no negative durations in JSON: {j}");
    }
}
