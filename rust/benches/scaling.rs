//! Raw-data-size scaling (paper §IV closing remark: "a larger size of raw
//! data can result in a bigger time consumption during selecting bulk
//! data") — the per-phase time of each method as the dataset grows, with
//! fixed-width selections.
//!
//! Expected shape: the default method's per-phase cost grows ~linearly
//! with raw size (full scan every phase); Oseba's grows only with the
//! *selection* size, so the default/oseba gap widens with scale.
//!
//! Run: `cargo bench --bench scaling` (OSEBA_SCALING_MAX to extend).

mod common;

use oseba::analysis::random_periods;
use oseba::bench::BenchConfig;
use oseba::config::parse_bytes;
use oseba::coordinator::{run_session, IndexKind, Method};
use oseba::util::humansize;

fn main() {
    let cfg = BenchConfig::from_env();
    let backend = common::backend_kind();
    let max = std::env::var("OSEBA_SCALING_MAX")
        .ok()
        .map(|v| parse_bytes(&v).expect("OSEBA_SCALING_MAX"))
        .unwrap_or(256 << 20);

    let mut sizes = vec![8usize << 20];
    while *sizes.last().unwrap() < max {
        sizes.push(sizes.last().unwrap() * 2);
    }
    // Fixed-width selections: 5 periods × 2% of the span each, so the
    // selected volume grows with the data but the *fraction* is constant.
    let periods = random_periods(5, 0.02, 42);

    oseba::bench::section(&format!(
        "scaling: per-session time vs raw size (backend {:?}, {} iters)",
        backend, cfg.iters
    ));
    println!(
        "{:<12} {:>12} {:>12} {:>9} {:>14}",
        "raw size", "default", "oseba", "speedup", "gap"
    );

    let mut speedups = Vec::new();
    let mut points: Vec<(usize, f64, f64)> = Vec::new();
    for &bytes in &sizes {
        let mut totals = [0.0f64; 2];
        for (mi, method) in [Method::Default, Method::Oseba].into_iter().enumerate() {
            for _ in 0..cfg.iters.max(1) {
                let (coord, ds, _) = common::setup(bytes, 15, backend);
                let rep =
                    run_session(&coord, &ds, method, IndexKind::Cias, &periods, 0, false)
                        .unwrap();
                totals[mi] += rep.metrics.accumulated_time().last().unwrap();
            }
            totals[mi] /= cfg.iters.max(1) as f64;
        }
        let speedup = totals[0] / totals[1];
        speedups.push(speedup);
        points.push((bytes, totals[0], totals[1]));
        println!(
            "{:<12} {:>12} {:>12} {:>8.2}x {:>14}",
            humansize::bytes(bytes),
            humansize::secs(totals[0]),
            humansize::secs(totals[1]),
            speedup,
            humansize::secs(totals[0] - totals[1])
        );
    }

    // Shape: the advantage at the largest size exceeds the smallest.
    assert!(
        speedups.last().unwrap() > speedups.first().unwrap(),
        "gap must widen with raw size: {speedups:?}"
    );
    println!(
        "\nshape check: speedup grows with raw size ✓ ({:.2}x → {:.2}x)",
        speedups.first().unwrap(),
        speedups.last().unwrap()
    );

    use oseba::util::json::Json;
    common::write_bench_json(
        "scaling",
        Json::obj(vec![
            ("bench", Json::str("scaling")),
            (
                "points",
                Json::arr(
                    points
                        .iter()
                        .map(|&(bytes, default_secs, oseba_secs)| {
                            Json::obj(vec![
                                ("raw_bytes", Json::num(bytes as f64)),
                                ("default_secs", Json::num(default_secs)),
                                ("oseba_secs", Json::num(oseba_secs)),
                                ("speedup", Json::num(default_secs / oseba_secs)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    );
}
