//! Columnar in-memory storage: schemas, record batches and partitions.

pub mod batch;
pub mod csv;
pub mod partition;
pub mod schema;

pub use batch::{BatchBuilder, RecordBatch};
pub use partition::{partition_batch, partition_batch_uniform, Partition, BLOCK_ROWS};
pub use schema::Schema;
