//! **§III-A vs §III-B micro-benchmark**: table vs CIAS build time, lookup
//! latency and metadata footprint as the partition count m grows
//! 15 → 1M.
//!
//! Expected shape (the paper's complexity argument): table space grows
//! linearly in m and lookup ~log m; CIAS space and lookup stay flat (all
//! regular partitions collapse into the compressed index).
//!
//! Run: `cargo bench --bench index_micro`.

mod common;

use oseba::bench::{bench, table, BenchConfig};
use oseba::index::{Cias, ContentIndex, PartitionMeta, RangeQuery, TableIndex};
use oseba::util::humansize;
use oseba::util::rng::Xoshiro256;

/// Synthetic regular metadata for m partitions (no data needed: the index
/// operates on metadata only — that is the point).
fn metas(m: usize, rows_per: usize, step: i64) -> Vec<PartitionMeta> {
    (0..m)
        .map(|i| {
            let key_min = (i * rows_per) as i64 * step;
            PartitionMeta {
                id: i,
                key_min,
                key_max: key_min + (rows_per as i64 - 1) * step,
                rows: rows_per,
                step: Some(step),
            }
        })
        .collect()
}

fn main() {
    let cfg = BenchConfig::from_env();
    let rows_per = 4096;
    let step = 3600i64;
    let sizes = [15usize, 100, 1_000, 10_000, 100_000, 1_000_000];

    oseba::bench::section("index build + footprint vs partition count");
    println!(
        "{:<10} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "m", "table bytes", "cias bytes", "table build", "cias build", "asl"
    );
    for &m in &sizes {
        let ms = metas(m, rows_per, step);
        let t_build = {
            let ms = ms.clone();
            bench(&cfg, "t", move || {
                let _ = TableIndex::from_meta(ms.clone()).unwrap();
            })
        };
        let c_build = {
            let ms = ms.clone();
            bench(&cfg, "c", move || {
                let _ = Cias::from_meta(ms.clone()).unwrap();
            })
        };
        let t = TableIndex::from_meta(ms.clone()).unwrap();
        let c = Cias::from_meta(ms).unwrap();
        println!(
            "{:<10} {:>12} {:>12} {:>12} {:>12} {:>8}",
            m,
            humansize::bytes(t.memory_bytes()),
            humansize::bytes(c.memory_bytes()),
            humansize::secs(t_build.summary.p50),
            humansize::secs(c_build.summary.p50),
            c.asl_len()
        );
        assert!(c.memory_bytes() <= 128, "cias stays O(1) on regular data");
    }

    oseba::bench::section("point-range lookup latency (1000 random queries/iter)");
    let mut results = Vec::new();
    for &m in &sizes {
        let ms = metas(m, rows_per, step);
        let span = (m * rows_per) as i64 * step;
        let t = TableIndex::from_meta(ms.clone()).unwrap();
        let c = Cias::from_meta(ms).unwrap();
        // Narrow queries: lookup cost, not output size, dominates.
        let queries: Vec<RangeQuery> = {
            let mut rng = Xoshiro256::seeded(m as u64);
            (0..1000)
                .map(|_| {
                    let lo = rng.below(span as u64) as i64;
                    RangeQuery { lo, hi: lo + step * 64 }
                })
                .collect()
        };
        let qs = queries.clone();
        results.push(bench(&cfg, &format!("table  m={m}"), move || {
            let mut acc = 0usize;
            for q in &qs {
                acc += t.lookup(*q).len();
            }
            std::hint::black_box(acc);
        }));
        let qs = queries.clone();
        results.push(bench(&cfg, &format!("cias   m={m}"), move || {
            let mut acc = 0usize;
            for q in &qs {
                acc += c.lookup(*q).len();
            }
            std::hint::black_box(acc);
        }));
    }
    println!("{}", table(&results));

    // Shape: cias lookup time must not grow with m (compare first vs last).
    let cias_first = results[1].summary.p50;
    let cias_last = results[results.len() - 1].summary.p50;
    println!(
        "cias p50 at m=15: {} | at m=1M: {} (flat-ness ratio {:.2})",
        humansize::secs(cias_first),
        humansize::secs(cias_last),
        cias_last / cias_first
    );

    // ---- segment_stats inner loop: 8-lane fold vs scalar reference -----
    oseba::bench::section("segment_stats fold: 8-lane (shipping) vs scalar reference");
    use oseba::runtime::{AnalysisBackend, NativeBackend};
    use oseba::util::stats::Moments;
    let mut rng = Xoshiro256::seeded(7);
    let blocks: Vec<Vec<f32>> = (0..256)
        .map(|_| (0..4096).map(|_| (rng.next_f32() - 0.5) * 100.0).collect())
        .collect();

    // Scalar single-accumulator reference (the pre-vectorization loop).
    let scalar_fold = |xs: &[f32]| -> Moments {
        let mut mx = -3.4e38f32;
        let mut mn = 3.4e38f32;
        let mut sum = 0f32;
        let mut sumsq = 0f32;
        let mut nans = 0usize;
        for &x in xs {
            if x.is_nan() {
                nans += 1;
                continue;
            }
            mx = mx.max(x);
            mn = mn.min(x);
            sum += x;
            sumsq += x * x;
        }
        let mut m = Moments::from_kernel(mx, mn, sum, sumsq, (xs.len() - nans) as f32);
        m.nans = nans as f64;
        m
    };

    // Correctness vs the f64 scan oracle before timing anything.
    for b in blocks.iter().take(8) {
        let got = NativeBackend.segment_stats(b, 0, b.len()).expect("stats");
        let want = Moments::scan(b);
        assert_eq!(got.count, want.count);
        assert_eq!(got.max, want.max);
        assert_eq!(got.min, want.min);
        assert!((got.mean() - want.mean()).abs() < 1e-3);
    }

    let mut fold_results = Vec::new();
    {
        let blocks = &blocks;
        fold_results.push(bench(&cfg, "segment_stats 8-lane (256 blocks)", move || {
            let mut acc = Moments::EMPTY;
            for b in blocks {
                acc = acc.merge(NativeBackend.segment_stats(b, 0, b.len()).expect("stats"));
            }
            std::hint::black_box(acc.count);
        }));
    }
    {
        let blocks = &blocks;
        fold_results.push(bench(&cfg, "scalar reference   (256 blocks)", move || {
            let mut acc = Moments::EMPTY;
            for b in blocks {
                acc = acc.merge(scalar_fold(b));
            }
            std::hint::black_box(acc.count);
        }));
    }
    println!("{}", table(&fold_results));
    let lanes = fold_results[0].summary.p50;
    let scalar = fold_results[1].summary.p50;
    println!(
        "8-lane {} vs scalar {} -> {:.2}x per 1 MiB of f32 blocks",
        humansize::secs(lanes),
        humansize::secs(scalar),
        scalar / lanes.max(1e-12)
    );

    // ---- masked fold: branchless 8-lane vs the scalar closure path -----
    oseba::bench::section("masked fold: branchless 8-lane vs scalar closure (50% selected)");
    use oseba::util::stats::fold_stats_f32_masked;
    let masks: Vec<Vec<bool>> = blocks
        .iter()
        .enumerate()
        .map(|(i, b)| {
            let mut rng = Xoshiro256::seeded(1000 + i as u64);
            b.iter().map(|_| rng.below(2) == 0).collect()
        })
        .collect();
    // Scalar reference: the pre-vectorization filtered path (branch per
    // row, sequential f64 absorb).
    let scalar_masked = |xs: &[f32], mask: &[bool]| -> Moments {
        let mut m = Moments::EMPTY;
        for (&x, &keep) in xs.iter().zip(mask) {
            if keep {
                m.absorb(x);
            }
        }
        m
    };
    // Correctness before timing: same counts and extrema, close sums.
    for (b, mask) in blocks.iter().zip(&masks).take(8) {
        let (mx, mn, sum, sumsq, selected, nans) = fold_stats_f32_masked(b, mask);
        let mut got = Moments::from_kernel(mx, mn, sum, sumsq, (selected - nans) as f32);
        got.nans = nans as f64;
        let want = scalar_masked(b, mask);
        assert_eq!(got.count, want.count);
        assert_eq!(got.max, want.max);
        assert_eq!(got.min, want.min);
        assert!((got.mean() - want.mean()).abs() < 1e-3);
    }
    let mut masked_results = Vec::new();
    {
        let (blocks, masks) = (&blocks, &masks);
        masked_results.push(bench(&cfg, "masked fold 8-lane (256 blocks)", move || {
            let mut acc = 0f64;
            for (b, mask) in blocks.iter().zip(masks) {
                let (_, _, sum, _, _, _) = fold_stats_f32_masked(b, mask);
                acc += sum as f64;
            }
            std::hint::black_box(acc);
        }));
    }
    {
        let (blocks, masks) = (&blocks, &masks);
        masked_results.push(bench(&cfg, "scalar closure     (256 blocks)", move || {
            let mut acc = Moments::EMPTY;
            for (b, mask) in blocks.iter().zip(masks) {
                acc = acc.merge(scalar_masked(b, mask));
            }
            std::hint::black_box(acc.count);
        }));
    }
    println!("{}", table(&masked_results));
    let masked_lanes = masked_results[0].summary.min;
    let masked_scalar = masked_results[1].summary.min;
    println!(
        "masked 8-lane {} vs scalar closure {} -> {:.2}x at 50% selectivity",
        humansize::secs(masked_lanes),
        humansize::secs(masked_scalar),
        masked_scalar / masked_lanes.max(1e-12)
    );
    assert!(
        masked_lanes < masked_scalar,
        "branchless masked fold must beat the scalar closure at 50% selectivity \
         ({masked_lanes:.2e}s vs {masked_scalar:.2e}s)"
    );

    // ---- observability overhead: instrumented vs uninstrumented stats ----
    oseba::bench::section("metrics overhead on the stats path (registry on vs off)");
    use oseba::coordinator::Query;
    let (coord, ds, _raw) = common::setup_native(4 << 20, 16);
    let cias = Cias::build(ds.partitions()).expect("cias");
    let key_hi = ds.key_max().unwrap_or(0);
    let stats_queries: Vec<Query> = {
        let mut rng = Xoshiro256::seeded(42);
        (0..200)
            .map(|_| {
                let lo = rng.below((key_hi - step * 64) as u64 + 1) as i64;
                Query::stats(RangeQuery { lo, hi: lo + step * 64 }, 0)
            })
            .collect()
    };
    let run_queries = |label: &str| {
        let (coord, ds, cias, qs) = (&coord, &ds, &cias, &stats_queries);
        bench(&cfg, label, move || {
            for q in qs {
                let _ = coord.execute_plan(ds, cias, q).expect("stats");
            }
        })
    };
    let metrics_on = run_queries("stats x200, metrics on ");
    coord.context().metrics().set_enabled(false);
    let metrics_off = run_queries("stats x200, metrics off");
    coord.context().metrics().set_enabled(true);
    println!("{}", table(&[metrics_on.clone(), metrics_off.clone()]));
    // Min-of-iters: the least-noisy estimate of the true cost of each arm.
    let on_min = metrics_on.summary.min;
    let off_min = metrics_off.summary.min;
    let overhead_ratio = on_min / off_min.max(1e-12);
    let per_query = (on_min - off_min).max(0.0) / stats_queries.len() as f64;
    println!(
        "instrumented {} vs uninstrumented {} -> ratio {:.3} ({:.1e}s/query)",
        humansize::secs(on_min),
        humansize::secs(off_min),
        overhead_ratio,
        per_query
    );
    // ISSUE 7 acceptance: histogram recording costs <5% of the stats
    // path (or, on noisy CI boxes, under 5us absolute per query).
    assert!(
        overhead_ratio < 1.05 || per_query < 5e-6,
        "metrics overhead too high: ratio {overhead_ratio:.3}, {per_query:.2e}s/query"
    );

    use oseba::util::json::Json;
    common::write_bench_json(
        "index_micro",
        Json::obj(vec![
            ("bench", Json::str("index_micro")),
            ("cias_lookup_p50_m15", Json::num(cias_first)),
            ("cias_lookup_p50_m1e6", Json::num(cias_last)),
            ("segment_stats_lanes_p50", Json::num(lanes)),
            ("segment_stats_scalar_p50", Json::num(scalar)),
            ("fold_speedup", Json::num(scalar / lanes.max(1e-12))),
            ("masked_fold_lanes_min", Json::num(masked_lanes)),
            ("masked_fold_scalar_min", Json::num(masked_scalar)),
            ("masked_fold_speedup", Json::num(masked_scalar / masked_lanes.max(1e-12))),
            ("metrics_on_min_secs", Json::num(on_min)),
            ("metrics_off_min_secs", Json::num(off_min)),
            ("metrics_overhead_ratio", Json::num(overhead_ratio)),
        ]),
    );
}
